//! Figure-2 companion example: spectrum analysis of the true softmax
//! attention matrix vs its Nystrom and spectral-shifting approximations,
//! on (a) Gaussian q,k and (b) a slow-decay SPSD kernel — prints the
//! cumulative-eigenvalue series the paper plots plus effective-rank and
//! tail statistics.
//!
//! Run: `cargo run --release --example spectrum_analysis` (no artifacts
//! needed — pure rust analysis path).

use ssaformer::attention::full::attention_matrix;
use ssaformer::attention::spectral_shift::{
    nystrom_matrix_exact, spectral_shift_matrix_exact, MiddleForm,
};
use ssaformer::attention::Tensor2;
use ssaformer::benchkit::Table;
use ssaformer::rngx::Rng;
use ssaformer::spectral::{Spectrum, SpectrumComparison};
use ssaformer::spsd;

fn main() {
    let (n, d, c) = (256, 64, 32);
    let mut rng = Rng::new(0);

    println!("== spectrum of softmax attention vs approximations ==");
    println!("(n={n}, d={d}, c={c} landmarks; rank_rtol=0.05)\n");
    let q = Tensor2::randn(&mut rng, n, d, 1.0);
    let k = Tensor2::randn(&mut rng, n, d, 1.0);
    let s_true = attention_matrix(&q, &k, None);
    let s_ny = nystrom_matrix_exact(&q, &k, c, None);
    let (s_ss, delta) = spectral_shift_matrix_exact(
        &q, &k, c, 0.05, MiddleForm::Eq8, true, None);
    println!("fitted spectral shift delta = {delta:.5}\n");

    let sp_true = Spectrum::of(&s_true);
    let sp_ny = Spectrum::of(&s_ny);
    let sp_ss = Spectrum::of(&s_ss);

    let mut t = Table::new(&["eig index", "cum S (true)", "cum Nystrom", "cum SS"]);
    let step = n / 16;
    for i in (0..n).step_by(step) {
        t.row(&[
            format!("{}", i + 1),
            format!("{:.4}", sp_true.cumulative[i]),
            format!("{:.4}", sp_ny.cumulative[i]),
            format!("{:.4}", sp_ss.cumulative[i]),
        ]);
    }
    println!("{}", t.render());

    let mut s = Table::new(&["statistic", "true S", "Nystrom", "spectral shift"]);
    s.row(&["effective rank".into(),
            format!("{:.1}", sp_true.effective_rank()),
            format!("{:.1}", sp_ny.effective_rank()),
            format!("{:.1}", sp_ss.effective_rank())]);
    s.row(&["eigs < 1e-8".into(),
            format!("{}", sp_true.near_zero_count(1e-8)),
            format!("{}", sp_ny.near_zero_count(1e-8)),
            format!("{}", sp_ss.near_zero_count(1e-8))]);
    s.row(&["tail mass after c".into(),
            format!("{:.4}", sp_true.tail_mass(c)),
            format!("{:.4}", sp_ny.tail_mass(c)),
            format!("{:.4}", sp_ss.tail_mass(c))]);
    println!("{}", s.render());
    println!("Figure-2 claim: the Nystrom spectrum collapses after index c \
              (rank ≤ c)\nwhile the spectral-shifting spectrum keeps a δ \
              floor — no long-tail cliff.\n");

    // (b) slow-decay SPSD kernel — where the paper says Nystrom is weak
    println!("== SPSD kernel with slow power-law spectrum (λ_i = i^-0.5) ==");
    let kmat = spsd::power_law_spsd(&mut rng, 128, 0.5);
    let cols = spsd::sample_columns(&mut rng, 128, 16,
                                    spsd::ColumnSampling::Strided);
    let ny = spsd::prototype_model(&kmat, &cols);
    let ss = spsd::modified_ss_model(&kmat, &cols, 0.3);
    let cmp_ny = SpectrumComparison::new(&kmat, &ny.approx);
    let cmp_ss = SpectrumComparison::new(&kmat, &ss.approx);
    println!("rel fro error: Nystrom {:.4}  SS {:.4}  (fitted δ={:.4})",
             spsd::rel_fro_error(&kmat, &ny.approx),
             spsd::rel_fro_error(&kmat, &ss.approx),
             ss.delta);
    println!("approx effective rank: Nystrom {:.1}  SS {:.1}  (true {:.1})",
             cmp_ny.approx_spectrum.effective_rank(),
             cmp_ss.approx_spectrum.effective_rank(),
             cmp_ny.true_spectrum.effective_rank());
}
