//! Serving demo: starts the full L3 stack (4-worker coordinator over a
//! sharded queue, embedding cache, TCP server) on an ephemeral port,
//! replays a Poisson workload trace against it from client threads —
//! then replays a slice of it again to light up the cache, fires one
//! expired-deadline request, and prints the latency/throughput report.
//! The paper's sec-9 deployment scenario in miniature.
//!
//! The execution backend is auto-selected: XLA artifacts when
//! `artifacts/` is built, otherwise the in-process CPU kernel backend —
//! so this example serves real embeddings with no artifacts at all.
//!
//! Run: `cargo run --release --example serve_attention
//! [variant] [layers] [projections]` — `variant` is any of
//! full|nystrom|ss|linformer|lsh|sparse or a per-layer list like
//! `ss,ss,full` (the AttentionOp seam makes them interchangeable),
//! `layers` the encoder depth (default 1, the seed single-pass model),
//! `projections` `on`/`off` (QKV/output maps in the full blocks).
//! Optionally `make artifacts` first to exercise the XLA path.

use ssaformer::config::{ServingConfig, Variant};
use ssaformer::coordinator::{Coordinator, ExecBackend};
use ssaformer::server::{serve, Client};
use ssaformer::workload::{generate_trace, LengthDist, TraceConfig};
use std::sync::Arc;

fn main() {
    let variants = std::env::args()
        .nth(1)
        .and_then(|s| Variant::parse_list(&s))
        .unwrap_or_else(|| vec![Variant::SpectralShift]);
    let layers: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| variants.len().max(1));
    let projections = std::env::args().nth(3).as_deref() == Some("on");

    println!("== ssaformer serving demo ({}, {} layer{}, projections {}) ==",
             variants.iter().map(|v| v.token()).collect::<Vec<_>>().join(","),
             layers, if layers == 1 { "" } else { "s" },
             if projections { "on" } else { "off" });
    let (variant, layer_variants) = ServingConfig::split_variants(variants);
    let cfg = ServingConfig {
        variant,
        layer_variants,
        layers,
        projections,
        max_batch: 4,
        max_wait_ms: 10,
        queue_capacity: 128,
        workers: 4,
        queue_shards: 2,
        cache_capacity: 256,
        ..Default::default()
    };
    cfg.validate().expect("example serving config");
    let backend = ExecBackend::auto(&cfg).expect("backend");
    let t0 = std::time::Instant::now();
    let coordinator = Arc::new(Coordinator::start(backend, &cfg).expect("start"));
    let backend_name = coordinator.backend().name();
    println!("backend: {backend_name} (warmup {:?}); {} workers, {} shards, \
              cache {} entries",
             t0.elapsed(), coordinator.workers(), coordinator.queue_shards(),
             coordinator.cache_capacity());
    println!("model: {}", coordinator.model_desc());

    let (addr, handle) = serve(coordinator.clone(), "127.0.0.1:0", 4)
        .expect("bind");
    println!("listening on {addr}");

    // Poisson trace: 60 requests, zipf-skewed lengths over the buckets
    let trace = generate_trace(&TraceConfig {
        rate: 40.0,
        count: 60,
        lengths: LengthDist::ZipfBuckets(1.1),
        buckets: vec![128, 256, 512],
        vocab: 2048,
        seed: 7,
    });

    // replay from 4 client threads, honoring arrival offsets
    let start = std::time::Instant::now();
    let mut handles = Vec::new();
    for chunk in trace.chunks(15) {
        let chunk: Vec<_> = chunk.to_vec();
        let addr = addr;
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("connect");
            let mut ok = 0;
            for req in &chunk {
                // pace to the trace arrival time
                let now = start.elapsed();
                if req.arrival > now {
                    std::thread::sleep(req.arrival - now);
                }
                let reply = client.encode(req.id, &req.tokens).expect("encode");
                if reply.starts_with("OK") {
                    ok += 1;
                } else {
                    eprintln!("  {reply}");
                }
            }
            ok
        }));
    }
    let ok: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let wall = start.elapsed();

    println!("\nreplayed {} requests ({} ok, served by {backend_name}) \
              in {:?} -> {:.1} req/s",
             trace.len(), ok, wall, ok as f64 / wall.as_secs_f64());

    // replay the first few sequences again: identical token content now
    // hits the embedding cache (visible as `cache: hits=` in STATS)
    let mut client = Client::connect(&addr).unwrap();
    for req in trace.iter().take(8) {
        let reply = client.encode(1000 + req.id, &req.tokens).expect("re-encode");
        assert!(reply.starts_with("OK"), "{reply}");
    }
    // and an *uncached* request with an already-blown deadline draws
    // `ERR deadline` without ever occupying a batch slot (a cached one
    // would still be served — hits are free)
    let reply = client
        .encode_with_deadline(9999, &[1, 2, 3, 4, 5], 0)
        .expect("deadline encode");
    println!("expired-deadline request -> {reply}");

    // the STATS block leads with backend + worker-pool identification
    println!("\nserver metrics:\n{}", client.stats().unwrap());
    handle.stop();
}
