//! End-to-end train → checkpoint → serve → error-bound demo, entirely
//! on the CPU kernel core (no artifacts, no toolchain beyond cargo):
//!
//! 1. train a ≥2-layer projected encoder deterministically with the
//!    in-repo trainer (`train::cpu`), printing the per-epoch loss
//!    curve and failing hard unless it strictly decreases;
//! 2. save the trained weights as a real `SSAFCKPT` checkpoint;
//! 3. serve that checkpoint through `weights`/`init = load` twice —
//!    one coordinator driven in-process, one behind a real TCP server
//!    — and check the `ENCODE` reply is bitwise what the in-process
//!    forward implies;
//! 4. sweep the approximation error of every variant against exact
//!    softmax on the *trained* weights and write
//!    `BENCH_error_bound.json`.
//!
//! Run: `cargo run --release --example train_tiny [--smoke]`
//! (`--smoke` or `SSAF_TRAIN_SMOKE=1` shrinks the run for CI lanes;
//! the legacy XLA-artifact path moved to `tests/integration_train.rs`.)

use ssaformer::config::{InitPolicy, ServingConfig, Variant};
use ssaformer::coordinator::{Coordinator, ExecBackend};
use ssaformer::coordinator::CpuModel;
use ssaformer::eval::{default_output_path, error_bound_sweep, ErrorBoundConfig};
use ssaformer::model::checkpoint;
use ssaformer::server;
use ssaformer::train::{train_cpu, CpuTrainConfig};
use std::io::{BufRead, BufReader, Write};
use std::sync::Arc;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("SSAF_TRAIN_SMOKE").is_ok_and(|v| v == "1");
    let cfg = if smoke {
        CpuTrainConfig {
            steps_per_epoch: 6,
            epochs: 2,
            batch: 4,
            corpus_lines: 120,
            ..Default::default()
        }
    } else {
        CpuTrainConfig::default()
    };
    println!(
        "training: d_model={} heads={} layers={} (projected) vocab={} \
         seq={} batch={} {} epochs x {} steps, {} lr={}{}",
        cfg.d_model, cfg.n_heads, cfg.layers, cfg.vocab, cfg.seq, cfg.batch,
        cfg.epochs, cfg.steps_per_epoch, cfg.optimizer.token(), cfg.lr,
        if smoke { " [smoke]" } else { "" });

    // 1. deterministic CPU training
    let outcome = train_cpu(&cfg);
    print!("{}", outcome.report.render());
    if !outcome.report.epoch_loss_strictly_decreasing() {
        eprintln!("FAIL: epoch losses {:?} are not strictly decreasing",
                  outcome.report.epoch_losses);
        std::process::exit(1);
    }
    println!("epoch loss strictly decreasing: ok");

    // 2. real SSAFCKPT checkpoint
    let ckpt_path = std::env::temp_dir().join(format!(
        "ssaformer-train-tiny-{}.ckpt", std::process::id()));
    checkpoint::save(&outcome.stack, &ckpt_path).expect("save checkpoint");
    println!("checkpoint: {} ({} bytes)", ckpt_path.display(),
             std::fs::metadata(&ckpt_path).map(|m| m.len()).unwrap_or(0));

    // 3. serve it through init = load — in-process and over TCP
    let serving = ServingConfig {
        artifacts_dir: "no/such/artifacts".into(),
        variant: Variant::Full,
        layers: cfg.layers,
        ffn_mult: cfg.ffn_mult,
        projections: true,
        init: InitPolicy::Load,
        weights: Some(ckpt_path.to_string_lossy().into_owned()),
        max_batch: 2,
        max_wait_ms: 2,
        queue_capacity: 32,
        workers: 1,
        cache_capacity: 0,
        ..Default::default()
    };
    serving.validate().expect("serving config");
    let start = || {
        Arc::new(Coordinator::start(
            ExecBackend::auto(&serving).expect("backend"), &serving)
            .expect("coordinator"))
    };
    let tokens: Vec<i32> = (0..60).map(|i| 3 + (i * 23) % 2000).collect();

    let local = start();
    let reference = local
        .submit_blocking(tokens.clone())
        .expect("submit").embedding.expect("embedding");
    let expect_line = format!(
        "OK 1 {}",
        reference.iter().take(8).map(|x| format!("{x:.5}"))
            .collect::<Vec<_>>().join(" "));

    let remote = start();
    let (addr, handle) =
        server::serve(remote.clone(), "127.0.0.1:0", 2).expect("server");
    let mut conn = std::net::TcpStream::connect(addr).expect("connect");
    let line = format!(
        "ENCODE 1 {}\n",
        tokens.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(" "));
    conn.write_all(line.as_bytes()).expect("send");
    let mut reply = String::new();
    BufReader::new(conn.try_clone().expect("clone"))
        .read_line(&mut reply).expect("reply");
    handle.stop();
    if reply.trim_end() != expect_line {
        eprintln!("FAIL: TCP ENCODE reply diverges from the in-process \
                   forward\n  got:  {}\n  want: {}",
                  reply.trim_end(), expect_line);
        std::process::exit(1);
    }
    println!("served via init=load: TCP ENCODE bitwise-equal to the \
              in-process forward: ok");

    // 4. error-bound sweep on the trained weights
    let eval_cfg = ErrorBoundConfig {
        samples: if smoke { 2 } else { 4 },
        ..Default::default()
    };
    let model = CpuModel::new(outcome.model_config, Variant::Full);
    let report = error_bound_sweep(&model, &outcome.stack, &eval_cfg);
    print!("{}", report.render());
    let json_path = default_output_path();
    std::fs::write(json_path, report.to_json()).expect("write json");
    println!("wrote {json_path}");

    let _ = std::fs::remove_file(&ckpt_path);
}
