//! E10 end-to-end validation: train the tiny MLM transformer through
//! the AOT train-step artifact (fwd+bwd+Adam compiled by XLA, driven
//! entirely from rust) on the synthetic bigram corpus, for both the
//! exact-attention and spectral-shifting variants, and print the loss
//! curves recorded in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example train_tiny [steps]`

use ssaformer::config::Variant;
use ssaformer::runtime::Engine;
use ssaformer::train::{train, TrainConfig};

fn main() {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(120);

    let engine = Engine::new("artifacts").expect("engine");
    let m = engine.manifest();
    println!("model: d_model={} layers={} heads={} vocab={} params={}",
             m.hyper["d_model"], m.hyper["n_layers"], m.hyper["n_heads"],
             m.hyper["vocab"], m.param_count);

    for variant in [Variant::SpectralShift, Variant::Full] {
        println!("\n==== training with {} attention ({} steps) ====",
                 variant.token(), steps);
        let cfg = TrainConfig {
            variant,
            steps,
            seed: 0,
            corpus_lines: 2000,
            log_every: 10,
        };
        match train(&engine, &cfg) {
            Ok(report) => print!("{}", report.render()),
            Err(e) => {
                eprintln!("train {}: {e}", variant.token());
                std::process::exit(1);
            }
        }
    }
    println!("\n(identical data order per seed: the curves are directly \
              comparable — see EXPERIMENTS.md §E10)");
}
