//! Quickstart: the one-screen tour of the public API.
//!
//! 1. pure-rust spectral-shifting attention vs exact attention,
//! 2. the Lemma-1 exact-recovery property on a constructed SPSD matrix,
//! 3. (if `make artifacts` has run) one batched encode through the AOT
//!    XLA artifact — the actual serving hot path.
//!
//! Run: `cargo run --release --example quickstart`

use ssaformer::attention::{
    softmax_attention, spectral_shift_attention, SpectralShiftConfig, Tensor2,
};
use ssaformer::config::Variant;
use ssaformer::rngx::Rng;
use ssaformer::runtime::{ArtifactKind, Engine};
use ssaformer::spsd;

fn main() {
    // ---- 1. O(n) spectral-shifting attention vs O(n²) exact ----------
    let (n, d, c) = (1024, 64, 64);
    let mut rng = Rng::new(0);
    let q = Tensor2::randn(&mut rng, n, d, 1.0);
    let k = Tensor2::randn(&mut rng, n, d, 1.0);
    let v = Tensor2::randn(&mut rng, n, d, 1.0);

    let t0 = std::time::Instant::now();
    let exact = softmax_attention(&q, &k, &v, None);
    let t_exact = t0.elapsed();

    let cfg = SpectralShiftConfig::new(c);
    let t1 = std::time::Instant::now();
    let approx = spectral_shift_attention(&q, &k, &v, &cfg);
    let t_ss = t1.elapsed();

    let rel: f32 = {
        let num: f32 = approx.data.iter().zip(&exact.data)
            .map(|(a, b)| (a - b).abs()).sum();
        let den: f32 = exact.data.iter().map(|b| b.abs()).sum();
        num / den
    };
    println!("attention n={n} d={d} c={c}");
    println!("  exact softmax : {:?}", t_exact);
    println!("  spectral shift: {:?}  ({:.1}x faster, rel-err {:.3})",
             t_ss, t_exact.as_secs_f64() / t_ss.as_secs_f64(), rel);

    // ---- 2. Lemma 1: exact recovery on spike+flat-tail SPSD ----------
    let theta = 0.4;
    let kmat = spsd::spiked_spsd(&mut rng, 64, 5, 6.0, 4.0, theta);
    let cols = spsd::sample_columns(&mut rng, 64, 12,
                                    spsd::ColumnSampling::UniformRandom);
    let nys = spsd::prototype_model(&kmat, &cols);
    let mss = spsd::modified_ss_model_shifted(&kmat, &cols, theta, 1e-8);
    println!("\nSPSD approximation (n=64, 5 spikes, flat tail θ={theta}, c=12):");
    println!("  Nystrom (prototype) rel error: {:.2e}",
             spsd::rel_fro_error(&kmat, &nys.approx));
    println!("  modified spectral shift      : {:.2e}  (Lemma 1: ≈0)",
             spsd::rel_fro_error(&kmat, &mss.approx));

    // ---- 3. serving hot path through the AOT artifact ----------------
    if std::path::Path::new("artifacts/manifest.txt").exists() {
        let engine = Engine::new("artifacts").expect("engine");
        let model = engine
            .load(ArtifactKind::Encode, Variant::SpectralShift, 128)
            .expect("encode artifact");
        let params = engine.init_params().unwrap();
        let params = engine.buffer_f32(&params, &[params.len()]).unwrap();
        let tokens: Vec<i32> = (0..model.entry.batch * 128)
            .map(|i| 3 + (i as i32 % 2000))
            .collect();
        let t2 = std::time::Instant::now();
        let emb = model.encode(&engine, &params, &tokens).unwrap();
        println!("\nAOT serving path (XLA artifact, batch={} seq=128):",
                 model.entry.batch);
        println!("  encode in {:?}, embedding[0][..4] = {:?}",
                 t2.elapsed(), &emb[..4]);
    } else {
        println!("\n(artifacts/ not built — run `make artifacts` to see the \
                  XLA serving path)");
    }
}
