//! Mini property-testing framework (S21) — the crate cache has no
//! proptest, so this provides the subset the invariant tests need:
//! seeded generators, a runner that reports the failing case + seed,
//! and greedy input shrinking for integer-vector cases.
//!
//! Usage:
//! ```ignore
//! proptest_mini::run(100, |g| {
//!     let n = g.usize_in(1, 64);
//!     let xs = g.vec_f32(n, -10.0, 10.0);
//!     prop_assert(xs.len() == n, format!("len {}", xs.len()))
//! });
//! ```

use crate::rngx::Rng;

/// Property outcome: Ok(()) or a failure message.
pub type PropResult = Result<(), String>;

/// Assertion helper.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Input generator handed to each property case.
pub struct Gen {
    rng: Rng,
    /// log of generated values for failure reporting
    trace: Vec<String>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), trace: Vec::new() }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let v = lo + self.rng.below((hi - lo + 1) as u64) as usize;
        self.trace.push(format!("usize={v}"));
        v
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.rng.range(lo, hi);
        self.trace.push(format!("f64={v:.4}"));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.below(2) == 1;
        self.trace.push(format!("bool={v}"));
        v
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        let v: Vec<f32> = (0..len)
            .map(|_| self.rng.range(lo as f64, hi as f64) as f32)
            .collect();
        self.trace.push(format!("vec_f32[{len}]"));
        v
    }

    pub fn vec_usize(&mut self, len: usize, lo: usize, hi: usize) -> Vec<usize> {
        let v: Vec<usize> = (0..len).map(|_| {
            lo + self.rng.below((hi - lo + 1) as u64) as usize
        }).collect();
        self.trace.push(format!("vec_usize[{len}]"));
        v
    }

    /// Pick one of the provided choices.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.below(xs.len() as u64) as usize;
        self.trace.push(format!("choice#{i}"));
        &xs[i]
    }

    /// Access the underlying RNG for custom generation.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `cases` property cases with deterministic per-case seeds.
/// Panics with the case seed + generated-value trace on first failure
/// so the case can be replayed with `run_seeded`.
pub fn run(cases: u64, prop: impl FnMut(&mut Gen) -> PropResult) {
    run_from(0xDEFA017, cases, prop)
}

/// Run with an explicit base seed (replay support).
pub fn run_from(base_seed: u64, cases: u64, mut prop: impl FnMut(&mut Gen) -> PropResult) {
    for case in 0..cases {
        let seed = base_seed ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property failed at case {case} (seed {seed:#x}): {msg}\n  inputs: {}",
                g.trace.join(", ")
            );
        }
    }
}

/// Replay a single failing seed.
pub fn run_seeded(seed: u64, mut prop: impl FnMut(&mut Gen) -> PropResult) {
    let mut g = Gen::new(seed);
    if let Err(msg) = prop(&mut g) {
        panic!("property failed (seed {seed:#x}): {msg}\n  inputs: {}",
               g.trace.join(", "));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run(50, |g| {
            let n = g.usize_in(1, 10);
            count += 0 * n; // silence
            prop_assert(n >= 1 && n <= 10, "range")
        });
        let _ = count;
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        run(20, |g| {
            let n = g.usize_in(0, 100);
            prop_assert(n < 95, format!("n={n}"))
        });
    }

    #[test]
    fn generators_in_bounds() {
        run(100, |g| {
            let x = g.f64_in(-2.0, 3.0);
            prop_assert((-2.0..3.0).contains(&x), format!("{x}"))?;
            let v = g.vec_f32(8, 0.0, 1.0);
            prop_assert(v.iter().all(|&y| (0.0..1.0).contains(&y)), "vec")?;
            let c = *g.choose(&[1, 2, 3]);
            prop_assert([1, 2, 3].contains(&c), "choice")
        });
    }

    #[test]
    fn deterministic_replay() {
        // same base seed ⇒ same generated values
        let mut first = Vec::new();
        run_from(42, 5, |g| {
            first.push(g.usize_in(0, 1000));
            Ok(())
        });
        let mut second = Vec::new();
        run_from(42, 5, |g| {
            second.push(g.usize_in(0, 1000));
            Ok(())
        });
        assert_eq!(first, second);
    }
}
