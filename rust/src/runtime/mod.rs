//! PJRT runtime (S13): loads HLO-text artifacts, compiles them on the
//! CPU PJRT client, and executes them from the serving/training hot
//! path. Python never runs here — the artifacts are self-contained.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax ≥
//! 0.5 emits protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md and DESIGN.md §3).
//!
//! This module also owns [`BackendKind`], the runtime's report of which
//! execution backend is live: serving does not *require* PJRT — when
//! artifacts or the XLA toolchain are absent the coordinator falls back
//! to the in-process CPU kernel backend
//! (`coordinator::cpu_engine`), and `STATS` reports the active kind.

pub mod manifest;

pub use manifest::{ArtifactEntry, ArtifactKind, Manifest, ManifestError, ParamEntry};

/// Which execution backend is serving (reported through the server's
/// `STATS` command and the CLI banner). Selection lives in
/// `coordinator::ExecBackend::auto`: XLA when the artifacts directory
/// loads and the PJRT client constructs, CPU otherwise — with the
/// offline `xla-stub` build that is always CPU.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT-compiled HLO artifacts on the PJRT runtime.
    Xla,
    /// The in-process `kernels::` CPU core (no artifacts).
    Cpu,
}

impl BackendKind {
    /// Stable identifier used on the wire (`STATS` backend line).
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Xla => "xla-pjrt",
            BackendKind::Cpu => "cpu-kernels",
        }
    }
}

use crate::config::Variant;
use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

/// Runtime errors (hand-written impls — no thiserror in tree).
#[derive(Debug)]
pub enum RuntimeError {
    Xla(String),
    Manifest(ManifestError),
    Io(std::io::Error),
    NotFound(String),
    Shape(String),
    /// Weight-checkpoint problem (bad file, shape mismatch) — serving
    /// with `init = load` fails closed on these instead of silently
    /// falling back to seeded weights.
    Checkpoint(String),
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::Xla(msg) => write!(f, "xla: {msg}"),
            RuntimeError::Manifest(e) => write!(f, "manifest: {e}"),
            RuntimeError::Io(e) => write!(f, "io: {e}"),
            RuntimeError::NotFound(what) => write!(f, "artifact not found: {what}"),
            RuntimeError::Shape(what) => write!(f, "shape mismatch: {what}"),
            RuntimeError::Checkpoint(what) => write!(f, "checkpoint: {what}"),
        }
    }
}

impl std::error::Error for RuntimeError {}

impl From<ManifestError> for RuntimeError {
    fn from(e: ManifestError) -> Self {
        RuntimeError::Manifest(e)
    }
}

impl From<std::io::Error> for RuntimeError {
    fn from(e: std::io::Error) -> Self {
        RuntimeError::Io(e)
    }
}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

type Result<T> = std::result::Result<T, RuntimeError>;

/// The PJRT engine: one CPU client + a compiled-executable cache.
///
/// Thread-safety: the underlying PJRT CPU client serializes compute;
/// the cache map is mutex-guarded. `Engine` is `Send + Sync` and meant
/// to sit in an `Arc` shared by coordinator workers.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<LoadedModel>>>,
}

// xla::PjRtClient wraps a thread-safe C++ client; the raw pointer makes
// the rust type !Send/!Sync by default.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

/// A compiled artifact plus its metadata.
pub struct LoadedModel {
    pub entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
}

unsafe impl Send for LoadedModel {}
unsafe impl Sync for LoadedModel {}

impl Engine {
    /// Create the CPU PJRT client and load the manifest from `dir`.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Engine> {
        let manifest = Manifest::load(&artifacts_dir)?;
        manifest.validate_layout()?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact (cached by file name).
    pub fn load(&self, kind: ArtifactKind, variant: Variant, seq: usize)
                -> Result<std::sync::Arc<LoadedModel>> {
        let entry = self
            .manifest
            .find(kind, variant, seq)
            .ok_or_else(|| RuntimeError::NotFound(format!(
                "{kind:?}/{}/n={seq}", variant.token())))?
            .clone();
        {
            let cache = self.cache.lock().unwrap();
            if let Some(m) = cache.get(&entry.file) {
                return Ok(m.clone());
            }
        }
        let path = self.manifest.path_of(&entry);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| RuntimeError::NotFound(
                path.display().to_string()))?)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let model = std::sync::Arc::new(LoadedModel { entry, exe });
        self.cache
            .lock()
            .unwrap()
            .insert(model.entry.file.clone(), model.clone());
        Ok(model)
    }

    /// Eagerly compile every encode artifact for `variant` (warmup).
    pub fn warmup(&self, variant: Variant) -> Result<Vec<usize>> {
        let buckets = self.manifest.encode_buckets(variant);
        for &seq in &buckets {
            self.load(ArtifactKind::Encode, variant, seq)?;
        }
        Ok(buckets)
    }

    /// Read the initial flat parameter vector from the artifacts dir.
    pub fn init_params(&self) -> Result<Vec<f32>> {
        let bytes = std::fs::read(self.manifest.init_params_path())?;
        if bytes.len() != 4 * self.manifest.param_count {
            return Err(RuntimeError::Shape(format!(
                "init_params.bin has {} bytes, expected {}",
                bytes.len(), 4 * self.manifest.param_count)));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Host→device transfer of an f32 tensor.
    pub fn buffer_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Host→device transfer of an i32 tensor.
    pub fn buffer_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }
}

impl LoadedModel {
    /// Execute with device-resident buffers (no host copies for inputs).
    /// The artifact returns one tuple; this decomposes it.
    pub fn execute_buffers(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let outs = self.exe.execute_b(args)?;
        let mut lit = outs[0][0].to_literal_sync()?;
        Ok(lit.decompose_tuple()?)
    }

    /// Execute with device buffers but keep outputs on device.
    /// Returns the raw tuple buffer(s) of replica 0.
    pub fn execute_buffers_on_device(&self, args: &[&xla::PjRtBuffer])
                                     -> Result<Vec<xla::PjRtBuffer>> {
        let mut outs = self.exe.execute_b(args)?;
        Ok(outs.remove(0))
    }

    /// Encode entry point: tokens (batch×seq, row-major i32) -> pooled
    /// embeddings (batch × d_model, flattened f32).
    pub fn encode(&self, engine: &Engine, params: &xla::PjRtBuffer,
                  tokens: &[i32]) -> Result<Vec<f32>> {
        let b = self.entry.batch;
        let n = self.entry.seq;
        if tokens.len() != b * n {
            return Err(RuntimeError::Shape(format!(
                "tokens len {} != batch {b} × seq {n}", tokens.len())));
        }
        let tok = engine.buffer_i32(tokens, &[b, n])?;
        let outs = self.execute_buffers(&[params, &tok])?;
        Ok(outs[0].to_vec::<f32>()?)
    }
}

/// Device-resident training state (params + Adam moments), updated
/// in place each step by re-binding to the step's output buffers.
pub struct TrainState {
    pub params: xla::PjRtBuffer,
    pub m: xla::PjRtBuffer,
    pub v: xla::PjRtBuffer,
    pub step: u64,
}

unsafe impl Send for TrainState {}

impl TrainState {
    /// Fresh state from the manifest's initial parameters.
    pub fn init(engine: &Engine) -> Result<TrainState> {
        let p = engine.init_params()?;
        let zeros = vec![0.0f32; p.len()];
        Ok(TrainState {
            params: engine.buffer_f32(&p, &[p.len()])?,
            m: engine.buffer_f32(&zeros, &[zeros.len()])?,
            v: engine.buffer_f32(&zeros, &[zeros.len()])?,
            step: 0,
        })
    }

    /// Run one train step artifact; returns the loss. Device buffers for
    /// params/m/v are swapped to the step outputs (no host round-trip).
    pub fn step(&mut self, engine: &Engine, model: &LoadedModel,
                tokens: &[i32], targets: &[i32], loss_mask: &[f32])
                -> Result<f32> {
        let b = model.entry.batch;
        let n = model.entry.seq;
        if tokens.len() != b * n || targets.len() != b * n
            || loss_mask.len() != b * n {
            return Err(RuntimeError::Shape(format!(
                "batch tensors must be {b}×{n}")));
        }
        self.step += 1;
        let step_lit = engine.buffer_f32(&[self.step as f32], &[])?;
        let tok = engine.buffer_i32(tokens, &[b, n])?;
        let tgt = engine.buffer_i32(targets, &[b, n])?;
        let msk = engine.buffer_f32(loss_mask, &[b, n])?;
        let outs = model.execute_buffers_on_device(&[
            &self.params, &self.m, &self.v, &step_lit, &tok, &tgt, &msk,
        ])?;
        // outputs: tuple(params', m', v', loss) — returned as one tuple
        // buffer; bring it to host only for the scalar loss, keep the
        // big tensors by decomposing on device when supported. The CPU
        // plugin returns the tuple as a single buffer, so decompose via
        // literal for the scalar and re-upload? No: PJRT CPU untuples
        // into multiple buffers already (outs.len() == 4).
        if outs.len() == 4 {
            let loss = outs[3].to_literal_sync()?.to_vec::<f32>()?[0];
            // re-bind state to the new device buffers — zero-copy chain
            let mut it = outs.into_iter();
            self.params = it.next().unwrap();
            self.m = it.next().unwrap();
            self.v = it.next().unwrap();
            Ok(loss)
        } else {
            // single tuple buffer fallback: host round-trip
            let mut lit = outs[0].to_literal_sync()?;
            let parts = lit.decompose_tuple()?;
            let loss = parts[3].to_vec::<f32>()?[0];
            let pvec = parts[0].to_vec::<f32>()?;
            let mvec = parts[1].to_vec::<f32>()?;
            let vvec = parts[2].to_vec::<f32>()?;
            self.params = engine.buffer_f32(&pvec, &[pvec.len()])?;
            self.m = engine.buffer_f32(&mvec, &[mvec.len()])?;
            self.v = engine.buffer_f32(&vvec, &[vvec.len()])?;
            Ok(loss)
        }
    }

    /// Download current parameters to host (checkpointing).
    pub fn params_to_host(&self) -> Result<Vec<f32>> {
        Ok(self.params.to_literal_sync()?.to_vec::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    //! Runtime tests need built artifacts; they are exercised end-to-end
    //! by `rust/tests/integration_runtime.rs` (skipped gracefully when
    //! artifacts/ is absent). Manifest parsing is covered in
    //! `manifest.rs`.

    use super::*;

    #[test]
    fn backend_kind_names_are_stable() {
        assert_eq!(BackendKind::Xla.name(), "xla-pjrt");
        assert_eq!(BackendKind::Cpu.name(), "cpu-kernels");
    }

    #[test]
    fn runtime_error_display() {
        let e = RuntimeError::NotFound("encode/ss/n=64".into());
        assert!(e.to_string().contains("encode/ss"));
        let e = RuntimeError::Shape("bad".into());
        assert!(e.to_string().contains("bad"));
    }
}
