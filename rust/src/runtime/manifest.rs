//! Artifact-manifest parser: reads `artifacts/manifest.txt` written by
//! `python/compile/aot.py` and exposes typed metadata the router and
//! training driver need (artifact index, model hyperparameters,
//! parameter layout).

use crate::config::Variant;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Kind of AOT artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    Encode,
    TrainStep,
}

impl ArtifactKind {
    fn parse(s: &str) -> Option<Self> {
        match s {
            "encode" => Some(ArtifactKind::Encode),
            "train_step" => Some(ArtifactKind::TrainStep),
            _ => None,
        }
    }
}

/// One artifact entry from the manifest.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub kind: ArtifactKind,
    pub variant: Variant,
    pub seq: usize,
    pub batch: usize,
    pub file: String,
}

/// One named parameter region of the flat parameter vector.
#[derive(Clone, Debug)]
pub struct ParamEntry {
    pub name: String,
    pub offset: usize,
    pub shape: Vec<usize>,
}

impl ParamEntry {
    pub fn size(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub param_count: usize,
    /// model hyperparameters (vocab, d_model, n_heads, n_layers, d_ff,
    /// landmarks, pinv_iters) by name
    pub hyper: HashMap<String, i64>,
    pub lr: f64,
    pub artifacts: Vec<ArtifactEntry>,
    pub params: Vec<ParamEntry>,
}

/// Manifest loading errors (hand-written impls — no thiserror in tree).
#[derive(Debug)]
pub enum ManifestError {
    Io(std::io::Error),
    Parse(usize, String),
    Missing(&'static str),
}

impl std::fmt::Display for ManifestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ManifestError::Io(e) => write!(f, "io: {e}"),
            ManifestError::Parse(line, msg) => write!(f, "manifest line {line}: {msg}"),
            ManifestError::Missing(field) => write!(f, "manifest missing field {field}"),
        }
    }
}

impl std::error::Error for ManifestError {}

impl From<std::io::Error> for ManifestError {
    fn from(e: std::io::Error) -> Self {
        ManifestError::Io(e)
    }
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest, ManifestError> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.txt"))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (dir recorded for artifact path resolution).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest, ManifestError> {
        let mut param_count = None;
        let mut hyper = HashMap::new();
        let mut lr = 1e-3;
        let mut artifacts = Vec::new();
        let mut params = Vec::new();

        for (no, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some(rest) = line.strip_prefix("artifact ") {
                let kv = parse_kv(rest);
                let get = |k: &str| -> Result<&str, ManifestError> {
                    kv.get(k).map(|s| *s).ok_or_else(|| {
                        ManifestError::Parse(no + 1, format!("artifact missing {k}"))
                    })
                };
                let kind = ArtifactKind::parse(get("kind")?).ok_or_else(|| {
                    ManifestError::Parse(no + 1, "bad artifact kind".into())
                })?;
                let variant = Variant::parse(get("variant")?).ok_or_else(|| {
                    ManifestError::Parse(no + 1, "bad variant".into())
                })?;
                artifacts.push(ArtifactEntry {
                    kind,
                    variant,
                    seq: parse_usize(get("seq")?, no)?,
                    batch: parse_usize(get("batch")?, no)?,
                    file: get("file")?.to_string(),
                });
            } else if let Some(rest) = line.strip_prefix("param ") {
                // "param <name> offset=<o> shape=<a>x<b>"
                let mut it = rest.split_whitespace();
                let name = it.next().ok_or_else(|| {
                    ManifestError::Parse(no + 1, "param missing name".into())
                })?;
                let kv = parse_kv(&rest[name.len()..]);
                let offset = parse_usize(
                    kv.get("offset").copied().unwrap_or(""), no)?;
                let shape: Vec<usize> = kv
                    .get("shape")
                    .copied()
                    .unwrap_or("")
                    .split('x')
                    .filter(|s| !s.is_empty())
                    .map(|s| parse_usize(s, no))
                    .collect::<Result<_, _>>()?;
                params.push(ParamEntry { name: name.to_string(), offset, shape });
            } else if let Some(eq) = line.find('=') {
                let key = &line[..eq];
                let val = &line[eq + 1..];
                match key {
                    "param_count" => param_count = Some(parse_usize(val, no)?),
                    "lr" => {
                        lr = val.parse().map_err(|_| {
                            ManifestError::Parse(no + 1, "bad lr".into())
                        })?
                    }
                    _ => {
                        if let Ok(v) = val.parse::<i64>() {
                            hyper.insert(key.to_string(), v);
                        }
                    }
                }
            }
        }

        Ok(Manifest {
            dir,
            param_count: param_count.ok_or(ManifestError::Missing("param_count"))?,
            hyper,
            lr,
            artifacts,
            params,
        })
    }

    /// Absolute path of an artifact file.
    pub fn path_of(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }

    /// Find an artifact by (kind, variant, seq).
    pub fn find(&self, kind: ArtifactKind, variant: Variant, seq: usize)
                -> Option<&ArtifactEntry> {
        self.artifacts
            .iter()
            .find(|a| a.kind == kind && a.variant == variant && a.seq == seq)
    }

    /// All encode seq buckets available for a variant (ascending).
    pub fn encode_buckets(&self, variant: Variant) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::Encode && a.variant == variant)
            .map(|a| a.seq)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Path of the initial-parameters binary.
    pub fn init_params_path(&self) -> PathBuf {
        self.dir.join("init_params.bin")
    }

    /// Validate the parameter layout is contiguous and sums to
    /// param_count.
    pub fn validate_layout(&self) -> Result<(), ManifestError> {
        let mut off = 0;
        for p in &self.params {
            if p.offset != off {
                return Err(ManifestError::Parse(
                    0,
                    format!("param {} offset {} != expected {off}", p.name, p.offset),
                ));
            }
            off += p.size();
        }
        if off != self.param_count {
            return Err(ManifestError::Parse(
                0,
                format!("layout sums to {off}, param_count {}", self.param_count),
            ));
        }
        Ok(())
    }
}

fn parse_kv(s: &str) -> HashMap<&str, &str> {
    s.split_whitespace()
        .filter_map(|tok| tok.split_once('='))
        .collect()
}

fn parse_usize(s: &str, line: usize) -> Result<usize, ManifestError> {
    s.parse()
        .map_err(|_| ManifestError::Parse(line + 1, format!("bad number {s:?}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# ssaformer artifact manifest
vocab=2048
d_model=256
param_count=100
lr=0.001
artifact kind=encode variant=ss seq=128 batch=4 file=encode_ss_n128_b4.hlo.txt inputs=x outputs=y
artifact kind=encode variant=ss seq=256 batch=4 file=encode_ss_n256_b4.hlo.txt inputs=x outputs=y
artifact kind=train_step variant=full seq=128 batch=8 file=train_step_full.hlo.txt inputs=x outputs=y
param embed offset=0 shape=10x8
param pos offset=80 shape=20x1
";

    fn sample() -> Manifest {
        Manifest::parse(SAMPLE, PathBuf::from("/tmp/artifacts")).unwrap()
    }

    #[test]
    fn parses_scalars_and_hyper() {
        let m = sample();
        assert_eq!(m.param_count, 100);
        assert_eq!(m.lr, 0.001);
        assert_eq!(m.hyper["vocab"], 2048);
        assert_eq!(m.hyper["d_model"], 256);
    }

    #[test]
    fn parses_artifacts_and_lookup() {
        let m = sample();
        assert_eq!(m.artifacts.len(), 3);
        let e = m.find(ArtifactKind::Encode, Variant::SpectralShift, 256).unwrap();
        assert_eq!(e.batch, 4);
        assert!(m.find(ArtifactKind::Encode, Variant::Full, 128).is_none());
        assert_eq!(m.encode_buckets(Variant::SpectralShift), vec![128, 256]);
        assert_eq!(m.path_of(e), PathBuf::from("/tmp/artifacts/encode_ss_n256_b4.hlo.txt"));
    }

    #[test]
    fn parses_param_layout_and_validates() {
        let m = sample();
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[0].size(), 80);
        assert!(m.validate_layout().is_ok());
    }

    #[test]
    fn layout_validation_catches_gaps() {
        let bad = SAMPLE.replace("offset=80", "offset=81");
        let m = Manifest::parse(&bad, PathBuf::new()).unwrap();
        assert!(m.validate_layout().is_err());
    }

    #[test]
    fn missing_param_count_is_error() {
        let bad = SAMPLE.replace("param_count=100", "");
        assert!(matches!(Manifest::parse(&bad, PathBuf::new()),
                         Err(ManifestError::Missing("param_count"))));
    }

    #[test]
    fn bad_lines_error_with_lineno() {
        let bad = "param_count=10\nartifact kind=encode variant=zzz seq=1 batch=1 file=f";
        assert!(Manifest::parse(bad, PathBuf::new()).is_err());
    }
}
