//! ssaformer CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   serve    [--config FILE] [--variant V] [--addr A]   start the TCP server
//!   train    [--epochs N] [--steps N] [--out CKPT] ...   deterministic CPU
//!            MLM training (+ optional error-bound sweep); the legacy
//!            artifact driver runs when --artifacts is passed
//!   info     [--artifacts DIR]                          inspect artifacts
//!   spectrum [--n N] [--c C]                            Figure-2 quick look
//!
//! (hand-rolled arg parsing: the crate cache has no clap.)

use ssaformer::config::{Config, InitPolicy, Role, ServingConfig, Variant};
use ssaformer::coordinator::cluster::{self, ClusterConfig, ClusterRouter};
use ssaformer::coordinator::{Coordinator, ExecBackend};
use ssaformer::coordinator::CpuModel;
use ssaformer::eval::{error_bound_sweep, ErrorBoundConfig};
use ssaformer::model::checkpoint;
use ssaformer::runtime::Engine;
use ssaformer::train::{train, train_cpu, CpuTrainConfig, OptimizerKind,
                       TrainConfig};
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let flags = parse_flags(&args[1.min(args.len())..]);
    let code = match cmd {
        "serve" => cmd_serve(&flags),
        "train" => cmd_train(&flags),
        "info" => cmd_info(&flags),
        "spectrum" => cmd_spectrum(&flags),
        "help" | "--help" | "-h" => {
            print!("{}", USAGE);
            0
        }
        other => {
            eprintln!("unknown command {other:?}\n{USAGE}");
            2
        }
    };
    std::process::exit(code);
}

const USAGE: &str = "\
ssaformer — spectral-shifting attention serving/training stack

USAGE: ssaformer <serve|train|info|spectrum|help> [flags]

  serve    --config FILE | --addr HOST:PORT
           --role replica|router (default replica; router forwards
                     ENCODE across --replicas, executes nothing)
           --replicas HOST:PORT,HOST:PORT,... (router role only)
           --probe-interval-ms MS (router health-probe period, >0)
           --variant full|nystrom|ss|linformer|lsh|sparse
                     (or a per-layer list: --variant ss,ss,full)
           --layers N (1 = seed single-pass model) --ffn-mult N
           --projections true|false (QKV/output maps in full blocks)
           --weights PATH --init seeded|load (checkpoint policy;
                     a --weights path implies --init load)
           --artifacts DIR --max-batch N --max-wait-ms MS
           --workers N --shards N --cache-capacity N (0 = off)
           --chunk-tokens N (long-document chunk length, 0 = reject
                     sequences past the largest bucket as before)
           --prefix-cache-capacity N (chunk-embedding entries, 0 = off)
           --default-deadline-ms MS (0 = none) --deadline-margin-ms MS
           --kernel auto|scalar|avx2|neon (micro-kernel arm; the
                     SSAF_KERNEL env var overrides this flag)
           --admission auto|full-f32|ss-f32|ss-bf16|ss-int8 (force
                     every request onto one (variant, precision) tier;
                     auto routes by ACCURACY= tags; the SSAF_ADMISSION
                     env var overrides this flag)
           (knob semantics + capacity planning: see OPERATIONS.md)
  train    in-repo deterministic CPU trainer (default; no artifacts):
           --epochs N --steps N (per epoch) --batch N --seq N
           --layers N (>= 2; layer 0 is the weightless seed block)
           --d-model N --heads N --ffn-mult N --vocab N
           --lr F --optimizer sgd|adam --seed S --workers N
           --out PATH (save the trained SSAFCKPT checkpoint;
                     serve it back with serve --weights PATH)
           --error-bound-json PATH (sweep every variant's attention
                     error vs exact softmax on the trained weights)
           legacy XLA-artifact driver (only when --artifacts is given):
           --artifacts DIR --variant full|ss --steps N --seed S
  info     --artifacts DIR
  spectrum --n N --c C  (pure-rust Figure-2 analysis; no artifacts needed)
";

fn parse_flags(args: &[String]) -> std::collections::HashMap<String, String> {
    let mut out = std::collections::HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = args.get(i + 1).cloned().unwrap_or_default();
            out.insert(key.to_string(), val);
            i += 2;
        } else {
            i += 1;
        }
    }
    out
}

type Flags = std::collections::HashMap<String, String>;

fn serving_config(flags: &Flags) -> Result<ServingConfig, String> {
    let mut cfg = if let Some(path) = flags.get("config") {
        let parsed = Config::from_file(path).map_err(|e| e.to_string())?;
        ServingConfig::from_config(&parsed).map_err(|e| e.to_string())?
    } else {
        ServingConfig::default()
    };
    if let Some(v) = flags.get("variant") {
        let list = Variant::parse_list(v).ok_or(format!("bad variant {v:?}"))?;
        (cfg.variant, cfg.layer_variants) = ServingConfig::split_variants(list);
    }
    if let Some(a) = flags.get("addr") {
        cfg.bind_addr = a.clone();
    }
    if let Some(d) = flags.get("artifacts") {
        cfg.artifacts_dir = d.clone();
    }
    if let Some(b) = flags.get("max-batch") {
        cfg.max_batch = b.parse().map_err(|_| "bad max-batch")?;
    }
    if let Some(w) = flags.get("max-wait-ms") {
        cfg.max_wait_ms = w.parse().map_err(|_| "bad max-wait-ms")?;
    }
    if let Some(w) = flags.get("workers") {
        cfg.workers = w.parse().map_err(|_| "bad workers")?;
    }
    if let Some(s) = flags.get("shards") {
        cfg.queue_shards = s.parse().map_err(|_| "bad shards")?;
    }
    if let Some(c) = flags.get("cache-capacity") {
        cfg.cache_capacity = c.parse().map_err(|_| "bad cache-capacity")?;
    }
    if let Some(c) = flags.get("chunk-tokens") {
        cfg.chunk_tokens = c.parse().map_err(|_| "bad chunk-tokens")?;
    }
    if let Some(c) = flags.get("prefix-cache-capacity") {
        cfg.prefix_cache_capacity =
            c.parse().map_err(|_| "bad prefix-cache-capacity")?;
    }
    if let Some(d) = flags.get("default-deadline-ms") {
        cfg.default_deadline_ms = d.parse().map_err(|_| "bad default-deadline-ms")?;
    }
    if let Some(m) = flags.get("deadline-margin-ms") {
        cfg.deadline_margin_ms = m.parse().map_err(|_| "bad deadline-margin-ms")?;
    }
    if let Some(l) = flags.get("layers") {
        cfg.layers = l.parse().map_err(|_| "bad layers")?;
    }
    if let Some(f) = flags.get("ffn-mult") {
        cfg.ffn_mult = f.parse().map_err(|_| "bad ffn-mult")?;
    }
    if let Some(p) = flags.get("projections") {
        cfg.projections = p.parse().map_err(|_| "bad projections")?;
    }
    if let Some(w) = flags.get("weights") {
        cfg.weights = Some(w.clone());
        // a weights flag without an explicit policy means "load it"
        if !flags.contains_key("init") {
            cfg.init = InitPolicy::Load;
        }
    }
    if let Some(i) = flags.get("init") {
        cfg.init = InitPolicy::parse(i).ok_or(format!("bad init {i:?}"))?;
    }
    if let Some(k) = flags.get("kernel") {
        cfg.kernel = if k.trim().eq_ignore_ascii_case("auto") {
            None
        } else {
            Some(ssaformer::kernels::Isa::parse(k)
                .ok_or(format!("bad kernel {k:?} (auto|scalar|avx2|neon)"))?)
        };
    }
    if let Some(a) = flags.get("admission") {
        cfg.admission = if a.trim().eq_ignore_ascii_case("auto") {
            None
        } else {
            Some(ssaformer::coordinator::TierKind::parse(a)
                .ok_or(format!(
                    "bad admission {a:?} \
                     (auto|full-f32|ss-f32|ss-bf16|ss-int8)"))?)
        };
    }
    if let Some(r) = flags.get("role") {
        cfg.role = Role::parse(r)
            .ok_or(format!("bad role {r:?} (replica|router)"))?;
    }
    if let Some(r) = flags.get("replicas") {
        cfg.replicas = r
            .split(',')
            .map(|a| a.trim().to_string())
            .filter(|a| !a.is_empty())
            .collect();
    }
    if let Some(p) = flags.get("probe-interval-ms") {
        cfg.probe_interval_ms = p.parse().map_err(|_| "bad probe-interval-ms")?;
    }
    cfg.validate().map_err(|e| e.to_string())?;
    Ok(cfg)
}

fn cmd_serve(flags: &Flags) -> i32 {
    let cfg = match serving_config(flags) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    if cfg.role == Role::Router {
        return cmd_serve_router(&cfg);
    }
    println!("loading artifacts from {} ...", cfg.artifacts_dir);
    // a bad weights checkpoint (or load-on-XLA) stops startup here —
    // fail closed, never silently serve seeded weights instead
    let (backend, skipped) = match ExecBackend::auto_with_reason(&cfg) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("backend: {e}");
            return 1;
        }
    };
    match (&backend, skipped) {
        (ExecBackend::Xla(engine), _) => {
            println!("platform: {}", engine.platform());
        }
        // a corrupt manifest should be visible, not silently replaced
        // by the CPU demo model
        (ExecBackend::Cpu(_), reason) => println!(
            "xla backend unavailable ({}) — serving on the CPU kernel backend",
            reason.map(|e| e.to_string()).unwrap_or_default()),
    }
    let coordinator = match Coordinator::start(backend, &cfg) {
        Ok(c) => Arc::new(c),
        Err(e) => {
            eprintln!("coordinator: {e}");
            return 1;
        }
    };
    let backend_name = coordinator.backend().name();
    println!("model: {}", coordinator.model_desc());
    println!("kernel: {}", coordinator.kernel_desc());
    println!("worker pool: {} workers over {} queue shards, cache {}",
             coordinator.workers(), coordinator.queue_shards(),
             match coordinator.cache_capacity() {
                 0 => "off".to_string(),
                 n => format!("{n} entries"),
             });
    println!("admission: {}", coordinator.admission_desc());
    match ssaformer::server::serve(coordinator, &cfg.bind_addr, 8) {
        Ok((addr, _handle)) => {
            println!("serving {} attention on {addr} (backend: {backend_name})",
                     cfg.variant.token());
            println!("protocol: ENCODE <id> [DEADLINE_MS=<ms>] \
                      [ACCURACY=<high|balanced|budget|err>] <tok...> \
                      | STATS | QUIT");
            // block forever (ctrl-c to stop)
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        Err(e) => {
            eprintln!("bind {}: {e}", cfg.bind_addr);
            1
        }
    }
}

/// Router-mode serve: no backend, no coordinator — a [`ClusterRouter`]
/// consistent-hashing ENCODE lines across the configured replicas
/// (see `coordinator::cluster` for the data flow and invariants).
fn cmd_serve_router(cfg: &ServingConfig) -> i32 {
    let ccfg = ClusterConfig {
        replicas: cfg.replicas.clone(),
        probe_interval: std::time::Duration::from_millis(cfg.probe_interval_ms),
        cache_capacity: cfg.cache_capacity,
        ..Default::default()
    };
    println!("router over {} replicas: {}",
             ccfg.replicas.len(), ccfg.replicas.join(", "));
    println!("probe interval: {}ms, reply cache: {}",
             cfg.probe_interval_ms,
             match cfg.cache_capacity {
                 0 => "off".to_string(),
                 n => format!("{n} entries"),
             });
    let router = Arc::new(ClusterRouter::new(ccfg));
    // one synchronous sweep so the first requests see honest membership
    router.probe_now();
    let up = router.membership().up_count();
    println!("initial probe: {up}/{} replicas up",
             router.membership().len());
    match cluster::serve_router(router, &cfg.bind_addr, 8) {
        Ok((addr, _handle)) => {
            println!("routing on {addr} (role: router)");
            println!("protocol: ENCODE <id> [DEADLINE_MS=<ms>] \
                      [ACCURACY=<high|balanced|budget|err>] <tok...> \
                      | STATS | PING | QUIT");
            // block forever (ctrl-c to stop)
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        Err(e) => {
            eprintln!("bind {}: {e}", cfg.bind_addr);
            1
        }
    }
}

fn cmd_train(flags: &Flags) -> i32 {
    // legacy path: an explicit --artifacts keeps the XLA train-step
    // driver reachable; everything else runs the in-repo CPU trainer
    if flags.contains_key("artifacts") {
        return cmd_train_artifact(flags);
    }
    let mut cfg = CpuTrainConfig::default();
    macro_rules! knob {
        ($flag:literal, $field:ident) => {
            if let Some(v) = flags.get($flag) {
                match v.parse() {
                    Ok(parsed) => cfg.$field = parsed,
                    Err(_) => {
                        eprintln!("bad {} {v:?}", $flag);
                        return 2;
                    }
                }
            }
        };
    }
    knob!("steps", steps_per_epoch);
    knob!("epochs", epochs);
    knob!("batch", batch);
    knob!("seq", seq);
    knob!("layers", layers);
    knob!("d-model", d_model);
    knob!("heads", n_heads);
    knob!("ffn-mult", ffn_mult);
    knob!("vocab", vocab);
    knob!("lr", lr);
    knob!("seed", seed);
    knob!("workers", workers);
    if let Some(o) = flags.get("optimizer") {
        match OptimizerKind::parse(o) {
            Some(kind) => cfg.optimizer = kind,
            None => {
                eprintln!("bad optimizer {o:?} (sgd|adam)");
                return 2;
            }
        }
    }
    println!(
        "training on the CPU kernel core: d_model={} heads={} layers={} \
         (projected) vocab={} seq={} batch={} {} epochs x {} steps, {} lr={}",
        cfg.d_model, cfg.n_heads, cfg.layers, cfg.vocab, cfg.seq, cfg.batch,
        cfg.epochs, cfg.steps_per_epoch, cfg.optimizer.token(), cfg.lr);
    let outcome = train_cpu(&cfg);
    print!("{}", outcome.report.render());

    if let Some(path) = flags.get("out") {
        if let Err(e) = checkpoint::save(&outcome.stack, path) {
            eprintln!("checkpoint {path}: {e}");
            return 1;
        }
        println!("checkpoint saved to {path} — serve it with: \
                  ssaformer serve --weights {path} --layers {} \
                  --ffn-mult {} --projections true",
                 cfg.layers, cfg.ffn_mult);
    }
    if let Some(path) = flags.get("error-bound-json") {
        let eval_cfg = ErrorBoundConfig { seq: cfg.seq, ..Default::default() };
        for &c in &eval_cfg.landmarks {
            if cfg.seq % c != 0 {
                eprintln!("error-bound sweep needs seq divisible by {c} \
                           (got {})", cfg.seq);
                return 2;
            }
        }
        let model = CpuModel::new(outcome.model_config, Variant::Full);
        let report = error_bound_sweep(&model, &outcome.stack, &eval_cfg);
        print!("{}", report.render());
        if let Err(e) = std::fs::write(path, report.to_json()) {
            eprintln!("write {path}: {e}");
            return 1;
        }
        println!("wrote {path}");
    }
    0
}

fn cmd_train_artifact(flags: &Flags) -> i32 {
    let dir = flags.get("artifacts").map(|s| s.as_str()).unwrap_or("artifacts");
    let variant = flags
        .get("variant")
        .map(|v| Variant::parse(v).expect("bad variant"))
        .unwrap_or(Variant::SpectralShift);
    let steps: usize = flags
        .get("steps")
        .and_then(|s| s.parse().ok())
        .unwrap_or(100);
    let seed: u64 = flags.get("seed").and_then(|s| s.parse().ok()).unwrap_or(0);
    let engine = match Engine::new(dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("engine: {e}\nrun `make artifacts` first");
            return 1;
        }
    };
    let cfg = TrainConfig { variant, steps, seed, ..Default::default() };
    println!("training {} for {} steps ...", variant.token(), steps);
    match train(&engine, &cfg) {
        Ok(report) => {
            print!("{}", report.render());
            0
        }
        Err(e) => {
            eprintln!("train: {e}");
            1
        }
    }
}

fn cmd_info(flags: &Flags) -> i32 {
    let dir = flags.get("artifacts").map(|s| s.as_str()).unwrap_or("artifacts");
    match ssaformer::runtime::Manifest::load(dir) {
        Ok(m) => {
            println!("artifacts dir : {}", m.dir.display());
            println!("param_count   : {}", m.param_count);
            for (k, v) in &m.hyper {
                println!("{k:14}: {v}");
            }
            println!("artifacts     :");
            for a in &m.artifacts {
                println!("  {:?} {} n={} b={} -> {}", a.kind, a.variant.token(),
                         a.seq, a.batch, a.file);
            }
            0
        }
        Err(e) => {
            eprintln!("manifest: {e}");
            1
        }
    }
}

fn cmd_spectrum(flags: &Flags) -> i32 {
    use ssaformer::attention::spectral_shift::{
        spectral_shift_matrix_exact, MiddleForm,
    };
    use ssaformer::attention::{full::attention_matrix, Tensor2};
    use ssaformer::spectral::SpectrumComparison;
    let n: usize = flags.get("n").and_then(|s| s.parse().ok()).unwrap_or(256);
    let c: usize = flags.get("c").and_then(|s| s.parse().ok()).unwrap_or(32);
    let mut rng = ssaformer::rngx::Rng::new(0);
    let q = Tensor2::randn(&mut rng, n, 64, 1.0);
    let k = Tensor2::randn(&mut rng, n, 64, 1.0);
    let s_true = attention_matrix(&q, &k, None);
    let (s_apx, delta) = spectral_shift_matrix_exact(
        &q, &k, c, 1e-2, MiddleForm::Eq8, true, None);
    let cmp = SpectrumComparison::new(&s_true, &s_apx);
    println!("n={n} c={c} delta={delta:.5}");
    println!("idx  cum_true  cum_approx");
    for (i, t, a) in cmp.cumulative_series(16) {
        println!("{i:4}  {t:.4}    {a:.4}");
    }
    println!("effective rank: true={:.1} approx={:.1}",
             cmp.true_spectrum.effective_rank(),
             cmp.approx_spectrum.effective_rank());
    0
}
