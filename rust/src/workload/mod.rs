//! Workload generation substrate (S18): synthetic request traces for the
//! serving benches (E8) — Poisson arrivals, configurable length
//! distributions, and deterministic token content.

use crate::rngx::Rng;
use std::time::Duration;

/// Request length distribution.
#[derive(Clone, Copy, Debug)]
pub enum LengthDist {
    /// All requests have the same length.
    Fixed(usize),
    /// Uniform in [lo, hi].
    Uniform(usize, usize),
    /// Zipf-skewed over the bucket list (short requests dominate),
    /// exponent s.
    ZipfBuckets(f64),
}

/// One synthetic request: token ids + arrival offset from trace start.
#[derive(Clone, Debug)]
pub struct TraceRequest {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub arrival: Duration,
}

/// Trace generator configuration.
#[derive(Clone, Debug)]
pub struct TraceConfig {
    /// Mean arrival rate (requests/second) for the Poisson process.
    pub rate: f64,
    /// Number of requests.
    pub count: usize,
    /// Length distribution (drawn lengths are capped to max bucket).
    pub lengths: LengthDist,
    /// Allowed sequence buckets (ascending) — lengths snap up to these.
    pub buckets: Vec<usize>,
    /// Vocabulary size for token content.
    pub vocab: usize,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            rate: 50.0,
            count: 200,
            lengths: LengthDist::ZipfBuckets(1.1),
            buckets: vec![128, 256, 512],
            vocab: 2048,
            seed: 0,
        }
    }
}

/// Generate a full trace: arrivals are a Poisson process at `rate`,
/// lengths drawn from `lengths` (tokens are drawn uniformly over the
/// word region of the vocabulary, avoiding the PAD/UNK/MASK specials).
pub fn generate_trace(cfg: &TraceConfig) -> Vec<TraceRequest> {
    assert!(!cfg.buckets.is_empty());
    let mut rng = Rng::new(cfg.seed);
    let mut t = Duration::ZERO;
    let max_len = *cfg.buckets.last().unwrap();
    (0..cfg.count)
        .map(|i| {
            t += Duration::from_secs_f64(rng.exponential(cfg.rate.max(1e-9)));
            let raw_len = match cfg.lengths {
                LengthDist::Fixed(l) => l,
                LengthDist::Uniform(lo, hi) => {
                    lo + rng.below((hi - lo + 1) as u64) as usize
                }
                LengthDist::ZipfBuckets(s) => {
                    // zipf over bucket ranks: rank 1 = smallest bucket
                    let r = rng.zipf(cfg.buckets.len() as u64, s) as usize;
                    cfg.buckets[r - 1]
                }
            }
            .min(max_len)
            .max(1);
            let tokens: Vec<i32> = (0..raw_len)
                .map(|_| {
                    crate::text::FIRST_WORD_ID
                        + rng.below((cfg.vocab as i64
                            - crate::text::FIRST_WORD_ID as i64)
                            as u64) as i32
                })
                .collect();
            TraceRequest { id: i as u64, tokens, arrival: t }
        })
        .collect()
}

/// Snap a raw length up to the smallest bucket that fits (None if it
/// exceeds every bucket) — shared with the router.
pub fn bucket_for(len: usize, buckets: &[usize]) -> Option<usize> {
    buckets.iter().copied().find(|&b| b >= len)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_monotone_and_rate_sane() {
        let cfg = TraceConfig { rate: 100.0, count: 500, ..Default::default() };
        let trace = generate_trace(&cfg);
        assert_eq!(trace.len(), 500);
        for w in trace.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        // mean inter-arrival ≈ 1/rate
        let total = trace.last().unwrap().arrival.as_secs_f64();
        let mean = total / 500.0;
        assert!((mean - 0.01).abs() < 0.003, "mean={mean}");
    }

    #[test]
    fn lengths_respect_buckets() {
        let cfg = TraceConfig {
            lengths: LengthDist::ZipfBuckets(1.2),
            buckets: vec![64, 128],
            count: 200,
            ..Default::default()
        };
        let trace = generate_trace(&cfg);
        for r in &trace {
            assert!(r.tokens.len() == 64 || r.tokens.len() == 128);
        }
        // zipf ⇒ short bucket dominates
        let short = trace.iter().filter(|r| r.tokens.len() == 64).count();
        assert!(short > trace.len() / 2);
    }

    #[test]
    fn tokens_in_word_region() {
        let cfg = TraceConfig { count: 50, vocab: 100, ..Default::default() };
        let trace = generate_trace(&cfg);
        for r in &trace {
            assert!(r.tokens.iter().all(|&t| {
                t >= crate::text::FIRST_WORD_ID && (t as usize) < 100
            }));
        }
    }

    #[test]
    fn fixed_and_uniform_lengths() {
        let cfg = TraceConfig {
            lengths: LengthDist::Fixed(60),
            count: 10,
            ..Default::default()
        };
        assert!(generate_trace(&cfg).iter().all(|r| r.tokens.len() == 60));
        let cfg = TraceConfig {
            lengths: LengthDist::Uniform(10, 20),
            count: 100,
            ..Default::default()
        };
        assert!(generate_trace(&cfg)
            .iter()
            .all(|r| (10..=20).contains(&r.tokens.len())));
    }

    #[test]
    fn bucket_snap() {
        let buckets = [128, 256, 512];
        assert_eq!(bucket_for(1, &buckets), Some(128));
        assert_eq!(bucket_for(128, &buckets), Some(128));
        assert_eq!(bucket_for(129, &buckets), Some(256));
        assert_eq!(bucket_for(513, &buckets), None);
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = TraceConfig { seed: 9, count: 20, ..Default::default() };
        let a = generate_trace(&cfg);
        let b = generate_trace(&cfg);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.arrival, y.arrival);
        }
    }
}
