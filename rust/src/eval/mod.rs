//! Per-variant attention error-bound evaluation on trained weights.
//!
//! The paper's headline claim — spectral shifting carries a much
//! stronger error bound than the Nyström approximation — has only ever
//! been exercised here on seeded Gaussian weights. This module measures
//! it on a *trained* [`EncoderStack`]: it replays the encoder forward
//! pass on real tokenized text, and at every attention site (each head
//! of each layer, the seed block included) computes the exact `full`
//! softmax output next to each approximate variant's output, sweeping
//! the landmark count.
//!
//! Per attention problem the error is the relative Frobenius distance
//! `‖O_approx − O_exact‖_F / ‖O_exact‖_F`. Per `(variant, landmarks)`
//! cell the report carries the mean and max over all problems, a pooled
//! Frobenius ratio `√(Σ‖ΔO‖² / Σ‖O_exact‖²)`, and a per-layer mean
//! breakdown. The forward pass always continues on the *exact* path,
//! so every variant is measured against identical activations.
//!
//! Landmark mapping per variant: `ss` and `nystrom` take the swept
//! value as their landmark count, `linformer` as its projected key
//! dimension `k`; `sparse` as its local window; `lsh` has no landmark
//! knob, so its rows are constant across the sweep (kept in the schema
//! so every variant appears at every swept point).
//!
//! # Precision axis
//!
//! Each `(variant, landmarks)` cell is additionally swept across the
//! serving precision tiers ([`Precision`]): the f32 row is the
//! classic measurement, and the `bf16`/`int8` rows snap the attention
//! inputs `Q, K, V` onto that tier's weight lattice
//! ([`QuantMatrix`] quantize→expand round trip) before running the
//! approximate operator — the site-local analogue of the quantized
//! projection GEMMs a tier-routed request runs through
//! ([`kernels::quant`](crate::kernels::quant)), and the one that
//! applies uniformly to projected and weightless blocks alike. The
//! reference is always the exact f32 `full` softmax, so a row reads
//! directly as "what a `(variant × precision)` admission tier costs in
//! relative Frobenius error" — the measured numbers behind
//! `coordinator::admission`'s tier table.
//!
//! The machine-readable output is `BENCH_error_bound.json`
//! (`ssaf-error-bound/v2`), written next to `BENCH_kernels.json`;
//! `tests/error_bound_ordering.rs` pins the paper's ss-vs-nystrom
//! ordering on the in-memory report.

use crate::attention::{
    FullOp, LinformerOp, LshOp, NystromOp, SparseOp, SpectralShiftConfig,
    SpectralShiftOp, Tensor2,
};
use crate::coordinator::CpuModel;
use crate::kernels::{gemm_into, KernelCtx, Precision, QuantMatrix, Workspace};
use crate::model::{AttentionOp, EncoderStack};
use crate::rngx::Rng;
use crate::text::{CorpusGenerator, Tokenizer};

/// The variants the sweep covers, in report order. `full` is the
/// reference, not a row.
pub const EVAL_VARIANTS: [&str; 5] =
    ["ss", "nystrom", "linformer", "lsh", "sparse"];

/// Configuration of one error-bound sweep.
#[derive(Clone, Debug)]
pub struct ErrorBoundConfig {
    /// Landmark counts to sweep; every value must divide `seq`.
    pub landmarks: Vec<usize>,
    /// Evaluation sequence length.
    pub seq: usize,
    /// Number of evaluation sequences.
    pub samples: usize,
    /// Seed for the evaluation text stream (independent of the model
    /// seed so eval data is not the training data).
    pub seed: u64,
    /// Newton–Schulz iterations for the pseudo-inverse variants.
    pub pinv_iters: usize,
    /// Precision tiers to sweep (`f32` is the classic measurement; the
    /// quantized tiers snap `Q, K, V` onto their weight lattice first).
    pub precisions: Vec<Precision>,
}

impl Default for ErrorBoundConfig {
    fn default() -> Self {
        ErrorBoundConfig {
            landmarks: vec![4, 8, 16],
            seq: 48,
            samples: 4,
            seed: 1009,
            pinv_iters: 8,
            precisions: Precision::ALL.to_vec(),
        }
    }
}

/// One `(variant, landmarks, precision)` cell of the report.
#[derive(Clone, Debug)]
pub struct ErrorBoundRow {
    pub variant: &'static str,
    pub landmarks: usize,
    /// Precision tier token (`f32`, `bf16`, `int8`).
    pub precision: &'static str,
    /// Mean over problems of `‖ΔO‖_F / ‖O_exact‖_F`.
    pub mean_rel_err: f64,
    /// Max over problems of the same.
    pub max_rel_err: f64,
    /// Pooled `√(Σ‖ΔO‖² / Σ‖O_exact‖²)`.
    pub fro_ratio: f64,
    /// Mean relative error per layer (index 0 = seed block).
    pub per_layer_mean_rel_err: Vec<f64>,
}

/// The full sweep result.
#[derive(Clone, Debug)]
pub struct ErrorBoundReport {
    pub seq: usize,
    pub samples: usize,
    pub layers: usize,
    pub n_heads: usize,
    pub d_model: usize,
    pub landmarks: Vec<usize>,
    pub precisions: Vec<Precision>,
    pub rows: Vec<ErrorBoundRow>,
}

impl ErrorBoundReport {
    /// The mean relative error of `variant` at landmark count `c` on
    /// the f32 tier — the classic (pre-precision-axis) lookup the
    /// ordering tests pin.
    pub fn mean_rel_err(&self, variant: &str, c: usize) -> Option<f64> {
        self.mean_rel_err_at(variant, c, Precision::F32)
    }

    /// The mean relative error of one `(variant, landmarks, precision)`
    /// tier cell.
    pub fn mean_rel_err_at(&self, variant: &str, c: usize,
                           p: Precision) -> Option<f64> {
        self.rows
            .iter()
            .find(|r| r.variant == variant && r.landmarks == c
                  && r.precision == p.token())
            .map(|r| r.mean_rel_err)
    }

    /// ASCII table for the example / subcommand output.
    pub fn render(&self) -> String {
        let mut t = crate::benchkit::Table::new(
            &["variant", "landmarks", "precision", "mean rel err",
              "max rel err", "fro ratio"]);
        for r in &self.rows {
            t.row(&[
                r.variant.to_string(),
                r.landmarks.to_string(),
                r.precision.to_string(),
                format!("{:.6}", r.mean_rel_err),
                format!("{:.6}", r.max_rel_err),
                format!("{:.6}", r.fro_ratio),
            ]);
        }
        format!(
            "{}\n({} layers x {} heads x {} samples at seq {}, exact \
             reference = full softmax)\n",
            t.render(), self.layers, self.n_heads, self.samples, self.seq)
    }

    /// Serialize as `ssaf-error-bound/v2` JSON (v1 plus the precision
    /// axis: a `precisions` list and a `precision` field per row).
    /// Hand-rolled like the
    /// bench snapshots — flat schema, no dependencies. Panics on
    /// non-finite metrics: an eval that produced NaN must not write an
    /// artifact that looks healthy.
    pub fn to_json(&self) -> String {
        fn num(x: f64) -> String {
            assert!(x.is_finite(), "non-finite metric in error-bound report");
            format!("{x}")
        }
        fn num_list(xs: &[f64]) -> String {
            let inner: Vec<String> = xs.iter().map(|&x| num(x)).collect();
            format!("[{}]", inner.join(","))
        }
        let landmarks: Vec<String> =
            self.landmarks.iter().map(|c| c.to_string()).collect();
        let precisions: Vec<String> = self
            .precisions
            .iter()
            .map(|p| format!("\"{}\"", p.token()))
            .collect();
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str("  \"schema\": \"ssaf-error-bound/v2\",\n");
        out.push_str("  \"reference\": \"full\",\n");
        out.push_str(&format!("  \"seq\": {},\n", self.seq));
        out.push_str(&format!("  \"samples\": {},\n", self.samples));
        out.push_str(&format!("  \"layers\": {},\n", self.layers));
        out.push_str(&format!("  \"n_heads\": {},\n", self.n_heads));
        out.push_str(&format!("  \"d_model\": {},\n", self.d_model));
        out.push_str(&format!("  \"landmarks\": [{}],\n", landmarks.join(",")));
        out.push_str(&format!("  \"precisions\": [{}],\n",
                              precisions.join(",")));
        out.push_str("  \"rows\": [\n");
        for (i, r) in self.rows.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"variant\": \"{}\", \"landmarks\": {}, \
                 \"precision\": \"{}\", \
                 \"mean_rel_err\": {}, \"max_rel_err\": {}, \
                 \"fro_ratio\": {}, \"per_layer_mean_rel_err\": {}}}{}\n",
                r.variant, r.landmarks, r.precision, num(r.mean_rel_err),
                num(r.max_rel_err), num(r.fro_ratio),
                num_list(&r.per_layer_mean_rel_err),
                if i + 1 == self.rows.len() { "" } else { "," }));
        }
        out.push_str("  ]\n}\n");
        out
    }
}

/// Where the JSON artifact goes: the repo root when run from `rust/`
/// (tests, `cargo run`), the current directory otherwise — the same
/// convention `benches/bench_snapshot.rs` uses for `BENCH_kernels.json`.
pub fn default_output_path() -> &'static str {
    if std::path::Path::new("../ROADMAP.md").exists() {
        "../BENCH_error_bound.json"
    } else {
        "BENCH_error_bound.json"
    }
}

/// Per-cell accumulator.
struct Acc {
    sum_rel: f64,
    max_rel: f64,
    count: usize,
    sum_diff_sq: f64,
    sum_ref_sq: f64,
    layer_sum_rel: Vec<f64>,
    layer_count: Vec<usize>,
}

impl Acc {
    fn new(layers: usize) -> Acc {
        Acc {
            sum_rel: 0.0,
            max_rel: 0.0,
            count: 0,
            sum_diff_sq: 0.0,
            sum_ref_sq: 0.0,
            layer_sum_rel: vec![0.0; layers],
            layer_count: vec![0; layers],
        }
    }

    fn record(&mut self, layer: usize, exact: &Tensor2, approx: &Tensor2) {
        assert_eq!((exact.rows, exact.cols), (approx.rows, approx.cols));
        let mut diff_sq = 0.0f64;
        let mut ref_sq = 0.0f64;
        for (&a, &e) in approx.data.iter().zip(&exact.data) {
            let d = (a - e) as f64;
            diff_sq += d * d;
            ref_sq += (e as f64) * (e as f64);
        }
        let rel = if ref_sq > 0.0 { (diff_sq / ref_sq).sqrt() } else { 0.0 };
        self.sum_rel += rel;
        self.max_rel = self.max_rel.max(rel);
        self.count += 1;
        self.sum_diff_sq += diff_sq;
        self.sum_ref_sq += ref_sq;
        self.layer_sum_rel[layer] += rel;
        self.layer_count[layer] += 1;
    }
}

/// Build the op for `variant` at swept landmark count `c` (see the
/// module docs for the per-variant mapping).
fn make_op(variant: &str, c: usize, pinv_iters: usize) -> Box<dyn AttentionOp> {
    match variant {
        "ss" => {
            let mut cfg = SpectralShiftConfig::new(c);
            cfg.pinv_iters = pinv_iters;
            Box::new(SpectralShiftOp(cfg))
        }
        "nystrom" => Box::new(NystromOp { landmarks: c, pinv_iters }),
        "linformer" => Box::new(LinformerOp { kdim: c, seed: 7 }),
        "lsh" => Box::new(LshOp { rounds: 2, bits: None, seed: 7 }),
        "sparse" => Box::new(SparseOp { window: Some(c), stride: None }),
        other => panic!("unknown eval variant {other}"),
    }
}

/// Run the sweep: replay the stack forward on `samples` tokenized
/// sequences from an eval-only text stream, measuring every variant at
/// every attention site against the exact softmax output.
///
/// `model` supplies the frozen embedding (and must share d_model /
/// n_heads with `stack`); `stack` supplies the — typically trained —
/// block weights.
pub fn error_bound_sweep(model: &CpuModel, stack: &EncoderStack,
                         cfg: &ErrorBoundConfig) -> ErrorBoundReport {
    assert!(!cfg.landmarks.is_empty(), "empty landmark sweep");
    for &c in &cfg.landmarks {
        assert!(c >= 1 && cfg.seq % c == 0,
                "seq {} not divisible by landmark count {c}", cfg.seq);
    }
    assert!(cfg.samples >= 1, "need at least one eval sequence");
    assert!(!cfg.precisions.is_empty(), "empty precision sweep");
    let d = stack.d_model();
    let heads = stack.n_heads();
    let dh = d / heads;
    let layers = stack.layers();
    let ctx = KernelCtx::sequential();
    let mut ws = Workspace::new();

    // eval-only token stream (seeded independently of training)
    let vocab = 512usize;
    let mut gen = CorpusGenerator::new(cfg.seed, 128, 4);
    let corpus = gen.corpus(cfg.samples.max(8), cfg.seq / 2, cfg.seq);
    let tok = Tokenizer::fit(&corpus, vocab);
    let mut rng = Rng::new(cfg.seed ^ 0x51EB);
    let sequences: Vec<Vec<i32>> = (0..cfg.samples)
        .map(|_| {
            let line = &corpus[rng.below(corpus.len() as u64) as usize];
            tok.encode(line, cfg.seq)
        })
        .collect();

    let cells: Vec<(&'static str, usize, Precision)> = EVAL_VARIANTS
        .iter()
        .flat_map(|&v| cfg.landmarks.iter().flat_map(move |&c| {
            cfg.precisions.iter().map(move |&p| (v, c, p))
        }))
        .collect();
    let mut accs: Vec<Acc> = cells.iter().map(|_| Acc::new(layers)).collect();

    // snap a tensor onto a precision tier's weight lattice (identity
    // for f32): the site-local analogue of the tier's quantized GEMMs
    fn snap(t: &Tensor2, p: Precision) -> Tensor2 {
        let mut out = Tensor2 {
            rows: t.rows,
            cols: t.cols,
            data: t.data.clone(),
        };
        if p != Precision::F32 {
            let qm = QuantMatrix::quantize(&t.data, t.rows, t.cols, p);
            qm.dequantize_into(&mut out.data);
        }
        out
    }

    // one closure measuring every cell at one attention problem, then
    // handing back the exact output for the forward to continue on.
    // The reference is always the exact f32 full softmax — quantized
    // cells are charged their full tier cost, not a same-tier delta.
    let measure = |layer: usize, q: &Tensor2, k: &Tensor2, v: &Tensor2,
                       accs: &mut [Acc], ws: &mut Workspace| -> Tensor2 {
        let e = FullOp.attend(&ctx, q, k, v, ws);
        let exact = Tensor2 { rows: e.rows, cols: e.cols, data: e.data.clone() };
        ws.put(e.data);
        let snapped: Vec<(Precision, Tensor2, Tensor2, Tensor2)> = cfg
            .precisions
            .iter()
            .map(|&p| (p, snap(q, p), snap(k, p), snap(v, p)))
            .collect();
        for (cell, acc) in cells.iter().zip(accs.iter_mut()) {
            let op = make_op(cell.0, cell.1, cfg.pinv_iters);
            let (_, qp, kp, vp) = snapped
                .iter()
                .find(|(p, _, _, _)| *p == cell.2)
                .expect("every cell precision was snapped");
            let approx = op.attend(&ctx, qp, kp, vp, ws);
            acc.record(layer, &exact, &approx);
            ws.put(approx.data);
        }
        exact
    };

    for seq_toks in &sequences {
        let mut x = model.embed_sequence(seq_toks, cfg.seq);
        // seed block: bare per-head attention, output replaces x
        let mut seed_out = Tensor2::zeros(cfg.seq, d);
        for h in 0..heads {
            let xs = head_slice(&x, h, dh);
            let o = measure(0, &xs, &xs, &xs, &mut accs, &mut ws);
            stitch(&mut seed_out, &o, h, dh);
        }
        x = seed_out;
        // full blocks: x += MHA(LN₁(x)); x += FFN(LN₂(x)), always
        // continuing on the exact attention output
        for (b, blk) in stack.blocks().iter().enumerate() {
            let ln = blk.attn_input(&ctx, &x, &mut ws);
            let mut att = Tensor2::zeros(cfg.seq, d);
            match blk.projections() {
                Some(p) => {
                    let mut merged = Tensor2::zeros(cfg.seq, d);
                    for h in 0..heads {
                        let mut q = Tensor2::zeros(cfg.seq, dh);
                        let mut k = Tensor2::zeros(cfg.seq, dh);
                        let mut v = Tensor2::zeros(cfg.seq, dh);
                        gemm_into(&ctx, &ln.data, p.wq(h), &mut q.data,
                                  cfg.seq, d, dh);
                        gemm_into(&ctx, &ln.data, p.wk(h), &mut k.data,
                                  cfg.seq, d, dh);
                        gemm_into(&ctx, &ln.data, p.wv(h), &mut v.data,
                                  cfg.seq, d, dh);
                        let o = measure(b + 1, &q, &k, &v, &mut accs, &mut ws);
                        stitch(&mut merged, &o, h, dh);
                    }
                    gemm_into(&ctx, &merged.data, p.wo(), &mut att.data,
                              cfg.seq, d, d);
                }
                None => {
                    for h in 0..heads {
                        let qs = head_slice(&ln, h, dh);
                        let o = measure(b + 1, &qs, &qs, &qs, &mut accs,
                                        &mut ws);
                        stitch(&mut att, &o, h, dh);
                    }
                }
            }
            ws.put(ln.data);
            for (xi, ai) in x.data.iter_mut().zip(&att.data) {
                *xi += *ai;
            }
            blk.ffn_sublayer(&ctx, &mut x, &mut ws);
        }
    }

    let rows = cells
        .iter()
        .zip(&accs)
        .map(|(&(variant, landmarks, precision), acc)| ErrorBoundRow {
            variant,
            landmarks,
            precision: precision.token(),
            mean_rel_err: acc.sum_rel / acc.count as f64,
            max_rel_err: acc.max_rel,
            fro_ratio: if acc.sum_ref_sq > 0.0 {
                (acc.sum_diff_sq / acc.sum_ref_sq).sqrt()
            } else {
                0.0
            },
            per_layer_mean_rel_err: acc
                .layer_sum_rel
                .iter()
                .zip(&acc.layer_count)
                .map(|(&s, &n)| if n > 0 { s / n as f64 } else { 0.0 })
                .collect(),
        })
        .collect();
    ErrorBoundReport {
        seq: cfg.seq,
        samples: cfg.samples,
        layers,
        n_heads: heads,
        d_model: d,
        landmarks: cfg.landmarks.clone(),
        precisions: cfg.precisions.clone(),
        rows,
    }
}

fn head_slice(x: &Tensor2, h: usize, dh: usize) -> Tensor2 {
    let mut out = Tensor2::zeros(x.rows, dh);
    for i in 0..x.rows {
        out.row_mut(i).copy_from_slice(&x.row(i)[h * dh..(h + 1) * dh]);
    }
    out
}

fn stitch(dst: &mut Tensor2, head_out: &Tensor2, h: usize, dh: usize) {
    for i in 0..dst.rows {
        dst.row_mut(i)[h * dh..(h + 1) * dh]
            .copy_from_slice(head_out.row(i));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Variant;
    use crate::coordinator::CpuModelConfig;
    use crate::kernels::BatchedVariant;

    fn tiny_setup() -> (CpuModel, EncoderStack) {
        let mcfg = CpuModelConfig {
            d_model: 16, n_heads: 2, vocab: 128, seed: 5, layers: 2,
            ffn_mult: 2, projections: true, ..Default::default()
        };
        let model = CpuModel::new(mcfg, Variant::Full);
        let stack = EncoderStack::new_mixed(
            vec![BatchedVariant::Full; 2], 16, 2, 2, 5, true);
        (model, stack)
    }

    #[test]
    fn sweep_covers_every_variant_at_every_landmark_and_precision() {
        let (model, stack) = tiny_setup();
        let cfg = ErrorBoundConfig {
            landmarks: vec![4, 8], seq: 16, samples: 2,
            ..Default::default()
        };
        let rep = error_bound_sweep(&model, &stack, &cfg);
        assert_eq!(rep.rows.len(),
                   EVAL_VARIANTS.len() * 2 * Precision::ALL.len());
        for r in &rep.rows {
            assert!(r.mean_rel_err.is_finite() && r.mean_rel_err >= 0.0,
                    "{} c={} {}", r.variant, r.landmarks, r.precision);
            assert!(r.max_rel_err >= r.mean_rel_err || r.max_rel_err == 0.0);
            assert_eq!(r.per_layer_mean_rel_err.len(), 2);
        }
        assert!(rep.mean_rel_err("ss", 4).is_some());
        assert!(rep.mean_rel_err("ss", 5).is_none());
        // the classic lookup IS the f32 tier cell
        assert_eq!(rep.mean_rel_err("ss", 4),
                   rep.mean_rel_err_at("ss", 4, Precision::F32));
        // every tier has a measured row, and the quantized ss tiers
        // carry real (nonzero) error against the exact f32 reference
        for p in Precision::ALL {
            let e = rep.mean_rel_err_at("ss", 4, p)
                .expect("tier row present");
            assert!(e.is_finite() && e > 0.0, "{}: {e}", p.token());
        }
    }

    #[test]
    fn json_is_well_formed_and_carries_the_schema() {
        let (model, stack) = tiny_setup();
        let cfg = ErrorBoundConfig {
            landmarks: vec![4], seq: 16, samples: 1, ..Default::default()
        };
        let rep = error_bound_sweep(&model, &stack, &cfg);
        let json = rep.to_json();
        assert!(json.contains("\"schema\": \"ssaf-error-bound/v2\""));
        assert!(json.contains("\"variant\": \"ss\""));
        assert!(json.contains("\"variant\": \"nystrom\""));
        assert!(json.contains("\"precisions\": [\"f32\",\"bf16\",\"int8\"]"));
        assert!(json.contains("\"precision\": \"int8\""));
        assert_eq!(json.matches("\"mean_rel_err\"").count(),
                   EVAL_VARIANTS.len() * Precision::ALL.len());
        // balanced braces/brackets — cheap structural check without a
        // JSON parser in-tree
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }

    #[test]
    fn precision_snap_is_identity_at_f32_and_lossy_below() {
        // a single-precision sweep at f32 must reproduce the classic
        // rows exactly (the snap is the identity there)
        let (model, stack) = tiny_setup();
        let f32_only = ErrorBoundConfig {
            landmarks: vec![4], seq: 16, samples: 1,
            precisions: vec![Precision::F32], ..Default::default()
        };
        let all = ErrorBoundConfig {
            landmarks: vec![4], seq: 16, samples: 1, ..Default::default()
        };
        let rep_f32 = error_bound_sweep(&model, &stack, &f32_only);
        let rep_all = error_bound_sweep(&model, &stack, &all);
        assert_eq!(rep_f32.rows.len(), EVAL_VARIANTS.len());
        for r in &rep_f32.rows {
            assert_eq!(Some(r.mean_rel_err),
                       rep_all.mean_rel_err_at(r.variant, r.landmarks,
                                               Precision::F32),
                       "{} c={}", r.variant, r.landmarks);
        }
        // int8-snapped inputs genuinely move the ss output — the tier
        // rows are measurements, not copies of the f32 row
        let f = rep_all.mean_rel_err_at("ss", 4, Precision::F32).unwrap();
        let i = rep_all.mean_rel_err_at("ss", 4, Precision::Int8).unwrap();
        assert_ne!(f, i, "int8 row must differ from the f32 row");
    }

    #[test]
    fn exact_reference_has_zero_error_against_itself() {
        // feeding the exact output through the accumulator must give 0
        let a = Tensor2::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let mut acc = Acc::new(1);
        acc.record(0, &a, &a);
        assert_eq!(acc.sum_rel, 0.0);
        assert_eq!(acc.max_rel, 0.0);
    }
}
