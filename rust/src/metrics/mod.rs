//! Metrics substrate (S19): log-bucketed latency histograms, counters,
//! and throughput meters used by the coordinator and the bench harness.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Log₂-bucketed latency histogram (microseconds, 1µs .. ~73h range).
/// Lock-free recording; quantiles computed on demand.
pub struct LatencyHistogram {
    /// bucket i counts samples in [2^i, 2^{i+1}) µs
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

const NBUCKETS: usize = 38;

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: (0..NBUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    /// Record one duration.
    pub fn record(&self, d: Duration) {
        let us = d.as_micros().min(u64::MAX as u128) as u64;
        let b = (64 - us.max(1).leading_zeros() as usize - 1).min(NBUCKETS - 1);
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean in microseconds.
    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate quantile (upper bucket edge), q in [0,1].
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1); // upper edge of bucket
            }
        }
        self.max_us()
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.0}us p50={}us p99={}us max={}us",
            self.count(),
            self.mean_us(),
            self.quantile_us(0.5),
            self.quantile_us(0.99),
            self.max_us()
        )
    }
}

/// Monotonic counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Coordinator-wide metrics bundle. All fields are updated lock-free by
/// the worker loop and read on demand by `report()` (the server's
/// `STATS` command).
#[derive(Default)]
pub struct ServingMetrics {
    pub requests_in: Counter,
    pub requests_done: Counter,
    pub requests_rejected: Counter,
    /// Requests that missed their deadline: rejected at admission with
    /// an already-expired deadline, or expired while queued (failed by
    /// the worker before occupying a batch slot). Disjoint from
    /// `requests_done` and `requests_rejected`.
    pub requests_expired: Counter,
    /// Admission-path embedding-cache hits — served instantly without
    /// queueing or batching (still counted in `requests_done`).
    pub cache_hits: Counter,
    /// Cache lookups that missed **and reached batch compute** (counted
    /// by the worker when the batch is formed, only when a cache is
    /// configured). Requests rejected at admission or expired while
    /// queued are excluded, so they cannot deflate the hit rate.
    pub cache_misses: Counter,
    /// Prefix-cache hits on the chunked long-document path: chunks
    /// whose pooled embedding was reused instead of recomputed. One
    /// document contributes one count per reused chunk.
    pub prefix_hits: Counter,
    /// Prefix-cache lookups that missed (the chunk went through the
    /// queue and was computed). `prefix_hits + prefix_misses` = chunks
    /// admitted on the long-document path.
    pub prefix_misses: Counter,
    /// Chunks actually executed for long documents (a miss that reached
    /// compute and returned an embedding). Tracks `prefix_misses` minus
    /// chunks lost to expiry/rejection mid-document.
    pub chunks_computed: Counter,
    /// Requests served on the configured path — untagged and with no
    /// forced tier, so admission routing never touched them (the
    /// byte-identical legacy behavior).
    pub admission_configured: Counter,
    /// Requests routed to each admission tier, indexed by
    /// `TierKind::index()` (`coordinator::admission`): full-f32,
    /// ss-f32, ss-bf16, ss-int8 — the STATS `admission:` line.
    pub admission_served: [Counter; 4],
    pub batches_executed: Counter,
    pub tokens_processed: Counter,
    /// Request slots offered across all executed batches (capacity ×
    /// batches); `requests_done / batch_slots` is batch occupancy.
    pub batch_slots: Counter,
    /// Padding positions *executed* on top of real tokens: the whole
    /// dense remainder of the capacity×bucket tensor on the XLA path,
    /// only the landmark-alignment tails on the CPU path (padding rows
    /// there are skipped outright).
    pub padded_tokens: Counter,
    pub queue_latency: LatencyHistogram,
    pub exec_latency: LatencyHistogram,
    pub e2e_latency: LatencyHistogram,
}

impl ServingMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Multi-line human-readable report (the `STATS` body; field
    /// meanings are specified in `server::` module docs).
    pub fn report(&self) -> String {
        let real = self.tokens_processed.get();
        let padded = self.padded_tokens.get();
        let hits = self.cache_hits.get();
        let lookups = hits + self.cache_misses.get();
        // cache hits never occupy a batch slot, so fill/occupancy are
        // computed over the batch-executed requests only
        let batched = self.requests_done.get().saturating_sub(hits);
        let phits = self.prefix_hits.get();
        let plookups = phits + self.prefix_misses.get();
        format!(
            "requests: in={} done={} rejected={} expired={}\n\
             cache:    hits={} misses={} ({:.0}% hit rate)\n\
             prefix:   hits={} misses={} chunks={} ({:.0}% hit rate)\n\
             admission: configured={} full-f32={} ss-f32={} ss-bf16={} \
             ss-int8={}\n\
             batches:  {} (avg fill {:.2} req/batch, occupancy {:.0}%)\n\
             tokens:   {} (+{} executed padding, {:.0}% waste)\n\
             queue:    {}\n\
             exec:     {}\n\
             e2e:      {}",
            self.requests_in.get(),
            self.requests_done.get(),
            self.requests_rejected.get(),
            self.requests_expired.get(),
            hits,
            self.cache_misses.get(),
            100.0 * hits as f64 / lookups.max(1) as f64,
            phits,
            self.prefix_misses.get(),
            self.chunks_computed.get(),
            100.0 * phits as f64 / plookups.max(1) as f64,
            self.admission_configured.get(),
            self.admission_served[0].get(),
            self.admission_served[1].get(),
            self.admission_served[2].get(),
            self.admission_served[3].get(),
            self.batches_executed.get(),
            batched as f64 / self.batches_executed.get().max(1) as f64,
            100.0 * batched as f64 / self.batch_slots.get().max(1) as f64,
            real,
            padded,
            100.0 * padded as f64 / (real + padded).max(1) as f64,
            self.queue_latency.summary(),
            self.exec_latency.summary(),
            self.e2e_latency.summary(),
        )
    }
}

/// Cluster-router metrics bundle — the router front-end's counterpart
/// to [`ServingMetrics`], surfaced as the `cluster:` lines of a
/// router-mode `STATS` report. All counters are lock-free; the
/// accounting invariant is
/// `forwarded = OK-from-replica + replica_lost` (every accepted request
/// either reaches a replica and is answered, or is reported lost —
/// never silently dropped), with `retried` counting the extra replica
/// attempts hidden inside `forwarded`.
#[derive(Default)]
pub struct RouterMetrics {
    /// ENCODE requests accepted by the router and sent toward a replica
    /// (cache hits and expired-at-router requests are excluded — they
    /// never touch a replica).
    pub forwarded: Counter,
    /// Additional replica attempts after a first attempt failed
    /// mid-flight (reconnects and failovers to the next ring
    /// preference).
    pub retried: Counter,
    /// Requests answered `ERR <id> replica-lost`: every ring preference
    /// failed. Disjoint from successful forwards.
    pub replica_lost: Counter,
    /// Requests answered `ERR <id> deadline` at the router because the
    /// forwarded budget had already reached zero — no replica was
    /// touched.
    pub expired_at_router: Counter,
    /// Router-side embedding-cache hits (short-circuited replies,
    /// bitwise-equal to a replica recompute).
    pub cache_hits: Counter,
    /// Router-side cache misses (the request went to a replica; its OK
    /// payload is inserted on the way back).
    pub cache_misses: Counter,
    /// Health probes that failed (connect error or bad `PING` reply) —
    /// each marks the probed replica down until a later probe succeeds.
    pub probe_failures: Counter,
}

impl RouterMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// The `cluster:` counter lines of a router STATS report (membership
    /// lines are added by the router itself, which owns that state).
    pub fn report(&self) -> String {
        let hits = self.cache_hits.get();
        let lookups = hits + self.cache_misses.get();
        format!(
            "cluster:  forwarded={} retried={} replica-lost={} \
             expired-at-router={} probe-failures={}\n\
             cluster:  cache hits={} misses={} ({:.0}% hit rate)",
            self.forwarded.get(),
            self.retried.get(),
            self.replica_lost.get(),
            self.expired_at_router.get(),
            self.probe_failures.get(),
            hits,
            self.cache_misses.get(),
            100.0 * hits as f64 / lookups.max(1) as f64,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_mean_and_quantiles() {
        let h = LatencyHistogram::new();
        for us in [100u64, 200, 400, 800, 100_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert!((h.mean_us() - 20300.0).abs() < 1.0);
        // p50 is the 3rd of 5 samples (400µs) → bucket [256,512) edge 512
        assert_eq!(h.quantile_us(0.5), 512);
        assert!(h.quantile_us(1.0) >= 100_000 / 2);
        assert_eq!(h.max_us(), 100_000);
    }

    #[test]
    fn histogram_empty() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn histogram_concurrent_recording() {
        use std::sync::Arc;
        let h = Arc::new(LatencyHistogram::new());
        let mut handles = Vec::new();
        for t in 0..4 {
            let h = h.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1000 {
                    h.record(Duration::from_micros(t * 100 + i));
                }
            }));
        }
        for j in handles {
            j.join().unwrap();
        }
        assert_eq!(h.count(), 4000);
    }

    #[test]
    fn counter_ops() {
        let c = Counter::new();
        c.inc();
        c.add(10);
        assert_eq!(c.get(), 11);
    }

    #[test]
    fn serving_metrics_report_contains_fields() {
        let m = ServingMetrics::new();
        m.requests_in.add(5);
        m.requests_done.add(4);
        m.batches_executed.add(2);
        m.batch_slots.add(8);
        m.tokens_processed.add(300);
        m.padded_tokens.add(100);
        let r = m.report();
        assert!(r.contains("in=5"));
        assert!(r.contains("done=4"));
        assert!(r.contains("expired=0"), "{r}");
        assert!(r.contains("avg fill 2.00"));
        assert!(r.contains("occupancy 50%"), "{r}");
        assert!(r.contains("+100 executed padding"), "{r}");
        assert!(r.contains("25% waste"), "{r}");
    }

    #[test]
    fn cache_hits_do_not_inflate_occupancy() {
        let m = ServingMetrics::new();
        // 8 served: 4 from batches (2 batches × 4 slots), 4 from cache
        m.requests_in.add(8);
        m.requests_done.add(8);
        m.cache_hits.add(4);
        m.cache_misses.add(4);
        m.batches_executed.add(2);
        m.batch_slots.add(8);
        let r = m.report();
        assert!(r.contains("hits=4 misses=4 (50% hit rate)"), "{r}");
        // occupancy counts only the batch-served half
        assert!(r.contains("avg fill 2.00"), "{r}");
        assert!(r.contains("occupancy 50%"), "{r}");
    }

    #[test]
    fn prefix_cache_line_reports_chunk_accounting() {
        let m = ServingMetrics::new();
        // a 3-chunk document replayed once: 3 cold misses computed,
        // then 3 warm hits — 50% hit rate over 6 chunk lookups
        m.prefix_misses.add(3);
        m.chunks_computed.add(3);
        m.prefix_hits.add(3);
        let r = m.report();
        assert!(
            r.contains("prefix:   hits=3 misses=3 chunks=3 (50% hit rate)"),
            "{r}"
        );
        // the prefix line is independent of the embedding-cache line
        assert!(r.contains("cache:    hits=0 misses=0 (0% hit rate)"), "{r}");
    }

    #[test]
    fn admission_line_counts_every_tier() {
        let m = ServingMetrics::new();
        m.admission_configured.add(7);
        m.admission_served[0].add(1); // full-f32
        m.admission_served[3].add(2); // ss-int8
        let r = m.report();
        assert!(
            r.contains(
                "admission: configured=7 full-f32=1 ss-f32=0 ss-bf16=0 \
                 ss-int8=2"),
            "{r}"
        );
    }

    #[test]
    fn expired_requests_are_reported() {
        let m = ServingMetrics::new();
        m.requests_in.add(3);
        m.requests_done.add(2);
        m.requests_expired.inc();
        let r = m.report();
        assert!(r.contains("expired=1"), "{r}");
    }

    #[test]
    fn router_metrics_report_contains_fields() {
        let m = RouterMetrics::new();
        m.forwarded.add(10);
        m.retried.add(2);
        m.replica_lost.inc();
        m.expired_at_router.add(3);
        m.probe_failures.add(4);
        m.cache_hits.add(6);
        m.cache_misses.add(2);
        let r = m.report();
        assert!(r.contains("forwarded=10"), "{r}");
        assert!(r.contains("retried=2"), "{r}");
        assert!(r.contains("replica-lost=1"), "{r}");
        assert!(r.contains("expired-at-router=3"), "{r}");
        assert!(r.contains("probe-failures=4"), "{r}");
        assert!(r.contains("hits=6 misses=2 (75% hit rate)"), "{r}");
        // every line of the block is namespaced for the STATS report
        assert!(r.lines().all(|l| l.starts_with("cluster:")), "{r}");
    }

    #[test]
    fn router_metrics_empty_report_is_well_formed() {
        let r = RouterMetrics::new().report();
        assert!(r.contains("forwarded=0"), "{r}");
        assert!(r.contains("(0% hit rate)"), "{r}");
    }
}
