//! Spectrum analysis — the machinery behind the paper's Figure 2.
//!
//! Computes eigenvalue spectra of (symmetrized) attention matrices and
//! their approximations, the cumulative-eigenvalue curves the figure
//! plots, effective rank, and tail-mass summaries.

use crate::linalg::{self, Matrix};

/// Spectrum summary of a (symmetrized) matrix.
#[derive(Clone, Debug)]
pub struct Spectrum {
    /// |eigenvalues|, sorted descending.
    pub values: Vec<f64>,
    /// Cumulative normalized sums: cum[i] = Σ_{j≤i} |λ_j| / Σ |λ|.
    pub cumulative: Vec<f64>,
}

impl Spectrum {
    /// Spectrum of (A + Aᵀ)/2. Attention matrices are not symmetric;
    /// the paper's Figure 2 plots eigenvalue magnitude curves — the
    /// symmetrized spectrum is the standard well-defined surrogate.
    pub fn of(a: &Matrix) -> Spectrum {
        let sym = a.symmetrize();
        let mut values: Vec<f64> = linalg::sym_eigenvalues(&sym, 1e-11)
            .into_iter()
            .map(f64::abs)
            .collect();
        values.sort_by(|x, y| y.partial_cmp(x).unwrap());
        let total: f64 = values.iter().sum();
        let mut cumulative = Vec::with_capacity(values.len());
        let mut run = 0.0;
        for &v in &values {
            run += v;
            cumulative.push(if total > 0.0 { run / total } else { 0.0 });
        }
        Spectrum { values, cumulative }
    }

    /// Smallest index i with cumulative[i] ≥ frac (1-based count).
    pub fn index_reaching(&self, frac: f64) -> usize {
        self.cumulative
            .iter()
            .position(|&c| c >= frac)
            .map(|i| i + 1)
            .unwrap_or(self.cumulative.len())
    }

    /// Effective rank: exp(entropy of the normalized spectrum)
    /// (Roy & Vetterli). Low for spiky spectra, ≈n for flat ones.
    pub fn effective_rank(&self) -> f64 {
        let total: f64 = self.values.iter().sum();
        if total <= 0.0 {
            return 0.0;
        }
        let mut h = 0.0;
        for &v in &self.values {
            let p = v / total;
            if p > 1e-300 {
                h -= p * p.ln();
            }
        }
        h.exp()
    }

    /// Fraction of spectral mass in eigenvalues after index `k`.
    pub fn tail_mass(&self, k: usize) -> f64 {
        if k >= self.cumulative.len() {
            return 0.0;
        }
        1.0 - self.cumulative[k.saturating_sub(1).min(self.cumulative.len() - 1)]
    }

    /// Count of eigenvalues below `eps` (the "collapsed" tail of a
    /// low-rank approximation).
    pub fn near_zero_count(&self, eps: f64) -> usize {
        self.values.iter().filter(|&&v| v < eps).count()
    }
}

/// The Figure-2 comparison for one (S, S̃) pair.
#[derive(Clone, Debug)]
pub struct SpectrumComparison {
    pub true_spectrum: Spectrum,
    pub approx_spectrum: Spectrum,
    /// eigenvalue count of S (=n)
    pub n: usize,
}

impl SpectrumComparison {
    pub fn new(s_true: &Matrix, s_approx: &Matrix) -> Self {
        SpectrumComparison {
            true_spectrum: Spectrum::of(s_true),
            approx_spectrum: Spectrum::of(s_approx),
            n: s_true.rows(),
        }
    }

    /// Render both cumulative curves at `points` sample indices —
    /// exactly the two series Figure 2 plots (x: eigen index,
    /// y: cumulative eigenvalue mass).
    pub fn cumulative_series(&self, points: usize) -> Vec<(usize, f64, f64)> {
        let n = self.n.max(1);
        let step = (n / points.max(1)).max(1);
        (0..n)
            .step_by(step)
            .map(|i| {
                (
                    i + 1,
                    self.true_spectrum.cumulative[i],
                    self.approx_spectrum.cumulative[i],
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::random_orthonormal;
    use crate::rngx::Rng;

    fn spiked(rng: &mut Rng, n: usize, k: usize, theta: f64) -> Matrix {
        let u = random_orthonormal(rng, n, n);
        let mut lam = vec![theta; n];
        for (i, l) in lam.iter_mut().take(k).enumerate() {
            *l = 5.0 - i as f64 * 0.3;
        }
        let d = Matrix::diag(&lam);
        crate::linalg::matmul(&crate::linalg::matmul(&u, &d), &u.transpose())
    }

    #[test]
    fn identity_spectrum_flat() {
        let s = Spectrum::of(&Matrix::eye(10));
        assert!((s.values[0] - 1.0).abs() < 1e-10);
        assert!((s.cumulative[4] - 0.5).abs() < 1e-10);
        assert!((s.effective_rank() - 10.0).abs() < 1e-6);
        assert_eq!(s.near_zero_count(0.5), 0);
    }

    #[test]
    fn rank_one_spectrum_spiky() {
        let mut m = Matrix::zeros(8, 8);
        m[(0, 0)] = 4.0;
        let s = Spectrum::of(&m);
        assert!((s.cumulative[0] - 1.0).abs() < 1e-12);
        assert!(s.effective_rank() < 1.01);
        assert_eq!(s.near_zero_count(1e-9), 7);
        assert_eq!(s.index_reaching(0.99), 1);
    }

    #[test]
    fn spiked_matrix_long_tail_detected() {
        let mut rng = Rng::new(1);
        let m = spiked(&mut rng, 40, 3, 0.5);
        let s = Spectrum::of(&m);
        // 3 spikes ≈ 14 mass, tail 37·0.5 = 18.5: cumulative reaches 0.99
        // only deep into the tail ⇒ long tail
        assert!(s.index_reaching(0.99) > 30);
        assert!(s.effective_rank() > 10.0);
        assert_eq!(s.near_zero_count(0.1), 0); // tail is flat, not zero
    }

    #[test]
    fn comparison_series_shape() {
        let mut rng = Rng::new(2);
        let a = spiked(&mut rng, 24, 2, 0.3);
        let b = Matrix::eye(24);
        let cmp = SpectrumComparison::new(&a, &b);
        let series = cmp.cumulative_series(8);
        assert!(series.len() >= 8);
        assert!(series.iter().all(|&(i, t, ap)| {
            i >= 1 && (0.0..=1.0 + 1e-9).contains(&t) && (0.0..=1.0 + 1e-9).contains(&ap)
        }));
        // cumulative curves are nondecreasing
        for w in series.windows(2) {
            assert!(w[1].1 >= w[0].1 && w[1].2 >= w[0].2);
        }
    }

    #[test]
    fn tail_mass_consistency() {
        let s = Spectrum::of(&Matrix::diag(&[4.0, 2.0, 1.0, 1.0]));
        // total 8; after first eigenvalue tail = 4/8
        assert!((s.tail_mass(1) - 0.5).abs() < 1e-12);
        assert_eq!(s.tail_mass(10), 0.0);
    }
}
