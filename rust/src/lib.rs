//! # ssaformer
//!
//! Production-grade reproduction of *"Beyond Nyströmformer —
//! Approximation of self-attention by Spectral Shifting"* (Verma, 2021)
//! as a three-layer Rust + JAX + Pallas system:
//!
//! * **L1** (`python/compile/kernels/`) — Pallas kernels for
//!   segment-means landmarks, flash attention, the streamed landmark
//!   cross-attention factor, the eq-11 Newton-Schulz pseudoinverse, and
//!   the fused spectral-shifting combine.
//! * **L2** (`python/compile/model.py`) — a JAX transformer encoder with
//!   pluggable attention (full / nystrom / ss), AOT-lowered once to HLO
//!   text artifacts.
//! * **L3** (this crate) — the serving/training stack: dynamic batcher
//!   behind a bucketed queue, dual execution backends (PJRT artifacts
//!   or the in-process multi-layer [`model::EncoderStack`] on the CPU
//!   kernel core, with every attention variant behind the
//!   [`model::AttentionOp`] seam), a multi-replica cluster tier (the
//!   [`coordinator::cluster`] consistent-hash router front-end with
//!   deterministic fault injection via [`server::FaultPlan`]), metrics,
//!   plus every substrate the paper's evaluation needs (dense linear
//!   algebra, SPSD model zoo, attention baselines, spectrum analysis,
//!   workload generation).
//!
//! ## Request lifecycle (one line)
//!
//! socket → [`server`] line protocol → [`coordinator`] route → embedding
//! cache (hit answers instantly; a hit is bitwise-equal to a recompute)
//! → sharded bucket queue, deadline-aware → worker pool (work-stealing)
//! → `batcher::assemble` → execution backend (XLA artifact **or**
//! [`kernels`] CPU core) → scatter/pool → cache insert → response
//! channel. A `--role router` process optionally fronts N such
//! replicas ([`coordinator::cluster`]): same wire protocol, consistent-
//! hash placement, failover (never a silent drop), cross-replica cache.
//! The full walkthrough, with the data-flow diagram, deadline
//! semantics, and the paper-symbol → function table, lives in
//! `ARCHITECTURE.md` at the repo root; the operator's view (knobs,
//! `STATS` reference, capacity planning) in `OPERATIONS.md`.
//!
//! ## Crate-wide invariants
//!
//! * **Bitwise thread-count determinism** — every [`kernels`] primitive
//!   splits work into fixed-size row blocks, so results are identical
//!   for 1 and N threads.
//! * **Zero steady-state allocation** — hot-path scratch comes from
//!   recycled [`kernels::Workspace`] arenas; once warm, serving a batch
//!   performs no heap allocation inside the kernels.
//! * **Padding never reaches responses** — `batcher::scatter` drops
//!   padding rows before any embedding is returned, and pooling on the
//!   CPU backend averages only real positions. Executed padding is
//!   bounded and metered (`padded_tokens`): the CPU backend skips
//!   padding *requests* outright and computes only the short
//!   landmark-alignment tail of each request (PAD-token keys inside
//!   that tail do participate in attention — they are part of the
//!   served function, deterministically); the XLA artifact executes its
//!   full dense tensor.
//!
//! ## Quick taste
//!
//! The paper's O(n) spectral-shifting attention, pure Rust:
//!
//! ```
//! use ssaformer::attention::{spectral_shift_attention, SpectralShiftConfig, Tensor2};
//! let mut rng = ssaformer::rngx::Rng::new(0);
//! let q = Tensor2::randn(&mut rng, 64, 16, 1.0); // n=64 tokens, d=16
//! let k = Tensor2::randn(&mut rng, 64, 16, 1.0);
//! let v = Tensor2::randn(&mut rng, 64, 16, 1.0);
//! let out = spectral_shift_attention(&q, &k, &v, &SpectralShiftConfig::new(8));
//! assert_eq!((out.rows, out.cols), (64, 16));
//! assert!(out.data.iter().all(|x| x.is_finite()));
//! ```
//!
//! And the CPU serving model that backs artifact-free serving:
//!
//! ```
//! use ssaformer::config::Variant;
//! use ssaformer::coordinator::{CpuModel, CpuModelConfig};
//! let model = CpuModel::new(CpuModelConfig::default(), Variant::SpectralShift);
//! // a 100-token request executes at the next landmark multiple
//! assert_eq!(model.padded_len(100), 112);
//! let x = model.embed_sequence(&[5, 6, 7], 3);
//! assert_eq!((x.rows, x.cols), (3, model.d_model()));
//! ```
//!
//! See DESIGN.md for the full system inventory and the per-experiment
//! index (Table 1, Figure 2, Lemma 1/Theorem 1, eq 11/12, sec 8/9).

pub mod attention;
pub mod benchkit;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod kernels;
pub mod linalg;
pub mod metrics;
pub mod minirt;
pub mod model;
pub mod proptest_mini;
pub mod rngx;
pub mod runtime;
pub mod server;
pub mod spectral;
pub mod spsd;
pub mod text;
pub mod train;
pub mod workload;
