//! # ssaformer
//!
//! Production-grade reproduction of *"Beyond Nyströmformer —
//! Approximation of self-attention by Spectral Shifting"* (Verma, 2021)
//! as a three-layer Rust + JAX + Pallas system:
//!
//! * **L1** (`python/compile/kernels/`) — Pallas kernels for
//!   segment-means landmarks, flash attention, the streamed landmark
//!   cross-attention factor, the eq-11 Newton-Schulz pseudoinverse, and
//!   the fused spectral-shifting combine.
//! * **L2** (`python/compile/model.py`) — a JAX transformer encoder with
//!   pluggable attention (full / nystrom / ss), AOT-lowered once to HLO
//!   text artifacts.
//! * **L3** (this crate) — the serving/training coordinator: PJRT
//!   runtime, request router, dynamic batcher, metrics, plus every
//!   substrate the paper's evaluation needs (dense linear algebra,
//!   SPSD model zoo, attention baselines, spectrum analysis, workload
//!   generation).
//!
//! See DESIGN.md for the full system inventory and the per-experiment
//! index (Table 1, Figure 2, Lemma 1/Theorem 1, eq 11/12, sec 8/9).

pub mod attention;
pub mod benchkit;
pub mod config;
pub mod coordinator;
pub mod kernels;
pub mod linalg;
pub mod metrics;
pub mod minirt;
pub mod proptest_mini;
pub mod rngx;
pub mod runtime;
pub mod server;
pub mod spectral;
pub mod spsd;
pub mod text;
pub mod train;
pub mod workload;
