//! Bench harness substrate (S22) — the crate cache has no criterion, so
//! timing, robust statistics, scaling-exponent fits and table printing
//! live here. Every `rust/benches/*.rs` target is a plain
//! `harness = false` binary built on this module.

use std::time::{Duration, Instant};

/// Timing statistics over repeated runs of a closure.
#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub p95: Duration,
}

impl Stats {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

/// Time `f` with warmup; chooses iteration count so total time stays
/// near `budget` (min 3, max `max_iters` runs).
pub fn bench<F: FnMut()>(mut f: F, budget: Duration, max_iters: usize) -> Stats {
    // warmup + calibration run
    let t0 = Instant::now();
    f();
    let first = t0.elapsed();
    let iters = if first.is_zero() {
        max_iters
    } else {
        ((budget.as_secs_f64() / first.as_secs_f64()) as usize).clamp(3, max_iters)
    };
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort();
    let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
    Stats {
        iters: samples.len(),
        mean,
        median: samples[samples.len() / 2],
        min: samples[0],
        p95: samples[(samples.len() as f64 * 0.95) as usize % samples.len()],
    }
}

/// Least-squares fit of log(y) = a + b·log(x): returns the scaling
/// exponent b. This is how the Table-1 bench turns measured wall-clock
/// into an empirical complexity exponent.
pub fn scaling_exponent(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let lx: Vec<f64> = xs.iter().map(|x| x.ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|y| y.ln()).collect();
    let n = lx.len() as f64;
    let mx = lx.iter().sum::<f64>() / n;
    let my = ly.iter().sum::<f64>() / n;
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in lx.iter().zip(&ly) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    num / den.max(1e-300)
}

/// Fixed-width table printer for bench reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with per-column widths; first column left-aligned, rest
    /// right-aligned (numeric convention).
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            w[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], w: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i == 0 {
                    line.push_str(&format!("{:<width$}", c, width = w[i]));
                } else {
                    line.push_str(&format!("  {:>width$}", c, width = w[i]));
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.headers, &w));
        out.push('\n');
        let total: usize = w.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &w));
            out.push('\n');
        }
        out
    }
}

/// Format a duration human-readably (µs/ms/s).
pub fn fmt_duration(d: Duration) -> String {
    let us = d.as_secs_f64() * 1e6;
    if us < 1000.0 {
        format!("{us:.1}µs")
    } else if us < 1_000_000.0 {
        format!("{:.2}ms", us / 1000.0)
    } else {
        format!("{:.3}s", us / 1e6)
    }
}

/// Standard bench header so all bench outputs are greppable.
pub fn banner(name: &str, what: &str) {
    println!("\n=== {name} ===");
    println!("{what}\n");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let mut x = 0u64;
        let s = bench(
            || {
                for i in 0..10_000 {
                    x = x.wrapping_add(i);
                }
            },
            Duration::from_millis(20),
            50,
        );
        assert!(s.iters >= 3);
        assert!(s.min <= s.median && s.median <= s.p95);
        std::hint::black_box(x);
    }

    #[test]
    fn scaling_exponent_recovers_powers() {
        let xs = [256.0, 512.0, 1024.0, 2048.0];
        let quad: Vec<f64> = xs.iter().map(|x| 3.0 * x * x).collect();
        assert!((scaling_exponent(&xs, &quad) - 2.0).abs() < 1e-9);
        let lin: Vec<f64> = xs.iter().map(|x| 0.5 * x).collect();
        assert!((scaling_exponent(&xs, &lin) - 1.0).abs() < 1e-9);
        let nlogn: Vec<f64> = xs.iter().map(|x| x * x.ln()).collect();
        let e = scaling_exponent(&xs, &nlogn);
        assert!(e > 1.05 && e < 1.35, "{e}");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["variant", "n=256", "n=512"]);
        t.row(&["full".into(), "1.0ms".into(), "4.0ms".into()]);
        t.row(&["ss".into(), "0.2ms".into(), "0.4ms".into()]);
        let r = t.render();
        assert!(r.contains("variant"));
        assert!(r.lines().count() == 4);
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines[0].len(), lines[2].len());
    }

    #[test]
    #[should_panic]
    fn table_row_width_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn fmt_duration_units() {
        assert!(fmt_duration(Duration::from_micros(5)).ends_with("µs"));
        assert!(fmt_duration(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(5)).ends_with('s'));
    }
}
