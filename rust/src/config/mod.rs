//! Configuration substrate (S20): a hand-rolled TOML-subset parser (the
//! crate cache has no serde/toml), typed serving/training configs, and
//! the artifact-manifest parser shared with `runtime::`.
//!
//! Supported TOML subset: `[section]` headers, `key = value` with
//! string ("…"), integer, float, and boolean values, `#` comments.

use crate::coordinator::admission::TierKind;
use crate::kernels::Isa;
use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

/// A parsed flat config: section -> key -> raw value.
#[derive(Debug, Default, Clone)]
pub struct Config {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

/// A TOML-subset scalar.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "{s}"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
        }
    }
}

/// Config parsing / validation errors.
///
/// Display/Error/From are hand-written — the crate cache has no
/// thiserror, and the crate builds with zero external dependencies.
#[derive(Debug)]
pub enum ConfigError {
    Parse(usize, String),
    Missing(String, String),
    Type(String, String, &'static str),
    Invalid(String, String, String),
    Io(std::io::Error),
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::Parse(line, msg) => write!(f, "line {line}: {msg}"),
            ConfigError::Missing(sec, key) => write!(f, "missing key [{sec}] {key}"),
            ConfigError::Type(sec, key, want) => {
                write!(f, "type mismatch for [{sec}] {key}: expected {want}")
            }
            ConfigError::Invalid(sec, key, why) => {
                write!(f, "invalid value for [{sec}] {key}: {why}")
            }
            ConfigError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for ConfigError {}

impl From<std::io::Error> for ConfigError {
    fn from(e: std::io::Error) -> Self {
        ConfigError::Io(e)
    }
}

impl Config {
    /// Parse the TOML subset from a string.
    pub fn parse(text: &str) -> Result<Config, ConfigError> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') || line.len() < 3 {
                    return Err(ConfigError::Parse(lineno + 1,
                        format!("malformed section header {line:?}")));
                }
                section = line[1..line.len() - 1].trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let Some(eq) = line.find('=') else {
                return Err(ConfigError::Parse(lineno + 1,
                    format!("expected key = value, got {line:?}")));
            };
            let key = line[..eq].trim().to_string();
            let valstr = line[eq + 1..].trim();
            if key.is_empty() || valstr.is_empty() {
                return Err(ConfigError::Parse(lineno + 1,
                    "empty key or value".into()));
            }
            let value = parse_value(valstr)
                .ok_or_else(|| ConfigError::Parse(lineno + 1,
                    format!("cannot parse value {valstr:?}")))?;
            cfg.sections.entry(section.clone()).or_default()
                .insert(key, value);
        }
        Ok(cfg)
    }

    pub fn from_file(path: impl AsRef<Path>) -> Result<Config, ConfigError> {
        Ok(Self::parse(&std::fs::read_to_string(path)?)?)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn get_str(&self, section: &str, key: &str) -> Result<&str, ConfigError> {
        match self.get(section, key) {
            Some(Value::Str(s)) => Ok(s),
            Some(_) => Err(ConfigError::Type(section.into(), key.into(), "string")),
            None => Err(ConfigError::Missing(section.into(), key.into())),
        }
    }

    pub fn get_i64(&self, section: &str, key: &str) -> Result<i64, ConfigError> {
        match self.get(section, key) {
            Some(Value::Int(i)) => Ok(*i),
            Some(_) => Err(ConfigError::Type(section.into(), key.into(), "integer")),
            None => Err(ConfigError::Missing(section.into(), key.into())),
        }
    }

    pub fn get_f64(&self, section: &str, key: &str) -> Result<f64, ConfigError> {
        match self.get(section, key) {
            Some(Value::Float(x)) => Ok(*x),
            Some(Value::Int(i)) => Ok(*i as f64),
            Some(_) => Err(ConfigError::Type(section.into(), key.into(), "float")),
            None => Err(ConfigError::Missing(section.into(), key.into())),
        }
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Result<bool, ConfigError> {
        match self.get(section, key) {
            Some(Value::Bool(b)) => Ok(*b),
            Some(_) => Err(ConfigError::Type(section.into(), key.into(), "bool")),
            None => Err(ConfigError::Missing(section.into(), key.into())),
        }
    }

    /// Typed getter with default.
    pub fn i64_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get_i64(section, key).unwrap_or(default)
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get_f64(section, key).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get_str(section, key).unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get_bool(section, key).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // no escape handling needed: strings in our subset cannot contain '#'
    match line.find('#') {
        Some(i) if !line[..i].contains('"') || line[..i].matches('"').count() % 2 == 0 => &line[..i],
        _ => line,
    }
}

fn parse_value(s: &str) -> Option<Value> {
    if s.starts_with('"') && s.ends_with('"') && s.len() >= 2 {
        return Some(Value::Str(s[1..s.len() - 1].to_string()));
    }
    if s == "true" {
        return Some(Value::Bool(true));
    }
    if s == "false" {
        return Some(Value::Bool(false));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Some(Value::Int(i));
    }
    if let Ok(x) = s.parse::<f64>() {
        return Some(Value::Float(x));
    }
    None
}

// ---------------------------------------------------------------------------
// Typed serving configuration
// ---------------------------------------------------------------------------

/// Attention variant selector shared across the stack. All six Table-1
/// operators are servable on the CPU backend (they plug into the
/// encoder stack through the `AttentionOp` seam); XLA artifacts exist
/// only for `full` / `nystrom` / `ss`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    Full,
    Nystrom,
    SpectralShift,
    Linformer,
    Lsh,
    Sparse,
}

impl Variant {
    pub fn parse(s: &str) -> Option<Variant> {
        match s {
            "full" => Some(Variant::Full),
            "nystrom" => Some(Variant::Nystrom),
            "ss" | "spectral_shift" => Some(Variant::SpectralShift),
            "linformer" => Some(Variant::Linformer),
            "lsh" => Some(Variant::Lsh),
            "sparse" => Some(Variant::Sparse),
            _ => None,
        }
    }

    /// The artifact-name token for this variant.
    pub fn token(&self) -> &'static str {
        match self {
            Variant::Full => "full",
            Variant::Nystrom => "nystrom",
            Variant::SpectralShift => "ss",
            Variant::Linformer => "linformer",
            Variant::Lsh => "lsh",
            Variant::Sparse => "sparse",
        }
    }

    /// Parse a comma-separated variant list (`"ss"`, `"ss,ss,full"`).
    /// One entry = a uniform stack; N entries = one operator per
    /// encoder block, seed block first (must then match `layers`).
    pub fn parse_list(s: &str) -> Option<Vec<Variant>> {
        let list: Option<Vec<Variant>> =
            s.split(',').map(|tok| Variant::parse(tok.trim())).collect();
        list.filter(|l| !l.is_empty())
    }
}

/// Where the CPU model's encoder weights come from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InitPolicy {
    /// Deterministic draw from the model seed (the default).
    Seeded,
    /// Load the checkpoint named by `weights`; any load problem fails
    /// serving closed instead of silently drawing seeded weights.
    Load,
}

impl InitPolicy {
    pub fn parse(s: &str) -> Option<InitPolicy> {
        match s {
            "seeded" => Some(InitPolicy::Seeded),
            "load" => Some(InitPolicy::Load),
            _ => None,
        }
    }

    pub fn token(&self) -> &'static str {
        match self {
            InitPolicy::Seeded => "seeded",
            InitPolicy::Load => "load",
        }
    }
}

/// Which half of the cluster tier a serving process is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    /// A single-process server executing requests locally (the default
    /// — and the only role that existed before the cluster tier).
    Replica,
    /// A front-end that consistent-hashes ENCODE requests across the
    /// configured `replicas` and executes nothing locally.
    Router,
}

impl Role {
    pub fn parse(s: &str) -> Option<Role> {
        match s {
            "replica" => Some(Role::Replica),
            "router" => Some(Role::Router),
            _ => None,
        }
    }

    pub fn token(&self) -> &'static str {
        match self {
            Role::Replica => "replica",
            Role::Router => "router",
        }
    }
}

/// Serving configuration (coordinator + server).
#[derive(Clone, Debug)]
pub struct ServingConfig {
    /// Directory holding *.hlo.txt artifacts + manifest.
    pub artifacts_dir: String,
    /// Attention variant to serve.
    pub variant: Variant,
    /// Max requests per batch (must match the artifact batch dim).
    pub max_batch: usize,
    /// Max time a request may wait for batchmates.
    pub max_wait_ms: u64,
    /// Bounded queue capacity (backpressure beyond this), split evenly
    /// across the queue shards.
    pub queue_capacity: usize,
    /// TCP bind address for the server example.
    pub bind_addr: String,
    /// Sequence buckets to route into (ascending). Must match artifacts.
    pub seq_buckets: Vec<usize>,
    /// Batch-executing worker threads per coordinator (≥ 1).
    pub workers: usize,
    /// Queue shards (0 = one per worker). Buckets map onto shards
    /// statically; idle workers steal ready batches across shards.
    pub queue_shards: usize,
    /// Embedding-cache entries (0 disables the cache).
    pub cache_capacity: usize,
    /// Deadline applied to requests that don't carry their own
    /// (milliseconds; 0 = no default deadline).
    pub default_deadline_ms: u64,
    /// How far before a queued request's deadline the batcher closes
    /// its bucket early, leaving this margin for execution.
    pub deadline_margin_ms: u64,
    /// Encoder depth on the CPU backend (≥ 1). `1` serves the seed
    /// single-pass model (bitwise-compatible with pre-stack releases);
    /// deeper stacks add full pre-LN blocks. Per-request cost scales
    /// roughly linearly with depth — see OPERATIONS.md capacity math.
    pub layers: usize,
    /// FFN expansion factor of each full encoder block (inner width =
    /// `ffn_mult · d_model`). Ignored at `layers = 1`.
    pub ffn_mult: usize,
    /// Per-layer attention operators (config `variant = "ss,ss,full"`,
    /// seed block first) — empty means every block runs `variant`.
    /// CPU backend only; must match `layers` when non-empty.
    pub layer_variants: Vec<Variant>,
    /// QKV/output projections (`W_Q`/`W_K`/`W_V`/`W_O`) in every full
    /// encoder block. Off (the default) serves the pre-projection
    /// function bitwise; the seed block never projects either way, so
    /// `layers = 1` ignores this knob entirely.
    pub projections: bool,
    /// Weight-checkpoint path for `init = load` (see
    /// `model::checkpoint` for the format).
    pub weights: Option<String>,
    /// Whether encoder weights are a seeded draw or loaded from
    /// `weights`. Defaults to `load` when a path is given, `seeded`
    /// otherwise; contradictory combinations are config errors.
    pub init: InitPolicy,
    /// Micro-kernel arm to pin (`scalar` | `avx2` | `neon`); `None`
    /// (config token `auto`, the default) detects the best supported
    /// arm at startup. The `SSAF_KERNEL` environment variable overrides
    /// this knob either way.
    pub kernel: Option<Isa>,
    /// Admission tier to force for *every* request (`full-f32` |
    /// `ss-f32` | `ss-bf16` | `ss-int8`); `None` (config token `auto`,
    /// the default) routes per request by accuracy budget. The
    /// `SSAF_ADMISSION` environment variable overrides this knob either
    /// way. CPU backend only — the artifact backend has no tier
    /// lattice and serves the configured path regardless.
    pub admission: Option<TierKind>,
    /// `replica` (default) serves requests locally; `router` forwards
    /// them across `replicas` (see `coordinator::cluster`).
    pub role: Role,
    /// Replica addresses (`host:port`) for `role = router` — config
    /// token is one comma-separated string. Must be empty in replica
    /// role and nonempty in router role.
    pub replicas: Vec<String>,
    /// Router health-probe sweep period (milliseconds, > 0). Ignored in
    /// replica role.
    pub probe_interval_ms: u64,
    /// Chunk length (tokens) for the streaming long-document ENCODE
    /// path: a sequence longer than the largest bucket is split into
    /// independent chunks of this many tokens, each encoded separately,
    /// and the pooled chunk embeddings are merged with a
    /// length-weighted mean. `0` disables chunking (long documents are
    /// rejected `too-long`, the pre-chunking behaviour). Both backends
    /// serve the chunked path; must not exceed the largest bucket, and
    /// the CPU start path additionally snaps it to a landmark-divisor
    /// multiple via `batcher::aligned_len` so chunks carry no
    /// alignment padding.
    pub chunk_tokens: usize,
    /// Prefix-reuse cache entries (pooled chunk embeddings keyed on
    /// chunk content hash; 0 disables). Consulted only on the chunked
    /// long-document path — whole-sequence hits stay with
    /// `cache_capacity`.
    pub prefix_cache_capacity: usize,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            artifacts_dir: "artifacts".into(),
            variant: Variant::SpectralShift,
            max_batch: 4,
            max_wait_ms: 20,
            queue_capacity: 256,
            bind_addr: "127.0.0.1:7878".into(),
            seq_buckets: vec![128, 256, 512, 1024],
            workers: 2,
            queue_shards: 0,
            cache_capacity: 1024,
            default_deadline_ms: 0,
            deadline_margin_ms: 5,
            layers: 1,
            ffn_mult: 4,
            layer_variants: Vec::new(),
            projections: false,
            weights: None,
            init: InitPolicy::Seeded,
            kernel: None,
            admission: None,
            role: Role::Replica,
            replicas: Vec::new(),
            probe_interval_ms: 500,
            chunk_tokens: 256,
            prefix_cache_capacity: 1024,
        }
    }
}

impl ServingConfig {
    /// Build from a parsed [serving] section, falling back to defaults.
    /// Negative values for any count/duration key are a `ConfigError`,
    /// not a silent two's-complement wrap into `usize::MAX`.
    pub fn from_config(cfg: &Config) -> Result<ServingConfig, ConfigError> {
        let d = ServingConfig::default();
        let variant_s = cfg.str_or("serving", "variant", "ss").to_string();
        let variants = Variant::parse_list(&variant_s).ok_or_else(|| {
            ConfigError::Invalid("serving".into(), "variant".into(), variant_s)
        })?;
        let (variant, layer_variants) = ServingConfig::split_variants(variants);
        let weights = match cfg.get("serving", "weights") {
            Some(Value::Str(s)) => Some(s.clone()),
            Some(_) => {
                return Err(ConfigError::Type("serving".into(), "weights".into(),
                                             "string"))
            }
            None => None,
        };
        let init = match cfg.get("serving", "init") {
            Some(Value::Str(s)) => InitPolicy::parse(s).ok_or_else(|| {
                ConfigError::Invalid("serving".into(), "init".into(), s.clone())
            })?,
            Some(_) => {
                return Err(ConfigError::Type("serving".into(), "init".into(),
                                             "string"))
            }
            None if weights.is_some() => InitPolicy::Load,
            None => InitPolicy::Seeded,
        };
        let projections = match cfg.get_bool("serving", "projections") {
            Ok(b) => b,
            Err(ConfigError::Missing(..)) => d.projections,
            Err(e) => return Err(e),
        };
        let kernel = match cfg.get("serving", "kernel") {
            Some(Value::Str(s)) if s.trim().eq_ignore_ascii_case("auto") => None,
            Some(Value::Str(s)) => Some(Isa::parse(s).ok_or_else(|| {
                ConfigError::Invalid("serving".into(), "kernel".into(), s.clone())
            })?),
            Some(_) => {
                return Err(ConfigError::Type("serving".into(), "kernel".into(),
                                             "string"))
            }
            None => None,
        };
        let admission = match cfg.get("serving", "admission") {
            Some(Value::Str(s)) if s.trim().eq_ignore_ascii_case("auto") => None,
            Some(Value::Str(s)) => Some(TierKind::parse(s).ok_or_else(|| {
                ConfigError::Invalid("serving".into(), "admission".into(),
                                     s.clone())
            })?),
            Some(_) => {
                return Err(ConfigError::Type("serving".into(),
                                             "admission".into(), "string"))
            }
            None => None,
        };
        let role = match cfg.get("serving", "role") {
            Some(Value::Str(s)) => Role::parse(s).ok_or_else(|| {
                ConfigError::Invalid("serving".into(), "role".into(), s.clone())
            })?,
            Some(_) => {
                return Err(ConfigError::Type("serving".into(), "role".into(),
                                             "string"))
            }
            None => d.role,
        };
        let replicas = match cfg.get("serving", "replicas") {
            Some(Value::Str(s)) => s
                .split(',')
                .map(|a| a.trim().to_string())
                .filter(|a| !a.is_empty())
                .collect(),
            Some(_) => {
                return Err(ConfigError::Type("serving".into(), "replicas".into(),
                                             "string"))
            }
            None => Vec::new(),
        };
        let unsigned = |key: &str, default: i64| -> Result<u64, ConfigError> {
            let v = cfg.i64_or("serving", key, default);
            u64::try_from(v).map_err(|_| ConfigError::Invalid(
                "serving".into(), key.into(), format!("{v} is negative")))
        };
        let out = ServingConfig {
            artifacts_dir: cfg.str_or("serving", "artifacts_dir",
                                      &d.artifacts_dir).to_string(),
            variant,
            max_batch: unsigned("max_batch", d.max_batch as i64)? as usize,
            max_wait_ms: unsigned("max_wait_ms", d.max_wait_ms as i64)?,
            queue_capacity: unsigned("queue_capacity",
                                     d.queue_capacity as i64)? as usize,
            bind_addr: cfg.str_or("serving", "bind_addr", &d.bind_addr).to_string(),
            seq_buckets: d.seq_buckets,
            workers: unsigned("workers", d.workers as i64)? as usize,
            queue_shards: unsigned("queue_shards", d.queue_shards as i64)? as usize,
            cache_capacity: unsigned("cache_capacity",
                                     d.cache_capacity as i64)? as usize,
            default_deadline_ms: unsigned("default_deadline_ms",
                                          d.default_deadline_ms as i64)?,
            deadline_margin_ms: unsigned("deadline_margin_ms",
                                         d.deadline_margin_ms as i64)?,
            layers: unsigned("layers", d.layers as i64)? as usize,
            ffn_mult: unsigned("ffn_mult", d.ffn_mult as i64)? as usize,
            layer_variants,
            projections,
            weights,
            init,
            kernel,
            admission,
            role,
            replicas,
            probe_interval_ms: unsigned("probe_interval_ms",
                                        d.probe_interval_ms as i64)?,
            chunk_tokens: unsigned("chunk_tokens",
                                   d.chunk_tokens as i64)? as usize,
            prefix_cache_capacity: unsigned("prefix_cache_capacity",
                                            d.prefix_cache_capacity as i64)?
                as usize,
        };
        out.validate()?;
        Ok(out)
    }

    /// Normalize a parsed `variant` list (nonempty) into the
    /// `(variant, layer_variants)` field pair: a single entry means a
    /// uniform stack (empty per-layer list), longer lists keep every
    /// entry with the first one leading. The ONE place the convention
    /// lives — config parsing, the CLI, and the example all call it.
    pub fn split_variants(list: Vec<Variant>) -> (Variant, Vec<Variant>) {
        let lead = list[0];
        (lead, if list.len() > 1 { list } else { Vec::new() })
    }

    /// One attention operator per encoder block, seed block first:
    /// the configured per-layer list, or `variant` replicated.
    pub fn effective_layer_variants(&self) -> Vec<Variant> {
        if self.layer_variants.is_empty() {
            vec![self.variant; self.layers]
        } else {
            self.layer_variants.clone()
        }
    }

    /// The shard count the coordinator will actually build:
    /// `queue_shards`, or one shard per worker when left at 0 (auto).
    pub fn effective_shards(&self) -> usize {
        match self.queue_shards {
            0 => self.workers.max(1),
            n => n,
        }
    }

    /// The configured default deadline as a duration (None when 0).
    pub fn default_deadline(&self) -> Option<std::time::Duration> {
        match self.default_deadline_ms {
            0 => None,
            ms => Some(std::time::Duration::from_millis(ms)),
        }
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.max_batch == 0 {
            return Err(ConfigError::Invalid("serving".into(), "max_batch".into(),
                                            "must be > 0".into()));
        }
        if self.workers == 0 {
            return Err(ConfigError::Invalid("serving".into(), "workers".into(),
                                            "must be > 0".into()));
        }
        if self.queue_capacity < self.max_batch * self.effective_shards() {
            return Err(ConfigError::Invalid(
                "serving".into(), "queue_capacity".into(),
                format!("{} < max_batch {} × {} shards (each shard must \
                         hold a full batch)",
                        self.queue_capacity, self.max_batch,
                        self.effective_shards())));
        }
        if self.seq_buckets.is_empty()
            || self.seq_buckets.windows(2).any(|w| w[0] >= w[1]) {
            return Err(ConfigError::Invalid("serving".into(), "seq_buckets".into(),
                                            "must be ascending, nonempty".into()));
        }
        let n_max = *self.seq_buckets.iter().max().unwrap();
        if self.chunk_tokens > n_max {
            return Err(ConfigError::Invalid(
                "serving".into(), "chunk_tokens".into(),
                format!("{} exceeds the largest bucket {} — each chunk \
                         must fit an existing bucket", self.chunk_tokens,
                        n_max)));
        }
        if self.layers == 0 {
            return Err(ConfigError::Invalid("serving".into(), "layers".into(),
                                            "must be >= 1".into()));
        }
        if self.ffn_mult == 0 {
            return Err(ConfigError::Invalid("serving".into(), "ffn_mult".into(),
                                            "must be >= 1".into()));
        }
        if !self.layer_variants.is_empty()
            && self.layer_variants.len() != self.layers {
            return Err(ConfigError::Invalid(
                "serving".into(), "variant".into(),
                format!("{} per-layer variants for layers = {}",
                        self.layer_variants.len(), self.layers)));
        }
        match (&self.weights, self.init) {
            (None, InitPolicy::Load) => {
                return Err(ConfigError::Invalid(
                    "serving".into(), "init".into(),
                    "init = load requires a weights path".into()));
            }
            (Some(_), InitPolicy::Seeded) => {
                return Err(ConfigError::Invalid(
                    "serving".into(), "weights".into(),
                    "weights path set but init = seeded — drop the path \
                     or set init = load".into()));
            }
            _ => {}
        }
        match self.role {
            Role::Router => {
                if self.replicas.is_empty() {
                    return Err(ConfigError::Invalid(
                        "serving".into(), "replicas".into(),
                        "role = router requires at least one replica \
                         address".into()));
                }
                if self.probe_interval_ms == 0 {
                    return Err(ConfigError::Invalid(
                        "serving".into(), "probe_interval_ms".into(),
                        "must be > 0".into()));
                }
            }
            Role::Replica => {
                if !self.replicas.is_empty() {
                    return Err(ConfigError::Invalid(
                        "serving".into(), "replicas".into(),
                        "replica addresses set but role = replica — set \
                         role = router or drop the list".into()));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# ssaformer serving config
[serving]
variant = "nystrom"
max_batch = 8
max_wait_ms = 5
queue_capacity = 64
bind_addr = "127.0.0.1:9000"

[train]
steps = 200
lr = 0.001
log_every = 10
resume = false
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get_str("serving", "variant").unwrap(), "nystrom");
        assert_eq!(c.get_i64("serving", "max_batch").unwrap(), 8);
        assert_eq!(c.get_f64("train", "lr").unwrap(), 0.001);
        assert!(!c.get_bool("train", "resume").unwrap());
        // int readable as float
        assert_eq!(c.get_f64("train", "steps").unwrap(), 200.0);
    }

    #[test]
    fn missing_and_type_errors() {
        let c = Config::parse(SAMPLE).unwrap();
        assert!(matches!(c.get_str("serving", "nope"),
                         Err(ConfigError::Missing(..))));
        assert!(matches!(c.get_bool("serving", "max_batch"),
                         Err(ConfigError::Type(..))));
    }

    #[test]
    fn defaults_via_or() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.i64_or("x", "y", 7), 7);
        assert_eq!(c.str_or("x", "y", "z"), "z");
    }

    #[test]
    fn parse_errors_carry_line_numbers() {
        let err = Config::parse("[serving]\nbad line").unwrap_err();
        match err {
            ConfigError::Parse(line, _) => assert_eq!(line, 2),
            other => panic!("{other:?}"),
        }
        assert!(Config::parse("[unclosed\n").is_err());
    }

    #[test]
    fn comments_stripped() {
        let c = Config::parse("[s]\nk = 5 # trailing\n# full line\n").unwrap();
        assert_eq!(c.get_i64("s", "k").unwrap(), 5);
    }

    #[test]
    fn serving_config_from_file_text() {
        let c = Config::parse(SAMPLE).unwrap();
        let s = ServingConfig::from_config(&c).unwrap();
        assert_eq!(s.variant, Variant::Nystrom);
        assert_eq!(s.max_batch, 8);
        assert_eq!(s.bind_addr, "127.0.0.1:9000");
    }

    #[test]
    fn serving_config_validation() {
        let mut s = ServingConfig::default();
        s.max_batch = 0;
        assert!(s.validate().is_err());
        let mut s = ServingConfig::default();
        s.queue_capacity = 1;
        assert!(s.validate().is_err());
        let mut s = ServingConfig::default();
        s.seq_buckets = vec![256, 128];
        assert!(s.validate().is_err());
    }

    #[test]
    fn serving_pool_and_deadline_knobs() {
        let c = Config::parse(
            "[serving]\nworkers = 4\nqueue_shards = 2\ncache_capacity = 128\n\
             default_deadline_ms = 250\ndeadline_margin_ms = 10\n\
             queue_capacity = 64\n").unwrap();
        let s = ServingConfig::from_config(&c).unwrap();
        assert_eq!(s.workers, 4);
        assert_eq!(s.queue_shards, 2);
        assert_eq!(s.effective_shards(), 2);
        assert_eq!(s.cache_capacity, 128);
        assert_eq!(s.default_deadline(),
                   Some(std::time::Duration::from_millis(250)));
        assert_eq!(s.deadline_margin_ms, 10);
    }

    #[test]
    fn shards_default_to_one_per_worker() {
        let mut s = ServingConfig::default();
        s.workers = 3;
        s.queue_shards = 0;
        assert_eq!(s.effective_shards(), 3);
        assert_eq!(s.default_deadline(), None); // 0 = disabled
        // zero workers is rejected
        s.workers = 0;
        assert!(s.validate().is_err());
    }

    #[test]
    fn negative_serving_values_are_config_errors_not_wraps() {
        for key in ["workers", "cache_capacity", "max_batch",
                    "default_deadline_ms", "chunk_tokens",
                    "prefix_cache_capacity"] {
            let c = Config::parse(&format!("[serving]\n{key} = -1\n")).unwrap();
            assert!(matches!(ServingConfig::from_config(&c),
                             Err(ConfigError::Invalid(..))),
                    "{key} = -1 must be rejected");
        }
    }

    #[test]
    fn queue_capacity_must_cover_every_shard() {
        let mut s = ServingConfig::default();
        s.workers = 4;
        s.queue_shards = 4;
        s.max_batch = 4;
        s.queue_capacity = 15; // < 4 shards × 4 slots
        assert!(s.validate().is_err());
        s.queue_capacity = 16;
        assert!(s.validate().is_ok());
    }

    #[test]
    fn chunking_knobs_parse_and_validate() {
        // defaults: chunking on at 256 tokens, 1024 prefix entries
        let s = ServingConfig::default();
        assert_eq!(s.chunk_tokens, 256);
        assert_eq!(s.prefix_cache_capacity, 1024);
        assert!(s.validate().is_ok());

        let c = Config::parse(
            "[serving]\nchunk_tokens = 128\nprefix_cache_capacity = 32\n")
            .unwrap();
        let s = ServingConfig::from_config(&c).unwrap();
        assert_eq!(s.chunk_tokens, 128);
        assert_eq!(s.prefix_cache_capacity, 32);

        // 0 disables chunking — long documents are rejected as before
        let c = Config::parse("[serving]\nchunk_tokens = 0\n").unwrap();
        assert_eq!(ServingConfig::from_config(&c).unwrap().chunk_tokens, 0);

        // a chunk larger than the largest bucket can never be planned
        let mut s = ServingConfig::default();
        s.chunk_tokens = *s.seq_buckets.iter().max().unwrap() + 1;
        assert!(s.validate().is_err());
        s.chunk_tokens = *s.seq_buckets.iter().max().unwrap();
        assert!(s.validate().is_ok());
    }

    #[test]
    fn kernel_knob_parses_and_rejects_garbage() {
        // default: auto-detect (no pinned arm)
        assert_eq!(ServingConfig::default().kernel, None);
        let c = Config::parse("[serving]\nkernel = \"scalar\"\n").unwrap();
        assert_eq!(ServingConfig::from_config(&c).unwrap().kernel,
                   Some(Isa::Scalar));
        // "auto" is the explicit spelling of the default
        let c = Config::parse("[serving]\nkernel = \"auto\"\n").unwrap();
        assert_eq!(ServingConfig::from_config(&c).unwrap().kernel, None);
        // unknown arms and wrong types are errors, not silent fallbacks
        let c = Config::parse("[serving]\nkernel = \"sse9\"\n").unwrap();
        assert!(matches!(ServingConfig::from_config(&c),
                         Err(ConfigError::Invalid(..))));
        let c = Config::parse("[serving]\nkernel = 2\n").unwrap();
        assert!(matches!(ServingConfig::from_config(&c),
                         Err(ConfigError::Type(..))));
    }

    #[test]
    fn admission_knob_parses_and_rejects_garbage() {
        // default: auto (per-request routing, no forced tier)
        assert_eq!(ServingConfig::default().admission, None);
        let c = Config::parse("[serving]\nadmission = \"ss-int8\"\n").unwrap();
        assert_eq!(ServingConfig::from_config(&c).unwrap().admission,
                   Some(TierKind::SsInt8));
        // "full" is accepted shorthand for the reference tier
        let c = Config::parse("[serving]\nadmission = \"full\"\n").unwrap();
        assert_eq!(ServingConfig::from_config(&c).unwrap().admission,
                   Some(TierKind::FullF32));
        // "auto" is the explicit spelling of the default
        let c = Config::parse("[serving]\nadmission = \"auto\"\n").unwrap();
        assert_eq!(ServingConfig::from_config(&c).unwrap().admission, None);
        // unknown tiers and wrong types are errors, not silent fallbacks
        let c = Config::parse("[serving]\nadmission = \"fp4\"\n").unwrap();
        assert!(matches!(ServingConfig::from_config(&c),
                         Err(ConfigError::Invalid(..))));
        let c = Config::parse("[serving]\nadmission = 8\n").unwrap();
        assert!(matches!(ServingConfig::from_config(&c),
                         Err(ConfigError::Type(..))));
    }

    #[test]
    fn variant_roundtrip() {
        for v in [Variant::Full, Variant::Nystrom, Variant::SpectralShift,
                  Variant::Linformer, Variant::Lsh, Variant::Sparse] {
            assert_eq!(Variant::parse(v.token()), Some(v));
        }
        assert_eq!(Variant::parse("spectral_shift"), Some(Variant::SpectralShift));
        assert_eq!(Variant::parse("bogus"), None);
    }

    #[test]
    fn encoder_knobs_parse_and_validate() {
        let c = Config::parse("[serving]\nlayers = 4\nffn_mult = 2\n").unwrap();
        let s = ServingConfig::from_config(&c).unwrap();
        assert_eq!((s.layers, s.ffn_mult), (4, 2));
        // defaults: the compatibility single-pass model
        let s = ServingConfig::default();
        assert_eq!((s.layers, s.ffn_mult), (1, 4));
        // zero depth / zero expansion are config errors
        let mut s = ServingConfig::default();
        s.layers = 0;
        assert!(s.validate().is_err());
        let mut s = ServingConfig::default();
        s.ffn_mult = 0;
        assert!(s.validate().is_err());
        for key in ["layers", "ffn_mult"] {
            let c = Config::parse(&format!("[serving]\n{key} = -1\n")).unwrap();
            assert!(matches!(ServingConfig::from_config(&c),
                             Err(ConfigError::Invalid(..))),
                    "{key} = -1 must be rejected");
        }
    }

    #[test]
    fn per_layer_variant_lists_parse_and_validate() {
        assert_eq!(Variant::parse_list("ss"), Some(vec![Variant::SpectralShift]));
        assert_eq!(Variant::parse_list("ss, ss ,full"),
                   Some(vec![Variant::SpectralShift, Variant::SpectralShift,
                             Variant::Full]));
        assert_eq!(Variant::parse_list("ss,bogus"), None);
        assert_eq!(Variant::parse_list(""), None);

        let c = Config::parse(
            "[serving]\nvariant = \"ss,ss,full\"\nlayers = 3\n").unwrap();
        let s = ServingConfig::from_config(&c).unwrap();
        assert_eq!(s.variant, Variant::SpectralShift, "first entry leads");
        assert_eq!(s.layer_variants,
                   vec![Variant::SpectralShift, Variant::SpectralShift,
                        Variant::Full]);
        assert_eq!(s.effective_layer_variants().len(), 3);
        // list length must match depth
        let c = Config::parse(
            "[serving]\nvariant = \"ss,full\"\nlayers = 3\n").unwrap();
        assert!(matches!(ServingConfig::from_config(&c),
                         Err(ConfigError::Invalid(..))));
        // a single variant replicates to the configured depth
        let s = ServingConfig { layers: 4, ..Default::default() };
        assert_eq!(s.effective_layer_variants(),
                   vec![Variant::SpectralShift; 4]);
    }

    #[test]
    fn cluster_role_knobs_parse_and_validate() {
        // defaults: replica role, no replicas, 500ms probes
        let s = ServingConfig::default();
        assert_eq!(s.role, Role::Replica);
        assert!(s.replicas.is_empty());
        assert_eq!(s.probe_interval_ms, 500);
        assert!(s.validate().is_ok());

        // router role parses its replica list (whitespace-tolerant)
        let c = Config::parse(
            "[serving]\nrole = \"router\"\n\
             replicas = \"127.0.0.1:4100, 127.0.0.1:4101\"\n\
             probe_interval_ms = 100\n").unwrap();
        let s = ServingConfig::from_config(&c).unwrap();
        assert_eq!(s.role, Role::Router);
        assert_eq!(s.replicas,
                   vec!["127.0.0.1:4100".to_string(),
                        "127.0.0.1:4101".to_string()]);
        assert_eq!(s.probe_interval_ms, 100);

        // router without replicas is a config error
        let c = Config::parse("[serving]\nrole = \"router\"\n").unwrap();
        assert!(matches!(ServingConfig::from_config(&c),
                         Err(ConfigError::Invalid(..))));
        // replicas without router role is a config error too
        let c = Config::parse(
            "[serving]\nreplicas = \"127.0.0.1:4100\"\n").unwrap();
        assert!(matches!(ServingConfig::from_config(&c),
                         Err(ConfigError::Invalid(..))));
        // zero probe interval in router role is rejected
        let c = Config::parse(
            "[serving]\nrole = \"router\"\nreplicas = \"a:1\"\n\
             probe_interval_ms = 0\n").unwrap();
        assert!(matches!(ServingConfig::from_config(&c),
                         Err(ConfigError::Invalid(..))));
        // unknown roles and wrong types fail, not silently default
        let c = Config::parse("[serving]\nrole = \"proxy\"\n").unwrap();
        assert!(matches!(ServingConfig::from_config(&c),
                         Err(ConfigError::Invalid(..))));
        let c = Config::parse("[serving]\nrole = 2\n").unwrap();
        assert!(matches!(ServingConfig::from_config(&c),
                         Err(ConfigError::Type(..))));
        // role tokens round-trip
        for r in [Role::Replica, Role::Router] {
            assert_eq!(Role::parse(r.token()), Some(r));
        }
    }

    #[test]
    fn projection_and_weight_knobs() {
        let s = ServingConfig::default();
        assert!(!s.projections);
        assert_eq!(s.init, InitPolicy::Seeded);
        assert!(s.weights.is_none());

        let c = Config::parse(
            "[serving]\nprojections = true\nlayers = 2\n").unwrap();
        let s = ServingConfig::from_config(&c).unwrap();
        assert!(s.projections);
        // a wrong type is an error, not a silent default
        let c = Config::parse("[serving]\nprojections = 1\n").unwrap();
        assert!(matches!(ServingConfig::from_config(&c),
                         Err(ConfigError::Type(..))));

        // weights path implies init = load
        let c = Config::parse(
            "[serving]\nweights = \"w.ckpt\"\n").unwrap();
        let s = ServingConfig::from_config(&c).unwrap();
        assert_eq!(s.init, InitPolicy::Load);
        assert_eq!(s.weights.as_deref(), Some("w.ckpt"));
        // explicit contradictions fail
        let c = Config::parse(
            "[serving]\nweights = \"w.ckpt\"\ninit = \"seeded\"\n").unwrap();
        assert!(matches!(ServingConfig::from_config(&c),
                         Err(ConfigError::Invalid(..))));
        let c = Config::parse("[serving]\ninit = \"load\"\n").unwrap();
        assert!(matches!(ServingConfig::from_config(&c),
                         Err(ConfigError::Invalid(..))));
        let c = Config::parse("[serving]\ninit = \"bogus\"\n").unwrap();
        assert!(matches!(ServingConfig::from_config(&c),
                         Err(ConfigError::Invalid(..))));
        // policy tokens round-trip
        for p in [InitPolicy::Seeded, InitPolicy::Load] {
            assert_eq!(InitPolicy::parse(p.token()), Some(p));
        }
    }
}
