//! Training drivers.
//!
//! Two paths live here:
//!
//! * [`cpu`] — the in-repo deterministic CPU trainer: forward through
//!   the real [`crate::model::EncoderStack`], hand-derived backward
//!   passes from [`backward`], seeded SGD/Adam, `SSAFCKPT` checkpoints
//!   that serve through `init=load`. This is the path `train_tiny`,
//!   the `train` subcommand and the error-bound harness use.
//! * The artifact driver below (S23, kept intact for
//!   `tests/integration_train.rs`): runs an AOT train-step artifact
//!   over the same synthetic corpus.

pub mod backward;
pub mod cpu;

pub use cpu::{train_cpu, CpuTrainConfig, CpuTrainOutcome, CpuTrainReport,
              OptimizerKind};

use crate::config::Variant;
use crate::rngx::Rng;
use crate::runtime::{ArtifactKind, Engine, RuntimeError, TrainState};
use crate::text::{make_mlm_batch, CorpusGenerator, Tokenizer};
use std::time::{Duration, Instant};

/// Training run configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub variant: Variant,
    pub steps: usize,
    pub seed: u64,
    /// corpus size (sentences) for the synthetic bigram corpus
    pub corpus_lines: usize,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            variant: Variant::SpectralShift,
            steps: 100,
            seed: 0,
            corpus_lines: 2000,
            log_every: 10,
        }
    }
}

/// One logged point of the loss curve.
#[derive(Clone, Copy, Debug)]
pub struct LossPoint {
    pub step: usize,
    pub loss: f32,
    pub step_time: Duration,
}

/// Result of a training run.
pub struct TrainReport {
    pub points: Vec<LossPoint>,
    pub total_time: Duration,
    pub final_loss: f32,
    pub initial_loss: f32,
    pub tokens_per_sec: f64,
}

impl TrainReport {
    /// Render the loss curve as an ASCII table (EXPERIMENTS.md format).
    pub fn render(&self) -> String {
        let mut t = crate::benchkit::Table::new(&["step", "loss", "step_time"]);
        for p in &self.points {
            t.row(&[
                p.step.to_string(),
                format!("{:.4}", p.loss),
                crate::benchkit::fmt_duration(p.step_time),
            ]);
        }
        format!(
            "{}\ninitial loss {:.4} -> final loss {:.4} ({} steps, {:.1} tok/s, total {})\n",
            t.render(),
            self.initial_loss,
            self.final_loss,
            self.points.last().map(|p| p.step).unwrap_or(0),
            self.tokens_per_sec,
            crate::benchkit::fmt_duration(self.total_time),
        )
    }
}

/// Run MLM training with the given variant's train-step artifact.
///
/// The corpus, tokenizer, masking and batch order are all deterministic
/// in `cfg.seed`, so full-vs-ss runs see identical data.
pub fn train(engine: &Engine, cfg: &TrainConfig) -> Result<TrainReport, RuntimeError> {
    // the train artifacts are emitted at one (seq, batch) point
    let entry = engine
        .manifest()
        .artifacts
        .iter()
        .find(|a| a.kind == ArtifactKind::TrainStep && a.variant == cfg.variant)
        .cloned()
        .ok_or_else(|| RuntimeError::NotFound(format!(
            "train_step for {:?}", cfg.variant)))?;
    let model = engine.load(ArtifactKind::TrainStep, cfg.variant, entry.seq)?;
    let (batch, seq) = (entry.batch, entry.seq);
    let vocab = engine.manifest().hyper.get("vocab").copied().unwrap_or(2048) as usize;

    // deterministic synthetic corpus + tokenizer
    let mut gen = CorpusGenerator::new(cfg.seed, vocab.saturating_sub(64).max(64), 4);
    let corpus = gen.corpus(cfg.corpus_lines, seq / 2, seq);
    let tok = Tokenizer::fit(&corpus, vocab);
    let encoded: Vec<Vec<i32>> = corpus.iter().map(|l| tok.encode(l, seq)).collect();

    let mut state = TrainState::init(engine)?;
    let mut rng = Rng::new(cfg.seed ^ 0xA5A5);
    let mut points = Vec::new();
    let mut initial_loss = f32::NAN;
    let mut final_loss = f32::NAN;
    let t0 = Instant::now();
    let mut tokens_seen = 0u64;

    for step in 1..=cfg.steps {
        // sample a batch of sentences
        let rows: Vec<Vec<i32>> = (0..batch)
            .map(|_| encoded[rng.below(encoded.len() as u64) as usize].clone())
            .collect();
        let mlm = make_mlm_batch(&mut rng, &rows, vocab);
        let ts = Instant::now();
        let loss = state.step(engine, &model, &mlm.tokens, &mlm.targets,
                              &mlm.loss_mask)?;
        let dt = ts.elapsed();
        tokens_seen += (batch * seq) as u64;
        if step == 1 {
            initial_loss = loss;
        }
        final_loss = loss;
        if step == 1 || step % cfg.log_every == 0 || step == cfg.steps {
            points.push(LossPoint { step, loss, step_time: dt });
        }
    }
    let total_time = t0.elapsed();
    Ok(TrainReport {
        points,
        total_time,
        final_loss,
        initial_loss,
        tokens_per_sec: tokens_seen as f64 / total_time.as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_sane() {
        let c = TrainConfig::default();
        assert!(c.steps > 0 && c.log_every > 0);
    }

    #[test]
    fn report_renders_curve() {
        let r = TrainReport {
            points: vec![
                LossPoint { step: 1, loss: 7.6, step_time: Duration::from_millis(100) },
                LossPoint { step: 10, loss: 6.2, step_time: Duration::from_millis(90) },
            ],
            total_time: Duration::from_secs(1),
            final_loss: 6.2,
            initial_loss: 7.6,
            tokens_per_sec: 1024.0,
        };
        let s = r.render();
        assert!(s.contains("7.6"));
        assert!(s.contains("6.2"));
        assert!(s.contains("tok/s"));
    }

    // Full training over a real artifact is exercised by
    // examples/train_tiny.rs and rust/tests/integration_train.rs.
}
