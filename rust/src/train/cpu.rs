//! Deterministic in-repo CPU trainer.
//!
//! Trains the real serving model — [`EncoderStack`] blocks with Q/K/V/
//! output projections, `full` (exact softmax) attention — on the
//! synthetic MLM task from [`crate::text`], entirely on the CPU kernel
//! core, and hands back a stack that saves through
//! [`crate::model::checkpoint`] and serves through `weights`/`init=load`
//! unchanged.
//!
//! # Shape of the run
//!
//! * **Data** — corpus, tokenizer, batch sampling and masking are all
//!   drawn once from `cfg.seed` ([`CorpusGenerator`] → [`Tokenizer`] →
//!   [`make_mlm_batch`]), producing a *fixed* list of
//!   `steps_per_epoch` batches that every epoch replays in order. The
//!   data stream is a pure function of the config.
//! * **Model** — the embedding table is the frozen seeded table the
//!   serving model uses ([`CpuModel::embed_sequence`]); block 0 is the
//!   weightless seed attention block; only the full blocks' weights
//!   (LN gains/biases, FFN, projections) train. The MLM head is *tied*
//!   to the frozen embedding: `logits = X·Eᵀ`, masked cross-entropy
//!   averaged over the batch's masked positions. A checkpoint plus
//!   `cfg.seed` therefore reproduces the trained function exactly.
//! * **Backward** — hand-derived VJPs from [`super::backward`],
//!   recording residuals on the way forward (post-LN activations,
//!   per-head attention probabilities, FFN pre-activations). Backprop
//!   stops at the seed block: it has no weights and its input is the
//!   frozen embedding.
//! * **Optimizer** — seeded SGD or bias-corrected Adam, applied
//!   tensor-by-tensor in a fixed order, after a global-norm clip.
//!
//! # Determinism contract
//!
//! Two runs with the same config are bitwise identical — including
//! across `workers` counts — because every GEMM-shaped op rides the
//! thread-count-deterministic kernel core, every reduction here (loss
//! sums, bias column sums, grad accumulation, the norm clip) runs
//! sequentially in index order, and batches replay in a fixed order.
//! `tests/train_e2e.rs` pins this on whole checkpoint files and loss
//! curves for `workers ∈ {1, 4}`.

use crate::attention::{default_scale, Tensor2};
use crate::config::Variant;
use crate::coordinator::{CpuModel, CpuModelConfig};
use crate::kernels::{
    flash_attention, gelu, gemm_into, layernorm, transpose_into,
    BatchedVariant, KernelCtx, Workspace,
};
use crate::minirt::ThreadPool;
use crate::model::{EncoderLayer, EncoderStack, LN_EPS};
use crate::rngx::Rng;
use crate::text::{make_mlm_batch, CorpusGenerator, MlmBatch, Tokenizer};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::backward::{
    accumulate, bias_gelu_backward, gemm_backward_acc, layernorm_backward,
    mha_backward, mha_forward, MhaCache, MhaGrads,
};

/// Gradient steps larger than this global L2 norm are rescaled onto the
/// sphere — cheap insurance for the first steps of a freshly seeded
/// stack. Deterministic: one sequential reduction over all gradient
/// tensors in block/field order.
const GRAD_CLIP: f32 = 5.0;

/// Optimizer choice for [`CpuTrainConfig`]. Both are elementwise and
/// order-fixed, so the choice never affects determinism — only the
/// loss trajectory.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptimizerKind {
    Sgd,
    /// Adam, β₁ = 0.9, β₂ = 0.999, ε = 1e-8, bias-corrected.
    Adam,
}

impl OptimizerKind {
    /// Parse a CLI/config token; unknown tokens are `None` so callers
    /// fail closed.
    pub fn parse(s: &str) -> Option<OptimizerKind> {
        match s {
            "sgd" => Some(OptimizerKind::Sgd),
            "adam" => Some(OptimizerKind::Adam),
            _ => None,
        }
    }

    pub fn token(&self) -> &'static str {
        match self {
            OptimizerKind::Sgd => "sgd",
            OptimizerKind::Adam => "adam",
        }
    }
}

/// Configuration of one deterministic CPU training run. Everything the
/// run computes — corpus, masks, weights, loss curve, checkpoint bytes
/// — is a pure function of this struct.
#[derive(Clone, Debug)]
pub struct CpuTrainConfig {
    pub d_model: usize,
    pub n_heads: usize,
    pub ffn_mult: usize,
    /// Stack depth *including* the weightless seed block; must be ≥ 2
    /// so there is at least one trainable block.
    pub layers: usize,
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    pub steps_per_epoch: usize,
    pub epochs: usize,
    pub lr: f32,
    pub optimizer: OptimizerKind,
    pub seed: u64,
    /// Synthetic corpus size (sentences).
    pub corpus_lines: usize,
    /// Kernel lanes for the GEMM-shaped work. Any value produces
    /// bitwise-identical results; 1 runs fully sequential.
    pub workers: usize,
}

impl Default for CpuTrainConfig {
    fn default() -> Self {
        // d_model / n_heads / vocab / seed match the serving defaults
        // (`CpuModelConfig::default`): `ExecBackend::cpu_from_config`
        // only exposes layers / ffn_mult / projections as knobs, so a
        // checkpoint trained at these dims is exactly what
        // `weights`/`init = load` serves.
        CpuTrainConfig {
            d_model: 64,
            n_heads: 4,
            ffn_mult: 2,
            layers: 3,
            vocab: 2048,
            seq: 48,
            batch: 8,
            steps_per_epoch: 25,
            epochs: 3,
            lr: 5e-3,
            optimizer: OptimizerKind::Adam,
            seed: 42,
            corpus_lines: 400,
            workers: 1,
        }
    }
}

impl CpuTrainConfig {
    /// The serving-model config this run trains weights for: same
    /// dims, same seed (→ same frozen embedding), projections on.
    /// `CpuModel::with_checkpoint` with this config accepts the saved
    /// stack directly.
    pub fn model_config(&self) -> CpuModelConfig {
        CpuModelConfig {
            d_model: self.d_model,
            n_heads: self.n_heads,
            vocab: self.vocab,
            seed: self.seed,
            layers: self.layers,
            ffn_mult: self.ffn_mult,
            projections: true,
            ..Default::default()
        }
    }

    fn validate(&self) {
        assert!(self.layers >= 2,
                "training needs layers >= 2 (layer 0 is the weightless \
                 seed block)");
        assert!(self.n_heads >= 1 && self.d_model % self.n_heads == 0,
                "d_model {} must split into {} heads",
                self.d_model, self.n_heads);
        assert!(self.d_model % 2 == 0, "sinusoid embedding needs even d_model");
        assert!(self.vocab > 8, "tokenizer needs vocab > 8");
        assert!(self.seq >= 8 && self.batch >= 1, "degenerate batch shape");
        assert!(self.steps_per_epoch >= 1 && self.epochs >= 1,
                "empty training run");
        assert!(self.lr > 0.0 && self.lr.is_finite(), "bad learning rate");
    }
}

/// Loss curve + throughput of one run. The curves (not the timings)
/// are part of the determinism contract.
#[derive(Clone, Debug)]
pub struct CpuTrainReport {
    /// Mean masked-CE per optimizer step, in step order.
    pub step_losses: Vec<f32>,
    /// Mean of `step_losses` per epoch.
    pub epoch_losses: Vec<f32>,
    pub initial_loss: f32,
    pub final_loss: f32,
    pub total_time: Duration,
    pub tokens_per_sec: f64,
}

impl CpuTrainReport {
    /// Render the per-epoch curve as an ASCII table.
    pub fn render(&self) -> String {
        let mut t = crate::benchkit::Table::new(&["epoch", "mean loss"]);
        for (e, loss) in self.epoch_losses.iter().enumerate() {
            t.row(&[(e + 1).to_string(), format!("{loss:.4}")]);
        }
        format!(
            "{}\nstep loss {:.4} -> {:.4} ({} steps, {:.1} tok/s, total {})\n",
            t.render(),
            self.initial_loss,
            self.final_loss,
            self.step_losses.len(),
            self.tokens_per_sec,
            crate::benchkit::fmt_duration(self.total_time),
        )
    }

    /// True iff the per-epoch mean loss strictly decreases — the
    /// train_tiny acceptance gate.
    pub fn epoch_loss_strictly_decreasing(&self) -> bool {
        self.epoch_losses.windows(2).all(|w| w[1] < w[0])
    }
}

/// A finished run: the trained stack (save it with
/// [`crate::model::checkpoint::save`]), the serving config it belongs
/// to, and the loss curve.
pub struct CpuTrainOutcome {
    pub stack: EncoderStack,
    pub model_config: CpuModelConfig,
    pub report: CpuTrainReport,
}

/// One block's gradient accumulators, field layout mirroring
/// [`EncoderLayer`]. Also reused as the Adam moment buffers (same
/// shapes, same fixed iteration order).
struct BlockGrads {
    ln1_gain: Vec<f32>,
    ln1_bias: Vec<f32>,
    ln2_gain: Vec<f32>,
    ln2_bias: Vec<f32>,
    w1: Vec<f32>,
    b1: Vec<f32>,
    w2: Vec<f32>,
    b2: Vec<f32>,
    mha: MhaGrads,
}

impl BlockGrads {
    fn zeros(d: usize, dff: usize, n_heads: usize) -> BlockGrads {
        BlockGrads {
            ln1_gain: vec![0.0; d],
            ln1_bias: vec![0.0; d],
            ln2_gain: vec![0.0; d],
            ln2_bias: vec![0.0; d],
            w1: vec![0.0; d * dff],
            b1: vec![0.0; dff],
            w2: vec![0.0; dff * d],
            b2: vec![0.0; d],
            mha: MhaGrads::zeros(d, n_heads),
        }
    }

    /// The fixed field order every reduction walks.
    fn tensors(&self) -> [&Vec<f32>; 12] {
        [&self.ln1_gain, &self.ln1_bias, &self.ln2_gain, &self.ln2_bias,
         &self.w1, &self.b1, &self.w2, &self.b2,
         &self.mha.wq, &self.mha.wk, &self.mha.wv, &self.mha.wo]
    }
}

/// Residuals recorded by one block's forward pass.
struct BlockCache {
    x_in: Tensor2,
    ln1: Tensor2,
    mha: MhaCache,
    x_mid: Tensor2,
    ln2: Tensor2,
    /// FFN pre-activation `ln2·W1 + b1`.
    z_pre: Tensor2,
    /// `gelu(z_pre)`.
    a1: Tensor2,
}

/// Clone a workspace-backed tensor into a trainer-owned one and return
/// the arena buffer, keeping take/put balanced across the step.
fn detach(t: Tensor2, ws: &mut Workspace) -> Tensor2 {
    let owned = Tensor2 { rows: t.rows, cols: t.cols, data: t.data.clone() };
    ws.put(t.data);
    owned
}

fn head_slice(x: &Tensor2, h: usize, dh: usize) -> Tensor2 {
    let mut out = Tensor2::zeros(x.rows, dh);
    for i in 0..x.rows {
        out.row_mut(i).copy_from_slice(&x.row(i)[h * dh..(h + 1) * dh]);
    }
    out
}

/// `out = a + b`, elementwise over equal-shape tensors.
fn add(a: &Tensor2, b: &Tensor2) -> Tensor2 {
    let mut out = Tensor2::zeros(a.rows, a.cols);
    for (o, (x, y)) in out.data.iter_mut().zip(a.data.iter().zip(&b.data)) {
        *o = x + y;
    }
    out
}

/// The weightless seed block: per-head exact self-attention on raw
/// column slices, heads concatenated, output *replacing* the input —
/// the same function `EncoderStack::forward_batch` runs at block 0
/// with the `full` operator.
fn seed_block_forward(ctx: &KernelCtx, x: &Tensor2, n_heads: usize,
                      ws: &mut Workspace) -> Tensor2 {
    let dh = x.cols / n_heads;
    let mut out = Tensor2::zeros(x.rows, x.cols);
    for h in 0..n_heads {
        let xs = head_slice(x, h, dh);
        let oh = flash_attention(ctx, &xs, &xs, &xs, default_scale(dh), ws);
        for i in 0..x.rows {
            out.row_mut(i)[h * dh..(h + 1) * dh].copy_from_slice(oh.row(i));
        }
        ws.put(oh.data);
    }
    out
}

/// One full pre-LN block, recording:
/// `x += MHA(LN₁(x)); x += FFN(LN₂(x))`.
fn block_forward(ctx: &KernelCtx, blk: &EncoderLayer, x_in: Tensor2,
                 ws: &mut Workspace) -> (Tensor2, BlockCache) {
    let (n, d) = (x_in.rows, x_in.cols);
    let dff = blk.b1.len();
    let proj = blk.proj.as_ref().expect("trainer requires projected blocks");
    // attention sublayer
    let ln1 = detach(layernorm(ctx, &x_in, &blk.ln1_gain, &blk.ln1_bias,
                               LN_EPS, ws), ws);
    let (att, mha) = mha_forward(ctx, &ln1, &proj.wq, &proj.wk, &proj.wv,
                                 &proj.wo, proj.n_heads(), ws);
    let x_mid = add(&x_in, &att);
    // FFN sublayer
    let ln2 = detach(layernorm(ctx, &x_mid, &blk.ln2_gain, &blk.ln2_bias,
                               LN_EPS, ws), ws);
    let mut z_pre = Tensor2::zeros(n, dff);
    gemm_into(ctx, &ln2.data, &blk.w1, &mut z_pre.data, n, d, dff);
    for i in 0..n {
        for (v, &b) in z_pre.row_mut(i).iter_mut().zip(&blk.b1) {
            *v += b;
        }
    }
    let mut a1 = Tensor2::zeros(n, dff);
    for (a, &z) in a1.data.iter_mut().zip(&z_pre.data) {
        *a = gelu(z);
    }
    let mut f2 = Tensor2::zeros(n, d);
    gemm_into(ctx, &a1.data, &blk.w2, &mut f2.data, n, dff, d);
    let mut x_out = add(&x_mid, &f2);
    for i in 0..n {
        for (v, &b) in x_out.row_mut(i).iter_mut().zip(&blk.b2) {
            *v += b;
        }
    }
    let cache = BlockCache { x_in, ln1, mha, x_mid, ln2, z_pre, a1 };
    (x_out, cache)
}

/// Backward through one block given `d_out` at its output.
/// Accumulates into `g`; returns the gradient at the block input.
fn block_backward(ctx: &KernelCtx, blk: &EncoderLayer, cache: &BlockCache,
                  d_out: &Tensor2, g: &mut BlockGrads,
                  ws: &mut Workspace) -> Tensor2 {
    let (n, d) = (cache.x_in.rows, cache.x_in.cols);
    let dff = cache.z_pre.cols;
    let proj = blk.proj.as_ref().expect("trainer requires projected blocks");

    // x_out = x_mid + a1·W2 + b2
    for i in 0..n {
        accumulate(&mut g.b2, d_out.row(i));
    }
    let mut d_a1 = Tensor2::zeros(n, dff);
    gemm_backward_acc(ctx, &cache.a1.data, &blk.w2, &d_out.data, n, dff, d,
                      &mut d_a1.data, &mut g.w2, ws);
    let mut d_z = Tensor2::zeros(n, dff);
    bias_gelu_backward(&cache.z_pre, &d_a1, &mut d_z, &mut g.b1);
    let mut d_ln2 = Tensor2::zeros(n, d);
    gemm_backward_acc(ctx, &cache.ln2.data, &blk.w1, &d_z.data, n, d, dff,
                      &mut d_ln2.data, &mut g.w1, ws);
    let mut d_from_ln2 = Tensor2::zeros(n, d);
    layernorm_backward(&cache.x_mid, &blk.ln2_gain, LN_EPS, &d_ln2,
                       &mut d_from_ln2, &mut g.ln2_gain, &mut g.ln2_bias);
    // residual seam: x_out depends on x_mid directly and through the FFN
    let d_x_mid = add(d_out, &d_from_ln2);

    // x_mid = x_in + MHA(LN₁(x_in))
    let d_ln1 = mha_backward(ctx, &cache.ln1, &proj.wq, &proj.wk, &proj.wv,
                             &proj.wo, proj.n_heads(), &cache.mha, &d_x_mid,
                             &mut g.mha, ws);
    let mut d_from_ln1 = Tensor2::zeros(n, d);
    layernorm_backward(&cache.x_in, &blk.ln1_gain, LN_EPS, &d_ln1,
                       &mut d_from_ln1, &mut g.ln1_gain, &mut g.ln1_bias);
    add(&d_x_mid, &d_from_ln1)
}

/// Tied-embedding MLM head for one sequence: masked-position logits
/// against the frozen table, stable row softmax, cross-entropy summed
/// (unscaled return) and `d_x` rows filled with
/// `(p − onehot)·E / total_masked`.
#[allow(clippy::too_many_arguments)]
fn mlm_head(ctx: &KernelCtx, x: &Tensor2, embed: &[f32], et: &[f32],
            vocab: usize, targets: &[i32], loss_mask: &[f32],
            inv_total_masked: f32, d_x: &mut Tensor2,
            ws: &mut Workspace) -> f32 {
    let (n, d) = (x.rows, x.cols);
    let masked: Vec<usize> = (0..n).filter(|&i| loss_mask[i] > 0.0).collect();
    if masked.is_empty() {
        return 0.0;
    }
    let nm = masked.len();
    let mut xm = ws.take(nm * d);
    for (r, &i) in masked.iter().enumerate() {
        xm[r * d..(r + 1) * d].copy_from_slice(x.row(i));
    }
    let mut logits = ws.take(nm * vocab);
    gemm_into(ctx, &xm, et, &mut logits, nm, d, vocab);
    let mut loss = 0.0f32;
    for (r, &i) in masked.iter().enumerate() {
        let row = &mut logits[r * vocab..(r + 1) * vocab];
        let target = targets[i] as usize;
        debug_assert!(target < vocab, "target id out of vocab");
        let mut max = f32::NEG_INFINITY;
        for &v in row.iter() {
            max = max.max(v);
        }
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        let inv_sum = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv_sum;
        }
        loss -= row[target].max(f32::MIN_POSITIVE).ln();
        // row now holds p; turn it into scaled dlogits in place
        row[target] -= 1.0;
        for v in row.iter_mut() {
            *v *= inv_total_masked;
        }
    }
    // dX_masked = dlogits · E, scattered back onto the masked rows
    let mut dxm = ws.take(nm * d);
    gemm_into(ctx, &logits, embed, &mut dxm, nm, vocab, d);
    for (r, &i) in masked.iter().enumerate() {
        d_x.row_mut(i).copy_from_slice(&dxm[r * d..(r + 1) * d]);
    }
    ws.put(dxm);
    ws.put(logits);
    ws.put(xm);
    loss
}

fn update_tensor(kind: OptimizerKind, lr_t: f32, clip: f32, p: &mut [f32],
                 g: &[f32], m: &mut [f32], v: &mut [f32]) {
    match kind {
        OptimizerKind::Sgd => {
            for (pv, &gv) in p.iter_mut().zip(g) {
                *pv -= lr_t * (gv * clip);
            }
        }
        OptimizerKind::Adam => {
            const B1: f32 = 0.9;
            const B2: f32 = 0.999;
            const EPS: f32 = 1e-8;
            for j in 0..p.len() {
                let gv = g[j] * clip;
                m[j] = B1 * m[j] + (1.0 - B1) * gv;
                v[j] = B2 * v[j] + (1.0 - B2) * gv * gv;
                p[j] -= lr_t * m[j] / (v[j].sqrt() + EPS);
            }
        }
    }
}

/// Global-norm clip over all blocks, then one optimizer step per
/// tensor in fixed block/field order.
fn clip_and_apply(stack: &mut EncoderStack, grads: &[BlockGrads],
                  adam_m: &mut [BlockGrads], adam_v: &mut [BlockGrads],
                  kind: OptimizerKind, lr: f32, t_step: i32) {
    let mut sq = 0.0f32;
    for g in grads {
        for t in g.tensors() {
            for &v in t.iter() {
                sq += v * v;
            }
        }
    }
    let norm = sq.sqrt();
    let clip = if norm > GRAD_CLIP { GRAD_CLIP / norm } else { 1.0 };
    let lr_t = match kind {
        OptimizerKind::Sgd => lr,
        // fold Adam's bias correction into the step size
        OptimizerKind::Adam => {
            lr * (1.0 - 0.999f32.powi(t_step)).sqrt()
                / (1.0 - 0.9f32.powi(t_step))
        }
    };
    for (bi, blk) in stack.blocks_mut().iter_mut().enumerate() {
        let g = &grads[bi];
        let (m, v) = (&mut adam_m[bi], &mut adam_v[bi]);
        update_tensor(kind, lr_t, clip, &mut blk.ln1_gain, &g.ln1_gain,
                      &mut m.ln1_gain, &mut v.ln1_gain);
        update_tensor(kind, lr_t, clip, &mut blk.ln1_bias, &g.ln1_bias,
                      &mut m.ln1_bias, &mut v.ln1_bias);
        update_tensor(kind, lr_t, clip, &mut blk.ln2_gain, &g.ln2_gain,
                      &mut m.ln2_gain, &mut v.ln2_gain);
        update_tensor(kind, lr_t, clip, &mut blk.ln2_bias, &g.ln2_bias,
                      &mut m.ln2_bias, &mut v.ln2_bias);
        update_tensor(kind, lr_t, clip, &mut blk.w1, &g.w1, &mut m.w1,
                      &mut v.w1);
        update_tensor(kind, lr_t, clip, &mut blk.b1, &g.b1, &mut m.b1,
                      &mut v.b1);
        update_tensor(kind, lr_t, clip, &mut blk.w2, &g.w2, &mut m.w2,
                      &mut v.w2);
        update_tensor(kind, lr_t, clip, &mut blk.b2, &g.b2, &mut m.b2,
                      &mut v.b2);
        let proj = blk.proj.as_mut().expect("projected trainer stack");
        update_tensor(kind, lr_t, clip, &mut proj.wq, &g.mha.wq,
                      &mut m.mha.wq, &mut v.mha.wq);
        update_tensor(kind, lr_t, clip, &mut proj.wk, &g.mha.wk,
                      &mut m.mha.wk, &mut v.mha.wk);
        update_tensor(kind, lr_t, clip, &mut proj.wv, &g.mha.wv,
                      &mut m.mha.wv, &mut v.mha.wv);
        update_tensor(kind, lr_t, clip, &mut proj.wo, &g.mha.wo,
                      &mut m.mha.wo, &mut v.mha.wo);
    }
}

/// Run one deterministic CPU training job. Panics on invalid configs
/// (this is an offline tool, not a serving path).
pub fn train_cpu(cfg: &CpuTrainConfig) -> CpuTrainOutcome {
    cfg.validate();
    let (d, heads, layers) = (cfg.d_model, cfg.n_heads, cfg.layers);
    let dff = d * cfg.ffn_mult;
    let mcfg = cfg.model_config();
    let model = CpuModel::new(mcfg, Variant::Full);
    let mut stack = EncoderStack::new_mixed(
        vec![BatchedVariant::Full; layers], d, heads, cfg.ffn_mult, cfg.seed,
        true);
    let ctx = if cfg.workers <= 1 {
        KernelCtx::sequential()
    } else {
        KernelCtx::with_pool(Arc::new(ThreadPool::new(cfg.workers - 1)))
    };
    let mut ws = Workspace::new();

    // fixed data stream: corpus → tokenizer → pre-drawn batches+masks,
    // replayed in order every epoch
    let mut gen = CorpusGenerator::new(
        cfg.seed, cfg.vocab.saturating_sub(64).max(64), 4);
    let corpus = gen.corpus(cfg.corpus_lines, cfg.seq / 2, cfg.seq);
    let tok = Tokenizer::fit(&corpus, cfg.vocab);
    let encoded: Vec<Vec<i32>> =
        corpus.iter().map(|l| tok.encode(l, cfg.seq)).collect();
    let mut rng = Rng::new(cfg.seed ^ 0xA5A5);
    let batches: Vec<MlmBatch> = (0..cfg.steps_per_epoch)
        .map(|_| {
            let rows: Vec<Vec<i32>> = (0..cfg.batch)
                .map(|_| encoded[rng.below(encoded.len() as u64) as usize]
                    .clone())
                .collect();
            make_mlm_batch(&mut rng, &rows, cfg.vocab)
        })
        .collect();

    // frozen tied head: E and Eᵀ
    let embed = model.embed_table().to_vec();
    let mut et = vec![0.0f32; d * cfg.vocab];
    transpose_into(&embed, &mut et, cfg.vocab, d);

    let n_blocks = layers - 1;
    let mut adam_m: Vec<BlockGrads> =
        (0..n_blocks).map(|_| BlockGrads::zeros(d, dff, heads)).collect();
    let mut adam_v: Vec<BlockGrads> =
        (0..n_blocks).map(|_| BlockGrads::zeros(d, dff, heads)).collect();

    let mut step_losses = Vec::with_capacity(cfg.epochs * cfg.steps_per_epoch);
    let mut epoch_losses = Vec::with_capacity(cfg.epochs);
    let t0 = Instant::now();
    let mut t_step = 0i32;
    for _epoch in 0..cfg.epochs {
        let mut epoch_sum = 0.0f32;
        for mlm in &batches {
            t_step += 1;
            let total_masked: f32 = mlm.loss_mask.iter().sum();
            let mut loss = 0.0f32;
            if total_masked > 0.0 {
                let inv_total = 1.0 / total_masked;
                let mut grads: Vec<BlockGrads> = (0..n_blocks)
                    .map(|_| BlockGrads::zeros(d, dff, heads))
                    .collect();
                // sequences run in batch order; gradient accumulation
                // order is therefore fixed
                for b in 0..mlm.batch {
                    let row = b * mlm.seq..(b + 1) * mlm.seq;
                    let x0 = model.embed_sequence(&mlm.tokens[row.clone()],
                                                  mlm.seq);
                    let x1 = seed_block_forward(&ctx, &x0, heads, &mut ws);
                    let mut caches = Vec::with_capacity(n_blocks);
                    let mut x = x1;
                    for blk in stack.blocks() {
                        let (x_out, cache) =
                            block_forward(&ctx, blk, x, &mut ws);
                        caches.push(cache);
                        x = x_out;
                    }
                    let mut d_x = Tensor2::zeros(mlm.seq, d);
                    loss += mlm_head(&ctx, &x, &embed, &et, cfg.vocab,
                                     &mlm.targets[row.clone()],
                                     &mlm.loss_mask[row], inv_total,
                                     &mut d_x, &mut ws);
                    for bi in (0..n_blocks).rev() {
                        d_x = block_backward(&ctx, &stack.blocks()[bi],
                                             &caches[bi], &d_x,
                                             &mut grads[bi], &mut ws);
                    }
                    // d_x at the seed-block boundary is discarded:
                    // block 0 is weightless, its input frozen
                }
                loss *= inv_total;
                clip_and_apply(&mut stack, &grads, &mut adam_m, &mut adam_v,
                               cfg.optimizer, cfg.lr, t_step);
            }
            step_losses.push(loss);
            epoch_sum += loss;
        }
        epoch_losses.push(epoch_sum / cfg.steps_per_epoch as f32);
    }
    let total_time = t0.elapsed();
    let tokens = (cfg.epochs * cfg.steps_per_epoch * cfg.batch * cfg.seq) as f64;
    let report = CpuTrainReport {
        initial_loss: step_losses.first().copied().unwrap_or(f32::NAN),
        final_loss: step_losses.last().copied().unwrap_or(f32::NAN),
        step_losses,
        epoch_losses,
        total_time,
        tokens_per_sec: tokens / total_time.as_secs_f64().max(1e-9),
    };
    CpuTrainOutcome { stack, model_config: mcfg, report }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> CpuTrainConfig {
        CpuTrainConfig {
            d_model: 16,
            n_heads: 2,
            ffn_mult: 2,
            layers: 2,
            vocab: 96,
            seq: 16,
            batch: 2,
            steps_per_epoch: 2,
            epochs: 2,
            corpus_lines: 60,
            ..Default::default()
        }
    }

    #[test]
    fn tiny_run_losses_finite_and_weights_move() {
        let out = train_cpu(&tiny());
        assert!(out.report.step_losses.iter().all(|l| l.is_finite()));
        assert_eq!(out.report.step_losses.len(), 4);
        assert_eq!(out.report.epoch_losses.len(), 2);
        // training must move the weights off the seeded init
        let seeded = EncoderStack::new_mixed(
            vec![BatchedVariant::Full; 2], 16, 2, 2, tiny().seed, true);
        let a = &out.stack.blocks()[0].w1;
        let b = &seeded.blocks()[0].w1;
        assert!(a.iter().zip(b).any(|(x, y)| x != y),
                "w1 unchanged after training");
    }

    #[test]
    fn same_config_is_bitwise_reproducible_in_process() {
        let (a, b) = (train_cpu(&tiny()), train_cpu(&tiny()));
        let la: Vec<u32> =
            a.report.step_losses.iter().map(|x| x.to_bits()).collect();
        let lb: Vec<u32> =
            b.report.step_losses.iter().map(|x| x.to_bits()).collect();
        assert_eq!(la, lb, "loss curves must be bitwise identical");
    }

    #[test]
    fn optimizer_kind_parses_and_round_trips() {
        for k in [OptimizerKind::Sgd, OptimizerKind::Adam] {
            assert_eq!(OptimizerKind::parse(k.token()), Some(k));
        }
        assert_eq!(OptimizerKind::parse("adamw"), None);
    }
}
