//! Hand-derived backward passes for the CPU trainer.
//!
//! Mirrors `python/compile/kernels/autodiff.py`'s approach — the
//! forward runs the production kernels, the backward is the VJP of the
//! same math written out by hand — except here both directions live in
//! Rust and the residuals (post-LN activations, per-head attention
//! probabilities, FFN pre-activations) are recorded by the trainer's
//! forward pass instead of being rematerialized.
//!
//! Every function below is a pure VJP of the matching `kernels::`
//! forward primitive:
//!
//! * GEMM       — `C = A·B` ⇒ `dA = dC·Bᵀ`, `dB = Aᵀ·dC`, computed
//!                with the same blocked [`gemm_into`] used forward, so
//!                the backward inherits the thread-count-determinism
//!                contract for free.
//! * layernorm  — population-variance form (`var = Σ(x−μ)²/d`,
//!                matching `kernels::layernorm`):
//!                `dx = (dŷ − mean(dŷ) − x̂·mean(dŷ⊙x̂)) / σ` with
//!                `dŷ = dy⊙gain`.
//! * bias+GELU  — tanh-GELU derivative of `kernels::gelu`'s exact
//!                constants (`√(2/π) = 0.797_884_56`, `0.044_715`).
//! * softmax-attention — with `S = softmax(scale·q·kᵀ)`, `O = S·v`:
//!                `dv = Sᵀ·dO`; `dS = dO·vᵀ`;
//!                `dz_ij = S_ij·(dS_ij − Σ_{j'} dS_ij'·S_ij')`;
//!                `dq = scale·dz·k`; `dk = scale·dzᵀ·q`.
//! * projection seam — the per-head q/k/v projections, the merged
//!                head concat and the output projection, composed from
//!                the GEMM and attention rules above.
//!
//! Determinism: the GEMM-shaped work rides the deterministic kernel
//! core; all reductions here (bias column sums, row softmax sums) run
//! sequentially in index order, so every gradient is bitwise identical
//! for any worker count — the property `tests/train_e2e.rs` pins on
//! whole checkpoints. Correctness against f64 central differences is
//! pinned by `tests/train_gradcheck.rs` at ≤1e-3.

use crate::attention::{default_scale, Tensor2};
use crate::kernels::{gemm_into, softmax_scores, transpose_into, KernelCtx, Workspace};

/// `dst += src`, elementwise. The one accumulation primitive the
/// trainer uses, kept sequential so gradient accumulation order is a
/// function of call order alone.
pub fn accumulate(dst: &mut [f32], src: &[f32]) {
    assert_eq!(dst.len(), src.len(), "gradient accumulation length");
    for (d, s) in dst.iter_mut().zip(src) {
        *d += *s;
    }
}

/// VJP of `C = A·B` (`A`: m×k, `B`: k×n, `dC`: m×n), **accumulating**
/// `dA += dC·Bᵀ` and `dB += Aᵀ·dC`. Pass zeroed buffers for overwrite
/// semantics. Scratch comes from `ws` and is returned before exit.
#[allow(clippy::too_many_arguments)]
pub fn gemm_backward_acc(ctx: &KernelCtx, a: &[f32], b: &[f32], d_c: &[f32],
                         m: usize, k: usize, n: usize,
                         d_a: &mut [f32], d_b: &mut [f32],
                         ws: &mut Workspace) {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(b.len(), k * n, "B shape");
    assert_eq!(d_c.len(), m * n, "dC shape");
    assert_eq!(d_a.len(), m * k, "dA shape");
    assert_eq!(d_b.len(), k * n, "dB shape");
    // dA = dC · Bᵀ
    let mut bt = ws.take(n * k);
    transpose_into(b, &mut bt, k, n);
    let mut scratch = ws.take(m * k);
    gemm_into(ctx, d_c, &bt, &mut scratch, m, n, k);
    accumulate(d_a, &scratch);
    ws.put(scratch);
    ws.put(bt);
    // dB = Aᵀ · dC
    let mut at = ws.take(k * m);
    transpose_into(a, &mut at, m, k);
    let mut scratch = ws.take(k * n);
    gemm_into(ctx, &at, d_c, &mut scratch, k, m, n);
    accumulate(d_b, &scratch);
    ws.put(scratch);
    ws.put(at);
}

/// VJP of `kernels::layernorm` (population variance, per-row moments).
/// Overwrites `d_x`; **accumulates** `d_gain` / `d_bias`.
pub fn layernorm_backward(x: &Tensor2, gain: &[f32], eps: f32, d_y: &Tensor2,
                          d_x: &mut Tensor2, d_gain: &mut [f32],
                          d_bias: &mut [f32]) {
    let (n, d) = (x.rows, x.cols);
    assert_eq!((d_y.rows, d_y.cols), (n, d), "dY shape");
    assert_eq!((d_x.rows, d_x.cols), (n, d), "dX shape");
    assert_eq!(gain.len(), d, "gain width");
    assert_eq!(d_gain.len(), d, "dgain width");
    assert_eq!(d_bias.len(), d, "dbias width");
    let inv_d = 1.0f32 / d as f32;
    for i in 0..n {
        let xr = x.row(i);
        let dyr = d_y.row(i);
        let mut mean = 0.0f32;
        for &v in xr {
            mean += v;
        }
        mean *= inv_d;
        let mut var = 0.0f32;
        for &v in xr {
            let c = v - mean;
            var += c * c;
        }
        var *= inv_d;
        let inv_sigma = 1.0 / (var + eps).sqrt();
        // dŷ = dy⊙gain and the two row reductions it feeds
        let mut sum_dyh = 0.0f32;
        let mut sum_dyh_xhat = 0.0f32;
        for j in 0..d {
            let xhat = (xr[j] - mean) * inv_sigma;
            let dyh = dyr[j] * gain[j];
            sum_dyh += dyh;
            sum_dyh_xhat += dyh * xhat;
            d_gain[j] += dyr[j] * xhat;
            d_bias[j] += dyr[j];
        }
        let m1 = sum_dyh * inv_d;
        let m2 = sum_dyh_xhat * inv_d;
        let dxr = d_x.row_mut(i);
        for j in 0..d {
            let xhat = (xr[j] - mean) * inv_sigma;
            dxr[j] = (dyr[j] * gain[j] - m1 - xhat * m2) * inv_sigma;
        }
    }
}

/// Derivative of `kernels::gelu` (tanh form, same constants):
/// `g'(z) = ½(1+tanh u) + ½·z·(1−tanh²u)·√(2/π)·(1+3·0.044715·z²)`.
#[inline]
pub fn gelu_grad(z: f32) -> f32 {
    const SQRT_2_OVER_PI: f32 = 0.797_884_56;
    let u = SQRT_2_OVER_PI * (z + 0.044_715 * z * z * z);
    let t = u.tanh();
    0.5 * (1.0 + t)
        + 0.5 * z * (1.0 - t * t) * SQRT_2_OVER_PI
            * (1.0 + 3.0 * 0.044_715 * z * z)
}

/// VJP of the fused bias+GELU (`a = gelu(x + bias)`), given the
/// recorded pre-activation `z_pre = x + bias`. Overwrites
/// `d_pre = d_act ⊙ gelu'(z_pre)` (which is both `dx` and the per-row
/// bias gradient); **accumulates** the column sums into `d_bias`.
pub fn bias_gelu_backward(z_pre: &Tensor2, d_act: &Tensor2,
                          d_pre: &mut Tensor2, d_bias: &mut [f32]) {
    let (n, d) = (z_pre.rows, z_pre.cols);
    assert_eq!((d_act.rows, d_act.cols), (n, d), "d_act shape");
    assert_eq!((d_pre.rows, d_pre.cols), (n, d), "d_pre shape");
    assert_eq!(d_bias.len(), d, "dbias width");
    for i in 0..n {
        let zr = z_pre.row(i);
        let dar = d_act.row(i);
        let dpr = d_pre.row_mut(i);
        for j in 0..d {
            let g = dar[j] * gelu_grad(zr[j]);
            dpr[j] = g;
            d_bias[j] += g;
        }
    }
}

/// VJP of exact softmax attention given the materialized probability
/// matrix `s = softmax(scale·q·kᵀ)` (n×n) and upstream `d_out` (n×dh).
/// Returns freshly-allocated `(dq, dk, dv)`.
pub fn softmax_attention_backward(ctx: &KernelCtx, q: &Tensor2, k: &Tensor2,
                                  v: &Tensor2, s: &Tensor2, scale: f32,
                                  d_out: &Tensor2, ws: &mut Workspace)
                                  -> (Tensor2, Tensor2, Tensor2) {
    let (n, dh) = (q.rows, q.cols);
    assert_eq!((k.rows, k.cols), (n, dh), "k shape");
    assert_eq!((v.rows, v.cols), (n, dh), "v shape");
    assert_eq!((s.rows, s.cols), (n, n), "s shape");
    assert_eq!((d_out.rows, d_out.cols), (n, dh), "d_out shape");

    // dv = Sᵀ · dO
    let mut st = ws.take(n * n);
    transpose_into(&s.data, &mut st, n, n);
    let mut dv = Tensor2::zeros(n, dh);
    gemm_into(ctx, &st, &d_out.data, &mut dv.data, n, n, dh);
    ws.put(st);

    // dS = dO · vᵀ
    let mut vt = ws.take(dh * n);
    transpose_into(&v.data, &mut vt, n, dh);
    let mut ds = ws.take(n * n);
    gemm_into(ctx, &d_out.data, &vt, &mut ds, n, dh, n);
    ws.put(vt);

    // softmax Jacobian, row-wise in place: dz = S ⊙ (dS − ⟨dS, S⟩_row)
    for i in 0..n {
        let srow = s.row(i);
        let dsrow = &mut ds[i * n..(i + 1) * n];
        let mut dot = 0.0f32;
        for j in 0..n {
            dot += dsrow[j] * srow[j];
        }
        for j in 0..n {
            dsrow[j] = srow[j] * (dsrow[j] - dot);
        }
    }

    // dq = scale · dz·k ; dk = scale · dzᵀ·q
    let mut dq = Tensor2::zeros(n, dh);
    gemm_into(ctx, &ds, &k.data, &mut dq.data, n, n, dh);
    let mut dzt = ws.take(n * n);
    transpose_into(&ds, &mut dzt, n, n);
    let mut dk = Tensor2::zeros(n, dh);
    gemm_into(ctx, &dzt, &q.data, &mut dk.data, n, n, dh);
    ws.put(dzt);
    ws.put(ds);
    for x in dq.data.iter_mut() {
        *x *= scale;
    }
    for x in dk.data.iter_mut() {
        *x *= scale;
    }
    (dq, dk, dv)
}

/// Recorded residuals of one projected multi-head attention sublayer:
/// per-head q/k/v, the materialized probability matrices, and the
/// merged head concat feeding the output projection.
pub struct MhaCache {
    pub q: Vec<Tensor2>,
    pub k: Vec<Tensor2>,
    pub v: Vec<Tensor2>,
    pub s: Vec<Tensor2>,
    pub merged: Tensor2,
}

/// Accumulated gradients of one projected attention sublayer. Head-major
/// layouts match [`Projections`](crate::model::Projections): `wq`/`wk`/
/// `wv` are `n_heads` concatenated d×dh blocks, `wo` is d×d.
pub struct MhaGrads {
    pub wq: Vec<f32>,
    pub wk: Vec<f32>,
    pub wv: Vec<f32>,
    pub wo: Vec<f32>,
}

impl MhaGrads {
    pub fn zeros(d: usize, n_heads: usize) -> MhaGrads {
        let dh = d / n_heads;
        MhaGrads {
            wq: vec![0.0; n_heads * d * dh],
            wk: vec![0.0; n_heads * d * dh],
            wv: vec![0.0; n_heads * d * dh],
            wo: vec![0.0; d * d],
        }
    }
}

/// Forward through the projection seam with recording: per head
/// `q_h = x·Wq_h`, `k_h = x·Wk_h`, `v_h = x·Wv_h`,
/// `S_h = softmax(scale·q_h·k_hᵀ)` (materialized — this is the
/// residual the backward needs), `O_h = S_h·v_h`; heads concat into
/// `merged`; `out = merged·Wo`. Numerically this is the same function
/// `Projections::mha_batch` serves (flash attention is an exact
/// softmax, just streamed), with the probabilities kept.
#[allow(clippy::too_many_arguments)]
pub fn mha_forward(ctx: &KernelCtx, x: &Tensor2, wq: &[f32], wk: &[f32],
                   wv: &[f32], wo: &[f32], n_heads: usize,
                   ws: &mut Workspace) -> (Tensor2, MhaCache) {
    let (n, d) = (x.rows, x.cols);
    assert_eq!(d % n_heads, 0, "d_model divisible by heads");
    let dh = d / n_heads;
    assert_eq!(wq.len(), n_heads * d * dh, "wq shape");
    assert_eq!(wk.len(), n_heads * d * dh, "wk shape");
    assert_eq!(wv.len(), n_heads * d * dh, "wv shape");
    assert_eq!(wo.len(), d * d, "wo shape");
    let scale = default_scale(dh);

    let mut cache = MhaCache {
        q: Vec::with_capacity(n_heads),
        k: Vec::with_capacity(n_heads),
        v: Vec::with_capacity(n_heads),
        s: Vec::with_capacity(n_heads),
        merged: Tensor2::zeros(n, d),
    };
    for h in 0..n_heads {
        let wslice = h * d * dh..(h + 1) * d * dh;
        let mut q = Tensor2::zeros(n, dh);
        let mut k = Tensor2::zeros(n, dh);
        let mut v = Tensor2::zeros(n, dh);
        gemm_into(ctx, &x.data, &wq[wslice.clone()], &mut q.data, n, d, dh);
        gemm_into(ctx, &x.data, &wk[wslice.clone()], &mut k.data, n, d, dh);
        gemm_into(ctx, &x.data, &wv[wslice], &mut v.data, n, d, dh);
        let s = softmax_scores(ctx, &q, &k, scale, ws);
        let mut o = Tensor2::zeros(n, dh);
        gemm_into(ctx, &s.data, &v.data, &mut o.data, n, n, dh);
        for i in 0..n {
            cache.merged.row_mut(i)[h * dh..(h + 1) * dh]
                .copy_from_slice(o.row(i));
        }
        cache.q.push(q);
        cache.k.push(k);
        cache.v.push(v);
        // softmax_scores hands out a ws-backed tensor; keep a trainer-
        // owned copy so the arena stays balanced across the step
        let s_owned = Tensor2 { rows: s.rows, cols: s.cols, data: s.data.clone() };
        ws.put(s.data);
        cache.s.push(s_owned);
    }
    let mut out = Tensor2::zeros(n, d);
    gemm_into(ctx, &cache.merged.data, wo, &mut out.data, n, d, d);
    (out, cache)
}

/// Backward through the projection seam. **Accumulates** into `grads`;
/// returns `d_x` (the gradient w.r.t. the post-LN input `x`).
#[allow(clippy::too_many_arguments)]
pub fn mha_backward(ctx: &KernelCtx, x: &Tensor2, wq: &[f32], wk: &[f32],
                    wv: &[f32], wo: &[f32], n_heads: usize, cache: &MhaCache,
                    d_out: &Tensor2, grads: &mut MhaGrads,
                    ws: &mut Workspace) -> Tensor2 {
    let (n, d) = (x.rows, x.cols);
    let dh = d / n_heads;
    let scale = default_scale(dh);
    assert_eq!((d_out.rows, d_out.cols), (n, d), "d_out shape");

    // out = merged·Wo  ⇒  d_merged = dO·Woᵀ, dWo += mergedᵀ·dO
    let mut d_merged = Tensor2::zeros(n, d);
    gemm_backward_acc(ctx, &cache.merged.data, wo, &d_out.data, n, d, d,
                      &mut d_merged.data, &mut grads.wo, ws);

    let mut d_x = Tensor2::zeros(n, d);
    for h in 0..n_heads {
        let mut d_oh = Tensor2::zeros(n, dh);
        for i in 0..n {
            d_oh.row_mut(i)
                .copy_from_slice(&d_merged.row(i)[h * dh..(h + 1) * dh]);
        }
        let (dq, dk, dv) = softmax_attention_backward(
            ctx, &cache.q[h], &cache.k[h], &cache.v[h], &cache.s[h], scale,
            &d_oh, ws);
        // q_h = x·Wq_h (etc.) ⇒ dWq_h += xᵀ·dq, d_x += dq·Wq_hᵀ
        let wslice = h * d * dh..(h + 1) * d * dh;
        gemm_backward_acc(ctx, &x.data, &wq[wslice.clone()], &dq.data, n, d,
                          dh, &mut d_x.data, &mut grads.wq[wslice.clone()], ws);
        gemm_backward_acc(ctx, &x.data, &wk[wslice.clone()], &dk.data, n, d,
                          dh, &mut d_x.data, &mut grads.wk[wslice.clone()], ws);
        gemm_backward_acc(ctx, &x.data, &wv[wslice.clone()], &dv.data, n, d,
                          dh, &mut d_x.data, &mut grads.wv[wslice], ws);
    }
    d_x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Rng;

    #[test]
    fn gemm_backward_matches_hand_rolled_small() {
        // C = A·B with A 2×3, B 3×2; dC = ones ⇒ dA = 1·Bᵀ, dB = Aᵀ·1
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [0.5f32, -1.0, 2.0, 0.0, 1.0, 3.0];
        let d_c = [1.0f32; 4];
        let mut d_a = vec![0.0f32; 6];
        let mut d_b = vec![0.0f32; 6];
        let ctx = KernelCtx::sequential();
        let mut ws = Workspace::new();
        gemm_backward_acc(&ctx, &a, &b, &d_c, 2, 3, 2, &mut d_a, &mut d_b,
                          &mut ws);
        // dA rows are both [b00+b01, b10+b11, b20+b21]
        let row = [-0.5f32, 2.0, 4.0];
        assert_eq!(&d_a[..3], &row);
        assert_eq!(&d_a[3..], &row);
        // dB rows: col sums of A broadcast over n
        assert_eq!(d_b, vec![5.0, 5.0, 7.0, 7.0, 9.0, 9.0]);
    }

    #[test]
    fn layernorm_backward_of_uniform_gain_kills_constant_shifts() {
        // LN is invariant to adding a constant to a row, so dx must sum
        // to ~0 along each row
        let mut rng = Rng::new(11);
        let x = Tensor2::randn(&mut rng, 4, 16, 1.0);
        let d_y = Tensor2::randn(&mut rng, 4, 16, 1.0);
        let gain = vec![1.0f32; 16];
        let mut d_x = Tensor2::zeros(4, 16);
        let mut d_gain = vec![0.0f32; 16];
        let mut d_bias = vec![0.0f32; 16];
        layernorm_backward(&x, &gain, 1e-5, &d_y, &mut d_x, &mut d_gain,
                           &mut d_bias);
        for i in 0..4 {
            let s: f32 = d_x.row(i).iter().sum();
            assert!(s.abs() < 1e-4, "row {i} dx sum {s}");
        }
    }

    #[test]
    fn gelu_grad_matches_finite_difference() {
        for &z in &[-3.0f32, -1.0, -0.1, 0.0, 0.5, 2.0] {
            let h = 1e-3f32;
            let fd = (crate::kernels::gelu(z + h) - crate::kernels::gelu(z - h))
                / (2.0 * h);
            assert!((gelu_grad(z) - fd).abs() < 1e-3,
                    "z={z}: {} vs {fd}", gelu_grad(z));
        }
    }

    #[test]
    fn attention_backward_probability_shift_invariance() {
        // rows of S sum to 1, so dk summed over keys of a rank-1
        // d_out... cheapest sanity: shapes + finiteness + dv row sums
        let mut rng = Rng::new(12);
        let q = Tensor2::randn(&mut rng, 8, 4, 1.0);
        let k = Tensor2::randn(&mut rng, 8, 4, 1.0);
        let v = Tensor2::randn(&mut rng, 8, 4, 1.0);
        let d_out = Tensor2::randn(&mut rng, 8, 4, 1.0);
        let ctx = KernelCtx::sequential();
        let mut ws = Workspace::new();
        let s = softmax_scores(&ctx, &q, &k, default_scale(4), &mut ws);
        let s = Tensor2 { rows: s.rows, cols: s.cols, data: s.data.clone() };
        let (dq, dk, dv) = softmax_attention_backward(
            &ctx, &q, &k, &v, &s, default_scale(4), &d_out, &mut ws);
        for t in [&dq, &dk, &dv] {
            assert_eq!((t.rows, t.cols), (8, 4));
            assert!(t.data.iter().all(|x| x.is_finite()));
        }
        // Σ_i dv[i] must equal Σ_i d_out[i] (columns of S sum over
        // queries weight d_out rows; total mass is preserved because
        // each S row sums to 1: Σ_j dv[j] = Σ_j Σ_i S_ij d_out[i]
        //                                  = Σ_i d_out[i])
        for c in 0..4 {
            let got: f32 = (0..8).map(|r| dv.row(r)[c]).sum();
            let want: f32 = (0..8).map(|r| d_out.row(r)[c]).sum();
            assert!((got - want).abs() < 1e-4, "col {c}: {got} vs {want}");
        }
    }

    #[test]
    fn mha_roundtrip_shapes_and_determinism_across_thread_counts() {
        let (n, d, heads) = (16, 8, 2);
        let dh = d / heads;
        let mut rng = Rng::new(13);
        let x = Tensor2::randn(&mut rng, n, d, 1.0);
        let wq = Tensor2::randn(&mut rng, heads * d, dh, 0.3).data;
        let wk = Tensor2::randn(&mut rng, heads * d, dh, 0.3).data;
        let wv = Tensor2::randn(&mut rng, heads * d, dh, 0.3).data;
        let wo = Tensor2::randn(&mut rng, d, d, 0.3).data;
        let d_out = Tensor2::randn(&mut rng, n, d, 1.0);

        let run = |ctx: &KernelCtx| {
            let mut ws = Workspace::new();
            let (out, cache) =
                mha_forward(ctx, &x, &wq, &wk, &wv, &wo, heads, &mut ws);
            let mut grads = MhaGrads::zeros(d, heads);
            let d_x = mha_backward(ctx, &x, &wq, &wk, &wv, &wo, heads,
                                   &cache, &d_out, &mut grads, &mut ws);
            (out, d_x, grads)
        };
        let (o1, dx1, g1) = run(&KernelCtx::sequential());
        let (o2, dx2, g2) = run(&KernelCtx::global());
        assert_eq!(o1.data, o2.data, "forward thread determinism");
        assert_eq!(dx1.data, dx2.data, "d_x thread determinism");
        assert_eq!(g1.wq, g2.wq);
        assert_eq!(g1.wo, g2.wo);
        assert_eq!((o1.rows, o1.cols), (n, d));
    }
}
