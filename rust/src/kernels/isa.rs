//! Runtime ISA dispatch for the kernel core.
//!
//! Every [`KernelCtx`](super::KernelCtx) carries one [`Isa`] arm; the
//! GEMM and fused kernels dispatch on it once per block, outside their
//! inner loops. The default arm is resolved per context construction by
//! [`active_isa`]: the `SSAF_KERNEL` environment override when set,
//! otherwise the best arm the host supports ([`Isa::detect`]).
//!
//! # Determinism scope
//!
//! * **Within an arm**: results are bitwise-invariant across thread
//!   counts — the arm never changes how work is split (fixed
//!   [`BLOCK_ROWS`](super::BLOCK_ROWS) blocks, k never split), only the
//!   register tile each block body uses.
//! * **Across arms**: the FMA arms contract mul+add to one rounding, so
//!   scalar and SIMD results differ in the last ulps; every arm stays
//!   within the 1e-4 parity envelope of the seed scalar references
//!   (property-tested per detected arm in `tests/kernel_parity.rs`).
//!   The `scalar` arm is byte-for-byte the pre-dispatch kernel core.
//!
//! # Why `avx512` is absent
//!
//! AVX-512 intrinsics are not stabilized on the toolchain this repo
//! pins (`rust-toolchain.toml`, stable 1.88); the dispatch seam is
//! ready for an `Avx512` arm the day the pin moves past 1.89.
//!
//! # No caching
//!
//! [`active_isa`] re-reads the environment on every call instead of
//! memoizing in a `OnceLock`. Contexts are constructed per batch / per
//! test, not per inner loop, so the cost is one env lookup well outside
//! the hot path — and it keeps the override observable by tests that
//! set `SSAF_KERNEL` for their own process (`tests/kernel_isa_override.rs`)
//! without global-state races between parallel in-process tests, which
//! instead pin arms per context via
//! [`KernelCtx::with_isa`](super::KernelCtx::with_isa).

/// One micro-kernel arm of the kernel core.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// The portable arm — byte-for-byte the pre-dispatch scalar core
    /// (8-wide unrolled loops the compiler may autovectorize, separate
    /// mul and add roundings). Supported everywhere; forced by the CI
    /// scalar gate lane.
    Scalar,
    /// x86-64 AVX2 + FMA: 8-row × 8-lane fused-multiply-add register
    /// tile in the GEMM, 256-bit dot/axpy/layernorm rows in the fused
    /// kernels, software prefetch on the streamed B panel.
    Avx2,
    /// AArch64 NEON: 4-row × 4-lane `vfmaq_f32` register tile and
    /// 128-bit fused-kernel rows.
    Neon,
}

impl Isa {
    /// Parse a config/env token (`scalar` | `avx2` | `neon`).
    pub fn parse(s: &str) -> Option<Isa> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(Isa::Scalar),
            "avx2" => Some(Isa::Avx2),
            "neon" => Some(Isa::Neon),
            _ => None,
        }
    }

    /// The canonical token (round-trips through [`Isa::parse`]); keys
    /// the per-ISA bench rows and the STATS `kernel:` field.
    pub fn token(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    /// Best arm the host CPU supports. One-time feature detection per
    /// call site (`is_x86_feature_detected!` caches internally).
    pub fn detect() -> Isa {
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
                return Isa::Avx2;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if std::arch::is_aarch64_feature_detected!("neon") {
                return Isa::Neon;
            }
        }
        Isa::Scalar
    }

    /// Whether this build, on this CPU, can execute the arm.
    /// [`KernelCtx::with_isa`](super::KernelCtx::with_isa) and
    /// [`env_override`] assert this at construction — the invariant that
    /// a context never carries an unsupported arm is what lets the GEMM
    /// and fused dispatchers enter their `unsafe` `target_feature`
    /// bodies behind a `debug_assert` instead of a per-call probe.
    pub fn supported(self) -> bool {
        match self {
            Isa::Scalar => true,
            Isa::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            Isa::Neon => {
                #[cfg(target_arch = "aarch64")]
                {
                    std::arch::is_aarch64_feature_detected!("neon")
                }
                #[cfg(not(target_arch = "aarch64"))]
                {
                    false
                }
            }
        }
    }

    /// Every arm this host can run (scalar first). The per-arm parity
    /// suite iterates this, so coverage widens automatically on hosts
    /// with more ISA extensions.
    pub fn available() -> Vec<Isa> {
        [Isa::Scalar, Isa::Avx2, Isa::Neon]
            .into_iter()
            .filter(|i| i.supported())
            .collect()
    }
}

/// The `SSAF_KERNEL` environment override, if set. Empty and `auto`
/// mean "no override". An unknown token or an arm the host cannot run
/// is a hard panic: the override exists for debugging and the CI scalar
/// lane, where silently falling back would defeat the point.
pub fn env_override() -> Option<Isa> {
    let s = std::env::var("SSAF_KERNEL").ok()?;
    let t = s.trim();
    if t.is_empty() || t.eq_ignore_ascii_case("auto") {
        return None;
    }
    let isa = Isa::parse(t).unwrap_or_else(|| {
        panic!("SSAF_KERNEL={t}: unknown kernel arm (scalar|avx2|neon|auto)")
    });
    assert!(isa.supported(),
            "SSAF_KERNEL={t}: arm not supported on this host (available: {})",
            Isa::available().iter().map(|i| i.token())
                .collect::<Vec<_>>().join(","));
    Some(isa)
}

/// The arm new contexts run: `SSAF_KERNEL` override, else detection.
/// This is the probe the override tests assert through.
pub fn active_isa() -> Isa {
    env_override().unwrap_or_else(Isa::detect)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_round_trip() {
        for isa in [Isa::Scalar, Isa::Avx2, Isa::Neon] {
            assert_eq!(Isa::parse(isa.token()), Some(isa));
        }
        assert_eq!(Isa::parse("AVX2"), Some(Isa::Avx2));
        assert_eq!(Isa::parse("bogus"), None);
        assert_eq!(Isa::parse(""), None);
    }

    #[test]
    fn scalar_is_always_available() {
        assert!(Isa::Scalar.supported());
        let avail = Isa::available();
        assert_eq!(avail[0], Isa::Scalar);
        assert!(avail.contains(&Isa::detect()));
    }

    #[test]
    fn detected_arm_is_supported() {
        assert!(Isa::detect().supported());
    }
}
