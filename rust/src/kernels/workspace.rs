//! Reusable scratch-buffer arena for the kernel core.
//!
//! Kernels never allocate internally: every intermediate (packed
//! panels, logits blocks, factor matrices) is taken from a caller-owned
//! [`Workspace`] and returned to it. After the first call at a given
//! shape the arena's buffers have converged to their peak capacities and
//! steady-state serving performs **zero** heap allocations in the hot
//! path.

/// A pool of recyclable f32 buffers.
#[derive(Default)]
pub struct Workspace {
    free: Vec<Vec<f32>>,
    allocations: usize,
}

impl Workspace {
    pub fn new() -> Self {
        Workspace::default()
    }

    /// Take a zero-filled buffer of exactly `len` elements, reusing a
    /// pooled buffer when one is large enough. Best-fit (smallest
    /// adequate capacity) so that a fixed take/put sequence replays
    /// allocation-free: small requests never consume the large buffers
    /// a later request needs.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut slot: Option<usize> = None;
        for (i, b) in self.free.iter().enumerate() {
            if b.capacity() >= len
                && slot.map_or(true, |j| b.capacity() < self.free[j].capacity())
            {
                slot = Some(i);
            }
        }
        let mut buf = match slot {
            Some(i) => self.free.swap_remove(i),
            None => self.free.pop().unwrap_or_default(),
        };
        if buf.capacity() < len {
            self.allocations += 1;
        }
        buf.clear();
        buf.resize(len, 0.0);
        buf
    }

    /// Return a buffer to the pool for reuse.
    pub fn put(&mut self, buf: Vec<f32>) {
        if buf.capacity() > 0 {
            self.free.push(buf);
        }
    }

    /// Number of times `take` had to grow or allocate a buffer — stable
    /// across calls once the arena is warm (asserted in tests).
    pub fn allocations(&self) -> usize {
        self.allocations
    }

    /// Buffers currently pooled (diagnostics).
    pub fn pooled(&self) -> usize {
        self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zero_filled_even_after_reuse() {
        let mut ws = Workspace::new();
        let mut a = ws.take(16);
        a.iter_mut().for_each(|x| *x = 7.0);
        ws.put(a);
        let b = ws.take(8);
        assert_eq!(b.len(), 8);
        assert!(b.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn steady_state_stops_allocating() {
        let mut ws = Workspace::new();
        for _ in 0..3 {
            let a = ws.take(128);
            let b = ws.take(64);
            ws.put(a);
            ws.put(b);
        }
        let warm = ws.allocations();
        for _ in 0..10 {
            let a = ws.take(128);
            let b = ws.take(64);
            ws.put(a);
            ws.put(b);
        }
        assert_eq!(ws.allocations(), warm, "arena must not allocate once warm");
    }

    #[test]
    fn empty_take_works() {
        let mut ws = Workspace::new();
        let v = ws.take(0);
        assert!(v.is_empty());
        ws.put(v); // zero-capacity buffers are dropped, not pooled
        assert_eq!(ws.pooled(), 0);
    }
}
