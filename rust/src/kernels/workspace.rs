//! Reusable scratch-buffer arena for the kernel core.
//!
//! Kernels never allocate internally: every intermediate (packed
//! panels, logits blocks, factor matrices) is taken from a caller-owned
//! [`Workspace`] and returned to it. After the first call at a given
//! shape the arena's buffers have converged to their peak capacities and
//! steady-state serving performs **zero** heap allocations in the hot
//! path.

/// A pool of recyclable f32 buffers.
#[derive(Default)]
pub struct Workspace {
    free: Vec<Vec<f32>>,
    allocations: usize,
}

impl Workspace {
    pub fn new() -> Self {
        Workspace::default()
    }

    /// Take a zero-filled buffer of exactly `len` elements, reusing a
    /// pooled buffer when one is large enough. Best-fit (smallest
    /// adequate capacity) so that a fixed take/put sequence replays
    /// allocation-free: small requests never consume the large buffers
    /// a later request needs.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut slot: Option<usize> = None;
        for (i, b) in self.free.iter().enumerate() {
            if b.capacity() >= len
                && slot.map_or(true, |j| b.capacity() < self.free[j].capacity())
            {
                slot = Some(i);
            }
        }
        let mut buf = match slot {
            Some(i) => self.free.swap_remove(i),
            None => self.free.pop().unwrap_or_default(),
        };
        if buf.capacity() < len {
            self.allocations += 1;
        }
        buf.clear();
        buf.resize(len, 0.0);
        buf
    }

    /// Pre-plan the arena for a known peak working set: take every
    /// buffer in `sizes` simultaneously, then return them all. After a
    /// plan, any take/put sequence whose concurrent demand is covered by
    /// `sizes` (element-wise) replays allocation-free — the encoder
    /// stack plans its per-layer activations this way at engine start,
    /// so even the *first* batch at the planned shape allocates nothing.
    pub fn plan(&mut self, sizes: &[usize]) {
        let bufs: Vec<Vec<f32>> = sizes.iter().map(|&s| self.take(s)).collect();
        for b in bufs {
            self.put(b);
        }
    }

    /// Return a buffer to the pool for reuse.
    pub fn put(&mut self, buf: Vec<f32>) {
        if buf.capacity() > 0 {
            self.free.push(buf);
        }
    }

    /// Number of times `take` had to grow or allocate a buffer — stable
    /// across calls once the arena is warm (asserted in tests).
    pub fn allocations(&self) -> usize {
        self.allocations
    }

    /// Buffers currently pooled (diagnostics).
    pub fn pooled(&self) -> usize {
        self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zero_filled_even_after_reuse() {
        let mut ws = Workspace::new();
        let mut a = ws.take(16);
        a.iter_mut().for_each(|x| *x = 7.0);
        ws.put(a);
        let b = ws.take(8);
        assert_eq!(b.len(), 8);
        assert!(b.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn steady_state_stops_allocating() {
        let mut ws = Workspace::new();
        for _ in 0..3 {
            let a = ws.take(128);
            let b = ws.take(64);
            ws.put(a);
            ws.put(b);
        }
        let warm = ws.allocations();
        for _ in 0..10 {
            let a = ws.take(128);
            let b = ws.take(64);
            ws.put(a);
            ws.put(b);
        }
        assert_eq!(ws.allocations(), warm, "arena must not allocate once warm");
    }

    #[test]
    fn planned_arena_serves_first_use_allocation_free() {
        let mut ws = Workspace::new();
        ws.plan(&[128, 128, 64, 32]);
        let planned = ws.allocations();
        // a workload whose concurrent demand fits the plan: no growth,
        // even on the very first replay
        for _ in 0..5 {
            let a = ws.take(128);
            let b = ws.take(100); // served by the second 128 slot
            let c = ws.take(64);
            let d = ws.take(17);
            ws.put(a);
            ws.put(b);
            ws.put(c);
            ws.put(d);
        }
        assert_eq!(ws.allocations(), planned, "planned shapes must not allocate");
        // demand beyond the plan still works (and is counted)
        let big = ws.take(4096);
        assert_eq!(ws.allocations(), planned + 1);
        ws.put(big);
    }

    #[test]
    fn empty_take_works() {
        let mut ws = Workspace::new();
        let v = ws.take(0);
        assert!(v.is_empty());
        ws.put(v); // zero-capacity buffers are dropped, not pooled
        assert_eq!(ws.pooled(), 0);
    }
}
