//! Cache-blocked, multi-threaded f32 compute core — the serving fast
//! path for every attention variant.
//!
//! The paper's O(n) claim only wins wall-clock when the constant
//! factors are engineered down (the same argument Linformer makes with
//! benches), so the hot-path kernels live here instead of in per-variant
//! scalar loops:
//!
//! * [`gemm::gemm_into`] — tiled GEMM: fixed 32-row parallel blocks,
//!   256-deep k panels packed per micro-panel, L2-resident column
//!   panels, and a per-[`Isa`] register tile (scalar 4×8, AVX2 8×8 FMA,
//!   NEON 4×4 FMA). Row-major, allocation-free.
//! * [`fused::softmax_gemm`] — rowsoftmax(scale·Q·K̃ᵀ)·X without
//!   materializing the n×c logits (per-block scratch only).
//! * [`fused::flash_attention`] — exact attention with the online
//!   softmax streamed over key blocks, row-parallel.
//! * [`batched::BatchedAttention`] — multi-head / multi-request fan-out
//!   over the pool, one workspace slot per in-flight task.
//! * [`quant::gemm_quant_into`] — bf16/int8 weight tiers (quantized
//!   once at load) expanded into workspace scratch and run through the
//!   same blocked GEMM with f32 accumulation, so precision is a
//!   serving-policy knob rather than a separate kernel family.
//!
//! Threading runs on the crate's own [`crate::minirt::ThreadPool`]
//! (shared process-wide handle, see [`global_pool`]); work is split into
//! *fixed-size row blocks* so the floating-point reduction order per
//! output row is identical for 1 and N threads — results are bitwise
//! deterministic across thread counts (property-tested in
//! `tests/kernel_parity.rs`).
//!
//! Scratch memory comes from a caller-provided [`Workspace`] arena:
//! buffers are recycled across calls, so steady-state serving performs
//! zero heap allocations inside the kernels.
//!
//! The naive scalar kernels ([`crate::attention::matmul_f32`] and the
//! seed implementations preserved in
//! [`crate::attention::spectral_shift::reference`]) remain in-tree as
//! the reference path the fast path is property-tested against.
//!
//! # Invariants
//!
//! * **Bitwise thread-count determinism (per arm)** — work splits into
//!   [`BLOCK_ROWS`]-sized blocks whose boundaries are a pure function
//!   of the problem shape (never the pool size), and the k dimension is
//!   never split, so each output element's floating-point reduction
//!   order — and therefore every bit of the result — is identical for 1
//!   and N threads (`tests/kernel_parity.rs`). The guarantee holds
//!   *within* a micro-kernel arm: the SIMD arms contract mul+add into
//!   FMA, so they differ from the scalar arm in the last ulps (each arm
//!   is property-tested against the seed references at 1e-4; see
//!   [`isa`]).
//! * **Zero steady-state allocation** — all scratch comes from a
//!   caller-owned [`Workspace`]; after a warmup call at a given shape,
//!   repeated calls allocate nothing (asserted by `allocations()`
//!   plateau tests across the kernel and serving layers):
//!
//! ```
//! use ssaformer::kernels::Workspace;
//! let mut ws = Workspace::new();
//! for _ in 0..3 { let b = ws.take(256); ws.put(b); } // warm up
//! let warm = ws.allocations();
//! for _ in 0..100 { let b = ws.take(256); ws.put(b); }
//! assert_eq!(ws.allocations(), warm); // steady state: zero new allocs
//! ```
//!
//! * **Sequential nesting under fan-out** — [`batched::BatchedAttention`]
//!   runs each task with a sequential [`KernelCtx`]: the batch dimension
//!   saturates the pool, avoiding pool-in-pool deadlock and preserving
//!   the determinism contract.

pub mod batched;
pub mod fused;
pub mod gemm;
pub mod isa;
pub mod quant;
pub(crate) mod simd;
pub mod workspace;

pub use batched::{
    attention_batched, attention_batched_self, attention_batched_self_pooled,
    AttnTask, BatchedAttention, BatchedVariant,
};
pub use fused::{
    bias_gelu, flash_attention, gelu, layernorm, softmax_gemm, softmax_scores,
};
pub use gemm::{gemm_f32, gemm_into, transpose_into};
pub use isa::{active_isa, Isa};
pub use quant::{gemm_quant_into, Precision, QuantMatrix};
pub use workspace::Workspace;

use crate::minirt::ThreadPool;
use std::sync::{Arc, OnceLock};

/// Rows per parallel block. Fixed (never derived from the thread count)
/// so block boundaries — and therefore per-row reduction order — do not
/// depend on parallelism.
pub const BLOCK_ROWS: usize = 32;

static GLOBAL_POOL: OnceLock<Arc<ThreadPool>> = OnceLock::new();

/// The process-wide kernel pool, shared by every attention variant and
/// the serving coordinator. Sized from `SSAFORMER_THREADS` when set,
/// otherwise from the machine's available parallelism. Created lazily
/// on first use and lives for the life of the process.
pub fn global_pool() -> Arc<ThreadPool> {
    GLOBAL_POOL
        .get_or_init(|| {
            let threads = std::env::var("SSAFORMER_THREADS")
                .ok()
                .and_then(|s| s.parse::<usize>().ok())
                .filter(|&n| n > 0)
                .unwrap_or_else(|| {
                    std::thread::available_parallelism()
                        .map(|n| n.get())
                        .unwrap_or(1)
                });
            Arc::new(ThreadPool::new(threads))
        })
        .clone()
}

/// Execution context handed to every kernel: either sequential or a
/// handle to a (shared) thread pool, plus the micro-kernel [`Isa`] arm
/// the kernels dispatch on. Constructors resolve the arm from
/// [`active_isa`] (`SSAF_KERNEL` override, else hardware detection);
/// [`KernelCtx::with_isa`] pins an explicit arm — the per-arm parity
/// tests and the `[serving] kernel` knob go through it.
#[derive(Clone)]
pub struct KernelCtx {
    pool: Option<Arc<ThreadPool>>,
    isa: Isa,
}

impl KernelCtx {
    /// Single-threaded execution (also used inside batched tasks, where
    /// the outer fan-out already owns the pool).
    pub fn sequential() -> Self {
        KernelCtx { pool: None, isa: active_isa() }
    }

    /// Run on an explicit pool handle.
    pub fn with_pool(pool: Arc<ThreadPool>) -> Self {
        KernelCtx { pool: Some(pool), isa: active_isa() }
    }

    /// Run on the shared process-wide pool.
    pub fn global() -> Self {
        KernelCtx::with_pool(global_pool())
    }

    /// Pin this context to an explicit micro-kernel arm (builder style).
    /// Panics when the host cannot execute the arm — a `KernelCtx`
    /// never carries an unsupported `Isa`, which is what lets the
    /// kernels enter their `target_feature` bodies without per-call
    /// feature probes.
    pub fn with_isa(mut self, isa: Isa) -> Self {
        assert!(isa.supported(),
                "kernel arm {} not supported on this host (available: {})",
                isa.token(),
                Isa::available().iter().map(|i| i.token())
                    .collect::<Vec<_>>().join(","));
        self.isa = isa;
        self
    }

    /// The micro-kernel arm this context dispatches to.
    pub fn isa(&self) -> Isa {
        self.isa
    }

    /// Parallel lanes this context can use (workers + the caller).
    pub fn threads(&self) -> usize {
        match &self.pool {
            Some(pool) => pool.size() + 1,
            None => 1,
        }
    }

    /// Run `tasks` closures, on the pool when available.
    pub(crate) fn run_tasks(&self, tasks: usize, f: impl Fn(usize) + Sync) {
        match &self.pool {
            Some(pool) if tasks > 1 => pool.scope_for(tasks, f),
            _ => {
                for i in 0..tasks {
                    f(i);
                }
            }
        }
    }

    /// Number of tasks a blocked loop over `nblocks` will fan out to.
    pub(crate) fn task_count(&self, nblocks: usize) -> usize {
        self.threads().min(nblocks).max(1)
    }

    /// Partition `nblocks` fixed-size blocks into contiguous per-task
    /// ranges and run them. `f` receives `(task_index, block_range)`;
    /// the task index addresses per-task scratch. Block boundaries are a
    /// pure function of the problem shape, so per-row arithmetic is
    /// independent of the thread count.
    pub(crate) fn run_blocks(
        &self,
        nblocks: usize,
        f: impl Fn(usize, std::ops::Range<usize>) + Sync,
    ) {
        if nblocks == 0 {
            return;
        }
        let ntasks = self.task_count(nblocks);
        let per_task = (nblocks + ntasks - 1) / ntasks;
        self.run_tasks(ntasks, |t| {
            let lo = t * per_task;
            let hi = ((t + 1) * per_task).min(nblocks);
            if lo < hi {
                f(t, lo..hi);
            }
        });
    }
}

/// Covariant `*mut T` wrapper so fork-join tasks can write disjoint
/// regions of a caller-owned buffer. Soundness contract: tasks touch
/// non-overlapping index ranges and the buffer outlives the fork-join
/// (guaranteed by `ThreadPool::scope_for` blocking until completion).
#[derive(Clone, Copy)]
pub(crate) struct SendMut<T>(pub *mut T);

unsafe impl<T> Send for SendMut<T> {}
unsafe impl<T> Sync for SendMut<T> {}

/// Parallel loop over the rows of a row-major `rows × cols` buffer.
/// Each row is handed to `f` exactly once as `(row_index, row_slice)`;
/// rows are grouped into [`BLOCK_ROWS`]-sized blocks per task.
pub(crate) fn par_rows(
    ctx: &KernelCtx,
    data: &mut [f32],
    rows: usize,
    cols: usize,
    f: impl Fn(usize, &mut [f32]) + Sync,
) {
    assert_eq!(data.len(), rows * cols);
    if rows == 0 {
        return;
    }
    let nblocks = (rows + BLOCK_ROWS - 1) / BLOCK_ROWS;
    let base = SendMut(data.as_mut_ptr());
    ctx.run_blocks(nblocks, |_task, blocks| {
        for b in blocks {
            let r0 = b * BLOCK_ROWS;
            let r1 = (r0 + BLOCK_ROWS).min(rows);
            for r in r0..r1 {
                // SAFETY: blocks partition 0..rows disjointly; `data`
                // outlives the fork-join.
                let row = unsafe {
                    std::slice::from_raw_parts_mut(base.0.add(r * cols), cols)
                };
                f(r, row);
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_pool_is_shared() {
        let a = global_pool();
        let b = global_pool();
        assert!(Arc::ptr_eq(&a, &b));
        assert!(a.size() >= 1);
    }

    #[test]
    fn sequential_ctx_has_one_thread() {
        assert_eq!(KernelCtx::sequential().threads(), 1);
        assert!(KernelCtx::global().threads() >= 2);
    }

    #[test]
    fn ctx_carries_a_pinned_arm() {
        // default arm is the resolved process arm; with_isa pins any
        // supported arm (scalar is always one)
        assert_eq!(KernelCtx::sequential().isa(), active_isa());
        let ctx = KernelCtx::global().with_isa(Isa::Scalar);
        assert_eq!(ctx.isa(), Isa::Scalar);
        for isa in Isa::available() {
            assert_eq!(KernelCtx::sequential().with_isa(isa).isa(), isa);
        }
    }

    #[test]
    fn par_rows_touches_every_row_once() {
        for rows in [0usize, 1, 31, 32, 33, 100] {
            let cols = 5;
            let mut data = vec![0.0f32; rows * cols];
            par_rows(&KernelCtx::global(), &mut data, rows, cols, |r, row| {
                for x in row.iter_mut() {
                    *x += (r + 1) as f32;
                }
            });
            for r in 0..rows {
                for c in 0..cols {
                    assert_eq!(data[r * cols + c], (r + 1) as f32);
                }
            }
        }
    }

    #[test]
    fn run_blocks_partitions_disjointly() {
        let ctx = KernelCtx::global();
        let nblocks = 37;
        let hits: Vec<std::sync::atomic::AtomicUsize> =
            (0..nblocks).map(|_| std::sync::atomic::AtomicUsize::new(0)).collect();
        ctx.run_blocks(nblocks, |_t, range| {
            for b in range {
                hits[b].fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(std::sync::atomic::Ordering::Relaxed) == 1));
    }
}
