//! Per-ISA vector primitives for the fused kernels: dot, axpy, and the
//! layernorm / bias-add row bodies. The GEMM micro-kernels live next to
//! their packing logic in [`super::gemm`]; this module covers the
//! row-shaped work (`flash_attention` score/value loops, `layernorm`
//! moments + affine, `bias_gelu` bias add).
//!
//! # Safety contract
//!
//! The `Avx2`/`Neon` arms enter `#[target_feature]` bodies. Callers
//! pass an [`Isa`] obtained from a [`KernelCtx`](super::KernelCtx),
//! which verifies [`Isa::supported`] at construction (`with_isa`
//! asserts; `active_isa` only yields supported arms) — so dispatch here
//! is a plain match with a `debug_assert`, not a per-call feature probe
//! in the hot loop.
//!
//! # Determinism
//!
//! Scalar arms are byte-for-byte the pre-dispatch implementations. SIMD
//! arms keep a *fixed* reduction order (lane accumulators combined in a
//! hardcoded pairing, then a left-to-right tail), so results are
//! bitwise-invariant across thread counts within an arm; FMA contraction
//! makes them differ from scalar in the last ulps (≤ the 1e-4 envelope,
//! property-tested per arm).

use super::gemm::axpy8;
use super::isa::Isa;

/// f32 dot product on the selected arm.
#[inline(always)]
pub(crate) fn dot(isa: Isa, a: &[f32], b: &[f32]) -> f32 {
    debug_assert!(isa.supported());
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: ctx-carried arms are verified supported (module docs).
        Isa::Avx2 => unsafe { x86::dot(a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above.
        Isa::Neon => unsafe { arm::dot(a, b) },
        _ => dot_scalar(a, b),
    }
}

/// c += w·b on the selected arm.
#[inline(always)]
pub(crate) fn axpy(isa: Isa, c: &mut [f32], w: f32, b: &[f32]) {
    debug_assert!(isa.supported());
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: ctx-carried arms are verified supported (module docs).
        Isa::Avx2 => unsafe { x86::axpy(c, w, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above.
        Isa::Neon => unsafe { arm::axpy(c, w, b) },
        _ => axpy8(c, w, b),
    }
}

/// Row mean and variance (biased, /n) for layernorm. The scalar arm is
/// the seed single-accumulator left-to-right pass; SIMD arms accumulate
/// lane-wise with the fixed horizontal pairing.
#[inline(always)]
pub(crate) fn moments(isa: Isa, x: &[f32]) -> (f32, f32) {
    debug_assert!(isa.supported());
    let n = x.len().max(1) as f32;
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: ctx-carried arms are verified supported (module docs).
        Isa::Avx2 => unsafe {
            let mean = x86::sum(x) / n;
            (mean, x86::centered_sumsq(x, mean) / n)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above.
        Isa::Neon => unsafe {
            let mean = arm::sum(x) / n;
            (mean, arm::centered_sumsq(x, mean) / n)
        },
        _ => {
            let mut mean = 0.0f32;
            for &v in x {
                mean += v;
            }
            mean /= n;
            let mut var = 0.0f32;
            for &v in x {
                let c = v - mean;
                var += c * c;
            }
            (mean, var / n)
        }
    }
}

/// Layernorm affine: o[j] = (x[j] − mean)·inv·gain[j] + bias[j].
#[inline(always)]
pub(crate) fn ln_affine(isa: Isa, o: &mut [f32], x: &[f32], mean: f32,
                        inv: f32, gain: &[f32], bias: &[f32]) {
    debug_assert!(isa.supported());
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: ctx-carried arms are verified supported (module docs).
        Isa::Avx2 => unsafe { x86::ln_affine(o, x, mean, inv, gain, bias) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above.
        Isa::Neon => unsafe { arm::ln_affine(o, x, mean, inv, gain, bias) },
        _ => {
            for (j, oj) in o.iter_mut().enumerate() {
                *oj = (x[j] - mean) * inv * gain[j] + bias[j];
            }
        }
    }
}

/// row[j] += bias[j]. A single-rounding add in every arm, so the result
/// is bitwise arm-invariant (the GELU that follows stays scalar).
#[inline(always)]
pub(crate) fn add_bias(isa: Isa, row: &mut [f32], bias: &[f32]) {
    debug_assert!(isa.supported());
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: ctx-carried arms are verified supported (module docs).
        Isa::Avx2 => unsafe { x86::add_bias(row, bias) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above.
        Isa::Neon => unsafe { arm::add_bias(row, bias) },
        _ => {
            for (v, &b) in row.iter_mut().zip(bias) {
                *v += b;
            }
        }
    }
}

/// f32 dot product, 8-wide unrolled — the scalar arm (kernel-core
/// counterpart of the reference `attention::dot_f32`; kept separate so
/// the reference path stays byte-for-byte the seed implementation).
#[inline(always)]
pub(crate) fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut acc = [0.0f32; 8];
    let mut i = 0;
    while i + 8 <= n {
        let aj = &a[i..i + 8];
        let bj = &b[i..i + 8];
        for t in 0..8 {
            acc[t] += aj[t] * bj[t];
        }
        i += 8;
    }
    let mut s = ((acc[0] + acc[4]) + (acc[1] + acc[5]))
        + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    /// Fixed-pairing horizontal sum of one 256-bit accumulator: the
    /// same (l0+l4)+(l1+l5) … tree the scalar arm uses, so the reduce
    /// order is a constant of the arm.
    ///
    /// SAFETY: caller runs under avx2.
    #[target_feature(enable = "avx2")]
    unsafe fn hsum(v: __m256) -> f32 {
        let mut l = [0.0f32; 8];
        _mm256_storeu_ps(l.as_mut_ptr(), v);
        ((l[0] + l[4]) + (l[1] + l[5])) + ((l[2] + l[6]) + (l[3] + l[7]))
    }

    /// SAFETY: caller verified avx2+fma support.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let (ap, bp) = (a.as_ptr(), b.as_ptr());
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i + 8 <= n {
            acc = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)),
                                  _mm256_loadu_ps(bp.add(i)), acc);
            i += 8;
        }
        let mut s = hsum(acc);
        while i < n {
            s = (*ap.add(i)).mul_add(*bp.add(i), s);
            i += 1;
        }
        s
    }

    /// SAFETY: caller verified avx2+fma support.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn axpy(c: &mut [f32], w: f32, b: &[f32]) {
        debug_assert_eq!(c.len(), b.len());
        let n = c.len();
        let (cp, bp) = (c.as_mut_ptr(), b.as_ptr());
        let wv = _mm256_set1_ps(w);
        let mut j = 0;
        while j + 8 <= n {
            let cv = _mm256_fmadd_ps(wv, _mm256_loadu_ps(bp.add(j)),
                                     _mm256_loadu_ps(cp.add(j)));
            _mm256_storeu_ps(cp.add(j), cv);
            j += 8;
        }
        while j < n {
            *cp.add(j) = w.mul_add(*bp.add(j), *cp.add(j));
            j += 1;
        }
    }

    /// SAFETY: caller verified avx2 support.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn sum(x: &[f32]) -> f32 {
        let n = x.len();
        let xp = x.as_ptr();
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i + 8 <= n {
            acc = _mm256_add_ps(acc, _mm256_loadu_ps(xp.add(i)));
            i += 8;
        }
        let mut s = hsum(acc);
        while i < n {
            s += *xp.add(i);
            i += 1;
        }
        s
    }

    /// Σ (x[i] − mean)² with lane accumulators.
    ///
    /// SAFETY: caller verified avx2+fma support.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn centered_sumsq(x: &[f32], mean: f32) -> f32 {
        let n = x.len();
        let xp = x.as_ptr();
        let mv = _mm256_set1_ps(mean);
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i + 8 <= n {
            let d = _mm256_sub_ps(_mm256_loadu_ps(xp.add(i)), mv);
            acc = _mm256_fmadd_ps(d, d, acc);
            i += 8;
        }
        let mut s = hsum(acc);
        while i < n {
            let d = *xp.add(i) - mean;
            s = d.mul_add(d, s);
            i += 1;
        }
        s
    }

    /// SAFETY: caller verified avx2+fma support.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn ln_affine(o: &mut [f32], x: &[f32], mean: f32,
                                   inv: f32, gain: &[f32], bias: &[f32]) {
        let n = o.len();
        debug_assert!(x.len() == n && gain.len() == n && bias.len() == n);
        let (op, xp, gp, bp) =
            (o.as_mut_ptr(), x.as_ptr(), gain.as_ptr(), bias.as_ptr());
        let mv = _mm256_set1_ps(mean);
        let iv = _mm256_set1_ps(inv);
        let mut j = 0;
        while j + 8 <= n {
            let t = _mm256_mul_ps(_mm256_sub_ps(_mm256_loadu_ps(xp.add(j)), mv), iv);
            let ov = _mm256_fmadd_ps(t, _mm256_loadu_ps(gp.add(j)),
                                     _mm256_loadu_ps(bp.add(j)));
            _mm256_storeu_ps(op.add(j), ov);
            j += 8;
        }
        while j < n {
            let t = (*xp.add(j) - mean) * inv;
            *op.add(j) = t.mul_add(*gp.add(j), *bp.add(j));
            j += 1;
        }
    }

    /// SAFETY: caller verified avx2 support.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn add_bias(row: &mut [f32], bias: &[f32]) {
        debug_assert_eq!(row.len(), bias.len());
        let n = row.len();
        let (rp, bp) = (row.as_mut_ptr(), bias.as_ptr());
        let mut j = 0;
        while j + 8 <= n {
            let v = _mm256_add_ps(_mm256_loadu_ps(rp.add(j)),
                                  _mm256_loadu_ps(bp.add(j)));
            _mm256_storeu_ps(rp.add(j), v);
            j += 8;
        }
        while j < n {
            *rp.add(j) += *bp.add(j);
            j += 1;
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod arm {
    use std::arch::aarch64::*;

    /// Fixed-pairing horizontal sum of one 128-bit accumulator.
    ///
    /// SAFETY: caller runs under neon.
    #[target_feature(enable = "neon")]
    unsafe fn hsum(v: float32x4_t) -> f32 {
        let mut l = [0.0f32; 4];
        vst1q_f32(l.as_mut_ptr(), v);
        (l[0] + l[2]) + (l[1] + l[3])
    }

    /// SAFETY: caller verified neon support.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let (ap, bp) = (a.as_ptr(), b.as_ptr());
        let mut acc = vdupq_n_f32(0.0);
        let mut i = 0;
        while i + 4 <= n {
            acc = vfmaq_f32(acc, vld1q_f32(ap.add(i)), vld1q_f32(bp.add(i)));
            i += 4;
        }
        let mut s = hsum(acc);
        while i < n {
            s = (*ap.add(i)).mul_add(*bp.add(i), s);
            i += 1;
        }
        s
    }

    /// SAFETY: caller verified neon support.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn axpy(c: &mut [f32], w: f32, b: &[f32]) {
        debug_assert_eq!(c.len(), b.len());
        let n = c.len();
        let (cp, bp) = (c.as_mut_ptr(), b.as_ptr());
        let wv = vdupq_n_f32(w);
        let mut j = 0;
        while j + 4 <= n {
            let cv = vfmaq_f32(vld1q_f32(cp.add(j)), wv, vld1q_f32(bp.add(j)));
            vst1q_f32(cp.add(j), cv);
            j += 4;
        }
        while j < n {
            *cp.add(j) = w.mul_add(*bp.add(j), *cp.add(j));
            j += 1;
        }
    }

    /// SAFETY: caller verified neon support.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn sum(x: &[f32]) -> f32 {
        let n = x.len();
        let xp = x.as_ptr();
        let mut acc = vdupq_n_f32(0.0);
        let mut i = 0;
        while i + 4 <= n {
            acc = vaddq_f32(acc, vld1q_f32(xp.add(i)));
            i += 4;
        }
        let mut s = hsum(acc);
        while i < n {
            s += *xp.add(i);
            i += 1;
        }
        s
    }

    /// Σ (x[i] − mean)² with lane accumulators.
    ///
    /// SAFETY: caller verified neon support.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn centered_sumsq(x: &[f32], mean: f32) -> f32 {
        let n = x.len();
        let xp = x.as_ptr();
        let mv = vdupq_n_f32(mean);
        let mut acc = vdupq_n_f32(0.0);
        let mut i = 0;
        while i + 4 <= n {
            let d = vsubq_f32(vld1q_f32(xp.add(i)), mv);
            acc = vfmaq_f32(acc, d, d);
            i += 4;
        }
        let mut s = hsum(acc);
        while i < n {
            let d = *xp.add(i) - mean;
            s = d.mul_add(d, s);
            i += 1;
        }
        s
    }

    /// SAFETY: caller verified neon support.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn ln_affine(o: &mut [f32], x: &[f32], mean: f32,
                                   inv: f32, gain: &[f32], bias: &[f32]) {
        let n = o.len();
        debug_assert!(x.len() == n && gain.len() == n && bias.len() == n);
        let (op, xp, gp, bp) =
            (o.as_mut_ptr(), x.as_ptr(), gain.as_ptr(), bias.as_ptr());
        let mv = vdupq_n_f32(mean);
        let iv = vdupq_n_f32(inv);
        let mut j = 0;
        while j + 4 <= n {
            let t = vmulq_f32(vsubq_f32(vld1q_f32(xp.add(j)), mv), iv);
            let ov = vfmaq_f32(vld1q_f32(bp.add(j)), t, vld1q_f32(gp.add(j)));
            vst1q_f32(op.add(j), ov);
            j += 4;
        }
        while j < n {
            let t = (*xp.add(j) - mean) * inv;
            *op.add(j) = t.mul_add(*gp.add(j), *bp.add(j));
            j += 1;
        }
    }

    /// SAFETY: caller verified neon support.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn add_bias(row: &mut [f32], bias: &[f32]) {
        debug_assert_eq!(row.len(), bias.len());
        let n = row.len();
        let (rp, bp) = (row.as_mut_ptr(), bias.as_ptr());
        let mut j = 0;
        while j + 4 <= n {
            vst1q_f32(rp.add(j), vaddq_f32(vld1q_f32(rp.add(j)),
                                           vld1q_f32(bp.add(j))));
            j += 4;
        }
        while j < n {
            *rp.add(j) += *bp.add(j);
            j += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::Tensor2;
    use crate::rngx::Rng;

    #[test]
    fn dot_matches_naive_on_every_arm() {
        let mut rng = Rng::new(6);
        for isa in Isa::available() {
            for n in [0usize, 1, 3, 7, 8, 9, 16, 31, 64] {
                let a = Tensor2::randn(&mut rng, 1, n.max(1), 1.0);
                let b = Tensor2::randn(&mut rng, 1, n.max(1), 1.0);
                let (a, b) = (&a.data[..n], &b.data[..n]);
                let want: f64 =
                    a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum();
                let got = dot(isa, a, b) as f64;
                assert!((got - want).abs() < 1e-4, "{}: n={n}", isa.token());
            }
        }
    }

    #[test]
    fn axpy_matches_naive_on_every_arm() {
        let mut rng = Rng::new(7);
        for isa in Isa::available() {
            for n in [1usize, 5, 8, 13, 32] {
                let b = Tensor2::randn(&mut rng, 1, n, 1.0);
                let mut c = vec![1.0f32; n];
                let mut want = vec![1.0f32; n];
                axpy(isa, &mut c, 0.5, &b.data);
                for (w, &x) in want.iter_mut().zip(&b.data) {
                    *w += 0.5 * x;
                }
                for (g, w) in c.iter().zip(&want) {
                    assert!((g - w).abs() < 1e-5, "{}: n={n}", isa.token());
                }
            }
        }
    }

    #[test]
    fn moments_match_scalar_on_every_arm() {
        let mut rng = Rng::new(8);
        let x = Tensor2::randn(&mut rng, 1, 37, 2.0);
        let (m0, v0) = moments(Isa::Scalar, &x.data);
        for isa in Isa::available() {
            let (m, v) = moments(isa, &x.data);
            assert!((m - m0).abs() < 1e-5 && (v - v0).abs() < 1e-4,
                    "{}: mean {m} vs {m0}, var {v} vs {v0}", isa.token());
        }
    }

    #[test]
    fn add_bias_is_bitwise_arm_invariant() {
        let mut rng = Rng::new(9);
        let base = Tensor2::randn(&mut rng, 1, 21, 1.0);
        let bias = Tensor2::randn(&mut rng, 1, 21, 1.0);
        let mut want = base.data.clone();
        add_bias(Isa::Scalar, &mut want, &bias.data);
        for isa in Isa::available() {
            let mut got = base.data.clone();
            add_bias(isa, &mut got, &bias.data);
            assert_eq!(got, want, "{}", isa.token());
        }
    }
}
