//! Batched multi-head attention: fan a popped serving batch out over
//! the kernel pool so heads × requests execute in parallel instead of
//! serially.
//!
//! Each in-flight task owns one [`Workspace`] slot (recycled across
//! batches → zero steady-state allocations inside the kernels) and runs
//! its head with a *sequential* [`KernelCtx`]: the batch dimension
//! already saturates the pool, and keeping nested work sequential both
//! avoids pool-in-pool deadlock and preserves bitwise determinism.
//!
//! Since the encoder-stack refactor the executor dispatches through the
//! [`AttentionOp`] seam: [`BatchedAttention::run`] takes any
//! `&dyn AttentionOp`, and [`BatchedVariant`] — the Copy-able serving
//! configuration covering all six variants — implements the trait by
//! building the matching op value on the stack (no allocation) and
//! delegating, so config-driven callers and hand-built ops share one
//! code path.

use super::workspace::Workspace;
use super::{KernelCtx, SendMut};
use crate::attention::spectral_shift::SpectralShiftConfig;
use crate::attention::{
    FullOp, LinformerOp, LshOp, NystromOp, SparseOp, SpectralShiftOp, Tensor2,
};
use crate::config::Variant;
use crate::model::AttentionOp;

/// One attention problem: a single head of a single request.
pub struct AttnTask {
    pub q: Tensor2,
    pub k: Tensor2,
    pub v: Tensor2,
}

/// Which attention kernel a batch executes — the Copy-able serving-side
/// configuration for every variant in Table 1. Implements
/// [`AttentionOp`] by delegating to the per-variant op structs, so a
/// `BatchedVariant` can be passed anywhere `&dyn AttentionOp` is
/// expected.
#[derive(Clone, Copy, Debug)]
pub enum BatchedVariant {
    /// Exact softmax attention (flash streaming).
    Full,
    /// Nystromformer with `landmarks` and `pinv_iters`.
    Nystrom { landmarks: usize, pinv_iters: usize },
    /// Spectral shifting (the paper's method).
    SpectralShift(SpectralShiftConfig),
    /// Linformer sequence-axis projection to `kdim` rows.
    Linformer { kdim: usize, seed: u64 },
    /// Reformer-style LSH bucketing (reference-grade scalar op).
    Lsh { rounds: usize, bits: Option<usize>, seed: u64 },
    /// Local+strided sparse pattern (reference-grade scalar op).
    Sparse { window: Option<usize>, stride: Option<usize> },
}

/// Fixed projection/hash seed for the serving-side Linformer and LSH
/// baselines: like the CPU model's embedding-table seed, it is part of
/// the served function, not a tunable.
const BASELINE_SEED: u64 = 0x55a_f0e2;

impl BatchedVariant {
    /// Map a serving-config variant onto its kernel. `landmarks` doubles
    /// as the Linformer projection dimension so every O(n) baseline runs
    /// at the same rank budget c (the comparison Table 1 makes);
    /// `pinv_iters` only affects the landmark variants.
    pub fn from_config(variant: Variant, landmarks: usize, pinv_iters: usize) -> Self {
        match variant {
            Variant::Full => BatchedVariant::Full,
            Variant::Nystrom => BatchedVariant::Nystrom { landmarks, pinv_iters },
            Variant::SpectralShift => {
                let mut cfg = SpectralShiftConfig::new(landmarks);
                cfg.pinv_iters = pinv_iters;
                BatchedVariant::SpectralShift(cfg)
            }
            Variant::Linformer => {
                BatchedVariant::Linformer { kdim: landmarks, seed: BASELINE_SEED }
            }
            Variant::Lsh => {
                BatchedVariant::Lsh { rounds: 2, bits: None, seed: BASELINE_SEED }
            }
            Variant::Sparse => {
                BatchedVariant::Sparse { window: None, stride: None }
            }
        }
    }

    /// Build the op value this configuration denotes and hand it to `f`
    /// — the single enum→op construction point. `name`, `attend` and
    /// `landmark_divisor` all delegate through here, so metrics keys
    /// can never desynchronize from the kernels actually executed.
    fn with_op<R>(&self, f: impl FnOnce(&dyn AttentionOp) -> R) -> R {
        match *self {
            BatchedVariant::Full => f(&FullOp),
            BatchedVariant::Nystrom { landmarks, pinv_iters } => {
                f(&NystromOp { landmarks, pinv_iters })
            }
            BatchedVariant::SpectralShift(cfg) => f(&SpectralShiftOp(cfg)),
            BatchedVariant::Linformer { kdim, seed } => {
                f(&LinformerOp { kdim, seed })
            }
            BatchedVariant::Lsh { rounds, bits, seed } => {
                f(&LshOp { rounds, bits, seed })
            }
            BatchedVariant::Sparse { window, stride } => {
                f(&SparseOp { window, stride })
            }
        }
    }
}

impl AttentionOp for BatchedVariant {
    fn name(&self) -> &'static str {
        self.with_op(|op| op.name())
    }

    fn landmark_divisor(&self) -> Option<usize> {
        self.with_op(|op| op.landmark_divisor())
    }

    fn attend(&self, ctx: &KernelCtx, q: &Tensor2, k: &Tensor2, v: &Tensor2,
              ws: &mut Workspace) -> Tensor2 {
        self.with_op(|op| op.attend(ctx, q, k, v, ws))
    }
}

/// Executor that owns the per-slot workspaces between batches.
pub struct BatchedAttention {
    ctx: KernelCtx,
    slots: Vec<Workspace>,
    /// head split/stitch scratch for [`attention_batched`]
    ws_main: Workspace,
}

impl BatchedAttention {
    pub fn new(ctx: KernelCtx) -> Self {
        BatchedAttention { ctx, slots: Vec::new(), ws_main: Workspace::new() }
    }

    /// The executor's split/stitch arena — callers staging per-request
    /// tensors (e.g. `coordinator::batcher::attention_scatter`) take
    /// buffers from here and return them after the batch so staging
    /// stays allocation-free in steady state.
    pub fn scratch(&mut self) -> &mut Workspace {
        &mut self.ws_main
    }

    /// The execution context this executor fans tasks out on (the
    /// encoder stack runs its LN/FFN kernels on the same context so the
    /// whole layer shares one pool).
    pub fn ctx(&self) -> &KernelCtx {
        &self.ctx
    }

    /// Return a [`run`](BatchedAttention::run) output's buffer to the
    /// per-task slot arena it was taken from (`task` = the task's index
    /// in that `run` call). Callers composing custom fan-outs — e.g.
    /// the projected MHA in [`model::layer`](crate::model::layer) —
    /// use this to keep the slot arenas flat across batches, exactly as
    /// [`attention_batched`] does internally for its own outputs.
    pub fn put_slot(&mut self, task: usize, buf: Vec<f32>) {
        assert!(task < self.slots.len(), "no such task slot");
        self.slots[task].put(buf);
    }

    /// Execute every task in parallel through the [`AttentionOp`] seam;
    /// returns one output per task, in order. Deterministic: identical
    /// results for any pool size.
    pub fn run(&mut self, tasks: &[AttnTask], op: &dyn AttentionOp) -> Vec<Tensor2> {
        let nt = tasks.len();
        if nt == 0 {
            return Vec::new();
        }
        while self.slots.len() < nt {
            self.slots.push(Workspace::new());
        }
        let mut outs: Vec<Tensor2> = (0..nt).map(|_| Tensor2::zeros(0, 0)).collect();
        let obase = SendMut(outs.as_mut_ptr());
        let sbase = SendMut(self.slots.as_mut_ptr());
        // chunk tasks into at most `threads` contiguous ranges (like
        // run_blocks) so the scope_for caller lane stays busy for the
        // whole batch instead of finishing one task and idling
        let isa = self.ctx.isa();
        self.ctx.run_blocks(nt, |_chunk, range| {
            // per-task sequential ctx inherits the executor's pinned
            // micro-kernel arm — never re-resolves it mid-batch
            let seq = KernelCtx::sequential().with_isa(isa);
            for i in range {
                // SAFETY: task i exclusively owns slot i and output i;
                // both vectors outlive the fork-join.
                let ws = unsafe { &mut *sbase.0.add(i) };
                let t = &tasks[i];
                let out = op.attend(&seq, &t.q, &t.k, &t.v, ws);
                unsafe {
                    *obase.0.add(i) = out;
                }
            }
        });
        outs
    }
}

/// Multi-head batched attention over whole requests: each request's
/// (n_i × h·dh) q/k/v is split into `n_heads` width-dh heads, **all**
/// heads of **all** requests execute in parallel on the pool, and the
/// per-head outputs are stitched back into one (n_i × h·dh) tensor per
/// request.
pub fn attention_batched(
    exec: &mut BatchedAttention,
    reqs: &[(Tensor2, Tensor2, Tensor2)],
    n_heads: usize,
    op: &dyn AttentionOp,
) -> Vec<Tensor2> {
    let refs: Vec<(&Tensor2, &Tensor2, &Tensor2)> =
        reqs.iter().map(|(q, k, v)| (q, k, v)).collect();
    attention_batched_core(exec, &refs, n_heads, op, false)
}

/// [`attention_batched`] for *self*-attention over per-request
/// activations: q = k = v = `xs[r]` — one activation tensor per
/// request, no triplicated staging. Merged outputs are fresh
/// allocations, like [`attention_batched`].
pub fn attention_batched_self(
    exec: &mut BatchedAttention,
    xs: &[Tensor2],
    n_heads: usize,
    op: &dyn AttentionOp,
) -> Vec<Tensor2> {
    let refs: Vec<(&Tensor2, &Tensor2, &Tensor2)> =
        xs.iter().map(|x| (x, x, x)).collect();
    attention_batched_core(exec, &refs, n_heads, op, false)
}

/// [`attention_batched_self`] with the merged per-request outputs taken
/// from the executor's scratch arena instead of freshly allocated — the
/// caller MUST return each output's buffer with
/// `exec.scratch().put(out.data)` once consumed, or the arena take/put
/// imbalance shows up as steady-state allocations. This is the encoder
/// stack's per-block path: it recycles every attention output within
/// the same batch, so serving stays allocation-free once warm.
pub fn attention_batched_self_pooled(
    exec: &mut BatchedAttention,
    xs: &[Tensor2],
    n_heads: usize,
    op: &dyn AttentionOp,
) -> Vec<Tensor2> {
    let refs: Vec<(&Tensor2, &Tensor2, &Tensor2)> =
        xs.iter().map(|x| (x, x, x)).collect();
    attention_batched_core(exec, &refs, n_heads, op, true)
}

fn attention_batched_core(
    exec: &mut BatchedAttention,
    reqs: &[(&Tensor2, &Tensor2, &Tensor2)],
    n_heads: usize,
    op: &dyn AttentionOp,
    pooled: bool,
) -> Vec<Tensor2> {
    assert!(n_heads > 0, "n_heads must be positive");
    if reqs.is_empty() {
        return Vec::new();
    }
    let mut tasks = Vec::with_capacity(reqs.len() * n_heads);
    for (q, k, v) in reqs {
        assert_eq!(q.cols, k.cols, "q/k width mismatch");
        assert_eq!(q.cols, v.cols, "q/v width mismatch");
        assert_eq!(k.rows, v.rows, "k/v length mismatch");
        assert!(q.cols % n_heads == 0,
                "model width {} not divisible by {n_heads} heads", q.cols);
        let dh = q.cols / n_heads;
        for h in 0..n_heads {
            tasks.push(AttnTask {
                q: slice_head(&mut exec.ws_main, q, h, dh),
                k: slice_head(&mut exec.ws_main, k, h, dh),
                v: slice_head(&mut exec.ws_main, v, h, dh),
            });
        }
    }
    let head_outs = exec.run(&tasks, op);
    // stitch heads back per request
    let mut outs = Vec::with_capacity(reqs.len());
    let mut it = head_outs.into_iter();
    let mut task_it = tasks.into_iter();
    let mut slot = 0;
    for (q, _, _) in reqs {
        let dh = q.cols / n_heads;
        let mut merged = if pooled {
            Tensor2 {
                rows: q.rows,
                cols: q.cols,
                data: exec.ws_main.take(q.rows * q.cols),
            }
        } else {
            Tensor2::zeros(q.rows, q.cols)
        };
        for h in 0..n_heads {
            let head = it.next().expect("one output per task");
            assert_eq!((head.rows, head.cols), (q.rows, dh));
            for i in 0..q.rows {
                merged.row_mut(i)[h * dh..(h + 1) * dh]
                    .copy_from_slice(head.row(i));
            }
            // the output buffer was taken from this task's slot arena:
            // return it there so slots stay allocation-free across
            // batches; the split copies go back to the stitch arena
            exec.slots[slot].put(head.data);
            slot += 1;
            let task = task_it.next().expect("one task per output");
            exec.ws_main.put(task.q.data);
            exec.ws_main.put(task.k.data);
            exec.ws_main.put(task.v.data);
        }
        outs.push(merged);
    }
    outs
}

/// Copy head `h` (columns h·dh .. (h+1)·dh) into a standalone tensor
/// backed by arena scratch.
fn slice_head(ws: &mut Workspace, x: &Tensor2, h: usize, dh: usize) -> Tensor2 {
    let mut data = ws.take(x.rows * dh);
    for i in 0..x.rows {
        data[i * dh..(i + 1) * dh]
            .copy_from_slice(&x.row(i)[h * dh..(h + 1) * dh]);
    }
    Tensor2 { rows: x.rows, cols: dh, data }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::flash_attention;
    use crate::attention::default_scale;
    use crate::rngx::Rng;

    fn reqs(seed: u64, shapes: &[(usize, usize)]) -> Vec<(Tensor2, Tensor2, Tensor2)> {
        let mut rng = Rng::new(seed);
        shapes
            .iter()
            .map(|&(n, d)| {
                (
                    Tensor2::randn(&mut rng, n, d, 1.0),
                    Tensor2::randn(&mut rng, n, d, 1.0),
                    Tensor2::randn(&mut rng, n, d, 1.0),
                )
            })
            .collect()
    }

    #[test]
    fn batched_full_matches_serial_single_head() {
        let rs = reqs(1, &[(48, 8), (64, 8), (16, 8)]);
        let mut exec = BatchedAttention::new(KernelCtx::global());
        let outs = attention_batched(&mut exec, &rs, 1, &BatchedVariant::Full);
        assert_eq!(outs.len(), 3);
        let mut ws = Workspace::new();
        for ((q, k, v), out) in rs.iter().zip(&outs) {
            let want = flash_attention(&KernelCtx::sequential(), q, k, v,
                                       default_scale(q.cols), &mut ws);
            assert_eq!(out.data, want.data, "batched must equal serial bitwise");
        }
    }

    #[test]
    fn multi_head_stitches_back_correctly() {
        // with h heads, each head must equal single-head attention on
        // its column slice
        let rs = reqs(2, &[(32, 16)]);
        let mut exec = BatchedAttention::new(KernelCtx::global());
        let outs = attention_batched(&mut exec, &rs, 4, &BatchedVariant::Full);
        let (q, k, v) = &rs[0];
        let mut ws = Workspace::new();
        for h in 0..4 {
            let qh = slice_head(&mut ws, q, h, 4);
            let kh = slice_head(&mut ws, k, h, 4);
            let vh = slice_head(&mut ws, v, h, 4);
            let want = flash_attention(&KernelCtx::sequential(), &qh, &kh, &vh,
                                       default_scale(4), &mut ws);
            for i in 0..q.rows {
                assert_eq!(&outs[0].row(i)[h * 4..(h + 1) * 4], want.row(i));
            }
        }
    }

    #[test]
    fn batched_spectral_shift_runs_and_is_deterministic() {
        let rs = reqs(3, &[(64, 16), (64, 16)]);
        let cfg = SpectralShiftConfig::new(8);
        let mut exec = BatchedAttention::new(KernelCtx::global());
        let a = attention_batched(&mut exec, &rs, 2,
                                  &BatchedVariant::SpectralShift(cfg));
        let mut exec_seq = BatchedAttention::new(KernelCtx::sequential());
        let b = attention_batched(&mut exec_seq, &rs, 2,
                                  &BatchedVariant::SpectralShift(cfg));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.data, y.data);
        }
    }

    #[test]
    fn self_attention_equals_triplicated_inputs() {
        // the encoder stack's q = k = v entry point must be the same
        // function as the general one fed three copies
        let mut rng = Rng::new(9);
        let xs = vec![
            Tensor2::randn(&mut rng, 64, 16, 1.0),
            Tensor2::randn(&mut rng, 32, 16, 1.0),
        ];
        let trips: Vec<(Tensor2, Tensor2, Tensor2)> =
            xs.iter().map(|x| (x.clone(), x.clone(), x.clone())).collect();
        let op = BatchedVariant::SpectralShift(SpectralShiftConfig::new(8));
        let mut exec = BatchedAttention::new(KernelCtx::global());
        let a = attention_batched_self(&mut exec, &xs, 2, &op);
        let b = attention_batched(&mut exec, &trips, 2, &op);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.data, y.data);
        }
    }

    #[test]
    fn pooled_self_attention_recycles_outputs_through_scratch() {
        let mut rng = Rng::new(11);
        let xs = vec![
            Tensor2::randn(&mut rng, 64, 16, 1.0),
            Tensor2::randn(&mut rng, 32, 16, 1.0),
        ];
        let op = BatchedVariant::Full;
        let mut exec = BatchedAttention::new(KernelCtx::global());
        // pooled and unpooled are the same function
        let plain = attention_batched_self(&mut exec, &xs, 2, &op);
        let pooled = attention_batched_self_pooled(&mut exec, &xs, 2, &op);
        for (p, q) in plain.iter().zip(&pooled) {
            assert_eq!(p.data, q.data);
        }
        for t in pooled {
            exec.scratch().put(t.data);
        }
        // steady state: pooled batches whose outputs are returned never
        // allocate from any executor arena
        let arena = |e: &BatchedAttention| -> usize {
            e.slots.iter().map(|w| w.allocations()).sum::<usize>()
                + e.ws_main.allocations()
        };
        let warm = arena(&exec);
        for _ in 0..3 {
            let outs = attention_batched_self_pooled(&mut exec, &xs, 2, &op);
            for t in outs {
                exec.scratch().put(t.data);
            }
        }
        assert_eq!(arena(&exec), warm,
                   "returned pooled outputs must keep the arenas flat");
    }

    #[test]
    fn all_six_variants_execute_batched() {
        let rs = reqs(5, &[(64, 16)]);
        let mut exec = BatchedAttention::new(KernelCtx::global());
        for variant in [
            BatchedVariant::Full,
            BatchedVariant::Nystrom { landmarks: 8, pinv_iters: 6 },
            BatchedVariant::SpectralShift(SpectralShiftConfig::new(8)),
            BatchedVariant::Linformer { kdim: 8, seed: 1 },
            BatchedVariant::Lsh { rounds: 2, bits: None, seed: 1 },
            BatchedVariant::Sparse { window: None, stride: None },
        ] {
            let outs = attention_batched(&mut exec, &rs, 2, &variant);
            assert_eq!(outs.len(), 1, "{}", variant.name());
            assert!(outs[0].data.iter().all(|x| x.is_finite()),
                    "{}", variant.name());
        }
    }

    #[test]
    fn workspace_slots_recycle_across_batches() {
        let rs = reqs(4, &[(64, 8), (64, 8)]);
        let mut exec = BatchedAttention::new(KernelCtx::global());
        let _ = attention_batched(&mut exec, &rs, 2, &BatchedVariant::Full);
        let warm: usize = exec.slots.iter().map(|w| w.allocations()).sum::<usize>()
            + exec.ws_main.allocations();
        for _ in 0..3 {
            let _ = attention_batched(&mut exec, &rs, 2, &BatchedVariant::Full);
        }
        let after: usize = exec.slots.iter().map(|w| w.allocations()).sum::<usize>()
            + exec.ws_main.allocations();
        assert_eq!(warm, after, "steady-state batches must not allocate from arenas");
    }

    #[test]
    fn variant_mapping_from_config() {
        match BatchedVariant::from_config(Variant::Nystrom, 16, 6) {
            BatchedVariant::Nystrom { landmarks, pinv_iters } => {
                assert_eq!((landmarks, pinv_iters), (16, 6));
            }
            other => panic!("{other:?}"),
        }
        match BatchedVariant::from_config(Variant::SpectralShift, 8, 4) {
            BatchedVariant::SpectralShift(cfg) => {
                assert_eq!(cfg.landmarks, 8);
                assert_eq!(cfg.pinv_iters, 4);
            }
            other => panic!("{other:?}"),
        }
        assert!(matches!(BatchedVariant::from_config(Variant::Full, 8, 4),
                         BatchedVariant::Full));
        // linformer runs at the same rank budget as the landmark methods
        match BatchedVariant::from_config(Variant::Linformer, 24, 4) {
            BatchedVariant::Linformer { kdim, .. } => assert_eq!(kdim, 24),
            other => panic!("{other:?}"),
        }
        assert!(matches!(BatchedVariant::from_config(Variant::Lsh, 8, 4),
                         BatchedVariant::Lsh { .. }));
        assert!(matches!(BatchedVariant::from_config(Variant::Sparse, 8, 4),
                         BatchedVariant::Sparse { .. }));
        // only the landmark variants constrain execution lengths
        assert_eq!(BatchedVariant::from_config(Variant::Linformer, 24, 4)
                       .landmark_divisor(),
                   None);
        assert_eq!(BatchedVariant::from_config(Variant::Nystrom, 24, 4)
                       .landmark_divisor(),
                   Some(24));
    }
}
