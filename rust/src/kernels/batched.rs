//! Batched multi-head attention: fan a popped serving batch out over
//! the kernel pool so heads × requests execute in parallel instead of
//! serially.
//!
//! Each in-flight task owns one [`Workspace`] slot (recycled across
//! batches → zero steady-state allocations inside the kernels) and runs
//! its head with a *sequential* [`KernelCtx`]: the batch dimension
//! already saturates the pool, and keeping nested work sequential both
//! avoids pool-in-pool deadlock and preserves bitwise determinism.

use super::workspace::Workspace;
use super::{flash_attention, KernelCtx, SendMut};
use crate::attention::nystrom::nystrom_attention_with;
use crate::attention::spectral_shift::{spectral_shift_attention_with, SpectralShiftConfig};
use crate::attention::{default_scale, Tensor2};
use crate::config::Variant;

/// One attention problem: a single head of a single request.
pub struct AttnTask {
    pub q: Tensor2,
    pub k: Tensor2,
    pub v: Tensor2,
}

/// Which attention kernel a batch executes.
#[derive(Clone, Copy, Debug)]
pub enum BatchedVariant {
    /// Exact softmax attention (flash streaming).
    Full,
    /// Nystromformer with `landmarks` and `pinv_iters`.
    Nystrom { landmarks: usize, pinv_iters: usize },
    /// Spectral shifting (the paper's method).
    SpectralShift(SpectralShiftConfig),
}

impl BatchedVariant {
    /// Map a serving-config variant onto its kernel, with the given
    /// landmark count / pinv iterations for the O(n) methods.
    pub fn from_config(variant: Variant, landmarks: usize, pinv_iters: usize) -> Self {
        match variant {
            Variant::Full => BatchedVariant::Full,
            Variant::Nystrom => BatchedVariant::Nystrom { landmarks, pinv_iters },
            Variant::SpectralShift => {
                let mut cfg = SpectralShiftConfig::new(landmarks);
                cfg.pinv_iters = pinv_iters;
                BatchedVariant::SpectralShift(cfg)
            }
        }
    }
}

/// Executor that owns the per-slot workspaces between batches.
pub struct BatchedAttention {
    ctx: KernelCtx,
    slots: Vec<Workspace>,
    /// head split/stitch scratch for [`attention_batched`]
    ws_main: Workspace,
}

impl BatchedAttention {
    pub fn new(ctx: KernelCtx) -> Self {
        BatchedAttention { ctx, slots: Vec::new(), ws_main: Workspace::new() }
    }

    /// The executor's split/stitch arena — callers staging per-request
    /// tensors (e.g. `coordinator::batcher::attention_scatter`) take
    /// buffers from here and return them after the batch so staging
    /// stays allocation-free in steady state.
    pub fn scratch(&mut self) -> &mut Workspace {
        &mut self.ws_main
    }

    /// Execute every task in parallel; returns one output per task, in
    /// order. Deterministic: identical results for any pool size.
    pub fn run(&mut self, tasks: &[AttnTask], variant: BatchedVariant) -> Vec<Tensor2> {
        let nt = tasks.len();
        if nt == 0 {
            return Vec::new();
        }
        while self.slots.len() < nt {
            self.slots.push(Workspace::new());
        }
        let mut outs: Vec<Tensor2> = (0..nt).map(|_| Tensor2::zeros(0, 0)).collect();
        let obase = SendMut(outs.as_mut_ptr());
        let sbase = SendMut(self.slots.as_mut_ptr());
        // chunk tasks into at most `threads` contiguous ranges (like
        // run_blocks) so the scope_for caller lane stays busy for the
        // whole batch instead of finishing one task and idling
        self.ctx.run_blocks(nt, |_chunk, range| {
            for i in range {
                // SAFETY: task i exclusively owns slot i and output i;
                // both vectors outlive the fork-join.
                let ws = unsafe { &mut *sbase.0.add(i) };
                let t = &tasks[i];
                let out = run_one(t, variant, ws);
                unsafe {
                    *obase.0.add(i) = out;
                }
            }
        });
        outs
    }
}

fn run_one(t: &AttnTask, variant: BatchedVariant, ws: &mut Workspace) -> Tensor2 {
    let seq = KernelCtx::sequential();
    match variant {
        BatchedVariant::Full => {
            flash_attention(&seq, &t.q, &t.k, &t.v, default_scale(t.q.cols), ws)
        }
        BatchedVariant::Nystrom { landmarks, pinv_iters } => {
            nystrom_attention_with(&t.q, &t.k, &t.v, landmarks, pinv_iters, None, &seq, ws)
        }
        BatchedVariant::SpectralShift(cfg) => {
            spectral_shift_attention_with(&t.q, &t.k, &t.v, &cfg, &seq, ws)
        }
    }
}

/// Multi-head batched attention over whole requests: each request's
/// (n_i × h·dh) q/k/v is split into `n_heads` width-dh heads, **all**
/// heads of **all** requests execute in parallel on the pool, and the
/// per-head outputs are stitched back into one (n_i × h·dh) tensor per
/// request.
pub fn attention_batched(
    exec: &mut BatchedAttention,
    reqs: &[(Tensor2, Tensor2, Tensor2)],
    n_heads: usize,
    variant: BatchedVariant,
) -> Vec<Tensor2> {
    assert!(n_heads > 0, "n_heads must be positive");
    if reqs.is_empty() {
        return Vec::new();
    }
    let mut tasks = Vec::with_capacity(reqs.len() * n_heads);
    for (q, k, v) in reqs {
        assert_eq!(q.cols, k.cols, "q/k width mismatch");
        assert_eq!(q.cols, v.cols, "q/v width mismatch");
        assert_eq!(k.rows, v.rows, "k/v length mismatch");
        assert!(q.cols % n_heads == 0,
                "model width {} not divisible by {n_heads} heads", q.cols);
        let dh = q.cols / n_heads;
        for h in 0..n_heads {
            tasks.push(AttnTask {
                q: slice_head(&mut exec.ws_main, q, h, dh),
                k: slice_head(&mut exec.ws_main, k, h, dh),
                v: slice_head(&mut exec.ws_main, v, h, dh),
            });
        }
    }
    let head_outs = exec.run(&tasks, variant);
    // stitch heads back per request
    let mut outs = Vec::with_capacity(reqs.len());
    let mut it = head_outs.into_iter();
    let mut task_it = tasks.into_iter();
    let mut slot = 0;
    for (q, _, _) in reqs {
        let dh = q.cols / n_heads;
        let mut merged = Tensor2::zeros(q.rows, q.cols);
        for h in 0..n_heads {
            let head = it.next().expect("one output per task");
            assert_eq!((head.rows, head.cols), (q.rows, dh));
            for i in 0..q.rows {
                merged.row_mut(i)[h * dh..(h + 1) * dh]
                    .copy_from_slice(head.row(i));
            }
            // the output buffer was taken from this task's slot arena:
            // return it there so slots stay allocation-free across
            // batches; the split copies go back to the stitch arena
            exec.slots[slot].put(head.data);
            slot += 1;
            let task = task_it.next().expect("one task per output");
            exec.ws_main.put(task.q.data);
            exec.ws_main.put(task.k.data);
            exec.ws_main.put(task.v.data);
        }
        outs.push(merged);
    }
    outs
}

/// Copy head `h` (columns h·dh .. (h+1)·dh) into a standalone tensor
/// backed by arena scratch.
fn slice_head(ws: &mut Workspace, x: &Tensor2, h: usize, dh: usize) -> Tensor2 {
    let mut data = ws.take(x.rows * dh);
    for i in 0..x.rows {
        data[i * dh..(i + 1) * dh]
            .copy_from_slice(&x.row(i)[h * dh..(h + 1) * dh]);
    }
    Tensor2 { rows: x.rows, cols: dh, data }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Rng;

    fn reqs(seed: u64, shapes: &[(usize, usize)]) -> Vec<(Tensor2, Tensor2, Tensor2)> {
        let mut rng = Rng::new(seed);
        shapes
            .iter()
            .map(|&(n, d)| {
                (
                    Tensor2::randn(&mut rng, n, d, 1.0),
                    Tensor2::randn(&mut rng, n, d, 1.0),
                    Tensor2::randn(&mut rng, n, d, 1.0),
                )
            })
            .collect()
    }

    #[test]
    fn batched_full_matches_serial_single_head() {
        let rs = reqs(1, &[(48, 8), (64, 8), (16, 8)]);
        let mut exec = BatchedAttention::new(KernelCtx::global());
        let outs = attention_batched(&mut exec, &rs, 1, BatchedVariant::Full);
        assert_eq!(outs.len(), 3);
        let mut ws = Workspace::new();
        for ((q, k, v), out) in rs.iter().zip(&outs) {
            let want = flash_attention(&KernelCtx::sequential(), q, k, v,
                                       default_scale(q.cols), &mut ws);
            assert_eq!(out.data, want.data, "batched must equal serial bitwise");
        }
    }

    #[test]
    fn multi_head_stitches_back_correctly() {
        // with h heads, each head must equal single-head attention on
        // its column slice
        let rs = reqs(2, &[(32, 16)]);
        let mut exec = BatchedAttention::new(KernelCtx::global());
        let outs = attention_batched(&mut exec, &rs, 4, BatchedVariant::Full);
        let (q, k, v) = &rs[0];
        let mut ws = Workspace::new();
        for h in 0..4 {
            let qh = slice_head(&mut ws, q, h, 4);
            let kh = slice_head(&mut ws, k, h, 4);
            let vh = slice_head(&mut ws, v, h, 4);
            let want = flash_attention(&KernelCtx::sequential(), &qh, &kh, &vh,
                                       default_scale(4), &mut ws);
            for i in 0..q.rows {
                assert_eq!(&outs[0].row(i)[h * 4..(h + 1) * 4], want.row(i));
            }
        }
    }

    #[test]
    fn batched_spectral_shift_runs_and_is_deterministic() {
        let rs = reqs(3, &[(64, 16), (64, 16)]);
        let cfg = SpectralShiftConfig::new(8);
        let mut exec = BatchedAttention::new(KernelCtx::global());
        let a = attention_batched(&mut exec, &rs, 2, BatchedVariant::SpectralShift(cfg));
        let mut exec_seq = BatchedAttention::new(KernelCtx::sequential());
        let b = attention_batched(&mut exec_seq, &rs, 2, BatchedVariant::SpectralShift(cfg));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.data, y.data);
        }
    }

    #[test]
    fn workspace_slots_recycle_across_batches() {
        let rs = reqs(4, &[(64, 8), (64, 8)]);
        let mut exec = BatchedAttention::new(KernelCtx::global());
        let _ = attention_batched(&mut exec, &rs, 2, BatchedVariant::Full);
        let warm: usize = exec.slots.iter().map(|w| w.allocations()).sum::<usize>()
            + exec.ws_main.allocations();
        for _ in 0..3 {
            let _ = attention_batched(&mut exec, &rs, 2, BatchedVariant::Full);
        }
        let after: usize = exec.slots.iter().map(|w| w.allocations()).sum::<usize>()
            + exec.ws_main.allocations();
        assert_eq!(warm, after, "steady-state batches must not allocate from arenas");
    }

    #[test]
    fn variant_mapping_from_config() {
        match BatchedVariant::from_config(Variant::Nystrom, 16, 6) {
            BatchedVariant::Nystrom { landmarks, pinv_iters } => {
                assert_eq!((landmarks, pinv_iters), (16, 6));
            }
            other => panic!("{other:?}"),
        }
        match BatchedVariant::from_config(Variant::SpectralShift, 8, 4) {
            BatchedVariant::SpectralShift(cfg) => {
                assert_eq!(cfg.landmarks, 8);
                assert_eq!(cfg.pinv_iters, 4);
            }
            other => panic!("{other:?}"),
        }
        assert!(matches!(BatchedVariant::from_config(Variant::Full, 8, 4),
                         BatchedVariant::Full));
    }
}
