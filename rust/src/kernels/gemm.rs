//! Tiled, multi-threaded f32 GEMM with per-[`Isa`] micro-kernels.
//!
//! Layout: everything row-major. Parallelism: fixed [`BLOCK_ROWS`]-row
//! blocks of C fanned out over the pool (M-parallel; K is never split,
//! so each output element's reduction order is fixed regardless of the
//! thread count — bitwise-deterministic results per arm). Within a
//! block, every arm walks the same cache structure:
//!
//! * the k dimension in [`KC`]-deep panels,
//! * B in [`NC`]-wide column panels, so the streamed KC×NC B panel
//!   (128 KiB at the defaults) stays L2-resident while every A
//!   micro-panel of the block sweeps it — this is what keeps the c×c
//!   Newton–Schulz pseudoinverse chain (`attention::nystrom::ns_pinv_with`)
//!   in cache as the landmark count grows,
//! * a group of A rows packed into a column-major micro-panel on the
//!   task's stack, sized to the register tile of the dispatched arm:
//!   scalar 4 rows × 8-wide unrolled axpy ([`micro_axpy4`]), AVX2
//!   8 rows × 8 FMA lanes with software prefetch on the B panel, NEON
//!   4 rows × 4 FMA lanes.
//!
//! B needs no packing: its rows are already contiguous and stream
//! through the j inner loop in order.
//!
//! Column blocking is arithmetic-order-neutral: each `c[i][j]` still
//! accumulates over k in ascending panel-then-p order, exactly one
//! column panel owning any given j — so the scalar arm is byte-for-byte
//! the pre-blocking kernel, and the `k_order_matmul_is_bitwise_the_blocked_gemm`
//! pin in `model::reference` keeps holding on that arm. The FMA arms
//! keep the same k order but contract mul+add to a single rounding,
//! which is why that pin (and nothing else) is scalar-arm-only.

use super::isa::Isa;
use super::workspace::Workspace;
use super::{KernelCtx, SendMut, BLOCK_ROWS};
use crate::attention::Tensor2;

/// Rows per scalar micro-kernel (register tile height). Divides
/// [`BLOCK_ROWS`], as do the per-ISA tile heights.
const MR: usize = 4;
/// k-depth of a cache panel (the packed micro-panel stays L1-resident:
/// 4 KiB scalar/NEON, 8 KiB AVX2). Reported at coordinator startup as
/// the Newton–Schulz k-blocking depth.
pub const KC: usize = 256;
/// Column width of the streamed B panel (KC×NC f32 = 128 KiB,
/// L2-resident). Reported alongside [`KC`] at coordinator startup.
pub const NC: usize = 128;

/// C = A · B on flat row-major slices; `c` is overwritten.
/// a: m×k, b: k×n, c: m×n.
pub fn gemm_into(ctx: &KernelCtx, a: &[f32], b: &[f32], c: &mut [f32],
                 m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "gemm: A is not m×k");
    assert_eq!(b.len(), k * n, "gemm: B is not k×n");
    assert_eq!(c.len(), m * n, "gemm: C is not m×n");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        c.fill(0.0);
        return;
    }
    let nblocks = (m + BLOCK_ROWS - 1) / BLOCK_ROWS;
    let cbase = SendMut(c.as_mut_ptr());
    let isa = ctx.isa();
    ctx.run_blocks(nblocks, |_task, blocks| {
        for blk in blocks {
            let r0 = blk * BLOCK_ROWS;
            let r1 = (r0 + BLOCK_ROWS).min(m);
            // SAFETY: blocks are disjoint row ranges of C and C outlives
            // the fork-join.
            let cblk = unsafe {
                std::slice::from_raw_parts_mut(cbase.0.add(r0 * n), (r1 - r0) * n)
            };
            gemm_rows(isa, &a[r0 * k..r1 * k], b, cblk, r1 - r0, k, n);
        }
    });
}

/// Sequential GEMM over `mb` rows: c (mb×n, overwritten) = a (mb×k) ·
/// b (k×n), dispatched to the register tile of `isa`. This is the
/// per-block body `gemm_into` parallelizes and the building block the
/// fused kernels reuse on their scratch.
pub(crate) fn gemm_rows(isa: Isa, a: &[f32], b: &[f32], c: &mut [f32],
                        mb: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), mb * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), mb * n);
    c.fill(0.0);
    if k == 0 || n == 0 {
        return;
    }
    debug_assert!(isa.supported());
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: a `KernelCtx` only carries host-supported arms
        // (asserted at construction), so avx2+fma are present here.
        Isa::Avx2 => unsafe { avx2::gemm_rows(a, b, c, mb, k, n) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above, for neon.
        Isa::Neon => unsafe { neon::gemm_rows(a, b, c, mb, k, n) },
        _ => gemm_rows_scalar(a, b, c, mb, k, n),
    }
}

/// The scalar arm — byte-for-byte the seed arithmetic (the [`NC`]
/// column loop regroups the j traversal but leaves every element's
/// multiply-add sequence untouched). `c` must be pre-zeroed.
fn gemm_rows_scalar(a: &[f32], b: &[f32], c: &mut [f32],
                    mb: usize, k: usize, n: usize) {
    let mut apack = [0.0f32; MR * KC];
    let mut kb = 0;
    while kb < k {
        let kc = KC.min(k - kb);
        let mut jb = 0;
        while jb < n {
            let nc = NC.min(n - jb);
            let mut i = 0;
            // 4-row micro-kernel over packed A panels
            while i + MR <= mb {
                for p in 0..kc {
                    for (r, slot) in
                        apack[p * MR..(p + 1) * MR].iter_mut().enumerate() {
                        *slot = a[(i + r) * k + kb + p];
                    }
                }
                let cblk = &mut c[i * n..(i + MR) * n];
                let (c0, rest) = cblk.split_at_mut(n);
                let (c1, rest) = rest.split_at_mut(n);
                let (c2, c3) = rest.split_at_mut(n);
                let (c0, c1, c2, c3) =
                    (&mut c0[jb..jb + nc], &mut c1[jb..jb + nc],
                     &mut c2[jb..jb + nc], &mut c3[jb..jb + nc]);
                for p in 0..kc {
                    let brow = &b[(kb + p) * n + jb..(kb + p) * n + jb + nc];
                    let ap = &apack[p * MR..(p + 1) * MR];
                    micro_axpy4(c0, c1, c2, c3, ap[0], ap[1], ap[2], ap[3], brow);
                }
                i += MR;
            }
            // remainder rows (mb % 4): single-row axpy, same k order
            while i < mb {
                let crow = &mut c[i * n + jb..i * n + jb + nc];
                for p in 0..kc {
                    let w = a[i * k + kb + p];
                    let brow = &b[(kb + p) * n + jb..(kb + p) * n + jb + nc];
                    axpy8(crow, w, brow);
                }
                i += 1;
            }
            jb += nc;
        }
        kb += kc;
    }
}

/// 4-row rank-1 update: c_r += a_r · b for r in 0..4, 8-wide unrolled.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn micro_axpy4(c0: &mut [f32], c1: &mut [f32], c2: &mut [f32], c3: &mut [f32],
               a0: f32, a1: f32, a2: f32, a3: f32, b: &[f32]) {
    let n = b.len();
    debug_assert!(c0.len() == n && c1.len() == n && c2.len() == n && c3.len() == n);
    let mut j = 0;
    while j + 8 <= n {
        let bj = &b[j..j + 8];
        let s0 = &mut c0[j..j + 8];
        for t in 0..8 {
            s0[t] += a0 * bj[t];
        }
        let s1 = &mut c1[j..j + 8];
        for t in 0..8 {
            s1[t] += a1 * bj[t];
        }
        let s2 = &mut c2[j..j + 8];
        for t in 0..8 {
            s2[t] += a2 * bj[t];
        }
        let s3 = &mut c3[j..j + 8];
        for t in 0..8 {
            s3[t] += a3 * bj[t];
        }
        j += 8;
    }
    while j < n {
        c0[j] += a0 * b[j];
        c1[j] += a1 * b[j];
        c2[j] += a2 * b[j];
        c3[j] += a3 * b[j];
        j += 1;
    }
}

/// Single-row axpy (c += w·b), 8-wide unrolled.
#[inline(always)]
pub(crate) fn axpy8(c: &mut [f32], w: f32, b: &[f32]) {
    let n = b.len();
    debug_assert_eq!(c.len(), n);
    let mut j = 0;
    while j + 8 <= n {
        let bj = &b[j..j + 8];
        let cj = &mut c[j..j + 8];
        for t in 0..8 {
            cj[t] += w * bj[t];
        }
        j += 8;
    }
    while j < n {
        c[j] += w * b[j];
        j += 1;
    }
}

/// The AVX2+FMA arm: an 8-row × 8-lane register tile (8 ymm
/// accumulators live across the whole k panel), software prefetch on
/// the streamed B panel, and the same KC/NC cache walk as the scalar
/// arm. Per element the k accumulation order is identical to scalar —
/// only the mul+add contraction differs — so the arm is bitwise
/// thread-count deterministic and within the 1e-4 envelope of the
/// references.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{KC, NC};
    use std::arch::x86_64::*;

    /// Register-tile height (divides [`super::BLOCK_ROWS`]).
    const MR8: usize = 8;
    /// B-panel rows prefetched ahead of the FMA stream.
    const PF: usize = 4;

    /// SAFETY: caller verified avx2+fma support. `c` must be pre-zeroed
    /// (the dispatcher zeroes it).
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn gemm_rows(a: &[f32], b: &[f32], c: &mut [f32],
                                   mb: usize, k: usize, n: usize) {
        let mut apack = [0.0f32; MR8 * KC];
        let (bp, cp) = (b.as_ptr(), c.as_mut_ptr());
        let mut kb = 0;
        while kb < k {
            let kc = KC.min(k - kb);
            let mut jb = 0;
            while jb < n {
                let nc = NC.min(n - jb);
                let jend = jb + nc;
                // full 8-lane extent of this column panel
                let jv = jb + (nc & !7);
                let mut i = 0;
                while i + MR8 <= mb {
                    // pack 8 A rows column-major for this k panel
                    for p in 0..kc {
                        for (r, slot) in
                            apack[p * MR8..(p + 1) * MR8].iter_mut().enumerate() {
                            *slot = a[(i + r) * k + kb + p];
                        }
                    }
                    let mut j = jb;
                    while j < jv {
                        // 8×8 tile: accumulators stay in ymm registers
                        // for the whole k panel
                        let mut acc = [_mm256_setzero_ps(); MR8];
                        for (r, accr) in acc.iter_mut().enumerate() {
                            *accr = _mm256_loadu_ps(cp.add((i + r) * n + j));
                        }
                        for p in 0..kc {
                            let bv = _mm256_loadu_ps(bp.add((kb + p) * n + j));
                            if p + PF < kc {
                                _mm_prefetch(
                                    bp.add((kb + p + PF) * n + j) as *const i8,
                                    _MM_HINT_T0);
                            }
                            let ap = apack.as_ptr().add(p * MR8);
                            for (r, accr) in acc.iter_mut().enumerate() {
                                *accr = _mm256_fmadd_ps(
                                    _mm256_set1_ps(*ap.add(r)), bv, *accr);
                            }
                        }
                        for (r, accr) in acc.iter().enumerate() {
                            _mm256_storeu_ps(cp.add((i + r) * n + j), *accr);
                        }
                        j += 8;
                    }
                    // tail columns (nc % 8): scalar FMA, same k order
                    while j < jend {
                        for r in 0..MR8 {
                            let mut s = *cp.add((i + r) * n + j);
                            for p in 0..kc {
                                s = (*bp.add((kb + p) * n + j))
                                    .mul_add(apack[p * MR8 + r], s);
                            }
                            *cp.add((i + r) * n + j) = s;
                        }
                        j += 1;
                    }
                    i += MR8;
                }
                // remainder rows (mb % 8): single-row FMA over the panel
                while i < mb {
                    let mut j = jb;
                    while j < jv {
                        let mut accv = _mm256_loadu_ps(cp.add(i * n + j));
                        for p in 0..kc {
                            let bv = _mm256_loadu_ps(bp.add((kb + p) * n + j));
                            accv = _mm256_fmadd_ps(
                                _mm256_set1_ps(a[i * k + kb + p]), bv, accv);
                        }
                        _mm256_storeu_ps(cp.add(i * n + j), accv);
                        j += 8;
                    }
                    while j < jend {
                        let mut s = *cp.add(i * n + j);
                        for p in 0..kc {
                            s = (*bp.add((kb + p) * n + j))
                                .mul_add(a[i * k + kb + p], s);
                        }
                        *cp.add(i * n + j) = s;
                        j += 1;
                    }
                    i += 1;
                }
                jb = jend;
            }
            kb += kc;
        }
    }
}

/// The NEON arm: a 4-row × 4-lane `vfmaq_f32` register tile on the
/// same KC/NC cache walk. Same k order as scalar, FMA contraction only
/// (no software prefetch: stable `core::arch` exposes none for
/// aarch64, and the hardware prefetchers handle the streamed panel).
#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{KC, NC};
    use std::arch::aarch64::*;

    /// Register-tile height (divides [`super::BLOCK_ROWS`]).
    const MR4: usize = 4;

    /// SAFETY: caller verified neon support. `c` must be pre-zeroed
    /// (the dispatcher zeroes it).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn gemm_rows(a: &[f32], b: &[f32], c: &mut [f32],
                                   mb: usize, k: usize, n: usize) {
        let mut apack = [0.0f32; MR4 * KC];
        let (bp, cp) = (b.as_ptr(), c.as_mut_ptr());
        let mut kb = 0;
        while kb < k {
            let kc = KC.min(k - kb);
            let mut jb = 0;
            while jb < n {
                let nc = NC.min(n - jb);
                let jend = jb + nc;
                let jv = jb + (nc & !3);
                let mut i = 0;
                while i + MR4 <= mb {
                    for p in 0..kc {
                        for (r, slot) in
                            apack[p * MR4..(p + 1) * MR4].iter_mut().enumerate() {
                            *slot = a[(i + r) * k + kb + p];
                        }
                    }
                    let mut j = jb;
                    while j < jv {
                        let mut acc = [vdupq_n_f32(0.0); MR4];
                        for (r, accr) in acc.iter_mut().enumerate() {
                            *accr = vld1q_f32(cp.add((i + r) * n + j));
                        }
                        for p in 0..kc {
                            let bv = vld1q_f32(bp.add((kb + p) * n + j));
                            let ap = apack.as_ptr().add(p * MR4);
                            for (r, accr) in acc.iter_mut().enumerate() {
                                *accr = vfmaq_f32(*accr, vdupq_n_f32(*ap.add(r)),
                                                  bv);
                            }
                        }
                        for (r, accr) in acc.iter().enumerate() {
                            vst1q_f32(cp.add((i + r) * n + j), *accr);
                        }
                        j += 4;
                    }
                    while j < jend {
                        for r in 0..MR4 {
                            let mut s = *cp.add((i + r) * n + j);
                            for p in 0..kc {
                                s = (*bp.add((kb + p) * n + j))
                                    .mul_add(apack[p * MR4 + r], s);
                            }
                            *cp.add((i + r) * n + j) = s;
                        }
                        j += 1;
                    }
                    i += MR4;
                }
                while i < mb {
                    let mut j = jb;
                    while j < jv {
                        let mut accv = vld1q_f32(cp.add(i * n + j));
                        for p in 0..kc {
                            accv = vfmaq_f32(accv,
                                             vdupq_n_f32(a[i * k + kb + p]),
                                             vld1q_f32(bp.add((kb + p) * n + j)));
                        }
                        vst1q_f32(cp.add(i * n + j), accv);
                        j += 4;
                    }
                    while j < jend {
                        let mut s = *cp.add(i * n + j);
                        for p in 0..kc {
                            s = (*bp.add((kb + p) * n + j))
                                .mul_add(a[i * k + kb + p], s);
                        }
                        *cp.add(i * n + j) = s;
                        j += 1;
                    }
                    i += 1;
                }
                jb = jend;
            }
            kb += kc;
        }
    }
}

/// C = A · B for [`Tensor2`], scratch from `ws` (recycle the returned
/// tensor's buffer with `ws.put(t.data)` when done with it).
pub fn gemm_f32(ctx: &KernelCtx, a: &Tensor2, b: &Tensor2, ws: &mut Workspace) -> Tensor2 {
    assert_eq!(a.cols, b.rows, "gemm shape mismatch: {}x{} · {}x{}",
               a.rows, a.cols, b.rows, b.cols);
    let mut data = ws.take(a.rows * b.cols);
    gemm_into(ctx, &a.data, &b.data, &mut data, a.rows, a.cols, b.cols);
    Tensor2 { rows: a.rows, cols: b.cols, data }
}

/// dst (cols×rows) = srcᵀ where src is rows×cols, both row-major.
pub fn transpose_into(src: &[f32], dst: &mut [f32], rows: usize, cols: usize) {
    assert_eq!(src.len(), rows * cols);
    assert_eq!(dst.len(), rows * cols);
    for i in 0..rows {
        let srow = &src[i * cols..(i + 1) * cols];
        for (j, &x) in srow.iter().enumerate() {
            dst[j * rows + i] = x;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::matmul_f32;
    use crate::rngx::Rng;

    fn randn(rng: &mut Rng, r: usize, c: usize) -> Tensor2 {
        Tensor2::randn(rng, r, c, 1.0)
    }

    #[test]
    fn known_2x2() {
        let ctx = KernelCtx::sequential();
        let mut ws = Workspace::new();
        let a = Tensor2::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Tensor2::from_vec(2, 2, vec![5., 6., 7., 8.]);
        let c = gemm_f32(&ctx, &a, &b, &mut ws);
        assert_eq!(c.data, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn matches_reference_on_odd_shapes() {
        let ctx = KernelCtx::global();
        let mut ws = Workspace::new();
        let mut rng = Rng::new(7);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 5, 2), (4, 4, 8),
                            (7, 300, 9), (33, 17, 5), (65, 64, 63), (129, 2, 1)] {
            let a = randn(&mut rng, m, k);
            let b = randn(&mut rng, k, n);
            let fast = gemm_f32(&ctx, &a, &b, &mut ws);
            let slow = matmul_f32(&a, &b);
            let mut denom = 0.0f32;
            for x in &slow.data {
                denom = denom.max(x.abs());
            }
            let err = fast.max_abs_diff(&slow) / denom.max(1e-6);
            assert!(err < 1e-4, "({m},{k},{n}): rel err {err}");
            ws.put(fast.data);
        }
    }

    #[test]
    fn thread_counts_are_bitwise_identical() {
        let mut rng = Rng::new(9);
        let a = randn(&mut rng, 70, 33);
        let b = randn(&mut rng, 33, 21);
        let mut ws = Workspace::new();
        let seq = gemm_f32(&KernelCtx::sequential(), &a, &b, &mut ws);
        let par = gemm_f32(&KernelCtx::global(), &a, &b, &mut ws);
        assert_eq!(seq.data, par.data, "reduction order must not depend on threads");
    }

    #[test]
    fn degenerate_dims() {
        let ctx = KernelCtx::sequential();
        let mut c = vec![5.0f32; 6];
        // k = 0: C must be zeroed
        gemm_into(&ctx, &[], &[], &mut c, 2, 0, 3);
        assert!(c.iter().all(|&x| x == 0.0));
        // m = 0 / n = 0: no-ops
        gemm_into(&ctx, &[], &[1.0, 2.0], &mut [], 0, 2, 1);
        gemm_into(&ctx, &[1.0, 2.0], &[], &mut [], 1, 2, 0);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(3);
        let a = randn(&mut rng, 5, 7);
        let mut at = vec![0.0f32; 35];
        let mut back = vec![0.0f32; 35];
        transpose_into(&a.data, &mut at, 5, 7);
        transpose_into(&at, &mut back, 7, 5);
        assert_eq!(a.data, back);
    }
}
