//! Tiled, multi-threaded f32 GEMM.
//!
//! Layout: everything row-major. Parallelism: fixed [`BLOCK_ROWS`]-row
//! blocks of C fanned out over the pool (M-parallel; K is never split,
//! so each output element's reduction order is fixed regardless of the
//! thread count — bitwise-deterministic results). Within a block:
//!
//! * the k dimension is walked in [`KC`]-deep cache panels,
//! * each group of [`MR`] = 4 A-rows is packed into a column-major
//!   micro-panel (one 4-wide column per k) held on the task's stack,
//! * the micro-kernel broadcasts the packed A column against a full
//!   B row with an 8-wide unrolled axpy, accumulating 4 C rows at once.
//!
//! B needs no packing: its rows are already contiguous and stream
//! through the j-unrolled inner loop in order.

use super::workspace::Workspace;
use super::{KernelCtx, SendMut, BLOCK_ROWS};
use crate::attention::Tensor2;

/// Rows per micro-kernel (register tile height). Divides [`BLOCK_ROWS`].
const MR: usize = 4;
/// k-depth of a cache panel (MR×KC packed panel = 4 KiB, L1-resident).
const KC: usize = 256;

/// C = A · B on flat row-major slices; `c` is overwritten.
/// a: m×k, b: k×n, c: m×n.
pub fn gemm_into(ctx: &KernelCtx, a: &[f32], b: &[f32], c: &mut [f32],
                 m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "gemm: A is not m×k");
    assert_eq!(b.len(), k * n, "gemm: B is not k×n");
    assert_eq!(c.len(), m * n, "gemm: C is not m×n");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        c.fill(0.0);
        return;
    }
    let nblocks = (m + BLOCK_ROWS - 1) / BLOCK_ROWS;
    let cbase = SendMut(c.as_mut_ptr());
    ctx.run_blocks(nblocks, |_task, blocks| {
        for blk in blocks {
            let r0 = blk * BLOCK_ROWS;
            let r1 = (r0 + BLOCK_ROWS).min(m);
            // SAFETY: blocks are disjoint row ranges of C and C outlives
            // the fork-join.
            let cblk = unsafe {
                std::slice::from_raw_parts_mut(cbase.0.add(r0 * n), (r1 - r0) * n)
            };
            gemm_rows(&a[r0 * k..r1 * k], b, cblk, r1 - r0, k, n);
        }
    });
}

/// Sequential GEMM over `mb` rows: c (mb×n, overwritten) = a (mb×k) ·
/// b (k×n). This is the per-block body `gemm_into` parallelizes and the
/// building block the fused kernels reuse on their scratch.
pub(crate) fn gemm_rows(a: &[f32], b: &[f32], c: &mut [f32],
                        mb: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), mb * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), mb * n);
    c.fill(0.0);
    if k == 0 || n == 0 {
        return;
    }
    let mut apack = [0.0f32; MR * KC];
    let mut kb = 0;
    while kb < k {
        let kc = KC.min(k - kb);
        let mut i = 0;
        // 4-row micro-kernel over packed A panels
        while i + MR <= mb {
            for p in 0..kc {
                for r in 0..MR {
                    apack[p * MR + r] = a[(i + r) * k + kb + p];
                }
            }
            let cblk = &mut c[i * n..(i + MR) * n];
            let (c0, rest) = cblk.split_at_mut(n);
            let (c1, rest) = rest.split_at_mut(n);
            let (c2, c3) = rest.split_at_mut(n);
            for p in 0..kc {
                let brow = &b[(kb + p) * n..(kb + p + 1) * n];
                let ap = &apack[p * MR..(p + 1) * MR];
                micro_axpy4(c0, c1, c2, c3, ap[0], ap[1], ap[2], ap[3], brow);
            }
            i += MR;
        }
        // remainder rows (mb % 4): single-row axpy, same k order
        while i < mb {
            let crow = &mut c[i * n..(i + 1) * n];
            for p in 0..kc {
                let w = a[i * k + kb + p];
                let brow = &b[(kb + p) * n..(kb + p + 1) * n];
                axpy8(crow, w, brow);
            }
            i += 1;
        }
        kb += kc;
    }
}

/// 4-row rank-1 update: c_r += a_r · b for r in 0..4, 8-wide unrolled.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn micro_axpy4(c0: &mut [f32], c1: &mut [f32], c2: &mut [f32], c3: &mut [f32],
               a0: f32, a1: f32, a2: f32, a3: f32, b: &[f32]) {
    let n = b.len();
    debug_assert!(c0.len() == n && c1.len() == n && c2.len() == n && c3.len() == n);
    let mut j = 0;
    while j + 8 <= n {
        let bj = &b[j..j + 8];
        let s0 = &mut c0[j..j + 8];
        for t in 0..8 {
            s0[t] += a0 * bj[t];
        }
        let s1 = &mut c1[j..j + 8];
        for t in 0..8 {
            s1[t] += a1 * bj[t];
        }
        let s2 = &mut c2[j..j + 8];
        for t in 0..8 {
            s2[t] += a2 * bj[t];
        }
        let s3 = &mut c3[j..j + 8];
        for t in 0..8 {
            s3[t] += a3 * bj[t];
        }
        j += 8;
    }
    while j < n {
        c0[j] += a0 * b[j];
        c1[j] += a1 * b[j];
        c2[j] += a2 * b[j];
        c3[j] += a3 * b[j];
        j += 1;
    }
}

/// Single-row axpy (c += w·b), 8-wide unrolled.
#[inline(always)]
pub(crate) fn axpy8(c: &mut [f32], w: f32, b: &[f32]) {
    let n = b.len();
    debug_assert_eq!(c.len(), n);
    let mut j = 0;
    while j + 8 <= n {
        let bj = &b[j..j + 8];
        let cj = &mut c[j..j + 8];
        for t in 0..8 {
            cj[t] += w * bj[t];
        }
        j += 8;
    }
    while j < n {
        c[j] += w * b[j];
        j += 1;
    }
}

/// C = A · B for [`Tensor2`], scratch from `ws` (recycle the returned
/// tensor's buffer with `ws.put(t.data)` when done with it).
pub fn gemm_f32(ctx: &KernelCtx, a: &Tensor2, b: &Tensor2, ws: &mut Workspace) -> Tensor2 {
    assert_eq!(a.cols, b.rows, "gemm shape mismatch: {}x{} · {}x{}",
               a.rows, a.cols, b.rows, b.cols);
    let mut data = ws.take(a.rows * b.cols);
    gemm_into(ctx, &a.data, &b.data, &mut data, a.rows, a.cols, b.cols);
    Tensor2 { rows: a.rows, cols: b.cols, data }
}

/// dst (cols×rows) = srcᵀ where src is rows×cols, both row-major.
pub fn transpose_into(src: &[f32], dst: &mut [f32], rows: usize, cols: usize) {
    assert_eq!(src.len(), rows * cols);
    assert_eq!(dst.len(), rows * cols);
    for i in 0..rows {
        let srow = &src[i * cols..(i + 1) * cols];
        for (j, &x) in srow.iter().enumerate() {
            dst[j * rows + i] = x;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::matmul_f32;
    use crate::rngx::Rng;

    fn randn(rng: &mut Rng, r: usize, c: usize) -> Tensor2 {
        Tensor2::randn(rng, r, c, 1.0)
    }

    #[test]
    fn known_2x2() {
        let ctx = KernelCtx::sequential();
        let mut ws = Workspace::new();
        let a = Tensor2::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Tensor2::from_vec(2, 2, vec![5., 6., 7., 8.]);
        let c = gemm_f32(&ctx, &a, &b, &mut ws);
        assert_eq!(c.data, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn matches_reference_on_odd_shapes() {
        let ctx = KernelCtx::global();
        let mut ws = Workspace::new();
        let mut rng = Rng::new(7);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 5, 2), (4, 4, 8),
                            (7, 300, 9), (33, 17, 5), (65, 64, 63), (129, 2, 1)] {
            let a = randn(&mut rng, m, k);
            let b = randn(&mut rng, k, n);
            let fast = gemm_f32(&ctx, &a, &b, &mut ws);
            let slow = matmul_f32(&a, &b);
            let mut denom = 0.0f32;
            for x in &slow.data {
                denom = denom.max(x.abs());
            }
            let err = fast.max_abs_diff(&slow) / denom.max(1e-6);
            assert!(err < 1e-4, "({m},{k},{n}): rel err {err}");
            ws.put(fast.data);
        }
    }

    #[test]
    fn thread_counts_are_bitwise_identical() {
        let mut rng = Rng::new(9);
        let a = randn(&mut rng, 70, 33);
        let b = randn(&mut rng, 33, 21);
        let mut ws = Workspace::new();
        let seq = gemm_f32(&KernelCtx::sequential(), &a, &b, &mut ws);
        let par = gemm_f32(&KernelCtx::global(), &a, &b, &mut ws);
        assert_eq!(seq.data, par.data, "reduction order must not depend on threads");
    }

    #[test]
    fn degenerate_dims() {
        let ctx = KernelCtx::sequential();
        let mut c = vec![5.0f32; 6];
        // k = 0: C must be zeroed
        gemm_into(&ctx, &[], &[], &mut c, 2, 0, 3);
        assert!(c.iter().all(|&x| x == 0.0));
        // m = 0 / n = 0: no-ops
        gemm_into(&ctx, &[], &[1.0, 2.0], &mut [], 0, 2, 1);
        gemm_into(&ctx, &[1.0, 2.0], &[], &mut [], 1, 2, 0);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(3);
        let a = randn(&mut rng, 5, 7);
        let mut at = vec![0.0f32; 35];
        let mut back = vec![0.0f32; 35];
        transpose_into(&a.data, &mut at, 5, 7);
        transpose_into(&at, &mut back, 7, 5);
        assert_eq!(a.data, back);
    }
}
