//! Fused softmax/GEMM kernels for the attention factor pipeline.
//!
//! All three kernels parallelize over fixed-size query-row blocks (see
//! the determinism note in the module docs): per output row the
//! arithmetic is a pure function of the inputs, never of the thread
//! count.

use super::gemm::{axpy8, gemm_rows};
use super::workspace::Workspace;
use super::{par_rows, KernelCtx, SendMut, BLOCK_ROWS};
use crate::attention::Tensor2;
use crate::linalg::scaled_softmax_row;

/// Keys streamed per online-softmax block in [`flash_attention`]
/// (mirrors the L1 Pallas flash kernel's key blocking).
const KEY_BLOCK: usize = 128;

/// Materialized softmax factor: F = rowsoftmax(scale · q · ktᵀ).
/// q: (m, d), kt: (c, d) landmarks → (m, c). Used for the c×c A factor
/// (which `ns_pinv` needs in full) and anywhere F itself is the output;
/// the combine step should prefer [`softmax_gemm`], which never
/// materializes F.
pub fn softmax_scores(ctx: &KernelCtx, q: &Tensor2, kt: &Tensor2, scale: f32,
                      ws: &mut Workspace) -> Tensor2 {
    assert_eq!(q.cols, kt.cols, "q/landmark width mismatch");
    let (m, d, c) = (q.rows, q.cols, kt.rows);
    let mut ktt = ws.take(d * c);
    super::gemm::transpose_into(&kt.data, &mut ktt, c, d);
    let mut f = ws.take(m * c);
    super::gemm::gemm_into(ctx, &q.data, &ktt, &mut f, m, d, c);
    ws.put(ktt);
    let mut out = Tensor2 { rows: m, cols: c, data: f };
    par_rows(ctx, &mut out.data, m, c, |_r, row| scaled_softmax_row(row, scale));
    out
}

/// Fused combine: out = rowsoftmax(scale · q · ktᵀ) · x, blocked over
/// query rows so the m×c logits never materialize — each task reuses a
/// `BLOCK_ROWS × c` scratch strip for scores and writes the finished
/// `BLOCK_ROWS × dv` output rows directly.
/// q: (m, d), kt: (c, d), x: (c, dv) → (m, dv).
pub fn softmax_gemm(ctx: &KernelCtx, q: &Tensor2, kt: &Tensor2, x: &Tensor2,
                    scale: f32, ws: &mut Workspace) -> Tensor2 {
    assert_eq!(q.cols, kt.cols, "q/landmark width mismatch");
    assert_eq!(kt.rows, x.rows, "landmark/value length mismatch");
    let (m, d, c, dv) = (q.rows, q.cols, kt.rows, x.cols);
    let mut ktt = ws.take(d * c);
    super::gemm::transpose_into(&kt.data, &mut ktt, c, d);
    let mut out = ws.take(m * dv);
    let nblocks = (m + BLOCK_ROWS - 1) / BLOCK_ROWS;
    let ntasks = ctx.task_count(nblocks);
    let mut scratch = ws.take(ntasks * BLOCK_ROWS * c);
    {
        let obase = SendMut(out.as_mut_ptr());
        let sbase = SendMut(scratch.as_mut_ptr());
        ctx.run_blocks(nblocks, |task, blocks| {
            // SAFETY: one scratch strip per task index, disjoint by
            // construction; out blocks are disjoint row ranges.
            let strip = unsafe {
                std::slice::from_raw_parts_mut(
                    sbase.0.add(task * BLOCK_ROWS * c), BLOCK_ROWS * c)
            };
            for blk in blocks {
                let r0 = blk * BLOCK_ROWS;
                let r1 = (r0 + BLOCK_ROWS).min(m);
                let mb = r1 - r0;
                let scores = &mut strip[..mb * c];
                gemm_rows(&q.data[r0 * d..r1 * d], &ktt, scores, mb, d, c);
                for r in 0..mb {
                    scaled_softmax_row(&mut scores[r * c..(r + 1) * c], scale);
                }
                let oblk = unsafe {
                    std::slice::from_raw_parts_mut(obase.0.add(r0 * dv), mb * dv)
                };
                gemm_rows(scores, &x.data, oblk, mb, c, dv);
            }
        });
    }
    ws.put(scratch);
    ws.put(ktt);
    Tensor2 { rows: m, cols: dv, data: out }
}

/// Exact attention out = softmax(scale · q · kᵀ) · v with the online
/// softmax streamed over [`KEY_BLOCK`]-sized key blocks (logits never
/// materialize beyond one block per row), parallel over query rows.
/// Doubles as the W = rowsoftmax(q̃ kᵀ)·V factor kernel with q = q̃.
/// q: (n, d), k: (mkeys, d), v: (mkeys, dv) → (n, dv).
pub fn flash_attention(ctx: &KernelCtx, q: &Tensor2, k: &Tensor2, v: &Tensor2,
                       scale: f32, ws: &mut Workspace) -> Tensor2 {
    assert_eq!(q.cols, k.cols, "q/k width mismatch");
    assert_eq!(k.rows, v.rows, "k/v length mismatch");
    let (n, dv, mkeys) = (q.rows, v.cols, k.rows);
    let mut out = Tensor2 { rows: n, cols: dv, data: ws.take(n * dv) };
    par_rows(ctx, &mut out.data, n, dv, |i, orow| {
        let qi = q.row(i);
        let mut scores = [0.0f32; KEY_BLOCK];
        let mut m_run = f32::NEG_INFINITY;
        let mut l_run = 0.0f32;
        let mut start = 0;
        while start < mkeys {
            let end = (start + KEY_BLOCK).min(mkeys);
            let mut m_cur = f32::NEG_INFINITY;
            for (jj, j) in (start..end).enumerate() {
                let s = dot8(qi, k.row(j)) * scale;
                scores[jj] = s;
                m_cur = m_cur.max(s);
            }
            let m_new = m_run.max(m_cur);
            let corr = if m_run.is_finite() { (m_run - m_new).exp() } else { 0.0 };
            l_run *= corr;
            for o in orow.iter_mut() {
                *o *= corr;
            }
            for (jj, j) in (start..end).enumerate() {
                let p = (scores[jj] - m_new).exp();
                l_run += p;
                axpy8(orow, p, v.row(j));
            }
            m_run = m_new;
            start = end;
        }
        let inv = 1.0 / l_run;
        for o in orow.iter_mut() {
            *o *= inv;
        }
    });
    out
}

/// f32 dot product, 8-wide unrolled (kernel-core counterpart of the
/// reference `attention::dot_f32`; kept separate so the reference path
/// stays byte-for-byte the seed implementation).
#[inline(always)]
fn dot8(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut acc = [0.0f32; 8];
    let mut i = 0;
    while i + 8 <= n {
        let aj = &a[i..i + 8];
        let bj = &b[i..i + 8];
        for t in 0..8 {
            acc[t] += aj[t] * bj[t];
        }
        i += 8;
    }
    let mut s = ((acc[0] + acc[4]) + (acc[1] + acc[5]))
        + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::matmul_f32;
    use crate::linalg::row_softmax_f32;
    use crate::rngx::Rng;

    fn qkv(seed: u64, n: usize, d: usize) -> (Tensor2, Tensor2, Tensor2) {
        let mut rng = Rng::new(seed);
        (
            Tensor2::randn(&mut rng, n, d, 1.0),
            Tensor2::randn(&mut rng, n, d, 1.0),
            Tensor2::randn(&mut rng, n, d, 1.0),
        )
    }

    /// Reference: materialize F with the naive kernels, then multiply.
    fn softmax_gemm_ref(q: &Tensor2, kt: &Tensor2, x: &Tensor2, scale: f32) -> Tensor2 {
        let mut ktt = Tensor2::zeros(kt.cols, kt.rows);
        super::super::gemm::transpose_into(&kt.data, &mut ktt.data, kt.rows, kt.cols);
        let mut f = matmul_f32(q, &ktt);
        for s in f.data.iter_mut() {
            *s *= scale;
        }
        row_softmax_f32(&mut f.data, f.rows, f.cols);
        matmul_f32(&f, x)
    }

    #[test]
    fn softmax_scores_rows_are_distributions() {
        let (q, k, _) = qkv(1, 97, 16);
        let mut rng = Rng::new(2);
        let kt = Tensor2::randn(&mut rng, 8, 16, 1.0);
        let mut ws = Workspace::new();
        let f = softmax_scores(&KernelCtx::global(), &q, &kt, 0.25, &mut ws);
        assert_eq!((f.rows, f.cols), (97, 8));
        for i in 0..f.rows {
            let s: f32 = f.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "row {i} sums to {s}");
        }
        let _ = k;
    }

    #[test]
    fn softmax_gemm_matches_materialized_reference() {
        let mut ws = Workspace::new();
        let ctx = KernelCtx::global();
        for &(n, d, c, dv) in &[(1usize, 3usize, 2usize, 5usize),
                                (33, 16, 8, 16), (100, 8, 10, 4)] {
            let mut rng = Rng::new(n as u64);
            let q = Tensor2::randn(&mut rng, n, d, 1.0);
            let kt = Tensor2::randn(&mut rng, c, d, 1.0);
            let x = Tensor2::randn(&mut rng, c, dv, 1.0);
            let fast = softmax_gemm(&ctx, &q, &kt, &x, 0.5, &mut ws);
            let slow = softmax_gemm_ref(&q, &kt, &x, 0.5);
            assert!(fast.max_abs_diff(&slow) < 1e-4,
                    "({n},{d},{c},{dv}): {}", fast.max_abs_diff(&slow));
            ws.put(fast.data);
        }
    }

    #[test]
    fn softmax_gemm_threads_bitwise_identical() {
        let mut ws = Workspace::new();
        let mut rng = Rng::new(5);
        let q = Tensor2::randn(&mut rng, 130, 16, 1.0);
        let kt = Tensor2::randn(&mut rng, 8, 16, 1.0);
        let x = Tensor2::randn(&mut rng, 8, 12, 1.0);
        let seq = softmax_gemm(&KernelCtx::sequential(), &q, &kt, &x, 0.3, &mut ws);
        let par = softmax_gemm(&KernelCtx::global(), &q, &kt, &x, 0.3, &mut ws);
        assert_eq!(seq.data, par.data);
    }

    #[test]
    fn flash_attention_matches_dense_softmax() {
        let (q, k, v) = qkv(4, 150, 8);
        let mut ws = Workspace::new();
        let scale = 1.0 / (8f32).sqrt();
        let fast = flash_attention(&KernelCtx::global(), &q, &k, &v, scale, &mut ws);
        // dense reference via softmax_gemm_ref with landmark set = keys
        let slow = softmax_gemm_ref(&q, &k, &v, scale);
        assert!(fast.max_abs_diff(&slow) < 1e-4, "{}", fast.max_abs_diff(&slow));
    }

    #[test]
    fn dot8_matches_naive() {
        let mut rng = Rng::new(6);
        for n in [0usize, 1, 7, 8, 9, 16, 31] {
            let a = Tensor2::randn(&mut rng, 1, n.max(1), 1.0);
            let b = Tensor2::randn(&mut rng, 1, n.max(1), 1.0);
            let a = &a.data[..n];
            let b = &b.data[..n];
            let want: f64 = a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum();
            assert!((dot8(a, b) as f64 - want).abs() < 1e-4);
        }
    }
}
