//! Fused softmax/GEMM kernels for the attention factor pipeline.
//!
//! All three kernels parallelize over fixed-size query-row blocks (see
//! the determinism note in the module docs): per output row the
//! arithmetic is a pure function of the inputs, never of the thread
//! count.

use super::gemm::gemm_rows;
use super::workspace::Workspace;
use super::{par_rows, simd, Isa, KernelCtx, SendMut, BLOCK_ROWS};
use crate::attention::Tensor2;
use crate::linalg::scaled_softmax_row;

/// Keys streamed per online-softmax block in [`flash_attention`]
/// (mirrors the L1 Pallas flash kernel's key blocking).
const KEY_BLOCK: usize = 128;

/// Materialized softmax factor: F = rowsoftmax(scale · q · ktᵀ).
/// q: (m, d), kt: (c, d) landmarks → (m, c). Used for the c×c A factor
/// (which `ns_pinv` needs in full) and anywhere F itself is the output;
/// the combine step should prefer [`softmax_gemm`], which never
/// materializes F.
pub fn softmax_scores(ctx: &KernelCtx, q: &Tensor2, kt: &Tensor2, scale: f32,
                      ws: &mut Workspace) -> Tensor2 {
    assert_eq!(q.cols, kt.cols, "q/landmark width mismatch");
    let (m, d, c) = (q.rows, q.cols, kt.rows);
    let mut ktt = ws.take(d * c);
    super::gemm::transpose_into(&kt.data, &mut ktt, c, d);
    let mut f = ws.take(m * c);
    super::gemm::gemm_into(ctx, &q.data, &ktt, &mut f, m, d, c);
    ws.put(ktt);
    let mut out = Tensor2 { rows: m, cols: c, data: f };
    par_rows(ctx, &mut out.data, m, c, |_r, row| scaled_softmax_row(row, scale));
    out
}

/// Fused combine: out = rowsoftmax(scale · q · ktᵀ) · x, blocked over
/// query rows so the m×c logits never materialize — each task reuses a
/// `BLOCK_ROWS × c` scratch strip for scores and writes the finished
/// `BLOCK_ROWS × dv` output rows directly.
/// q: (m, d), kt: (c, d), x: (c, dv) → (m, dv).
pub fn softmax_gemm(ctx: &KernelCtx, q: &Tensor2, kt: &Tensor2, x: &Tensor2,
                    scale: f32, ws: &mut Workspace) -> Tensor2 {
    assert_eq!(q.cols, kt.cols, "q/landmark width mismatch");
    assert_eq!(kt.rows, x.rows, "landmark/value length mismatch");
    let (m, d, c, dv) = (q.rows, q.cols, kt.rows, x.cols);
    let isa = ctx.isa();
    let mut ktt = ws.take(d * c);
    super::gemm::transpose_into(&kt.data, &mut ktt, c, d);
    let mut out = ws.take(m * dv);
    let nblocks = (m + BLOCK_ROWS - 1) / BLOCK_ROWS;
    let ntasks = ctx.task_count(nblocks);
    let mut scratch = ws.take(ntasks * BLOCK_ROWS * c);
    {
        let obase = SendMut(out.as_mut_ptr());
        let sbase = SendMut(scratch.as_mut_ptr());
        ctx.run_blocks(nblocks, |task, blocks| {
            // SAFETY: one scratch strip per task index, disjoint by
            // construction; out blocks are disjoint row ranges.
            let strip = unsafe {
                std::slice::from_raw_parts_mut(
                    sbase.0.add(task * BLOCK_ROWS * c), BLOCK_ROWS * c)
            };
            for blk in blocks {
                let r0 = blk * BLOCK_ROWS;
                let r1 = (r0 + BLOCK_ROWS).min(m);
                let mb = r1 - r0;
                let scores = &mut strip[..mb * c];
                gemm_rows(isa, &q.data[r0 * d..r1 * d], &ktt, scores, mb, d, c);
                for r in 0..mb {
                    scaled_softmax_row(&mut scores[r * c..(r + 1) * c], scale);
                }
                let oblk = unsafe {
                    std::slice::from_raw_parts_mut(obase.0.add(r0 * dv), mb * dv)
                };
                gemm_rows(isa, scores, &x.data, oblk, mb, c, dv);
            }
        });
    }
    ws.put(scratch);
    ws.put(ktt);
    Tensor2 { rows: m, cols: dv, data: out }
}

/// Exact attention out = softmax(scale · q · kᵀ) · v with the online
/// softmax streamed over [`KEY_BLOCK`]-sized key blocks (logits never
/// materialize beyond one block per row), parallel over query rows.
/// Doubles as the W = rowsoftmax(q̃ kᵀ)·V factor kernel with q = q̃.
/// q: (n, d), k: (mkeys, d), v: (mkeys, dv) → (n, dv).
pub fn flash_attention(ctx: &KernelCtx, q: &Tensor2, k: &Tensor2, v: &Tensor2,
                       scale: f32, ws: &mut Workspace) -> Tensor2 {
    assert_eq!(q.cols, k.cols, "q/k width mismatch");
    assert_eq!(k.rows, v.rows, "k/v length mismatch");
    let (n, dv, mkeys) = (q.rows, v.cols, k.rows);
    let isa = ctx.isa();
    let mut out = Tensor2 { rows: n, cols: dv, data: ws.take(n * dv) };
    par_rows(ctx, &mut out.data, n, dv, |i, orow| {
        let qi = q.row(i);
        let mut scores = [0.0f32; KEY_BLOCK];
        let mut m_run = f32::NEG_INFINITY;
        let mut l_run = 0.0f32;
        let mut start = 0;
        while start < mkeys {
            let end = (start + KEY_BLOCK).min(mkeys);
            let mut m_cur = f32::NEG_INFINITY;
            for (jj, j) in (start..end).enumerate() {
                let s = simd::dot(isa, qi, k.row(j)) * scale;
                scores[jj] = s;
                m_cur = m_cur.max(s);
            }
            let m_new = m_run.max(m_cur);
            let corr = if m_run.is_finite() { (m_run - m_new).exp() } else { 0.0 };
            l_run *= corr;
            for o in orow.iter_mut() {
                *o *= corr;
            }
            for (jj, j) in (start..end).enumerate() {
                let p = (scores[jj] - m_new).exp();
                l_run += p;
                simd::axpy(isa, orow, p, v.row(j));
            }
            m_run = m_new;
            start = end;
        }
        let inv = 1.0 / l_run;
        for o in orow.iter_mut() {
            *o *= inv;
        }
    });
    out
}

/// Fused layer normalization with gain and bias, row-parallel:
/// out[i,j] = (x[i,j] − μᵢ)/√(σᵢ² + eps) · gain[j] + bias[j].
///
/// μ/σ² accumulate in a fixed order that depends only on the arm and
/// the row contents (scalar: left-to-right; SIMD: lane accumulators
/// with a hardcoded horizontal pairing), never the thread count, so
/// outputs inherit the kernel-core bitwise thread-count determinism.
/// The output tensor is backed by `ws` scratch — recycle with
/// `ws.put(out.data)`.
pub fn layernorm(ctx: &KernelCtx, x: &Tensor2, gain: &[f32], bias: &[f32],
                 eps: f32, ws: &mut Workspace) -> Tensor2 {
    let (n, d) = (x.rows, x.cols);
    assert_eq!(gain.len(), d, "layernorm gain width");
    assert_eq!(bias.len(), d, "layernorm bias width");
    let isa = ctx.isa();
    let mut out = Tensor2 { rows: n, cols: d, data: ws.take(n * d) };
    par_rows(ctx, &mut out.data, n, d, |i, orow| {
        let xrow = x.row(i);
        let (mean, var) = simd::moments(isa, xrow);
        let inv = 1.0 / (var + eps).sqrt();
        simd::ln_affine(isa, orow, xrow, mean, inv, gain, bias);
    });
    out
}

/// Fused bias + GELU (tanh form), in place and row-parallel:
/// x[i,j] ← gelu(x[i,j] + bias[j]). This is the FFN activation the
/// encoder stack runs between its two GEMMs; fusing the bias add into
/// the activation pass saves one full traversal of the (n × ffn) tensor.
/// The bias add is a single rounding in every arm and the GELU itself
/// stays scalar, so `bias_gelu` output is bitwise identical across
/// arms (not just within one).
pub fn bias_gelu(ctx: &KernelCtx, x: &mut Tensor2, bias: &[f32]) {
    assert_eq!(bias.len(), x.cols, "bias width mismatch");
    let (n, d) = (x.rows, x.cols);
    let isa = ctx.isa();
    par_rows(ctx, &mut x.data, n, d, |_i, row| {
        if isa == Isa::Scalar {
            // seed single-pass form
            for (v, &b) in row.iter_mut().zip(bias) {
                *v = gelu(*v + b);
            }
        } else {
            simd::add_bias(isa, row, bias);
            for v in row.iter_mut() {
                *v = gelu(*v);
            }
        }
    });
}

/// GELU, tanh approximation (the form the exported encoder uses):
/// 0.5·z·(1 + tanh(√(2/π)·(z + 0.044715·z³))).
#[inline(always)]
pub fn gelu(z: f32) -> f32 {
    const SQRT_2_OVER_PI: f32 = 0.797_884_56;
    0.5 * z * (1.0 + (SQRT_2_OVER_PI * (z + 0.044_715 * z * z * z)).tanh())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::matmul_f32;
    use crate::linalg::row_softmax_f32;
    use crate::rngx::Rng;

    fn qkv(seed: u64, n: usize, d: usize) -> (Tensor2, Tensor2, Tensor2) {
        let mut rng = Rng::new(seed);
        (
            Tensor2::randn(&mut rng, n, d, 1.0),
            Tensor2::randn(&mut rng, n, d, 1.0),
            Tensor2::randn(&mut rng, n, d, 1.0),
        )
    }

    /// Reference: materialize F with the naive kernels, then multiply.
    fn softmax_gemm_ref(q: &Tensor2, kt: &Tensor2, x: &Tensor2, scale: f32) -> Tensor2 {
        let mut ktt = Tensor2::zeros(kt.cols, kt.rows);
        super::super::gemm::transpose_into(&kt.data, &mut ktt.data, kt.rows, kt.cols);
        let mut f = matmul_f32(q, &ktt);
        for s in f.data.iter_mut() {
            *s *= scale;
        }
        row_softmax_f32(&mut f.data, f.rows, f.cols);
        matmul_f32(&f, x)
    }

    #[test]
    fn softmax_scores_rows_are_distributions() {
        let (q, k, _) = qkv(1, 97, 16);
        let mut rng = Rng::new(2);
        let kt = Tensor2::randn(&mut rng, 8, 16, 1.0);
        let mut ws = Workspace::new();
        let f = softmax_scores(&KernelCtx::global(), &q, &kt, 0.25, &mut ws);
        assert_eq!((f.rows, f.cols), (97, 8));
        for i in 0..f.rows {
            let s: f32 = f.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "row {i} sums to {s}");
        }
        let _ = k;
    }

    #[test]
    fn softmax_gemm_matches_materialized_reference() {
        let mut ws = Workspace::new();
        let ctx = KernelCtx::global();
        for &(n, d, c, dv) in &[(1usize, 3usize, 2usize, 5usize),
                                (33, 16, 8, 16), (100, 8, 10, 4)] {
            let mut rng = Rng::new(n as u64);
            let q = Tensor2::randn(&mut rng, n, d, 1.0);
            let kt = Tensor2::randn(&mut rng, c, d, 1.0);
            let x = Tensor2::randn(&mut rng, c, dv, 1.0);
            let fast = softmax_gemm(&ctx, &q, &kt, &x, 0.5, &mut ws);
            let slow = softmax_gemm_ref(&q, &kt, &x, 0.5);
            assert!(fast.max_abs_diff(&slow) < 1e-4,
                    "({n},{d},{c},{dv}): {}", fast.max_abs_diff(&slow));
            ws.put(fast.data);
        }
    }

    #[test]
    fn softmax_gemm_threads_bitwise_identical() {
        let mut ws = Workspace::new();
        let mut rng = Rng::new(5);
        let q = Tensor2::randn(&mut rng, 130, 16, 1.0);
        let kt = Tensor2::randn(&mut rng, 8, 16, 1.0);
        let x = Tensor2::randn(&mut rng, 8, 12, 1.0);
        let seq = softmax_gemm(&KernelCtx::sequential(), &q, &kt, &x, 0.3, &mut ws);
        let par = softmax_gemm(&KernelCtx::global(), &q, &kt, &x, 0.3, &mut ws);
        assert_eq!(seq.data, par.data);
    }

    #[test]
    fn flash_attention_matches_dense_softmax() {
        let (q, k, v) = qkv(4, 150, 8);
        let mut ws = Workspace::new();
        let scale = 1.0 / (8f32).sqrt();
        let fast = flash_attention(&KernelCtx::global(), &q, &k, &v, scale, &mut ws);
        // dense reference via softmax_gemm_ref with landmark set = keys
        let slow = softmax_gemm_ref(&q, &k, &v, scale);
        assert!(fast.max_abs_diff(&slow) < 1e-4, "{}", fast.max_abs_diff(&slow));
    }

    #[test]
    fn layernorm_rows_are_normalized() {
        let mut rng = Rng::new(11);
        let x = Tensor2::randn(&mut rng, 40, 16, 3.0);
        let gain = vec![1.0f32; 16];
        let bias = vec![0.0f32; 16];
        let mut ws = Workspace::new();
        let y = layernorm(&KernelCtx::global(), &x, &gain, &bias, 1e-5, &mut ws);
        for i in 0..y.rows {
            let mean: f32 = y.row(i).iter().sum::<f32>() / 16.0;
            let var: f32 = y.row(i).iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 16.0;
            assert!(mean.abs() < 1e-5, "row {i} mean {mean}");
            assert!((var - 1.0).abs() < 1e-3, "row {i} var {var}");
        }
    }

    #[test]
    fn layernorm_applies_gain_and_bias() {
        let x = Tensor2::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        let gain = vec![2.0f32; 4];
        let bias = vec![10.0f32; 4];
        let mut ws = Workspace::new();
        let y = layernorm(&KernelCtx::sequential(), &x, &gain, &bias, 1e-5, &mut ws);
        // plain LN of the same row, scaled by 2 and shifted by 10
        let plain = layernorm(&KernelCtx::sequential(), &x,
                              &[1.0; 4], &[0.0; 4], 1e-5, &mut ws);
        for j in 0..4 {
            assert!((y.data[j] - (2.0 * plain.data[j] + 10.0)).abs() < 1e-5);
        }
    }

    #[test]
    fn layernorm_threads_bitwise_identical() {
        let mut rng = Rng::new(12);
        let x = Tensor2::randn(&mut rng, 130, 32, 1.0);
        let mut gain = vec![0.0f32; 32];
        let mut bias = vec![0.0f32; 32];
        rng.fill_normal_f32(&mut gain, 1.0, 0.1);
        rng.fill_normal_f32(&mut bias, 0.0, 0.1);
        let mut ws = Workspace::new();
        let seq = layernorm(&KernelCtx::sequential(), &x, &gain, &bias, 1e-5, &mut ws);
        let par = layernorm(&KernelCtx::global(), &x, &gain, &bias, 1e-5, &mut ws);
        assert_eq!(seq.data, par.data);
    }

    #[test]
    fn bias_gelu_matches_scalar_and_is_deterministic() {
        let mut rng = Rng::new(13);
        let base = Tensor2::randn(&mut rng, 70, 24, 2.0);
        let mut bias = vec![0.0f32; 24];
        rng.fill_normal_f32(&mut bias, 0.0, 0.5);
        let mut a = base.clone();
        bias_gelu(&KernelCtx::global(), &mut a, &bias);
        let mut b = base.clone();
        bias_gelu(&KernelCtx::sequential(), &mut b, &bias);
        assert_eq!(a.data, b.data, "thread count must not change bits");
        for (i, (&got, &x)) in a.data.iter().zip(&base.data).enumerate() {
            let want = gelu(x + bias[i % 24]);
            assert_eq!(got, want, "element {i}");
        }
    }

    #[test]
    fn gelu_known_values() {
        assert_eq!(gelu(0.0), 0.0);
        // gelu(x) → x for large x, → 0 for very negative x
        assert!((gelu(10.0) - 10.0).abs() < 1e-4);
        assert!(gelu(-10.0).abs() < 1e-4);
        // tanh-form value at 1.0 ≈ 0.8412
        assert!((gelu(1.0) - 0.8412).abs() < 1e-3);
    }

    #[test]
    fn bias_gelu_is_bitwise_arm_invariant() {
        let mut rng = Rng::new(14);
        let base = Tensor2::randn(&mut rng, 33, 17, 2.0);
        let mut bias = vec![0.0f32; 17];
        rng.fill_normal_f32(&mut bias, 0.0, 0.5);
        let mut want = base.clone();
        bias_gelu(&KernelCtx::sequential().with_isa(Isa::Scalar),
                  &mut want, &bias);
        for isa in Isa::available() {
            let mut got = base.clone();
            bias_gelu(&KernelCtx::sequential().with_isa(isa), &mut got, &bias);
            assert_eq!(got.data, want.data, "{}", isa.token());
        }
    }
}
