//! Quantized-weight GEMM tiers: bf16 and int8 paths for the serving
//! admission policy (`coordinator::admission`).
//!
//! The paper's framing makes accuracy a budgeted resource — spectral
//! shifting buys a stronger error bound at the same O(n) cost — and
//! this module extends that budget axis *below* f32: weights are
//! quantized **once** (at checkpoint/engine load, never per request)
//! into a [`QuantMatrix`], and [`gemm_quant_into`] runs the product
//! with **f32 accumulation** through the exact same packed-panel
//! blocking and [`KernelCtx`] ISA dispatch as the f32 path — the
//! quantized weights are expanded into workspace scratch and handed to
//! [`gemm_into`], so blocking constants, block boundaries, and the
//! per-arm micro-kernels are literally shared, not re-implemented.
//!
//! Formats:
//!
//! * **bf16** — truncation of the f32 high half (round-toward-zero on
//!   the 8-bit mantissa). Expansion is exact: `(h as u32) << 16`
//!   reproduces an f32 whose low mantissa bits are zero.
//! * **int8** — per-row absmax scaling: row `r` stores
//!   `scale_r = absmax_r / 127` and `q = round(w / scale_r)` clamped to
//!   `[-127, 127]`; expansion is `q as f32 * scale_r`. A zero row has
//!   `scale_r = 0` and expands to exact zeros.
//!
//! # Invariants
//!
//! * **Deterministic within an arm** — quantization is a pure
//!   elementwise function of the weights, and the product runs on
//!   [`gemm_into`], so the fixed-block thread-count-determinism
//!   contract of the f32 path carries over bitwise (tested below and
//!   in the per-arm suite).
//! * **Documented error envelopes** — against the f32 reference on
//!   unit-scale Gaussian weights, the relative Frobenius error of a
//!   quantized product stays under `1e-2` for bf16 and `5e-2` for
//!   int8 (the envelopes `tests` pin and `coordinator::admission`'s
//!   default tier table is calibrated against; the *measured* per-tier
//!   numbers on trained weights live in `BENCH_error_bound.json`).
//! * **Quantize-once** — a [`QuantMatrix`] never rescales after
//!   construction; serving the same tier twice is bitwise identical.

use super::gemm::gemm_into;
use super::workspace::Workspace;
use super::KernelCtx;

/// A weight-precision tier. `F32` is the identity tier (no
/// [`QuantMatrix`] exists for it — full-precision weights never leave
/// their original buffers).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Precision {
    F32,
    Bf16,
    Int8,
}

impl Precision {
    /// Every tier, in decreasing-precision order (report order).
    pub const ALL: [Precision; 3] =
        [Precision::F32, Precision::Bf16, Precision::Int8];

    /// Parse a precision token (config/wire casing-insensitive).
    pub fn parse(s: &str) -> Option<Precision> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f32" | "fp32" => Some(Precision::F32),
            "bf16" => Some(Precision::Bf16),
            "int8" | "i8" => Some(Precision::Int8),
            _ => None,
        }
    }

    /// Canonical token (inverse of [`Precision::parse`]).
    pub fn token(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Bf16 => "bf16",
            Precision::Int8 => "int8",
        }
    }
}

/// Storage of one quantized weight matrix (row-major `rows × cols`,
/// same layout as the f32 weight it was built from).
enum QuantData {
    /// f32 high halves; expansion shifts them back up exactly.
    Bf16(Vec<u16>),
    /// Row-quantized values plus one f32 scale per row.
    Int8 { q: Vec<i8>, scales: Vec<f32> },
}

/// A weight matrix quantized once at load time. Holds everything
/// [`gemm_quant_into`] needs to expand the weights into scratch;
/// construction is the only place scales are computed.
pub struct QuantMatrix {
    rows: usize,
    cols: usize,
    data: QuantData,
}

/// Truncate one f32 to its bf16 bit pattern (high half).
#[inline]
pub fn bf16_from_f32(x: f32) -> u16 {
    (x.to_bits() >> 16) as u16
}

/// Expand one bf16 bit pattern back to f32 (exact).
#[inline]
pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

impl QuantMatrix {
    /// Quantize a row-major `rows × cols` f32 weight. Panics on
    /// `Precision::F32` — the identity tier has no quantized form —
    /// and on a length mismatch.
    pub fn quantize(w: &[f32], rows: usize, cols: usize,
                    precision: Precision) -> QuantMatrix {
        assert_eq!(w.len(), rows * cols, "quantize: weight is not rows×cols");
        let data = match precision {
            Precision::F32 => {
                panic!("f32 is the identity tier; nothing to quantize")
            }
            Precision::Bf16 => {
                QuantData::Bf16(w.iter().map(|&x| bf16_from_f32(x)).collect())
            }
            Precision::Int8 => {
                let mut q = Vec::with_capacity(w.len());
                let mut scales = Vec::with_capacity(rows);
                for r in 0..rows {
                    let row = &w[r * cols..(r + 1) * cols];
                    let absmax = row.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                    let scale = absmax / 127.0;
                    scales.push(scale);
                    if scale == 0.0 {
                        q.extend(std::iter::repeat(0i8).take(cols));
                    } else {
                        q.extend(row.iter().map(|&x| {
                            (x / scale).round().clamp(-127.0, 127.0) as i8
                        }));
                    }
                }
                QuantData::Int8 { q, scales }
            }
        };
        QuantMatrix { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// The tier this matrix was quantized to.
    pub fn precision(&self) -> Precision {
        match self.data {
            QuantData::Bf16(_) => Precision::Bf16,
            QuantData::Int8 { .. } => Precision::Int8,
        }
    }

    /// Expand into `out` (length `rows × cols`). Pure and exact: the
    /// expanded values ARE the tier's weight lattice, so expanding
    /// twice is bitwise identical.
    pub fn dequantize_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.rows * self.cols,
                   "dequantize: out is not rows×cols");
        match &self.data {
            QuantData::Bf16(h) => {
                for (o, &b) in out.iter_mut().zip(h) {
                    *o = bf16_to_f32(b);
                }
            }
            QuantData::Int8 { q, scales } => {
                for r in 0..self.rows {
                    let s = scales[r];
                    let src = &q[r * self.cols..(r + 1) * self.cols];
                    let dst = &mut out[r * self.cols..(r + 1) * self.cols];
                    for (o, &v) in dst.iter_mut().zip(src) {
                        *o = v as f32 * s;
                    }
                }
            }
        }
    }

    /// Expand into a workspace buffer (caller returns it with
    /// `ws.put`). Zero steady-state allocation once the arena is warm.
    pub fn dequantize(&self, ws: &mut Workspace) -> Vec<f32> {
        let mut buf = ws.take(self.rows * self.cols);
        self.dequantize_into(&mut buf);
        buf
    }
}

/// `C = A · B̃` where `B̃` is the quantized weight expanded to its
/// tier lattice: f32 accumulation, identical packed-panel blocking and
/// ISA dispatch to [`gemm_into`] (which this literally calls). `a` is
/// `m × k` f32, `bq` must be `k × n`, `c` is `m × n`.
pub fn gemm_quant_into(ctx: &KernelCtx, a: &[f32], bq: &QuantMatrix,
                       c: &mut [f32], m: usize, k: usize, n: usize,
                       ws: &mut Workspace) {
    assert_eq!((bq.rows, bq.cols), (k, n), "gemm_quant: B is not k×n");
    let b = bq.dequantize(ws);
    gemm_into(ctx, a, &b, c, m, k, n);
    ws.put(b);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Isa;
    use crate::rngx::Rng;

    fn randn(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn rel_fro(a: &[f32], b: &[f32]) -> f64 {
        let mut d = 0.0f64;
        let mut r = 0.0f64;
        for (&x, &y) in a.iter().zip(b) {
            d += ((x - y) as f64).powi(2);
            r += (y as f64).powi(2);
        }
        (d / r.max(1e-30)).sqrt()
    }

    #[test]
    fn precision_tokens_round_trip() {
        for p in Precision::ALL {
            assert_eq!(Precision::parse(p.token()), Some(p));
        }
        assert_eq!(Precision::parse(" BF16 "), Some(Precision::Bf16));
        assert_eq!(Precision::parse("i8"), Some(Precision::Int8));
        assert!(Precision::parse("fp8").is_none());
        assert!(Precision::parse("").is_none());
    }

    #[test]
    fn bf16_truncation_is_exact_on_8bit_mantissas() {
        // values with ≤8 mantissa bits survive the round trip bitwise
        for x in [0.0f32, 1.0, -1.0, 0.5, -2.75, 1024.0, -0.015625] {
            assert_eq!(bf16_to_f32(bf16_from_f32(x)), x, "{x}");
        }
        // a value needing more mantissa keeps its high half only
        let x = 1.0 + f32::EPSILON;
        assert_eq!(bf16_to_f32(bf16_from_f32(x)), 1.0);
    }

    #[test]
    fn int8_scales_are_per_row_absmax() {
        // row 0 absmax 4 → scale 4/127; row 1 all zero → scale 0
        let w = vec![2.0f32, -4.0, 1.0, 0.0, 0.0, 0.0];
        let q = QuantMatrix::quantize(&w, 2, 3, Precision::Int8);
        let mut out = vec![0.0f32; 6];
        q.dequantize_into(&mut out);
        let s = 4.0f32 / 127.0;
        // absmax element is exact; others land on the row lattice
        assert_eq!(out[1], -127.0 * s);
        assert_eq!(out[0], (2.0f32 / s).round() * s);
        assert_eq!(&out[3..], &[0.0, 0.0, 0.0], "zero row stays exact zero");
        assert_eq!(q.precision(), Precision::Int8);
    }

    #[test]
    fn dequantize_is_bitwise_repeatable() {
        let mut rng = Rng::new(31);
        let w = randn(&mut rng, 24 * 16);
        for p in [Precision::Bf16, Precision::Int8] {
            let q = QuantMatrix::quantize(&w, 24, 16, p);
            let mut a = vec![0.0f32; w.len()];
            let mut b = vec![1.0f32; w.len()];
            q.dequantize_into(&mut a);
            q.dequantize_into(&mut b);
            assert_eq!(a, b, "{p:?} expansion must be pure");
        }
    }

    #[test]
    fn quant_gemm_is_bitwise_the_f32_gemm_on_the_expanded_weights() {
        // the load-bearing equivalence: the quantized path IS the f32
        // path on the tier's weight lattice — same blocking, same arm,
        // same accumulation order
        let (m, k, n) = (33, 40, 17);
        let mut rng = Rng::new(7);
        let a = randn(&mut rng, m * k);
        let w = randn(&mut rng, k * n);
        let mut ws = Workspace::new();
        for p in [Precision::Bf16, Precision::Int8] {
            let q = QuantMatrix::quantize(&w, k, n, p);
            let mut expanded = vec![0.0f32; k * n];
            q.dequantize_into(&mut expanded);
            let mut c_ref = vec![0.0f32; m * n];
            gemm_into(&KernelCtx::global(), &a, &expanded, &mut c_ref,
                      m, k, n);
            let mut c_q = vec![0.0f32; m * n];
            gemm_quant_into(&KernelCtx::global(), &a, &q, &mut c_q,
                            m, k, n, &mut ws);
            assert_eq!(c_q, c_ref, "{p:?}");
        }
    }

    #[test]
    fn per_arm_parity_stays_inside_the_documented_envelopes() {
        // bf16 ≤ 1e-2, int8 ≤ 5e-2 relative Frobenius error vs the f32
        // product — the envelopes the admission tier table trusts
        let (m, k, n) = (48, 64, 32);
        let mut rng = Rng::new(91);
        let a = randn(&mut rng, m * k);
        let w = randn(&mut rng, k * n);
        let mut ws = Workspace::new();
        for isa in Isa::available() {
            let ctx = KernelCtx::sequential().with_isa(isa);
            let mut c_ref = vec![0.0f32; m * n];
            gemm_into(&ctx, &a, &w, &mut c_ref, m, k, n);
            for (p, envelope) in
                [(Precision::Bf16, 1e-2), (Precision::Int8, 5e-2)]
            {
                let q = QuantMatrix::quantize(&w, k, n, p);
                let mut c_q = vec![0.0f32; m * n];
                gemm_quant_into(&ctx, &a, &q, &mut c_q, m, k, n, &mut ws);
                let err = rel_fro(&c_q, &c_ref);
                assert!(err > 0.0, "{p:?}/{}: suspicious exact match \
                                    on Gaussian weights", isa.token());
                assert!(err < envelope,
                        "{p:?}/{}: rel err {err} breaks envelope {envelope}",
                        isa.token());
            }
        }
    }

    #[test]
    fn thread_counts_are_bitwise_identical_within_a_tier() {
        let (m, k, n) = (70, 33, 19);
        let mut rng = Rng::new(17);
        let a = randn(&mut rng, m * k);
        let w = randn(&mut rng, k * n);
        let mut ws = Workspace::new();
        for p in [Precision::Bf16, Precision::Int8] {
            let q = QuantMatrix::quantize(&w, k, n, p);
            let mut seq = vec![0.0f32; m * n];
            let mut par = vec![0.0f32; m * n];
            gemm_quant_into(&KernelCtx::sequential(), &a, &q, &mut seq,
                            m, k, n, &mut ws);
            gemm_quant_into(&KernelCtx::global(), &a, &q, &mut par,
                            m, k, n, &mut ws);
            assert_eq!(seq, par, "{p:?}");
        }
    }

    #[test]
    fn degenerate_dims_do_not_panic() {
        let mut ws = Workspace::new();
        let q = QuantMatrix::quantize(&[], 0, 4, Precision::Int8);
        let mut c = vec![0.0f32; 0];
        gemm_quant_into(&KernelCtx::sequential(), &[], &q, &mut c,
                        0, 0, 4, &mut ws);
    }
}
