//! Deterministic PRNG + distributions substrate.
//!
//! The crate cache has no `rand`; this module provides the xoshiro256**
//! generator (Blackman & Vigna) seeded via SplitMix64, plus the
//! distributions the workload generator and benches need: uniform,
//! standard normal (Box-Muller), exponential (inter-arrival times) and
//! Zipf (request-length skew).

/// xoshiro256** — fast, high-quality 64-bit PRNG with 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller variate
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically; any u64 gives a full-period generator.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Next raw u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1) double
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift; bias negligible for our uses but we
        // keep the rejection loop for exactness.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // avoid log(0)
        let u1 = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare_normal = Some(r * s);
        r * c
    }

    /// Exponential with rate lambda (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Zipf-distributed integer in [1, n] with exponent `s` (rejection
    /// sampling, Devroye). Used for skewed request-length workloads.
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        debug_assert!(n >= 1);
        if n == 1 {
            return 1;
        }
        // inversion-rejection after Jason Crease / Devroye
        let t = ((n as f64).powf(1.0 - s) - s) / (1.0 - s);
        loop {
            let p = self.uniform() * t;
            let x = if p <= 1.0 {
                p
            } else {
                (p * (1.0 - s) + s).powf(1.0 / (1.0 - s))
            };
            let k = (x as u64).clamp(1, n);
            let ratio = (k as f64).powf(-s)
                / if k == 1 { 1.0 } else { x.powf(-s) };
            if self.uniform() < ratio {
                return k;
            }
        }
    }

    /// Fill a slice with standard-normal f32s.
    pub fn fill_normal_f32(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for x in out.iter_mut() {
            *x = mean + std * self.normal() as f32;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval_with_sane_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(4);
        let n = 50_000;
        let lambda = 2.5;
        let mean: f64 = (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn zipf_is_skewed_and_bounded() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let mut counts = [0usize; 11];
        for _ in 0..n {
            let k = r.zipf(10, 1.2) as usize;
            assert!((1..=10).contains(&k));
            counts[k] += 1;
        }
        assert!(counts[1] > counts[2]);
        assert!(counts[2] > counts[5]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(8);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 20);
        assert!(t.iter().all(|&i| i < 50));
    }
}
