//! Sparse (local + strided) attention — the Table-1 O(n√n) baseline
//! (Child et al. 2019 "Sparse Transformer", fixed pattern, non-causal).
//!
//! Each query attends to (a) a local window of w = √n neighbours and
//! (b) every s-th "summary" column with stride s = √n, giving O(n·√n)
//! score evaluations.

use super::{axpy_f32, default_scale, dot_f32, Tensor2};
use crate::model::AttentionOp;

/// Sparse local+strided attention as a pluggable [`AttentionOp`].
/// Reference-grade: scalar per head (like [`LshOp`](super::lsh::LshOp)),
/// parallelism comes from the heads × requests fan-out around it. As
/// with `LshOp`, the output is copied into `ws` scratch so arena
/// take/put stays balanced under the batched executor.
#[derive(Clone, Copy, Debug, Default)]
pub struct SparseOp {
    /// Local window half-width; `None` derives √n.
    pub window: Option<usize>,
    /// Summary-column stride; `None` derives √n.
    pub stride: Option<usize>,
}

impl AttentionOp for SparseOp {
    fn name(&self) -> &'static str {
        "sparse"
    }

    fn attend(&self, _ctx: &crate::kernels::KernelCtx, q: &Tensor2, k: &Tensor2,
              v: &Tensor2, ws: &mut crate::kernels::Workspace) -> Tensor2 {
        let out = sparse_attention(q, k, v, self.window, self.stride, None);
        let mut data = ws.take(out.rows * out.cols);
        data.copy_from_slice(&out.data);
        Tensor2 { rows: out.rows, cols: out.cols, data }
    }
}

/// Sparse attention with window and stride both ≈ √n (overridable).
pub fn sparse_attention(q: &Tensor2, k: &Tensor2, v: &Tensor2,
                        window: Option<usize>, stride: Option<usize>,
                        scale: Option<f32>) -> Tensor2 {
    assert_eq!(q.cols, k.cols);
    assert_eq!(k.rows, v.rows);
    let n = q.rows;
    let m = k.rows;
    let scale = scale.unwrap_or_else(|| default_scale(q.cols));
    let root = (m as f64).sqrt().ceil() as usize;
    let w = window.unwrap_or(root).max(1);
    let s = stride.unwrap_or(root).max(1);

    let mut out = Tensor2::zeros(n, v.cols);
    let mut idx: Vec<usize> = Vec::with_capacity(2 * w + m / s + 2);
    let mut scores: Vec<f32> = Vec::with_capacity(2 * w + m / s + 2);
    for i in 0..n {
        let qi = q.row(i);
        idx.clear();
        scores.clear();
        // local window centred on the aligned position
        let center = i.min(m - 1);
        let lo = center.saturating_sub(w);
        let hi = (center + w + 1).min(m);
        for j in lo..hi {
            idx.push(j);
        }
        // strided summary columns
        let mut j = 0;
        while j < m {
            if j < lo || j >= hi {
                idx.push(j);
            }
            j += s;
        }
        // softmax over the selected set
        let mut mx = f32::NEG_INFINITY;
        for &j in &idx {
            let sc = dot_f32(qi, k.row(j)) * scale;
            scores.push(sc);
            mx = mx.max(sc);
        }
        let mut sum = 0.0f32;
        for sc in scores.iter_mut() {
            *sc = (*sc - mx).exp();
            sum += *sc;
        }
        let inv = 1.0 / sum;
        let orow = out.row_mut(i);
        for (&j, &p) in idx.iter().zip(&scores) {
            axpy_f32(orow, p * inv, v.row(j));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::full::softmax_attention;
    use crate::attention::testutil::{qkv, rel_err};

    #[test]
    fn full_window_recovers_exact() {
        let (q, k, v) = qkv(1, 64, 8);
        let got = sparse_attention(&q, &k, &v, Some(64), Some(1), None);
        let want = softmax_attention(&q, &k, &v, None);
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn rows_are_convex_combinations() {
        let (q, k, v) = qkv(2, 100, 8);
        let got = sparse_attention(&q, &k, &v, None, None, None);
        let vmin = v.data.iter().copied().fold(f32::INFINITY, f32::min);
        let vmax = v.data.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        assert!(got.data.iter().all(|&x| x >= vmin - 1e-4 && x <= vmax + 1e-4));
    }

    #[test]
    fn approximates_exact_reasonably() {
        // Gaussian q,k give near-uniform attention whose exact output is
        // tiny (mean of n values); a √n-subset estimate has ~√(n/|S|)×
        // the variance, so the mean-abs ratio is large but bounded.
        let (q, k, v) = qkv(3, 256, 16);
        let got = sparse_attention(&q, &k, &v, None, None, None);
        let want = softmax_attention(&q, &k, &v, None);
        let e = rel_err(&got, &want);
        assert!(e < 3.0, "rel err {e}");
        // widening the window must reduce the error
        let wide = sparse_attention(&q, &k, &v, Some(128), Some(2), None);
        assert!(rel_err(&wide, &want) < e, "window widening didn't help");
    }

    #[test]
    fn no_duplicate_attention_targets() {
        // stride positions inside the window must not be double-counted:
        // weights still sum to 1 (checked via constant-v trick)
        let (q, k, _) = qkv(4, 81, 8);
        let ones = Tensor2::from_vec(81, 1, vec![1.0; 81]);
        let got = sparse_attention(&q, &k, &ones, None, None, None);
        for i in 0..81 {
            assert!((got.data[i] - 1.0).abs() < 1e-5);
        }
    }
}
