//! Modified spectral-shifting attention — the paper's contribution
//! (sec 4-5), O(n) f32 path.
//!
//!   out = F · [Z (I − δZ)] · W  +  δ V         (eq 8 + δIₙ add-back)
//!   δ̂  = max(0, (tr A − tr(ZA²)) / max(c − tr(ZA), ε))
//!
//! with F, A, W = B·V shared with the Nystromformer implementation and
//! Z the eq-11 iterative pseudoinverse. `middle_form` switches between
//! the derivation-consistent eq-8 factor and the as-printed eq-4 factor
//! (see DESIGN.md §1 note); `rank_rtol` only affects the exact/SVD path
//! used for analysis (`spectral_shift_matrix_exact`).
//!
//! The attention entry point executes on the `kernels::` blocked
//! parallel core (A via tiled softmax-GEMM, W via the flash streaming
//! kernel, Z on the parallel GEMM, combine fused so F never
//! materializes). The seed scalar implementation is preserved verbatim
//! in [`reference`] as the parity/bench baseline.

use super::nystrom::{landmark_factors, ns_pinv_with};
use super::{default_scale, Tensor2};
use crate::kernels::{gemm_f32, softmax_gemm, KernelCtx, Workspace};
use crate::linalg::{self, Matrix};
use crate::model::AttentionOp;

/// Spectral shifting (the paper's method) as a pluggable
/// [`AttentionOp`]: the [`SpectralShiftConfig`] carries every tunable,
/// so the op is a transparent newtype over it.
#[derive(Clone, Copy, Debug)]
pub struct SpectralShiftOp(pub SpectralShiftConfig);

impl AttentionOp for SpectralShiftOp {
    fn name(&self) -> &'static str {
        "spectral_shift"
    }

    fn landmark_divisor(&self) -> Option<usize> {
        Some(self.0.landmarks)
    }

    fn attend(&self, ctx: &KernelCtx, q: &Tensor2, k: &Tensor2, v: &Tensor2,
              ws: &mut Workspace) -> Tensor2 {
        spectral_shift_attention_with(q, k, v, &self.0, ctx, ws)
    }
}

/// Which middle factor to build (paper inconsistency; eq8 is primary).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MiddleForm {
    /// A⁺(I − δA⁺) — from the derivation, eqs (6)-(8).
    Eq8,
    /// A⁺(I − δA) — as printed in eqs (4)/(10).
    Eq4,
}

/// Tunables for the spectral-shifting approximation.
#[derive(Clone, Copy, Debug)]
pub struct SpectralShiftConfig {
    /// Number of landmarks c (n must be divisible by it).
    pub landmarks: usize,
    /// Newton-Schulz iterations for A⁺.
    pub pinv_iters: usize,
    /// eq8 (derivation) vs eq4 (as printed).
    pub middle_form: MiddleForm,
    /// Add the δIₙ term back to the approximation (the actual "spectral
    /// shift"; turning it off degrades to a rank-c model — E9 ablation).
    pub add_shift_identity: bool,
    /// Attention scale; None = 1/√d.
    pub scale: Option<f32>,
}

impl SpectralShiftConfig {
    pub fn new(landmarks: usize) -> Self {
        SpectralShiftConfig {
            landmarks,
            pinv_iters: 8,
            middle_form: MiddleForm::Eq8,
            add_shift_identity: true,
            scale: None,
        }
    }
}

/// The matmul-only δ estimator mirroring `ref.delta_ss_iterative`.
pub(crate) fn delta_iterative(a: &Tensor2, z: &Tensor2, eps: f32) -> f32 {
    delta_iterative_with(&KernelCtx::global(), a, z, eps, &mut Workspace::new())
}

pub(crate) fn delta_iterative_with(ctx: &KernelCtx, a: &Tensor2, z: &Tensor2,
                                   eps: f32, ws: &mut Workspace) -> f32 {
    let c = a.rows;
    let za = gemm_f32(ctx, z, a, ws);
    let tr_za: f32 = (0..c).map(|i| za.data[i * c + i]).sum();
    let zaa = gemm_f32(ctx, &za, a, ws);
    let tr_a: f32 = (0..c).map(|i| a.data[i * c + i]).sum();
    let tr_zaa: f32 = (0..c).map(|i| zaa.data[i * c + i]).sum();
    ws.put(za.data);
    ws.put(zaa.data);
    let den = (c as f32 - tr_za).max(eps);
    ((tr_a - tr_zaa) / den).max(0.0)
}

/// Spectral-shifting attention, O(n·c·(d+dv) + c³).
pub fn spectral_shift_attention(q: &Tensor2, k: &Tensor2, v: &Tensor2,
                                cfg: &SpectralShiftConfig) -> Tensor2 {
    spectral_shift_attention_with(q, k, v, cfg, &KernelCtx::global(),
                                  &mut Workspace::new())
}

/// `spectral_shift_attention` on an explicit kernel context + workspace
/// — the zero-allocation serving entry point (used per-task by
/// `kernels::batched`). The F·(M·W) combine is fused; F never
/// materializes.
pub fn spectral_shift_attention_with(q: &Tensor2, k: &Tensor2, v: &Tensor2,
                                     cfg: &SpectralShiftConfig,
                                     ctx: &KernelCtx, ws: &mut Workspace)
                                     -> Tensor2 {
    let scale = cfg.scale.unwrap_or_else(|| default_scale(q.cols));
    let c = cfg.landmarks;
    let lf = landmark_factors(q, k, v, c, scale, ctx, ws);
    let z = ns_pinv_with(&lf.a, cfg.pinv_iters, ctx, ws);
    let delta = delta_iterative_with(ctx, &lf.a, &z, 1e-3, ws);
    // M = Z(I − δZ)  or  Z(I − δA)
    let other = match cfg.middle_form {
        MiddleForm::Eq8 => &z,
        MiddleForm::Eq4 => &lf.a,
    };
    let mut inner = Tensor2 { rows: c, cols: c, data: ws.take(c * c) };
    for i in 0..c {
        for j in 0..c {
            let id = if i == j { 1.0 } else { 0.0 };
            inner.data[i * c + j] = id - delta * other.data[i * c + j];
        }
    }
    let m = gemm_f32(ctx, &z, &inner, ws);
    let mw = gemm_f32(ctx, &m, &lf.w, ws);
    let mut out = softmax_gemm(ctx, q, &lf.kt, &mw, scale, ws);
    if cfg.add_shift_identity {
        for (o, x) in out.data.iter_mut().zip(&v.data) {
            *o += delta * x;
        }
    }
    ws.put(lf.qt.data);
    ws.put(lf.kt.data);
    ws.put(lf.a.data);
    ws.put(lf.w.data);
    ws.put(z.data);
    ws.put(inner.data);
    ws.put(m.data);
    ws.put(mw.data);
    out
}

/// Dense n×n spectral-shifting matrix with the *exact* (SVD, f64)
/// pseudoinverse and tolerance-rank δ — the analysis path used by the
/// Figure-2 spectrum bench and the E4/E5 error studies.
///
/// Returns (S̃, δ).
pub fn spectral_shift_matrix_exact(q: &Tensor2, k: &Tensor2, c: usize,
                                   rank_rtol: f64, middle_form: MiddleForm,
                                   add_shift_identity: bool,
                                   scale: Option<f32>) -> (Matrix, f64) {
    let scale = scale.unwrap_or_else(|| default_scale(q.cols)) as f64;
    let qm = q.to_matrix();
    let km = k.to_matrix();
    let qt = segment_means_f64(&qm, c);
    let kt = segment_means_f64(&km, c);
    let f = linalg::row_softmax(&linalg::matmul(&qm, &kt.transpose()).scale(scale));
    let a = linalg::row_softmax(&linalg::matmul(&qt, &kt.transpose()).scale(scale));
    let b = linalg::row_softmax(&linalg::matmul(&qt, &km.transpose()).scale(scale));
    let apinv = linalg::pinv(&a, rank_rtol);
    let delta = delta_exact(&a, &apinv, rank_rtol);
    let other = match middle_form {
        MiddleForm::Eq8 => &apinv,
        MiddleForm::Eq4 => &a,
    };
    let inner = Matrix::eye(c).sub(&other.scale(delta));
    let mid = linalg::matmul(&apinv, &inner);
    let mut s = linalg::matmul(&linalg::matmul(&f, &mid), &b);
    if add_shift_identity {
        s = s.add_scaled_identity(delta);
    }
    (s, delta)
}

/// Dense Nystromformer matrix (exact pinv) — baseline for the same benches.
pub fn nystrom_matrix_exact(q: &Tensor2, k: &Tensor2, c: usize,
                            scale: Option<f32>) -> Matrix {
    let scale = scale.unwrap_or_else(|| default_scale(q.cols)) as f64;
    let qm = q.to_matrix();
    let km = k.to_matrix();
    let qt = segment_means_f64(&qm, c);
    let kt = segment_means_f64(&km, c);
    let f = linalg::row_softmax(&linalg::matmul(&qm, &kt.transpose()).scale(scale));
    let a = linalg::row_softmax(&linalg::matmul(&qt, &kt.transpose()).scale(scale));
    let b = linalg::row_softmax(&linalg::matmul(&qt, &km.transpose()).scale(scale));
    linalg::matmul(&linalg::matmul(&f, &linalg::pinv(&a, 1e-10)), &b)
}

/// SVD-based δ (paper sec 4 closed form) on f64.
pub fn delta_exact(a: &Matrix, apinv: &Matrix, rank_rtol: f64) -> f64 {
    let c = a.rows();
    let r = linalg::numerical_rank(a, rank_rtol);
    if c <= r {
        return 0.0;
    }
    let aa = linalg::matmul(a, a);
    let num = a.trace() - linalg::matmul(apinv, &aa).trace();
    (num / (c - r) as f64).max(0.0)
}

/// f64 segment means (analysis path).
pub fn segment_means_f64(x: &Matrix, c: usize) -> Matrix {
    assert!(x.rows() % c == 0);
    let l = x.rows() / c;
    Matrix::from_fn(c, x.cols(), |j, col| {
        (0..l).map(|i| x[(j * l + i, col)]).sum::<f64>() / l as f64
    })
}

/// The seed scalar implementations, preserved byte-for-byte in spirit:
/// unvectorized per-row dot loops, per-call allocations, single thread.
/// They are the ground truth the `kernels::` fast path is
/// property-tested against (`tests/kernel_parity.rs`) and the baseline
/// the `bench_snapshot` bench reports speedups over.
pub mod reference {
    use crate::attention::landmarks::segment_means;
    use crate::attention::{axpy_f32, default_scale, dot_f32, matmul_f32, Tensor2};

    use super::{MiddleForm, SpectralShiftConfig};

    /// Seed `factors`: per-row dot loops for F/A, blocked online
    /// softmax for W.
    pub fn factors_ref(q: &Tensor2, k: &Tensor2, v: &Tensor2, c: usize,
                       scale: f32) -> (Tensor2, Tensor2, Tensor2) {
        let qt = segment_means(q, c);
        let kt = segment_means(k, c);
        let mut f = Tensor2::zeros(q.rows, c);
        for i in 0..q.rows {
            let qi = q.row(i);
            let frow = f.row_mut(i);
            for j in 0..c {
                frow[j] = dot_f32(qi, kt.row(j)) * scale;
            }
        }
        crate::linalg::row_softmax_f32(&mut f.data, q.rows, c);
        let mut a = Tensor2::zeros(c, c);
        for i in 0..c {
            let qi = qt.row(i);
            let arow = a.row_mut(i);
            for j in 0..c {
                arow[j] = dot_f32(qi, kt.row(j)) * scale;
            }
        }
        crate::linalg::row_softmax_f32(&mut a.data, c, c);
        let mut w = Tensor2::zeros(c, v.cols);
        let block = 128.min(k.rows.max(1));
        let mut scores = vec![0.0f32; block];
        for i in 0..c {
            let qi = qt.row(i);
            let wrow = w.row_mut(i);
            let mut m_run = f32::NEG_INFINITY;
            let mut l_run = 0.0f32;
            let mut start = 0;
            while start < k.rows {
                let end = (start + block).min(k.rows);
                let mut m_cur = f32::NEG_INFINITY;
                for (jj, j) in (start..end).enumerate() {
                    let s = dot_f32(qi, k.row(j)) * scale;
                    scores[jj] = s;
                    m_cur = m_cur.max(s);
                }
                let m_new = m_run.max(m_cur);
                let corr = if m_run.is_finite() { (m_run - m_new).exp() } else { 0.0 };
                l_run *= corr;
                for o in wrow.iter_mut() {
                    *o *= corr;
                }
                for (jj, j) in (start..end).enumerate() {
                    let p = (scores[jj] - m_new).exp();
                    l_run += p;
                    axpy_f32(wrow, p, v.row(j));
                }
                m_run = m_new;
                start = end;
            }
            let inv = 1.0 / l_run;
            for o in wrow.iter_mut() {
                *o *= inv;
            }
        }
        (f, a, w)
    }

    /// Seed order-7 Newton-Schulz pinv over `matmul_f32`.
    pub fn ns_pinv_ref(a: &Tensor2, iters: usize) -> Tensor2 {
        let c = a.rows;
        assert_eq!(a.rows, a.cols);
        let mut n1 = 0.0f32;
        for j in 0..c {
            let s: f32 = (0..c).map(|i| a.data[i * c + j].abs()).sum();
            n1 = n1.max(s);
        }
        let ninf = (0..c)
            .map(|i| a.row(i).iter().map(|x| x.abs()).sum::<f32>())
            .fold(0.0f32, f32::max);
        let denom = (n1 * ninf).max(f32::MIN_POSITIVE);
        let mut z = Tensor2::zeros(c, c);
        for i in 0..c {
            for j in 0..c {
                z.data[i * c + j] = a.data[j * c + i] / denom;
            }
        }
        let eye = |s: f32| {
            let mut m = Tensor2::zeros(c, c);
            for i in 0..c {
                m.data[i * c + i] = s;
            }
            m
        };
        for _ in 0..iters {
            let az = matmul_f32(a, &z);
            let mut inner1 = eye(7.0);
            for (x, y) in inner1.data.iter_mut().zip(&az.data) {
                *x -= y;
            }
            let t = matmul_f32(&az, &inner1);
            let mut inner2 = eye(15.0);
            for (x, y) in inner2.data.iter_mut().zip(&t.data) {
                *x -= y;
            }
            let t = matmul_f32(&az, &inner2);
            let mut inner3 = eye(13.0);
            for (x, y) in inner3.data.iter_mut().zip(&t.data) {
                *x -= y;
            }
            z = matmul_f32(&z, &inner3);
            for x in z.data.iter_mut() {
                *x *= 0.25;
            }
        }
        z
    }

    /// Seed δ estimator over `matmul_f32`.
    pub fn delta_iterative_ref(a: &Tensor2, z: &Tensor2, eps: f32) -> f32 {
        let c = a.rows;
        let za = matmul_f32(z, a);
        let tr_za: f32 = (0..c).map(|i| za.data[i * c + i]).sum();
        let zaa = matmul_f32(&za, a);
        let tr_a: f32 = (0..c).map(|i| a.data[i * c + i]).sum();
        let tr_zaa: f32 = (0..c).map(|i| zaa.data[i * c + i]).sum();
        let den = (c as f32 - tr_za).max(eps);
        ((tr_a - tr_zaa) / den).max(0.0)
    }

    /// Seed Nystromformer attention (materialized F, naive matmuls).
    pub fn nystrom_attention_ref(q: &Tensor2, k: &Tensor2, v: &Tensor2,
                                 c: usize, pinv_iters: usize,
                                 scale: Option<f32>) -> Tensor2 {
        let scale = scale.unwrap_or_else(|| default_scale(q.cols));
        let (f, a, w) = factors_ref(q, k, v, c, scale);
        let z = ns_pinv_ref(&a, pinv_iters);
        let zw = matmul_f32(&z, &w);
        matmul_f32(&f, &zw)
    }

    /// Seed spectral-shifting attention (the scalar hot path this PR's
    /// kernel core replaces).
    pub fn spectral_shift_attention_ref(q: &Tensor2, k: &Tensor2, v: &Tensor2,
                                        cfg: &SpectralShiftConfig) -> Tensor2 {
        let scale = cfg.scale.unwrap_or_else(|| default_scale(q.cols));
        let c = cfg.landmarks;
        let (f, a, w) = factors_ref(q, k, v, c, scale);
        let z = ns_pinv_ref(&a, cfg.pinv_iters);
        let delta = delta_iterative_ref(&a, &z, 1e-3);
        let other = match cfg.middle_form {
            MiddleForm::Eq8 => &z,
            MiddleForm::Eq4 => &a,
        };
        let mut inner = Tensor2::zeros(c, c);
        for i in 0..c {
            for j in 0..c {
                let id = if i == j { 1.0 } else { 0.0 };
                inner.data[i * c + j] = id - delta * other.data[i * c + j];
            }
        }
        let m = matmul_f32(&z, &inner);
        let mw = matmul_f32(&m, &w);
        let mut out = matmul_f32(&f, &mw);
        if cfg.add_shift_identity {
            for (o, x) in out.data.iter_mut().zip(&v.data) {
                *o += delta * x;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::full::{attention_matrix, softmax_attention};
    use crate::attention::nystrom::{factors, ns_pinv_f32, nystrom_attention};
    use crate::attention::testutil::{qkv, rel_err};

    #[test]
    fn matches_nystrom_when_delta_zero() {
        // full-rank A ⇒ δ̂≈0 ⇒ SS ≈ Nystrom
        let (q, k, v) = qkv(1, 128, 16);
        let ss = spectral_shift_attention(&q, &k, &v,
                                          &SpectralShiftConfig::new(16));
        let ny = nystrom_attention(&q, &k, &v, 16, 8, None);
        assert!(rel_err(&ss, &ny) < 0.1, "{}", rel_err(&ss, &ny));
    }

    #[test]
    fn approximates_exact_attention() {
        let (q, k, v) = qkv(2, 256, 32);
        let ss = spectral_shift_attention(&q, &k, &v,
                                          &SpectralShiftConfig::new(64));
        let exact = softmax_attention(&q, &k, &v, None);
        assert!(rel_err(&ss, &exact) < 1.0);
    }

    #[test]
    fn eq4_and_eq8_agree_when_delta_small() {
        let (q, k, v) = qkv(3, 128, 16);
        let mut cfg = SpectralShiftConfig::new(16);
        cfg.middle_form = MiddleForm::Eq8;
        let a = spectral_shift_attention(&q, &k, &v, &cfg);
        cfg.middle_form = MiddleForm::Eq4;
        let b = spectral_shift_attention(&q, &k, &v, &cfg);
        assert!(rel_err(&a, &b) < 0.05);
    }

    #[test]
    fn shift_identity_changes_output_by_delta_v() {
        let (q, k, v) = qkv(4, 64, 8);
        let mut cfg = SpectralShiftConfig::new(8);
        cfg.add_shift_identity = true;
        let with = spectral_shift_attention(&q, &k, &v, &cfg);
        cfg.add_shift_identity = false;
        let without = spectral_shift_attention(&q, &k, &v, &cfg);
        // difference must be exactly δ·v (elementwise proportional to v)
        let mut max_ratio_dev = 0.0f32;
        let mut delta_est = None;
        for i in 0..with.data.len() {
            if v.data[i].abs() > 0.5 {
                let r = (with.data[i] - without.data[i]) / v.data[i];
                match delta_est {
                    None => delta_est = Some(r),
                    Some(d) => max_ratio_dev = max_ratio_dev.max((r - d).abs()),
                }
            }
        }
        assert!(max_ratio_dev < 1e-4, "not a uniform δ·v shift: {max_ratio_dev}");
    }

    #[test]
    fn fast_path_matches_seed_reference() {
        // the kernels:: fast path must reproduce the preserved seed
        // implementation to fp-reassociation precision
        let (q, k, v) = qkv(11, 256, 16);
        for form in [MiddleForm::Eq8, MiddleForm::Eq4] {
            let mut cfg = SpectralShiftConfig::new(32);
            cfg.middle_form = form;
            let fast = spectral_shift_attention(&q, &k, &v, &cfg);
            let seed = reference::spectral_shift_attention_ref(&q, &k, &v, &cfg);
            let e = rel_err(&fast, &seed);
            assert!(e < 1e-4, "{form:?}: fast vs seed rel err {e}");
        }
    }

    #[test]
    fn thread_counts_are_bitwise_identical() {
        let (q, k, v) = qkv(12, 128, 16);
        let cfg = SpectralShiftConfig::new(16);
        let mut ws = Workspace::new();
        let seq = spectral_shift_attention_with(&q, &k, &v, &cfg,
                                                &KernelCtx::sequential(), &mut ws);
        let par = spectral_shift_attention_with(&q, &k, &v, &cfg,
                                                &KernelCtx::global(), &mut ws);
        assert_eq!(seq.data, par.data);
    }

    #[test]
    fn workspace_reuse_stops_allocating() {
        let (q, k, v) = qkv(13, 128, 16);
        let cfg = SpectralShiftConfig::new(16);
        let ctx = KernelCtx::global();
        let mut ws = Workspace::new();
        let out = spectral_shift_attention_with(&q, &k, &v, &cfg, &ctx, &mut ws);
        ws.put(out.data);
        let warm = ws.allocations();
        for _ in 0..4 {
            let out = spectral_shift_attention_with(&q, &k, &v, &cfg, &ctx, &mut ws);
            ws.put(out.data);
        }
        assert_eq!(ws.allocations(), warm,
                   "steady-state attention must not allocate from the arena");
    }

    #[test]
    fn exact_matrix_error_shrinks_with_c() {
        // Gaussian q,k are the hard near-uniform-attention case; the
        // useful invariant is monotone improvement with landmark count
        // and a bounded error at c = n/2.
        let (q, k, _) = qkv(5, 64, 16);
        let s_true = attention_matrix(&q, &k, None);
        let err_at = |c: usize| {
            let (s_apx, _d) = spectral_shift_matrix_exact(
                &q, &k, c, 1e-6, MiddleForm::Eq8, true, None);
            crate::linalg::norms::fro(&s_true.sub(&s_apx))
                / crate::linalg::norms::fro(&s_true)
        };
        let e4 = err_at(4);
        let e32 = err_at(32);
        assert!(e32 < e4, "e4={e4} e32={e32}");
        assert!(e32 < 1.5, "fro rel err {e32}");
    }

    #[test]
    fn figure1_constraint_postsoftmax_sampling_differs() {
        // E2: selecting columns AFTER the row softmax is not the same as
        // landmark-first-then-softmax — the reason sec 5 restructures
        // the computation (Figure 1).
        let (q, k, _) = qkv(6, 64, 8);
        let c = 8;
        let s_true = attention_matrix(&q, &k, None); // n×n, O(n²)
        // post-softmax column selection of landmark-mean columns
        let km = k.to_matrix();
        let qm = q.to_matrix();
        let kt = segment_means_f64(&km, c);
        let scale = 1.0 / (8f64).sqrt();
        // landmark-first F factor
        let f_landmark = linalg::row_softmax(
            &linalg::matmul(&qm, &kt.transpose()).scale(scale));
        // post-softmax segment means of S's columns (what Figure 1 says
        // you CANNOT use without computing all of S first)
        let f_post = segment_means_f64(&s_true.transpose(), c).transpose();
        let diff = f_landmark.max_abs_diff(&f_post);
        assert!(diff > 1e-3, "the two orders coincided: {diff}");
    }

    #[test]
    fn delta_exact_on_constructed_block() {
        // diag(2,2,2,θ,θ,θ) with rtol between θ/2 and 1 ⇒ δ = θ
        let theta = 0.2;
        let a = Matrix::diag(&[2.0, 2.0, 2.0, theta, theta, theta]);
        let apinv = linalg::pinv(&a, 0.5);
        let d = delta_exact(&a, &apinv, 0.5);
        assert!((d - theta).abs() < 1e-9, "{d}");
    }

    #[test]
    fn delta_iterative_near_zero_on_full_rank() {
        let (q, k, v) = qkv(7, 128, 16);
        let scale = default_scale(16);
        let (_f, a, _w) = factors(&q, &k, &v, 16, scale);
        let z = ns_pinv_f32(&a, 20);
        let d = delta_iterative(&a, &z, 1e-3);
        assert!(d < 0.05, "{d}");
    }

    #[test]
    fn delta_estimators_agree() {
        let (q, k, v) = qkv(14, 128, 16);
        let scale = default_scale(16);
        let (_f, a, _w) = factors(&q, &k, &v, 16, scale);
        let z = ns_pinv_f32(&a, 12);
        let fast = delta_iterative(&a, &z, 1e-3);
        let seed = reference::delta_iterative_ref(&a, &z, 1e-3);
        assert!((fast - seed).abs() < 1e-4, "fast {fast} vs seed {seed}");
    }
}
