//! Modified spectral-shifting attention — the paper's contribution
//! (sec 4-5), O(n) f32 path.
//!
//!   out = F · [Z (I − δZ)] · W  +  δ V         (eq 8 + δIₙ add-back)
//!   δ̂  = max(0, (tr A − tr(ZA²)) / max(c − tr(ZA), ε))
//!
//! with F, A, W = B·V shared with the Nystromformer implementation and
//! Z the eq-11 iterative pseudoinverse. `middle_form` switches between
//! the derivation-consistent eq-8 factor and the as-printed eq-4 factor
//! (see DESIGN.md §1 note); `rank_rtol` only affects the exact/SVD path
//! used for analysis (`spectral_shift_matrix`).

use super::nystrom::{factors, ns_pinv_f32};
use super::{default_scale, matmul_f32, Tensor2};
use crate::linalg::{self, Matrix};

/// Which middle factor to build (paper inconsistency; eq8 is primary).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MiddleForm {
    /// A⁺(I − δA⁺) — from the derivation, eqs (6)-(8).
    Eq8,
    /// A⁺(I − δA) — as printed in eqs (4)/(10).
    Eq4,
}

/// Tunables for the spectral-shifting approximation.
#[derive(Clone, Copy, Debug)]
pub struct SpectralShiftConfig {
    /// Number of landmarks c (n must be divisible by it).
    pub landmarks: usize,
    /// Newton-Schulz iterations for A⁺.
    pub pinv_iters: usize,
    /// eq8 (derivation) vs eq4 (as printed).
    pub middle_form: MiddleForm,
    /// Add the δIₙ term back to the approximation (the actual "spectral
    /// shift"; turning it off degrades to a rank-c model — E9 ablation).
    pub add_shift_identity: bool,
    /// Attention scale; None = 1/√d.
    pub scale: Option<f32>,
}

impl SpectralShiftConfig {
    pub fn new(landmarks: usize) -> Self {
        SpectralShiftConfig {
            landmarks,
            pinv_iters: 8,
            middle_form: MiddleForm::Eq8,
            add_shift_identity: true,
            scale: None,
        }
    }
}

/// The matmul-only δ estimator mirroring `ref.delta_ss_iterative`.
pub(crate) fn delta_iterative(a: &Tensor2, z: &Tensor2, eps: f32) -> f32 {
    let c = a.rows;
    let za = matmul_f32(z, a);
    let tr_za: f32 = (0..c).map(|i| za.data[i * c + i]).sum();
    let zaa = matmul_f32(&za, a);
    let tr_a: f32 = (0..c).map(|i| a.data[i * c + i]).sum();
    let tr_zaa: f32 = (0..c).map(|i| zaa.data[i * c + i]).sum();
    let den = (c as f32 - tr_za).max(eps);
    ((tr_a - tr_zaa) / den).max(0.0)
}

/// Spectral-shifting attention, O(n·c·(d+dv) + c³).
pub fn spectral_shift_attention(q: &Tensor2, k: &Tensor2, v: &Tensor2,
                                cfg: &SpectralShiftConfig) -> Tensor2 {
    let scale = cfg.scale.unwrap_or_else(|| default_scale(q.cols));
    let c = cfg.landmarks;
    let (f, a, w) = factors(q, k, v, c, scale);
    let z = ns_pinv_f32(&a, cfg.pinv_iters);
    let delta = delta_iterative(&a, &z, 1e-3);
    // M = Z(I − δZ)  or  Z(I − δA)
    let other = match cfg.middle_form {
        MiddleForm::Eq8 => &z,
        MiddleForm::Eq4 => &a,
    };
    let mut inner = Tensor2::zeros(c, c);
    for i in 0..c {
        for j in 0..c {
            let id = if i == j { 1.0 } else { 0.0 };
            inner.data[i * c + j] = id - delta * other.data[i * c + j];
        }
    }
    let m = matmul_f32(&z, &inner);
    let mw = matmul_f32(&m, &w);
    let mut out = matmul_f32(&f, &mw);
    if cfg.add_shift_identity {
        for (o, x) in out.data.iter_mut().zip(&v.data) {
            *o += delta * x;
        }
    }
    out
}

/// Dense n×n spectral-shifting matrix with the *exact* (SVD, f64)
/// pseudoinverse and tolerance-rank δ — the analysis path used by the
/// Figure-2 spectrum bench and the E4/E5 error studies.
///
/// Returns (S̃, δ).
pub fn spectral_shift_matrix_exact(q: &Tensor2, k: &Tensor2, c: usize,
                                   rank_rtol: f64, middle_form: MiddleForm,
                                   add_shift_identity: bool,
                                   scale: Option<f32>) -> (Matrix, f64) {
    let scale = scale.unwrap_or_else(|| default_scale(q.cols)) as f64;
    let qm = q.to_matrix();
    let km = k.to_matrix();
    let qt = segment_means_f64(&qm, c);
    let kt = segment_means_f64(&km, c);
    let f = linalg::row_softmax(&linalg::matmul(&qm, &kt.transpose()).scale(scale));
    let a = linalg::row_softmax(&linalg::matmul(&qt, &kt.transpose()).scale(scale));
    let b = linalg::row_softmax(&linalg::matmul(&qt, &km.transpose()).scale(scale));
    let apinv = linalg::pinv(&a, rank_rtol);
    let delta = delta_exact(&a, &apinv, rank_rtol);
    let other = match middle_form {
        MiddleForm::Eq8 => &apinv,
        MiddleForm::Eq4 => &a,
    };
    let inner = Matrix::eye(c).sub(&other.scale(delta));
    let mid = linalg::matmul(&apinv, &inner);
    let mut s = linalg::matmul(&linalg::matmul(&f, &mid), &b);
    if add_shift_identity {
        s = s.add_scaled_identity(delta);
    }
    (s, delta)
}

/// Dense Nystromformer matrix (exact pinv) — baseline for the same benches.
pub fn nystrom_matrix_exact(q: &Tensor2, k: &Tensor2, c: usize,
                            scale: Option<f32>) -> Matrix {
    let scale = scale.unwrap_or_else(|| default_scale(q.cols)) as f64;
    let qm = q.to_matrix();
    let km = k.to_matrix();
    let qt = segment_means_f64(&qm, c);
    let kt = segment_means_f64(&km, c);
    let f = linalg::row_softmax(&linalg::matmul(&qm, &kt.transpose()).scale(scale));
    let a = linalg::row_softmax(&linalg::matmul(&qt, &kt.transpose()).scale(scale));
    let b = linalg::row_softmax(&linalg::matmul(&qt, &km.transpose()).scale(scale));
    linalg::matmul(&linalg::matmul(&f, &linalg::pinv(&a, 1e-10)), &b)
}

/// SVD-based δ (paper sec 4 closed form) on f64.
pub fn delta_exact(a: &Matrix, apinv: &Matrix, rank_rtol: f64) -> f64 {
    let c = a.rows();
    let r = linalg::numerical_rank(a, rank_rtol);
    if c <= r {
        return 0.0;
    }
    let aa = linalg::matmul(a, a);
    let num = a.trace() - linalg::matmul(apinv, &aa).trace();
    (num / (c - r) as f64).max(0.0)
}

/// f64 segment means (analysis path).
pub fn segment_means_f64(x: &Matrix, c: usize) -> Matrix {
    assert!(x.rows() % c == 0);
    let l = x.rows() / c;
    Matrix::from_fn(c, x.cols(), |j, col| {
        (0..l).map(|i| x[(j * l + i, col)]).sum::<f64>() / l as f64
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::full::{attention_matrix, softmax_attention};
    use crate::attention::nystrom::nystrom_attention;
    use crate::attention::testutil::{qkv, rel_err};

    #[test]
    fn matches_nystrom_when_delta_zero() {
        // full-rank A ⇒ δ̂≈0 ⇒ SS ≈ Nystrom
        let (q, k, v) = qkv(1, 128, 16);
        let ss = spectral_shift_attention(&q, &k, &v,
                                          &SpectralShiftConfig::new(16));
        let ny = nystrom_attention(&q, &k, &v, 16, 8, None);
        assert!(rel_err(&ss, &ny) < 0.1, "{}", rel_err(&ss, &ny));
    }

    #[test]
    fn approximates_exact_attention() {
        let (q, k, v) = qkv(2, 256, 32);
        let ss = spectral_shift_attention(&q, &k, &v,
                                          &SpectralShiftConfig::new(64));
        let exact = softmax_attention(&q, &k, &v, None);
        assert!(rel_err(&ss, &exact) < 1.0);
    }

    #[test]
    fn eq4_and_eq8_agree_when_delta_small() {
        let (q, k, v) = qkv(3, 128, 16);
        let mut cfg = SpectralShiftConfig::new(16);
        cfg.middle_form = MiddleForm::Eq8;
        let a = spectral_shift_attention(&q, &k, &v, &cfg);
        cfg.middle_form = MiddleForm::Eq4;
        let b = spectral_shift_attention(&q, &k, &v, &cfg);
        assert!(rel_err(&a, &b) < 0.05);
    }

    #[test]
    fn shift_identity_changes_output_by_delta_v() {
        let (q, k, v) = qkv(4, 64, 8);
        let mut cfg = SpectralShiftConfig::new(8);
        cfg.add_shift_identity = true;
        let with = spectral_shift_attention(&q, &k, &v, &cfg);
        cfg.add_shift_identity = false;
        let without = spectral_shift_attention(&q, &k, &v, &cfg);
        // difference must be exactly δ·v (elementwise proportional to v)
        let mut max_ratio_dev = 0.0f32;
        let mut delta_est = None;
        for i in 0..with.data.len() {
            if v.data[i].abs() > 0.5 {
                let r = (with.data[i] - without.data[i]) / v.data[i];
                match delta_est {
                    None => delta_est = Some(r),
                    Some(d) => max_ratio_dev = max_ratio_dev.max((r - d).abs()),
                }
            }
        }
        assert!(max_ratio_dev < 1e-4, "not a uniform δ·v shift: {max_ratio_dev}");
    }

    #[test]
    fn exact_matrix_error_shrinks_with_c() {
        // Gaussian q,k are the hard near-uniform-attention case; the
        // useful invariant is monotone improvement with landmark count
        // and a bounded error at c = n/2.
        let (q, k, _) = qkv(5, 64, 16);
        let s_true = attention_matrix(&q, &k, None);
        let err_at = |c: usize| {
            let (s_apx, _d) = spectral_shift_matrix_exact(
                &q, &k, c, 1e-6, MiddleForm::Eq8, true, None);
            crate::linalg::norms::fro(&s_true.sub(&s_apx))
                / crate::linalg::norms::fro(&s_true)
        };
        let e4 = err_at(4);
        let e32 = err_at(32);
        assert!(e32 < e4, "e4={e4} e32={e32}");
        assert!(e32 < 1.5, "fro rel err {e32}");
    }

    #[test]
    fn figure1_constraint_postsoftmax_sampling_differs() {
        // E2: selecting columns AFTER the row softmax is not the same as
        // landmark-first-then-softmax — the reason sec 5 restructures
        // the computation (Figure 1).
        let (q, k, _) = qkv(6, 64, 8);
        let c = 8;
        let s_true = attention_matrix(&q, &k, None); // n×n, O(n²)
        // post-softmax column selection of landmark-mean columns
        let km = k.to_matrix();
        let qm = q.to_matrix();
        let kt = segment_means_f64(&km, c);
        let qt = segment_means_f64(&qm, c);
        let scale = 1.0 / (8f64).sqrt();
        // landmark-first F factor
        let f_landmark = linalg::row_softmax(
            &linalg::matmul(&qm, &kt.transpose()).scale(scale));
        // post-softmax segment means of S's columns (what Figure 1 says
        // you CANNOT use without computing all of S first)
        let f_post = segment_means_f64(&s_true.transpose(), c).transpose();
        let diff = f_landmark.max_abs_diff(&f_post);
        assert!(diff > 1e-3, "the two orders coincided: {diff}");
        let _ = qt;
    }

    #[test]
    fn delta_exact_on_constructed_block() {
        // diag(2,2,2,θ,θ,θ) with rtol between θ/2 and 1 ⇒ δ = θ
        let theta = 0.2;
        let a = Matrix::diag(&[2.0, 2.0, 2.0, theta, theta, theta]);
        let apinv = linalg::pinv(&a, 0.5);
        let d = delta_exact(&a, &apinv, 0.5);
        assert!((d - theta).abs() < 1e-9, "{d}");
    }

    #[test]
    fn delta_iterative_near_zero_on_full_rank() {
        let (q, k, v) = qkv(7, 128, 16);
        let scale = default_scale(16);
        let (_f, a, _w) = factors(&q, &k, &v, 16, scale);
        let z = ns_pinv_f32(&a, 20);
        let d = delta_iterative(&a, &z, 1e-3);
        assert!(d < 0.05, "{d}");
    }
}
