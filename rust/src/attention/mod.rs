//! Attention variants (S2-S4, S8-S10 in DESIGN.md) — f32 fast path.
//!
//! Every row of the paper's Table 1 is implemented here so the
//! `table1_complexity` bench can *measure* the scaling claims instead of
//! citing them:
//!
//! | variant                  | module            | paper complexity |
//! |--------------------------|-------------------|------------------|
//! | exact softmax            | `full`            | O(n²)            |
//! | sparse/strided           | `sparse`          | O(n√n)           |
//! | LSH (Reformer-style)     | `lsh`             | O(n log n)       |
//! | Linformer projection     | `linformer`       | O(n)             |
//! | Nystromformer            | `nystrom`         | O(n)             |
//! | spectral shifting (ours) | `spectral_shift`  | O(n)             |
//!
//! ## Kernel-layer architecture (fast path vs reference path)
//!
//! Since the kernel-core PR the variants are *thin pipelines* over the
//! [`crate::kernels`] compute layer; the scalar implementations remain
//! in-tree as the property-test baseline:
//!
//! * **Fast path** — every variant's public entry point delegates to a
//!   `*_with` twin. Signature convention: attention-level `*_with`
//!   twins append `(ctx: &KernelCtx, ws: &mut Workspace)` after the
//!   base signature; `crate::kernels` primitives (and the small
//!   helpers `segment_means_with` / `delta_iterative_with` that follow
//!   them) take `ctx` first and `ws` last. The twin runs on
//!   the shared `minirt` pool: tiled parallel GEMM (`kernels::gemm`),
//!   fused `softmax_gemm` for the F·(M·W) combine (F's n×c logits never
//!   materialize), the row-parallel flash kernel for exact attention and
//!   the streamed W = L(Q̃Kᵀ)·V factor, and arena-recycled scratch (zero
//!   steady-state allocations). Work splits over fixed-size row blocks,
//!   so outputs are **bitwise identical for any thread count**. Batched
//!   serving fans heads × requests out via `kernels::batched` (see
//!   `coordinator::batcher::attention_scatter`).
//! * **Reference path** — [`matmul_f32`] below plus the seed scalar
//!   pipeline preserved in [`spectral_shift::reference`]. The fast path
//!   is property-tested against it (max rel err < 1e-4) in
//!   `tests/kernel_parity.rs`, and `benches/bench_snapshot.rs` records
//!   the fast/reference speedup to `BENCH_kernels.json`.
//! * **Op seam** — every variant additionally exports a small struct
//!   (`FullOp`, `NystromOp`, `SpectralShiftOp`, `LinformerOp`, `LshOp`,
//!   `SparseOp`) implementing [`crate::model::AttentionOp`], the single
//!   dispatch point the encoder stack and the batched executor route
//!   through. Serving no longer matches on a variant enum at each call
//!   site; it holds one `&dyn AttentionOp`.
//!
//! The serving hot path executes the AOT-compiled XLA artifacts through
//! `runtime::` when artifacts are present; without them the coordinator
//! serves straight off this layer via `coordinator::cpu_engine`, so the
//! kernel core is both the CPU execution engine and the analysis/bench
//! substrate.
//!
//! # Invariants
//!
//! * **Reference/fast parity** — every `*_with` fast path reproduces
//!   its preserved seed scalar implementation to max rel err < 1e-4
//!   (`tests/kernel_parity.rs`); the reference path is never "improved",
//!   it is the ground truth.
//! * **Thread-count determinism** — inherited from `crate::kernels`:
//!   variant outputs are bitwise identical for any pool size.
//! * **Workspace discipline** — `*_with` twins take every intermediate
//!   from the caller's `Workspace` and return it before exiting, so
//!   steady-state calls allocate only their output tensor (and not even
//!   that when the caller recycles it with `ws.put`).

pub mod full;
pub mod landmarks;
pub mod linformer;
pub mod lsh;
pub mod nystrom;
pub mod spectral_shift;
pub mod sparse;

pub use full::{softmax_attention, FullOp};
pub use landmarks::{segment_means, segment_means_with};
pub use linformer::{linformer_attention, linformer_attention_with, LinformerOp};
pub use lsh::{lsh_attention, LshOp};
pub use nystrom::{nystrom_attention, nystrom_attention_with, NystromOp};
pub use spectral_shift::{
    spectral_shift_attention, spectral_shift_attention_with, SpectralShiftConfig,
    SpectralShiftOp,
};
pub use sparse::{sparse_attention, SparseOp};

/// A (rows × cols) f32 row-major tensor view used across the variants.
#[derive(Clone, Debug)]
pub struct Tensor2 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Tensor2 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Tensor2 { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Tensor2 { rows, cols, data }
    }

    /// Gaussian-filled tensor (test/bench workloads).
    pub fn randn(rng: &mut crate::rngx::Rng, rows: usize, cols: usize, std: f32) -> Self {
        let mut t = Self::zeros(rows, cols);
        rng.fill_normal_f32(&mut t.data, 0.0, std);
        t
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn max_abs_diff(&self, other: &Tensor2) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Mean |x| — used for relative-error reporting in benches.
    pub fn mean_abs(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|x| x.abs()).sum::<f32>() / self.data.len() as f32
    }

    pub fn to_matrix(&self) -> crate::linalg::Matrix {
        crate::linalg::Matrix::from_f32(self.rows, self.cols, &self.data)
    }
}

/// f32 dot product, 4-way unrolled.
#[inline]
pub(crate) fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// C += alpha * row_a ⊗ row_b accumulation helper: out[j] += w * v[j].
#[inline]
pub(crate) fn axpy_f32(out: &mut [f32], w: f32, v: &[f32]) {
    debug_assert_eq!(out.len(), v.len());
    for (o, x) in out.iter_mut().zip(v) {
        *o += w * x;
    }
}

/// C = A · B for Tensor2 (transposes B once for locality, per-row dot
/// products). This is the **reference** matmul the `kernels::` fast
/// path is property-tested against — keep it naive and obviously
/// correct; use [`crate::kernels::gemm_f32`] on hot paths.
pub fn matmul_f32(a: &Tensor2, b: &Tensor2) -> Tensor2 {
    assert_eq!(a.cols, b.rows);
    // transpose b
    let mut bt = vec![0.0f32; b.rows * b.cols];
    for i in 0..b.rows {
        for j in 0..b.cols {
            bt[j * b.rows + i] = b.data[i * b.cols + j];
        }
    }
    let mut c = Tensor2::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for j in 0..b.cols {
            crow[j] = dot_f32(arow, &bt[j * b.rows..(j + 1) * b.rows]);
        }
    }
    c
}

/// Default attention scale 1/√d.
#[inline]
pub fn default_scale(d: usize) -> f32 {
    1.0 / (d as f32).sqrt()
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::Tensor2;
    use crate::rngx::Rng;

    /// Standard q,k,v triple for variant tests.
    pub fn qkv(seed: u64, n: usize, d: usize) -> (Tensor2, Tensor2, Tensor2) {
        let mut rng = Rng::new(seed);
        (
            Tensor2::randn(&mut rng, n, d, 1.0),
            Tensor2::randn(&mut rng, n, d, 1.0),
            Tensor2::randn(&mut rng, n, d, 1.0),
        )
    }

    /// Relative mean-abs error between two tensors.
    pub fn rel_err(a: &Tensor2, b: &Tensor2) -> f32 {
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (x, y) in a.data.iter().zip(&b.data) {
            num += (x - y).abs() as f64;
            den += y.abs() as f64;
        }
        (num / den.max(1e-30)) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor2_basics() {
        let t = Tensor2::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.row(1), &[4., 5., 6.]);
        assert_eq!(t.mean_abs(), 3.5);
    }

    #[test]
    fn matmul_f32_known() {
        let a = Tensor2::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Tensor2::from_vec(2, 2, vec![5., 6., 7., 8.]);
        let c = matmul_f32(&a, &b);
        assert_eq!(c.data, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn matmul_f32_matches_f64_matrix() {
        let mut rng = crate::rngx::Rng::new(21);
        let a = Tensor2::randn(&mut rng, 7, 5, 1.0);
        let b = Tensor2::randn(&mut rng, 5, 9, 1.0);
        let c = matmul_f32(&a, &b);
        let cm = crate::linalg::matmul(&a.to_matrix(), &b.to_matrix());
        for i in 0..7 {
            for j in 0..9 {
                assert!((c.data[i * 9 + j] as f64 - cm[(i, j)]).abs() < 1e-4);
            }
        }
    }
}
