//! Linformer attention — the Table-1 O(n) projection baseline
//! (Wang et al. 2020): project keys/values along the sequence axis with
//! a fixed k×n matrix E, then run exact attention against the k
//! projected rows.
//!
//! The original learns E; as a serving-side baseline we use a fixed
//! random Gaussian projection (seeded), which preserves the complexity
//! and the JL-style approximation character.

use super::{default_scale, Tensor2};
use crate::kernels::{flash_attention, gemm_f32, KernelCtx, Workspace};
use crate::model::AttentionOp;
use crate::rngx::Rng;

/// Linformer as a pluggable [`AttentionOp`]. The projection matrix is
/// regenerated from `seed` on every call (cheap next to the GEMMs), so
/// the op stays stateless and the served function is fixed by
/// `(kdim, seed)`.
#[derive(Clone, Copy, Debug)]
pub struct LinformerOp {
    /// Projection dimension (rows kept after E·K / E·V).
    pub kdim: usize,
    /// Seed of the fixed Gaussian projection — part of the served
    /// function, like the CPU model's embedding-table seed.
    pub seed: u64,
}

impl AttentionOp for LinformerOp {
    fn name(&self) -> &'static str {
        "linformer"
    }

    fn attend(&self, ctx: &KernelCtx, q: &Tensor2, k: &Tensor2, v: &Tensor2,
              ws: &mut Workspace) -> Tensor2 {
        linformer_attention_with(q, k, v, self.kdim, self.seed, None, ctx, ws)
    }
}

/// Linformer attention with projection dimension `kdim`.
pub fn linformer_attention(q: &Tensor2, k: &Tensor2, v: &Tensor2,
                           kdim: usize, seed: u64,
                           scale: Option<f32>) -> Tensor2 {
    linformer_attention_with(q, k, v, kdim, seed, scale,
                             &KernelCtx::global(), &mut Workspace::new())
}

/// `linformer_attention` on an explicit kernel context + workspace: the
/// projections K' = E·K and V' = E·V run on the blocked parallel GEMM
/// and the attention over the kdim projected rows streams through the
/// flash kernel.
pub fn linformer_attention_with(q: &Tensor2, k: &Tensor2, v: &Tensor2,
                                kdim: usize, seed: u64, scale: Option<f32>,
                                ctx: &KernelCtx, ws: &mut Workspace) -> Tensor2 {
    assert_eq!(q.cols, k.cols);
    assert_eq!(k.rows, v.rows);
    let m = k.rows;
    let mut rng = Rng::new(seed);
    // E: (kdim, m) Gaussian / sqrt(kdim)
    let std = 1.0 / (kdim as f32).sqrt();
    let mut e = Tensor2 { rows: kdim, cols: m, data: ws.take(kdim * m) };
    rng.fill_normal_f32(&mut e.data, 0.0, std);

    // K' = E K (kdim, d); V' = E V (kdim, dv)
    let kp = gemm_f32(ctx, &e, k, ws);
    let vp = gemm_f32(ctx, &e, v, ws);
    let scale = scale.unwrap_or_else(|| default_scale(q.cols));
    let out = flash_attention(ctx, q, &kp, &vp, scale, ws);
    ws.put(e.data);
    ws.put(kp.data);
    ws.put(vp.data);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::qkv;

    #[test]
    fn shapes_and_finiteness() {
        let (q, k, v) = qkv(1, 128, 16);
        let got = linformer_attention(&q, &k, &v, 32, 7, None);
        assert_eq!((got.rows, got.cols), (128, 16));
        assert!(got.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn deterministic_for_seed() {
        let (q, k, v) = qkv(2, 64, 8);
        let a = linformer_attention(&q, &k, &v, 16, 9, None);
        let b = linformer_attention(&q, &k, &v, 16, 9, None);
        assert_eq!(a.data, b.data);
        let c = linformer_attention(&q, &k, &v, 16, 10, None);
        assert!(a.max_abs_diff(&c) > 0.0);
    }

    #[test]
    fn projection_dim_controls_cost_not_shape() {
        let (q, k, v) = qkv(3, 96, 8);
        for kd in [8, 24, 48] {
            let got = linformer_attention(&q, &k, &v, kd, 1, None);
            assert_eq!((got.rows, got.cols), (96, 8));
        }
    }
}
