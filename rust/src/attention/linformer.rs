//! Linformer attention — the Table-1 O(n) projection baseline
//! (Wang et al. 2020): project keys/values along the sequence axis with
//! a fixed k×n matrix E, then run exact attention against the k
//! projected rows.
//!
//! The original learns E; as a serving-side baseline we use a fixed
//! random Gaussian projection (seeded), which preserves the complexity
//! and the JL-style approximation character.

use super::{default_scale, full::softmax_attention, Tensor2};
use crate::rngx::Rng;

/// Linformer attention with projection dimension `kdim`.
pub fn linformer_attention(q: &Tensor2, k: &Tensor2, v: &Tensor2,
                           kdim: usize, seed: u64,
                           scale: Option<f32>) -> Tensor2 {
    assert_eq!(q.cols, k.cols);
    assert_eq!(k.rows, v.rows);
    let m = k.rows;
    let mut rng = Rng::new(seed);
    // E: (kdim, m) Gaussian / sqrt(kdim)
    let std = 1.0 / (kdim as f32).sqrt();
    let mut e = vec![0.0f32; kdim * m];
    rng.fill_normal_f32(&mut e, 0.0, std);

    // K' = E K (kdim, d); V' = E V (kdim, dv)
    let mut kp = Tensor2::zeros(kdim, k.cols);
    let mut vp = Tensor2::zeros(kdim, v.cols);
    for r in 0..kdim {
        let erow = &e[r * m..(r + 1) * m];
        let krow = kp.row_mut(r);
        for (j, &w) in erow.iter().enumerate() {
            for (o, x) in krow.iter_mut().zip(k.row(j)) {
                *o += w * x;
            }
        }
        let vrow = vp.row_mut(r);
        for (j, &w) in erow.iter().enumerate() {
            for (o, x) in vrow.iter_mut().zip(v.row(j)) {
                *o += w * x;
            }
        }
    }
    let scale = scale.unwrap_or_else(|| default_scale(q.cols));
    softmax_attention(q, &kp, &vp, Some(scale))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::qkv;

    #[test]
    fn shapes_and_finiteness() {
        let (q, k, v) = qkv(1, 128, 16);
        let got = linformer_attention(&q, &k, &v, 32, 7, None);
        assert_eq!((got.rows, got.cols), (128, 16));
        assert!(got.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn deterministic_for_seed() {
        let (q, k, v) = qkv(2, 64, 8);
        let a = linformer_attention(&q, &k, &v, 16, 9, None);
        let b = linformer_attention(&q, &k, &v, 16, 9, None);
        assert_eq!(a.data, b.data);
        let c = linformer_attention(&q, &k, &v, 16, 10, None);
        assert!(a.max_abs_diff(&c) > 0.0);
    }

    #[test]
    fn projection_dim_controls_cost_not_shape() {
        let (q, k, v) = qkv(3, 96, 8);
        for kd in [8, 24, 48] {
            let got = linformer_attention(&q, &k, &v, kd, 1, None);
            assert_eq!((got.rows, got.cols), (96, 8));
        }
    }
}
