//! Linformer attention — the Table-1 O(n) projection baseline
//! (Wang et al. 2020): project keys/values along the sequence axis with
//! a fixed k×n matrix E, then run exact attention against the k
//! projected rows.
//!
//! The original learns E; as a serving-side baseline we use a fixed
//! random Gaussian projection (seeded), which preserves the complexity
//! and the JL-style approximation character.

use super::{default_scale, Tensor2};
use crate::kernels::{flash_attention, gemm_f32, KernelCtx, Workspace};
use crate::model::AttentionOp;
use crate::rngx::Rng;
use std::sync::{Arc, Mutex, OnceLock};

/// Linformer as a pluggable [`AttentionOp`]. The projection matrix is
/// a pure function of `(seed, kdim, key count)` — memoized
/// process-wide (the private `projection` cache below) so the serving
/// hot path stops paying one Gaussian draw of `kdim·n` normals per
/// head per request — so the op stays stateless and the served
/// function is fixed by `(kdim, seed)`.
#[derive(Clone, Copy, Debug)]
pub struct LinformerOp {
    /// Projection dimension (rows kept after E·K / E·V).
    pub kdim: usize,
    /// Seed of the fixed Gaussian projection — part of the served
    /// function, like the CPU model's embedding-table seed.
    pub seed: u64,
}

/// Memo entries kept for distinct `(seed, kdim, key count)` triples.
/// Serving sees one triple per (bucket-aligned) execution length, so a
/// small bound covers steady state; eviction is least-recently-used.
const PROJ_CACHE_CAP: usize = 32;

type ProjKey = (u64, usize, usize);
static PROJ_CACHE: OnceLock<Mutex<Vec<(ProjKey, Arc<Vec<f32>>)>>> =
    OnceLock::new();

/// The seeded `(kdim × m)` Gaussian projection, memoized. The draw is
/// deterministic, so a cached hit is **bitwise identical** to
/// regeneration (pinned by `memoized_projection_is_bitwise_identical`)
/// — memoization is observationally pure and does not weaken the
/// [`AttentionOp`] purity contract.
fn projection(seed: u64, kdim: usize, m: usize) -> Arc<Vec<f32>> {
    let cache = PROJ_CACHE.get_or_init(|| Mutex::new(Vec::new()));
    let key: ProjKey = (seed, kdim, m);
    {
        let mut entries = cache.lock().unwrap();
        if let Some(pos) = entries.iter().position(|(k, _)| *k == key) {
            let hit = entries.remove(pos);
            let data = hit.1.clone();
            entries.push(hit); // most-recently-used at the tail
            return data;
        }
    }
    // draw outside the lock: concurrent misses on one key duplicate
    // work, never results (the draw is deterministic)
    let std = 1.0 / (kdim as f32).sqrt();
    let mut data = vec![0.0f32; kdim * m];
    Rng::new(seed).fill_normal_f32(&mut data, 0.0, std);
    let data = Arc::new(data);
    let mut entries = cache.lock().unwrap();
    if !entries.iter().any(|(k, _)| *k == key) {
        if entries.len() >= PROJ_CACHE_CAP {
            entries.remove(0); // least-recently-used at the head
        }
        entries.push((key, data.clone()));
    }
    data
}

impl AttentionOp for LinformerOp {
    fn name(&self) -> &'static str {
        "linformer"
    }

    fn attend(&self, ctx: &KernelCtx, q: &Tensor2, k: &Tensor2, v: &Tensor2,
              ws: &mut Workspace) -> Tensor2 {
        linformer_attention_with(q, k, v, self.kdim, self.seed, None, ctx, ws)
    }
}

/// Linformer attention with projection dimension `kdim`.
pub fn linformer_attention(q: &Tensor2, k: &Tensor2, v: &Tensor2,
                           kdim: usize, seed: u64,
                           scale: Option<f32>) -> Tensor2 {
    linformer_attention_with(q, k, v, kdim, seed, scale,
                             &KernelCtx::global(), &mut Workspace::new())
}

/// `linformer_attention` on an explicit kernel context + workspace: the
/// projections K' = E·K and V' = E·V run on the blocked parallel GEMM
/// and the attention over the kdim projected rows streams through the
/// flash kernel.
pub fn linformer_attention_with(q: &Tensor2, k: &Tensor2, v: &Tensor2,
                                kdim: usize, seed: u64, scale: Option<f32>,
                                ctx: &KernelCtx, ws: &mut Workspace) -> Tensor2 {
    assert_eq!(q.cols, k.cols);
    assert_eq!(k.rows, v.rows);
    let m = k.rows;
    // E: (kdim, m) Gaussian / sqrt(kdim), memoized per (seed, kdim, m)
    // — copied into ws scratch so workspace discipline is unchanged
    let cached = projection(seed, kdim, m);
    let mut e = Tensor2 { rows: kdim, cols: m, data: ws.take(kdim * m) };
    e.data.copy_from_slice(&cached);

    // K' = E K (kdim, d); V' = E V (kdim, dv)
    let kp = gemm_f32(ctx, &e, k, ws);
    let vp = gemm_f32(ctx, &e, v, ws);
    let scale = scale.unwrap_or_else(|| default_scale(q.cols));
    let out = flash_attention(ctx, q, &kp, &vp, scale, ws);
    ws.put(e.data);
    ws.put(kp.data);
    ws.put(vp.data);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::qkv;

    #[test]
    fn shapes_and_finiteness() {
        let (q, k, v) = qkv(1, 128, 16);
        let got = linformer_attention(&q, &k, &v, 32, 7, None);
        assert_eq!((got.rows, got.cols), (128, 16));
        assert!(got.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn deterministic_for_seed() {
        let (q, k, v) = qkv(2, 64, 8);
        let a = linformer_attention(&q, &k, &v, 16, 9, None);
        let b = linformer_attention(&q, &k, &v, 16, 9, None);
        assert_eq!(a.data, b.data);
        let c = linformer_attention(&q, &k, &v, 16, 10, None);
        assert!(a.max_abs_diff(&c) > 0.0);
    }

    #[test]
    fn projection_dim_controls_cost_not_shape() {
        let (q, k, v) = qkv(3, 96, 8);
        for kd in [8, 24, 48] {
            let got = linformer_attention(&q, &k, &v, kd, 1, None);
            assert_eq!((got.rows, got.cols), (96, 8));
        }
    }

    #[test]
    fn memoized_projection_is_bitwise_identical() {
        // the memo must be invisible: E from the cache equals a fresh
        // regeneration bit for bit, and therefore so does attention
        let (seed, kdim, m) = (0xBEEF_u64, 16, 64);
        let mut fresh = vec![0.0f32; kdim * m];
        Rng::new(seed).fill_normal_f32(&mut fresh, 0.0,
                                       1.0 / (kdim as f32).sqrt());
        let first = projection(seed, kdim, m); // cold: draws + inserts
        let second = projection(seed, kdim, m); // warm (unless a
        // concurrent test evicted the key — either way the value is
        // pinned to the deterministic draw)
        assert_eq!(*first, fresh, "cached draw must equal regeneration");
        assert_eq!(*second, fresh);
        // end to end: repeated attends (cold then warm) are bitwise equal
        let (q, k, v) = qkv(5, m, 8);
        let a = linformer_attention(&q, &k, &v, kdim, seed, None);
        let b = linformer_attention(&q, &k, &v, kdim, seed, None);
        assert_eq!(a.data, b.data, "memoization must not change attention");
    }

    #[test]
    fn projection_cache_is_bounded() {
        // distinct key counts far beyond the cap must not grow the memo
        // without bound — and correctness survives eviction
        let (q, k, v) = qkv(6, 64, 8);
        for m in 0..2 * PROJ_CACHE_CAP {
            let _ = projection(0xCAFE, 8, 8 + m);
        }
        let len = PROJ_CACHE.get().unwrap().lock().unwrap().len();
        assert!(len <= PROJ_CACHE_CAP, "memo grew to {len}");
        let a = linformer_attention(&q, &k, &v, 8, 0xCAFE, None);
        let b = linformer_attention(&q, &k, &v, 8, 0xCAFE, None);
        assert_eq!(a.data, b.data);
    }
}
