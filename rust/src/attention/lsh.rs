//! LSH-bucketed attention — the Table-1 O(n log n) baseline
//! (Reformer-style, simplified: random-hyperplane signed hashing,
//! queries attend within their bucket only, multiple hash rounds
//! averaged).
//!
//! Kitaev et al. share q=k and sort by bucket; we keep separate q/k and
//! a direct bucket-intersection formulation, which preserves the
//! complexity shape (n·bucket_size per round, bucket_size ≈ n/2^bits,
//! bits ≈ log n).

use super::{axpy_f32, default_scale, dot_f32, Tensor2};
use crate::model::AttentionOp;
use crate::rngx::Rng;

/// LSH attention as a pluggable [`AttentionOp`]. Reference-grade: the
/// scalar implementation below allocates internally and ignores the
/// kernel context (single-threaded per head — the batched executor
/// still fans heads × requests over the pool around it). The output is
/// copied into a `ws`-backed tensor so callers that recycle op outputs
/// through the arena (the batched executor's slot discipline) stay
/// balanced: every `put` of an op output is matched by a `take` here.
#[derive(Clone, Copy, Debug)]
pub struct LshOp {
    /// Independent hash rounds averaged together.
    pub rounds: usize,
    /// Hyperplanes per hash; `None` derives ⌈log₂(n/64)⌉ from the key
    /// count (Reformer's ≈64-key buckets).
    pub bits: Option<usize>,
    /// Hyperplane seed — part of the served function.
    pub seed: u64,
}

impl AttentionOp for LshOp {
    fn name(&self) -> &'static str {
        "lsh"
    }

    fn attend(&self, _ctx: &crate::kernels::KernelCtx, q: &Tensor2, k: &Tensor2,
              v: &Tensor2, ws: &mut crate::kernels::Workspace) -> Tensor2 {
        let out = lsh_attention(q, k, v, self.rounds, self.bits, self.seed, None);
        let mut data = ws.take(out.rows * out.cols);
        data.copy_from_slice(&out.data);
        Tensor2 { rows: out.rows, cols: out.cols, data }
    }
}

/// LSH attention with `rounds` independent hash functions of `bits`
/// random hyperplanes each. bits=None picks ⌈log₂(n/64)⌉ so the expected
/// bucket size stays ≈64 (Reformer's constant chunk size): per-round
/// work is n·64 score evaluations + n·bits hashing ⇒ O(n log n).
pub fn lsh_attention(q: &Tensor2, k: &Tensor2, v: &Tensor2,
                     rounds: usize, bits: Option<usize>, seed: u64,
                     scale: Option<f32>) -> Tensor2 {
    assert_eq!(q.cols, k.cols);
    assert_eq!(k.rows, v.rows);
    let n = q.rows;
    let m = k.rows;
    let d = q.cols;
    let scale = scale.unwrap_or_else(|| default_scale(d));
    let bits = bits.unwrap_or_else(|| {
        (((m.max(2) as f64) / 64.0).max(2.0).log2().ceil() as usize).clamp(1, 16)
    });
    let mut rng = Rng::new(seed);
    let nb = 1usize << bits;

    let mut out = Tensor2::zeros(n, v.cols);
    let mut weight_sum = vec![0.0f32; n];

    for _round in 0..rounds {
        // random hyperplanes
        let mut planes = vec![0.0f32; bits * d];
        rng.fill_normal_f32(&mut planes, 0.0, 1.0);
        let hash = |x: &[f32]| -> usize {
            let mut h = 0usize;
            for b in 0..bits {
                if dot_f32(x, &planes[b * d..(b + 1) * d]) >= 0.0 {
                    h |= 1 << b;
                }
            }
            h
        };
        // bucket keys
        let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); nb];
        for j in 0..m {
            buckets[hash(k.row(j))].push(j);
        }
        // per-query softmax within its bucket
        for i in 0..n {
            let qi = q.row(i);
            let b = &buckets[hash(qi)];
            if b.is_empty() {
                continue;
            }
            let mut mx = f32::NEG_INFINITY;
            let mut scores = Vec::with_capacity(b.len());
            for &j in b {
                let s = dot_f32(qi, k.row(j)) * scale;
                scores.push(s);
                mx = mx.max(s);
            }
            let mut sum = 0.0f32;
            for s in scores.iter_mut() {
                *s = (*s - mx).exp();
                sum += *s;
            }
            let inv = 1.0 / sum;
            let orow = out.row_mut(i);
            for (&j, &p) in b.iter().zip(&scores) {
                axpy_f32(orow, p * inv, v.row(j));
            }
            weight_sum[i] += 1.0;
        }
    }
    // average over rounds; queries that never matched a bucket fall back
    // to the global mean value (rare)
    let mut vbar = vec![0.0f32; v.cols];
    for j in 0..m {
        for (a, x) in vbar.iter_mut().zip(v.row(j)) {
            *a += x / m as f32;
        }
    }
    for i in 0..n {
        let orow = out.row_mut(i);
        if weight_sum[i] > 0.0 {
            let inv = 1.0 / weight_sum[i];
            for o in orow.iter_mut() {
                *o *= inv;
            }
        } else {
            orow.copy_from_slice(&vbar);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::full::softmax_attention;
    use crate::attention::testutil::{qkv, rel_err};

    #[test]
    fn zero_bits_single_bucket_recovers_exact() {
        let (q, k, v) = qkv(1, 48, 8);
        // 1 bit but force all keys to one side: use bits=1 with rounds=1
        // won't be exact; instead bits such that nb=1 → bucket = all
        let got = lsh_attention(&q, &k, &v, 1, Some(0), 7, None);
        let want = softmax_attention(&q, &k, &v, None);
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn output_finite_and_bounded() {
        let (q, k, v) = qkv(2, 200, 16);
        let got = lsh_attention(&q, &k, &v, 4, None, 3, None);
        assert!(got.data.iter().all(|x| x.is_finite()));
        let vmin = v.data.iter().copied().fold(f32::INFINITY, f32::min);
        let vmax = v.data.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        assert!(got.data.iter().all(|&x| x >= vmin - 1e-3 && x <= vmax + 1e-3));
    }

    #[test]
    fn similar_vectors_attend() {
        // identical q and k rows always share a bucket ⇒ LSH attention of
        // x with itself recovers near-self attention for spiky values
        let mut rng = crate::rngx::Rng::new(5);
        let x = Tensor2::randn(&mut rng, 64, 16, 1.0);
        let got = lsh_attention(&x, &x, &x, 2, Some(3), 11, None);
        let want = softmax_attention(&x, &x, &x, None);
        // same-bucket guarantee for q=k makes this a decent approximation
        assert!(rel_err(&got, &want) < 1.5);
    }

    #[test]
    fn deterministic_for_seed() {
        let (q, k, v) = qkv(3, 100, 8);
        let a = lsh_attention(&q, &k, &v, 2, None, 42, None);
        let b = lsh_attention(&q, &k, &v, 2, None, 42, None);
        assert_eq!(a.data, b.data);
    }
}
