//! Exact softmax self-attention — the O(n²) Table-1 baseline.
//!
//! Blocked over queries with an online-softmax accumulation over keys,
//! mirroring the L1 Pallas flash kernel's structure (one row of scores
//! never materializes more than a block at a time). Execution delegates
//! to `kernels::flash_attention`, which runs the same recurrence
//! row-parallel on the shared kernel pool.

use super::{default_scale, Tensor2};
use crate::kernels::{flash_attention, KernelCtx, Workspace};
use crate::model::AttentionOp;

/// Exact softmax attention as a pluggable [`AttentionOp`] (the O(n²)
/// upper baseline every approximation is judged against). Stateless:
/// the flash kernel streams keys, so no configuration is needed.
#[derive(Clone, Copy, Debug, Default)]
pub struct FullOp;

impl AttentionOp for FullOp {
    fn name(&self) -> &'static str {
        "full"
    }

    fn attend(&self, ctx: &KernelCtx, q: &Tensor2, k: &Tensor2, v: &Tensor2,
              ws: &mut Workspace) -> Tensor2 {
        flash_attention(ctx, q, k, v, default_scale(q.cols), ws)
    }
}

/// Exact attention out = softmax(q kᵀ · scale) v.
///
/// q: (n, d), k: (m, d), v: (m, dv). `scale` defaults to 1/√d.
/// Convenience wrapper over [`crate::kernels::flash_attention`]; hot
/// paths that care about steady-state allocations should call the
/// kernel directly with their own context and workspace.
pub fn softmax_attention(q: &Tensor2, k: &Tensor2, v: &Tensor2,
                         scale: Option<f32>) -> Tensor2 {
    let scale = scale.unwrap_or_else(|| default_scale(q.cols));
    flash_attention(&KernelCtx::global(), q, k, v, scale, &mut Workspace::new())
}

/// Dense n×n attention matrix S = softmax(q kᵀ · scale) — analysis only
/// (used by the Figure-2 spectrum study and error benches).
pub fn attention_matrix(q: &Tensor2, k: &Tensor2, scale: Option<f32>) -> crate::linalg::Matrix {
    let scale = scale.unwrap_or_else(|| default_scale(q.cols)) as f64;
    let qm = q.to_matrix();
    let km = k.to_matrix();
    let mut s = crate::linalg::matmul(&qm, &km.transpose()).scale(scale);
    crate::linalg::row_softmax_inplace(&mut s);
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::qkv;
    use crate::attention::{axpy_f32, dot_f32};

    /// Unblocked naive reference.
    fn naive(q: &Tensor2, k: &Tensor2, v: &Tensor2) -> Tensor2 {
        let scale = default_scale(q.cols);
        let mut out = Tensor2::zeros(q.rows, v.cols);
        for i in 0..q.rows {
            let mut s: Vec<f32> = (0..k.rows)
                .map(|j| dot_f32(q.row(i), k.row(j)) * scale)
                .collect();
            let m = s.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0;
            for x in s.iter_mut() {
                *x = (*x - m).exp();
                sum += *x;
            }
            for x in s.iter_mut() {
                *x /= sum;
            }
            for (j, &w) in s.iter().enumerate() {
                axpy_f32(out.row_mut(i), w, v.row(j));
            }
        }
        out
    }

    #[test]
    fn matches_naive_small() {
        let (q, k, v) = qkv(1, 50, 8);
        let got = softmax_attention(&q, &k, &v, None);
        let want = naive(&q, &k, &v);
        assert!(got.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn matches_naive_across_block_boundary() {
        // n = 300 spans multiple 128-key blocks
        let (q, k, v) = qkv(2, 300, 16);
        let got = softmax_attention(&q, &k, &v, None);
        let want = naive(&q, &k, &v);
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn output_in_value_hull() {
        let (q, k, v) = qkv(3, 128, 8);
        let got = softmax_attention(&q, &k, &v, None);
        let vmin = v.data.iter().copied().fold(f32::INFINITY, f32::min);
        let vmax = v.data.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        assert!(got.data.iter().all(|&x| x >= vmin - 1e-4 && x <= vmax + 1e-4));
    }

    #[test]
    fn large_logits_stable() {
        let mut rng = crate::rngx::Rng::new(4);
        let q = Tensor2::randn(&mut rng, 64, 8, 30.0);
        let k = Tensor2::randn(&mut rng, 64, 8, 30.0);
        let v = Tensor2::randn(&mut rng, 64, 8, 1.0);
        let got = softmax_attention(&q, &k, &v, None);
        assert!(got.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn attention_matrix_rows_sum_to_one() {
        let (q, k, _) = qkv(5, 40, 8);
        let s = attention_matrix(&q, &k, None);
        for i in 0..40 {
            let sum: f64 = s.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-10);
        }
    }

    #[test]
    fn cross_attention_shapes() {
        // m != n (key length differs from query length)
        let (q, _, _) = qkv(6, 32, 8);
        let (_, k, v) = qkv(7, 80, 8);
        let out = softmax_attention(&q, &k, &v, None);
        assert_eq!((out.rows, out.cols), (32, 8));
    }
}
