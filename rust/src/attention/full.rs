//! Exact softmax self-attention — the O(n²) Table-1 baseline.
//!
//! Blocked over queries with an online-softmax accumulation over keys,
//! mirroring the L1 Pallas flash kernel's structure (one row of scores
//! never materializes more than a block at a time).

use super::{axpy_f32, default_scale, dot_f32, Tensor2};

/// Exact attention out = softmax(q kᵀ · scale) v.
///
/// q: (n, d), k: (m, d), v: (m, dv). `scale` defaults to 1/√d.
pub fn softmax_attention(q: &Tensor2, k: &Tensor2, v: &Tensor2,
                         scale: Option<f32>) -> Tensor2 {
    assert_eq!(q.cols, k.cols, "q/k width mismatch");
    assert_eq!(k.rows, v.rows, "k/v length mismatch");
    let scale = scale.unwrap_or_else(|| default_scale(q.cols));
    let n = q.rows;
    let m = k.rows;
    let dv = v.cols;
    let block_k = 128.min(m.max(1));

    let mut out = Tensor2::zeros(n, dv);
    let mut scores = vec![0.0f32; block_k];
    for i in 0..n {
        let qi = q.row(i);
        let mut m_run = f32::NEG_INFINITY;
        let mut l_run = 0.0f32;
        let orow = out.row_mut(i);
        let mut start = 0;
        while start < m {
            let end = (start + block_k).min(m);
            let len = end - start;
            let mut m_cur = f32::NEG_INFINITY;
            for (jj, j) in (start..end).enumerate() {
                let s = dot_f32(qi, k.row(j)) * scale;
                scores[jj] = s;
                m_cur = m_cur.max(s);
            }
            let m_new = m_run.max(m_cur);
            let corr = if m_run.is_finite() { (m_run - m_new).exp() } else { 0.0 };
            l_run *= corr;
            for o in orow.iter_mut() {
                *o *= corr;
            }
            for (jj, j) in (start..end).enumerate() {
                let p = (scores[jj] - m_new).exp();
                l_run += p;
                axpy_f32(orow, p, v.row(j));
            }
            m_run = m_new;
            let _ = len;
            start = end;
        }
        let inv = 1.0 / l_run;
        for o in orow.iter_mut() {
            *o *= inv;
        }
    }
    out
}

/// Dense n×n attention matrix S = softmax(q kᵀ · scale) — analysis only
/// (used by the Figure-2 spectrum study and error benches).
pub fn attention_matrix(q: &Tensor2, k: &Tensor2, scale: Option<f32>) -> crate::linalg::Matrix {
    let scale = scale.unwrap_or_else(|| default_scale(q.cols)) as f64;
    let qm = q.to_matrix();
    let km = k.to_matrix();
    let mut s = crate::linalg::matmul(&qm, &km.transpose()).scale(scale);
    crate::linalg::row_softmax_inplace(&mut s);
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::qkv;

    /// Unblocked naive reference.
    fn naive(q: &Tensor2, k: &Tensor2, v: &Tensor2) -> Tensor2 {
        let scale = default_scale(q.cols);
        let mut out = Tensor2::zeros(q.rows, v.cols);
        for i in 0..q.rows {
            let mut s: Vec<f32> = (0..k.rows)
                .map(|j| dot_f32(q.row(i), k.row(j)) * scale)
                .collect();
            let m = s.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0;
            for x in s.iter_mut() {
                *x = (*x - m).exp();
                sum += *x;
            }
            for x in s.iter_mut() {
                *x /= sum;
            }
            for (j, &w) in s.iter().enumerate() {
                axpy_f32(out.row_mut(i), w, v.row(j));
            }
        }
        out
    }

    #[test]
    fn matches_naive_small() {
        let (q, k, v) = qkv(1, 50, 8);
        let got = softmax_attention(&q, &k, &v, None);
        let want = naive(&q, &k, &v);
        assert!(got.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn matches_naive_across_block_boundary() {
        // n = 300 spans multiple 128-key blocks
        let (q, k, v) = qkv(2, 300, 16);
        let got = softmax_attention(&q, &k, &v, None);
        let want = naive(&q, &k, &v);
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn output_in_value_hull() {
        let (q, k, v) = qkv(3, 128, 8);
        let got = softmax_attention(&q, &k, &v, None);
        let vmin = v.data.iter().copied().fold(f32::INFINITY, f32::min);
        let vmax = v.data.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        assert!(got.data.iter().all(|&x| x >= vmin - 1e-4 && x <= vmax + 1e-4));
    }

    #[test]
    fn large_logits_stable() {
        let mut rng = crate::rngx::Rng::new(4);
        let q = Tensor2::randn(&mut rng, 64, 8, 30.0);
        let k = Tensor2::randn(&mut rng, 64, 8, 30.0);
        let v = Tensor2::randn(&mut rng, 64, 8, 1.0);
        let got = softmax_attention(&q, &k, &v, None);
        assert!(got.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn attention_matrix_rows_sum_to_one() {
        let (q, k, _) = qkv(5, 40, 8);
        let s = attention_matrix(&q, &k, None);
        for i in 0..40 {
            let sum: f64 = s.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-10);
        }
    }

    #[test]
    fn cross_attention_shapes() {
        // m != n (key length differs from query length)
        let (q, _, _) = qkv(6, 32, 8);
        let (_, k, v) = qkv(7, 80, 8);
        let out = softmax_attention(&q, &k, &v, None);
        assert_eq!((out.rows, out.cols), (32, 8));
    }
}
