//! Nystromformer attention (paper sec 2.4) — O(n) f32 path.
//!
//!   out = L(QK̃ᵀ) · A⁺ · (L(Q̃Kᵀ) V)
//!
//! with segment-means landmarks and the eq-11 order-7 Newton-Schulz
//! pseudoinverse (same iteration count semantics as the Pallas kernel).

use super::landmarks::segment_means;
use super::{axpy_f32, default_scale, dot_f32, matmul_f32, Tensor2};

/// The three softmax factors. Returns (F, A, W=B·V) with B never stored:
/// B's rows are streamed against V with an online softmax, so memory is
/// O(nc + c² + c·dv).
pub(crate) fn factors(q: &Tensor2, k: &Tensor2, v: &Tensor2, c: usize,
                      scale: f32) -> (Tensor2, Tensor2, Tensor2) {
    let qt = segment_means(q, c);
    let kt = segment_means(k, c);
    // F = rowsoftmax(q k̃ᵀ): (n, c) — softmax over c entries, local per row
    let mut f = Tensor2::zeros(q.rows, c);
    for i in 0..q.rows {
        let qi = q.row(i);
        let frow = f.row_mut(i);
        for j in 0..c {
            frow[j] = dot_f32(qi, kt.row(j)) * scale;
        }
    }
    crate::linalg::row_softmax_f32(&mut f.data, q.rows, c);
    // A = rowsoftmax(q̃ k̃ᵀ): (c, c)
    let mut a = Tensor2::zeros(c, c);
    for i in 0..c {
        let qi = qt.row(i);
        let arow = a.row_mut(i);
        for j in 0..c {
            arow[j] = dot_f32(qi, kt.row(j)) * scale;
        }
    }
    crate::linalg::row_softmax_f32(&mut a.data, c, c);
    // W = rowsoftmax(q̃ kᵀ) V: (c, dv), streamed over the n keys with the
    // online-softmax recurrence (the Figure-1 constraint: the row softmax
    // needs every column, so the normalizer accumulates across blocks).
    let mut w = Tensor2::zeros(c, v.cols);
    let block = 128.min(k.rows.max(1));
    let mut scores = vec![0.0f32; block];
    for i in 0..c {
        let qi = qt.row(i);
        let wrow = w.row_mut(i);
        let mut m_run = f32::NEG_INFINITY;
        let mut l_run = 0.0f32;
        let mut start = 0;
        while start < k.rows {
            let end = (start + block).min(k.rows);
            let mut m_cur = f32::NEG_INFINITY;
            for (jj, j) in (start..end).enumerate() {
                let s = dot_f32(qi, k.row(j)) * scale;
                scores[jj] = s;
                m_cur = m_cur.max(s);
            }
            let m_new = m_run.max(m_cur);
            let corr = if m_run.is_finite() { (m_run - m_new).exp() } else { 0.0 };
            l_run *= corr;
            for o in wrow.iter_mut() {
                *o *= corr;
            }
            for (jj, j) in (start..end).enumerate() {
                let p = (scores[jj] - m_new).exp();
                l_run += p;
                axpy_f32(wrow, p, v.row(j));
            }
            m_run = m_new;
            start = end;
        }
        let inv = 1.0 / l_run;
        for o in wrow.iter_mut() {
            *o *= inv;
        }
    }
    (f, a, w)
}

/// f32 order-7 Newton-Schulz pinv (eq 11), mirroring kernels/pinv_iter.py.
pub(crate) fn ns_pinv_f32(a: &Tensor2, iters: usize) -> Tensor2 {
    let c = a.rows;
    assert_eq!(a.rows, a.cols);
    // Z0 = Aᵀ / (‖A‖₁‖A‖∞)
    let mut n1 = 0.0f32;
    for j in 0..c {
        let s: f32 = (0..c).map(|i| a.data[i * c + j].abs()).sum();
        n1 = n1.max(s);
    }
    let ninf = (0..c)
        .map(|i| a.row(i).iter().map(|x| x.abs()).sum::<f32>())
        .fold(0.0f32, f32::max);
    let denom = (n1 * ninf).max(f32::MIN_POSITIVE);
    let mut z = Tensor2::zeros(c, c);
    for i in 0..c {
        for j in 0..c {
            z.data[i * c + j] = a.data[j * c + i] / denom;
        }
    }
    let eye = |s: f32| {
        let mut m = Tensor2::zeros(c, c);
        for i in 0..c {
            m.data[i * c + i] = s;
        }
        m
    };
    for _ in 0..iters {
        let az = matmul_f32(a, &z);
        // inner1 = 7I − AZ
        let mut inner1 = eye(7.0);
        for (x, y) in inner1.data.iter_mut().zip(&az.data) {
            *x -= y;
        }
        // inner2 = 15I − AZ·inner1
        let t = matmul_f32(&az, &inner1);
        let mut inner2 = eye(15.0);
        for (x, y) in inner2.data.iter_mut().zip(&t.data) {
            *x -= y;
        }
        // inner3 = 13I − AZ·inner2
        let t = matmul_f32(&az, &inner2);
        let mut inner3 = eye(13.0);
        for (x, y) in inner3.data.iter_mut().zip(&t.data) {
            *x -= y;
        }
        z = matmul_f32(&z, &inner3);
        for x in z.data.iter_mut() {
            *x *= 0.25;
        }
    }
    z
}

/// Nystromformer attention: out = F · (Z · W). O(n·c·(d+dv) + c³).
pub fn nystrom_attention(q: &Tensor2, k: &Tensor2, v: &Tensor2, c: usize,
                         pinv_iters: usize, scale: Option<f32>) -> Tensor2 {
    let scale = scale.unwrap_or_else(|| default_scale(q.cols));
    let (f, a, w) = factors(q, k, v, c, scale);
    let z = ns_pinv_f32(&a, pinv_iters);
    let zw = matmul_f32(&z, &w);
    matmul_f32(&f, &zw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::full::softmax_attention;
    use crate::attention::testutil::{qkv, rel_err};

    #[test]
    fn c_equals_n_recovers_exact_attention() {
        // with one landmark per token, F = L(QKᵀ̃)=… and A is invertible:
        // Nystrom is exact when c = n (landmarks are the tokens).
        let (q, k, v) = qkv(1, 32, 8);
        let approx = nystrom_attention(&q, &k, &v, 32, 30, None);
        let exact = softmax_attention(&q, &k, &v, None);
        assert!(rel_err(&approx, &exact) < 0.05,
                "rel={}", rel_err(&approx, &exact));
    }

    #[test]
    fn reasonable_approximation_quality() {
        let (q, k, v) = qkv(2, 256, 32);
        let approx = nystrom_attention(&q, &k, &v, 64, 12, None);
        let exact = softmax_attention(&q, &k, &v, None);
        let e = rel_err(&approx, &exact);
        assert!(e < 1.0, "rel err too large: {e}");
        // and it must beat a trivial all-zeros baseline by a wide margin
        assert!(approx.mean_abs() > 0.1 * exact.mean_abs());
    }

    #[test]
    fn more_landmarks_do_not_hurt() {
        let (q, k, v) = qkv(3, 128, 16);
        let exact = softmax_attention(&q, &k, &v, None);
        let e8 = rel_err(&nystrom_attention(&q, &k, &v, 8, 12, None), &exact);
        let e64 = rel_err(&nystrom_attention(&q, &k, &v, 64, 12, None), &exact);
        assert!(e64 < e8 * 1.2, "e8={e8} e64={e64}");
    }

    #[test]
    fn ns_pinv_inverts_well_conditioned() {
        let mut rng = crate::rngx::Rng::new(4);
        let mut a = Tensor2::randn(&mut rng, 12, 12, 0.1);
        for i in 0..12 {
            a.data[i * 12 + i] += 1.0;
        }
        let z = ns_pinv_f32(&a, 10);
        let az = matmul_f32(&a, &z);
        for i in 0..12 {
            for j in 0..12 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((az.data[i * 12 + j] - want).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn factors_rows_are_distributions() {
        let (q, k, v) = qkv(5, 64, 8);
        let (f, a, _w) = factors(&q, &k, &v, 8, default_scale(8));
        for i in 0..f.rows {
            let s: f32 = f.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        for i in 0..a.rows {
            let s: f32 = a.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn w_factor_matches_dense_composition() {
        let (q, k, v) = qkv(6, 96, 8);
        let c = 12;
        let scale = default_scale(8);
        let (_f, _a, w) = factors(&q, &k, &v, c, scale);
        // dense: B = rowsoftmax(q̃ kᵀ); W = B V
        let qt = segment_means(&q, c);
        let mut b = Tensor2::zeros(c, 96);
        for i in 0..c {
            for j in 0..96 {
                b.data[i * 96 + j] = dot_f32(qt.row(i), k.row(j)) * scale;
            }
        }
        crate::linalg::row_softmax_f32(&mut b.data, c, 96);
        let want = matmul_f32(&b, &v);
        assert!(w.max_abs_diff(&want) < 1e-4);
    }
}
