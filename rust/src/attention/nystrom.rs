//! Nystromformer attention (paper sec 2.4) — O(n) f32 path.
//!
//!   out = L(QK̃ᵀ) · A⁺ · (L(Q̃Kᵀ) V)
//!
//! with segment-means landmarks and the eq-11 order-7 Newton-Schulz
//! pseudoinverse (same iteration count semantics as the Pallas kernel).
//!
//! Execution runs on the `kernels::` blocked/parallel core: the F and A
//! factors come out of the tiled softmax-GEMM, W streams through the
//! flash kernel's online softmax, the Newton-Schulz iterations run on
//! the parallel GEMM, and the final combine uses the fused
//! `softmax_gemm` so F is never materialized on the attention path.

use super::landmarks::segment_means_with;
use super::{default_scale, Tensor2};
use crate::kernels::{
    flash_attention, gemm_f32, gemm_into, softmax_gemm, softmax_scores, KernelCtx, Workspace,
};
use crate::model::AttentionOp;

/// Nystromformer as a pluggable [`AttentionOp`]. Execution lengths must
/// be divisible by `landmarks` (reported via `landmark_divisor`, aligned
/// upstream by the batcher).
#[derive(Clone, Copy, Debug)]
pub struct NystromOp {
    pub landmarks: usize,
    pub pinv_iters: usize,
}

impl AttentionOp for NystromOp {
    fn name(&self) -> &'static str {
        "nystrom"
    }

    fn landmark_divisor(&self) -> Option<usize> {
        Some(self.landmarks)
    }

    fn attend(&self, ctx: &KernelCtx, q: &Tensor2, k: &Tensor2, v: &Tensor2,
              ws: &mut Workspace) -> Tensor2 {
        nystrom_attention_with(q, k, v, self.landmarks, self.pinv_iters, None,
                               ctx, ws)
    }
}

/// The shared landmark-factor prologue every O(n) variant starts with:
/// segment-means landmarks q̃/k̃, A = L(q̃k̃ᵀ), and W = L(q̃kᵀ)·V streamed
/// through the flash kernel's online softmax (B never stored — the
/// Figure-1 constraint: the row softmax needs every column, so the
/// normalizer accumulates across key blocks). F is deliberately *not*
/// here: the attention entry points fuse it via `softmax_gemm`, and
/// `factors` materializes it only for analysis/tests.
pub(crate) struct LandmarkFactors {
    pub qt: Tensor2,
    pub kt: Tensor2,
    pub a: Tensor2,
    pub w: Tensor2,
}

pub(crate) fn landmark_factors(q: &Tensor2, k: &Tensor2, v: &Tensor2, c: usize,
                               scale: f32, ctx: &KernelCtx, ws: &mut Workspace)
                               -> LandmarkFactors {
    let qt = segment_means_with(ctx, q, c, ws);
    let kt = segment_means_with(ctx, k, c, ws);
    let a = softmax_scores(ctx, &qt, &kt, scale, ws);
    let w = flash_attention(ctx, &qt, k, v, scale, ws);
    LandmarkFactors { qt, kt, a, w }
}

/// The three softmax factors, materialized. Returns (F, A, W=B·V) with
/// memory O(nc + c² + c·dv). The attention entry points below skip F
/// and fuse the combine instead.
pub(crate) fn factors(q: &Tensor2, k: &Tensor2, v: &Tensor2, c: usize,
                      scale: f32) -> (Tensor2, Tensor2, Tensor2) {
    factors_with(q, k, v, c, scale, &KernelCtx::global(), &mut Workspace::new())
}

/// `factors` on an explicit kernel context + workspace.
pub(crate) fn factors_with(q: &Tensor2, k: &Tensor2, v: &Tensor2, c: usize,
                           scale: f32, ctx: &KernelCtx, ws: &mut Workspace)
                           -> (Tensor2, Tensor2, Tensor2) {
    let lf = landmark_factors(q, k, v, c, scale, ctx, ws);
    // F = rowsoftmax(q k̃ᵀ): (n, c)
    let f = softmax_scores(ctx, q, &lf.kt, scale, ws);
    ws.put(lf.qt.data);
    ws.put(lf.kt.data);
    (f, lf.a, lf.w)
}

/// f32 order-7 Newton-Schulz pinv (eq 11), mirroring kernels/pinv_iter.py.
pub(crate) fn ns_pinv_f32(a: &Tensor2, iters: usize) -> Tensor2 {
    ns_pinv_with(a, iters, &KernelCtx::global(), &mut Workspace::new())
}

/// Newton-Schulz pinv on the blocked parallel GEMM; all five c×c
/// intermediates live in (and return to) the workspace arena.
pub(crate) fn ns_pinv_with(a: &Tensor2, iters: usize, ctx: &KernelCtx,
                           ws: &mut Workspace) -> Tensor2 {
    let c = a.rows;
    assert_eq!(a.rows, a.cols);
    // Z0 = Aᵀ / (‖A‖₁‖A‖∞)
    let mut n1 = 0.0f32;
    for j in 0..c {
        let s: f32 = (0..c).map(|i| a.data[i * c + j].abs()).sum();
        n1 = n1.max(s);
    }
    let ninf = (0..c)
        .map(|i| a.row(i).iter().map(|x| x.abs()).sum::<f32>())
        .fold(0.0f32, f32::max);
    let denom = (n1 * ninf).max(f32::MIN_POSITIVE);
    let mut z = ws.take(c * c);
    for i in 0..c {
        for j in 0..c {
            z[i * c + j] = a.data[j * c + i] / denom;
        }
    }
    let mut az = ws.take(c * c);
    let mut inner = ws.take(c * c);
    let mut tmp = ws.take(c * c);
    let mut znew = ws.take(c * c);
    for _ in 0..iters {
        gemm_into(ctx, &a.data, &z, &mut az, c, c, c);
        // inner1 = 7I − AZ
        scaled_identity_minus(&mut inner, &az, 7.0, c);
        // inner2 = 15I − AZ·inner1
        gemm_into(ctx, &az, &inner, &mut tmp, c, c, c);
        scaled_identity_minus(&mut inner, &tmp, 15.0, c);
        // inner3 = 13I − AZ·inner2
        gemm_into(ctx, &az, &inner, &mut tmp, c, c, c);
        scaled_identity_minus(&mut inner, &tmp, 13.0, c);
        // Z ← ¼ Z·inner3
        gemm_into(ctx, &z, &inner, &mut znew, c, c, c);
        for x in znew.iter_mut() {
            *x *= 0.25;
        }
        std::mem::swap(&mut z, &mut znew);
    }
    ws.put(az);
    ws.put(inner);
    ws.put(tmp);
    ws.put(znew);
    Tensor2 { rows: c, cols: c, data: z }
}

/// out = s·I − m (c×c).
fn scaled_identity_minus(out: &mut [f32], m: &[f32], s: f32, c: usize) {
    for (o, x) in out.iter_mut().zip(m) {
        *o = -x;
    }
    for i in 0..c {
        out[i * c + i] += s;
    }
}

/// Nystromformer attention: out = F · (Z · W). O(n·c·(d+dv) + c³).
pub fn nystrom_attention(q: &Tensor2, k: &Tensor2, v: &Tensor2, c: usize,
                         pinv_iters: usize, scale: Option<f32>) -> Tensor2 {
    nystrom_attention_with(q, k, v, c, pinv_iters, scale,
                           &KernelCtx::global(), &mut Workspace::new())
}

/// `nystrom_attention` on an explicit kernel context + workspace — the
/// zero-allocation serving entry point (used per-task by
/// `kernels::batched`). The combine is fused: F never materializes.
pub fn nystrom_attention_with(q: &Tensor2, k: &Tensor2, v: &Tensor2, c: usize,
                              pinv_iters: usize, scale: Option<f32>,
                              ctx: &KernelCtx, ws: &mut Workspace) -> Tensor2 {
    let scale = scale.unwrap_or_else(|| default_scale(q.cols));
    let lf = landmark_factors(q, k, v, c, scale, ctx, ws);
    let z = ns_pinv_with(&lf.a, pinv_iters, ctx, ws);
    let zw = gemm_f32(ctx, &z, &lf.w, ws);
    let out = softmax_gemm(ctx, q, &lf.kt, &zw, scale, ws);
    ws.put(lf.qt.data);
    ws.put(lf.kt.data);
    ws.put(lf.a.data);
    ws.put(lf.w.data);
    ws.put(z.data);
    ws.put(zw.data);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::full::softmax_attention;
    use crate::attention::landmarks::segment_means;
    use crate::attention::testutil::{qkv, rel_err};
    use crate::attention::{dot_f32, matmul_f32};

    #[test]
    fn c_equals_n_recovers_exact_attention() {
        // with one landmark per token, F = L(QKᵀ̃)=… and A is invertible:
        // Nystrom is exact when c = n (landmarks are the tokens).
        let (q, k, v) = qkv(1, 32, 8);
        let approx = nystrom_attention(&q, &k, &v, 32, 30, None);
        let exact = softmax_attention(&q, &k, &v, None);
        assert!(rel_err(&approx, &exact) < 0.05,
                "rel={}", rel_err(&approx, &exact));
    }

    #[test]
    fn reasonable_approximation_quality() {
        let (q, k, v) = qkv(2, 256, 32);
        let approx = nystrom_attention(&q, &k, &v, 64, 12, None);
        let exact = softmax_attention(&q, &k, &v, None);
        let e = rel_err(&approx, &exact);
        assert!(e < 1.0, "rel err too large: {e}");
        // and it must beat a trivial all-zeros baseline by a wide margin
        assert!(approx.mean_abs() > 0.1 * exact.mean_abs());
    }

    #[test]
    fn more_landmarks_do_not_hurt() {
        let (q, k, v) = qkv(3, 128, 16);
        let exact = softmax_attention(&q, &k, &v, None);
        let e8 = rel_err(&nystrom_attention(&q, &k, &v, 8, 12, None), &exact);
        let e64 = rel_err(&nystrom_attention(&q, &k, &v, 64, 12, None), &exact);
        assert!(e64 < e8 * 1.2, "e8={e8} e64={e64}");
    }

    #[test]
    fn ns_pinv_inverts_well_conditioned() {
        let mut rng = crate::rngx::Rng::new(4);
        let mut a = Tensor2::randn(&mut rng, 12, 12, 0.1);
        for i in 0..12 {
            a.data[i * 12 + i] += 1.0;
        }
        let z = ns_pinv_f32(&a, 10);
        let az = matmul_f32(&a, &z);
        for i in 0..12 {
            for j in 0..12 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((az.data[i * 12 + j] - want).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn factors_rows_are_distributions() {
        let (q, k, v) = qkv(5, 64, 8);
        let (f, a, _w) = factors(&q, &k, &v, 8, default_scale(8));
        for i in 0..f.rows {
            let s: f32 = f.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        for i in 0..a.rows {
            let s: f32 = a.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn w_factor_matches_dense_composition() {
        let (q, k, v) = qkv(6, 96, 8);
        let c = 12;
        let scale = default_scale(8);
        let (_f, _a, w) = factors(&q, &k, &v, c, scale);
        // dense: B = rowsoftmax(q̃ kᵀ); W = B V
        let qt = segment_means(&q, c);
        let mut b = Tensor2::zeros(c, 96);
        for i in 0..c {
            for j in 0..96 {
                b.data[i * 96 + j] = dot_f32(qt.row(i), k.row(j)) * scale;
            }
        }
        crate::linalg::row_softmax_f32(&mut b.data, c, 96);
        let want = matmul_f32(&b, &v);
        assert!(w.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn fused_path_matches_materialized_composition() {
        // out = F·(Z·W) assembled with the naive reference kernels must
        // match the fused softmax_gemm combine
        let (q, k, v) = qkv(7, 128, 16);
        let (c, iters) = (16, 8);
        let scale = default_scale(16);
        let fast = nystrom_attention(&q, &k, &v, c, iters, None);
        let (f, a, w) = factors(&q, &k, &v, c, scale);
        let z = ns_pinv_f32(&a, iters);
        let zw = matmul_f32(&z, &w);
        let want = matmul_f32(&f, &zw);
        let e = rel_err(&fast, &want);
        assert!(e < 1e-4, "fused vs materialized rel err {e}");
    }

    #[test]
    fn thread_counts_are_bitwise_identical() {
        let (q, k, v) = qkv(8, 128, 16);
        let mut ws = Workspace::new();
        let seq = nystrom_attention_with(&q, &k, &v, 16, 8, None,
                                         &KernelCtx::sequential(), &mut ws);
        let par = nystrom_attention_with(&q, &k, &v, 16, 8, None,
                                         &KernelCtx::global(), &mut ws);
        assert_eq!(seq.data, par.data);
    }
}
