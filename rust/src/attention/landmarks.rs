//! Segment-means landmark selection (paper sec 2.3 eq 1) — f32 path.

use super::Tensor2;

/// (n, d) -> (c, d) per-segment means. n must be divisible by c.
pub fn segment_means(x: &Tensor2, c: usize) -> Tensor2 {
    segment_means_with(&crate::kernels::KernelCtx::sequential(), x, c,
                       &mut crate::kernels::Workspace::new())
}

/// `segment_means` on an explicit kernel context: output rows (one per
/// segment) fan out over the pool. Each row accumulates its own segment
/// in input order, so results are identical for any thread count. The
/// output tensor is backed by `ws` scratch (recycle with
/// `ws.put(t.data)`), keeping the attention hot paths allocation-free.
pub fn segment_means_with(ctx: &crate::kernels::KernelCtx, x: &Tensor2, c: usize,
                          ws: &mut crate::kernels::Workspace) -> Tensor2 {
    assert!(c > 0 && x.rows % c == 0,
            "n={} not divisible by c={c}", x.rows);
    let l = x.rows / c;
    let inv = 1.0 / l as f32;
    let mut out = Tensor2 { rows: c, cols: x.cols, data: ws.take(c * x.cols) };
    crate::kernels::par_rows(ctx, &mut out.data, c, x.cols, |j, orow| {
        for i in j * l..(j + 1) * l {
            for (o, v) in orow.iter_mut().zip(x.row(i)) {
                *o += v;
            }
        }
        for o in orow.iter_mut() {
            *o *= inv;
        }
    });
    out
}

/// Random-column landmark selection (the E9 ablation alternative):
/// picks c distinct rows of x.
pub fn random_landmarks(rng: &mut crate::rngx::Rng, x: &Tensor2, c: usize) -> Tensor2 {
    assert!(c <= x.rows);
    let idx = rng.sample_indices(x.rows, c);
    let mut out = Tensor2::zeros(c, x.cols);
    for (jj, &i) in idx.iter().enumerate() {
        out.row_mut(jj).copy_from_slice(x.row(i));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Rng;

    #[test]
    fn matches_manual_means() {
        let x = Tensor2::from_vec(4, 2, vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        let lm = segment_means(&x, 2);
        assert_eq!(lm.data, vec![2., 3., 6., 7.]);
    }

    #[test]
    fn c_equals_n_identity() {
        let mut rng = Rng::new(1);
        let x = Tensor2::randn(&mut rng, 16, 4, 1.0);
        let lm = segment_means(&x, 16);
        assert!(lm.max_abs_diff(&x) < 1e-7);
    }

    #[test]
    fn c_equals_one_is_global_mean() {
        let x = Tensor2::from_vec(4, 1, vec![1., 2., 3., 6.]);
        let lm = segment_means(&x, 1);
        assert_eq!(lm.data, vec![3.0]);
    }

    #[test]
    #[should_panic]
    fn indivisible_panics() {
        let x = Tensor2::zeros(10, 2);
        segment_means(&x, 3);
    }

    #[test]
    fn parallel_matches_sequential_bitwise() {
        let mut rng = Rng::new(3);
        let x = Tensor2::randn(&mut rng, 96, 7, 1.0);
        let seq = segment_means(&x, 12);
        let par = segment_means_with(&crate::kernels::KernelCtx::global(), &x, 12,
                                     &mut crate::kernels::Workspace::new());
        assert_eq!(seq.data, par.data);
    }

    #[test]
    fn random_landmarks_are_rows_of_input() {
        let mut rng = Rng::new(2);
        let x = Tensor2::randn(&mut rng, 20, 3, 1.0);
        let lm = random_landmarks(&mut rng, &x, 5);
        for j in 0..5 {
            let found = (0..20).any(|i| {
                x.row(i).iter().zip(lm.row(j)).all(|(a, b)| a == b)
            });
            assert!(found, "landmark {j} is not an input row");
        }
    }
}
