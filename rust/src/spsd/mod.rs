//! SPSD matrix-approximation model zoo (Wang, Luo & Zhang JMLR 2016) —
//! substrate S6 for the Lemma 1 / Theorem 1 experiments (E4).
//!
//! Three models over an explicit SPSD matrix K with column selection P:
//!   * prototype / Nystrom:  K̃ = C A⁺ Cᵀ                (paper sec 2.2)
//!   * full spectral shift:  K̃ = C Uˢˢ Cᵀ + δˢˢ Iₙ       (paper sec 3,
//!     fits (U, δ) against the WHOLE matrix — O(n²c))
//!   * modified spectral shift: same form, fit only on the sampled
//!     block A_s (paper sec 4 — O(c³))
//!
//! plus generators for spiked-spectrum SPSD test matrices and column-
//! sampling strategies (uniform-random, segment-strided).

use crate::linalg::{self, Matrix};
use crate::rngx::Rng;

/// SPSD test matrix with k spikes (λ from `spike_hi` down to `spike_lo`)
/// and an exactly flat tail at θ — the Lemma-1 spectrum shape.
pub fn spiked_spsd(rng: &mut Rng, n: usize, k: usize, spike_hi: f64,
                   spike_lo: f64, theta: f64) -> Matrix {
    assert!(k <= n && spike_lo > theta && theta >= 0.0);
    let u = linalg::random_orthonormal(rng, n, n);
    let mut lam = vec![theta; n];
    for i in 0..k {
        lam[i] = if k == 1 {
            spike_hi
        } else {
            spike_hi + (spike_lo - spike_hi) * i as f64 / (k - 1) as f64
        };
    }
    let mut ud = u.clone();
    for i in 0..n {
        for j in 0..n {
            ud[(i, j)] *= lam[j];
        }
    }
    linalg::matmul(&ud, &u.transpose()).symmetrize()
}

/// SPSD matrix with power-law spectrum λ_i = (i+1)^{-decay} — the
/// slow-decay regime where the paper says Nystrom underperforms.
pub fn power_law_spsd(rng: &mut Rng, n: usize, decay: f64) -> Matrix {
    let u = linalg::random_orthonormal(rng, n, n);
    let lam: Vec<f64> = (0..n).map(|i| ((i + 1) as f64).powf(-decay)).collect();
    let mut ud = u.clone();
    for i in 0..n {
        for j in 0..n {
            ud[(i, j)] *= lam[j];
        }
    }
    linalg::matmul(&ud, &u.transpose()).symmetrize()
}

/// Column-selection strategies for the sampling matrix P.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ColumnSampling {
    /// c distinct uniform-random columns.
    UniformRandom,
    /// every (n/c)-th column (the deterministic segment-strided analogue
    /// of segment-means).
    Strided,
}

/// Pick c column indices of an n-column matrix.
pub fn sample_columns(rng: &mut Rng, n: usize, c: usize,
                      how: ColumnSampling) -> Vec<usize> {
    assert!(c <= n && c > 0);
    match how {
        ColumnSampling::UniformRandom => {
            let mut idx = rng.sample_indices(n, c);
            idx.sort_unstable();
            idx
        }
        ColumnSampling::Strided => {
            let step = n / c;
            (0..c).map(|j| j * step).collect()
        }
    }
}

/// Result of fitting one SPSD approximation model.
pub struct SpsdApprox {
    /// The reconstructed n×n approximation.
    pub approx: Matrix,
    /// The fitted spectral shift (0 for the prototype model).
    pub delta: f64,
}

/// Prototype (Nystrom) model: K̃ = C A⁺ Cᵀ.
pub fn prototype_model(k: &Matrix, cols: &[usize]) -> SpsdApprox {
    let c = k.select_columns(cols);
    let a = k.principal_submatrix(cols);
    let apinv = linalg::pinv(&a, 1e-12);
    let approx = linalg::matmul(&linalg::matmul(&c, &apinv), &c.transpose());
    SpsdApprox { approx, delta: 0.0 }
}

/// Full spectral-shifting model (paper sec 3, Wang 2016): fit against
/// the whole matrix. O(n²c); the accuracy ceiling the modified model is
/// compared to.
///
///   δ  = (tr K − tr(C⁺ K (C⁺)ᵀ · (CᵀC)) … ) — we use the JMLR closed
///   form δ = (tr(K) − tr(C⁺KC)) / (n − rank(C)),
///   U  = C⁺ K (C⁺)ᵀ − δ (CᵀC)⁺.
pub fn full_ss_model(k: &Matrix, cols: &[usize], rank_rtol: f64) -> SpsdApprox {
    let n = k.rows();
    let c = k.select_columns(cols);
    let cpinv = linalg::pinv(&c, rank_rtol); // (c, n)
    let rank_c = linalg::numerical_rank(&c, rank_rtol);
    let delta = if n > rank_c {
        // tr(C⁺ K C): K projected into the selected column space
        let proj = linalg::matmul(&linalg::matmul(&cpinv, k), &c);
        ((k.trace() - proj.trace()) / (n - rank_c) as f64).max(0.0)
    } else {
        0.0
    };
    let u = {
        let kc = linalg::matmul(&linalg::matmul(&cpinv, k), &cpinv.transpose());
        let ctc = linalg::gram(&c);
        kc.sub(&linalg::pinv(&ctc, rank_rtol).scale(delta))
    };
    let approx = linalg::matmul(&linalg::matmul(&c, &u), &c.transpose())
        .add_scaled_identity(delta);
    SpsdApprox { approx, delta }
}

/// Modified spectral-shifting model (paper sec 4): fit (U, δ) only on
/// the sampled c×c block A_s. O(c³).
///
///   δ = (tr A − tr(A⁺A²)) / (c − rank A),  U = A⁺ − δ (A²)⁺
pub fn modified_ss_model(k: &Matrix, cols: &[usize], rank_rtol: f64) -> SpsdApprox {
    let c_mat = k.select_columns(cols);
    let a = k.principal_submatrix(cols);
    let csz = cols.len();
    let apinv = linalg::pinv(&a, rank_rtol);
    let r = linalg::numerical_rank(&a, rank_rtol);
    let delta = if csz > r {
        let aa = linalg::matmul(&a, &a);
        ((a.trace() - linalg::matmul(&apinv, &aa).trace()) / (csz - r) as f64)
            .max(0.0)
    } else {
        0.0
    };
    let aa = linalg::matmul(&a, &a);
    let u = apinv.sub(&linalg::pinv(&aa, rank_rtol).scale(delta));
    let approx = linalg::matmul(&linalg::matmul(&c_mat, &u), &c_mat.transpose())
        .add_scaled_identity(delta);
    SpsdApprox { approx, delta }
}

/// Modified SS with the sec-3 shift applied first: K̃ = K − θIₙ before
/// column selection, approximating the rank-k part exactly, then adding
/// θIₙ back. This is the configuration Lemma 1 speaks about when the
/// tail level is known (E4 uses it for the exact-recovery check).
pub fn modified_ss_model_shifted(k: &Matrix, cols: &[usize], shift: f64,
                                 rank_rtol: f64) -> SpsdApprox {
    let kshift = k.add_scaled_identity(-shift);
    let fitted = modified_ss_model(&kshift, cols, rank_rtol);
    SpsdApprox {
        approx: fitted.approx.add_scaled_identity(shift),
        delta: fitted.delta + shift,
    }
}

/// Relative spectral error ‖K − K̃‖₂ / ‖K‖₂.
pub fn rel_spectral_error(k: &Matrix, approx: &Matrix) -> f64 {
    linalg::norms::spectral(&k.sub(approx), 60) / linalg::norms::spectral(k, 60)
}

/// Relative Frobenius error.
pub fn rel_fro_error(k: &Matrix, approx: &Matrix) -> f64 {
    linalg::norms::fro(&k.sub(approx)) / linalg::norms::fro(k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spiked_matrix_has_requested_spectrum() {
        let mut rng = Rng::new(1);
        let k = spiked_spsd(&mut rng, 24, 3, 5.0, 3.0, 0.5);
        let ev = linalg::sym_eigenvalues(&k, 1e-12);
        assert!((ev[0] - 5.0).abs() < 1e-8);
        assert!((ev[2] - 3.0).abs() < 1e-8);
        for &l in &ev[3..] {
            assert!((l - 0.5).abs() < 1e-8);
        }
    }

    #[test]
    fn power_law_spectrum_decays() {
        let mut rng = Rng::new(2);
        let k = power_law_spsd(&mut rng, 16, 1.0);
        let ev = linalg::sym_eigenvalues(&k, 1e-12);
        assert!((ev[0] - 1.0).abs() < 1e-8);
        assert!((ev[15] - 1.0 / 16.0).abs() < 1e-8);
    }

    #[test]
    fn prototype_exact_on_low_rank() {
        // K exactly rank 3, c=6 random columns span it (a.s.)
        let mut rng = Rng::new(3);
        let b = Matrix::from_fn(20, 3, |_, _| rng.normal());
        let k = linalg::matmul(&b, &b.transpose());
        let cols = sample_columns(&mut rng, 20, 6, ColumnSampling::UniformRandom);
        let fit = prototype_model(&k, &cols);
        assert!(rel_fro_error(&k, &fit.approx) < 1e-8);
    }

    #[test]
    fn lemma1_exact_recovery_modified_ss() {
        // spikes k=4, flat tail θ; shift by θ ⇒ rank-4 残り; c=10 ≥ k
        let mut rng = Rng::new(4);
        let theta = 0.4;
        let k = spiked_spsd(&mut rng, 40, 4, 6.0, 4.0, theta);
        let cols = sample_columns(&mut rng, 40, 10, ColumnSampling::UniformRandom);
        let fit = modified_ss_model_shifted(&k, &cols, theta, 1e-8);
        assert!(rel_fro_error(&k, &fit.approx) < 1e-7,
                "err={}", rel_fro_error(&k, &fit.approx));
    }

    #[test]
    fn theorem1_ss_beats_prototype_on_flat_tail() {
        let mut rng = Rng::new(5);
        let theta = 0.5;
        let k = spiked_spsd(&mut rng, 48, 4, 6.0, 4.0, theta);
        let cols = sample_columns(&mut rng, 48, 12, ColumnSampling::Strided);
        let proto = prototype_model(&k, &cols);
        let mss = modified_ss_model_shifted(&k, &cols, theta, 1e-8);
        let e_proto = rel_spectral_error(&k, &proto.approx);
        let e_mss = rel_spectral_error(&k, &mss.approx);
        assert!(e_mss < e_proto * 0.1,
                "mss={e_mss} proto={e_proto}");
        // prototype's error floor is exactly the dropped tail θ
        assert!(e_proto > 0.5 * theta / linalg::norms::spectral(&k, 60));
    }

    #[test]
    fn full_ss_estimates_tail_level() {
        let mut rng = Rng::new(6);
        let theta = 0.3;
        let k = spiked_spsd(&mut rng, 36, 3, 5.0, 4.0, theta);
        let cols = sample_columns(&mut rng, 36, 9, ColumnSampling::UniformRandom);
        let fit = full_ss_model(&k, &cols, 1e-10);
        // δ from the full model ≈ mean dropped tail ≈ θ (biased slightly
        // low because the sampled columns carry some tail mass)
        assert!(fit.delta > 0.1 && fit.delta < 2.0 * theta, "{}", fit.delta);
    }

    #[test]
    fn full_ss_more_accurate_than_modified_more_expensive() {
        // accuracy order: full SS ≥ modified SS (both ≥ prototype on
        // flat-tail inputs). This is the sec-3 vs sec-4 tradeoff.
        let mut rng = Rng::new(7);
        let k = spiked_spsd(&mut rng, 40, 4, 6.0, 3.0, 0.4);
        let cols = sample_columns(&mut rng, 40, 10, ColumnSampling::UniformRandom);
        let full = full_ss_model(&k, &cols, 1e-10);
        let proto = prototype_model(&k, &cols);
        let e_full = rel_fro_error(&k, &full.approx);
        let e_proto = rel_fro_error(&k, &proto.approx);
        assert!(e_full < e_proto, "full={e_full} proto={e_proto}");
    }

    #[test]
    fn column_sampling_strategies() {
        let mut rng = Rng::new(8);
        let u = sample_columns(&mut rng, 100, 10, ColumnSampling::UniformRandom);
        assert_eq!(u.len(), 10);
        assert!(u.windows(2).all(|w| w[0] < w[1]));
        let s = sample_columns(&mut rng, 100, 10, ColumnSampling::Strided);
        assert_eq!(s, vec![0, 10, 20, 30, 40, 50, 60, 70, 80, 90]);
    }
}
