//! CPU execution backend: serve real embeddings with **no XLA
//! artifacts**, driving the [`EncoderStack`](crate::model::EncoderStack)
//! directly.
//!
//! The XLA worker executes an AOT-compiled encode artifact per batch;
//! this module is its in-process twin. A [`CpuModel`] supplies a
//! deterministic token→activation map (seeded Gaussian embedding table
//! plus a sinusoidal position signal) **and** the seeded multi-layer
//! encoder weights; a [`CpuEngine`] turns one assembled [`BatchPlan`]
//! into per-request pooled embeddings:
//!
//! 1. embed each real request's tokens (plus the landmark-alignment
//!    padding tail) into one `(plen × d_model)` activation tensor per
//!    request,
//! 2. run the batch through [`EncoderStack::forward_batch`] — the seed
//!    bare-attention block, then `layers − 1` pre-LN encoder blocks,
//!    heads × requests fanned over the kernel pool through the
//!    [`AttentionOp`](crate::model::AttentionOp) seam,
//! 3. mean-pool each request's **real** rows into one `d_model` vector.
//!
//! Determinism contract: for a fixed [`CpuModelConfig`] and token
//! sequence the served embedding is a pure function of the inputs —
//! independent of batch composition, arrival order, and kernel thread
//! count (every kernel splits work by problem shape, never pool size).
//! `tests/model_parity.rs` pins this against the scalar multi-layer
//! reference, and `tests/integration_cpu_serving.rs` end-to-end at the
//! default `layers = 1`.
//!
//! Padding discipline: a request of length `len` executes at
//! `padded_len(len)` positions ([`aligned_len`] under the operator's
//! landmark divisor; exactly `len` for divisor-free operators). Rows
//! past `padded_len` and slots past `plan.fill` are never touched, and
//! pooled outputs only average real rows.

use super::admission::TierKind;
use super::batcher::{aligned_len, BatchPlan};
use crate::attention::Tensor2;
use crate::config::Variant;
use crate::kernels::{BatchedAttention, BatchedVariant, KernelCtx, Workspace};
use crate::model::{quantize_stack, AttentionOp, Checkpoint, CheckpointError,
                   EncoderStack};
use crate::rngx::Rng;
use std::sync::Arc;

/// Hyperparameters of the deterministic CPU serving model.
#[derive(Clone, Copy, Debug)]
pub struct CpuModelConfig {
    /// Model width (columns of every activation tensor).
    pub d_model: usize,
    /// Attention heads; must divide `d_model`.
    pub n_heads: usize,
    /// Landmark count c for the O(n) variants (doubles as the Linformer
    /// projection dimension — one rank budget across baselines).
    pub landmarks: usize,
    /// Newton-Schulz iterations for the A⁺ pseudoinverse.
    pub pinv_iters: usize,
    /// Embedding-table rows; token ids are wrapped into this range.
    pub vocab: usize,
    /// Seed for the embedding table and encoder weights — fixes the
    /// served function.
    pub seed: u64,
    /// Encoder depth (≥ 1). `1` is the weightless seed block alone —
    /// bitwise-identical to the pre-stack single-pass model.
    pub layers: usize,
    /// FFN expansion factor: inner width = `ffn_mult · d_model`.
    pub ffn_mult: usize,
    /// QKV/output projections in every full encoder block. The seed
    /// block never projects, so `false` (and any depth-1 model) serves
    /// the pre-projection function bitwise.
    pub projections: bool,
}

impl Default for CpuModelConfig {
    fn default() -> Self {
        CpuModelConfig {
            d_model: 64,
            n_heads: 4,
            landmarks: 16,
            pinv_iters: 8,
            vocab: 2048,
            seed: 42,
            layers: 1,
            ffn_mult: 4,
            projections: false,
        }
    }
}

/// Deterministic token→activation model executed by [`CpuEngine`].
///
/// Two instances built from the same config are functionally identical,
/// which is what lets the end-to-end test rebuild the model and check
/// served embeddings against the scalar reference pipeline.
pub struct CpuModel {
    cfg: CpuModelConfig,
    serving_variants: Vec<Variant>,
    stack: EncoderStack,
    /// Admission tier stacks ([`CpuModel::build_tiers`]) — empty until
    /// a serving coordinator asks for them, so trainer/test models pay
    /// nothing for the admission lattice.
    tiers: Vec<(TierKind, EncoderStack)>,
    /// vocab × d_model Gaussian embedding table (seeded).
    embed: Vec<f32>,
    /// sinusoid frequency per even dimension (d_model/2 entries),
    /// precomputed so the per-token embed loop never calls `powf`.
    pos_freqs: Vec<f32>,
}

impl CpuModel {
    /// A uniform stack: every block runs `variant`, weights seeded.
    pub fn new(cfg: CpuModelConfig, variant: Variant) -> CpuModel {
        CpuModel::new_mixed(cfg, &[variant])
    }

    /// Seeded model with per-layer operators: `variants` is either one
    /// entry (replicated to every block) or exactly `cfg.layers`
    /// entries, seed block first.
    pub fn new_mixed(cfg: CpuModelConfig, variants: &[Variant]) -> CpuModel {
        let (serving, kernel) = CpuModel::resolve_variants(&cfg, variants);
        let stack = EncoderStack::new_mixed(kernel, cfg.d_model, cfg.n_heads,
                                            cfg.ffn_mult, cfg.seed,
                                            cfg.projections);
        CpuModel::assemble(cfg, serving, stack)
    }

    /// Model serving externally trained weights: the checkpoint's
    /// shape must match `cfg` exactly (depth, widths, projection flag)
    /// — any disagreement or file problem fails closed with a typed
    /// [`CheckpointError`].
    pub fn with_checkpoint(cfg: CpuModelConfig, variants: &[Variant],
                           ckpt: Checkpoint)
                           -> Result<CpuModel, CheckpointError> {
        let (serving, kernel) = CpuModel::resolve_variants(&cfg, variants);
        ckpt.check_shape(cfg.d_model, cfg.n_heads, cfg.ffn_mult, cfg.layers,
                         cfg.projections)?;
        let stack = ckpt.into_stack(kernel)?;
        Ok(CpuModel::assemble(cfg, serving, stack))
    }

    /// Validate the config and expand `variants` to one serving/kernel
    /// operator per block.
    fn resolve_variants(cfg: &CpuModelConfig, variants: &[Variant])
                        -> (Vec<Variant>, Vec<BatchedVariant>) {
        assert!(cfg.n_heads > 0 && cfg.d_model % cfg.n_heads == 0,
                "d_model {} must be divisible by n_heads {}",
                cfg.d_model, cfg.n_heads);
        assert!(cfg.landmarks > 0 && cfg.vocab > 0, "degenerate model config");
        assert!(cfg.layers > 0, "encoder depth must be >= 1");
        assert!(cfg.ffn_mult > 0, "ffn_mult must be >= 1");
        let serving: Vec<Variant> = match variants.len() {
            1 => vec![variants[0]; cfg.layers],
            n if n == cfg.layers => variants.to_vec(),
            n => panic!("{n} per-layer variants for layers = {}", cfg.layers),
        };
        let kernel = serving
            .iter()
            .map(|&v| BatchedVariant::from_config(v, cfg.landmarks,
                                                  cfg.pinv_iters))
            .collect();
        (serving, kernel)
    }

    fn assemble(cfg: CpuModelConfig, serving_variants: Vec<Variant>,
                stack: EncoderStack) -> CpuModel {
        let mut rng = Rng::new(cfg.seed);
        let mut embed = vec![0.0f32; cfg.vocab * cfg.d_model];
        rng.fill_normal_f32(&mut embed, 0.0, 1.0);
        let pos_freqs = (0..cfg.d_model / 2)
            .map(|h| 10_000f32.powf(-((2 * h) as f32) / cfg.d_model as f32))
            .collect();
        CpuModel { cfg, serving_variants, stack, tiers: Vec::new(), embed,
                   pos_freqs }
    }

    /// Build the admission tier stacks from the loaded weights — the
    /// "quantize once at load" half of the precision-tier contract.
    /// Every [`TierKind`] gets a stack: `full-f32` re-bases every block
    /// on exact attention at f32, and the `ss-*` tiers run spectral
    /// shifting (model landmarks / pinv iters) at f32 / bf16 / int8.
    /// Idempotent; serving coordinators call it once before the model
    /// is shared, and non-serving paths never pay for it. Which tiers
    /// are *admissible* (bucket divisibility) is the coordinator's
    /// call, not the model's.
    pub fn build_tiers(&mut self) {
        if !self.tiers.is_empty() {
            return;
        }
        let full = vec![
            BatchedVariant::from_config(Variant::Full, self.cfg.landmarks,
                                        self.cfg.pinv_iters);
            self.cfg.layers
        ];
        let ss = vec![
            BatchedVariant::from_config(Variant::SpectralShift,
                                        self.cfg.landmarks,
                                        self.cfg.pinv_iters);
            self.cfg.layers
        ];
        for tier in TierKind::ALL {
            let variants = if tier.is_ss() { ss.clone() } else { full.clone() };
            let stack = quantize_stack(&self.stack, variants,
                                       tier.precision());
            self.tiers.push((tier, stack));
        }
    }

    /// Whether [`CpuModel::build_tiers`] has run.
    pub fn tiers_built(&self) -> bool {
        !self.tiers.is_empty()
    }

    /// The encoder stack serving `tier`, if tiers are built.
    pub fn tier_stack(&self, tier: TierKind) -> Option<&EncoderStack> {
        self.tiers.iter().find(|(t, _)| *t == tier).map(|(_, s)| s)
    }

    /// [`CpuModel::padded_len`] under `tier`'s operator instead of the
    /// configured one (full tiers never pad; ss tiers align to the
    /// landmark count). Panics if tiers were never built.
    pub fn tier_padded_len(&self, tier: TierKind, len: usize) -> usize {
        let stack = self.tier_stack(tier).expect("tier stacks not built");
        aligned_len(len, stack.landmark_divisor())
    }

    pub fn d_model(&self) -> usize {
        self.cfg.d_model
    }

    /// The frozen vocab × d_model token-embedding table. The CPU
    /// trainer's tied MLM head computes logits against these rows (the
    /// table is drawn from `cfg.seed` and never updated, so a saved
    /// checkpoint plus the config seed fully determine the trained
    /// function).
    pub(crate) fn embed_table(&self) -> &[f32] {
        &self.embed
    }

    pub fn n_heads(&self) -> usize {
        self.cfg.n_heads
    }

    pub fn landmarks(&self) -> usize {
        self.cfg.landmarks
    }

    pub fn pinv_iters(&self) -> usize {
        self.cfg.pinv_iters
    }

    /// Encoder depth (seed block + full blocks).
    pub fn layers(&self) -> usize {
        self.cfg.layers
    }

    /// FFN expansion factor.
    pub fn ffn_mult(&self) -> usize {
        self.cfg.ffn_mult
    }

    /// The serving-config variant of the seed block (uniform models:
    /// the only one).
    pub fn variant(&self) -> Variant {
        self.serving_variants[0]
    }

    /// One serving-config variant per encoder block, seed block first.
    pub fn variants(&self) -> &[Variant] {
        &self.serving_variants
    }

    /// Whether full blocks run QKV/output projections.
    pub fn projections(&self) -> bool {
        self.cfg.projections
    }

    /// The kernel dispatch the seed-block variant maps onto (also the
    /// model's `&dyn AttentionOp`).
    pub fn kernel_variant(&self) -> BatchedVariant {
        self.stack.variant()
    }

    /// The encoder stack this model serves through.
    pub fn stack(&self) -> &EncoderStack {
        &self.stack
    }

    /// One-line description for STATS / operator logs: depth, per-block
    /// operator(s), widths, projection flag, and weight provenance.
    pub fn describe(&self) -> String {
        let names: Vec<&str> =
            self.stack.variants().iter().map(|v| v.name()).collect();
        let variant = if names.iter().all(|n| *n == names[0]) {
            names[0].to_string()
        } else {
            names.join(",")
        };
        format!("{} layers, variant={variant}, d_model={}, heads={}, \
                 ffn_mult={}, projections={}, weights={}",
                self.cfg.layers, self.cfg.d_model, self.cfg.n_heads,
                self.cfg.ffn_mult,
                if self.cfg.projections { "on" } else { "off" },
                self.stack.init().token())
    }

    /// `Some(c)` when execution lengths must be divisible by the
    /// landmark count (segment-means operators), `None` otherwise —
    /// delegated to the attention operator through the stack.
    pub fn landmark_divisor(&self) -> Option<usize> {
        self.stack.landmark_divisor()
    }

    /// The sequence length a `len`-token request executes at:
    /// [`aligned_len`] under the operator's landmark divisor — the same
    /// helper the batching paths use, so model and batcher cannot drift.
    pub fn padded_len(&self, len: usize) -> usize {
        aligned_len(len, self.landmark_divisor())
    }

    /// Embed `tokens` into `out` (`tokens.len() × d_model`, row-major):
    /// table row for the (range-wrapped) token id plus a sinusoidal
    /// position signal so repeated tokens at different positions map to
    /// distinct activations.
    pub fn embed_into(&self, tokens: &[i32], out: &mut [f32]) {
        let d = self.cfg.d_model;
        assert_eq!(out.len(), tokens.len() * d, "embed buffer shape");
        for (i, &tok) in tokens.iter().enumerate() {
            let row = (tok as i64).rem_euclid(self.cfg.vocab as i64) as usize;
            let orow = &mut out[i * d..(i + 1) * d];
            orow.copy_from_slice(&self.embed[row * d..(row + 1) * d]);
            let pos = i as f32;
            for (h, &freq) in self.pos_freqs.iter().enumerate() {
                let j = 2 * h;
                orow[j] += (pos * freq).sin();
                orow[j + 1] += (pos * freq).cos();
            }
        }
    }

    /// `(len × d_model)` activations for `tokens`, truncated or
    /// right-padded with the PAD token to exactly `len` rows — the
    /// standalone twin of the batched staging in
    /// [`CpuEngine::encode_batch`], used by tests to rebuild the exact
    /// kernel inputs.
    pub fn embed_sequence(&self, tokens: &[i32], len: usize) -> Tensor2 {
        let mut padded: Vec<i32> = tokens.iter().copied().take(len).collect();
        padded.resize(len, crate::text::PAD);
        let mut t = Tensor2::zeros(len, self.cfg.d_model);
        self.embed_into(&padded, &mut t.data);
        t
    }
}

/// Batch executor owned by one coordinator CPU worker thread. Holds a
/// shared handle to the model, the multi-head fan-out executor, and a
/// staging arena so steady-state batches embed + execute with zero heap
/// allocations from the arenas.
///
/// A worker *pool* runs one `CpuEngine` per thread, all [`fork`]ed from
/// the same engine: the (read-only) model — embedding table and encoder
/// weights included — is shared behind an `Arc`, while the executor and
/// staging arena are per-worker (they are the mutable state). Forked
/// engines compute bitwise-identical embeddings: the model is literally
/// the same memory, and the kernels are thread-count deterministic.
///
/// [`fork`]: CpuEngine::fork
pub struct CpuEngine {
    model: Arc<CpuModel>,
    exec: BatchedAttention,
    stage: Workspace,
}

impl CpuEngine {
    pub fn new(model: CpuModel) -> CpuEngine {
        CpuEngine::with_model(Arc::new(model))
    }

    /// Build an engine over an already-shared model.
    pub fn with_model(model: Arc<CpuModel>) -> CpuEngine {
        CpuEngine {
            model,
            exec: BatchedAttention::new(KernelCtx::global()),
            stage: Workspace::new(),
        }
    }

    /// A sibling engine over the same shared model, with its own
    /// executor and staging arena — one per worker-pool thread. The
    /// sibling inherits this engine's pinned micro-kernel arm, so every
    /// worker in a pool executes the same arm (the cache-coherence
    /// argument needs worker-independent bits).
    pub fn fork(&self) -> CpuEngine {
        let mut e = CpuEngine::with_model(self.model.clone());
        e.set_kernel_isa(self.exec.ctx().isa());
        e
    }

    /// Pin this engine's kernels to an explicit micro-kernel arm
    /// (coordinator startup resolves `SSAF_KERNEL` / the `[serving]
    /// kernel` knob / detection and applies the result here). Rebuilds
    /// the executor, so call before [`CpuEngine::plan_for`].
    pub fn set_kernel_isa(&mut self, isa: crate::kernels::Isa) {
        self.exec = BatchedAttention::new(KernelCtx::global().with_isa(isa));
    }

    pub fn model(&self) -> &CpuModel {
        &self.model
    }

    /// Build the model's admission tier stacks
    /// ([`CpuModel::build_tiers`]) if this engine still *uniquely* owns
    /// the model — i.e. before any [`CpuEngine::fork`]. Returns whether
    /// tier stacks are available afterwards; a shared, never-tiered
    /// model stays untiered (the coordinator then admits full-f32
    /// only).
    pub fn ensure_tiers(&mut self) -> bool {
        if let Some(m) = Arc::get_mut(&mut self.model) {
            m.build_tiers();
        }
        self.model.tiers_built()
    }

    /// Pre-plan the staging arena for batches of `capacity` requests at
    /// up to `max_seq` positions ([`EncoderStack::plan_sizes`] →
    /// [`Workspace::plan`]), so even the first batch at the largest
    /// bucket allocates nothing from the stage. The coordinator calls
    /// this per worker engine before serving.
    pub fn plan_for(&mut self, capacity: usize, max_seq: usize) {
        let sizes = self.model.stack().plan_sizes(capacity, max_seq);
        self.stage.plan(&sizes);
    }

    /// Padding positions [`CpuEngine::encode_batch`] will execute on top
    /// of the real tokens for these request lengths (the CPU path's
    /// padding-waste metric: landmark-alignment tails only, since
    /// padding *rows* never execute at all).
    pub fn padded_positions(&self, lens: &[usize]) -> u64 {
        lens.iter().map(|&l| (self.model.padded_len(l) - l) as u64).sum()
    }

    /// [`CpuEngine::padded_positions`] under an admission tier: `None`
    /// is the configured operator, `Some(t)` pads to tier `t`'s
    /// alignment instead.
    pub fn padded_positions_for(&self, tier: Option<TierKind>,
                                lens: &[usize]) -> u64 {
        match tier {
            None => self.padded_positions(lens),
            Some(t) => lens
                .iter()
                .map(|&l| (self.model.tier_padded_len(t, l) - l) as u64)
                .sum(),
        }
    }

    /// Execute one assembled batch: embed every real request, forward
    /// the batch through the encoder stack (heads × requests in
    /// parallel on the kernel pool), and mean-pool each request's real
    /// rows. `lens[r]` is request r's true token count, exactly what the
    /// caller handed `assemble`. Returns one `d_model` embedding per
    /// real request, in order.
    pub fn encode_batch(&mut self, plan: &BatchPlan, lens: &[usize]) -> Vec<Vec<f32>> {
        self.encode_batch_with(plan, lens, None)
    }

    /// [`CpuEngine::encode_batch`] through an admission tier's stack:
    /// `None` serves the configured model (bitwise the pre-admission
    /// path — same stack, same padding), `Some(tier)` swaps in the
    /// load-time tier stack and pads to *its* landmark alignment. The
    /// staging arena needs no tier-specific planning: tier stacks share
    /// `plan_sizes` with the source (pinned in `model::quantized`).
    pub fn encode_batch_with(&mut self, plan: &BatchPlan, lens: &[usize],
                             tier: Option<TierKind>) -> Vec<Vec<f32>> {
        assert_eq!(lens.len(), plan.fill, "one length per real request");
        let stack = match tier {
            None => &self.model.stack,
            Some(t) => self.model.tier_stack(t).expect(
                "tier-routed batch on a model without built tier stacks"),
        };
        let d = self.model.cfg.d_model;
        // stage one activation tensor per real request — a 1-request
        // batch in a capacity-4 plan stages exactly one tensor
        let mut xs: Vec<Tensor2> = Vec::with_capacity(plan.fill);
        for (r, &len) in lens.iter().enumerate() {
            assert!(len > 0 && len <= plan.seq,
                    "request {r} length {len} outside 1..={}", plan.seq);
            let plen = aligned_len(len, stack.landmark_divisor()).min(plan.seq);
            // assemble() already PAD-filled the row tail, so the slice
            // covers the landmark-alignment padding tokens too
            let toks = &plan.tokens[r * plan.seq..r * plan.seq + plen];
            let mut x = Tensor2 {
                rows: plen,
                cols: d,
                data: self.stage.take(plen * d),
            };
            self.model.embed_into(toks, &mut x.data);
            xs.push(x);
        }
        stack.forward_batch(&mut self.exec, &mut xs, &mut self.stage);
        let outs = xs
            .iter()
            .zip(lens)
            .map(|(t, &len)| mean_pool(t, len))
            .collect();
        for t in xs {
            self.stage.put(t.data);
        }
        outs
    }
}

/// Mean over the first `len` rows of `t` — pooling only ever sees real
/// positions, never the landmark-alignment tail.
fn mean_pool(t: &Tensor2, len: usize) -> Vec<f32> {
    let len = len.min(t.rows).max(1);
    let mut out = vec![0.0f32; t.cols];
    for i in 0..len {
        for (o, v) in out.iter_mut().zip(t.row(i)) {
            *o += *v;
        }
    }
    let inv = 1.0 / len as f32;
    for o in out.iter_mut() {
        *o *= inv;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::spectral_shift::{reference, SpectralShiftConfig};
    use crate::coordinator::batcher::assemble;
    use crate::model::reference::forward_ref;

    fn toks(n: usize, seed: i32) -> Vec<i32> {
        (0..n).map(|i| 3 + ((i as i32 * 17 + seed) % 2000)).collect()
    }

    #[test]
    fn padded_len_per_variant() {
        let m = CpuModel::new(CpuModelConfig::default(), Variant::SpectralShift);
        assert_eq!(m.padded_len(1), 16);
        assert_eq!(m.padded_len(16), 16);
        assert_eq!(m.padded_len(17), 32);
        assert_eq!(m.landmark_divisor(), Some(16));
        let m = CpuModel::new(CpuModelConfig::default(), Variant::Full);
        assert_eq!(m.padded_len(17), 17);
        assert_eq!(m.landmark_divisor(), None);
        // divisor-free O(n) baselines execute at the exact length too
        let m = CpuModel::new(CpuModelConfig::default(), Variant::Linformer);
        assert_eq!(m.padded_len(17), 17);
        assert_eq!(m.landmark_divisor(), None);
    }

    #[test]
    fn model_is_deterministic_across_instances() {
        let a = CpuModel::new(CpuModelConfig::default(), Variant::SpectralShift);
        let b = CpuModel::new(CpuModelConfig::default(), Variant::SpectralShift);
        let t = toks(40, 1);
        let xa = a.embed_sequence(&t, 48);
        let xb = b.embed_sequence(&t, 48);
        assert_eq!(xa.data, xb.data);
        // position signal distinguishes repeated tokens
        let rep = a.embed_sequence(&[7, 7], 2);
        assert_ne!(rep.row(0), rep.row(1));
    }

    #[test]
    fn out_of_range_tokens_wrap_instead_of_panicking() {
        let m = CpuModel::new(CpuModelConfig::default(), Variant::Full);
        let x = m.embed_sequence(&[-5, 9999, i32::MAX], 3);
        assert!(x.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn describe_names_depth_and_operator() {
        let cfg = CpuModelConfig { layers: 4, ..Default::default() };
        let m = CpuModel::new(cfg, Variant::SpectralShift);
        let d = m.describe();
        assert!(d.contains("4 layers"), "{d}");
        assert!(d.contains("variant=spectral_shift"), "{d}");
        assert!(d.contains("projections=off"), "{d}");
        assert!(d.contains("weights=seeded"), "{d}");
        assert_eq!(m.layers(), 4);
        assert_eq!(m.ffn_mult(), 4);
    }

    #[test]
    fn describe_names_mixing_and_projections() {
        let cfg = CpuModelConfig { layers: 2, projections: true,
                                   ..Default::default() };
        let m = CpuModel::new_mixed(
            cfg, &[Variant::SpectralShift, Variant::Full]);
        let d = m.describe();
        assert!(d.contains("variant=spectral_shift,full"), "{d}");
        assert!(d.contains("projections=on"), "{d}");
        assert_eq!(m.variants(), &[Variant::SpectralShift, Variant::Full]);
        assert_eq!(m.variant(), Variant::SpectralShift, "seed block leads");
        assert!(m.projections());
    }

    #[test]
    fn projected_encode_matches_the_scalar_projected_reference() {
        let cfg = CpuModelConfig { layers: 2, ffn_mult: 2, projections: true,
                                   ..Default::default() };
        let model = CpuModel::new(cfg, Variant::SpectralShift);
        let verify = CpuModel::new(cfg, Variant::SpectralShift);
        let mut engine = CpuEngine::new(model);
        let t = toks(100, 12);
        let plan = assemble(&[t.as_slice()], 4, 128);
        let got = engine.encode_batch(&plan, &[t.len()]);
        let plen = verify.padded_len(t.len());
        let x = verify.embed_sequence(&t, plen);
        let full = forward_ref(verify.stack(), &x);
        let want = mean_pool(&full, t.len());
        for (j, (a, b)) in got[0].iter().zip(&want).enumerate() {
            assert!((a - b).abs() <= 1e-4 * b.abs().max(1.0),
                    "dim {j}: engine {a} vs projected reference {b}");
        }
    }

    #[test]
    fn checkpointed_model_serves_bitwise_the_saved_function() {
        let cfg = CpuModelConfig { layers: 3, ffn_mult: 2, projections: true,
                                   ..Default::default() };
        let seeded = CpuModel::new(cfg, Variant::SpectralShift);
        let path = std::env::temp_dir().join(format!(
            "ssaformer-engine-ckpt-{}.bin", std::process::id()));
        crate::model::checkpoint::save(seeded.stack(), &path).unwrap();
        let ckpt = crate::model::checkpoint::load(&path).unwrap();
        let loaded = CpuModel::with_checkpoint(
            cfg, &[Variant::SpectralShift], ckpt).unwrap();
        assert!(loaded.describe().contains("weights=loaded"),
                "{}", loaded.describe());
        let t = toks(80, 13);
        let plan = assemble(&[t.as_slice()], 4, 128);
        let a = CpuEngine::new(seeded).encode_batch(&plan, &[t.len()]);
        let b = CpuEngine::new(loaded).encode_batch(&plan, &[t.len()]);
        assert_eq!(a, b, "checkpoint load must reproduce the served function");
        // a shape disagreement fails closed
        let ckpt = crate::model::checkpoint::load(&path).unwrap();
        let narrow = CpuModelConfig { layers: 2, ..cfg };
        assert!(matches!(
            CpuModel::with_checkpoint(narrow, &[Variant::SpectralShift], ckpt),
            Err(crate::model::CheckpointError::Mismatch { .. })));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn encode_batch_matches_per_head_reference() {
        // engine path (batched kernels) vs the seed scalar pipeline,
        // per head, then pooled — mixed lengths incl. a padded tail
        let model = CpuModel::new(CpuModelConfig::default(), Variant::SpectralShift);
        let verify = CpuModel::new(CpuModelConfig::default(), Variant::SpectralShift);
        let mut engine = CpuEngine::new(model);
        let reqs = [toks(100, 1), toks(128, 2), toks(40, 3)];
        let refs: Vec<&[i32]> = reqs.iter().map(|t| t.as_slice()).collect();
        let lens: Vec<usize> = reqs.iter().map(|t| t.len()).collect();
        let plan = assemble(&refs, 4, 128);
        let got = engine.encode_batch(&plan, &lens);
        assert_eq!(got.len(), 3);
        let (d, h) = (verify.d_model(), verify.n_heads());
        let dh = d / h;
        for (r, t) in reqs.iter().enumerate() {
            let plen = verify.padded_len(t.len());
            let x = verify.embed_sequence(t, plen);
            let mut full = Tensor2::zeros(plen, d);
            for head in 0..h {
                let mut xs = Tensor2::zeros(plen, dh);
                for i in 0..plen {
                    for j in 0..dh {
                        xs.data[i * dh + j] = x.data[i * d + head * dh + j];
                    }
                }
                let mut cfg = SpectralShiftConfig::new(verify.landmarks());
                cfg.pinv_iters = verify.pinv_iters();
                let oh = reference::spectral_shift_attention_ref(&xs, &xs, &xs, &cfg);
                for i in 0..plen {
                    for j in 0..dh {
                        full.data[i * d + head * dh + j] = oh.data[i * dh + j];
                    }
                }
            }
            let want = mean_pool(&full, t.len());
            for (j, (a, b)) in got[r].iter().zip(&want).enumerate() {
                assert!((a - b).abs() <= 1e-4 * b.abs().max(1.0),
                        "req {r} dim {j}: engine {a} vs reference {b}");
            }
        }
    }

    #[test]
    fn multi_layer_encode_matches_stack_reference() {
        // the engine at layers = 3 must equal the scalar multi-layer
        // forward: embed → forward_ref → pool
        let cfg = CpuModelConfig { layers: 3, ffn_mult: 2, ..Default::default() };
        let model = CpuModel::new(cfg, Variant::SpectralShift);
        let verify = CpuModel::new(cfg, Variant::SpectralShift);
        let mut engine = CpuEngine::new(model);
        let t = toks(100, 5);
        let plan = assemble(&[t.as_slice()], 4, 128);
        let got = engine.encode_batch(&plan, &[t.len()]);
        let plen = verify.padded_len(t.len());
        let x = verify.embed_sequence(&t, plen);
        let full = forward_ref(verify.stack(), &x);
        let want = mean_pool(&full, t.len());
        for (j, (a, b)) in got[0].iter().zip(&want).enumerate() {
            assert!((a - b).abs() <= 1e-4 * b.abs().max(1.0),
                    "dim {j}: engine {a} vs stack reference {b}");
        }
    }

    #[test]
    fn encode_batch_is_independent_of_batch_composition() {
        let mk = || CpuEngine::new(
            CpuModel::new(CpuModelConfig::default(), Variant::SpectralShift));
        let t = toks(100, 4);
        let mut solo = mk();
        let plan1 = assemble(&[t.as_slice()], 4, 128);
        let alone = solo.encode_batch(&plan1, &[t.len()]);
        let mut full = mk();
        let other = toks(64, 5);
        let plan2 = assemble(&[other.as_slice(), t.as_slice()], 4, 128);
        let batched = full.encode_batch(&plan2, &[other.len(), t.len()]);
        assert_eq!(alone[0], batched[1],
                   "embedding must not depend on batchmates");
    }

    #[test]
    fn steady_state_batches_do_not_allocate_from_stage() {
        let mut engine = CpuEngine::new(
            CpuModel::new(CpuModelConfig::default(), Variant::SpectralShift));
        let reqs = [toks(100, 6), toks(50, 7)];
        let refs: Vec<&[i32]> = reqs.iter().map(|t| t.as_slice()).collect();
        let lens: Vec<usize> = reqs.iter().map(|t| t.len()).collect();
        let plan = assemble(&refs, 4, 128);
        let _ = engine.encode_batch(&plan, &lens);
        let warm = engine.stage.allocations();
        for _ in 0..3 {
            let _ = engine.encode_batch(&plan, &lens);
        }
        assert_eq!(engine.stage.allocations(), warm);
    }

    #[test]
    fn planned_engine_first_batch_allocates_nothing_from_stage() {
        // the multi-layer path exercises LN/FFN scratch too
        let cfg = CpuModelConfig { layers: 3, ffn_mult: 2, ..Default::default() };
        let mut engine = CpuEngine::new(
            CpuModel::new(cfg, Variant::SpectralShift));
        engine.plan_for(4, 128);
        let planned = engine.stage.allocations();
        let reqs = [toks(100, 8), toks(128, 9), toks(40, 10), toks(64, 11)];
        let refs: Vec<&[i32]> = reqs.iter().map(|t| t.as_slice()).collect();
        let lens: Vec<usize> = reqs.iter().map(|t| t.len()).collect();
        let plan = assemble(&refs, 4, 128);
        let _ = engine.encode_batch(&plan, &lens);
        assert_eq!(engine.stage.allocations(), planned,
                   "planned stage must cover the first full batch");
    }

    #[test]
    fn forked_engines_share_the_model_and_agree_bitwise() {
        let mut a = CpuEngine::new(
            CpuModel::new(CpuModelConfig::default(), Variant::SpectralShift));
        let mut b = a.fork();
        assert!(std::ptr::eq(a.model(), b.model()), "model must be shared");
        let t = toks(100, 8);
        let plan = assemble(&[t.as_slice()], 4, 128);
        let ea = a.encode_batch(&plan, &[t.len()]);
        let eb = b.encode_batch(&plan, &[t.len()]);
        assert_eq!(ea, eb, "forked workers must serve identical embeddings");
    }

    #[test]
    fn padded_positions_counts_alignment_tails() {
        let engine = CpuEngine::new(
            CpuModel::new(CpuModelConfig::default(), Variant::SpectralShift));
        // 100 → 112 (+12), 128 → 128 (+0), 40 → 48 (+8)
        assert_eq!(engine.padded_positions(&[100, 128, 40]), 20);
    }

    #[test]
    fn tier_stacks_cover_every_tier_and_build_once() {
        use crate::coordinator::admission::TierKind;
        let mut m = CpuModel::new(CpuModelConfig::default(), Variant::Full);
        assert!(!m.tiers_built(), "trainer/test models skip the lattice");
        assert!(m.tier_stack(TierKind::SsInt8).is_none());
        m.build_tiers();
        assert!(m.tiers_built());
        for tier in TierKind::ALL {
            let s = m.tier_stack(tier).expect("tier stack missing");
            assert_eq!(s.landmark_divisor(),
                       if tier.is_ss() { Some(16) } else { None });
        }
        // idempotent — a second call must not duplicate the lattice
        let before = m.tiers.len();
        m.build_tiers();
        assert_eq!(m.tiers.len(), before);
        // 100 pads to 112 under ss tiers, stays exact under full-f32
        assert_eq!(m.tier_padded_len(TierKind::SsInt8, 100), 112);
        assert_eq!(m.tier_padded_len(TierKind::FullF32, 100), 100);
        let e = CpuEngine::new(m);
        assert_eq!(e.padded_positions_for(Some(TierKind::SsBf16),
                                          &[100, 128, 40]), 20);
        assert_eq!(e.padded_positions_for(Some(TierKind::FullF32),
                                          &[100, 128, 40]), 0);
        assert_eq!(e.padded_positions_for(None, &[100, 128, 40]), 0);
    }

    #[test]
    fn full_f32_tier_serves_bitwise_the_configured_full_model() {
        use crate::coordinator::admission::TierKind;
        // configured variant = full, so the full-f32 tier is the same
        // operator over a bitwise weight copy: encode must be identical
        let mut m = CpuModel::new(CpuModelConfig::default(), Variant::Full);
        m.build_tiers();
        let mut engine = CpuEngine::new(m);
        let t = toks(100, 21);
        let plan = assemble(&[t.as_slice()], 4, 128);
        let base = engine.encode_batch(&plan, &[t.len()]);
        let tiered = engine.encode_batch_with(&plan, &[t.len()],
                                              Some(TierKind::FullF32));
        assert_eq!(base, tiered, "full-f32 tier must be the f32 reference");
    }

    #[test]
    fn quantized_tiers_diverge_boundedly_and_deterministically() {
        use crate::coordinator::admission::TierKind;
        let mut m = CpuModel::new(
            CpuModelConfig { layers: 2, ffn_mult: 2, ..Default::default() },
            Variant::Full);
        m.build_tiers();
        let mut engine = CpuEngine::new(m);
        let t = toks(96, 5);
        let plan = assemble(&[t.as_slice()], 4, 128);
        let base = engine.encode_batch(&plan, &[t.len()]);
        // quantization error is judged against the same operator at f32,
        // so the bound matches the model::quantized forward pin instead
        // of also absorbing the full-vs-ss operator gap
        let ss_f32 = engine.encode_batch_with(&plan, &[t.len()],
                                              Some(TierKind::SsF32));
        assert_ne!(ss_f32, base, "ss tier must swap the operator");
        for tier in [TierKind::SsF32, TierKind::SsBf16, TierKind::SsInt8] {
            let a = engine.encode_batch_with(&plan, &[t.len()], Some(tier));
            let b = engine.encode_batch_with(&plan, &[t.len()], Some(tier));
            assert_eq!(a, b, "{tier:?} must be deterministic");
            let (mut num, mut den) = (0f64, 0f64);
            for (x, y) in a[0].iter().zip(&ss_f32[0]) {
                num += ((x - y) as f64).powi(2);
                den += (*y as f64).powi(2);
            }
            let rel = (num / den.max(1e-30)).sqrt();
            let bound = match tier {
                TierKind::SsF32 => {
                    assert_eq!(rel, 0.0, "ss-f32 is its own reference");
                    continue;
                }
                _ => 0.2,
            };
            assert!(rel > 0.0 && rel < bound,
                    "{tier:?} rel err {rel} outside (0, {bound})");
        }
    }

    #[test]
    fn forked_engines_agree_on_tier_routed_batches() {
        use crate::coordinator::admission::TierKind;
        let mut m = CpuModel::new(CpuModelConfig::default(), Variant::Full);
        m.build_tiers();
        let mut a = CpuEngine::new(m);
        let mut b = a.fork();
        let t = toks(64, 30);
        let plan = assemble(&[t.as_slice()], 4, 128);
        let ea = a.encode_batch_with(&plan, &[t.len()], Some(TierKind::SsInt8));
        let eb = b.encode_batch_with(&plan, &[t.len()], Some(TierKind::SsInt8));
        assert_eq!(ea, eb, "tier stacks are shared through the model Arc");
    }
}
