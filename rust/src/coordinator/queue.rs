//! Bounded per-bucket request queues with condvar wakeups — the
//! coordinator's admission + backpressure point.
//!
//! Two layers:
//!
//! * [`BucketQueue`] — one mutex-protected set of per-bucket FIFO
//!   lanes. Batch formation is *deadline-aware*: a lane becomes ready
//!   when it is full, when its head has aged past `max_wait`, **or**
//!   when any queued item's deadline is within `deadline_margin` of
//!   expiring (so a batch is closed early rather than letting its
//!   members blow their deadlines waiting for batchmates).
//! * [`ShardedQueue`] — N independent `BucketQueue` shards. Buckets are
//!   assigned to shards statically (`bucket_idx % shards`), which keeps
//!   every batch bucket-homogeneous *and* keeps same-bucket requests in
//!   one lane so batches still fill. Each worker in the pool has a home
//!   shard it blocks on, and **steals** a ready batch from any other
//!   shard when its home has nothing to do — so one hot bucket is
//!   drained by every idle worker, not just the shard's "owner".

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// A queued item tagged with its bucket, enqueue time, and optional
/// absolute deadline (requests past it are expired by the worker, not
/// by the queue — the queue only uses deadlines for early batch close).
pub struct Queued<T> {
    pub bucket: usize,
    pub enqueued: Instant,
    pub deadline: Option<Instant>,
    pub item: T,
}

struct Lane<T> {
    items: VecDeque<Queued<T>>,
    /// Earliest deadline among queued items (None when no item carries
    /// one). Maintained incrementally on push, recomputed on drain, so
    /// the readiness/wake paths — which run on every worker poll, under
    /// the shard mutex — stay O(lanes) instead of O(queued items).
    min_deadline: Option<Instant>,
}

struct Inner<T> {
    /// one FIFO per bucket index
    lanes: Vec<Lane<T>>,
    total: usize,
    closed: bool,
}

/// Bounded multi-lane FIFO. `push` applies backpressure by rejection
/// (serving semantics: better to fail fast than stall the socket);
/// `pop_batch` blocks until a lane is "ready" per the batch policy.
pub struct BucketQueue<T> {
    inner: Mutex<Inner<T>>,
    ready: Condvar,
    capacity: usize,
}

/// Why a push was refused.
#[derive(Debug, PartialEq, Eq)]
pub enum PushError {
    Full,
    Closed,
    BadBucket,
}

/// Batch-formation policy: a lane is ready when it has `max_batch`
/// items, its head item has waited ≥ `max_wait`, or any queued item's
/// deadline is within `deadline_margin` of now (early close — leave
/// the margin for execution itself).
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub deadline_margin: Duration,
}

impl<T> BucketQueue<T> {
    pub fn new(n_buckets: usize, capacity: usize) -> Self {
        BucketQueue {
            inner: Mutex::new(Inner {
                lanes: (0..n_buckets)
                    .map(|_| Lane { items: VecDeque::new(), min_deadline: None })
                    .collect(),
                total: 0,
                closed: false,
            }),
            ready: Condvar::new(),
            capacity,
        }
    }

    /// Enqueue into a bucket lane; rejects when at capacity or closed.
    pub fn push(&self, bucket_idx: usize, item: T) -> Result<(), PushError> {
        self.push_with_deadline(bucket_idx, item, None)
    }

    /// [`BucketQueue::push`] with an absolute deadline the batcher may
    /// close the lane early for.
    pub fn push_with_deadline(&self, bucket_idx: usize, item: T,
                              deadline: Option<Instant>) -> Result<(), PushError> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(PushError::Closed);
        }
        if bucket_idx >= g.lanes.len() {
            return Err(PushError::BadBucket);
        }
        if g.total >= self.capacity {
            return Err(PushError::Full);
        }
        let lane = &mut g.lanes[bucket_idx];
        lane.items.push_back(Queued {
            bucket: bucket_idx,
            enqueued: Instant::now(),
            deadline,
            item,
        });
        lane.min_deadline = match (lane.min_deadline, deadline) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        g.total += 1;
        drop(g);
        self.ready.notify_one();
        Ok(())
    }

    /// Total queued items.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().total
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close the queue: pending pops drain remaining items, further
    /// pushes fail.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.ready.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    /// Blocking pop of the next batch per `policy`.
    ///
    /// Returns items all from ONE lane (a batch must share its artifact
    /// bucket), at most `policy.max_batch` of them, or None once closed
    /// and drained. Lane choice: the oldest-head lane among every ready
    /// lane — full, aged past `max_wait`, under deadline pressure, or
    /// (once closed) simply nonempty. Oldest-head selection is the
    /// anti-starvation rule: younger full lanes cannot starve a
    /// deadline-pressed or aged lane.
    pub fn pop_batch(&self, policy: BatchPolicy) -> Option<Vec<Queued<T>>> {
        let mut g = self.inner.lock().unwrap();
        loop {
            let now = Instant::now();
            if let Some(idx) = ready_lane(&g, policy, now) {
                return Some(drain(&mut g, idx, policy.max_batch));
            }
            // a closed queue with items always has a ready lane, so
            // reaching here closed means fully drained
            if g.closed {
                return None;
            }
            match next_wake(&g, policy, now) {
                Some(wait) => {
                    let (ng, _t) = self.ready.wait_timeout(g, wait).unwrap();
                    g = ng;
                }
                None => g = self.ready.wait(g).unwrap(),
            }
        }
    }

    /// Non-blocking pop: a ready batch if some lane is ready right now,
    /// else None. This is the work-stealing probe — it never waits.
    pub fn try_pop_batch(&self, policy: BatchPolicy) -> Option<Vec<Queued<T>>> {
        let mut g = self.inner.lock().unwrap();
        let now = Instant::now();
        ready_lane(&g, policy, now).map(|idx| drain(&mut g, idx, policy.max_batch))
    }

    /// [`BucketQueue::pop_batch`] bounded to block at most `max_block`.
    /// Returns None on timeout *or* once closed and drained (callers in
    /// a steal loop re-check [`BucketQueue::is_closed`] to tell the two
    /// apart).
    pub fn pop_batch_timeout(&self, policy: BatchPolicy,
                             max_block: Duration) -> Option<Vec<Queued<T>>> {
        let start = Instant::now();
        let mut g = self.inner.lock().unwrap();
        loop {
            let now = Instant::now();
            if let Some(idx) = ready_lane(&g, policy, now) {
                return Some(drain(&mut g, idx, policy.max_batch));
            }
            if g.closed {
                return None;
            }
            let elapsed = now.duration_since(start);
            if elapsed >= max_block {
                return None;
            }
            let budget = max_block - elapsed;
            let wait = next_wake(&g, policy, now).map_or(budget, |w| w.min(budget));
            let (ng, _t) = self.ready.wait_timeout(g, wait).unwrap();
            g = ng;
        }
    }
}

/// The lane to drain right now, if any: the **oldest-head** lane among
/// every ready lane (full, aged out, deadline-pressed, or — once the
/// queue is closed — simply nonempty). Oldest-head selection is the
/// anti-starvation rule: a stream of younger full lanes cannot starve a
/// deadline-pressed (or aged) lane past its deadline, because the
/// pressed lane's head is older and wins the pop.
fn ready_lane<T>(inner: &Inner<T>, policy: BatchPolicy, now: Instant) -> Option<usize> {
    let mut best: Option<(Instant, usize)> = None;
    for (i, lane) in inner.lanes.iter().enumerate() {
        let Some(head) = lane.items.front() else { continue };
        let full = lane.items.len() >= policy.max_batch;
        let aged = now.duration_since(head.enqueued) >= policy.max_wait;
        let pressed = lane.min_deadline.map_or(false, |d| {
            d.checked_sub(policy.deadline_margin)
                .map_or(true, |close_at| close_at <= now)
        });
        if full || aged || pressed || inner.closed {
            let key = (head.enqueued, i);
            if best.map_or(true, |b| key < b) {
                best = Some(key);
            }
        }
    }
    best.map(|(_, i)| i)
}

/// How long a popper may sleep before some lane could become ready by
/// aging or deadline pressure (None when the queue is empty).
fn next_wake<T>(inner: &Inner<T>, policy: BatchPolicy, now: Instant) -> Option<Duration> {
    let mut wake: Option<Instant> = None;
    let mut min = |t: Instant| wake = Some(wake.map_or(t, |w| w.min(t)));
    for lane in &inner.lanes {
        if let Some(head) = lane.items.front() {
            min(head.enqueued + policy.max_wait);
        }
        if let Some(d) = lane.min_deadline {
            min(d.checked_sub(policy.deadline_margin).unwrap_or(now));
        }
    }
    // floor the wait so a boundary race cannot hot-spin the condvar
    wake.map(|w| w.saturating_duration_since(now).max(Duration::from_micros(100)))
}

fn drain<T>(inner: &mut Inner<T>, lane: usize, n: usize) -> Vec<Queued<T>> {
    let lane = &mut inner.lanes[lane];
    let take = lane.items.len().min(n);
    let mut out = Vec::with_capacity(take);
    for _ in 0..take {
        out.push(lane.items.pop_front().unwrap());
    }
    // the drained prefix may have carried the minimum; recompute over
    // the remainder (once per popped batch, not per poll)
    if lane.min_deadline.is_some() {
        lane.min_deadline = lane.items.iter().filter_map(|q| q.deadline).min();
    }
    inner.total -= take;
    out
}

// ---------------------------------------------------------------------------
// Sharding
// ---------------------------------------------------------------------------

/// How long a worker blocks on its home shard between steal scans.
/// Bounds steal-discovery latency; an idle worker wakes ~1000×/s, which
/// is noise next to a single attention batch.
const STEAL_POLL: Duration = Duration::from_millis(1);

/// N independent [`BucketQueue`] shards with static bucket→shard
/// assignment and work-stealing pops.
///
/// Sharding is about *lock* pressure, not parallelism — any number of
/// workers can pop concurrently from one shard (the mutex serializes
/// only batch formation, which is microseconds). Assigning whole
/// buckets to shards (`bucket % shards`) rather than spraying requests
/// round-robin keeps each bucket's traffic in a single lane, so batch
/// fill does not degrade as shards are added.
pub struct ShardedQueue<T> {
    shards: Vec<BucketQueue<T>>,
}

impl<T> ShardedQueue<T> {
    /// `n_shards` shards over `n_buckets` buckets, splitting
    /// `total_capacity` evenly (each shard holds at least `max(cap/n,
    /// 1)` items; backpressure is per-shard). The shard count is
    /// clamped to the bucket count: with a static `bucket % shards`
    /// map, any shard beyond `n_buckets` could never receive a push and
    /// would silently strand its slice of the capacity split.
    pub fn new(n_shards: usize, n_buckets: usize, total_capacity: usize) -> Self {
        let n_shards = n_shards.clamp(1, n_buckets.max(1));
        let per_shard = (total_capacity / n_shards).max(1);
        ShardedQueue {
            shards: (0..n_shards)
                .map(|_| BucketQueue::new(n_buckets, per_shard))
                .collect(),
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    fn shard_of(&self, bucket_idx: usize) -> usize {
        bucket_idx % self.shards.len()
    }

    /// Enqueue into the bucket's shard.
    pub fn push(&self, bucket_idx: usize, item: T,
                deadline: Option<Instant>) -> Result<(), PushError> {
        self.shards[self.shard_of(bucket_idx)]
            .push_with_deadline(bucket_idx, item, deadline)
    }

    /// Total items across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.is_empty())
    }

    /// Close every shard: pending pops drain, further pushes fail.
    pub fn close(&self) {
        for s in &self.shards {
            s.close();
        }
    }

    /// True once **every** shard is closed. Deliberately all-shards,
    /// not a single-shard probe: close() is not atomic across shards,
    /// and a push can still be accepted by a not-yet-closed shard while
    /// close() is mid-iteration. Requiring all shards closed before
    /// workers may exit guarantees any such accepted item is observed
    /// by `is_empty()` (its shard's close — and therefore this check —
    /// happens after the push landed) and drained, preserving the
    /// "accepted implies answered" shutdown contract.
    pub fn is_closed(&self) -> bool {
        self.shards.iter().all(|s| s.is_closed())
    }

    /// Blocking pop for worker `home`: take a ready batch from the home
    /// shard if there is one, else *steal* from the first other shard
    /// with a ready batch, else block briefly on the home shard and
    /// rescan. Returns None only once the queue is closed and fully
    /// drained.
    pub fn pop_batch_worker(&self, home: usize,
                            policy: BatchPolicy) -> Option<Vec<Queued<T>>> {
        let n = self.shards.len();
        let home = home % n;
        loop {
            for k in 0..n {
                let s = (home + k) % n;
                if let Some(batch) = self.shards[s].try_pop_batch(policy) {
                    return Some(batch);
                }
            }
            if self.is_closed() && self.is_empty() {
                return None;
            }
            if let Some(batch) = self.shards[home].pop_batch_timeout(policy, STEAL_POLL) {
                return Some(batch);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn pol(max_batch: usize, wait_ms: u64) -> BatchPolicy {
        BatchPolicy {
            max_batch,
            max_wait: Duration::from_millis(wait_ms),
            deadline_margin: Duration::from_millis(5),
        }
    }

    #[test]
    fn push_pop_full_batch() {
        let q: BucketQueue<u32> = BucketQueue::new(2, 16);
        for i in 0..4 {
            q.push(1, i).unwrap();
        }
        let b = q.pop_batch(pol(4, 5000)).unwrap();
        assert_eq!(b.len(), 4);
        assert!(b.iter().all(|x| x.bucket == 1));
        assert_eq!(b.iter().map(|x| x.item).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert!(q.is_empty());
    }

    #[test]
    fn timeout_flushes_partial_batch() {
        let q: BucketQueue<u32> = BucketQueue::new(2, 16);
        q.push(0, 7).unwrap();
        let t0 = Instant::now();
        let b = q.pop_batch(pol(8, 30)).unwrap();
        assert_eq!(b.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn deadline_pressure_closes_lane_early() {
        let q: BucketQueue<u32> = BucketQueue::new(1, 16);
        // head has no deadline; the SECOND item's deadline must still
        // close the lane (pressure scans the whole lane, not the head)
        q.push(0, 1).unwrap();
        q.push_with_deadline(0, 2,
            Some(Instant::now() + Duration::from_millis(40))).unwrap();
        let t0 = Instant::now();
        // max_wait of 10s would otherwise hold the partial batch
        let b = q.pop_batch(pol(8, 10_000)).unwrap();
        let waited = t0.elapsed();
        assert_eq!(b.len(), 2);
        // closed at ~deadline - margin (40-5 ms), far before max_wait
        assert!(waited < Duration::from_secs(5), "waited {waited:?}");
        assert!(waited >= Duration::from_millis(20), "closed too early: {waited:?}");
    }

    #[test]
    fn pressed_lane_preempts_younger_full_lane() {
        let q: BucketQueue<u32> = BucketQueue::new(2, 64);
        // older, deadline-pressed singleton in lane 1 ...
        q.push_with_deadline(1, 99, Some(Instant::now())).unwrap();
        std::thread::sleep(Duration::from_millis(2));
        // ... must win the pop over a younger but full lane 0
        for i in 0..4 {
            q.push(0, i).unwrap();
        }
        let b = q.try_pop_batch(pol(4, 10_000)).unwrap();
        assert!(b.iter().all(|x| x.bucket == 1),
                "deadline-pressed lane starved behind a full lane");
    }

    #[test]
    fn already_expired_deadline_pops_immediately() {
        let q: BucketQueue<u32> = BucketQueue::new(1, 16);
        q.push_with_deadline(0, 9, Some(Instant::now())).unwrap();
        // delivered (not dropped): expiry handling is the worker's job
        let b = q.try_pop_batch(pol(8, 10_000)).unwrap();
        assert_eq!(b.len(), 1);
        assert!(b[0].deadline.unwrap() <= Instant::now());
    }

    #[test]
    fn try_pop_is_nonblocking() {
        let q: BucketQueue<u32> = BucketQueue::new(1, 16);
        assert!(q.try_pop_batch(pol(4, 1000)).is_none());
        q.push(0, 1).unwrap();
        // young, below max_batch, no deadline → not ready
        assert!(q.try_pop_batch(pol(4, 1000)).is_none());
        for i in 0..3 {
            q.push(0, i).unwrap();
        }
        assert_eq!(q.try_pop_batch(pol(4, 1000)).unwrap().len(), 4);
    }

    #[test]
    fn pop_batch_timeout_times_out_then_pops() {
        let q: BucketQueue<u32> = BucketQueue::new(1, 16);
        let t0 = Instant::now();
        assert!(q.pop_batch_timeout(pol(4, 1000), Duration::from_millis(20)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(15));
        for i in 0..4 {
            q.push(0, i).unwrap();
        }
        assert!(q.pop_batch_timeout(pol(4, 1000), Duration::from_millis(20)).is_some());
    }

    #[test]
    fn capacity_backpressure() {
        let q: BucketQueue<u32> = BucketQueue::new(1, 2);
        q.push(0, 1).unwrap();
        q.push(0, 2).unwrap();
        assert_eq!(q.push(0, 3), Err(PushError::Full));
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn bad_bucket_and_closed() {
        let q: BucketQueue<u32> = BucketQueue::new(1, 4);
        assert_eq!(q.push(5, 1), Err(PushError::BadBucket));
        q.close();
        assert_eq!(q.push(0, 1), Err(PushError::Closed));
    }

    #[test]
    fn close_drains_then_none() {
        let q: BucketQueue<u32> = BucketQueue::new(1, 4);
        q.push(0, 1).unwrap();
        q.close();
        let p = pol(4, 1000);
        assert_eq!(q.pop_batch(p).unwrap().len(), 1);
        assert!(q.pop_batch(p).is_none());
    }

    #[test]
    fn concurrent_producers_one_consumer() {
        let q: Arc<BucketQueue<u64>> = Arc::new(BucketQueue::new(3, 1024));
        let mut handles = Vec::new();
        for t in 0..3 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..100u64 {
                    while q.push(t as usize, i).is_err() {
                        std::thread::yield_now();
                    }
                }
            }));
        }
        let consumer = {
            let q = q.clone();
            std::thread::spawn(move || {
                let p = pol(8, 5);
                let mut got = 0usize;
                while got < 300 {
                    if let Some(b) = q.pop_batch(p) {
                        // batch homogeneity invariant
                        let lane = b[0].bucket;
                        assert!(b.iter().all(|x| x.bucket == lane));
                        got += b.len();
                    }
                }
                got
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(consumer.join().unwrap(), 300);
    }

    #[test]
    fn property_fifo_within_lane() {
        crate::proptest_mini::run(50, |g| {
            let q: BucketQueue<usize> = BucketQueue::new(2, 256);
            let n = g.usize_in(1, 50);
            for i in 0..n {
                q.push(0, i).map_err(|e| format!("{e:?}"))?;
            }
            let p = pol(g.usize_in(1, 16), 0);
            let mut seen = Vec::new();
            while seen.len() < n {
                let b = q.pop_batch(p).ok_or("closed early")?;
                seen.extend(b.iter().map(|x| x.item));
            }
            crate::proptest_mini::prop_assert(
                seen == (0..n).collect::<Vec<_>>(),
                format!("not FIFO: {seen:?}"))
        });
    }

    // --- sharded queue ---

    #[test]
    fn sharded_routes_buckets_to_fixed_shards() {
        let q: ShardedQueue<u32> = ShardedQueue::new(2, 4, 64);
        // buckets 0,2 → shard 0; buckets 1,3 → shard 1
        q.push(0, 10, None).unwrap();
        q.push(1, 11, None).unwrap();
        q.push(2, 12, None).unwrap();
        assert_eq!(q.shards[0].len(), 2);
        assert_eq!(q.shards[1].len(), 1);
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn worker_steals_ready_batch_from_other_shard() {
        let q: ShardedQueue<u32> = ShardedQueue::new(2, 2, 64);
        // a full batch lands in shard 1 (bucket 1); worker 0's home is
        // shard 0, which stays empty — it must steal
        for i in 0..4 {
            q.push(1, i, None).unwrap();
        }
        let t0 = Instant::now();
        let b = q.pop_batch_worker(0, pol(4, 10_000)).unwrap();
        assert_eq!(b.len(), 4);
        assert!(b.iter().all(|x| x.bucket == 1));
        // stolen promptly (full lane is ready immediately), not after
        // the 10s aging flush
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn sharded_close_drains_all_shards_then_none() {
        let q: ShardedQueue<u32> = ShardedQueue::new(3, 3, 64);
        for b in 0..3 {
            q.push(b, b as u32, None).unwrap();
        }
        q.close();
        assert!(q.is_closed());
        let p = pol(4, 1000);
        let mut got = 0;
        while let Some(b) = q.pop_batch_worker(0, p) {
            got += b.len();
        }
        assert_eq!(got, 3);
        assert!(q.pop_batch_worker(1, p).is_none());
        assert_eq!(q.push(0, 9, None), Err(PushError::Closed));
    }

    #[test]
    fn shards_clamp_to_bucket_count() {
        // 8 requested shards over 3 buckets → only 3 reachable; the
        // clamp keeps the full capacity usable instead of stranding
        // 5/8 of it in unreachable shards
        let q: ShardedQueue<u32> = ShardedQueue::new(8, 3, 24);
        assert_eq!(q.shard_count(), 3);
        // per-shard capacity is 24/3 = 8, not 24/8 = 3
        for i in 0..8 {
            q.push(0, i, None).unwrap();
        }
        assert_eq!(q.push(0, 99, None), Err(PushError::Full));
    }

    #[test]
    fn sharded_capacity_is_split() {
        let q: ShardedQueue<u32> = ShardedQueue::new(2, 2, 4);
        // shard capacity = 4/2 = 2
        q.push(0, 1, None).unwrap();
        q.push(0, 2, None).unwrap();
        assert_eq!(q.push(0, 3, None), Err(PushError::Full));
        // the other shard still accepts
        q.push(1, 4, None).unwrap();
    }

    #[test]
    fn sharded_concurrent_workers_drain_everything() {
        let q: Arc<ShardedQueue<u64>> = Arc::new(ShardedQueue::new(2, 4, 2048));
        let n_items = 400u64;
        let producer = {
            let q = q.clone();
            std::thread::spawn(move || {
                for i in 0..n_items {
                    let bucket = (i % 4) as usize;
                    while q.push(bucket, i, None).is_err() {
                        std::thread::yield_now();
                    }
                }
                q.close();
            })
        };
        let drained = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let mut workers = Vec::new();
        for w in 0..4 {
            let q = q.clone();
            let drained = drained.clone();
            workers.push(std::thread::spawn(move || {
                let p = pol(8, 2);
                while let Some(b) = q.pop_batch_worker(w, p) {
                    let lane = b[0].bucket;
                    assert!(b.iter().all(|x| x.bucket == lane), "mixed batch");
                    drained.fetch_add(b.len() as u64,
                                      std::sync::atomic::Ordering::Relaxed);
                }
            }));
        }
        producer.join().unwrap();
        for w in workers {
            w.join().unwrap();
        }
        assert_eq!(drained.load(std::sync::atomic::Ordering::Relaxed), n_items);
        assert!(q.is_empty());
    }
}
