//! Bounded per-bucket request queue with condvar wakeups — the
//! coordinator's admission + backpressure point.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// A queued item tagged with its bucket and enqueue time.
pub struct Queued<T> {
    pub bucket: usize,
    pub enqueued: Instant,
    pub item: T,
}

struct Inner<T> {
    /// one FIFO per bucket index
    lanes: Vec<VecDeque<Queued<T>>>,
    total: usize,
    closed: bool,
}

/// Bounded multi-lane FIFO. `push` applies backpressure by rejection
/// (serving semantics: better to fail fast than stall the socket);
/// `pop_batch` blocks until a lane is "ready" per the batch policy.
pub struct BucketQueue<T> {
    inner: Mutex<Inner<T>>,
    ready: Condvar,
    capacity: usize,
}

/// Why a push was refused.
#[derive(Debug, PartialEq, Eq)]
pub enum PushError {
    Full,
    Closed,
    BadBucket,
}

/// Batch-formation policy: a lane is ready when it has `max_batch`
/// items, or its head item has waited ≥ `max_wait`.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl<T> BucketQueue<T> {
    pub fn new(n_buckets: usize, capacity: usize) -> Self {
        BucketQueue {
            inner: Mutex::new(Inner {
                lanes: (0..n_buckets).map(|_| VecDeque::new()).collect(),
                total: 0,
                closed: false,
            }),
            ready: Condvar::new(),
            capacity,
        }
    }

    /// Enqueue into a bucket lane; rejects when at capacity or closed.
    pub fn push(&self, bucket_idx: usize, item: T) -> Result<(), PushError> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(PushError::Closed);
        }
        if bucket_idx >= g.lanes.len() {
            return Err(PushError::BadBucket);
        }
        if g.total >= self.capacity {
            return Err(PushError::Full);
        }
        g.lanes[bucket_idx].push_back(Queued {
            bucket: bucket_idx,
            enqueued: Instant::now(),
            item,
        });
        g.total += 1;
        drop(g);
        self.ready.notify_one();
        Ok(())
    }

    /// Total queued items.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().total
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close the queue: pending pops drain remaining items, further
    /// pushes fail.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.ready.notify_all();
    }

    /// Blocking pop of the next batch per `policy`.
    ///
    /// Returns items all from ONE lane (a batch must share its artifact
    /// bucket), at most `policy.max_batch` of them, or None once closed
    /// and drained. Lane choice: any full lane first, else the lane with
    /// the oldest head once it has aged past max_wait.
    pub fn pop_batch(&self, policy: BatchPolicy) -> Option<Vec<Queued<T>>> {
        let mut g = self.inner.lock().unwrap();
        loop {
            // full lane?
            if let Some(idx) = (0..g.lanes.len())
                .find(|&i| g.lanes[i].len() >= policy.max_batch)
            {
                return Some(drain(&mut g, idx, policy.max_batch));
            }
            // aged lane? pick oldest head across lanes
            let now = Instant::now();
            let oldest = (0..g.lanes.len())
                .filter_map(|i| g.lanes[i].front().map(|q| (q.enqueued, i)))
                .min();
            if let Some((head_t, idx)) = oldest {
                let age = now.duration_since(head_t);
                if age >= policy.max_wait {
                    return Some(drain(&mut g, idx, policy.max_batch));
                }
                if g.closed {
                    return Some(drain(&mut g, idx, policy.max_batch));
                }
                // wait until the head would age out (or new arrivals)
                let timeout = policy.max_wait - age;
                let (ng, _t) = self.ready.wait_timeout(g, timeout).unwrap();
                g = ng;
            } else {
                if g.closed {
                    return None;
                }
                g = self.ready.wait(g).unwrap();
            }
        }
    }
}

fn drain<T>(inner: &mut Inner<T>, lane: usize, n: usize) -> Vec<Queued<T>> {
    let take = inner.lanes[lane].len().min(n);
    let mut out = Vec::with_capacity(take);
    for _ in 0..take {
        out.push(inner.lanes[lane].pop_front().unwrap());
    }
    inner.total -= take;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn push_pop_full_batch() {
        let q: BucketQueue<u32> = BucketQueue::new(2, 16);
        for i in 0..4 {
            q.push(1, i).unwrap();
        }
        let b = q
            .pop_batch(BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(5) })
            .unwrap();
        assert_eq!(b.len(), 4);
        assert!(b.iter().all(|x| x.bucket == 1));
        assert_eq!(b.iter().map(|x| x.item).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert!(q.is_empty());
    }

    #[test]
    fn timeout_flushes_partial_batch() {
        let q: BucketQueue<u32> = BucketQueue::new(2, 16);
        q.push(0, 7).unwrap();
        let t0 = Instant::now();
        let b = q
            .pop_batch(BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(30),
            })
            .unwrap();
        assert_eq!(b.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn capacity_backpressure() {
        let q: BucketQueue<u32> = BucketQueue::new(1, 2);
        q.push(0, 1).unwrap();
        q.push(0, 2).unwrap();
        assert_eq!(q.push(0, 3), Err(PushError::Full));
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn bad_bucket_and_closed() {
        let q: BucketQueue<u32> = BucketQueue::new(1, 4);
        assert_eq!(q.push(5, 1), Err(PushError::BadBucket));
        q.close();
        assert_eq!(q.push(0, 1), Err(PushError::Closed));
    }

    #[test]
    fn close_drains_then_none() {
        let q: BucketQueue<u32> = BucketQueue::new(1, 4);
        q.push(0, 1).unwrap();
        q.close();
        let p = BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(1) };
        assert_eq!(q.pop_batch(p).unwrap().len(), 1);
        assert!(q.pop_batch(p).is_none());
    }

    #[test]
    fn concurrent_producers_one_consumer() {
        let q: Arc<BucketQueue<u64>> = Arc::new(BucketQueue::new(3, 1024));
        let mut handles = Vec::new();
        for t in 0..3 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..100u64 {
                    while q.push(t as usize, i).is_err() {
                        std::thread::yield_now();
                    }
                }
            }));
        }
        let consumer = {
            let q = q.clone();
            std::thread::spawn(move || {
                let p = BatchPolicy {
                    max_batch: 8,
                    max_wait: Duration::from_millis(5),
                };
                let mut got = 0usize;
                while got < 300 {
                    if let Some(b) = q.pop_batch(p) {
                        // batch homogeneity invariant
                        let lane = b[0].bucket;
                        assert!(b.iter().all(|x| x.bucket == lane));
                        got += b.len();
                    }
                }
                got
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(consumer.join().unwrap(), 300);
    }

    #[test]
    fn property_fifo_within_lane() {
        crate::proptest_mini::run(50, |g| {
            let q: BucketQueue<usize> = BucketQueue::new(2, 256);
            let n = g.usize_in(1, 50);
            for i in 0..n {
                q.push(0, i).map_err(|e| format!("{e:?}"))?;
            }
            let p = BatchPolicy {
                max_batch: g.usize_in(1, 16),
                max_wait: Duration::from_millis(0),
            };
            let mut seen = Vec::new();
            while seen.len() < n {
                let b = q.pop_batch(p).ok_or("closed early")?;
                seen.extend(b.iter().map(|x| x.item));
            }
            crate::proptest_mini::prop_assert(
                seen == (0..n).collect::<Vec<_>>(),
                format!("not FIFO: {seen:?}"))
        });
    }
}
