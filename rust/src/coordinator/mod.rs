//! The L3 coordinator (S14): router → sharded bucket queue → worker
//! pool → execution backend, with an embedding cache on the admission
//! path and metrics at every stage.
//!
//! Data path (python-free; see `ARCHITECTURE.md` for the full request
//! lifecycle walkthrough and `OPERATIONS.md` for the operator's view):
//!
//! ```text
//!   submit(tokens, deadline?) ──route──▶ EmbeddingCache ──hit──▶ response
//!                                            │ miss
//!                                            ▼
//!                      ShardedQueue (bucket → shard, deadline-aware)
//!                        │              │              │
//!                   pop/steal      pop/steal      pop/steal
//!                        ▼              ▼              ▼
//!                    worker 0       worker 1  ...  worker N-1
//!                        └── expire → assemble → ExecBackend
//!                                       ──scatter/pool──▶ cache insert
//!                                                       ──▶ response
//! ```
//!
//! Two execution backends implement the same submit/batch/execute/
//! respond loop ([`ExecBackend`]): the PJRT workers execute compiled
//! encode artifacts, and the CPU workers drive the in-process
//! multi-layer [`model::EncoderStack`](crate::model::EncoderStack) on
//! the [`kernels`](crate::kernels) core via [`cpu_engine::CpuEngine`]
//! (one forked engine per worker, sharing one model; all attention
//! routed through the `AttentionOp` seam). [`ExecBackend::auto`] picks
//! XLA when artifacts + PJRT are available and falls back to CPU
//! otherwise, so the stack serves real embeddings even with the offline
//! `xla-stub` build.
//!
//! # Invariants
//!
//! * **Batch homogeneity** — every popped batch shares one sequence
//!   bucket ([`queue::BucketQueue::pop_batch`]), so one artifact shape /
//!   one padded tensor shape covers the whole batch. Sharding preserves
//!   this: a bucket lives entirely inside one shard.
//! * **Padding skip** — [`batcher::attention_scatter`] never executes
//!   padding *rows* (slots past `fill`) and excludes every position
//!   beyond the per-request length it is given from attention;
//!   `scatter` drops the same rows on the artifact path. The CPU engine
//!   passes landmark-*aligned* lengths, so a short alignment tail of
//!   PAD embeddings is executed (and metered as `padded_tokens`) —
//!   pooling still averages only real positions.
//! * **Cache coherence** — a cache hit is bitwise-equal to a recompute:
//!   both backends are deterministic functions of the token sequence
//!   (independent of batch composition, worker assignment, and thread
//!   count), and the cache stores only final per-request embeddings.
//!   See [`cache`] for the full argument.
//! * **Deadline honesty** — a request with an already-expired deadline
//!   is rejected at admission ([`SubmitError::DeadlineExpired`]); one
//!   that expires while queued is failed by the popping worker *before*
//!   batch assembly. Expired requests never occupy a batch slot, and
//!   the batcher closes a bucket early when a queued deadline is within
//!   `deadline_margin_ms` of expiring.
//! * **Order preservation** — responses are delivered on per-request
//!   channels; within a batch, outputs are scattered back in submission
//!   order.
//! * **Backend-independent protocol** — [`Response`] and the serving
//!   metrics have the same meaning on both backends; which one is live
//!   is reported via [`Coordinator::backend`] and the server's `STATS`
//!   report.
//!
//! Assemble/scatter are pure and unit-testable:
//!
//! ```
//! use ssaformer::coordinator::{assemble, scatter};
//! let plan = assemble(&[&[5, 6, 7][..]], /*capacity=*/2, /*seq=*/4);
//! assert_eq!((plan.fill, plan.tokens.len()), (1, 8));
//! // an executor output of capacity × width scatters back to fill rows
//! let rows = scatter(&plan, &vec![1.0; 2 * 3], 3);
//! assert_eq!(rows, vec![vec![1.0, 1.0, 1.0]]);
//! ```
//!
//! The paper's sec-9 deployment claim ("this method can reduce training
//! and inference time") is exercised by swapping the served attention
//! variant (full / nystrom / ss) while this coordinator stays fixed —
//! see the serving_throughput bench (E8).

pub mod admission;
pub mod batcher;
pub mod bucket_router;
pub mod cache;
pub mod cluster;
pub mod cpu_engine;
pub mod prefix_cache;
pub mod queue;

pub use admission::{Accuracy, AdmissionPolicy, TierKind};
pub use batcher::{aligned_len, assemble, attention_scatter, scatter, BatchPlan};
pub use bucket_router::{BucketRouter, Route};
pub use cache::{EmbeddingCache, LruCache};
pub use cluster::{ClusterConfig, ClusterRouter, HashRing};
pub use cpu_engine::{CpuEngine, CpuModel, CpuModelConfig};
pub use prefix_cache::{merge_chunk_embeddings, PrefixCache};
pub use queue::{BatchPolicy, BucketQueue, PushError, Queued, ShardedQueue};

use admission::resolve_admission;
use crate::config::{ServingConfig, Variant};
use crate::kernels::{gemm, isa, Isa};
use crate::metrics::ServingMetrics;
use crate::minirt::CancelToken;
use crate::runtime::{ArtifactKind, BackendKind, Engine};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// The micro-kernel arm a coordinator will run, resolved with the
/// documented precedence: `SSAF_KERNEL` environment override, else the
/// `[serving] kernel` knob, else hardware detection.
fn resolve_kernel_isa(cfg: &ServingConfig) -> Isa {
    isa::env_override().or(cfg.kernel).unwrap_or_else(Isa::detect)
}

/// Log the kernel-dispatch decision once per process: the arm replicas
/// actually execute, what detection alone would have picked, and the
/// Newton–Schulz-relevant
/// GEMM blocking parameters ([`gemm::KC`] k panels / [`gemm::NC`]
/// L2-resident column panels). Operators get the same facts per
/// coordinator from the STATS `kernel:` field.
fn report_kernel_dispatch(active: Isa) {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        eprintln!(
            "ssaformer kernel dispatch: arm={} detected={} gemm KC={} NC={}",
            active.token(), Isa::detect().token(), gemm::KC, gemm::NC);
    });
}

/// A completed request.
#[derive(Debug)]
pub struct Response {
    pub id: u64,
    /// pooled embedding (d_model floats) on success
    pub embedding: Result<Vec<f32>, String>,
    /// queue wait + execution time
    pub queue_time: Duration,
    pub exec_time: Duration,
    /// The admission tier that served this request; `None` on the
    /// configured (untagged, unforced) path — which serves bitwise what
    /// a build without admission routing would.
    pub tier: Option<TierKind>,
}

/// One encode request — the argument of the single admission entry
/// point [`Coordinator::submit`]. A bare `Vec<i32>` converts via
/// `From`, so `submit(tokens)` keeps reading naturally; deadline and
/// accuracy budgets ride the builder:
///
/// ```
/// use ssaformer::coordinator::{Accuracy, EncodeRequest};
/// use std::time::Duration;
/// let req = EncodeRequest::new(vec![5, 6, 7])
///     .deadline(Duration::from_millis(250))
///     .accuracy(Accuracy::Budget);
/// # let _ = req;
/// ```
#[derive(Clone, Debug, Default)]
pub struct EncodeRequest {
    tokens: Vec<i32>,
    deadline: Option<Duration>,
    accuracy: Option<Accuracy>,
    internal: bool,
}

impl EncodeRequest {
    pub fn new(tokens: Vec<i32>) -> EncodeRequest {
        EncodeRequest { tokens, ..Default::default() }
    }

    /// Deadline *budget*: time from submission until the response is
    /// useless to the caller. Unset falls back to the configured
    /// default deadline.
    pub fn deadline(mut self, budget: Duration) -> EncodeRequest {
        self.deadline = Some(budget);
        self
    }

    /// [`EncodeRequest::deadline`] from an `Option` — the wire path
    /// threads its already-optional `DEADLINE_MS` through unchanged.
    pub fn deadline_opt(mut self, budget: Option<Duration>) -> EncodeRequest {
        self.deadline = budget;
        self
    }

    /// Accuracy budget for admission routing. Unset means "the
    /// configured path": no tier routing at all (unless the operator
    /// forced a tier).
    pub fn accuracy(mut self, accuracy: Accuracy) -> EncodeRequest {
        self.accuracy = Some(accuracy);
        self
    }

    /// [`EncodeRequest::accuracy`] from an `Option`, for wire plumbing.
    pub fn accuracy_opt(mut self, accuracy: Option<Accuracy>) -> EncodeRequest {
        self.accuracy = accuracy;
        self
    }

    /// Mark this request as internally-generated work (not caller
    /// traffic): it skips request-level accounting (`requests_in`,
    /// `requests_done`, e2e latency, admission counters) and the
    /// whole-sequence embedding cache. Long-document chunks are the
    /// in-tree example; external callers should not set this.
    pub fn internal(mut self) -> EncodeRequest {
        self.internal = true;
        self
    }
}

impl From<Vec<i32>> for EncodeRequest {
    fn from(tokens: Vec<i32>) -> EncodeRequest {
        EncodeRequest::new(tokens)
    }
}

struct Pending {
    id: u64,
    tokens: Vec<i32>,
    tx: mpsc::Sender<Response>,
    /// An internally-generated chunk of a long document (see
    /// `submit_chunked`), not a caller request: workers execute it like
    /// any other item but skip the request-level accounting
    /// (`requests_done`, `cache_misses`, e2e latency) and the
    /// whole-sequence embedding cache — the parent document carries
    /// those, and chunk reuse belongs to the [`PrefixCache`].
    internal: bool,
    /// The admission tier this item executes on (`None` = configured
    /// path). Decided once at admission; workers split batches into
    /// tier-homogeneous sub-batches on it.
    tier: Option<TierKind>,
}

/// Why admission failed.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    QueueFull,
    TooLong { len: usize, max: usize },
    Empty,
    /// The request's deadline had already passed at admission — it was
    /// rejected without ever occupying a queue or batch slot.
    DeadlineExpired,
    ShuttingDown,
}

/// Shared device-resident parameter buffer.
struct ParamsBuffer(xla::PjRtBuffer);
unsafe impl Send for ParamsBuffer {}
unsafe impl Sync for ParamsBuffer {}

/// The execution engine behind the coordinator's worker pool.
pub enum ExecBackend {
    /// AOT-compiled encode artifacts executed on the PJRT runtime.
    Xla(Arc<Engine>),
    /// The in-process CPU kernel core — no artifacts required. The
    /// worker pool forks one engine per thread off this one.
    Cpu(Box<CpuEngine>),
}

impl ExecBackend {
    /// Backend auto-selection: XLA when the artifacts directory loads
    /// and the PJRT client constructs, otherwise the CPU kernel backend
    /// built from the full serving config (per-layer variants,
    /// projections, and — under `init = load` — checkpoint weights).
    /// With the offline `xla-stub` build this always selects CPU.
    ///
    /// Errors fail closed: a bad weights checkpoint (or `init = load`
    /// on the XLA backend, which has no loadable encoder weights) stops
    /// startup instead of silently serving seeded weights.
    pub fn auto(cfg: &ServingConfig)
                -> Result<ExecBackend, crate::runtime::RuntimeError> {
        Ok(ExecBackend::auto_with_reason(cfg)?.0)
    }

    /// [`ExecBackend::auto`], also returning *why* XLA was skipped (the
    /// engine construction error) so entry points can surface a corrupt
    /// manifest instead of silently serving the CPU demo model.
    pub fn auto_with_reason(cfg: &ServingConfig)
                            -> Result<(ExecBackend, Option<crate::runtime::RuntimeError>),
                                      crate::runtime::RuntimeError> {
        match Engine::new(&cfg.artifacts_dir) {
            Ok(engine) => {
                // CPU-only model knobs must not be silently dropped by
                // artifact selection: replicas with and without
                // artifacts would then serve two different functions
                // behind one STATS `model:` promise. Fail closed, like
                // a bad checkpoint.
                if cfg.init == crate::config::InitPolicy::Load {
                    return Err(crate::runtime::RuntimeError::Checkpoint(
                        "init = load applies to the CPU backend only; \
                         remove the weights knob or the artifacts dir".into()));
                }
                // a uniform `variant = ss,ss,ss` list is the same
                // request as `variant = ss` + `layers = 3`, so only
                // genuine mixing trips this arm — depth itself is
                // gated below either way
                let mixed =
                    cfg.layer_variants.iter().any(|&v| v != cfg.variant);
                if cfg.projections || mixed {
                    return Err(crate::runtime::RuntimeError::Xla(
                        "cpu-only model knobs set (projections / per-layer \
                         variant mixing) but the XLA artifact backend was \
                         selected; remove the knobs or the artifacts dir"
                            .into()));
                }
                if cfg.layers != 1 {
                    return Err(crate::runtime::RuntimeError::Xla(format!(
                        "layers = {} is a CPU-backend knob (the encode \
                         artifact is single-pass); remove it or the \
                         artifacts dir", cfg.layers)));
                }
                Ok((ExecBackend::Xla(Arc::new(engine)), None))
            }
            Err(e) => Ok((ExecBackend::cpu_from_config(cfg)?, Some(e))),
        }
    }

    /// Build the CPU kernel backend for `cfg`: seeded weights under
    /// `init = seeded`, checkpoint weights (fail-closed) under
    /// `init = load`, per-layer operators from the `variant` list, and
    /// the projection flag threaded through to the stack.
    pub fn cpu_from_config(cfg: &ServingConfig)
                           -> Result<ExecBackend, crate::runtime::RuntimeError> {
        let mcfg = CpuModelConfig {
            layers: cfg.layers,
            ffn_mult: cfg.ffn_mult,
            projections: cfg.projections,
            ..Default::default()
        };
        let variants = cfg.effective_layer_variants();
        let model = match cfg.init {
            crate::config::InitPolicy::Seeded => {
                CpuModel::new_mixed(mcfg, &variants)
            }
            crate::config::InitPolicy::Load => {
                let path = cfg.weights.as_deref().ok_or_else(|| {
                    crate::runtime::RuntimeError::Checkpoint(
                        "init = load without a weights path".into())
                })?;
                let ckpt = crate::model::checkpoint::load(path)?;
                CpuModel::with_checkpoint(mcfg, &variants, ckpt)?
            }
        };
        Ok(ExecBackend::Cpu(Box::new(CpuEngine::new(model))))
    }

    /// Which backend this is, for manifest/metrics reporting.
    pub fn kind(&self) -> BackendKind {
        match self {
            ExecBackend::Xla(_) => BackendKind::Xla,
            ExecBackend::Cpu(_) => BackendKind::Cpu,
        }
    }
}

/// Admission scaffolding shared by both backends — router, sharded
/// queue, cache, metrics, cancel token, batch policy — built in one
/// place so the XLA and CPU start paths cannot diverge.
struct Scaffold {
    router: BucketRouter,
    queue: Arc<ShardedQueue<Pending>>,
    cache: Option<Arc<EmbeddingCache>>,
    prefix_cache: Option<Arc<PrefixCache>>,
    metrics: Arc<ServingMetrics>,
    cancel: CancelToken,
    policy: BatchPolicy,
    default_deadline: Option<Duration>,
    n_workers: usize,
    /// Long-document chunk length (0 = chunking disabled). The start
    /// paths clamp it to the largest bucket and — on the CPU backend —
    /// round it up to the landmark divisor before the coordinator is
    /// built, so every chunk routes to an existing bucket.
    chunk_tokens: usize,
}

impl Scaffold {
    fn new(buckets: &[usize], cfg: &ServingConfig) -> Scaffold {
        let shards = cfg.effective_shards();
        Scaffold {
            router: BucketRouter::new(buckets.to_vec()),
            queue: Arc::new(ShardedQueue::new(shards, buckets.len(),
                                              cfg.queue_capacity)),
            cache: match cfg.cache_capacity {
                0 => None,
                n => Some(Arc::new(EmbeddingCache::new(n))),
            },
            prefix_cache: match cfg.prefix_cache_capacity {
                0 => None,
                n => Some(Arc::new(PrefixCache::new(n))),
            },
            metrics: Arc::new(ServingMetrics::new()),
            cancel: CancelToken::new(),
            policy: BatchPolicy {
                max_batch: cfg.max_batch,
                max_wait: Duration::from_millis(cfg.max_wait_ms),
                deadline_margin: Duration::from_millis(cfg.deadline_margin_ms),
            },
            default_deadline: cfg.default_deadline(),
            n_workers: cfg.workers.max(1),
            chunk_tokens: cfg.chunk_tokens,
        }
    }

    fn into_coordinator(self, workers: Vec<std::thread::JoinHandle<()>>,
                        kind: BackendKind, model_desc: String,
                        kernel_isa: Isa,
                        admission: Option<AdmissionPolicy>) -> Coordinator {
        Coordinator {
            router: self.router,
            queue: self.queue,
            cache: self.cache,
            prefix_cache: self.prefix_cache,
            metrics: self.metrics,
            cancel: self.cancel,
            workers,
            next_id: std::sync::atomic::AtomicU64::new(0),
            backend_kind: kind,
            default_deadline: self.default_deadline,
            model_desc,
            kernel_isa,
            chunk_tokens: self.chunk_tokens,
            admission,
        }
    }
}

/// The serving coordinator. A pool of worker threads executes batches
/// pulled (and stolen) from a sharded bucket queue; admission is
/// lock-light and callers receive responses on per-request channels.
pub struct Coordinator {
    router: BucketRouter,
    queue: Arc<ShardedQueue<Pending>>,
    cache: Option<Arc<EmbeddingCache>>,
    prefix_cache: Option<Arc<PrefixCache>>,
    pub metrics: Arc<ServingMetrics>,
    cancel: CancelToken,
    workers: Vec<std::thread::JoinHandle<()>>,
    next_id: std::sync::atomic::AtomicU64,
    backend_kind: BackendKind,
    default_deadline: Option<Duration>,
    /// One-line served-model identification (depth, operator, widths) —
    /// the `model:` line of the STATS report.
    model_desc: String,
    /// Micro-kernel arm the execution workers run (resolved once at
    /// startup; CPU backend pins every engine to it).
    kernel_isa: Isa,
    /// Long-document chunk length, already bucket-clamped and (CPU)
    /// landmark-aligned; 0 = chunking disabled (`too-long` as before).
    chunk_tokens: usize,
    /// The accuracy-aware admission policy ([`admission`]); `None` on
    /// the artifact backend, which serves only the configured variant
    /// (accuracy-tagged requests there fall back to the configured
    /// path).
    admission: Option<AdmissionPolicy>,
}

impl Coordinator {
    /// Build and start the coordinator on the given execution backend.
    /// The XLA backend warms up (compiles) every encode artifact for
    /// the configured variant and uploads the parameters once; the CPU
    /// backend validates the bucket list against the model's landmark
    /// count. Either way `cfg.workers` batch-execution workers are
    /// spawned over `cfg.effective_shards()` queue shards.
    pub fn start(backend: ExecBackend, cfg: &ServingConfig)
                 -> Result<Coordinator, crate::runtime::RuntimeError> {
        match backend {
            ExecBackend::Xla(engine) => Coordinator::start_xla(engine, cfg),
            ExecBackend::Cpu(engine) => Coordinator::start_cpu(engine, cfg),
        }
    }

    fn start_xla(engine: Arc<Engine>, cfg: &ServingConfig)
                 -> Result<Coordinator, crate::runtime::RuntimeError> {
        let buckets = engine.manifest().encode_buckets(cfg.variant);
        assert!(!buckets.is_empty(), "no encode artifacts for {:?}", cfg.variant);
        let mut s = Scaffold::new(&buckets, cfg);
        // every chunk must route to an existing bucket; artifact bucket
        // lists come from the manifest (config validation never saw
        // them), so clamp here
        s.chunk_tokens =
            s.chunk_tokens.min(*buckets.iter().max().expect("nonempty"));

        // preload executables + parameters
        engine.warmup(cfg.variant)?;
        let init = engine.init_params()?;
        let params = Arc::new(ParamsBuffer(
            engine.buffer_f32(&init, &[init.len()])?));

        let mut workers = Vec::with_capacity(s.n_workers);
        for w in 0..s.n_workers {
            let queue = s.queue.clone();
            let cache = s.cache.clone();
            let metrics = s.metrics.clone();
            let engine = engine.clone();
            let params = params.clone();
            let variant = cfg.variant;
            let policy = s.policy;
            let buckets = buckets.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("ssaformer-xla-worker-{w}"))
                    .spawn(move || {
                        worker_loop_xla(&engine, variant, &buckets, &queue, w,
                                        policy, &metrics, cache.as_deref(),
                                        &params);
                    })
                    .expect("spawn coordinator worker"));
        }
        let desc = format!("artifact encoder, variant={}", cfg.variant.token());
        // the XLA batch path never touches the CPU micro-kernels, but
        // the arm is still resolved and reported so STATS reads the
        // same either way (cache/admission helpers stay scalar-free)
        let kernel_isa = resolve_kernel_isa(cfg);
        report_kernel_dispatch(kernel_isa);
        // artifact encoders serve exactly one compiled (variant, f32)
        // function — there is no tier lattice to route across, so
        // accuracy-tagged requests fall back to the configured path
        Ok(s.into_coordinator(workers, BackendKind::Xla, desc, kernel_isa,
                              None))
    }

    fn start_cpu(engine: Box<CpuEngine>, cfg: &ServingConfig)
                 -> Result<Coordinator, crate::runtime::RuntimeError> {
        let buckets = cfg.seq_buckets.clone();
        assert!(!buckets.is_empty(), "serving config must define seq buckets");
        // landmark variants execute at lengths rounded up to c, which
        // must still fit the bucket — require bucket % c == 0 up front
        if let Some(c) = engine.model().landmark_divisor() {
            if let Some(&bad) = buckets.iter().find(|&&b| b % c != 0) {
                return Err(crate::runtime::RuntimeError::Shape(format!(
                    "seq bucket {bad} not divisible by landmark count {c}")));
            }
        }
        let mut s = Scaffold::new(&buckets, cfg);
        let model_desc = engine.model().describe();
        // chunk boundaries align to the landmark divisor so a full
        // chunk executes with zero alignment-padding tail; the largest
        // bucket is divisor-divisible (checked above), so the aligned
        // chunk still fits it
        s.chunk_tokens = aligned_len(
            s.chunk_tokens.min(*buckets.last().expect("nonempty buckets")),
            engine.model().landmark_divisor());

        // one engine per worker, all sharing the model of the one we
        // were handed; every stage arena is pre-planned for a full batch
        // at the largest bucket so first batches allocate nothing
        let mut engine = *engine;
        // quantize the admission tier lattice once, while the model is
        // still uniquely owned (pre-fork). A tier is admissible only if
        // its stacks exist and its alignment divides every bucket —
        // everything else falls back toward full-f32 at decide time.
        let tiers_built = engine.ensure_tiers();
        let mut available = vec![TierKind::FullF32];
        if tiers_built {
            for tier in [TierKind::SsF32, TierKind::SsBf16, TierKind::SsInt8] {
                let div = engine.model().tier_stack(tier)
                    .and_then(|st| st.landmark_divisor());
                if div.map_or(true, |c| buckets.iter().all(|&b| b % c == 0)) {
                    available.push(tier);
                }
            }
        }
        let admission = AdmissionPolicy::new(
            resolve_admission(cfg.admission), available,
            *buckets.first().expect("nonempty buckets"));
        let kernel_isa = resolve_kernel_isa(cfg);
        report_kernel_dispatch(kernel_isa);
        engine.set_kernel_isa(kernel_isa);
        let max_bucket = *buckets.last().expect("nonempty buckets");
        engine.plan_for(cfg.max_batch, max_bucket);
        let mut engines: Vec<CpuEngine> = (1..s.n_workers)
            .map(|_| {
                let mut e = engine.fork();
                e.plan_for(cfg.max_batch, max_bucket);
                e
            })
            .collect();
        engines.insert(0, engine);

        let mut workers = Vec::with_capacity(s.n_workers);
        for (w, mut eng) in engines.into_iter().enumerate() {
            let queue = s.queue.clone();
            let cache = s.cache.clone();
            let metrics = s.metrics.clone();
            let policy = s.policy;
            let capacity = cfg.max_batch;
            let buckets = buckets.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("ssaformer-cpu-worker-{w}"))
                    .spawn(move || {
                        worker_loop_cpu(&mut eng, capacity, &buckets, &queue, w,
                                        policy, &metrics, cache.as_deref());
                    })
                    .expect("spawn coordinator worker"));
        }
        Ok(s.into_coordinator(workers, BackendKind::Cpu, model_desc,
                              kernel_isa, Some(admission)))
    }

    /// The execution backend serving this coordinator's requests.
    pub fn backend(&self) -> BackendKind {
        self.backend_kind
    }

    /// One-line description of the served model (encoder depth,
    /// attention operator, widths) — surfaced as the STATS `model:`
    /// line.
    pub fn model_desc(&self) -> &str {
        &self.model_desc
    }

    /// The micro-kernel arm the execution workers run.
    pub fn kernel_isa(&self) -> Isa {
        self.kernel_isa
    }

    /// One-line kernel-dispatch description — the STATS `kernel:` line:
    /// active arm, what detection alone would pick, and the GEMM
    /// blocking parameters the Newton–Schulz chain depends on.
    pub fn kernel_desc(&self) -> String {
        format!("{} (detected {}, gemm KC={} NC={})",
                self.kernel_isa.token(), Isa::detect().token(),
                gemm::KC, gemm::NC)
    }

    /// Batch-execution worker threads in the pool.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Queue shards the worker pool pulls from.
    pub fn queue_shards(&self) -> usize {
        self.queue.shard_count()
    }

    /// Embedding-cache entry bound (0 when the cache is disabled).
    pub fn cache_capacity(&self) -> usize {
        self.cache.as_ref().map_or(0, |c| c.capacity())
    }

    /// Embedding-cache entries currently resident.
    pub fn cache_len(&self) -> usize {
        self.cache.as_ref().map_or(0, |c| c.len())
    }

    /// Effective long-document chunk length (bucket-clamped and, on the
    /// CPU backend, landmark-aligned). 0 means chunking is disabled and
    /// sequences past the largest bucket are rejected `too-long`.
    pub fn chunk_tokens(&self) -> usize {
        self.chunk_tokens
    }

    /// Prefix-cache entry bound (0 when disabled).
    pub fn prefix_cache_capacity(&self) -> usize {
        self.prefix_cache.as_ref().map_or(0, |c| c.capacity())
    }

    /// Prefix-cache entries currently resident.
    pub fn prefix_cache_len(&self) -> usize {
        self.prefix_cache.as_ref().map_or(0, |c| c.len())
    }

    /// The admission policy this coordinator routes with — `None` on
    /// the artifact backend (no tier lattice; accuracy tags fall back
    /// to the configured path).
    pub fn admission(&self) -> Option<&AdmissionPolicy> {
        self.admission.as_ref()
    }

    /// One-line admission-policy description — the STATS `admission:`
    /// header's policy half ([`AdmissionPolicy::describe`]).
    pub fn admission_desc(&self) -> String {
        match &self.admission {
            Some(p) => p.describe(),
            None => "policy=unavailable (artifact backend)".to_string(),
        }
    }

    /// Requests currently queued across every shard — the backpressure
    /// signal replicas report in their `PING` reply (`q=<depth>`) so a
    /// router can prefer the less-loaded of its top ring candidates.
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Submit a request; returns the receiver for its response. This is
    /// the single admission entry point: anything convertible into an
    /// [`EncodeRequest`] goes through here, so `submit(tokens)` (a bare
    /// `Vec<i32>` uses the configured default deadline and no accuracy
    /// budget) and the full builder form are the same code path.
    ///
    /// Deadline semantics: an already-expired deadline is rejected here
    /// with [`SubmitError::DeadlineExpired`] (never occupying a batch
    /// slot); a request that expires while queued is answered with an
    /// `Err("deadline")` embedding by the worker that pops it, again
    /// before batch assembly. A cache hit is served even under an
    /// expired deadline — it costs nothing.
    ///
    /// Admission semantics: a request carrying an accuracy budget (or
    /// any request, when the operator forced a tier) is routed to a
    /// (variant, precision) tier by the [`AdmissionPolicy`]; the serving
    /// tier comes back in [`Response::tier`]. Untagged, unforced
    /// requests serve on the configured path — byte-identical to a
    /// build without admission routing.
    ///
    /// ```
    /// use ssaformer::config::{ServingConfig, Variant};
    /// use ssaformer::coordinator::{
    ///     Coordinator, CpuEngine, CpuModel, CpuModelConfig, EncodeRequest,
    ///     ExecBackend, SubmitError,
    /// };
    /// use std::time::Duration;
    /// let cfg = ServingConfig::default();
    /// let engine = Box::new(CpuEngine::new(CpuModel::new(
    ///     CpuModelConfig::default(), Variant::SpectralShift)));
    /// let c = Coordinator::start(ExecBackend::Cpu(engine), &cfg).unwrap();
    /// // a zero budget has always already expired at admission
    /// assert_eq!(c.submit(EncodeRequest::new(vec![5, 6, 7])
    ///                .deadline(Duration::ZERO))
    ///                .err(),
    ///            Some(SubmitError::DeadlineExpired));
    /// assert_eq!(c.metrics.requests_expired.get(), 1);
    /// // a generous budget serves normally
    /// let rx = c.submit(EncodeRequest::new(vec![5, 6, 7])
    ///               .deadline(Duration::from_secs(30))).unwrap();
    /// assert!(rx.recv().unwrap().embedding.is_ok());
    /// ```
    pub fn submit(&self, req: impl Into<EncodeRequest>)
                  -> Result<mpsc::Receiver<Response>, SubmitError> {
        let req = req.into();
        if self.cancel.is_cancelled() {
            return Err(SubmitError::ShuttingDown);
        }
        if !req.internal {
            self.metrics.requests_in.inc();
        }
        // the admission decision: None = configured path. Decided once,
        // up front, so the cache policy and the long-document chunker
        // below both see the same tier.
        let tier = self.admission.as_ref()
            .and_then(|p| p.decide(req.tokens.len(), req.accuracy));
        let EncodeRequest { tokens, deadline: budget, internal, .. } = req;
        let bucket = match self.router.route(tokens.len()) {
            Route::Bucket(b) => b,
            Route::TooLong { len, max } => {
                // the streaming long-document path: split into
                // independent chunks, reuse known ones, merge — one
                // logical request, one response
                if self.chunk_tokens > 0 {
                    return self.submit_chunked(tokens, budget, tier);
                }
                self.metrics.requests_rejected.inc();
                return Err(SubmitError::TooLong { len, max });
            }
            Route::Empty => {
                self.metrics.requests_rejected.inc();
                return Err(SubmitError::Empty);
            }
        };
        let idx = self.router.bucket_index(bucket).unwrap();
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        // cache fast path: serve a known embedding instantly (even a
        // tight deadline is met by a hit). Tier-routed requests skip
        // the cache entirely — its entries are configured-path
        // embeddings and a tier serves a different function.
        if tier.is_none() && !internal {
            if let Some(cache) = &self.cache {
                let t0 = Instant::now();
                if let Some(emb) = cache.get(&tokens) {
                    self.metrics.cache_hits.inc();
                    self.metrics.requests_done.inc();
                    self.metrics.admission_configured.inc();
                    self.metrics.e2e_latency.record(t0.elapsed());
                    let (tx, rx) = mpsc::channel();
                    // the lookup under the lock was a refcount bump; the
                    // response's owned copy is made out here
                    let _ = tx.send(Response {
                        id,
                        embedding: Ok(emb.to_vec()),
                        queue_time: Duration::ZERO,
                        exec_time: Duration::ZERO,
                        tier: None,
                    });
                    return Ok(rx);
                }
            }
        }
        // checked: an absurd budget that overflows Instant (e.g. a wire
        // DEADLINE_MS of u64::MAX) degrades to "no deadline", not a panic
        let deadline = budget
            .or(self.default_deadline)
            .and_then(|b| Instant::now().checked_add(b));
        if let Some(d) = deadline {
            if d <= Instant::now() {
                self.metrics.requests_expired.inc();
                return Err(SubmitError::DeadlineExpired);
            }
        }
        let (tx, rx) = mpsc::channel();
        // cache_misses is counted by the worker when the batch reaches
        // compute — never here, so rejected or queued-then-expired
        // requests cannot deflate the hit rate
        let item = Pending { id, tokens, tx, internal, tier };
        match self.queue.push(idx, item, deadline) {
            Ok(()) => {
                if !internal {
                    self.count_admission(tier);
                }
                Ok(rx)
            }
            Err(PushError::Full) => {
                self.metrics.requests_rejected.inc();
                Err(SubmitError::QueueFull)
            }
            Err(_) => Err(SubmitError::ShuttingDown),
        }
    }

    /// Meter one admitted caller request on the STATS `admission:` line.
    fn count_admission(&self, tier: Option<TierKind>) {
        match tier {
            None => self.metrics.admission_configured.inc(),
            Some(t) => self.metrics.admission_served[t.index()].inc(),
        }
    }

    /// Deprecated: deadline budgets ride the [`EncodeRequest`] builder
    /// now — `submit(EncodeRequest::new(tokens).deadline(budget))`.
    #[deprecated(note = "use submit(EncodeRequest::new(tokens)\
                         .deadline_opt(budget))")]
    pub fn submit_with_deadline(&self, tokens: Vec<i32>, budget: Option<Duration>)
                                -> Result<mpsc::Receiver<Response>, SubmitError> {
        self.submit(EncodeRequest::new(tokens).deadline_opt(budget))
    }

    /// Serve a document longer than the largest bucket by splitting it
    /// into independent `chunk_tokens`-sized chunks, encoding each as
    /// its own sequence, and length-weighted-merging the pooled chunk
    /// embeddings ([`merge_chunk_embeddings`]) into one response.
    ///
    /// Chunk independence makes reuse *exact*: each chunk's embedding is
    /// a pure function of the chunk's tokens, so a [`PrefixCache`] hit
    /// is bitwise the recompute, and a document sharing its first k
    /// chunks with prior traffic only computes the tail. Missing chunks
    /// go through the normal sharded queue as `internal` items — they
    /// batch with regular traffic and spread across the worker pool —
    /// while this (caller) thread blocks until every chunk resolves,
    /// mirroring the blocking `recv` the caller would perform anyway.
    ///
    /// Accounting stays request-level: the document is one `requests_in`
    /// / `requests_done` / e2e-latency unit; per-chunk work is metered
    /// by `prefix_hits` / `prefix_misses` / `chunks_computed` (and the
    /// usual token/batch counters, which measure real compute).
    ///
    /// A tier-routed document propagates its tier to every chunk and
    /// skips the prefix cache in both directions — its entries are
    /// configured-path chunk embeddings, which a tier must neither
    /// serve nor pollute.
    fn submit_chunked(&self, tokens: Vec<i32>, budget: Option<Duration>,
                      tier: Option<TierKind>)
                      -> Result<mpsc::Receiver<Response>, SubmitError> {
        let t0 = Instant::now();
        let deadline = budget
            .or(self.default_deadline)
            .and_then(|b| Instant::now().checked_add(b));
        if let Some(d) = deadline {
            if d <= Instant::now() {
                self.metrics.requests_expired.inc();
                return Err(SubmitError::DeadlineExpired);
            }
        }
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.count_admission(tier);
        // pass 1: split, consult the prefix cache, enqueue every miss —
        // all misses are in flight before we wait on any of them
        let mut parts: Vec<(usize, Option<Arc<[f32]>>)> = Vec::new();
        let mut waits: Vec<(usize, Vec<i32>, mpsc::Receiver<Response>)> =
            Vec::new();
        for chunk in tokens.chunks(self.chunk_tokens) {
            let slot = parts.len();
            let cached = if tier.is_none() {
                self.prefix_cache.as_ref().and_then(|p| p.get(chunk))
            } else {
                None
            };
            match cached {
                Some(emb) => {
                    self.metrics.prefix_hits.inc();
                    parts.push((chunk.len(), Some(emb)));
                }
                None => {
                    if tier.is_none() {
                        self.metrics.prefix_misses.inc();
                    }
                    parts.push((chunk.len(), None));
                    let rx = self.submit_chunk(chunk.to_vec(), deadline,
                                               tier)?;
                    waits.push((slot, chunk.to_vec(), rx));
                }
            }
        }
        // pass 2: collect computed chunks, teaching the prefix cache
        // each one so the next overlapping document reuses it
        for (slot, chunk, rx) in waits {
            let resp = rx.recv().map_err(|_| SubmitError::ShuttingDown)?;
            match resp.embedding {
                Ok(emb) => {
                    self.metrics.chunks_computed.inc();
                    let shared: Arc<[f32]> = Arc::from(&emb[..]);
                    if tier.is_none() {
                        if let Some(p) = &self.prefix_cache {
                            p.insert(&chunk, shared.clone());
                        }
                    }
                    parts[slot].1 = Some(shared);
                }
                Err(msg) => {
                    // a failed chunk fails the document with the same
                    // wire taxonomy (`deadline`, `execute: …`); expiry
                    // is counted here — once per document, matching the
                    // one `requests_in`
                    if msg == "deadline" {
                        self.metrics.requests_expired.inc();
                    }
                    let (tx, rx) = mpsc::channel();
                    let _ = tx.send(Response {
                        id,
                        embedding: Err(msg),
                        queue_time: t0.elapsed(),
                        exec_time: Duration::ZERO,
                        tier,
                    });
                    return Ok(rx);
                }
            }
        }
        let resolved: Vec<(usize, Arc<[f32]>)> = parts
            .into_iter()
            .map(|(len, emb)| (len, emb.expect("every chunk resolved")))
            .collect();
        let embedding = merge_chunk_embeddings(&resolved);
        self.metrics.requests_done.inc();
        self.metrics.e2e_latency.record(t0.elapsed());
        let (tx, rx) = mpsc::channel();
        let _ = tx.send(Response {
            id,
            embedding: Ok(embedding),
            queue_time: Duration::ZERO,
            exec_time: t0.elapsed(),
            tier,
        });
        Ok(rx)
    }

    /// Enqueue one chunk of a long document as an `internal` item: no
    /// request-level counters, no whole-sequence cache lookup (chunk
    /// reuse is the prefix cache's job), the parent document's absolute
    /// deadline carried through so queued chunks expire exactly when
    /// the document does.
    fn submit_chunk(&self, tokens: Vec<i32>, deadline: Option<Instant>,
                    tier: Option<TierKind>)
                    -> Result<mpsc::Receiver<Response>, SubmitError> {
        let bucket = match self.router.route(tokens.len()) {
            Route::Bucket(b) => b,
            // unreachable by construction — chunk_tokens is clamped to
            // the largest bucket at startup — but fail closed anyway
            Route::TooLong { len, max } => {
                return Err(SubmitError::TooLong { len, max })
            }
            Route::Empty => return Err(SubmitError::Empty),
        };
        let idx = self.router.bucket_index(bucket).unwrap();
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let item = Pending { id, tokens, tx, internal: true, tier };
        match self.queue.push(idx, item, deadline) {
            Ok(()) => Ok(rx),
            Err(PushError::Full) => {
                // the document is the rejected request, counted once
                self.metrics.requests_rejected.inc();
                Err(SubmitError::QueueFull)
            }
            Err(_) => Err(SubmitError::ShuttingDown),
        }
    }

    /// Convenience: submit and block for the response. Takes the same
    /// `impl Into<EncodeRequest>` as [`Coordinator::submit`].
    pub fn submit_blocking(&self, req: impl Into<EncodeRequest>)
                           -> Result<Response, SubmitError> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| SubmitError::ShuttingDown)
    }

    /// Graceful shutdown: drain the queue, stop the worker pool.
    pub fn shutdown(mut self) {
        self.cancel.cancel();
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.cancel.cancel();
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Fail every already-expired request in the batch with an
/// `Err("deadline")` response (the wire's `ERR <id> deadline`) and
/// return the still-live remainder. Runs on the popping worker *before*
/// batch assembly, so expired requests never occupy batch slots.
fn split_expired(batch: Vec<Queued<Pending>>,
                 metrics: &ServingMetrics) -> Vec<Queued<Pending>> {
    let now = Instant::now();
    let mut live = Vec::with_capacity(batch.len());
    for q in batch {
        if q.deadline.map_or(false, |d| d <= now) {
            // internal chunks answer Err("deadline") like any item, but
            // the expiry counter belongs to the parent document (one
            // logical request), which counts it on collection
            if !q.item.internal {
                metrics.requests_expired.inc();
            }
            let _ = q.item.tx.send(Response {
                id: q.item.id,
                embedding: Err("deadline".to_string()),
                queue_time: now.duration_since(q.enqueued),
                exec_time: Duration::ZERO,
                tier: q.item.tier,
            });
        } else {
            live.push(q);
        }
    }
    live
}

/// Record the served embedding for each request so an identical token
/// sequence hits on the next admission. Internal chunk items are
/// skipped: chunk reuse belongs to the prefix cache (keyed and metered
/// separately), and letting chunks churn the whole-sequence LRU would
/// evict real request entries. Tier-routed items are skipped too — the
/// cache-coherence invariant ("a hit is bitwise a recompute") is stated
/// over the configured function, and a tier serves a different one.
fn cache_batch(cache: Option<&EmbeddingCache>, batch: &[Queued<Pending>],
               rows: &[Vec<f32>]) {
    if let Some(cache) = cache {
        for (q, emb) in batch.iter().zip(rows) {
            if !q.item.internal && q.item.tier.is_none() {
                cache.insert(&q.item.tokens, emb);
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop_xla(engine: &Engine, variant: Variant, buckets: &[usize],
                   queue: &ShardedQueue<Pending>, home: usize,
                   policy: BatchPolicy, metrics: &ServingMetrics,
                   cache: Option<&EmbeddingCache>, params: &ParamsBuffer) {
    while let Some(batch) = queue.pop_batch_worker(home, policy) {
        let batch = split_expired(batch, metrics);
        if batch.is_empty() {
            continue;
        }
        // a cache miss = a looked-up request that reached compute
        // (expired/rejected ones never count against the hit rate;
        // internal chunks never looked the cache up at all)
        if cache.is_some() {
            metrics.cache_misses.add(
                batch.iter().filter(|q| !q.item.internal).count() as u64);
        }
        let bucket = buckets[batch[0].bucket];
        let now = Instant::now();
        for q in &batch {
            metrics
                .queue_latency
                .record(now.duration_since(q.enqueued));
        }
        // load is cached post-warmup; a miss only happens on new buckets
        let model = match engine.load(ArtifactKind::Encode, variant, bucket) {
            Ok(m) => m,
            Err(e) => {
                fail_batch(batch, &format!("load: {e}"));
                continue;
            }
        };
        let token_refs: Vec<&[i32]> =
            batch.iter().map(|q| q.item.tokens.as_slice()).collect();
        let plan = assemble(&token_refs, model.entry.batch, bucket);
        let real_tokens: u64 = token_refs.iter().map(|t| t.len() as u64).sum();
        metrics.tokens_processed.add(real_tokens);
        metrics.batch_slots.add(model.entry.batch as u64);
        // the artifact executes the whole dense capacity×bucket tensor,
        // so every non-real position is executed padding
        metrics
            .padded_tokens
            .add((model.entry.batch * bucket) as u64 - real_tokens);
        let t_exec = Instant::now();
        let result = model.encode(engine, &params.0, &plan.tokens);
        let exec_time = t_exec.elapsed();
        metrics.exec_latency.record(exec_time);
        metrics.batches_executed.inc();
        match result {
            Ok(flat) => {
                let d_model = flat.len() / model.entry.batch;
                let rows = scatter(&plan, &flat, d_model);
                cache_batch(cache, &batch, &rows);
                let finish = Instant::now();
                for (q, emb) in batch.into_iter().zip(rows) {
                    // request-level accounting belongs to the parent
                    // document for internal chunk items
                    if !q.item.internal {
                        metrics.requests_done.inc();
                        metrics
                            .e2e_latency
                            .record(finish.duration_since(q.enqueued));
                    }
                    let _ = q.item.tx.send(Response {
                        id: q.item.id,
                        embedding: Ok(emb),
                        queue_time: now.duration_since(q.enqueued),
                        exec_time,
                        tier: q.item.tier,
                    });
                }
            }
            Err(e) => fail_batch(batch, &format!("execute: {e}")),
        }
    }
}

/// The CPU twin of [`worker_loop_xla`]: same pop/steal → expire →
/// assemble → execute → respond cycle, but the "artifact" is
/// [`CpuEngine::encode_batch_with`] running on the in-process kernel
/// core. Batch capacity is the configured `max_batch` (there is no
/// artifact batch dimension to match). Every worker in the pool runs
/// this loop with its own forked engine.
///
/// Popped batches are bucket-homogeneous but may mix admission tiers;
/// the loop splits each into tier-homogeneous sub-batches (order
/// preserved within a tier) since one kernel execution serves exactly
/// one (variant, precision) stack.
fn worker_loop_cpu(engine: &mut CpuEngine, capacity: usize, buckets: &[usize],
                   queue: &ShardedQueue<Pending>, home: usize,
                   policy: BatchPolicy, metrics: &ServingMetrics,
                   cache: Option<&EmbeddingCache>) {
    while let Some(batch) = queue.pop_batch_worker(home, policy) {
        let batch = split_expired(batch, metrics);
        if batch.is_empty() {
            continue;
        }
        // a cache miss = a looked-up request that reached compute
        // (expired/rejected ones never count against the hit rate;
        // internal chunks and tier-routed requests never looked the
        // cache up at all)
        if cache.is_some() {
            metrics.cache_misses.add(
                batch.iter()
                    .filter(|q| !q.item.internal && q.item.tier.is_none())
                    .count() as u64);
        }
        let now = Instant::now();
        for q in &batch {
            metrics
                .queue_latency
                .record(now.duration_since(q.enqueued));
        }
        let bucket = buckets[batch[0].bucket];
        // tier-homogeneous sub-batches, first-seen tier order
        let mut groups: Vec<(Option<TierKind>, Vec<Queued<Pending>>)> =
            Vec::new();
        for q in batch {
            match groups.iter_mut().find(|(t, _)| *t == q.item.tier) {
                Some((_, g)) => g.push(q),
                None => groups.push((q.item.tier, vec![q])),
            }
        }
        for (tier, group) in groups {
            let token_refs: Vec<&[i32]> =
                group.iter().map(|q| q.item.tokens.as_slice()).collect();
            let lens: Vec<usize> = token_refs.iter().map(|t| t.len()).collect();
            let plan = assemble(&token_refs, capacity, bucket);
            metrics
                .tokens_processed
                .add(lens.iter().map(|&l| l as u64).sum());
            metrics.batch_slots.add(capacity as u64);
            // CPU path skips padding rows entirely; only the
            // landmark-alignment tails (of the executing tier's
            // operator) are executed padding
            metrics.padded_tokens.add(
                engine.padded_positions_for(tier, &lens));
            let t_exec = Instant::now();
            let rows = engine.encode_batch_with(&plan, &lens, tier);
            let exec_time = t_exec.elapsed();
            metrics.exec_latency.record(exec_time);
            metrics.batches_executed.inc();
            cache_batch(cache, &group, &rows);
            let finish = Instant::now();
            for (q, emb) in group.into_iter().zip(rows) {
                // request-level accounting belongs to the parent
                // document for internal chunk items
                if !q.item.internal {
                    metrics.requests_done.inc();
                    metrics
                        .e2e_latency
                        .record(finish.duration_since(q.enqueued));
                }
                let _ = q.item.tx.send(Response {
                    id: q.item.id,
                    embedding: Ok(emb),
                    queue_time: now.duration_since(q.enqueued),
                    exec_time,
                    tier: q.item.tier,
                });
            }
        }
    }
}

fn fail_batch(batch: Vec<Queued<Pending>>, msg: &str) {
    for q in batch {
        let _ = q.item.tx.send(Response {
            id: q.item.id,
            embedding: Err(msg.to_string()),
            queue_time: Duration::ZERO,
            exec_time: Duration::ZERO,
            tier: q.item.tier,
        });
    }
}

#[cfg(test)]
mod tests {
    //! Coordinator logic that needs no execution engine is tested here;
    //! end-to-end CPU serving (worker pool, cache, deadlines over TCP)
    //! lives in `rust/tests/integration_cpu_serving.rs` and the
    //! artifact path in `rust/tests/integration_serving.rs`.

    use super::*;

    #[test]
    fn submit_error_semantics() {
        assert_eq!(SubmitError::QueueFull, SubmitError::QueueFull);
        assert_eq!(SubmitError::DeadlineExpired, SubmitError::DeadlineExpired);
        let e = SubmitError::TooLong { len: 600, max: 512 };
        match e {
            SubmitError::TooLong { len, max } => {
                assert_eq!(len, 600);
                assert_eq!(max, 512);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn auto_backend_falls_back_to_cpu_without_artifacts() {
        let cfg = ServingConfig {
            artifacts_dir: "definitely/not/a/real/artifacts/dir".into(),
            ..Default::default()
        };
        let backend = ExecBackend::auto(&cfg).unwrap();
        assert_eq!(backend.kind(), BackendKind::Cpu);
    }

    #[test]
    fn cpu_backend_rejects_misaligned_buckets() {
        let cfg = ServingConfig {
            seq_buckets: vec![100], // not divisible by the 16 landmarks
            ..Default::default()
        };
        let engine = Box::new(CpuEngine::new(CpuModel::new(
            CpuModelConfig::default(), Variant::SpectralShift)));
        assert!(Coordinator::start(ExecBackend::Cpu(engine), &cfg).is_err());
    }

    #[test]
    fn split_expired_fails_only_expired_requests() {
        let metrics = ServingMetrics::new();
        let now = Instant::now();
        let mk = |id: u64, deadline: Option<Instant>| {
            let (tx, rx) = mpsc::channel();
            (Queued {
                bucket: 0,
                enqueued: now,
                deadline,
                item: Pending { id, tokens: vec![1, 2, 3], tx,
                                internal: false, tier: None },
            }, rx)
        };
        let (expired, rx_expired) = mk(0, Some(now)); // already past
        let (live_dl, _rx_live_dl) =
            mk(1, Some(now + Duration::from_secs(60)));
        let (no_dl, _rx_no_dl) = mk(2, None);
        let live = split_expired(vec![expired, live_dl, no_dl], &metrics);
        // expired request got its ERR-deadline response...
        let resp = rx_expired.try_recv().expect("expired request answered");
        assert_eq!(resp.embedding.unwrap_err(), "deadline");
        assert_eq!(metrics.requests_expired.get(), 1);
        // ...and the survivors continue toward assembly, in order
        let ids: Vec<u64> = live.iter().map(|q| q.item.id).collect();
        assert_eq!(ids, vec![1, 2]);
    }

    #[test]
    fn pool_and_cache_report_their_shape() {
        let cfg = ServingConfig {
            workers: 3,
            queue_shards: 2,
            cache_capacity: 16,
            ..Default::default()
        };
        let engine = Box::new(CpuEngine::new(CpuModel::new(
            CpuModelConfig::default(), Variant::SpectralShift)));
        let c = Coordinator::start(ExecBackend::Cpu(engine), &cfg).unwrap();
        assert_eq!(c.workers(), 3);
        assert_eq!(c.queue_shards(), 2);
        assert_eq!(c.cache_capacity(), 16);
        assert_eq!(c.cache_len(), 0);
        // the default chunk length is already divisor-aligned; the
        // default prefix cache rides along
        assert_eq!(c.chunk_tokens(), 256);
        assert_eq!(c.prefix_cache_capacity(), 1024);
        assert_eq!(c.prefix_cache_len(), 0);
        assert_eq!(c.queue_depth(), 0);
        assert!(c.model_desc().contains("1 layers"), "{}", c.model_desc());
        assert!(c.model_desc().contains("variant=spectral_shift"),
                "{}", c.model_desc());
    }

    #[test]
    fn chunk_length_is_landmark_aligned_and_bucket_clamped() {
        // 24 rounds up to the next multiple of the 16 landmarks…
        let cfg = ServingConfig {
            seq_buckets: vec![32, 64],
            chunk_tokens: 24,
            ..Default::default()
        };
        let engine = Box::new(CpuEngine::new(CpuModel::new(
            CpuModelConfig::default(), Variant::SpectralShift)));
        let c = Coordinator::start(ExecBackend::Cpu(engine), &cfg).unwrap();
        assert_eq!(c.chunk_tokens(), 32);
        // …0 stays 0 (chunking disabled)…
        let cfg = ServingConfig {
            seq_buckets: vec![32, 64],
            chunk_tokens: 0,
            ..Default::default()
        };
        let engine = Box::new(CpuEngine::new(CpuModel::new(
            CpuModelConfig::default(), Variant::SpectralShift)));
        let c = Coordinator::start(ExecBackend::Cpu(engine), &cfg).unwrap();
        assert_eq!(c.chunk_tokens(), 0);
        // …and an oversized chunk clamps to the largest bucket
        let cfg = ServingConfig {
            seq_buckets: vec![32, 64],
            chunk_tokens: 512,
            ..Default::default()
        };
        let engine = Box::new(CpuEngine::new(CpuModel::new(
            CpuModelConfig::default(), Variant::SpectralShift)));
        let c = Coordinator::start(ExecBackend::Cpu(engine), &cfg).unwrap();
        assert_eq!(c.chunk_tokens(), 64);
    }

    #[test]
    fn long_documents_serve_chunked_and_replay_hits_the_prefix_cache() {
        let cfg = ServingConfig {
            seq_buckets: vec![32],
            chunk_tokens: 16,
            prefix_cache_capacity: 8,
            cache_capacity: 0, // whole-sequence cache off: every serve
            // of the document exercises the chunked path
            workers: 2,
            queue_capacity: 64,
            ..Default::default()
        };
        let engine = Box::new(CpuEngine::new(CpuModel::new(
            CpuModelConfig::default(), Variant::SpectralShift)));
        let c = Coordinator::start(ExecBackend::Cpu(engine), &cfg).unwrap();
        // 40 tokens over a 32-token n_max: chunks of 16 + 16 + 8
        let doc: Vec<i32> = (0..40).map(|i| 5 + (i % 97)).collect();
        let cold = c.submit_blocking(doc.clone()).unwrap().embedding.unwrap();
        assert_eq!(c.metrics.prefix_misses.get(), 3);
        assert_eq!(c.metrics.chunks_computed.get(), 3);
        assert_eq!(c.metrics.prefix_hits.get(), 0);
        // one logical request, start to finish
        assert_eq!(c.metrics.requests_in.get(), 1);
        assert_eq!(c.metrics.requests_done.get(), 1);
        assert_eq!(c.prefix_cache_len(), 3);

        // replay: every chunk hits, and the merged embedding is
        // bitwise the cold serve (chunk reuse is exact)
        let warm = c.submit_blocking(doc).unwrap().embedding.unwrap();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&warm), bits(&cold));
        assert_eq!(c.metrics.prefix_hits.get(), 3);
        assert_eq!(c.metrics.chunks_computed.get(), 3, "hits recomputed");
        assert_eq!(c.metrics.requests_done.get(), 2);

        // a document sharing the first two chunks only computes its tail
        let mut overlap: Vec<i32> = (0..32).map(|i| 5 + (i % 97)).collect();
        overlap.extend((0..8).map(|i| 900 + i));
        let r = c.submit_blocking(overlap).unwrap();
        assert!(r.embedding.is_ok());
        assert_eq!(c.metrics.prefix_hits.get(), 5, "shared prefix missed");
        assert_eq!(c.metrics.chunks_computed.get(), 4, "only the new tail");
    }

    #[test]
    fn disabled_chunking_still_rejects_long_documents() {
        let cfg = ServingConfig {
            seq_buckets: vec![32],
            chunk_tokens: 0,
            ..Default::default()
        };
        let engine = Box::new(CpuEngine::new(CpuModel::new(
            CpuModelConfig::default(), Variant::SpectralShift)));
        let c = Coordinator::start(ExecBackend::Cpu(engine), &cfg).unwrap();
        let doc: Vec<i32> = (0..40).collect();
        assert_eq!(c.submit(doc).err(),
                   Some(SubmitError::TooLong { len: 40, max: 32 }));
        assert_eq!(c.metrics.requests_rejected.get(), 1);
    }

    #[test]
    fn accuracy_routes_tiers_and_untagged_stays_configured() {
        let cfg = ServingConfig::default();
        let engine = Box::new(CpuEngine::new(CpuModel::new(
            CpuModelConfig::default(), Variant::Full)));
        let c = Coordinator::start(ExecBackend::Cpu(engine), &cfg).unwrap();
        let pol = c.admission().expect("cpu backend builds a policy");
        assert_eq!(pol.available(), TierKind::ALL, "default buckets admit \
                   every tier (all divisible by 16 landmarks)");
        assert!(c.admission_desc().starts_with("policy=auto"),
                "{}", c.admission_desc());
        let toks: Vec<i32> = (0..40).map(|i| 5 + (i % 97)).collect();
        // untagged: configured path, no tier in the response
        let r = c.submit_blocking(toks.clone()).unwrap();
        assert_eq!(r.tier, None);
        assert!(r.embedding.is_ok());
        // budget accuracy: the cheapest tier serves and is echoed
        let r = c.submit_blocking(
            EncodeRequest::new(toks.clone()).accuracy(Accuracy::Budget))
            .unwrap();
        assert_eq!(r.tier, Some(TierKind::SsInt8));
        assert!(r.embedding.is_ok());
        // high accuracy: the f32 reference tier
        let r = c.submit_blocking(
            EncodeRequest::new(toks.clone()).accuracy(Accuracy::High))
            .unwrap();
        assert_eq!(r.tier, Some(TierKind::FullF32));
        // the admission line saw one configured and two tiered requests
        assert_eq!(c.metrics.admission_configured.get(), 1);
        assert_eq!(c.metrics.admission_served[TierKind::SsInt8.index()].get(),
                   1);
        assert_eq!(c.metrics.admission_served[TierKind::FullF32.index()].get(),
                   1);
        // the deprecated deadline shim still lands on the same path
        #[allow(deprecated)]
        let rx = c.submit_with_deadline(
            toks, Some(Duration::from_secs(30))).unwrap();
        assert!(rx.recv().unwrap().embedding.is_ok());
    }

    #[test]
    fn tier_routed_requests_bypass_the_embedding_cache() {
        let cfg = ServingConfig { cache_capacity: 16, ..Default::default() };
        let engine = Box::new(CpuEngine::new(CpuModel::new(
            CpuModelConfig::default(), Variant::Full)));
        let c = Coordinator::start(ExecBackend::Cpu(engine), &cfg).unwrap();
        let toks: Vec<i32> = (0..32).map(|i| 7 + (i % 89)).collect();
        // seed the cache on the configured path
        let cold = c.submit_blocking(toks.clone()).unwrap().embedding.unwrap();
        assert_eq!(c.cache_len(), 1);
        // a tiered serve of the same tokens must compute, not hit, and
        // must not overwrite the configured entry
        let tiered = c.submit_blocking(
            EncodeRequest::new(toks.clone()).accuracy(Accuracy::Budget))
            .unwrap().embedding.unwrap();
        assert_eq!(c.metrics.cache_hits.get(), 0);
        assert_eq!(c.cache_len(), 1);
        assert_ne!(cold, tiered, "int8 ss tier serves a different function");
        // and the configured path still hits its own (untainted) entry
        let warm = c.submit_blocking(toks).unwrap().embedding.unwrap();
        assert_eq!(c.metrics.cache_hits.get(), 1);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&warm), bits(&cold));
    }

    #[test]
    fn forced_admission_routes_untagged_requests() {
        // the [serving] admission knob (here via the config field)
        // forces every request onto one tier
        let cfg = ServingConfig { admission: Some(TierKind::SsBf16),
                                  ..Default::default() };
        let engine = Box::new(CpuEngine::new(CpuModel::new(
            CpuModelConfig::default(), Variant::Full)));
        let c = Coordinator::start(ExecBackend::Cpu(engine), &cfg).unwrap();
        assert!(c.admission_desc().starts_with("policy=forced-ss-bf16"),
                "{}", c.admission_desc());
        let r = c.submit_blocking(vec![5, 6, 7]).unwrap();
        assert_eq!(r.tier, Some(TierKind::SsBf16));
        assert!(r.embedding.is_ok());
    }

    #[test]
    fn misaligned_buckets_fall_back_to_the_f32_tier() {
        // bucket 100 is not divisible by the 16 landmarks, so no ss
        // tier is admissible; a full-variant model still starts (its
        // configured path needs no alignment) and budget requests fall
        // back to full-f32
        let cfg = ServingConfig { seq_buckets: vec![100],
                                  ..Default::default() };
        let engine = Box::new(CpuEngine::new(CpuModel::new(
            CpuModelConfig::default(), Variant::Full)));
        let c = Coordinator::start(ExecBackend::Cpu(engine), &cfg).unwrap();
        assert_eq!(c.admission().unwrap().available(),
                   &[TierKind::FullF32]);
        let r = c.submit_blocking(
            EncodeRequest::new(vec![5, 6, 7]).accuracy(Accuracy::Budget))
            .unwrap();
        assert_eq!(r.tier, Some(TierKind::FullF32));
    }

    #[test]
    fn tiered_long_documents_chunk_with_the_tier_and_skip_prefix_reuse() {
        let cfg = ServingConfig {
            seq_buckets: vec![32],
            chunk_tokens: 16,
            prefix_cache_capacity: 8,
            cache_capacity: 0,
            queue_capacity: 64,
            ..Default::default()
        };
        let engine = Box::new(CpuEngine::new(CpuModel::new(
            CpuModelConfig::default(), Variant::Full)));
        let c = Coordinator::start(ExecBackend::Cpu(engine), &cfg).unwrap();
        let doc: Vec<i32> = (0..40).map(|i| 5 + (i % 97)).collect();
        let r = c.submit_blocking(
            EncodeRequest::new(doc.clone()).accuracy(Accuracy::Budget))
            .unwrap();
        assert_eq!(r.tier, Some(TierKind::SsInt8));
        assert!(r.embedding.is_ok());
        // tier-routed chunks neither consult nor teach the prefix cache
        assert_eq!(c.metrics.prefix_hits.get(), 0);
        assert_eq!(c.metrics.prefix_misses.get(), 0);
        assert_eq!(c.metrics.chunks_computed.get(), 3);
        assert_eq!(c.prefix_cache_len(), 0);
        assert_eq!(c.metrics.admission_served[TierKind::SsInt8.index()].get(),
                   1, "the document is one admission unit");
        // an untagged replay of the same document takes the configured
        // chunked path and fills the cache as before
        let r = c.submit_blocking(doc).unwrap();
        assert_eq!(r.tier, None);
        assert_eq!(c.metrics.prefix_misses.get(), 3);
        assert_eq!(c.prefix_cache_len(), 3);
    }

    #[test]
    fn auto_cpu_backend_inherits_encoder_knobs() {
        let cfg = ServingConfig {
            artifacts_dir: "definitely/not/a/real/artifacts/dir".into(),
            layers: 3,
            ffn_mult: 2,
            projections: true,
            layer_variants: vec![Variant::SpectralShift,
                                 Variant::SpectralShift, Variant::Full],
            ..Default::default()
        };
        match ExecBackend::auto(&cfg).unwrap() {
            ExecBackend::Cpu(engine) => {
                assert_eq!(engine.model().layers(), 3);
                assert_eq!(engine.model().ffn_mult(), 2);
                assert!(engine.model().projections());
                assert_eq!(engine.model().variants()[2], Variant::Full);
            }
            ExecBackend::Xla(_) => panic!("no artifacts, must fall back"),
        }
    }

    #[test]
    fn load_policy_fails_closed_on_bad_checkpoints() {
        use crate::config::InitPolicy;
        // missing file
        let cfg = ServingConfig {
            artifacts_dir: "definitely/not/a/real/artifacts/dir".into(),
            weights: Some("definitely/not/a/real/weights.ckpt".into()),
            init: InitPolicy::Load,
            ..Default::default()
        };
        assert!(matches!(ExecBackend::auto(&cfg),
                         Err(crate::runtime::RuntimeError::Checkpoint(_))));
        // shape mismatch: a depth-3 checkpoint cannot serve layers = 2
        let path = std::env::temp_dir().join(format!(
            "ssaformer-coord-ckpt-{}.bin", std::process::id()));
        let donor = CpuModel::new(
            CpuModelConfig { layers: 3, ..Default::default() },
            Variant::SpectralShift);
        crate::model::checkpoint::save(donor.stack(), &path).unwrap();
        let cfg = ServingConfig {
            artifacts_dir: "definitely/not/a/real/artifacts/dir".into(),
            weights: Some(path.to_string_lossy().into_owned()),
            init: InitPolicy::Load,
            layers: 2,
            ..Default::default()
        };
        assert!(matches!(ExecBackend::auto(&cfg),
                         Err(crate::runtime::RuntimeError::Checkpoint(_))));
        // the matching depth loads and serves
        let cfg = ServingConfig { layers: 3, ..cfg };
        match ExecBackend::auto(&cfg).unwrap() {
            ExecBackend::Cpu(engine) => {
                assert!(engine.model().describe().contains("weights=loaded"),
                        "{}", engine.model().describe());
            }
            ExecBackend::Xla(_) => panic!("no artifacts, must fall back"),
        }
        std::fs::remove_file(&path).unwrap();
    }
}
