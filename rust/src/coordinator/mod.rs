//! The L3 coordinator (S14): router → bounded bucket queue → dynamic
//! batcher → execution backend, with metrics at every stage.
//!
//! Data path (python-free; see `ARCHITECTURE.md` for the full request
//! lifecycle walkthrough):
//!
//! ```text
//!   submit(tokens) ──route──▶ BucketQueue ──pop_batch──▶ worker thread
//!     ──assemble──▶ ExecBackend ──scatter/pool──▶ response channel
//!                      │
//!                      ├─ Xla: AOT encode artifact on the PJRT client
//!                      └─ Cpu: kernels::batched on the minirt pool
//! ```
//!
//! Two execution backends implement the same submit/batch/execute/
//! respond loop ([`ExecBackend`]): the PJRT worker executes compiled
//! encode artifacts, and the CPU worker drives the in-process
//! [`kernels`](crate::kernels) core through
//! [`batcher::attention_scatter`] via [`cpu_engine::CpuEngine`].
//! [`ExecBackend::auto`] picks XLA when artifacts + PJRT are available
//! and falls back to CPU otherwise, so the stack serves real embeddings
//! even with the offline `xla-stub` build.
//!
//! # Invariants
//!
//! * **Batch homogeneity** — every popped batch shares one sequence
//!   bucket ([`queue::BucketQueue::pop_batch`]), so one artifact shape /
//!   one padded tensor shape covers the whole batch.
//! * **Padding skip** — [`batcher::attention_scatter`] never executes
//!   padding *rows* (slots past `fill`) and excludes every position
//!   beyond the per-request length it is given from attention;
//!   `scatter` drops the same rows on the artifact path. The CPU engine
//!   passes landmark-*aligned* lengths, so a short alignment tail of
//!   PAD embeddings is executed (and metered as `padded_tokens`) —
//!   pooling still averages only real positions.
//! * **Order preservation** — responses are delivered on per-request
//!   channels; within a batch, outputs are scattered back in submission
//!   order.
//! * **Backend-independent protocol** — [`Response`] and the serving
//!   metrics have the same meaning on both backends; which one is live
//!   is reported via [`Coordinator::backend`] and the server's `STATS`
//!   report.
//!
//! Assemble/scatter are pure and unit-testable:
//!
//! ```
//! use ssaformer::coordinator::{assemble, scatter};
//! let plan = assemble(&[&[5, 6, 7][..]], /*capacity=*/2, /*seq=*/4);
//! assert_eq!((plan.fill, plan.tokens.len()), (1, 8));
//! // an executor output of capacity × width scatters back to fill rows
//! let rows = scatter(&plan, &vec![1.0; 2 * 3], 3);
//! assert_eq!(rows, vec![vec![1.0, 1.0, 1.0]]);
//! ```
//!
//! The paper's sec-9 deployment claim ("this method can reduce training
//! and inference time") is exercised by swapping the served attention
//! variant (full / nystrom / ss) while this coordinator stays fixed —
//! see the serving_throughput bench (E8).

pub mod batcher;
pub mod cpu_engine;
pub mod queue;
pub mod router;

pub use batcher::{assemble, scatter, BatchPlan};
pub use cpu_engine::{CpuEngine, CpuModel, CpuModelConfig};
pub use queue::{BatchPolicy, BucketQueue, PushError, Queued};
pub use router::{Route, Router};

use crate::config::{ServingConfig, Variant};
use crate::metrics::ServingMetrics;
use crate::minirt::CancelToken;
use crate::runtime::{ArtifactKind, BackendKind, Engine};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A completed request.
#[derive(Debug)]
pub struct Response {
    pub id: u64,
    /// pooled embedding (d_model floats) on success
    pub embedding: Result<Vec<f32>, String>,
    /// queue wait + execution time
    pub queue_time: Duration,
    pub exec_time: Duration,
}

struct Pending {
    id: u64,
    tokens: Vec<i32>,
    tx: mpsc::Sender<Response>,
}

/// Why admission failed.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    QueueFull,
    TooLong { len: usize, max: usize },
    Empty,
    ShuttingDown,
}

/// Shared device-resident parameter buffer.
struct ParamsBuffer(xla::PjRtBuffer);
unsafe impl Send for ParamsBuffer {}
unsafe impl Sync for ParamsBuffer {}

/// The execution engine behind the coordinator's worker loop.
pub enum ExecBackend {
    /// AOT-compiled encode artifacts executed on the PJRT runtime.
    Xla(Arc<Engine>),
    /// The in-process CPU kernel core — no artifacts required.
    Cpu(Box<CpuEngine>),
}

impl ExecBackend {
    /// Backend auto-selection: XLA when the artifacts directory loads
    /// and the PJRT client constructs, otherwise the CPU kernel backend
    /// with the default deterministic model. With the offline
    /// `xla-stub` build this always selects CPU.
    pub fn auto(cfg: &ServingConfig) -> ExecBackend {
        ExecBackend::auto_with_reason(cfg).0
    }

    /// [`ExecBackend::auto`], also returning *why* XLA was skipped (the
    /// engine construction error) so entry points can surface a corrupt
    /// manifest instead of silently serving the CPU demo model.
    pub fn auto_with_reason(cfg: &ServingConfig)
                            -> (ExecBackend, Option<crate::runtime::RuntimeError>) {
        match Engine::new(&cfg.artifacts_dir) {
            Ok(engine) => (ExecBackend::Xla(Arc::new(engine)), None),
            Err(e) => (
                ExecBackend::Cpu(Box::new(CpuEngine::new(CpuModel::new(
                    CpuModelConfig::default(),
                    cfg.variant,
                )))),
                Some(e),
            ),
        }
    }

    /// Which backend this is, for manifest/metrics reporting.
    pub fn kind(&self) -> BackendKind {
        match self {
            ExecBackend::Xla(_) => BackendKind::Xla,
            ExecBackend::Cpu(_) => BackendKind::Cpu,
        }
    }
}

/// Admission scaffolding shared by both backends — router, bounded
/// queue, metrics, cancel token, batch policy — built in one place so
/// the XLA and CPU start paths cannot diverge.
struct Scaffold {
    router: Router,
    queue: Arc<BucketQueue<Pending>>,
    metrics: Arc<ServingMetrics>,
    cancel: CancelToken,
    policy: BatchPolicy,
}

impl Scaffold {
    fn new(buckets: &[usize], cfg: &ServingConfig) -> Scaffold {
        Scaffold {
            router: Router::new(buckets.to_vec()),
            queue: Arc::new(BucketQueue::new(buckets.len(), cfg.queue_capacity)),
            metrics: Arc::new(ServingMetrics::new()),
            cancel: CancelToken::new(),
            policy: BatchPolicy {
                max_batch: cfg.max_batch,
                max_wait: Duration::from_millis(cfg.max_wait_ms),
            },
        }
    }

    fn into_coordinator(self, worker: std::thread::JoinHandle<()>,
                        kind: BackendKind) -> Coordinator {
        Coordinator {
            router: self.router,
            queue: self.queue,
            metrics: self.metrics,
            cancel: self.cancel,
            worker: Some(worker),
            next_id: std::sync::atomic::AtomicU64::new(0),
            backend_kind: kind,
        }
    }
}

/// The serving coordinator. One worker thread per instance executes
/// batches; admission is lock-light and callers receive responses on
/// per-request channels.
pub struct Coordinator {
    router: Router,
    queue: Arc<BucketQueue<Pending>>,
    pub metrics: Arc<ServingMetrics>,
    cancel: CancelToken,
    worker: Option<std::thread::JoinHandle<()>>,
    next_id: std::sync::atomic::AtomicU64,
    backend_kind: BackendKind,
}

impl Coordinator {
    /// Build and start the coordinator on the given execution backend.
    /// The XLA backend warms up (compiles) every encode artifact for
    /// the configured variant and uploads the parameters once; the CPU
    /// backend validates the bucket list against the model's landmark
    /// count. Either way a single batch-execution worker is spawned.
    pub fn start(backend: ExecBackend, cfg: &ServingConfig)
                 -> Result<Coordinator, crate::runtime::RuntimeError> {
        match backend {
            ExecBackend::Xla(engine) => Coordinator::start_xla(engine, cfg),
            ExecBackend::Cpu(engine) => Coordinator::start_cpu(engine, cfg),
        }
    }

    fn start_xla(engine: Arc<Engine>, cfg: &ServingConfig)
                 -> Result<Coordinator, crate::runtime::RuntimeError> {
        let buckets = engine.manifest().encode_buckets(cfg.variant);
        assert!(!buckets.is_empty(), "no encode artifacts for {:?}", cfg.variant);
        let s = Scaffold::new(&buckets, cfg);

        // preload executables + parameters
        engine.warmup(cfg.variant)?;
        let init = engine.init_params()?;
        let params = Arc::new(ParamsBuffer(
            engine.buffer_f32(&init, &[init.len()])?));

        let worker = {
            let queue = s.queue.clone();
            let metrics = s.metrics.clone();
            let cancel = s.cancel.clone();
            let engine = engine.clone();
            let variant = cfg.variant;
            let policy = s.policy;
            std::thread::Builder::new()
                .name("ssaformer-coordinator".into())
                .spawn(move || {
                    worker_loop_xla(&engine, variant, &buckets, &queue, policy,
                                    &metrics, &cancel, &params);
                })
                .expect("spawn coordinator worker")
        };
        Ok(s.into_coordinator(worker, BackendKind::Xla))
    }

    fn start_cpu(engine: Box<CpuEngine>, cfg: &ServingConfig)
                 -> Result<Coordinator, crate::runtime::RuntimeError> {
        let buckets = cfg.seq_buckets.clone();
        assert!(!buckets.is_empty(), "serving config must define seq buckets");
        // landmark variants execute at lengths rounded up to c, which
        // must still fit the bucket — require bucket % c == 0 up front
        if let Some(c) = engine.model().landmark_divisor() {
            if let Some(&bad) = buckets.iter().find(|&&b| b % c != 0) {
                return Err(crate::runtime::RuntimeError::Shape(format!(
                    "seq bucket {bad} not divisible by landmark count {c}")));
            }
        }
        let s = Scaffold::new(&buckets, cfg);

        let worker = {
            let queue = s.queue.clone();
            let metrics = s.metrics.clone();
            let cancel = s.cancel.clone();
            let policy = s.policy;
            let capacity = cfg.max_batch;
            let mut engine = engine;
            std::thread::Builder::new()
                .name("ssaformer-cpu-coordinator".into())
                .spawn(move || {
                    worker_loop_cpu(&mut engine, capacity, &buckets, &queue,
                                    policy, &metrics, &cancel);
                })
                .expect("spawn coordinator worker")
        };
        Ok(s.into_coordinator(worker, BackendKind::Cpu))
    }

    /// The execution backend serving this coordinator's requests.
    pub fn backend(&self) -> BackendKind {
        self.backend_kind
    }

    /// Submit a request; returns the receiver for its response.
    pub fn submit(&self, tokens: Vec<i32>)
                  -> Result<mpsc::Receiver<Response>, SubmitError> {
        if self.cancel.is_cancelled() {
            return Err(SubmitError::ShuttingDown);
        }
        self.metrics.requests_in.inc();
        let bucket = match self.router.route(tokens.len()) {
            Route::Bucket(b) => b,
            Route::TooLong { len, max } => {
                self.metrics.requests_rejected.inc();
                return Err(SubmitError::TooLong { len, max });
            }
            Route::Empty => {
                self.metrics.requests_rejected.inc();
                return Err(SubmitError::Empty);
            }
        };
        let idx = self.router.bucket_index(bucket).unwrap();
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        match self.queue.push(idx, Pending { id, tokens, tx }) {
            Ok(()) => Ok(rx),
            Err(PushError::Full) => {
                self.metrics.requests_rejected.inc();
                Err(SubmitError::QueueFull)
            }
            Err(_) => Err(SubmitError::ShuttingDown),
        }
    }

    /// Convenience: submit and block for the response.
    pub fn submit_blocking(&self, tokens: Vec<i32>) -> Result<Response, SubmitError> {
        let rx = self.submit(tokens)?;
        rx.recv().map_err(|_| SubmitError::ShuttingDown)
    }

    /// Graceful shutdown: drain the queue, stop the worker.
    pub fn shutdown(mut self) {
        self.cancel.cancel();
        self.queue.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.cancel.cancel();
        self.queue.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop_xla(engine: &Engine, variant: Variant, buckets: &[usize],
                   queue: &BucketQueue<Pending>, policy: BatchPolicy,
                   metrics: &ServingMetrics, cancel: &CancelToken,
                   params: &ParamsBuffer) {
    while !cancel.is_cancelled() || !queue.is_empty() {
        let Some(batch) = queue.pop_batch(policy) else { break };
        if batch.is_empty() {
            continue;
        }
        let bucket = buckets[batch[0].bucket];
        let now = Instant::now();
        for q in &batch {
            metrics
                .queue_latency
                .record(now.duration_since(q.enqueued));
        }
        // load is cached post-warmup; a miss only happens on new buckets
        let model = match engine.load(ArtifactKind::Encode, variant, bucket) {
            Ok(m) => m,
            Err(e) => {
                fail_batch(batch, &format!("load: {e}"));
                continue;
            }
        };
        let token_refs: Vec<&[i32]> =
            batch.iter().map(|q| q.item.tokens.as_slice()).collect();
        let plan = assemble(&token_refs, model.entry.batch, bucket);
        let real_tokens: u64 = token_refs.iter().map(|t| t.len() as u64).sum();
        metrics.tokens_processed.add(real_tokens);
        metrics.batch_slots.add(model.entry.batch as u64);
        // the artifact executes the whole dense capacity×bucket tensor,
        // so every non-real position is executed padding
        metrics
            .padded_tokens
            .add((model.entry.batch * bucket) as u64 - real_tokens);
        let t_exec = Instant::now();
        let result = model.encode(engine, &params.0, &plan.tokens);
        let exec_time = t_exec.elapsed();
        metrics.exec_latency.record(exec_time);
        metrics.batches_executed.inc();
        match result {
            Ok(flat) => {
                let d_model = flat.len() / model.entry.batch;
                let rows = scatter(&plan, &flat, d_model);
                let finish = Instant::now();
                for (q, emb) in batch.into_iter().zip(rows) {
                    metrics.requests_done.inc();
                    metrics
                        .e2e_latency
                        .record(finish.duration_since(q.enqueued));
                    let _ = q.item.tx.send(Response {
                        id: q.item.id,
                        embedding: Ok(emb),
                        queue_time: now.duration_since(q.enqueued),
                        exec_time,
                    });
                }
            }
            Err(e) => fail_batch(batch, &format!("execute: {e}")),
        }
    }
}

/// The CPU twin of [`worker_loop_xla`]: same pop → assemble → execute →
/// respond cycle, but the "artifact" is [`CpuEngine::encode_batch`]
/// running on the in-process kernel core. Batch capacity is the
/// configured `max_batch` (there is no artifact batch dimension to
/// match).
fn worker_loop_cpu(engine: &mut CpuEngine, capacity: usize, buckets: &[usize],
                   queue: &BucketQueue<Pending>, policy: BatchPolicy,
                   metrics: &ServingMetrics, cancel: &CancelToken) {
    while !cancel.is_cancelled() || !queue.is_empty() {
        let Some(batch) = queue.pop_batch(policy) else { break };
        if batch.is_empty() {
            continue;
        }
        let bucket = buckets[batch[0].bucket];
        let now = Instant::now();
        for q in &batch {
            metrics
                .queue_latency
                .record(now.duration_since(q.enqueued));
        }
        let token_refs: Vec<&[i32]> =
            batch.iter().map(|q| q.item.tokens.as_slice()).collect();
        let lens: Vec<usize> = token_refs.iter().map(|t| t.len()).collect();
        let plan = assemble(&token_refs, capacity, bucket);
        metrics
            .tokens_processed
            .add(lens.iter().map(|&l| l as u64).sum());
        metrics.batch_slots.add(capacity as u64);
        // CPU path skips padding rows entirely; only the
        // landmark-alignment tails are executed padding
        metrics.padded_tokens.add(engine.padded_positions(&lens));
        let t_exec = Instant::now();
        let rows = engine.encode_batch(&plan, &lens);
        let exec_time = t_exec.elapsed();
        metrics.exec_latency.record(exec_time);
        metrics.batches_executed.inc();
        let finish = Instant::now();
        for (q, emb) in batch.into_iter().zip(rows) {
            metrics.requests_done.inc();
            metrics
                .e2e_latency
                .record(finish.duration_since(q.enqueued));
            let _ = q.item.tx.send(Response {
                id: q.item.id,
                embedding: Ok(emb),
                queue_time: now.duration_since(q.enqueued),
                exec_time,
            });
        }
    }
}

fn fail_batch(batch: Vec<Queued<Pending>>, msg: &str) {
    for q in batch {
        let _ = q.item.tx.send(Response {
            id: q.item.id,
            embedding: Err(msg.to_string()),
            queue_time: Duration::ZERO,
            exec_time: Duration::ZERO,
        });
    }
}

#[cfg(test)]
mod tests {
    //! Coordinator logic that needs no execution engine is tested here;
    //! end-to-end CPU serving lives in
    //! `rust/tests/integration_cpu_serving.rs` and the artifact path in
    //! `rust/tests/integration_serving.rs`.

    use super::*;

    #[test]
    fn submit_error_semantics() {
        assert_eq!(SubmitError::QueueFull, SubmitError::QueueFull);
        let e = SubmitError::TooLong { len: 600, max: 512 };
        match e {
            SubmitError::TooLong { len, max } => {
                assert_eq!(len, 600);
                assert_eq!(max, 512);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn auto_backend_falls_back_to_cpu_without_artifacts() {
        let cfg = ServingConfig {
            artifacts_dir: "definitely/not/a/real/artifacts/dir".into(),
            ..Default::default()
        };
        let backend = ExecBackend::auto(&cfg);
        assert_eq!(backend.kind(), BackendKind::Cpu);
    }

    #[test]
    fn cpu_backend_rejects_misaligned_buckets() {
        let cfg = ServingConfig {
            seq_buckets: vec![100], // not divisible by the 16 landmarks
            ..Default::default()
        };
        let engine = Box::new(CpuEngine::new(CpuModel::new(
            CpuModelConfig::default(), Variant::SpectralShift)));
        assert!(Coordinator::start(ExecBackend::Cpu(engine), &cfg).is_err());
    }
}
