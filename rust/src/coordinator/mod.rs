//! The L3 coordinator (S14): router → bounded bucket queue → dynamic
//! batcher → PJRT execution, with metrics at every stage.
//!
//! Data path (python-free):
//!   submit(tokens) ──route──▶ BucketQueue ──pop_batch──▶ worker thread
//!     ──assemble──▶ encode artifact (PJRT) ──scatter──▶ response channel
//!
//! The paper's sec-9 deployment claim ("this method can reduce training
//! and inference time") is exercised by swapping the served attention
//! variant (full / nystrom / ss) while this coordinator stays fixed —
//! see the serving_throughput bench (E8).

pub mod batcher;
pub mod queue;
pub mod router;

pub use batcher::{assemble, scatter, BatchPlan};
pub use queue::{BatchPolicy, BucketQueue, PushError, Queued};
pub use router::{Route, Router};

use crate::config::{ServingConfig, Variant};
use crate::metrics::ServingMetrics;
use crate::minirt::CancelToken;
use crate::runtime::{ArtifactKind, Engine};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A completed request.
#[derive(Debug)]
pub struct Response {
    pub id: u64,
    /// pooled embedding (d_model floats) on success
    pub embedding: Result<Vec<f32>, String>,
    /// queue wait + execution time
    pub queue_time: Duration,
    pub exec_time: Duration,
}

struct Pending {
    id: u64,
    tokens: Vec<i32>,
    tx: mpsc::Sender<Response>,
}

/// Why admission failed.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    QueueFull,
    TooLong { len: usize, max: usize },
    Empty,
    ShuttingDown,
}

/// Shared device-resident parameter buffer.
struct ParamsBuffer(xla::PjRtBuffer);
unsafe impl Send for ParamsBuffer {}
unsafe impl Sync for ParamsBuffer {}

/// The serving coordinator. One worker thread per instance executes
/// batches; admission is lock-light and callers receive responses on
/// per-request channels.
pub struct Coordinator {
    router: Router,
    queue: Arc<BucketQueue<Pending>>,
    pub metrics: Arc<ServingMetrics>,
    cancel: CancelToken,
    worker: Option<std::thread::JoinHandle<()>>,
    next_id: std::sync::atomic::AtomicU64,
}

impl Coordinator {
    /// Build and start the coordinator: warms up (compiles) every
    /// encode artifact for the configured variant, uploads the
    /// parameters once, and spawns the batch-execution worker.
    pub fn start(engine: Arc<Engine>, cfg: &ServingConfig)
                 -> Result<Coordinator, crate::runtime::RuntimeError> {
        let buckets = engine.manifest().encode_buckets(cfg.variant);
        assert!(!buckets.is_empty(), "no encode artifacts for {:?}", cfg.variant);
        let router = Router::new(buckets.clone());
        let queue = Arc::new(BucketQueue::new(buckets.len(), cfg.queue_capacity));
        let metrics = Arc::new(ServingMetrics::new());
        let cancel = CancelToken::new();

        // preload executables + parameters
        engine.warmup(cfg.variant)?;
        let init = engine.init_params()?;
        let params = Arc::new(ParamsBuffer(
            engine.buffer_f32(&init, &[init.len()])?));

        let worker = {
            let queue = queue.clone();
            let metrics = metrics.clone();
            let cancel = cancel.clone();
            let engine = engine.clone();
            let variant = cfg.variant;
            let policy = BatchPolicy {
                max_batch: cfg.max_batch,
                max_wait: Duration::from_millis(cfg.max_wait_ms),
            };
            let buckets = buckets.clone();
            std::thread::Builder::new()
                .name("ssaformer-coordinator".into())
                .spawn(move || {
                    worker_loop(&engine, variant, &buckets, &queue, policy,
                                &metrics, &cancel, &params);
                })
                .expect("spawn coordinator worker")
        };

        Ok(Coordinator {
            router,
            queue,
            metrics,
            cancel,
            worker: Some(worker),
            next_id: std::sync::atomic::AtomicU64::new(0),
        })
    }

    /// Submit a request; returns the receiver for its response.
    pub fn submit(&self, tokens: Vec<i32>)
                  -> Result<mpsc::Receiver<Response>, SubmitError> {
        if self.cancel.is_cancelled() {
            return Err(SubmitError::ShuttingDown);
        }
        self.metrics.requests_in.inc();
        let bucket = match self.router.route(tokens.len()) {
            Route::Bucket(b) => b,
            Route::TooLong { len, max } => {
                self.metrics.requests_rejected.inc();
                return Err(SubmitError::TooLong { len, max });
            }
            Route::Empty => {
                self.metrics.requests_rejected.inc();
                return Err(SubmitError::Empty);
            }
        };
        let idx = self.router.bucket_index(bucket).unwrap();
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        match self.queue.push(idx, Pending { id, tokens, tx }) {
            Ok(()) => Ok(rx),
            Err(PushError::Full) => {
                self.metrics.requests_rejected.inc();
                Err(SubmitError::QueueFull)
            }
            Err(_) => Err(SubmitError::ShuttingDown),
        }
    }

    /// Convenience: submit and block for the response.
    pub fn submit_blocking(&self, tokens: Vec<i32>) -> Result<Response, SubmitError> {
        let rx = self.submit(tokens)?;
        rx.recv().map_err(|_| SubmitError::ShuttingDown)
    }

    /// Graceful shutdown: drain the queue, stop the worker.
    pub fn shutdown(mut self) {
        self.cancel.cancel();
        self.queue.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.cancel.cancel();
        self.queue.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(engine: &Engine, variant: Variant, buckets: &[usize],
               queue: &BucketQueue<Pending>, policy: BatchPolicy,
               metrics: &ServingMetrics, cancel: &CancelToken,
               params: &ParamsBuffer) {
    while !cancel.is_cancelled() || !queue.is_empty() {
        let Some(batch) = queue.pop_batch(policy) else { break };
        if batch.is_empty() {
            continue;
        }
        let bucket = buckets[batch[0].bucket];
        let now = Instant::now();
        for q in &batch {
            metrics
                .queue_latency
                .record(now.duration_since(q.enqueued));
        }
        // load is cached post-warmup; a miss only happens on new buckets
        let model = match engine.load(ArtifactKind::Encode, variant, bucket) {
            Ok(m) => m,
            Err(e) => {
                fail_batch(batch, &format!("load: {e}"));
                continue;
            }
        };
        let token_refs: Vec<&[i32]> =
            batch.iter().map(|q| q.item.tokens.as_slice()).collect();
        let plan = assemble(&token_refs, model.entry.batch, bucket);
        metrics
            .tokens_processed
            .add(token_refs.iter().map(|t| t.len() as u64).sum());
        let t_exec = Instant::now();
        let result = model.encode(engine, &params.0, &plan.tokens);
        let exec_time = t_exec.elapsed();
        metrics.exec_latency.record(exec_time);
        metrics.batches_executed.inc();
        match result {
            Ok(flat) => {
                let d_model = flat.len() / model.entry.batch;
                let rows = scatter(&plan, &flat, d_model);
                let finish = Instant::now();
                for (q, emb) in batch.into_iter().zip(rows) {
                    metrics.requests_done.inc();
                    metrics
                        .e2e_latency
                        .record(finish.duration_since(q.enqueued));
                    let _ = q.item.tx.send(Response {
                        id: q.item.id,
                        embedding: Ok(emb),
                        queue_time: now.duration_since(q.enqueued),
                        exec_time,
                    });
                }
            }
            Err(e) => fail_batch(batch, &format!("execute: {e}")),
        }
    }
}

fn fail_batch(batch: Vec<Queued<Pending>>, msg: &str) {
    for q in batch {
        let _ = q.item.tx.send(Response {
            id: q.item.id,
            embedding: Err(msg.to_string()),
            queue_time: Duration::ZERO,
            exec_time: Duration::ZERO,
        });
    }
}

#[cfg(test)]
mod tests {
    //! Coordinator logic that needs no PJRT engine is tested here;
    //! end-to-end serving over real artifacts lives in
    //! `rust/tests/integration_serving.rs`.

    use super::*;

    #[test]
    fn submit_error_semantics() {
        assert_eq!(SubmitError::QueueFull, SubmitError::QueueFull);
        let e = SubmitError::TooLong { len: 600, max: 512 };
        match e {
            SubmitError::TooLong { len, max } => {
                assert_eq!(len, 600);
                assert_eq!(max, 512);
            }
            _ => unreachable!(),
        }
    }
}
