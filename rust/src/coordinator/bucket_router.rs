//! Bucket router: snaps request lengths to artifact sequence buckets
//! and validates admissibility. The routing decision is pure (no locks)
//! so it is unit-testable in isolation.
//!
//! Not to be confused with the cluster *request* router
//! ([`cluster::ClusterRouter`](super::cluster::ClusterRouter)), which
//! consistent-hashes whole requests across replica processes — this
//! type picks a sequence bucket *within* one serving process.

use crate::workload::bucket_for;

/// Routing outcome for one request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    /// Request fits bucket with the given sequence length.
    Bucket(usize),
    /// Longer than every configured bucket.
    TooLong { len: usize, max: usize },
    /// Empty request.
    Empty,
}

/// Bucket router over a fixed ascending bucket list.
#[derive(Clone, Debug)]
pub struct BucketRouter {
    buckets: Vec<usize>,
}

impl BucketRouter {
    pub fn new(buckets: Vec<usize>) -> BucketRouter {
        assert!(!buckets.is_empty() && buckets.windows(2).all(|w| w[0] < w[1]),
                "buckets must be ascending and nonempty");
        BucketRouter { buckets }
    }

    pub fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    /// Route a token sequence of length `len`.
    pub fn route(&self, len: usize) -> Route {
        if len == 0 {
            return Route::Empty;
        }
        match bucket_for(len, &self.buckets) {
            Some(b) => Route::Bucket(b),
            None => Route::TooLong { len, max: *self.buckets.last().unwrap() },
        }
    }

    /// Index of a bucket in the configured list.
    pub fn bucket_index(&self, bucket: usize) -> Option<usize> {
        self.buckets.iter().position(|&b| b == bucket)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_to_smallest_fitting_bucket() {
        let r = BucketRouter::new(vec![128, 256, 512]);
        assert_eq!(r.route(1), Route::Bucket(128));
        assert_eq!(r.route(128), Route::Bucket(128));
        assert_eq!(r.route(129), Route::Bucket(256));
        assert_eq!(r.route(512), Route::Bucket(512));
    }

    #[test]
    fn rejects_out_of_range() {
        let r = BucketRouter::new(vec![128, 256]);
        assert_eq!(r.route(0), Route::Empty);
        assert_eq!(r.route(257), Route::TooLong { len: 257, max: 256 });
    }

    #[test]
    fn bucket_index() {
        let r = BucketRouter::new(vec![128, 256, 512]);
        assert_eq!(r.bucket_index(256), Some(1));
        assert_eq!(r.bucket_index(100), None);
    }

    #[test]
    #[should_panic]
    fn unsorted_buckets_panic() {
        BucketRouter::new(vec![256, 128]);
    }

    #[test]
    fn property_route_is_minimal_fitting() {
        crate::proptest_mini::run(200, |g| {
            let nb = g.usize_in(1, 4);
            let mut buckets: Vec<usize> = (0..nb)
                .map(|i| (i + 1) * g.usize_in(16, 64))
                .collect();
            buckets.sort_unstable();
            buckets.dedup();
            let r = BucketRouter::new(buckets.clone());
            let len = g.usize_in(1, 400);
            match r.route(len) {
                Route::Bucket(b) => {
                    crate::proptest_mini::prop_assert(
                        b >= len && buckets.contains(&b),
                        format!("bucket {b} < len {len}"))?;
                    // minimality: no smaller bucket fits
                    crate::proptest_mini::prop_assert(
                        buckets.iter().all(|&x| x >= b || x < len),
                        "not minimal")
                }
                Route::TooLong { .. } => crate::proptest_mini::prop_assert(
                    len > *buckets.last().unwrap(), "wrong TooLong"),
                Route::Empty => crate::proptest_mini::prop_assert(len == 0, "empty"),
            }
        });
    }
}
