//! Chunk-granular prefix-reuse cache for the streaming long-document
//! ENCODE path.
//!
//! The [`EmbeddingCache`](super::cache::EmbeddingCache) is keyed on
//! *whole* token sequences, so templated documents that share a long
//! prefix — chat transcripts with a common system prompt, boilerplate
//! report headers — recompute every layer from scratch as soon as one
//! suffix token differs. The chunked long-document path (see
//! `Coordinator::submit_chunked`) splits a document into fixed-size
//! independent chunks; [`PrefixCache`] memoizes the *pooled embedding
//! of each chunk*, keyed on chunk content, so a document sharing its
//! first k chunks with prior traffic only computes the tail.
//!
//! # Why chunk reuse is exact
//!
//! A bidirectional encoder's activations for a prefix depend on the
//! suffix — attention mixes every position with every other — so
//! reusing *intra-sequence* prefix activations would be approximate.
//! Chunks sidestep this: each chunk runs through the [`EncoderStack`]
//! (crate::model::EncoderStack) as its own independent sequence, so its
//! pooled embedding is a pure function of the chunk's tokens alone.
//! The document embedding is the length-weighted mean of the chunk
//! embeddings ([`merge_chunk_embeddings`]), accumulated in fixed chunk
//! order, so equal token streams merge to bitwise-equal results no
//! matter which chunks were cache hits. The coherence invariant of the
//! embedding cache therefore carries over verbatim: **a prefix-cache
//! hit is bitwise-identical to recomputing the chunk**
//! (`tests/integration_longdoc.rs` pins this end to end over TCP).
//!
//! # Keying
//!
//! Entries are keyed on the chunk's FNV-1a content hash
//! ([`hash_tokens`](super::cluster::hash_tokens) — the same keying the
//! cluster ring uses, deterministic across processes) with the chunk's
//! tokens stored alongside and compared on every hit. A 64-bit hash
//! collision is therefore a *miss*, never a wrong answer — the bitwise
//! invariant does not rest on hash uniqueness.

use super::cache::LruCache;
use super::cluster::hash_tokens;
use std::sync::{Arc, Mutex};

/// Thread-safe bounded LRU of pooled chunk embeddings, keyed on chunk
/// content. Shared by the admission path (lookups while splitting a
/// long document) and the chunk-completion path (inserts).
///
/// Values are `Arc<[f32]>`: a hit is a refcount bump, and the merge
/// loop reads the shared payload without copying.
///
/// ```
/// use ssaformer::coordinator::PrefixCache;
/// use std::sync::Arc;
/// let cache = PrefixCache::new(8);
/// let emb: Arc<[f32]> = Arc::from(&[0.5_f32, -2.0][..]);
/// assert!(cache.get(&[1, 2, 3]).is_none());
/// cache.insert(&[1, 2, 3], emb.clone());
/// // a hit shares the stored allocation — bitwise by construction
/// assert!(Arc::ptr_eq(&cache.get(&[1, 2, 3]).unwrap(), &emb));
/// assert!(cache.get(&[1, 2]).is_none());
/// assert_eq!((cache.len(), cache.capacity()), (1, 8));
/// ```
pub struct PrefixCache {
    inner: Mutex<LruCache<u64, (Box<[i32]>, Arc<[f32]>)>>,
}

impl PrefixCache {
    /// A cache bounded at `capacity` entries (must be > 0; the
    /// coordinator expresses `prefix_cache_capacity = 0` as the absence
    /// of a cache, mirroring the embedding cache).
    pub fn new(capacity: usize) -> Self {
        PrefixCache { inner: Mutex::new(LruCache::new(capacity)) }
    }

    /// The pooled embedding previously computed for exactly this chunk,
    /// if still resident. A hit refreshes recency and verifies the
    /// stored tokens — a hash collision reads as a miss.
    pub fn get(&self, chunk: &[i32]) -> Option<Arc<[f32]>> {
        let key = hash_tokens(chunk);
        let mut inner = self.inner.lock().unwrap();
        match inner.get(&key) {
            Some((stored, emb)) if stored.as_ref() == chunk => {
                Some(emb.clone())
            }
            _ => None,
        }
    }

    /// Record the pooled embedding for `chunk` (evicting the LRU entry
    /// when full). Re-inserting an existing chunk refreshes it —
    /// idempotent, since a recompute is bitwise identical. A colliding
    /// key is overwritten with the newer chunk: last-writer-wins is
    /// sound because `get` verifies tokens.
    pub fn insert(&self, chunk: &[i32], embedding: Arc<[f32]>) {
        let key = hash_tokens(chunk);
        let entry = (chunk.to_vec().into_boxed_slice(), embedding);
        self.inner.lock().unwrap().insert(key, entry);
    }

    /// Entries currently resident.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The configured entry bound.
    pub fn capacity(&self) -> usize {
        self.inner.lock().unwrap().capacity()
    }
}

/// Length-weighted mean of per-chunk pooled embeddings: the document
/// embedding a single mean-pool over all real tokens would produce if
/// every chunk had been encoded at its own length.
///
/// Each chunk's pooled row is its per-position mean over `len` real
/// tokens, so weighting by `len` and renormalizing by the total
/// recovers the whole-document pool of the chunk-staged activations.
/// Accumulation runs in fixed chunk order with a single f32 reciprocal
/// multiply at the end (the same rounding shape `CpuEngine::mean_pool`
/// uses), so the result is a deterministic function of the
/// `(len, embedding)` list alone — cache hits cannot perturb it.
///
/// # Panics
/// When `parts` is empty or the embeddings disagree on width.
pub fn merge_chunk_embeddings(parts: &[(usize, Arc<[f32]>)]) -> Vec<f32> {
    assert!(!parts.is_empty(), "merge of zero chunks");
    let d = parts[0].1.len();
    let total: usize = parts.iter().map(|(len, _)| *len).sum();
    let mut out = vec![0.0f32; d];
    for (len, emb) in parts {
        assert_eq!(emb.len(), d, "chunk embedding width mismatch");
        let w = *len as f32;
        for (o, v) in out.iter_mut().zip(emb.iter()) {
            *o += w * *v;
        }
    }
    let inv = 1.0 / total as f32;
    for o in out.iter_mut() {
        *o *= inv;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arc(v: &[f32]) -> Arc<[f32]> {
        Arc::from(v)
    }

    #[test]
    fn hit_is_the_stored_allocation_and_respects_recency() {
        let c = PrefixCache::new(2);
        let a = arc(&[1.0, 2.0]);
        c.insert(&[10, 11], a.clone());
        c.insert(&[20, 21], arc(&[3.0, 4.0]));
        let hit = c.get(&[10, 11]).unwrap(); // refreshes [10,11]
        assert!(Arc::ptr_eq(&hit, &a), "hit copied the payload");
        c.insert(&[30, 31], arc(&[5.0, 6.0])); // evicts [20,21]
        assert!(c.get(&[20, 21]).is_none());
        assert!(c.get(&[10, 11]).is_some());
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn keyed_on_content_not_length_or_order() {
        let c = PrefixCache::new(4);
        c.insert(&[1, 2, 3], arc(&[0.5]));
        assert!(c.get(&[1, 2]).is_none());
        assert!(c.get(&[3, 2, 1]).is_none());
        assert!(c.get(&[1, 2, 3, 0]).is_none());
        assert!(c.get(&[1, 2, 3]).is_some());
    }

    #[test]
    fn hash_collision_reads_as_miss_not_wrong_answer() {
        // Force a collision by inserting directly under the other
        // chunk's key: a real FNV-1a collision is not constructible by
        // hand, but the guard only sees (key, stored-tokens), so this
        // exercises the same path.
        let c = PrefixCache::new(4);
        let key = hash_tokens(&[7, 8, 9]);
        c.inner
            .lock()
            .unwrap()
            .insert(key, (vec![1, 1, 1].into_boxed_slice(), arc(&[9.0])));
        // lookup of [7,8,9] finds the slot but the stored tokens differ
        assert!(c.get(&[7, 8, 9]).is_none(),
                "collision must be a miss, never a wrong embedding");
    }

    #[test]
    fn reinsert_refreshes_idempotently() {
        let c = PrefixCache::new(2);
        c.insert(&[1], arc(&[1.0]));
        c.insert(&[2], arc(&[2.0]));
        c.insert(&[1], arc(&[1.0])); // refresh, not a growth
        assert_eq!(c.len(), 2);
        c.insert(&[3], arc(&[3.0])); // evicts [2], the LRU
        assert!(c.get(&[2]).is_none());
        assert!(c.get(&[1]).is_some());
    }

    #[test]
    fn merge_is_the_length_weighted_mean() {
        // two chunks of equal width: 3 tokens of [1,0], 1 token of [5,4]
        let parts = vec![(3usize, arc(&[1.0, 0.0])), (1, arc(&[5.0, 4.0]))];
        let merged = merge_chunk_embeddings(&parts);
        // (3·1 + 1·5)/4 = 2.0 ; (3·0 + 1·4)/4 = 1.0
        assert_eq!(merged, vec![2.0, 1.0]);
    }

    #[test]
    fn merge_of_one_chunk_is_bitwise_that_chunk() {
        // a single full-length chunk must round exactly like the
        // unchunked path: w·v · (1/w) with w = len both times
        let emb = arc(&[0.1, -3.25e-7, f32::MIN_POSITIVE, 42.0]);
        let merged = merge_chunk_embeddings(&[(128, emb.clone())]);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        // 128·v · (1/128) is exact (power-of-two scaling), and odd
        // lengths also round back: f32 round-trip of w·v/w at w well
        // inside the mantissa — pin the power-of-two case bitwise
        assert_eq!(bits(&merged), bits(&emb));
    }

    #[test]
    fn merge_is_deterministic_across_hit_patterns() {
        // the merge sees only (len, embedding) pairs — simulate "chunk
        // 0 was a hit" by cloning the Arc vs re-wrapping equal bits
        let a = arc(&[0.25, 0.5, -1.5]);
        let b = arc(&[1.0, -2.0, 3.0]);
        let cold = merge_chunk_embeddings(&[(64, a.clone()), (40, b.clone())]);
        let warm = merge_chunk_embeddings(
            &[(64, a.clone()), (40, arc(&[1.0, -2.0, 3.0]))]);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&cold), bits(&warm));
        let _ = b;
    }
}
