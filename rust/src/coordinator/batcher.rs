//! Batch assembly: turns a same-bucket group of requests into the dense
//! padded token tensor the encode artifact expects, and scatters
//! per-request results back out. Pure functions — no locks, no I/O —
//! so the padding/scatter invariants are property-testable.

use crate::text::PAD;

/// A request's tokens plus its slot in the assembled batch.
pub struct BatchPlan {
    /// artifact batch capacity (rows)
    pub capacity: usize,
    /// bucket sequence length (columns)
    pub seq: usize,
    /// number of real requests (≤ capacity); rows beyond are padding
    pub fill: usize,
    /// row-major (capacity × seq) token tensor
    pub tokens: Vec<i32>,
}

/// Assemble a padded batch. Requests longer than `seq` are a caller bug
/// (the router must have bucketed them) and panic in debug builds.
pub fn assemble(requests: &[&[i32]], capacity: usize, seq: usize) -> BatchPlan {
    assert!(requests.len() <= capacity,
            "{} requests > batch capacity {capacity}", requests.len());
    let mut tokens = vec![PAD; capacity * seq];
    for (row, toks) in requests.iter().enumerate() {
        debug_assert!(toks.len() <= seq, "request longer than bucket");
        let take = toks.len().min(seq);
        tokens[row * seq..row * seq + take].copy_from_slice(&toks[..take]);
    }
    BatchPlan { capacity, seq, fill: requests.len(), tokens }
}

/// Split the artifact's (capacity × width) output into per-request rows,
/// dropping padding rows.
pub fn scatter(plan: &BatchPlan, output: &[f32], width: usize) -> Vec<Vec<f32>> {
    assert_eq!(output.len(), plan.capacity * width,
               "output len {} != capacity {} × width {width}",
               output.len(), plan.capacity);
    (0..plan.fill)
        .map(|row| output[row * width..(row + 1) * width].to_vec())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pads_rows_and_tail() {
        let r1 = vec![5, 6, 7];
        let r2 = vec![8];
        let plan = assemble(&[&r1, &r2], 4, 5);
        assert_eq!(plan.fill, 2);
        assert_eq!(&plan.tokens[0..5], &[5, 6, 7, PAD, PAD]);
        assert_eq!(&plan.tokens[5..10], &[8, PAD, PAD, PAD, PAD]);
        // padding rows all PAD
        assert!(plan.tokens[10..].iter().all(|&t| t == PAD));
    }

    #[test]
    fn scatter_drops_padding_rows() {
        let r1 = vec![1, 2];
        let plan = assemble(&[&r1], 3, 2);
        let out: Vec<f32> = (0..3 * 4).map(|i| i as f32).collect();
        let rows = scatter(&plan, &out, 4);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0], vec![0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic]
    fn overfull_batch_panics() {
        let r = vec![1];
        assemble(&[&r, &r, &r], 2, 4);
    }

    #[test]
    fn property_assemble_scatter_roundtrip() {
        crate::proptest_mini::run(100, |g| {
            let cap = g.usize_in(1, 8);
            let seq = g.usize_in(1, 32);
            let fill = g.usize_in(0, cap);
            let reqs: Vec<Vec<i32>> = (0..fill)
                .map(|_| {
                    let len = g.usize_in(1, seq);
                    (0..len).map(|i| 3 + (i as i32 % 50)).collect()
                })
                .collect();
            let refs: Vec<&[i32]> = reqs.iter().map(|r| r.as_slice()).collect();
            let plan = assemble(&refs, cap, seq);
            crate::proptest_mini::prop_assert(
                plan.tokens.len() == cap * seq, "tensor size")?;
            // every request's tokens appear verbatim at its row
            for (row, r) in reqs.iter().enumerate() {
                let slice = &plan.tokens[row * seq..row * seq + r.len()];
                crate::proptest_mini::prop_assert(
                    slice == r.as_slice(), format!("row {row} corrupted"))?;
                // remainder of the row is PAD
                crate::proptest_mini::prop_assert(
                    plan.tokens[row * seq + r.len()..(row + 1) * seq]
                        .iter()
                        .all(|&t| t == PAD),
                    "row tail not padded")?;
            }
            // scatter returns exactly fill rows of the right width
            let width = g.usize_in(1, 16);
            let out: Vec<f32> = (0..cap * width).map(|i| i as f32).collect();
            let rows = scatter(&plan, &out, width);
            crate::proptest_mini::prop_assert(rows.len() == plan.fill, "fill")?;
            for (i, r) in rows.iter().enumerate() {
                crate::proptest_mini::prop_assert(
                    r.as_slice() == &out[i * width..(i + 1) * width],
                    "scatter row")?;
            }
            Ok(())
        });
    }
}
