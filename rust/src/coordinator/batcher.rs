//! Batch assembly: turns a same-bucket group of requests into the dense
//! padded token tensor the encode artifact expects, and scatters
//! per-request results back out. Pure functions — no locks, no I/O —
//! so the padding/scatter invariants are property-testable.
//!
//! [`attention_scatter`] is the CPU execution twin of `scatter`: it
//! takes an assembled plan plus stacked q/k/v activations and executes
//! every real request's multi-head attention on the `kernels::` core,
//! heads × requests in parallel over the shared pool — a popped batch
//! no longer runs its requests serially.
//!
//! Batch *formation* (when a bucket's lane closes: full, aged, or
//! deadline-pressed) lives upstream in
//! [`queue`](super::queue::BatchPolicy); by the time a worker calls
//! [`assemble`] the batch is final and already stripped of expired
//! requests, so everything in this module stays pure per-batch
//! shuffling.

use crate::attention::Tensor2;
use crate::kernels::{attention_batched, BatchedAttention};
use crate::model::AttentionOp;
use crate::text::PAD;

/// The one place landmark alignment is computed: the execution length of
/// a `len`-token request under an operator with `divisor = Some(c)` is
/// `len` rounded up to the next multiple of c (segment-means landmarks
/// need divisibility); divisor-free operators execute at `len` exactly.
/// `CpuModel::padded_len`, the padding-waste metric, and the encoder
/// stack all route through this helper so the serving model can never
/// drift from the batcher's notion of alignment.
pub fn aligned_len(len: usize, divisor: Option<usize>) -> usize {
    match divisor {
        Some(c) => {
            assert!(c > 0, "landmark divisor must be positive");
            (len + c - 1) / c * c
        }
        None => len,
    }
}

/// A request's tokens plus its slot in the assembled batch.
pub struct BatchPlan {
    /// artifact batch capacity (rows)
    pub capacity: usize,
    /// bucket sequence length (columns)
    pub seq: usize,
    /// number of real requests (≤ capacity); rows beyond are padding
    pub fill: usize,
    /// row-major (capacity × seq) token tensor
    pub tokens: Vec<i32>,
}

/// Assemble a padded batch. Requests longer than `seq` are a caller bug
/// (the router must have bucketed them) and panic in debug builds.
pub fn assemble(requests: &[&[i32]], capacity: usize, seq: usize) -> BatchPlan {
    assert!(requests.len() <= capacity,
            "{} requests > batch capacity {capacity}", requests.len());
    let mut tokens = vec![PAD; capacity * seq];
    for (row, toks) in requests.iter().enumerate() {
        debug_assert!(toks.len() <= seq, "request longer than bucket");
        let take = toks.len().min(seq);
        tokens[row * seq..row * seq + take].copy_from_slice(&toks[..take]);
    }
    BatchPlan { capacity, seq, fill: requests.len(), tokens }
}

/// Split the artifact's (capacity × width) output into per-request rows,
/// dropping padding rows.
pub fn scatter(plan: &BatchPlan, output: &[f32], width: usize) -> Vec<Vec<f32>> {
    assert_eq!(output.len(), plan.capacity * width,
               "output len {} != capacity {} × width {width}",
               output.len(), plan.capacity);
    (0..plan.fill)
        .map(|row| output[row * width..(row + 1) * width].to_vec())
        .collect()
}

/// Execute per-request self-attention for an assembled batch on the CPU
/// kernel core. `q`/`k`/`v` are row-major (seq × d)-per-request stacks
/// aligned with the plan's rows and covering at least the `fill` real
/// requests (capacity-sized stacks also accepted — slots past `fill`
/// are never read); `lens[r]` is request r's *execution* length
/// (1..=`plan.seq`): exactly how many leading positions of its slot
/// participate in attention. Padding *requests* (rows beyond `fill`)
/// never execute, and positions past `lens[r]` are excluded from the
/// request's q/k/v entirely. Callers choose what `lens` means: the real
/// token count gives attention over real keys only, while
/// `cpu_engine::CpuEngine` passes landmark-*aligned* lengths, whose
/// short PAD-embedding tail does participate in attention (counted by
/// the `padded_tokens` metric). All heads of all requests fan out over
/// the kernel pool in parallel. Returns one (lens\[r\] × d) output per
/// real request, in order — padding rows dropped exactly as in
/// [`scatter`].
///
/// For the landmark variants (`Nystrom` / `SpectralShift`) every
/// `lens[r]` must be divisible by the landmark count — which is why the
/// CPU engine aligns them (the artifact path gets the same guarantee
/// from its bucket shapes).
pub fn attention_scatter(exec: &mut BatchedAttention, plan: &BatchPlan,
                         q: &[f32], k: &[f32], v: &[f32], d: usize,
                         lens: &[usize], n_heads: usize,
                         op: &dyn AttentionOp) -> Vec<Tensor2> {
    let per_req = plan.seq * d;
    assert!(q.len() >= plan.fill * per_req,
            "q len {} < fill {} × seq {} × d {d}",
            q.len(), plan.fill, plan.seq);
    assert_eq!(k.len(), q.len(), "k/q length mismatch");
    assert_eq!(v.len(), q.len(), "v/q length mismatch");
    assert_eq!(lens.len(), plan.fill, "one length per real request");
    let reqs: Vec<(Tensor2, Tensor2, Tensor2)> = (0..plan.fill)
        .map(|r| {
            let len = lens[r];
            assert!(len > 0 && len <= plan.seq,
                    "request {r} length {len} outside 1..={}", plan.seq);
            let mut slice = |buf: &[f32]| {
                let mut data = exec.scratch().take(len * d);
                data.copy_from_slice(&buf[r * per_req..r * per_req + len * d]);
                Tensor2 { rows: len, cols: d, data }
            };
            (slice(q), slice(k), slice(v))
        })
        .collect();
    let outs = attention_batched(exec, &reqs, n_heads, op);
    for (rq, rk, rv) in reqs {
        exec.scratch().put(rq.data);
        exec.scratch().put(rk.data);
        exec.scratch().put(rv.data);
    }
    outs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligned_len_rounds_up_only_under_a_divisor() {
        // divisor-free ops execute at the exact length
        assert_eq!(aligned_len(0, None), 0);
        assert_eq!(aligned_len(17, None), 17);
        // landmark ops round up to the next multiple
        assert_eq!(aligned_len(1, Some(16)), 16);
        assert_eq!(aligned_len(16, Some(16)), 16);
        assert_eq!(aligned_len(17, Some(16)), 32);
        assert_eq!(aligned_len(112, Some(16)), 112);
        assert_eq!(aligned_len(0, Some(16)), 0);
        // property: smallest multiple of c that is >= len
        for len in 0..200usize {
            for c in [1usize, 3, 16, 64] {
                let a = aligned_len(len, Some(c));
                assert!(a >= len && a % c == 0 && a < len + c,
                        "len {len} c {c} -> {a}");
            }
        }
    }

    #[test]
    #[should_panic]
    fn aligned_len_rejects_zero_divisor() {
        aligned_len(5, Some(0));
    }

    #[test]
    fn pads_rows_and_tail() {
        let r1 = vec![5, 6, 7];
        let r2 = vec![8];
        let plan = assemble(&[&r1, &r2], 4, 5);
        assert_eq!(plan.fill, 2);
        assert_eq!(&plan.tokens[0..5], &[5, 6, 7, PAD, PAD]);
        assert_eq!(&plan.tokens[5..10], &[8, PAD, PAD, PAD, PAD]);
        // padding rows all PAD
        assert!(plan.tokens[10..].iter().all(|&t| t == PAD));
    }

    #[test]
    fn scatter_drops_padding_rows() {
        let r1 = vec![1, 2];
        let plan = assemble(&[&r1], 3, 2);
        let out: Vec<f32> = (0..3 * 4).map(|i| i as f32).collect();
        let rows = scatter(&plan, &out, 4);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0], vec![0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic]
    fn overfull_batch_panics() {
        let r = vec![1];
        assemble(&[&r, &r, &r], 2, 4);
    }

    #[test]
    fn attention_scatter_skips_padding_and_matches_serial() {
        use crate::kernels::{flash_attention, KernelCtx, Workspace};
        let mut rng = crate::rngx::Rng::new(21);
        let (cap, seq, d, heads) = (4usize, 32usize, 8usize, 2usize);
        // request 0 fills its bucket, request 1 is short (padded tail)
        let lens = [seq, 24usize];
        let fill = lens.len();
        let mut q = vec![0.0f32; cap * seq * d];
        let mut k = vec![0.0f32; cap * seq * d];
        let mut v = vec![0.0f32; cap * seq * d];
        // fill the real positions; poison every padded position — the
        // tail of the short request AND the padding requests — with
        // huge values that would corrupt the result if ever touched
        for buf in [&mut q, &mut k, &mut v] {
            for x in buf.iter_mut() {
                *x = 1e30;
            }
            for (r, &len) in lens.iter().enumerate() {
                rng.fill_normal_f32(
                    &mut buf[r * seq * d..r * seq * d + len * d], 0.0, 1.0);
            }
        }
        let toks: Vec<Vec<i32>> = lens.iter().map(|&l| vec![5; l]).collect();
        let refs: Vec<&[i32]> = toks.iter().map(|t| t.as_slice()).collect();
        let plan = assemble(&refs, cap, seq);
        let mut exec = BatchedAttention::new(KernelCtx::global());
        let outs = attention_scatter(&mut exec, &plan, &q, &k, &v, d, &lens,
                                     heads, &crate::kernels::BatchedVariant::Full);
        assert_eq!(outs.len(), fill);
        // per-request, per-head serial reference over the real positions
        let mut ws = Workspace::new();
        for (r, out) in outs.iter().enumerate() {
            let len = lens[r];
            assert_eq!((out.rows, out.cols), (len, d));
            assert!(out.data.iter().all(|x| x.is_finite()),
                    "padding leaked into request {r}");
            let dh = d / heads;
            let base = r * seq * d;
            for h in 0..heads {
                let col0 = h * dh;
                let mut qh = Tensor2::zeros(len, dh);
                let mut kh = Tensor2::zeros(len, dh);
                let mut vh = Tensor2::zeros(len, dh);
                for i in 0..len {
                    for j in 0..dh {
                        qh.data[i * dh + j] = q[base + i * d + col0 + j];
                        kh.data[i * dh + j] = k[base + i * d + col0 + j];
                        vh.data[i * dh + j] = v[base + i * d + col0 + j];
                    }
                }
                let want = flash_attention(
                    &KernelCtx::sequential(), &qh, &kh, &vh,
                    crate::attention::default_scale(dh), &mut ws);
                for i in 0..len {
                    assert_eq!(&out.row(i)[col0..col0 + dh], want.row(i),
                               "req {r} head {h} row {i}");
                }
            }
        }
    }

    #[test]
    fn property_assemble_scatter_roundtrip() {
        crate::proptest_mini::run(100, |g| {
            let cap = g.usize_in(1, 8);
            let seq = g.usize_in(1, 32);
            let fill = g.usize_in(0, cap);
            let reqs: Vec<Vec<i32>> = (0..fill)
                .map(|_| {
                    let len = g.usize_in(1, seq);
                    (0..len).map(|i| 3 + (i as i32 % 50)).collect()
                })
                .collect();
            let refs: Vec<&[i32]> = reqs.iter().map(|r| r.as_slice()).collect();
            let plan = assemble(&refs, cap, seq);
            crate::proptest_mini::prop_assert(
                plan.tokens.len() == cap * seq, "tensor size")?;
            // every request's tokens appear verbatim at its row
            for (row, r) in reqs.iter().enumerate() {
                let slice = &plan.tokens[row * seq..row * seq + r.len()];
                crate::proptest_mini::prop_assert(
                    slice == r.as_slice(), format!("row {row} corrupted"))?;
                // remainder of the row is PAD
                crate::proptest_mini::prop_assert(
                    plan.tokens[row * seq + r.len()..(row + 1) * seq]
                        .iter()
                        .all(|&t| t == PAD),
                    "row tail not padded")?;
            }
            // scatter returns exactly fill rows of the right width
            let width = g.usize_in(1, 16);
            let out: Vec<f32> = (0..cap * width).map(|i| i as f32).collect();
            let rows = scatter(&plan, &out, width);
            crate::proptest_mini::prop_assert(rows.len() == plan.fill, "fill")?;
            for (i, r) in rows.iter().enumerate() {
                crate::proptest_mini::prop_assert(
                    r.as_slice() == &out[i * width..(i + 1) * width],
                    "scatter row")?;
            }
            Ok(())
        });
    }
}
