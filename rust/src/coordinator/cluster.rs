//! Cluster serving tier: a router front-end that consistent-hashes
//! ENCODE requests across N replica serving processes over the existing
//! line protocol ([`server`](crate::server)).
//!
//! ```text
//!              clients (same wire protocol as a replica)
//!                 │
//!                 ▼
//!   ┌──────────── router process (`--role router`) ────────────┐
//!   │ parse ──▶ router cache ──hit──▶ reply (bitwise recompute) │
//!   │             │ miss                                        │
//!   │             ▼                                             │
//!   │ deadline gate (expired → ERR deadline, no replica I/O)    │
//!   │             ▼                                             │
//!   │ HashRing.preferences(fnv1a64(tokens)) ──▶ try replicas    │
//!   │     in order: reconnect-once → failover → ERR replica-lost│
//!   └──────┬───────────────┬───────────────┬───────────────────┘
//!          ▼               ▼               ▼
//!      replica 0       replica 1  ...  replica N-1
//!      (`--role replica` = today's single-process server)
//! ```
//!
//! # Invariants
//!
//! * **Drain/handoff — no silent drops.** Once the router accepts an
//!   ENCODE line, the request is either answered by a replica (possibly
//!   after reconnects and failovers to later ring preferences) or
//!   answered `ERR <id> replica-lost`. The accounting identity
//!   `forwarded = replica-answered + replica-lost` is load-bearing and
//!   asserted by `tests/integration_cluster.rs`.
//! * **At-least-once forwarding is safe.** A replica that dies after
//!   executing but before replying may leave a duplicate execution
//!   behind when the router retries elsewhere. That is harmless:
//!   encoding is a pure deterministic function of the token sequence
//!   (the coordinator's cache-coherence invariant), so duplicates
//!   produce bitwise-identical embeddings and at-least-once semantics
//!   need no dedup protocol.
//! * **A hit anywhere is bitwise a recompute.** The router cache is
//!   keyed identically to [`cache::EmbeddingCache`](super::cache) —
//!   the full parsed token sequence — and stores the replica's `OK`
//!   payload text. Because the wire format (`%.5f`) is itself a
//!   deterministic function of the embedding, replaying the cached
//!   payload is byte-identical to re-asking any replica. Requests
//!   carrying non-deadline options (e.g. `ACCURACY=`) bypass the
//!   router cache in both directions — a tier-routed reply is *not* a
//!   recompute of the default tier — and their options are forwarded
//!   verbatim ([`WireOptions::render_extras`](crate::server::options::WireOptions::render_extras)),
//!   so the replica's admission policy, not the router, decides the
//!   tier.
//! * **Deterministic placement.** Keys are FNV-1a 64 hashes (fixed
//!   offset/prime — unlike `std`'s randomly keyed SipHash) so the ring
//!   assigns identically in every process; tests rebuild the ring to
//!   predict placement, and a router restart preserves it.
//! * **Backpressure-aware placement.** Replica `PING` replies carry
//!   the coordinator's instantaneous queue depth (`OK 0 pong q=<n>`);
//!   each probe sweep records it in the membership table. When the ring
//!   owner was strictly more loaded than the runner-up at the last
//!   probe, the router swaps the top two *up* candidates — requests
//!   shed from a saturated replica to its first failover instead of
//!   queueing behind it. Only the top-2 order changes: every replica
//!   stays in the failover list, so the no-silent-drop invariant is
//!   untouched, and equal loads (including the fresh all-zero state)
//!   leave ring order intact.
//! * **Deadline honesty across the hop.** `DEADLINE_MS` is forwarded
//!   minus the time already spent in the router; a budget that reaches
//!   zero at the router is answered `ERR <id> deadline` without
//!   touching a replica (mirroring the replica's own
//!   zero-budget-expires-at-admission rule).
//!
//! Fault tolerance is exercised by the deterministic
//! [`FaultPlan`](crate::server::FaultPlan) seam on the replica side.

use crate::metrics::RouterMetrics;
use crate::minirt::{CancelToken, ThreadPool};
use crate::server::options::parse_options;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Virtual nodes per replica on the hash ring. 128 points per replica
/// keeps the load spread within ~2× of uniform for small clusters
/// (pinned by a property test) while ring build stays trivially cheap.
pub const DEFAULT_VNODES: usize = 128;

/// FNV-1a 64-bit. Chosen over `std`'s `DefaultHasher` because SipHash
/// is randomly keyed per process — useless for a ring that must assign
/// identically on the router, in tests, and across restarts.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// Ring key for a token sequence: FNV-1a over the little-endian token
/// bytes. Same tokens → same key in every process.
pub fn hash_tokens(tokens: &[i32]) -> u64 {
    let mut bytes = Vec::with_capacity(tokens.len() * 4);
    for t in tokens {
        bytes.extend_from_slice(&t.to_le_bytes());
    }
    fnv1a64(&bytes)
}

/// Consistent-hash ring over named replicas with virtual nodes.
///
/// Each replica contributes `vnodes` points at `fnv1a64("{name}#{v}")`;
/// a key is assigned to the replica owning the first point clockwise of
/// it. Adding a replica only *inserts* points (keys move only **to**
/// it); removing one only deletes its points (keys move only **from**
/// it) — the minimal-movement property the join/leave property tests
/// pin down.
#[derive(Clone, Debug)]
pub struct HashRing {
    /// (ring position, replica index), sorted by position.
    points: Vec<(u64, usize)>,
    n_replicas: usize,
}

impl HashRing {
    /// Build the ring. `names` must be nonempty; replica indices in
    /// [`assign`](HashRing::assign) refer to positions in `names`.
    pub fn build(names: &[String], vnodes: usize) -> HashRing {
        assert!(!names.is_empty(), "ring needs at least one replica");
        assert!(vnodes > 0, "ring needs at least one virtual node");
        let mut points = Vec::with_capacity(names.len() * vnodes);
        for (i, name) in names.iter().enumerate() {
            for v in 0..vnodes {
                points.push((fnv1a64(format!("{name}#{v}").as_bytes()), i));
            }
        }
        // position ties (astronomically unlikely) resolve by replica
        // index so the ring is still a pure function of `names`
        points.sort_unstable();
        HashRing { points, n_replicas: names.len() }
    }

    pub fn replicas(&self) -> usize {
        self.n_replicas
    }

    pub fn vnode_points(&self) -> usize {
        self.points.len()
    }

    /// The replica owning `key`: first ring point clockwise of it,
    /// wrapping at the top.
    pub fn assign(&self, key: u64) -> usize {
        let i = self.points.partition_point(|&(p, _)| p < key);
        self.points[i % self.points.len()].1
    }

    /// Failover order for `key`: every replica exactly once, starting
    /// with the owner and continuing clockwise by first appearance.
    /// Deterministic, so retry behavior is replayable.
    pub fn preferences(&self, key: u64) -> Vec<usize> {
        let start = self.points.partition_point(|&(p, _)| p < key);
        let mut order = Vec::with_capacity(self.n_replicas);
        let mut seen = vec![false; self.n_replicas];
        for off in 0..self.points.len() {
            let r = self.points[(start + off) % self.points.len()].1;
            if !seen[r] {
                seen[r] = true;
                order.push(r);
                if order.len() == self.n_replicas {
                    break;
                }
            }
        }
        order
    }
}

/// Replica membership table: addresses plus lock-free up/down flags,
/// written by the health prober and by forwarding failures, read by the
/// forwarding path and the STATS report.
pub struct Membership {
    addrs: Vec<String>,
    up: Vec<AtomicBool>,
    /// Queue depth each replica reported in its last `PING` reply
    /// (`q=` suffix) — the backpressure signal placement reads. Zero
    /// until the first probe parses one, so a fresh router places by
    /// pure ring order.
    load: Vec<AtomicU64>,
}

impl Membership {
    pub fn new(addrs: Vec<String>) -> Membership {
        // optimistic start: every replica is presumed up until a probe
        // or a forwarding failure says otherwise, so a router can serve
        // before its first probe sweep completes
        let up = addrs.iter().map(|_| AtomicBool::new(true)).collect();
        let load = addrs.iter().map(|_| AtomicU64::new(0)).collect();
        Membership { addrs, up, load }
    }

    pub fn len(&self) -> usize {
        self.addrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.addrs.is_empty()
    }

    pub fn addr(&self, i: usize) -> &str {
        &self.addrs[i]
    }

    pub fn addrs(&self) -> &[String] {
        &self.addrs
    }

    pub fn is_up(&self, i: usize) -> bool {
        self.up[i].load(Ordering::Relaxed)
    }

    pub fn set_up(&self, i: usize, up: bool) {
        self.up[i].store(up, Ordering::Relaxed);
    }

    pub fn up_count(&self) -> usize {
        self.up.iter().filter(|u| u.load(Ordering::Relaxed)).count()
    }

    /// The queue depth replica `i` reported at its last probe.
    pub fn load(&self, i: usize) -> u64 {
        self.load[i].load(Ordering::Relaxed)
    }

    pub fn set_load(&self, i: usize, depth: u64) {
        self.load[i].store(depth, Ordering::Relaxed);
    }

    /// `(addr, up)` snapshot for the STATS membership lines.
    pub fn snapshot(&self) -> Vec<(String, bool)> {
        self.addrs
            .iter()
            .cloned()
            .zip(self.up.iter().map(|u| u.load(Ordering::Relaxed)))
            .collect()
    }
}

/// Router construction knobs (CLI/config mapping in `main.rs` and
/// `OPERATIONS.md`).
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Replica addresses (`host:port`), the ring's identity — order
    /// matters only for replica *indices*, not placement.
    pub replicas: Vec<String>,
    /// Health-probe sweep period.
    pub probe_interval: Duration,
    /// Router-side reply cache entries (0 disables).
    pub cache_capacity: usize,
    /// Virtual nodes per replica.
    pub vnodes: usize,
    /// Per-attempt TCP connect budget.
    pub connect_timeout: Duration,
    /// Per-attempt reply budget (read timeout on replica connections) —
    /// bounds how long a dead-but-connected replica can stall one
    /// forwarding attempt.
    pub reply_timeout: Duration,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            replicas: Vec::new(),
            probe_interval: Duration::from_millis(500),
            cache_capacity: 1024,
            vnodes: DEFAULT_VNODES,
            connect_timeout: Duration::from_millis(500),
            reply_timeout: Duration::from_secs(10),
        }
    }
}

/// One pooled connection to a replica. Line-oriented, blocking, with
/// connect/read timeouts from [`ClusterConfig`].
struct ReplicaConn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl ReplicaConn {
    fn connect(addr: &str, cfg: &ClusterConfig) -> std::io::Result<ReplicaConn> {
        let sock: SocketAddr = addr
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                format!("unresolvable replica address {addr}")))?;
        let stream = TcpStream::connect_timeout(&sock, cfg.connect_timeout)?;
        stream.set_read_timeout(Some(cfg.reply_timeout))?;
        stream.set_write_timeout(Some(cfg.reply_timeout))?;
        stream.set_nodelay(true).ok();
        Ok(ReplicaConn {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    /// One request line out, one reply line back. A closed or
    /// mid-line-truncated connection (the FaultPlan kill) surfaces as
    /// `UnexpectedEof`.
    fn roundtrip(&mut self, line: &str) -> std::io::Result<String> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut reply = String::new();
        let n = self.reader.read_line(&mut reply)?;
        if n == 0 || !reply.ends_with('\n') {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "replica connection closed mid-reply"));
        }
        Ok(reply.trim_end().to_string())
    }
}

/// The cluster request router. Owns the ring, the membership table, the
/// reply cache, and the router metrics; per-connection replica pools
/// live in the connection handlers (no global connection lock).
pub struct ClusterRouter {
    cfg: ClusterConfig,
    ring: HashRing,
    membership: Membership,
    cache: Option<Mutex<super::LruCache<Box<[i32]>, String>>>,
    pub metrics: Arc<RouterMetrics>,
}

impl ClusterRouter {
    /// Build a router over `cfg.replicas`. Panics on an empty replica
    /// list — `config::validate` rejects that long before here.
    pub fn new(cfg: ClusterConfig) -> ClusterRouter {
        assert!(!cfg.replicas.is_empty(), "router needs at least one replica");
        let ring = HashRing::build(&cfg.replicas, cfg.vnodes.max(1));
        let membership = Membership::new(cfg.replicas.clone());
        let cache = match cfg.cache_capacity {
            0 => None,
            n => Some(Mutex::new(super::LruCache::new(n))),
        };
        ClusterRouter {
            cfg,
            ring,
            membership,
            cache,
            metrics: Arc::new(RouterMetrics::new()),
        }
    }

    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    pub fn membership(&self) -> &Membership {
        &self.membership
    }

    pub fn ring(&self) -> &HashRing {
        &self.ring
    }

    /// Router-cache entries currently resident.
    pub fn cache_len(&self) -> usize {
        self.cache
            .as_ref()
            .map_or(0, |c| c.lock().expect("router cache lock").len())
    }

    /// One synchronous health sweep: round-trip `PING` to every
    /// replica, flip its up/down flag on the outcome and record the
    /// queue depth its pong reported. The background prober calls this
    /// on its interval; tests call it directly so membership
    /// transitions are deterministic, not timing-dependent.
    pub fn probe_now(&self) {
        for i in 0..self.membership.len() {
            let reply = ReplicaConn::connect(self.membership.addr(i), &self.cfg)
                .and_then(|mut c| c.roundtrip("PING"));
            let healthy = reply
                .as_ref()
                .map(|r| r.starts_with("OK"))
                .unwrap_or(false);
            if !healthy {
                self.metrics.probe_failures.inc();
            }
            // a pong without the q= suffix (an older replica) or a
            // failed probe reads as load 0 — placement degrades to pure
            // ring order, never an error
            let depth = reply
                .ok()
                .and_then(|r| parse_queue_depth(&r))
                .unwrap_or(0);
            self.membership.set_load(i, depth);
            self.membership.set_up(i, healthy);
        }
    }

    /// Failover order for a token sequence: ring preferences with the
    /// replicas currently marked up moved to the front (ring order
    /// preserved within each group). Down replicas stay as a last
    /// resort — probe state may be stale, and trying them beats
    /// reporting a loss.
    ///
    /// Backpressure-aware placement: when the first up candidate was
    /// *strictly* more loaded than the second at the last probe sweep,
    /// the two swap — the request sheds to the runner-up instead of
    /// queueing behind a saturated owner. Strict comparison keeps ties
    /// (and the fresh all-zero state) in ring order, so placement only
    /// deviates on a measured imbalance, and only the top-2 order ever
    /// changes — the failover set is untouched.
    fn candidates(&self, tokens: &[i32]) -> Vec<usize> {
        let prefs = self.ring.preferences(hash_tokens(tokens));
        let (mut up, down): (Vec<usize>, Vec<usize>) =
            prefs.into_iter().partition(|&r| self.membership.is_up(r));
        if up.len() >= 2
            && self.membership.load(up[0]) > self.membership.load(up[1])
        {
            up.swap(0, 1);
        }
        up.extend(down);
        up
    }

    fn cache_get(&self, tokens: &[i32]) -> Option<String> {
        let cache = self.cache.as_ref()?;
        cache.lock().expect("router cache lock").get(tokens).cloned()
    }

    fn cache_put(&self, tokens: &[i32], payload: String) {
        if let Some(cache) = &self.cache {
            cache
                .lock()
                .expect("router cache lock")
                .insert(tokens.to_vec().into_boxed_slice(), payload);
        }
    }

    /// The `cluster:` membership lines of the router STATS report (the
    /// counter lines come from [`RouterMetrics::report`]).
    fn membership_report(&self) -> String {
        let snap = self.membership.snapshot();
        let up = snap.iter().filter(|(_, u)| *u).count();
        let mut out = format!(
            "cluster:  replicas={} up={} down={} vnodes={} probe-interval={}ms",
            snap.len(),
            up,
            snap.len() - up,
            self.cfg.vnodes,
            self.cfg.probe_interval.as_millis());
        for (i, (addr, alive)) in snap.into_iter().enumerate() {
            out.push_str(&format!(
                "\ncluster:  member {addr} {} q={}",
                if alive { "up" } else { "down" },
                self.membership.load(i)));
        }
        out
    }
}

/// Per-connection-handler pool of replica connections, keyed by replica
/// index. Lives on the handler's stack, so the forwarding path takes no
/// global lock and a slow replica only stalls the clients multiplexed
/// onto that handler's connection.
type ConnPool = HashMap<usize, ReplicaConn>;

/// Forward `line` to replica `r`, reusing the pooled connection. One
/// transparent reconnect-and-resend on failure (a pooled connection may
/// have died idle); a second failure marks the replica down and reports
/// the attempt failed. Resending is safe — see the at-least-once
/// invariant in the module docs.
fn try_replica(router: &ClusterRouter, conns: &mut ConnPool, r: usize,
               line: &str) -> std::io::Result<String> {
    let attempt = |conns: &mut ConnPool| -> std::io::Result<String> {
        if !conns.contains_key(&r) {
            let c = ReplicaConn::connect(router.membership.addr(r),
                                         &router.cfg)?;
            conns.insert(r, c);
        }
        let conn = conns.get_mut(&r).expect("just inserted");
        conn.roundtrip(line)
    };
    match attempt(conns) {
        Ok(reply) => Ok(reply),
        Err(_) => {
            conns.remove(&r);
            match attempt(conns) {
                Ok(reply) => {
                    router.membership.set_up(r, true);
                    Ok(reply)
                }
                Err(e) => {
                    conns.remove(&r);
                    router.membership.set_up(r, false);
                    Err(e)
                }
            }
        }
    }
}

/// The `q=<depth>` field of a pong reply (`OK 0 pong q=7`), if present
/// and numeric. Pure — unit-tested directly. `None` for replicas that
/// predate the suffix; the prober treats that as load 0.
pub fn parse_queue_depth(reply: &str) -> Option<u64> {
    reply
        .split_whitespace()
        .find_map(|f| f.strip_prefix("q="))
        .and_then(|v| v.parse().ok())
}

/// The forwarded budget after `elapsed_ms` spent in the router. Pure —
/// unit-tested directly; `0` means the deadline is already blown.
pub fn remaining_budget_ms(orig_ms: u64, elapsed_ms: u64) -> u64 {
    orig_ms.saturating_sub(elapsed_ms)
}

/// Serialize the forward line for a replica attempt. `extras` is the
/// client's non-deadline option prefix, re-rendered verbatim
/// (`WireOptions::render_extras`) so the replica parses exactly the
/// options the client sent; empty when none. The deadline is *not*
/// verbatim — it is rebuilt from the remaining budget per attempt.
fn forward_line(id: u64, deadline_ms: Option<u64>, extras: &str,
                tokens: &[i32]) -> String {
    let toks: Vec<String> = tokens.iter().map(|t| t.to_string()).collect();
    let mut line = format!("ENCODE {id}");
    if let Some(ms) = deadline_ms {
        line.push_str(&format!(" DEADLINE_MS={ms}"));
    }
    if !extras.is_empty() {
        line.push(' ');
        line.push_str(extras);
    }
    line.push(' ');
    line.push_str(&toks.join(" "));
    line
}

/// Parse + execute one protocol line against the cluster (the router
/// twin of [`server::dispatch`](crate::server::dispatch) — same verbs,
/// same parse errors, forwarding instead of local execution).
pub fn dispatch_router(line: &str, router: &ClusterRouter,
                       conns: &mut ConnPool) -> String {
    let arrival = Instant::now();
    let mut parts = line.split_whitespace().peekable();
    match parts.next() {
        Some("ENCODE") => {
            let Some(id) = parts.next().and_then(|s| s.parse::<u64>().ok()) else {
                return "ERR 0 bad-id\n".into();
            };
            // same option grammar as the replica (server::options) —
            // the router rejects exactly the lines a replica would
            let opts = match parse_options(&mut parts) {
                Ok(o) => o,
                Err(e) => return format!("ERR {id} {}\n", e.err_token()),
            };
            let deadline_ms = opts.deadline_ms;
            // parse exactly as the replica would, so the cache key the
            // router uses is the key any replica's cache uses
            let tokens: Vec<i32> = parts.filter_map(|t| t.parse().ok()).collect();
            // cache fast path first, mirroring the coordinator: a hit
            // costs nothing, so it is served even under a blown
            // deadline. Requests with non-deadline options bypass the
            // cache entirely — its entries are default-tier payloads.
            if !opts.has_extras() {
                if let Some(payload) = router.cache_get(&tokens) {
                    router.metrics.cache_hits.inc();
                    return format!("OK {id} {payload}\n");
                }
            }
            // deadline gate: a budget that is already zero never
            // touches a replica (DEADLINE_MS=0 is the replica's own
            // always-expired admission case)
            if let Some(orig) = deadline_ms {
                let elapsed = arrival.elapsed().as_millis() as u64;
                if remaining_budget_ms(orig, elapsed) == 0 {
                    router.metrics.expired_at_router.inc();
                    return format!("ERR {id} deadline\n");
                }
            }
            // a miss = a looked-up request that goes toward a replica
            // (expired-at-router requests never deflate the hit rate,
            // mirroring the coordinator's accounting; option-carrying
            // requests were never looked up, so they meter nothing)
            if router.cache.is_some() && !opts.has_extras() {
                router.metrics.cache_misses.inc();
            }
            router.metrics.forwarded.inc();
            let extras = opts.render_extras();
            let mut first = true;
            for r in router.candidates(&tokens) {
                if !first {
                    router.metrics.retried.inc();
                }
                first = false;
                // recompute the forwarded budget per attempt — failed
                // attempts eat real time the replica must not be
                // granted back
                let fwd_deadline = match deadline_ms {
                    Some(orig) => {
                        let elapsed = arrival.elapsed().as_millis() as u64;
                        let left = remaining_budget_ms(orig, elapsed);
                        if left == 0 {
                            router.metrics.expired_at_router.inc();
                            return format!("ERR {id} deadline\n");
                        }
                        Some(left)
                    }
                    None => None,
                };
                let fwd = forward_line(id, fwd_deadline, &extras, &tokens);
                if let Ok(reply) = try_replica(router, conns, r, &fwd) {
                    if !opts.has_extras() {
                        if let Some(payload) =
                            reply.strip_prefix(&format!("OK {id} ")) {
                            router.cache_put(&tokens, payload.to_string());
                        }
                    }
                    return format!("{reply}\n");
                }
            }
            router.metrics.replica_lost.inc();
            format!("ERR {id} replica-lost\n")
        }
        Some("STATS") => {
            format!("backend:  router\nrole:     router\n{}\n{}\n.\n",
                    router.membership_report(),
                    router.metrics.report())
        }
        Some("PING") => "OK 0 pong\n".into(),
        Some("QUIT") => "OK 0 bye\n".into(),
        _ => "ERR 0 unknown-command\n".into(),
    }
}

/// Handle to stop a router's acceptor and prober threads.
pub struct RouterHandle {
    stop: CancelToken,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl RouterHandle {
    pub fn stop(mut self) {
        self.stop.cancel();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for RouterHandle {
    fn drop(&mut self) {
        self.stop.cancel();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Serve the router on `bind` (the cluster twin of
/// [`server::serve`](crate::server::serve)): an acceptor loop fanning
/// connections onto a handler pool, plus a background health prober
/// sweeping every `probe_interval`. Returns the bound address (useful
/// with port 0) and a stop handle.
pub fn serve_router(router: Arc<ClusterRouter>, bind: &str, pool_size: usize)
                    -> std::io::Result<(SocketAddr, RouterHandle)> {
    let listener = TcpListener::bind(bind)?;
    let addr = listener.local_addr()?;
    let stop = CancelToken::new();

    let accept_stop = stop.clone();
    let accept_router = router.clone();
    let acceptor = std::thread::Builder::new()
        .name("ssaformer-router-acceptor".into())
        .spawn(move || {
            let pool = ThreadPool::new(pool_size);
            listener.set_nonblocking(true).ok();
            loop {
                if accept_stop.is_cancelled() {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        let r = accept_router.clone();
                        let stop = accept_stop.clone();
                        pool.execute(move || handle_router_conn(stream, &r, &stop));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
            pool.shutdown();
        })?;

    let probe_stop = stop.clone();
    let probe_router = router.clone();
    let prober = std::thread::Builder::new()
        .name("ssaformer-router-prober".into())
        .spawn(move || {
            // sleep in small slices so stop() is honored promptly even
            // under a long probe interval
            loop {
                let mut slept = Duration::ZERO;
                while slept < probe_router.cfg.probe_interval {
                    if probe_stop.is_cancelled() {
                        return;
                    }
                    let slice = Duration::from_millis(50)
                        .min(probe_router.cfg.probe_interval - slept);
                    std::thread::sleep(slice);
                    slept += slice;
                }
                if probe_stop.is_cancelled() {
                    return;
                }
                probe_router.probe_now();
            }
        })?;

    Ok((addr, RouterHandle { stop, threads: vec![acceptor, prober] }))
}

/// Per-connection router loop: same line discipline as the replica's
/// `handle_conn` (read timeout for shutdown, partial-line tolerance),
/// with a connection-local replica pool.
fn handle_router_conn(stream: TcpStream, router: &ClusterRouter,
                      stop: &CancelToken) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    let mut conns: ConnPool = HashMap::new();
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => break,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock
                || e.kind() == std::io::ErrorKind::TimedOut => {
                if stop.is_cancelled() {
                    break;
                }
                continue;
            }
            Err(_) => break,
            Ok(_) if !line.ends_with('\n') => continue, // partial line
            Ok(_) => {}
        }
        let trimmed = line.trim().to_string();
        line.clear();
        if trimmed.is_empty() {
            continue;
        }
        let reply = dispatch_router(&trimmed, router, &mut conns);
        if writer.write_all(reply.as_bytes()).is_err() {
            break;
        }
        if trimmed == "QUIT" {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    //! Ring/membership/budget logic needs no sockets and is tested
    //! here (including the satellite property tests); the full
    //! router-over-TCP fault matrix lives in
    //! `rust/tests/integration_cluster.rs`.

    use super::*;
    use crate::proptest_mini::{prop_assert, run};

    fn names(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("10.0.0.{i}:4100")).collect()
    }

    #[test]
    fn fnv1a64_known_vectors() {
        // published FNV-1a 64 test vectors
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn token_hash_is_content_keyed() {
        assert_eq!(hash_tokens(&[1, 2, 3]), hash_tokens(&[1, 2, 3]));
        assert_ne!(hash_tokens(&[1, 2, 3]), hash_tokens(&[1, 2, 4]));
        assert_ne!(hash_tokens(&[1, 2, 3]), hash_tokens(&[3, 2, 1]));
        // length-sensitive, not just content-sensitive
        assert_ne!(hash_tokens(&[1, 2]), hash_tokens(&[1, 2, 0]));
    }

    #[test]
    fn ring_covers_all_replicas_in_preference_order() {
        let ring = HashRing::build(&names(4), DEFAULT_VNODES);
        assert_eq!(ring.vnode_points(), 4 * DEFAULT_VNODES);
        for key in [0u64, 1, u64::MAX, 0xdead_beef] {
            let prefs = ring.preferences(key);
            assert_eq!(prefs.len(), 4);
            let mut sorted = prefs.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, vec![0, 1, 2, 3], "each replica once");
            assert_eq!(prefs[0], ring.assign(key), "owner leads");
        }
    }

    #[test]
    fn single_replica_ring_is_total() {
        let ring = HashRing::build(&names(1), DEFAULT_VNODES);
        for key in [0u64, 42, u64::MAX] {
            assert_eq!(ring.assign(key), 0);
            assert_eq!(ring.preferences(key), vec![0]);
        }
    }

    #[test]
    fn membership_flags_and_snapshot() {
        let m = Membership::new(names(3));
        assert_eq!(m.len(), 3);
        assert_eq!(m.up_count(), 3);
        m.set_up(1, false);
        assert!(!m.is_up(1));
        assert!(m.is_up(0) && m.is_up(2));
        assert_eq!(m.up_count(), 2);
        let snap = m.snapshot();
        assert_eq!(snap[1], ("10.0.0.1:4100".to_string(), false));
    }

    #[test]
    fn remaining_budget_saturates() {
        assert_eq!(remaining_budget_ms(100, 30), 70);
        assert_eq!(remaining_budget_ms(100, 100), 0);
        assert_eq!(remaining_budget_ms(100, 5000), 0);
        assert_eq!(remaining_budget_ms(0, 0), 0);
    }

    #[test]
    fn forward_line_round_trips_the_wire_grammar() {
        assert_eq!(forward_line(7, None, "", &[5, 6, 7]), "ENCODE 7 5 6 7");
        assert_eq!(forward_line(7, Some(250), "", &[5]),
                   "ENCODE 7 DEADLINE_MS=250 5");
        assert_eq!(forward_line(1, None, "", &[]), "ENCODE 1 ");
        // non-deadline options forward verbatim, after the rebuilt
        // deadline, and parse back through the shared grammar
        assert_eq!(forward_line(7, Some(250), "ACCURACY=budget", &[5]),
                   "ENCODE 7 DEADLINE_MS=250 ACCURACY=budget 5");
        assert_eq!(forward_line(2, None, "ACCURACY=0.050", &[1, 2]),
                   "ENCODE 2 ACCURACY=0.050 1 2");
        let fwd = forward_line(9, Some(9), "ACCURACY=high", &[3]);
        let (opts, rest) = crate::server::options::parse_option_str(
            fwd.strip_prefix("ENCODE 9 ").unwrap()).unwrap();
        assert_eq!(opts.deadline_ms, Some(9));
        assert_eq!(opts.render_extras(), "ACCURACY=high");
        assert_eq!(rest, vec!["3"]);
    }

    // ---- satellite: consistent-hash ring property tests ----

    #[test]
    fn property_assignment_is_deterministic_across_builds() {
        // the ring is a pure function of (names, vnodes): two
        // independent builds — as in two processes — agree on every key
        run(50, |g| {
            let n = g.usize_in(1, 6);
            let a = HashRing::build(&names(n), DEFAULT_VNODES);
            let b = HashRing::build(&names(n), DEFAULT_VNODES);
            for _ in 0..20 {
                let key = g.rng().below(u64::MAX);
                prop_assert(a.assign(key) == b.assign(key),
                            format!("key {key} diverged"))?;
                prop_assert(a.preferences(key) == b.preferences(key),
                            "preference order diverged")?;
            }
            Ok(())
        });
    }

    #[test]
    fn property_join_moves_keys_only_to_the_new_replica() {
        run(50, |g| {
            let n = g.usize_in(1, 5);
            let before = HashRing::build(&names(n), DEFAULT_VNODES);
            let after = HashRing::build(&names(n + 1), DEFAULT_VNODES);
            for _ in 0..50 {
                let key = g.rng().below(u64::MAX);
                let (old, new) = (before.assign(key), after.assign(key));
                prop_assert(new == old || new == n,
                            format!("key {key} moved {old}→{new}, \
                                     not to joined replica {n}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn property_leave_moves_keys_only_from_the_lost_replica() {
        run(50, |g| {
            let n = g.usize_in(2, 6);
            let before = HashRing::build(&names(n), DEFAULT_VNODES);
            let after = HashRing::build(&names(n - 1), DEFAULT_VNODES);
            for _ in 0..50 {
                let key = g.rng().below(u64::MAX);
                let (old, new) = (before.assign(key), after.assign(key));
                prop_assert(old == new || old == n - 1,
                            format!("key {key} moved {old}→{new} though \
                                     only replica {} left", n - 1))?;
            }
            Ok(())
        });
    }

    #[test]
    fn property_load_spread_within_2x_of_uniform() {
        // 1k synthetic token-sequence keys: the hottest replica stays
        // within 2× of the uniform share (the DEFAULT_VNODES sizing
        // argument)
        run(20, |g| {
            let n = g.usize_in(2, 5);
            let ring = HashRing::build(&names(n), DEFAULT_VNODES);
            let mut load = vec![0usize; n];
            for i in 0..1000 {
                let toks: Vec<i32> = (0..8)
                    .map(|j| (i * 8 + j) as i32 + g.usize_in(0, 3) as i32)
                    .collect();
                load[ring.assign(hash_tokens(&toks))] += 1;
            }
            let max = *load.iter().max().unwrap();
            prop_assert(max as f64 <= 2.0 * 1000.0 / n as f64,
                        format!("spread {load:?} exceeds 2x uniform"))?;
            prop_assert(load.iter().all(|&l| l > 0),
                        format!("starved replica in {load:?}"))
        });
    }

    #[test]
    fn router_candidates_prefer_up_replicas_but_keep_down_ones() {
        let cfg = ClusterConfig {
            replicas: names(3),
            ..Default::default()
        };
        let router = ClusterRouter::new(cfg);
        let toks = vec![5, 6, 7];
        let prefs = router.ring.preferences(hash_tokens(&toks));
        // all up: candidates are exactly the ring preference order
        assert_eq!(router.candidates(&toks), prefs);
        // owner down: it drops to the back, everyone still present
        router.membership.set_up(prefs[0], false);
        let c = router.candidates(&toks);
        assert_eq!(c.len(), 3);
        assert_eq!(*c.last().unwrap(), prefs[0]);
        assert_eq!(c[0], prefs[1]);
    }

    #[test]
    fn parse_queue_depth_reads_the_pong_suffix() {
        assert_eq!(parse_queue_depth("OK 0 pong q=7"), Some(7));
        assert_eq!(parse_queue_depth("OK 0 pong q=0"), Some(0));
        // a replica that predates the suffix
        assert_eq!(parse_queue_depth("OK 0 pong"), None);
        // garbage never panics the prober
        assert_eq!(parse_queue_depth("OK 0 pong q=abc"), None);
        assert_eq!(parse_queue_depth(""), None);
    }

    #[test]
    fn saturated_owner_sheds_to_the_second_ring_choice() {
        let router = ClusterRouter::new(ClusterConfig {
            replicas: names(3),
            ..Default::default()
        });
        let toks = vec![5, 6, 7];
        let prefs = router.ring.preferences(hash_tokens(&toks));
        // fresh state (all loads 0): placement is pure ring order
        assert_eq!(router.candidates(&toks), prefs);
        // owner strictly more loaded than the runner-up: top two swap,
        // the rest of the failover order is untouched
        router.membership.set_load(prefs[0], 9);
        router.membership.set_load(prefs[1], 2);
        let c = router.candidates(&toks);
        assert_eq!(c[0], prefs[1], "saturated owner must shed");
        assert_eq!(c[1], prefs[0], "owner stays as first failover");
        assert_eq!(c[2], prefs[2]);
        // equal load is a tie: ring order, no churn
        router.membership.set_load(prefs[0], 2);
        assert_eq!(router.candidates(&toks), prefs);
        // less-loaded owner keeps the request
        router.membership.set_load(prefs[0], 1);
        assert_eq!(router.candidates(&toks), prefs);
    }

    #[test]
    fn router_cache_is_token_keyed_and_bounded() {
        let cfg = ClusterConfig {
            replicas: names(1),
            cache_capacity: 2,
            ..Default::default()
        };
        let router = ClusterRouter::new(cfg);
        assert_eq!(router.cache_len(), 0);
        router.cache_put(&[1, 2], "0.1 0.2".into());
        router.cache_put(&[3, 4], "0.3 0.4".into());
        assert_eq!(router.cache_get(&[1, 2]).as_deref(), Some("0.1 0.2"));
        // LRU bound: inserting a third evicts the least-recent ([3,4])
        router.cache_put(&[5, 6], "0.5 0.6".into());
        assert_eq!(router.cache_len(), 2);
        assert!(router.cache_get(&[3, 4]).is_none());
        assert_eq!(router.cache_get(&[1, 2]).as_deref(), Some("0.1 0.2"));
    }

    #[test]
    fn membership_report_names_every_member() {
        let router = ClusterRouter::new(ClusterConfig {
            replicas: names(2),
            ..Default::default()
        });
        router.membership.set_up(1, false);
        router.membership.set_load(0, 3);
        let rep = router.membership_report();
        assert!(rep.contains("replicas=2 up=1 down=1"), "{rep}");
        assert!(rep.contains("member 10.0.0.0:4100 up q=3"), "{rep}");
        assert!(rep.contains("member 10.0.0.1:4100 down q=0"), "{rep}");
        assert!(rep.lines().all(|l| l.starts_with("cluster:")), "{rep}");
    }
}
