//! Bounded LRU embedding cache for the serving path.
//!
//! Repeated token sequences are common in serving traffic (retried
//! requests, shared prompt prefixes, popular queries); recomputing the
//! full attention stack for each repeat wastes the exact FLOPs the
//! paper's O(n) approximation saves. [`EmbeddingCache`] memoizes the
//! coordinator's *final* pooled embeddings, keyed on the full token
//! content of the request.
//!
//! # Coherence invariant
//!
//! A cache hit MUST be **bitwise-equal** to a recompute. This holds
//! because both execution backends are deterministic functions of the
//! token sequence alone: the CPU engine's output is independent of
//! batch composition, arrival order, and kernel thread count (the
//! determinism contract in [`cpu_engine`](super::cpu_engine)), and the
//! XLA artifact executes one fixed program per bucket. The cache never
//! stores anything derived from *how* a request was batched — only the
//! per-request pooled embedding after padding rows were dropped — so
//! serving a hit is observationally identical to recomputing, minus
//! the latency. `tests/integration_cpu_serving.rs` pins hit-vs-
//! recompute equality end to end.
//!
//! The cache is keyed on token content, not request id: two requests
//! with identical tokens share one entry regardless of who sent them.
//! Capacity is counted in entries; each entry owns one key copy in the
//! index, one in the recency list, and a `d_model` embedding
//! (~`8·seq + 4·d_model` bytes per entry at the default model — the
//! sizing arithmetic is worked through in `OPERATIONS.md`).

use std::borrow::Borrow;
use std::collections::HashMap;
use std::hash::Hash;
use std::sync::{Arc, Mutex};

/// Sentinel for "no node" in the intrusive recency list.
const NIL: usize = usize::MAX;

struct Slot<K, V> {
    key: K,
    value: V,
    prev: usize,
    next: usize,
}

/// A bounded least-recently-used map (hand-rolled: the crate builds
/// with zero external dependencies).
///
/// `get` and `insert` are O(1): a `HashMap` indexes into a slot arena
/// threaded with an intrusive doubly-linked recency list, so eviction
/// pops the list tail without scanning. Freed slots are recycled, so a
/// full cache performs no allocation on the replace path beyond the
/// incoming key/value themselves.
///
/// ```
/// use ssaformer::coordinator::cache::LruCache;
/// let mut c = LruCache::new(2);
/// c.insert("a", 1);
/// c.insert("b", 2);
/// assert_eq!(c.get(&"a"), Some(&1)); // "a" is now most recent
/// c.insert("c", 3);                  // evicts "b", the LRU entry
/// assert_eq!(c.get(&"b"), None);
/// assert_eq!(c.len(), 2);
/// ```
pub struct LruCache<K, V> {
    capacity: usize,
    map: HashMap<K, usize>,
    slots: Vec<Option<Slot<K, V>>>,
    free: Vec<usize>,
    /// most recently used
    head: usize,
    /// least recently used (eviction candidate)
    tail: usize,
}

impl<K: Hash + Eq + Clone, V> LruCache<K, V> {
    /// Create a cache holding at most `capacity` entries.
    ///
    /// # Panics
    /// When `capacity == 0` — a zero-size cache is "caching disabled"
    /// and should be expressed by not constructing one (the coordinator
    /// maps `cache_capacity = 0` to `None`).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "LruCache capacity must be > 0");
        LruCache {
            capacity,
            map: HashMap::with_capacity(capacity),
            slots: Vec::with_capacity(capacity),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
        }
    }

    /// Entries currently cached (≤ capacity).
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// The configured entry bound.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Look up `key`, marking the entry most-recently-used on a hit.
    pub fn get<Q>(&mut self, key: &Q) -> Option<&V>
    where
        K: Borrow<Q>,
        Q: Hash + Eq + ?Sized,
    {
        let i = *self.map.get(key)?;
        self.touch(i);
        self.slots[i].as_ref().map(|s| &s.value)
    }

    /// Look up `key` WITHOUT updating recency (diagnostics/tests).
    pub fn peek<Q>(&self, key: &Q) -> Option<&V>
    where
        K: Borrow<Q>,
        Q: Hash + Eq + ?Sized,
    {
        let i = *self.map.get(key)?;
        self.slots[i].as_ref().map(|s| &s.value)
    }

    /// Insert `key → value`, evicting the least-recently-used entry if
    /// the cache is full. Returns the previous value when `key` was
    /// already present (the entry is refreshed to most-recent either
    /// way).
    pub fn insert(&mut self, key: K, value: V) -> Option<V> {
        if let Some(&i) = self.map.get(&key) {
            let slot = self.slots[i].as_mut().expect("mapped slot occupied");
            let old = std::mem::replace(&mut slot.value, value);
            self.touch(i);
            return Some(old);
        }
        if self.map.len() == self.capacity {
            self.pop_lru();
        }
        let slot = Slot { key: key.clone(), value, prev: NIL, next: NIL };
        let i = match self.free.pop() {
            Some(i) => {
                self.slots[i] = Some(slot);
                i
            }
            None => {
                self.slots.push(Some(slot));
                self.slots.len() - 1
            }
        };
        self.attach_front(i);
        self.map.insert(key, i);
        None
    }

    /// Remove and return the least-recently-used entry.
    pub fn pop_lru(&mut self) -> Option<(K, V)> {
        if self.tail == NIL {
            return None;
        }
        let i = self.tail;
        self.detach(i);
        let slot = self.slots[i].take().expect("tail slot occupied");
        self.free.push(i);
        self.map.remove(&slot.key);
        Some((slot.key, slot.value))
    }

    /// Move slot `i` to the front (most-recent) of the recency list.
    fn touch(&mut self, i: usize) {
        if self.head == i {
            return;
        }
        self.detach(i);
        self.attach_front(i);
    }

    fn detach(&mut self, i: usize) {
        let (p, n) = {
            let s = self.slots[i].as_ref().expect("detach occupied slot");
            (s.prev, s.next)
        };
        match p {
            NIL => self.head = n,
            p => self.slots[p].as_mut().expect("prev occupied").next = n,
        }
        match n {
            NIL => self.tail = p,
            n => self.slots[n].as_mut().expect("next occupied").prev = p,
        }
        let s = self.slots[i].as_mut().expect("detach occupied slot");
        s.prev = NIL;
        s.next = NIL;
    }

    fn attach_front(&mut self, i: usize) {
        let old_head = self.head;
        {
            let s = self.slots[i].as_mut().expect("attach occupied slot");
            s.prev = NIL;
            s.next = old_head;
        }
        match old_head {
            NIL => self.tail = i,
            h => self.slots[h].as_mut().expect("head occupied").prev = i,
        }
        self.head = i;
    }
}

/// Thread-safe embedding cache shared by the coordinator's admission
/// path (lookups) and every worker in the pool (inserts).
///
/// One coarse mutex around the [`LruCache`]: a lookup or insert is a
/// hash + a few pointer swaps, microseconds against the milliseconds an
/// attention batch costs, so the lock is never the bottleneck — and a
/// single lock keeps the recency order exact. Hit/miss *counters* live
/// in [`ServingMetrics`](crate::metrics::ServingMetrics) (lock-free),
/// not here: the cache stores state, the metrics layer observes it.
///
/// Entries hold `Arc<[f32]>` embeddings, so a hit is a refcount bump
/// under the lock — not a `d_model`-sized copy — and the payload can be
/// shared with the prefix cache and the chunk-merge path without
/// cloning.
///
/// ```
/// use ssaformer::coordinator::cache::EmbeddingCache;
/// let cache = EmbeddingCache::new(8);
/// assert!(cache.get(&[5, 6, 7]).is_none());
/// cache.insert(&[5, 6, 7], &[0.25, -1.5]);
/// // a hit returns exactly the stored embedding, bitwise
/// assert_eq!(cache.get(&[5, 6, 7]).as_deref(), Some(&[0.25_f32, -1.5][..]));
/// // keyed on full token content: a different sequence is a miss
/// assert!(cache.get(&[5, 6]).is_none());
/// assert_eq!((cache.len(), cache.capacity()), (1, 8));
/// ```
pub struct EmbeddingCache {
    inner: Mutex<LruCache<Box<[i32]>, Arc<[f32]>>>,
}

impl EmbeddingCache {
    /// A cache bounded at `capacity` entries (must be > 0; the
    /// coordinator expresses "disabled" as the absence of a cache).
    pub fn new(capacity: usize) -> Self {
        EmbeddingCache { inner: Mutex::new(LruCache::new(capacity)) }
    }

    /// The pooled embedding previously served for exactly these tokens,
    /// if still resident. A hit refreshes the entry's recency and costs
    /// one refcount bump — the embedding payload is never copied.
    pub fn get(&self, tokens: &[i32]) -> Option<Arc<[f32]>> {
        self.inner.lock().unwrap().get(tokens).cloned()
    }

    /// Record the served embedding for `tokens` (evicting the LRU entry
    /// when full). Inserting an existing key refreshes it — idempotent
    /// under the coherence invariant, since a recompute is bitwise
    /// identical. The one copy into the shared `Arc` happens before the
    /// lock is taken.
    pub fn insert(&self, tokens: &[i32], embedding: &[f32]) {
        let shared: Arc<[f32]> = Arc::from(embedding);
        self.inner
            .lock()
            .unwrap()
            .insert(tokens.to_vec().into_boxed_slice(), shared);
    }

    /// Entries currently resident.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The configured entry bound.
    pub fn capacity(&self) -> usize {
        self.inner.lock().unwrap().capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eviction_follows_recency_not_insertion() {
        let mut c = LruCache::new(3);
        c.insert(1, "one");
        c.insert(2, "two");
        c.insert(3, "three");
        // touch 1, making 2 the LRU
        assert_eq!(c.get(&1), Some(&"one"));
        c.insert(4, "four");
        assert_eq!(c.get(&2), None, "LRU entry must be the one evicted");
        assert_eq!(c.get(&1), Some(&"one"));
        assert_eq!(c.get(&3), Some(&"three"));
        assert_eq!(c.get(&4), Some(&"four"));
        assert_eq!(c.len(), 3);
    }

    #[test]
    fn insert_existing_replaces_and_refreshes() {
        let mut c = LruCache::new(2);
        c.insert("a", 1);
        c.insert("b", 2);
        assert_eq!(c.insert("a", 10), Some(1)); // refreshes "a"
        c.insert("c", 3); // evicts "b"
        assert_eq!(c.get(&"b"), None);
        assert_eq!(c.get(&"a"), Some(&10));
    }

    #[test]
    fn pop_lru_drains_in_recency_order() {
        let mut c = LruCache::new(4);
        for i in 0..4 {
            c.insert(i, i * 10);
        }
        c.get(&0); // 0 becomes most recent: order is now 1,2,3,0
        let drained: Vec<i32> = std::iter::from_fn(|| c.pop_lru())
            .map(|(k, _)| k)
            .collect();
        assert_eq!(drained, vec![1, 2, 3, 0]);
        assert!(c.is_empty());
        assert_eq!(c.pop_lru(), None);
    }

    #[test]
    fn capacity_one_always_holds_latest() {
        let mut c = LruCache::new(1);
        for i in 0..10 {
            c.insert(i, i);
            assert_eq!(c.len(), 1);
            assert_eq!(c.get(&i), Some(&i));
        }
        assert_eq!(c.get(&8), None);
    }

    #[test]
    fn freed_slots_are_recycled() {
        let mut c = LruCache::new(2);
        for i in 0..100 {
            c.insert(i, i);
        }
        // arena never grows past capacity even after 98 evictions
        assert!(c.slots.len() <= 2, "slots grew to {}", c.slots.len());
    }

    #[test]
    fn peek_does_not_refresh() {
        let mut c = LruCache::new(2);
        c.insert("a", 1);
        c.insert("b", 2);
        assert_eq!(c.peek(&"a"), Some(&1)); // no recency update
        c.insert("c", 3); // evicts "a" — peek did not protect it
        assert_eq!(c.get(&"a"), None);
    }

    #[test]
    fn embedding_cache_hit_is_bitwise_and_bounded() {
        let cache = EmbeddingCache::new(2);
        let emb = vec![1.0f32, -0.0, f32::MIN_POSITIVE, 3.5e-8];
        cache.insert(&[1, 2, 3], &emb);
        let hit = cache.get(&[1, 2, 3]).unwrap();
        // bitwise, not approximate: compare the raw representations
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&hit), bits(&emb));
        // a second hit shares the same allocation — refcount bump, not
        // a payload copy
        let again = cache.get(&[1, 2, 3]).unwrap();
        assert!(Arc::ptr_eq(&hit, &again), "hit copied the payload");
        // capacity pressure evicts the LRU key
        cache.insert(&[4], &[0.0]);
        cache.get(&[1, 2, 3]); // refresh
        cache.insert(&[5], &[0.0]); // evicts [4]
        assert!(cache.get(&[4]).is_none());
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn embedding_cache_is_shareable_across_threads() {
        let cache = Arc::new(EmbeddingCache::new(64));
        let mut handles = Vec::new();
        for t in 0..4i32 {
            let cache = cache.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    let key = [t, i];
                    cache.insert(&key, &[t as f32, i as f32]);
                    assert_eq!(cache.get(&key).as_deref(),
                               Some(&[t as f32, i as f32][..]));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(cache.len() <= 64);
    }
}
