//! Accuracy-aware admission: map each request's (sequence length,
//! accuracy budget) onto a served (variant × precision) tier.
//!
//! The paper's result — spectral shifting holds a strictly stronger
//! error bound than Nyström at the same O(n) cost — makes accuracy a
//! *servable resource*: a request can ask for more or less of it, and
//! the policy here spends it. Tiers order the lattice the engine
//! pre-builds at load ([`crate::model::quantize_stack`]):
//!
//! | tier       | operators      | weights | default table rel-err |
//! |------------|----------------|---------|-----------------------|
//! | `full-f32` | exact softmax  | f32     | 0 (reference)         |
//! | `ss-f32`   | spectral shift | f32     | ~2e-2                 |
//! | `ss-bf16`  | spectral shift | bf16    | ~2.5e-2               |
//! | `ss-int8`  | spectral shift | int8    | ~6e-2                 |
//!
//! The table values are the *defaults* the numeric `ACCURACY=<bound>`
//! form routes against, calibrated from `BENCH_error_bound.json`'s
//! (variant × precision) rows on trained weights (regenerate with
//! `train --error-bound-json`; the measured artifact is authoritative,
//! the embedded table is its serving-side summary — no JSON is parsed
//! at runtime).
//!
//! Policy (ROADMAP defaults):
//!
//! * **untagged + unforced → `None`** — the request serves on the
//!   configured stack exactly as before this module existed, so every
//!   bitwise pin (cache hit ≡ recompute, replica ≡ direct, replay)
//!   survives by construction.
//! * `ACCURACY=high` → `full-f32`.
//! * `ACCURACY=balanced` → `full-f32` for short sequences (within the
//!   smallest bucket), `ss-f32` past it — the paper's own trade.
//! * `ACCURACY=budget` → `ss-int8` (background traffic).
//! * `ACCURACY=<float>` → the cheapest tier whose table error fits the
//!   bound, scanning `ss-int8 → ss-bf16 → ss-f32 → full-f32`.
//! * A forced tier (`SSAF_ADMISSION` env > `[serving] admission` knob,
//!   same precedence idiom as the kernel arm) applies to **every**
//!   request, tagged or not.
//!
//! A tier the engine could not build (ss landmark divisor must divide
//! every bucket) falls back toward higher precision; `full-f32` is
//! always buildable, so `decide` is total.

use crate::kernels::Precision;

/// One point of the (variant × precision) admission lattice.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TierKind {
    FullF32,
    SsF32,
    SsBf16,
    SsInt8,
}

impl TierKind {
    /// All tiers in decreasing-precision order (STATS/report order;
    /// [`TierKind::index`] is the position here).
    pub const ALL: [TierKind; 4] = [
        TierKind::FullF32,
        TierKind::SsF32,
        TierKind::SsBf16,
        TierKind::SsInt8,
    ];

    /// Stable index into per-tier counter arrays
    /// ([`crate::metrics::ServingMetrics::admission_served`]).
    pub fn index(self) -> usize {
        match self {
            TierKind::FullF32 => 0,
            TierKind::SsF32 => 1,
            TierKind::SsBf16 => 2,
            TierKind::SsInt8 => 3,
        }
    }

    /// Canonical token: wire metadata (`tier=`), config knob, STATS.
    pub fn token(self) -> &'static str {
        match self {
            TierKind::FullF32 => "full-f32",
            TierKind::SsF32 => "ss-f32",
            TierKind::SsBf16 => "ss-bf16",
            TierKind::SsInt8 => "ss-int8",
        }
    }

    /// Parse a tier token (inverse of [`TierKind::token`]).
    pub fn parse(s: &str) -> Option<TierKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "full-f32" | "full" => Some(TierKind::FullF32),
            "ss-f32" => Some(TierKind::SsF32),
            "ss-bf16" => Some(TierKind::SsBf16),
            "ss-int8" => Some(TierKind::SsInt8),
            _ => None,
        }
    }

    /// The weight precision this tier serves.
    pub fn precision(self) -> Precision {
        match self {
            TierKind::FullF32 | TierKind::SsF32 => Precision::F32,
            TierKind::SsBf16 => Precision::Bf16,
            TierKind::SsInt8 => Precision::Int8,
        }
    }

    /// Whether the tier runs the spectral-shift operator (vs exact
    /// softmax) — what decides landmark-alignment availability.
    pub fn is_ss(self) -> bool {
        !matches!(self, TierKind::FullF32)
    }

    /// Default relative-Frobenius error vs the f32 `full` reference
    /// (see the module table; `BENCH_error_bound.json` is the measured
    /// counterpart).
    pub fn table_err(self) -> f64 {
        match self {
            TierKind::FullF32 => 0.0,
            TierKind::SsF32 => 0.02,
            TierKind::SsBf16 => 0.025,
            TierKind::SsInt8 => 0.06,
        }
    }
}

/// A request's accuracy budget, parsed from the wire `ACCURACY=` field
/// or [`EncodeRequest::accuracy`](super::EncodeRequest).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Accuracy {
    /// Full fidelity: the f32 exact-softmax tier.
    High,
    /// The paper's trade: exact while short, spectral shift past the
    /// smallest bucket.
    Balanced,
    /// Background traffic: the cheapest (int8) tier.
    Budget,
    /// A numeric relative-error bound: the cheapest tier whose table
    /// error fits.
    Bound(f64),
}

impl Accuracy {
    /// Parse a wire/config accuracy value: a named level or a finite
    /// non-negative float.
    pub fn parse(s: &str) -> Option<Accuracy> {
        let t = s.trim();
        match t.to_ascii_lowercase().as_str() {
            "high" => Some(Accuracy::High),
            "balanced" => Some(Accuracy::Balanced),
            "budget" => Some(Accuracy::Budget),
            _ => t.parse::<f64>()
                .ok()
                .filter(|e| e.is_finite() && *e >= 0.0)
                .map(Accuracy::Bound),
        }
    }
}

/// The resolved admission policy one coordinator serves with: which
/// tiers the engine actually built, where "short" ends, and whether an
/// operator override forces a tier.
#[derive(Clone, Debug)]
pub struct AdmissionPolicy {
    forced: Option<TierKind>,
    available: Vec<TierKind>,
    /// `balanced`'s short/long cutoff: the smallest serving bucket.
    short_cutoff: usize,
}

impl AdmissionPolicy {
    /// Build a policy. `available` must contain [`TierKind::FullF32`]
    /// (the engine can always build it — the exact f32 stack *is* the
    /// configured model's shape).
    pub fn new(forced: Option<TierKind>, available: Vec<TierKind>,
               short_cutoff: usize) -> AdmissionPolicy {
        assert!(available.contains(&TierKind::FullF32),
                "full-f32 must always be an available tier");
        AdmissionPolicy { forced, available, short_cutoff }
    }

    pub fn forced(&self) -> Option<TierKind> {
        self.forced
    }

    pub fn available(&self) -> &[TierKind] {
        &self.available
    }

    fn is_available(&self, t: TierKind) -> bool {
        self.available.contains(&t)
    }

    /// Walk `want` toward higher precision until an available tier is
    /// found. Total: `full-f32` (index 0) is always available.
    fn fallback(&self, want: TierKind) -> TierKind {
        let mut i = want.index();
        loop {
            let t = TierKind::ALL[i];
            if self.is_available(t) {
                return t;
            }
            i = i.checked_sub(1).expect("full-f32 is always available");
        }
    }

    /// The admission decision for one request. `None` means "serve on
    /// the configured path" — chosen exactly when the request carries
    /// no accuracy budget and no tier is forced, so untagged traffic
    /// is byte-identical to a build without admission routing.
    pub fn decide(&self, len: usize, accuracy: Option<Accuracy>)
                  -> Option<TierKind> {
        if let Some(t) = self.forced {
            return Some(self.fallback(t));
        }
        let want = match accuracy? {
            Accuracy::High => TierKind::FullF32,
            Accuracy::Balanced => {
                if len <= self.short_cutoff {
                    TierKind::FullF32
                } else {
                    TierKind::SsF32
                }
            }
            Accuracy::Budget => TierKind::SsInt8,
            Accuracy::Bound(e) => {
                // cheapest first; full-f32 (err 0) makes the scan total
                *[TierKind::SsInt8, TierKind::SsBf16, TierKind::SsF32,
                  TierKind::FullF32]
                    .iter()
                    .find(|t| t.table_err() <= e)
                    .expect("full-f32 fits every bound")
            }
        };
        Some(self.fallback(want))
    }

    /// One-line policy description for startup logs and the STATS
    /// `admission:` header.
    pub fn describe(&self) -> String {
        let tiers: Vec<&str> =
            self.available.iter().map(|t| t.token()).collect();
        format!(
            "policy={} tiers={}",
            match self.forced {
                Some(t) => format!("forced-{}", t.token()),
                None => "auto".to_string(),
            },
            tiers.join(","))
    }
}

/// The `SSAF_ADMISSION` env override, mirroring
/// [`isa::env_override`](crate::kernels::isa::env_override):
/// `None` when unset, `Some(None)` for `auto`, `Some(Some(tier))` for
/// a forced tier. Panics on an unknown token — an operator who typed a
/// tier wants that tier, not a silent default.
pub fn env_override() -> Option<Option<TierKind>> {
    let raw = std::env::var("SSAF_ADMISSION").ok()?;
    if raw.trim().eq_ignore_ascii_case("auto") {
        return Some(None);
    }
    match TierKind::parse(&raw) {
        Some(t) => Some(Some(t)),
        None => panic!(
            "SSAF_ADMISSION={raw:?} is not a tier \
             (auto|full-f32|ss-f32|ss-bf16|ss-int8)"),
    }
}

/// Resolve the forced-tier setting: env override > `[serving]
/// admission` knob > auto (no forcing) — the same precedence ladder as
/// the kernel arm.
pub fn resolve_admission(knob: Option<TierKind>) -> Option<TierKind> {
    match env_override() {
        Some(over) => over,
        None => knob,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_tiers() -> Vec<TierKind> {
        TierKind::ALL.to_vec()
    }

    #[test]
    fn tier_tokens_round_trip_and_index_is_stable() {
        for (i, t) in TierKind::ALL.into_iter().enumerate() {
            assert_eq!(t.index(), i);
            assert_eq!(TierKind::parse(t.token()), Some(t));
        }
        assert_eq!(TierKind::parse("FULL"), Some(TierKind::FullF32));
        assert!(TierKind::parse("ss-fp64").is_none());
    }

    #[test]
    fn accuracy_parses_levels_and_bounds() {
        assert_eq!(Accuracy::parse("high"), Some(Accuracy::High));
        assert_eq!(Accuracy::parse(" Balanced "), Some(Accuracy::Balanced));
        assert_eq!(Accuracy::parse("budget"), Some(Accuracy::Budget));
        assert_eq!(Accuracy::parse("0.03"), Some(Accuracy::Bound(0.03)));
        assert_eq!(Accuracy::parse("0"), Some(Accuracy::Bound(0.0)));
        assert!(Accuracy::parse("-0.1").is_none());
        assert!(Accuracy::parse("NaN").is_none());
        assert!(Accuracy::parse("speedy").is_none());
        assert!(Accuracy::parse("").is_none());
    }

    #[test]
    fn untagged_unforced_requests_stay_on_the_configured_path() {
        let p = AdmissionPolicy::new(None, all_tiers(), 128);
        assert_eq!(p.decide(5, None), None);
        assert_eq!(p.decide(100_000, None), None);
    }

    #[test]
    fn roadmap_defaults_route_as_documented() {
        let p = AdmissionPolicy::new(None, all_tiers(), 128);
        assert_eq!(p.decide(64, Some(Accuracy::High)),
                   Some(TierKind::FullF32));
        // balanced: short stays exact, long goes spectral-shift
        assert_eq!(p.decide(128, Some(Accuracy::Balanced)),
                   Some(TierKind::FullF32));
        assert_eq!(p.decide(129, Some(Accuracy::Balanced)),
                   Some(TierKind::SsF32));
        assert_eq!(p.decide(64, Some(Accuracy::Budget)),
                   Some(TierKind::SsInt8));
    }

    #[test]
    fn numeric_bounds_buy_the_cheapest_fitting_tier() {
        let p = AdmissionPolicy::new(None, all_tiers(), 128);
        let at = |e| p.decide(64, Some(Accuracy::Bound(e))).unwrap();
        assert_eq!(at(0.1), TierKind::SsInt8);
        assert_eq!(at(0.03), TierKind::SsBf16);
        assert_eq!(at(0.02), TierKind::SsF32);
        assert_eq!(at(0.001), TierKind::FullF32);
        assert_eq!(at(0.0), TierKind::FullF32);
    }

    #[test]
    fn forced_tier_overrides_every_request() {
        let p = AdmissionPolicy::new(Some(TierKind::SsBf16), all_tiers(), 128);
        assert_eq!(p.decide(5, None), Some(TierKind::SsBf16));
        assert_eq!(p.decide(5, Some(Accuracy::High)),
                   Some(TierKind::SsBf16));
    }

    #[test]
    fn unavailable_tiers_fall_back_toward_precision() {
        // ss tiers unbuildable (landmark divisor vs buckets): every
        // budgeted request lands on the exact tier rather than failing
        let p = AdmissionPolicy::new(None, vec![TierKind::FullF32], 128);
        assert_eq!(p.decide(64, Some(Accuracy::Budget)),
                   Some(TierKind::FullF32));
        assert_eq!(p.decide(500, Some(Accuracy::Balanced)),
                   Some(TierKind::FullF32));
        // a forced unbuildable tier falls back the same way
        let f = AdmissionPolicy::new(Some(TierKind::SsInt8),
                                     vec![TierKind::FullF32], 128);
        assert_eq!(f.decide(5, None), Some(TierKind::FullF32));
    }

    #[test]
    #[should_panic(expected = "full-f32")]
    fn policies_without_the_reference_tier_are_construction_bugs() {
        AdmissionPolicy::new(None, vec![TierKind::SsInt8], 128);
    }

    #[test]
    fn describe_names_the_policy_and_tiers() {
        let p = AdmissionPolicy::new(None, all_tiers(), 128);
        assert_eq!(p.describe(),
                   "policy=auto tiers=full-f32,ss-f32,ss-bf16,ss-int8");
        let f = AdmissionPolicy::new(Some(TierKind::SsInt8),
                                     vec![TierKind::FullF32,
                                          TierKind::SsInt8], 128);
        assert_eq!(f.describe(),
                   "policy=forced-ss-int8 tiers=full-f32,ss-int8");
    }

    #[test]
    fn knob_resolution_defers_to_the_env_ladder() {
        // the env var is process-global, so only the unset path is
        // asserted here (the CI admission lane exercises the override)
        if std::env::var("SSAF_ADMISSION").is_err() {
            assert_eq!(resolve_admission(None), None);
            assert_eq!(resolve_admission(Some(TierKind::SsF32)),
                       Some(TierKind::SsF32));
        }
    }
}
