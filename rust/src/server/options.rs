//! The ENCODE option grammar — ONE parser for every wire entry point.
//!
//! `ENCODE <id> [KEY=VALUE ...] <tok> <tok> ...`: any `KEY=VALUE`
//! tokens (key: one or more of `[A-Z_]`) before the first bare token
//! are request options; the first token that is not of that shape ends
//! the option prefix and starts the payload. Both the replica
//! ([`dispatch`](super::dispatch)) and the cluster router
//! (`coordinator::cluster::dispatch_router`) parse through this module,
//! so the grammar cannot drift between tiers — the PR-9 era hardcoded
//! a single `DEADLINE_MS=` peek in two places.
//!
//! Recognized keys:
//!
//! * `DEADLINE_MS=<ms>` — end-to-end deadline budget. A non-numeric
//!   value keeps its historical error token `bad-deadline`.
//! * `ACCURACY=<high|balanced|budget|float>` — accuracy budget for the
//!   admission policy (`coordinator::admission`).
//!
//! Fail-closed rules (all answered `ERR <id> bad-option`):
//!
//! * unknown keys — a typo'd option must not silently become a dropped
//!   token;
//! * duplicate keys — two values for one knob have no right answer;
//! * empty values (`KEY=`);
//! * oversized lists (> [`MAX_OPTIONS`]) or values
//!   (> [`MAX_VALUE_LEN`] bytes) — wire hygiene against hostile lines.
//!
//! An option-shaped token *after* the first bare token is payload, not
//! an option; like any non-numeric payload token it is skipped by the
//! token parse (unchanged from the pre-grammar behavior).
//!
//! Options round-trip: [`WireOptions::render_extras`] re-serializes
//! the non-deadline options from their original spellings, which is
//! what lets the router forward them verbatim (property-tested below).

use crate::coordinator::admission::Accuracy;

/// Most options one line may carry.
pub const MAX_OPTIONS: usize = 8;
/// Longest accepted option value, in bytes.
pub const MAX_VALUE_LEN: usize = 64;

/// Why an option prefix was rejected. [`OptionError::err_token`] is
/// the wire error token (see the taxonomy in [`super`]'s docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptionError {
    /// Unknown key, duplicate key, empty value, oversized list/value,
    /// or an unparsable `ACCURACY` value.
    BadOption,
    /// `DEADLINE_MS` with a non-numeric value — kept on its historical
    /// error token so pre-grammar clients see unchanged replies.
    BadDeadline,
}

impl OptionError {
    pub fn err_token(self) -> &'static str {
        match self {
            OptionError::BadOption => "bad-option",
            OptionError::BadDeadline => "bad-deadline",
        }
    }
}

/// The parsed option prefix of one ENCODE line.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WireOptions {
    /// `DEADLINE_MS=` value, if present.
    pub deadline_ms: Option<u64>,
    /// `ACCURACY=` value, if present (parsed form).
    pub accuracy: Option<Accuracy>,
    /// Every accepted `(key, value)` pair in wire order, original
    /// spellings — the verbatim-forwarding source.
    raw: Vec<(String, String)>,
}

impl WireOptions {
    /// Whether any option beyond `DEADLINE_MS` is present — the
    /// routing caches key on tokens alone, so such requests must
    /// bypass them (`coordinator` cache-coherence invariant).
    pub fn has_extras(&self) -> bool {
        self.raw.iter().any(|(k, _)| k != "DEADLINE_MS")
    }

    /// Re-serialize the non-deadline options (wire order, original
    /// spellings), e.g. `"ACCURACY=budget"`. Empty string when none.
    /// The deadline is excluded because the router re-derives it from
    /// the remaining budget.
    pub fn render_extras(&self) -> String {
        let parts: Vec<String> = self
            .raw
            .iter()
            .filter(|(k, _)| k != "DEADLINE_MS")
            .map(|(k, v)| format!("{k}={v}"))
            .collect();
        parts.join(" ")
    }
}

/// Whether a token has the option shape `[A-Z_]+=...`.
fn is_option_token(tok: &str) -> bool {
    match tok.split_once('=') {
        Some((key, _)) => {
            !key.is_empty()
                && key.bytes().all(|b| b == b'_' || b.is_ascii_uppercase())
        }
        None => false,
    }
}

/// Consume the option prefix from `parts`, leaving the payload tokens
/// unconsumed. The single grammar implementation — both wire
/// dispatchers call exactly this.
pub fn parse_options<'a, I>(parts: &mut std::iter::Peekable<I>)
                            -> Result<WireOptions, OptionError>
where
    I: Iterator<Item = &'a str>,
{
    let mut opts = WireOptions::default();
    while let Some(&tok) = parts.peek() {
        if !is_option_token(tok) {
            break;
        }
        parts.next();
        if opts.raw.len() == MAX_OPTIONS {
            return Err(OptionError::BadOption);
        }
        let (key, value) = tok.split_once('=').expect("option shape");
        if value.is_empty() || value.len() > MAX_VALUE_LEN {
            return Err(OptionError::BadOption);
        }
        if opts.raw.iter().any(|(k, _)| k == key) {
            return Err(OptionError::BadOption);
        }
        match key {
            "DEADLINE_MS" => {
                let ms = value.parse::<u64>()
                    .map_err(|_| OptionError::BadDeadline)?;
                opts.deadline_ms = Some(ms);
            }
            "ACCURACY" => {
                opts.accuracy = Some(
                    Accuracy::parse(value).ok_or(OptionError::BadOption)?);
            }
            _ => return Err(OptionError::BadOption),
        }
        opts.raw.push((key.to_string(), value.to_string()));
    }
    Ok(opts)
}

/// Parse an option prefix from a whole string (testing / router
/// convenience): returns the options and the remaining payload slice
/// of tokens.
pub fn parse_option_str(s: &str)
                        -> Result<(WireOptions, Vec<&str>), OptionError> {
    let mut parts = s.split_whitespace().peekable();
    let opts = parse_options(&mut parts)?;
    Ok((opts, parts.collect()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest_mini::{prop_assert, run};

    #[test]
    fn empty_prefix_parses_to_defaults() {
        let (o, rest) = parse_option_str("1 2 3").unwrap();
        assert_eq!(o, WireOptions::default());
        assert_eq!(rest, vec!["1", "2", "3"]);
        assert!(!o.has_extras());
        assert_eq!(o.render_extras(), "");
    }

    #[test]
    fn recognized_keys_parse_in_any_order() {
        let (o, rest) =
            parse_option_str("DEADLINE_MS=250 ACCURACY=budget 5 6").unwrap();
        assert_eq!(o.deadline_ms, Some(250));
        assert_eq!(o.accuracy, Some(Accuracy::Budget));
        assert_eq!(rest, vec!["5", "6"]);
        let (o2, _) =
            parse_option_str("ACCURACY=0.05 DEADLINE_MS=9 7").unwrap();
        assert_eq!(o2.accuracy, Some(Accuracy::Bound(0.05)));
        assert_eq!(o2.deadline_ms, Some(9));
    }

    #[test]
    fn extras_exclude_the_deadline_and_keep_spelling() {
        let (o, _) =
            parse_option_str("DEADLINE_MS=250 ACCURACY=0.050 1").unwrap();
        assert!(o.has_extras());
        // original spelling "0.050" survives for verbatim forwarding
        assert_eq!(o.render_extras(), "ACCURACY=0.050");
        let (d, _) = parse_option_str("DEADLINE_MS=250 1").unwrap();
        assert!(!d.has_extras());
        assert_eq!(d.render_extras(), "");
    }

    #[test]
    fn unknown_duplicate_empty_and_oversized_fail_closed() {
        assert_eq!(parse_option_str("PRIORITY=3 1").unwrap_err(),
                   OptionError::BadOption);
        assert_eq!(parse_option_str("ACCURACY=high ACCURACY=budget 1")
                       .unwrap_err(),
                   OptionError::BadOption);
        assert_eq!(parse_option_str("DEADLINE_MS=5 DEADLINE_MS=5 1")
                       .unwrap_err(),
                   OptionError::BadOption);
        assert_eq!(parse_option_str("ACCURACY= 1").unwrap_err(),
                   OptionError::BadOption);
        let huge = format!("ACCURACY={} 1", "9".repeat(MAX_VALUE_LEN + 1));
        assert_eq!(parse_option_str(&huge).unwrap_err(),
                   OptionError::BadOption);
        // a long hostile option list dies on its first bad key (the
        // MAX_OPTIONS bound guards the day more keys are recognized)
        let many: String = (0..=MAX_OPTIONS)
            .map(|i| format!("K{}=1 ", "E".repeat(i + 1)))
            .collect();
        assert_eq!(parse_option_str(&format!("{many}1")).unwrap_err(),
                   OptionError::BadOption);
    }

    #[test]
    fn bad_deadline_keeps_its_historical_error_token() {
        assert_eq!(parse_option_str("DEADLINE_MS=abc 1").unwrap_err(),
                   OptionError::BadDeadline);
        assert_eq!(parse_option_str("DEADLINE_MS=-1 1").unwrap_err(),
                   OptionError::BadDeadline);
        assert_eq!(OptionError::BadDeadline.err_token(), "bad-deadline");
        assert_eq!(OptionError::BadOption.err_token(), "bad-option");
    }

    #[test]
    fn accuracy_values_validate_at_parse_time() {
        assert!(parse_option_str("ACCURACY=high 1").is_ok());
        assert!(parse_option_str("ACCURACY=0.03 1").is_ok());
        assert_eq!(parse_option_str("ACCURACY=speedy 1").unwrap_err(),
                   OptionError::BadOption);
        assert_eq!(parse_option_str("ACCURACY=-0.5 1").unwrap_err(),
                   OptionError::BadOption);
    }

    #[test]
    fn option_shaped_tokens_after_payload_are_payload() {
        // the prefix ends at the first bare token; later option-shaped
        // tokens are (non-numeric, skipped) payload — unchanged from
        // the pre-grammar parse
        let (o, rest) = parse_option_str("5 ACCURACY=budget 6").unwrap();
        assert_eq!(o, WireOptions::default());
        assert_eq!(rest, vec!["5", "ACCURACY=budget", "6"]);
        // lowercase keys never look like options
        let (o2, rest2) = parse_option_str("accuracy=high 1").unwrap();
        assert_eq!(o2, WireOptions::default());
        assert_eq!(rest2, vec!["accuracy=high", "1"]);
    }

    #[test]
    fn property_options_round_trip_through_render() {
        // any accepted prefix re-serializes (deadline re-attached) to a
        // line that parses back to the same options
        run(100, |g| {
            let mut line = String::new();
            let deadline = g.usize_in(0, 2) > 0;
            if deadline {
                line.push_str(&format!("DEADLINE_MS={} ",
                                       g.usize_in(0, 10_000)));
            }
            let acc = match g.usize_in(0, 4) {
                0 => None,
                1 => Some("high".to_string()),
                2 => Some("balanced".to_string()),
                3 => Some("budget".to_string()),
                _ => Some(format!("0.{:03}", g.usize_in(1, 999))),
            };
            if let Some(a) = &acc {
                line.push_str(&format!("ACCURACY={a} "));
            }
            line.push_str("1 2 3");
            let (o, rest) = parse_option_str(&line)
                .map_err(|e| format!("{line:?} rejected: {e:?}"))?;
            prop_assert(rest == vec!["1", "2", "3"], "payload survived")?;
            // rebuild from the parsed form and re-parse: fixed point
            let mut rebuilt = String::new();
            if let Some(ms) = o.deadline_ms {
                rebuilt.push_str(&format!("DEADLINE_MS={ms} "));
            }
            let extras = o.render_extras();
            if !extras.is_empty() {
                rebuilt.push_str(&extras);
                rebuilt.push(' ');
            }
            rebuilt.push_str("1 2 3");
            let (o2, _) = parse_option_str(&rebuilt)
                .map_err(|e| format!("{rebuilt:?} rejected: {e:?}"))?;
            prop_assert(o2 == o, format!("{line:?} → {rebuilt:?} drifted"))
        });
    }

    #[test]
    fn property_duplicates_and_unknowns_always_reject() {
        run(100, |g| {
            let key = match g.usize_in(0, 2) {
                0 => "DEADLINE_MS".to_string(),
                1 => "ACCURACY".to_string(),
                // unknown key of random length
                _ => "X".repeat(g.usize_in(1, 12)),
            };
            let known = key == "DEADLINE_MS" || key == "ACCURACY";
            let value = if key == "ACCURACY" { "high" } else { "5" };
            let dup = format!("{key}={value} {key}={value} 1");
            let r = parse_option_str(&dup);
            prop_assert(r.is_err(), format!("{dup:?} accepted"))?;
            if !known {
                let single = format!("{key}={value} 1");
                prop_assert(parse_option_str(&single).is_err(),
                            format!("{single:?} accepted"))?;
            }
            Ok(())
        });
    }
}
