//! TCP line-protocol server + client (S16).
//!
//! # Protocol specification
//!
//! Newline-delimited ASCII; one request line yields one reply (or one
//! `.`-terminated block). Backend-agnostic: the same wire format is
//! served by the XLA and CPU execution backends.
//!
//! ## Requests
//!
//! ```text
//! ENCODE <id> [KEY=VALUE ...] <tok1> <tok2> ... \n
//!                                      encode a token sequence
//! STATS\n                              metrics + backend report
//! PING\n                               liveness probe → `OK 0 pong q=<depth>`
//! QUIT\n                               close this connection
//! ```
//!
//! `<id>` is an arbitrary non-negative integer echoed back verbatim —
//! correlation only, no server-side meaning. Any `KEY=VALUE` tokens
//! (key: `[A-Z_]+`) between the id and the first bare token are
//! request **options**, parsed by the [`options`] grammar shared with
//! the cluster router. Recognized keys:
//!
//! * `DEADLINE_MS=<ms>` — deadline budget, as before.
//! * `ACCURACY=<high|balanced|budget|float>` — accuracy budget for the
//!   admission policy ([`coordinator::admission`](crate::coordinator::admission)):
//!   named tiers or a numeric relative-error bound. The policy maps it
//!   to a `(variant, precision)` tier; the served tier is echoed in the
//!   `OK` reply and metered on the `admission:` STATS line.
//!
//! Unknown keys, duplicate keys, empty or oversized values are
//! answered `ERR <id> bad-option` — an option must never silently
//! degrade to a skipped payload token. The
//! `DEADLINE_MS=<ms>` option gives the
//! request a deadline budget. A request whose deadline expires
//! **before its batch is formed** is answered `ERR <id> deadline`
//! instead of being served late, and never occupies a batch slot;
//! enforcement points are admission, early batch close
//! (`deadline_margin_ms` before expiry), and batch pop. A request
//! already inside an executing batch is never aborted: if execution
//! itself overruns the deadline, the (still-correct) embedding is
//! delivered late as `OK` — clients with hard cutoffs should discard
//! replies past their own deadline. Omitting the field applies the
//! server's configured `default_deadline_ms` (0 = no deadline). Tokens
//! that fail to parse as `i32` are skipped; out-of-vocabulary ids are
//! accepted (the CPU model wraps them into range).
//!
//! A sequence longer than the largest bucket is **chunked** when the
//! server runs with `chunk_tokens > 0`: the coordinator splits it into
//! fixed-size chunks, encodes each as an independent sequence (reusing
//! prefix-cache hits where prior traffic shared chunks), merges the
//! pooled chunk embeddings length-weighted, and answers with a single
//! `OK` reply — the wire shape is identical to a short request. With
//! `chunk_tokens = 0` such requests are rejected `too-long` as before.
//!
//! ## Responses
//!
//! ```text
//! OK <id> <f1> ... <f8>[ tier=<t>]\n  first 8 embedding dims, %.5f
//! ERR <id> <reason>\n                 request failed, see taxonomy
//! ```
//!
//! The ` tier=<t>` suffix appears only on replies the admission policy
//! routed to a non-default tier (`full-f32`, `ss-f32`, `ss-bf16`,
//! `ss-int8`); untagged requests under an `auto` policy reply exactly
//! as before — byte-identical to pre-admission servers.
//!
//! ## `ERR` taxonomy
//!
//! | reason                  | meaning                                      |
//! |-------------------------|----------------------------------------------|
//! | `bad-id`                | `ENCODE` id missing or not a `u64`           |
//! | `bad-deadline`          | `DEADLINE_MS=` value not a `u64`             |
//! | `bad-option`            | unknown/duplicate option key, empty or       |
//! |                         | oversized value, bad `ACCURACY` value        |
//! | `empty`                 | no valid tokens in the request               |
//! | `too-long-<n>-max-<m>`  | length n exceeds the largest bucket m        |
//! |                         | (only when chunking is off: `chunk_tokens=0`)|
//! | `queue-full`            | admission backpressure; retry later          |
//! | `deadline`              | deadline expired before execution; the       |
//! |                         | request consumed no batch slot               |
//! | `shutting-down`         | coordinator is draining; do not retry here   |
//! | `replica-lost`          | (router front-end only) every replica that   |
//! |                         | could serve the request failed mid-flight;   |
//! |                         | the request was accepted, retried on live    |
//! |                         | replicas, and is reported lost — never       |
//! |                         | silently dropped. See [`coordinator::cluster`](crate::coordinator::cluster). |
//! | `unknown-command`       | first word not ENCODE/STATS/PING/QUIT        |
//! | *anything else*         | execution failure, whitespace dashed         |
//!
//! `PING` exists for the cluster tier's health probes: the router
//! front-end ([`coordinator::cluster`](crate::coordinator::cluster))
//! marks a replica up/down by round-tripping `PING` on its probe
//! interval. The reply carries the replica's instantaneous queue depth
//! as a ` q=<depth>` suffix — the backpressure signal the router's
//! placement uses to shed load from a saturated first ring choice to
//! the runner-up. Probes only require the `OK` prefix, so old routers
//! interoperate with new replicas. Router-mode processes speak the same
//! wire protocol and extend `STATS` with `cluster:` lines (membership,
//! forward/retry counters) — field reference in `OPERATIONS.md`.
//!
//! ## `STATS` report
//!
//! A multi-line block terminated by a lone `.` (each field is specified
//! operator-style in `OPERATIONS.md`):
//!
//! ```text
//! backend:  <cpu-kernels|xla-pjrt>     which execution backend is live
//! model:    L layers, variant=<op[,op…]>, d_model=D, heads=H, ffn_mult=M, projections=<on|off>, weights=<seeded|loaded>
//! kernel:   <arm> (detected <arm>, gemm KC=.. NC=..)   active micro-kernel arm
//! workers:  N (S queue shards, cache L/C)   worker pool + cache shape
//! policy:   policy=<auto|forced-<tier>> tiers=<t1,...>   admission policy
//! requests: in=N done=N rejected=N expired=N   admission counters
//! cache:    hits=N misses=N (H% hit rate)
//! prefix:   hits=N misses=N chunks=N (H% hit rate)   chunked long-doc path
//! admission: configured=N full-f32=N ss-f32=N ss-bf16=N ss-int8=N
//! batches:  N (avg fill F req/batch, occupancy P%)
//! tokens:   N (+P executed padding, W% waste)
//! queue:    n=.. mean=..us p50=..us p99=..us max=..us
//! exec:     per-batch execution latency histogram (same fields)
//! e2e:      submit→response latency histogram (same fields)
//! .
//! ```
//!
//! `model` identifies the served function: encoder depth (1 = the
//! seed single-pass model; deeper stacks add pre-LN blocks), the
//! attention operator behind the `AttentionOp` seam (one per block
//! when per-layer mixing is configured), the widths, whether full
//! blocks run QKV/output projections, and whether the encoder weights
//! are the seeded draw or a loaded checkpoint — on the XLA backend it
//! reads `artifact encoder, variant=…` instead.
//! `occupancy` is batch-served requests per offered batch slot (cache
//! hits bypass batching and are excluded); `executed padding` counts
//! padding positions the backend actually computed (dense remainder on
//! XLA, landmark-alignment tails on CPU) — the padding-waste signal for
//! batcher tuning. `expired` counts deadline misses, which appear in
//! neither `done` nor `rejected`. The `prefix:` line meters the chunked
//! long-document path: `hits`/`misses` are per-chunk prefix-cache
//! lookups, `chunks` counts chunk executions — a chunked document is
//! one logical request in the `requests:` line (admitted once, done
//! once) while its per-chunk compute shows up here. The `policy:` line
//! is the live admission policy (forced via the `[serving] admission`
//! knob or `SSAF_ADMISSION`; `policy=unavailable` on the artifact
//! backend) and the `admission:` line counts where requests actually
//! landed: `configured` is the untagged/default path, the per-tier
//! fields count tier-routed requests (a chunked document counts once).
//!
//! Deliberately minimal — the protocol exists so the serving stack can
//! be exercised end-to-end over a real socket (examples/serve_attention,
//! tests/integration_cpu_serving.rs and the E8 bench drive it).

pub mod options;

use crate::coordinator::{Coordinator, EncodeRequest, SubmitError};
use crate::minirt::ThreadPool;
use options::parse_options;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

/// Deterministic fault injection on the replica connection layer — the
/// test seam behind `rust/tests/integration_cluster.rs`. A plan is
/// seeded and *purely arithmetic*: which connections it affects depends
/// only on `(accept order, seed, every_nth)`, never on wall-clock or
/// thread scheduling, so a failing scenario replays bit-for-bit.
///
/// Faults model the three replica failure modes the cluster router must
/// survive:
///
/// * `refuse_accept` — the process is up but not serving: affected
///   connections are closed at accept before a byte is exchanged
///   (connection refused, as seen by the router).
/// * `drop_after_bytes` — a replica dies mid-reply: affected
///   connections deliver at most this many reply bytes (the last line
///   may be truncated mid-float) and are then hard-closed. This is the
///   "kill a replica mid-batch" scenario.
/// * `response_delay` — a slow replica: every reply on affected
///   connections is delayed by this much before the first byte, long
///   enough to blow past `deadline_margin` in the deadline tests.
///
/// `every_nth` selects which accepted connections the plan affects:
/// connection `i` (0-based accept order) is affected iff
/// `every_nth <= 1` (all of them) or `(i + seed) % every_nth == 0`.
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultPlan {
    /// Mixed into connection selection so distinct scenarios affect
    /// distinct connection subsets without changing `every_nth`.
    pub seed: u64,
    /// Close affected connections at accept, before any I/O.
    pub refuse_accept: bool,
    /// Hard-close affected connections after this many reply bytes.
    pub drop_after_bytes: Option<usize>,
    /// Sleep this long before every reply on affected connections.
    pub response_delay: Option<Duration>,
    /// Affect every n-th accepted connection (`<= 1` = all).
    pub every_nth: u64,
}

impl FaultPlan {
    /// Does this plan fire on the `conn_index`-th accepted connection?
    pub fn affects(&self, conn_index: u64) -> bool {
        self.every_nth <= 1 || (conn_index + self.seed) % self.every_nth == 0
    }
}

/// Per-connection fault state derived from a [`FaultPlan`] at accept
/// time: the remaining reply-byte budget and the per-reply delay.
struct ConnFaults {
    delay: Option<Duration>,
    budget: Option<usize>,
}

/// Serve until `coordinator` shuts down or the listener errors.
/// Returns the bound address (useful with port 0).
pub fn serve(coordinator: Arc<Coordinator>, bind: &str, pool_size: usize)
             -> std::io::Result<(std::net::SocketAddr, ServerHandle)> {
    serve_with_faults(coordinator, bind, pool_size, None)
}

/// [`serve`] with a deterministic [`FaultPlan`] applied to accepted
/// connections — the replica side of the cluster fault-injection
/// harness. `None` is exactly [`serve`]; production entry points never
/// pass a plan.
pub fn serve_with_faults(coordinator: Arc<Coordinator>, bind: &str,
                         pool_size: usize, faults: Option<FaultPlan>)
                         -> std::io::Result<(std::net::SocketAddr, ServerHandle)> {
    let listener = TcpListener::bind(bind)?;
    let addr = listener.local_addr()?;
    let stop = crate::minirt::CancelToken::new();
    let accept_stop = stop.clone();
    let handle_thread = std::thread::Builder::new()
        .name("ssaformer-acceptor".into())
        .spawn(move || {
            let pool = ThreadPool::new(pool_size);
            listener
                .set_nonblocking(false)
                .expect("listener blocking mode");
            // accept loop with a poll-ish stop check via timeout
            listener.set_nonblocking(true).ok();
            let mut conn_index: u64 = 0;
            loop {
                if accept_stop.is_cancelled() {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        let fired = faults
                            .filter(|f| f.affects(conn_index));
                        conn_index += 1;
                        if fired.map_or(false, |f| f.refuse_accept) {
                            drop(stream); // close before any I/O
                            continue;
                        }
                        let conn_faults = fired.map(|f| ConnFaults {
                            delay: f.response_delay,
                            budget: f.drop_after_bytes,
                        });
                        let c = coordinator.clone();
                        let stop = accept_stop.clone();
                        pool.execute(move || {
                            handle_conn(stream, &c, &stop, conn_faults)
                        });
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
            pool.shutdown();
        })?;
    Ok((addr, ServerHandle { stop, thread: Some(handle_thread) }))
}

/// Handle to stop the acceptor loop.
pub struct ServerHandle {
    stop: crate::minirt::CancelToken,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    pub fn stop(mut self) {
        self.stop.cancel();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop.cancel();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn handle_conn(stream: TcpStream, coordinator: &Coordinator,
               stop: &crate::minirt::CancelToken,
               mut faults: Option<ConnFaults>) {
    let peer = stream.peer_addr().ok();
    // Read timeout so handler threads can observe shutdown instead of
    // blocking forever on an idle connection (ServerHandle::stop joins
    // the pool — without this, a connected-but-quiet client deadlocks
    // shutdown).
    let _ = stream.set_read_timeout(Some(std::time::Duration::from_millis(200)));
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    // NOTE: `line` is NOT cleared on timeout — read_line may have
    // appended a partial line before the timeout fired and the rest
    // arrives on the next read.
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => break,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock
                || e.kind() == std::io::ErrorKind::TimedOut => {
                if stop.is_cancelled() {
                    break;
                }
                continue;
            }
            Err(_) => break,
            Ok(_) if !line.ends_with('\n') => continue, // partial line
            Ok(_) => {}
        }
        let trimmed = line.trim().to_string();
        line.clear();
        if trimmed.is_empty() {
            continue;
        }
        let reply = dispatch(&trimmed, coordinator);
        // fault seam: delay and/or truncate the reply, deterministically
        if let Some(f) = faults.as_mut() {
            if let Some(d) = f.delay {
                std::thread::sleep(d);
            }
            if let Some(budget) = f.budget.as_mut() {
                let bytes = reply.as_bytes();
                if bytes.len() >= *budget {
                    // deliver exactly the remaining budget (possibly
                    // truncating mid-line) and hard-close: the client
                    // sees a partial reply then EOF, like a replica
                    // dying mid-batch
                    let _ = writer.write_all(&bytes[..*budget]);
                    let _ = writer.flush();
                    let _ = writer.shutdown(std::net::Shutdown::Both);
                    break;
                }
                *budget -= bytes.len();
            }
        }
        if writer.write_all(reply.as_bytes()).is_err() {
            break;
        }
        if trimmed == "QUIT" {
            break;
        }
    }
    let _ = peer;
}

/// Parse + execute one protocol line (pure w.r.t. the socket; separately
/// unit-tested).
pub fn dispatch(line: &str, coordinator: &Coordinator) -> String {
    let mut parts = line.split_whitespace().peekable();
    match parts.next() {
        Some("ENCODE") => {
            let Some(id) = parts.next().and_then(|s| s.parse::<u64>().ok()) else {
                return "ERR 0 bad-id\n".into();
            };
            // option prefix, directly after the id — the one shared
            // grammar (options::parse_options), never an ad-hoc peek
            let opts = match parse_options(&mut parts) {
                Ok(o) => o,
                Err(e) => return format!("ERR {id} {}\n", e.err_token()),
            };
            let deadline = opts.deadline_ms
                .map(std::time::Duration::from_millis);
            let tokens: Vec<i32> = parts.filter_map(|t| t.parse().ok()).collect();
            let req = EncodeRequest::new(tokens)
                .deadline_opt(deadline)
                .accuracy_opt(opts.accuracy);
            let submitted = coordinator
                .submit(req)
                .and_then(|rx| rx.recv().map_err(|_| SubmitError::ShuttingDown));
            match submitted {
                Ok(resp) => match resp.embedding {
                    Ok(emb) => {
                        let head: Vec<String> = emb
                            .iter()
                            .take(8)
                            .map(|x| format!("{x:.5}"))
                            .collect();
                        match resp.tier {
                            Some(t) => format!("OK {id} {} tier={}\n",
                                               head.join(" "), t.token()),
                            None => format!("OK {id} {}\n", head.join(" ")),
                        }
                    }
                    Err(e) => format!("ERR {id} {}\n", sanitize(&e)),
                },
                Err(SubmitError::QueueFull) => format!("ERR {id} queue-full\n"),
                Err(SubmitError::TooLong { len, max }) => {
                    format!("ERR {id} too-long-{len}-max-{max}\n")
                }
                Err(SubmitError::Empty) => format!("ERR {id} empty\n"),
                Err(SubmitError::DeadlineExpired) => format!("ERR {id} deadline\n"),
                Err(SubmitError::ShuttingDown) => format!("ERR {id} shutting-down\n"),
            }
        }
        Some("STATS") => {
            let cache = match coordinator.cache_capacity() {
                0 => "off".to_string(),
                cap => format!("{}/{}", coordinator.cache_len(), cap),
            };
            format!("backend:  {}\nmodel:    {}\nkernel:   {}\nworkers:  {} \
                     ({} queue shards, cache {})\npolicy:   {}\n{}\n.\n",
                    coordinator.backend().name(),
                    coordinator.model_desc(),
                    coordinator.kernel_desc(),
                    coordinator.workers(),
                    coordinator.queue_shards(),
                    cache,
                    coordinator.admission_desc(),
                    coordinator.metrics.report())
        }
        // liveness probe for the cluster tier's health checks: cheap,
        // never blocks on a worker. The queue-depth suffix is the
        // backpressure signal the router's placement reads at probe time.
        Some("PING") => format!("OK 0 pong q={}\n", coordinator.queue_depth()),
        Some("QUIT") => "OK 0 bye\n".into(),
        _ => "ERR 0 unknown-command\n".into(),
    }
}

fn sanitize(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_whitespace() { '-' } else { c })
        .collect()
}

/// Minimal blocking client for the line protocol (examples + benches).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    /// Send ENCODE and wait for the reply line.
    pub fn encode(&mut self, id: u64, tokens: &[i32]) -> std::io::Result<String> {
        let toks: Vec<String> = tokens.iter().map(|t| t.to_string()).collect();
        writeln!(self.writer, "ENCODE {id} {}", toks.join(" "))?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(line.trim().to_string())
    }

    /// Send ENCODE with a `DEADLINE_MS=` budget and wait for the reply
    /// line (`ERR <id> deadline` when the budget is blown).
    pub fn encode_with_deadline(&mut self, id: u64, tokens: &[i32],
                                deadline_ms: u64) -> std::io::Result<String> {
        let toks: Vec<String> = tokens.iter().map(|t| t.to_string()).collect();
        writeln!(self.writer, "ENCODE {id} DEADLINE_MS={deadline_ms} {}",
                 toks.join(" "))?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(line.trim().to_string())
    }

    /// Send ENCODE with an arbitrary pre-rendered option prefix (e.g.
    /// `"ACCURACY=budget DEADLINE_MS=50"`) and wait for the reply line.
    /// An empty `opts` degrades to [`Client::encode`]'s wire shape.
    pub fn encode_with(&mut self, id: u64, opts: &str, tokens: &[i32])
                       -> std::io::Result<String> {
        let toks: Vec<String> = tokens.iter().map(|t| t.to_string()).collect();
        if opts.is_empty() {
            writeln!(self.writer, "ENCODE {id} {}", toks.join(" "))?;
        } else {
            writeln!(self.writer, "ENCODE {id} {opts} {}", toks.join(" "))?;
        }
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(line.trim().to_string())
    }

    /// Round-trip a liveness probe; returns the reply line
    /// (`OK 0 pong q=<depth>` from a healthy server, where `q=` is the
    /// instantaneous coordinator queue depth).
    pub fn ping(&mut self) -> std::io::Result<String> {
        writeln!(self.writer, "PING")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(line.trim().to_string())
    }

    /// Fetch the metrics report.
    pub fn stats(&mut self) -> std::io::Result<String> {
        writeln!(self.writer, "STATS")?;
        let mut out = String::new();
        loop {
            let mut line = String::new();
            if self.reader.read_line(&mut line)? == 0 {
                break;
            }
            if line.trim() == "." {
                break;
            }
            out.push_str(&line);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sanitize_replaces_whitespace() {
        assert_eq!(sanitize("a b\tc"), "a-b-c");
    }

    #[test]
    fn fault_plan_selection_is_deterministic_arithmetic() {
        // every_nth <= 1 affects every connection
        let all = FaultPlan { every_nth: 0, ..Default::default() };
        assert!((0..8).all(|i| all.affects(i)));
        let all = FaultPlan { every_nth: 1, ..Default::default() };
        assert!((0..8).all(|i| all.affects(i)));
        // every_nth = 3, seed 0: connections 0, 3, 6, ...
        let p = FaultPlan { every_nth: 3, ..Default::default() };
        let hit: Vec<u64> = (0..9).filter(|&i| p.affects(i)).collect();
        assert_eq!(hit, vec![0, 3, 6]);
        // the seed shifts the affected subset without changing its size
        let p = FaultPlan { every_nth: 3, seed: 1, ..Default::default() };
        let hit: Vec<u64> = (0..9).filter(|&i| p.affects(i)).collect();
        assert_eq!(hit, vec![2, 5, 8]);
        // and the same plan always selects the same subset
        let again: Vec<u64> = (0..9).filter(|&i| p.affects(i)).collect();
        assert_eq!(hit, again);
    }

    // dispatch() against a live coordinator is covered by
    // rust/tests/integration_cpu_serving.rs; the FaultPlan seam
    // end-to-end (drop/delay/refuse over real sockets) by
    // rust/tests/integration_cluster.rs.
}
