//! Matrix norms used throughout the error analyses.

use super::matmul::matmul;
use super::matrix::Matrix;

/// Frobenius norm.
pub fn fro(a: &Matrix) -> f64 {
    a.data().iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// ‖A‖₁: max column absolute sum.
pub fn one(a: &Matrix) -> f64 {
    let mut best: f64 = 0.0;
    for j in 0..a.cols() {
        let s: f64 = (0..a.rows()).map(|i| a[(i, j)].abs()).sum();
        best = best.max(s);
    }
    best
}

/// ‖A‖∞: max row absolute sum (the norm in the paper's eq 12 bound).
pub fn inf(a: &Matrix) -> f64 {
    a.data()
        .chunks(a.cols().max(1))
        .map(|r| r.iter().map(|x| x.abs()).sum::<f64>())
        .fold(0.0, f64::max)
}

/// Spectral norm ‖A‖₂ via power iteration on AᵀA.
pub fn spectral(a: &Matrix, iters: usize) -> f64 {
    let g = matmul(&a.transpose(), a); // n×n PSD
    let n = g.rows();
    if n == 0 {
        return 0.0;
    }
    let mut x = vec![1.0 / (n as f64).sqrt(); n];
    let mut lam = 0.0;
    for _ in 0..iters {
        let y = super::matmul::matvec(&g, &x);
        let norm = y.iter().map(|v| v * v).sum::<f64>().sqrt();
        if norm < f64::MIN_POSITIVE {
            return 0.0;
        }
        lam = norm;
        for (xi, yi) in x.iter_mut().zip(&y) {
            *xi = yi / norm;
        }
    }
    lam.sqrt()
}

/// Max absolute entry.
pub fn max_abs(a: &Matrix) -> f64 {
    a.data().iter().map(|x| x.abs()).fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norms_of_identity() {
        let i = Matrix::eye(4);
        assert_eq!(fro(&i), 2.0);
        assert_eq!(one(&i), 1.0);
        assert_eq!(inf(&i), 1.0);
        assert!((spectral(&i, 30) - 1.0).abs() < 1e-10);
        assert_eq!(max_abs(&i), 1.0);
    }

    #[test]
    fn known_asymmetric() {
        let a = Matrix::from_vec(2, 2, vec![1.0, -2.0, 3.0, 4.0]);
        assert_eq!(one(&a), 6.0); // col 1: |−2|+|4| = 6
        assert_eq!(inf(&a), 7.0); // row 1: |3|+|4| = 7
        assert_eq!(max_abs(&a), 4.0);
        assert!((fro(&a) - (30.0f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn spectral_matches_largest_singular_value() {
        let mut rng = crate::rngx::Rng::new(13);
        let a = Matrix::from_fn(9, 6, |_, _| rng.normal());
        let s = crate::linalg::svd::singular_values(&a);
        assert!((spectral(&a, 200) - s[0]).abs() < 1e-6 * s[0]);
    }

    #[test]
    fn norm_inequalities() {
        // ‖A‖₂ ≤ sqrt(‖A‖₁‖A‖∞) — the bound behind the NS init
        let mut rng = crate::rngx::Rng::new(19);
        for _ in 0..5 {
            let a = Matrix::from_fn(7, 7, |_, _| rng.normal());
            let s2 = spectral(&a, 100);
            assert!(s2 <= (one(&a) * inf(&a)).sqrt() + 1e-9);
            assert!(s2 <= fro(&a) + 1e-9);
        }
    }
}
