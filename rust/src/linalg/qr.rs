//! Householder QR — used for generating random orthonormal bases in the
//! SPSD matrix generators (spiked-spectrum test matrices) and for rank
//! computations on tall factors.

use super::matrix::Matrix;

/// Thin QR: a (m×n, m ≥ n) = q (m×n, orthonormal cols) · r (n×n upper).
pub struct Qr {
    pub q: Matrix,
    pub r: Matrix,
}

/// Householder QR with column-by-column reflectors.
pub fn qr(a: &Matrix) -> Qr {
    let m = a.rows();
    let n = a.cols();
    assert!(m >= n, "qr expects tall/square input, got {m}x{n}");
    let mut r = a.clone();
    // store reflectors
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n);
    for k in 0..n {
        // build reflector for column k below the diagonal
        let mut v = vec![0.0; m - k];
        let mut norm = 0.0;
        for i in k..m {
            v[i - k] = r[(i, k)];
            norm += r[(i, k)] * r[(i, k)];
        }
        let norm = norm.sqrt();
        if norm < f64::MIN_POSITIVE {
            vs.push(v);
            continue;
        }
        let alpha = if v[0] >= 0.0 { -norm } else { norm };
        v[0] -= alpha;
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 > f64::MIN_POSITIVE {
            // apply H = I − 2vvᵀ/‖v‖² to R[k.., k..]
            for j in k..n {
                let mut dotv = 0.0;
                for i in k..m {
                    dotv += v[i - k] * r[(i, j)];
                }
                let s = 2.0 * dotv / vnorm2;
                for i in k..m {
                    r[(i, j)] -= s * v[i - k];
                }
            }
        }
        vs.push(v);
    }

    // form thin Q by applying reflectors to the first n identity columns
    let mut q = Matrix::zeros(m, n);
    for j in 0..n {
        q[(j, j)] = 1.0;
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 < f64::MIN_POSITIVE {
            continue;
        }
        for j in 0..n {
            let mut dotv = 0.0;
            for i in k..m {
                dotv += v[i - k] * q[(i, j)];
            }
            let s = 2.0 * dotv / vnorm2;
            for i in k..m {
                q[(i, j)] -= s * v[i - k];
            }
        }
    }

    // truncate R to n×n upper triangle
    let mut rn = Matrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            rn[(i, j)] = r[(i, j)];
        }
    }
    Qr { q, r: rn }
}

/// Random matrix with orthonormal columns (Haar-ish via QR of Gaussian).
pub fn random_orthonormal(rng: &mut crate::rngx::Rng, m: usize, n: usize) -> Matrix {
    let g = Matrix::from_fn(m, n, |_, _| rng.normal());
    qr(&g).q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::matmul;

    #[test]
    fn qr_reconstructs() {
        let mut rng = crate::rngx::Rng::new(3);
        let a = Matrix::from_fn(10, 6, |_, _| rng.normal());
        let d = qr(&a);
        let back = matmul(&d.q, &d.r);
        assert!(a.max_abs_diff(&back) < 1e-10);
    }

    #[test]
    fn q_orthonormal() {
        let mut rng = crate::rngx::Rng::new(4);
        let a = Matrix::from_fn(12, 5, |_, _| rng.normal());
        let d = qr(&a);
        let qtq = matmul(&d.q.transpose(), &d.q);
        assert!(qtq.max_abs_diff(&Matrix::eye(5)) < 1e-10);
    }

    #[test]
    fn r_upper_triangular() {
        let mut rng = crate::rngx::Rng::new(5);
        let a = Matrix::from_fn(8, 8, |_, _| rng.normal());
        let d = qr(&a);
        for i in 0..8 {
            for j in 0..i {
                assert_eq!(d.r[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn random_orthonormal_has_unit_columns() {
        let mut rng = crate::rngx::Rng::new(6);
        let q = random_orthonormal(&mut rng, 20, 8);
        let qtq = matmul(&q.transpose(), &q);
        assert!(qtq.max_abs_diff(&Matrix::eye(8)) < 1e-10);
    }
}
