//! Dense row-major f64 matrix — the analysis substrate.
//!
//! Used by the spectrum analysis (Figure 2), the SPSD model zoo
//! (Lemma 1 / Theorem 1 experiments) and the exact-pinv reference path.
//! The serving hot path uses `attention::*` f32 routines instead; this
//! type favours numerical robustness over raw speed.

use std::fmt;
use std::ops::{Index, IndexMut};

/// Dense row-major matrix of f64.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix of the given shape.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer/shape mismatch");
        Matrix { rows, cols, data }
    }

    /// Diagonal matrix from a slice.
    pub fn diag(d: &[f64]) -> Self {
        let n = d.len();
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = d[i];
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    /// Raw row-major data.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Borrow one row.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Column-subset copy: keep columns listed in `cols` (in order).
    pub fn select_columns(&self, cols: &[usize]) -> Matrix {
        let mut m = Matrix::zeros(self.rows, cols.len());
        for i in 0..self.rows {
            for (jj, &j) in cols.iter().enumerate() {
                m[(i, jj)] = self[(i, j)];
            }
        }
        m
    }

    /// Row-subset copy.
    pub fn select_rows(&self, rows: &[usize]) -> Matrix {
        let mut m = Matrix::zeros(rows.len(), self.cols);
        for (ii, &i) in rows.iter().enumerate() {
            m.row_mut(ii).copy_from_slice(self.row(i));
        }
        m
    }

    /// Principal submatrix on the given indices (rows ∩ cols).
    pub fn principal_submatrix(&self, idx: &[usize]) -> Matrix {
        let mut m = Matrix::zeros(idx.len(), idx.len());
        for (ii, &i) in idx.iter().enumerate() {
            for (jj, &j) in idx.iter().enumerate() {
                m[(ii, jj)] = self[(i, j)];
            }
        }
        m
    }

    /// Elementwise map.
    pub fn map(&self, f: impl Fn(f64) -> f64) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// self + other.
    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a + b)
            .collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// self - other.
    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// alpha * self.
    pub fn scale(&self, alpha: f64) -> Matrix {
        self.map(|x| alpha * x)
    }

    /// self + alpha * I (square only).
    pub fn add_scaled_identity(&self, alpha: f64) -> Matrix {
        assert!(self.is_square());
        let mut m = self.clone();
        for i in 0..self.rows {
            m[(i, i)] += alpha;
        }
        m
    }

    /// Trace (square only).
    pub fn trace(&self) -> f64 {
        assert!(self.is_square());
        (0..self.rows).map(|i| self[(i, i)]).sum()
    }

    /// Symmetrize: (A + Aᵀ)/2.
    pub fn symmetrize(&self) -> Matrix {
        assert!(self.is_square());
        let mut m = self.clone();
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                m[(i, j)] = v;
                m[(j, i)] = v;
            }
        }
        m
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Convert to f32 row-major buffer (for the serving fast path).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    /// Build from an f32 row-major buffer.
    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Matrix {
        assert_eq!(data.len(), rows * cols);
        Matrix {
            rows,
            cols,
            data: data.iter().map(|&x| x as f64).collect(),
        }
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let show = self.rows.min(6);
        for i in 0..show {
            let cols = self.cols.min(8);
            let row: Vec<String> = self.row(i)[..cols]
                .iter()
                .map(|x| format!("{x:9.4}"))
                .collect();
            writeln!(f, "  [{}{}]", row.join(", "),
                     if self.cols > 8 { ", ..." } else { "" })?;
        }
        if self.rows > show {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let m = Matrix::from_fn(2, 3, |i, j| (i * 10 + j) as f64);
        assert_eq!(m[(0, 0)], 0.0);
        assert_eq!(m[(1, 2)], 12.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0]);
    }

    #[test]
    fn eye_and_diag() {
        let i3 = Matrix::eye(3);
        assert_eq!(i3.trace(), 3.0);
        let d = Matrix::diag(&[1.0, 2.0, 3.0]);
        assert_eq!(d[(1, 1)], 2.0);
        assert_eq!(d[(0, 1)], 0.0);
    }

    #[test]
    fn transpose_involutive() {
        let m = Matrix::from_fn(3, 5, |i, j| (i * 7 + j * 3) as f64);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose()[(4, 2)], m[(2, 4)]);
    }

    #[test]
    fn select_columns_and_rows() {
        let m = Matrix::from_fn(4, 4, |i, j| (i * 4 + j) as f64);
        let c = m.select_columns(&[0, 3]);
        assert_eq!(c.cols(), 2);
        assert_eq!(c[(2, 1)], m[(2, 3)]);
        let r = m.select_rows(&[1, 2]);
        assert_eq!(r[(0, 0)], m[(1, 0)]);
        let p = m.principal_submatrix(&[1, 3]);
        assert_eq!(p[(0, 1)], m[(1, 3)]);
        assert_eq!(p[(1, 0)], m[(3, 1)]);
    }

    #[test]
    fn arithmetic() {
        let a = Matrix::from_fn(2, 2, |i, j| (i + j) as f64);
        let b = Matrix::eye(2);
        assert_eq!(a.add(&b)[(0, 0)], 1.0);
        assert_eq!(a.sub(&b)[(1, 1)], 1.0);
        assert_eq!(a.scale(2.0)[(0, 1)], 2.0);
        assert_eq!(a.add_scaled_identity(5.0)[(0, 0)], 5.0);
    }

    #[test]
    fn symmetrize_is_symmetric() {
        let m = Matrix::from_fn(4, 4, |i, j| (i * 3 + j * 11) as f64);
        let s = m.symmetrize();
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(s[(i, j)], s[(j, i)]);
            }
        }
    }

    #[test]
    fn f32_roundtrip() {
        let m = Matrix::from_fn(3, 3, |i, j| (i + j) as f64 * 0.5);
        let back = Matrix::from_f32(3, 3, &m.to_f32());
        assert!(m.max_abs_diff(&back) < 1e-6);
    }

    #[test]
    #[should_panic]
    fn from_vec_shape_mismatch_panics() {
        Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0]);
    }
}
