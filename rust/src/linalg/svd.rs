//! Singular value decomposition via one-sided Jacobi (Hestenes).
//!
//! Numerically robust for the small/medium matrices this crate analyses
//! (landmark blocks c ≤ 256, attention matrices n ≤ a few thousand for
//! the Figure-2 study). Returns the thin SVD A = U Σ Vᵀ with singular
//! values sorted descending.

use super::matrix::Matrix;

/// Thin SVD: `a == u · diag(s) · vt` with u: m×k, s: k, vt: k×n, k=min(m,n).
pub struct Svd {
    pub u: Matrix,
    pub s: Vec<f64>,
    pub vt: Matrix,
}

/// One-sided Jacobi SVD. For m < n the decomposition is computed on Aᵀ
/// and swapped back.
pub fn svd(a: &Matrix) -> Svd {
    if a.rows() < a.cols() {
        let t = svd(&a.transpose());
        return Svd { u: t.vt.transpose(), s: t.s, vt: t.u.transpose() };
    }
    let m = a.rows();
    let n = a.cols();
    // Work on columns of W (copy of A); V accumulates right rotations.
    let mut w = a.clone();
    let mut v = Matrix::eye(n);

    let eps = 1e-13;
    for _sweep in 0..60 {
        let mut converged = true;
        for p in 0..n {
            for q in (p + 1)..n {
                // column dot products
                let (mut app, mut aqq, mut apq) = (0.0, 0.0, 0.0);
                for i in 0..m {
                    let wip = w[(i, p)];
                    let wiq = w[(i, q)];
                    app += wip * wip;
                    aqq += wiq * wiq;
                    apq += wip * wiq;
                }
                if apq.abs() <= eps * (app * aqq).sqrt() + f64::MIN_POSITIVE {
                    continue;
                }
                converged = false;
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                for i in 0..m {
                    let wip = w[(i, p)];
                    let wiq = w[(i, q)];
                    w[(i, p)] = c * wip - s * wiq;
                    w[(i, q)] = s * wip + c * wiq;
                }
                for i in 0..n {
                    let vip = v[(i, p)];
                    let viq = v[(i, q)];
                    v[(i, p)] = c * vip - s * viq;
                    v[(i, q)] = s * vip + c * viq;
                }
            }
        }
        if converged {
            break;
        }
    }

    // singular values = column norms of W; U = W normalized
    let mut s: Vec<f64> = (0..n)
        .map(|j| (0..m).map(|i| w[(i, j)] * w[(i, j)]).sum::<f64>().sqrt())
        .collect();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| s[j].partial_cmp(&s[i]).unwrap());

    let mut u = Matrix::zeros(m, n);
    let mut vt = Matrix::zeros(n, n);
    let mut s_sorted = vec![0.0; n];
    for (new_j, &old_j) in order.iter().enumerate() {
        let sv = s[old_j];
        s_sorted[new_j] = sv;
        if sv > f64::MIN_POSITIVE {
            for i in 0..m {
                u[(i, new_j)] = w[(i, old_j)] / sv;
            }
        }
        for i in 0..n {
            vt[(new_j, i)] = v[(i, old_j)];
        }
    }
    s = s_sorted;
    Svd { u, s, vt }
}

/// Singular values only, descending.
pub fn singular_values(a: &Matrix) -> Vec<f64> {
    svd(a).s
}

/// Numerical rank: #{σ_i > rtol · σ_max}.
pub fn numerical_rank(a: &Matrix, rtol: f64) -> usize {
    let s = singular_values(a);
    match s.first() {
        None => 0,
        Some(&smax) if smax <= 0.0 => 0,
        Some(&smax) => s.iter().filter(|&&x| x > rtol * smax).count(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::matmul;

    fn reconstruct(d: &Svd) -> Matrix {
        let k = d.s.len();
        let mut us = d.u.clone();
        for i in 0..us.rows() {
            for j in 0..k {
                us[(i, j)] *= d.s[j];
            }
        }
        matmul(&us, &d.vt)
    }

    #[test]
    fn diagonal_known_singulars() {
        let a = Matrix::diag(&[-4.0, 2.0, 1.0]);
        let s = singular_values(&a);
        assert!((s[0] - 4.0).abs() < 1e-10);
        assert!((s[1] - 2.0).abs() < 1e-10);
        assert!((s[2] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn reconstruction_square() {
        let mut rng = crate::rngx::Rng::new(17);
        let a = Matrix::from_fn(12, 12, |_, _| rng.normal());
        let d = svd(&a);
        assert!(a.max_abs_diff(&reconstruct(&d)) < 1e-9);
    }

    #[test]
    fn reconstruction_tall_and_wide() {
        let mut rng = crate::rngx::Rng::new(23);
        let tall = Matrix::from_fn(15, 6, |_, _| rng.normal());
        let d = svd(&tall);
        assert!(tall.max_abs_diff(&reconstruct(&d)) < 1e-9);
        let wide = Matrix::from_fn(5, 11, |_, _| rng.normal());
        let d2 = svd(&wide);
        assert!(wide.max_abs_diff(&reconstruct(&d2)) < 1e-9);
    }

    #[test]
    fn u_and_v_orthonormal() {
        let mut rng = crate::rngx::Rng::new(31);
        let a = Matrix::from_fn(10, 7, |_, _| rng.normal());
        let d = svd(&a);
        let utu = matmul(&d.u.transpose(), &d.u);
        assert!(utu.max_abs_diff(&Matrix::eye(7)) < 1e-9);
        let vvt = matmul(&d.vt, &d.vt.transpose());
        assert!(vvt.max_abs_diff(&Matrix::eye(7)) < 1e-9);
    }

    #[test]
    fn rank_detection() {
        // rank-2 outer product matrix (columns must be independent:
        // one linear in i, one quadratic)
        let u = Matrix::from_fn(8, 2, |i, j| {
            if j == 0 { (i + 1) as f64 } else { (i * i) as f64 + 1.0 }
        });
        let a = matmul(&u, &u.transpose());
        assert_eq!(numerical_rank(&a, 1e-9), 2);
        assert_eq!(numerical_rank(&Matrix::zeros(4, 4), 1e-9), 0);
        assert_eq!(numerical_rank(&Matrix::eye(5), 1e-9), 5);
    }

    #[test]
    fn singulars_match_eigen_of_gram() {
        let mut rng = crate::rngx::Rng::new(41);
        let a = Matrix::from_fn(9, 9, |_, _| rng.normal());
        let s = singular_values(&a);
        let g = matmul(&a.transpose(), &a).symmetrize();
        let ev = crate::linalg::eigen::sym_eigenvalues(&g, 1e-13);
        for (si, li) in s.iter().zip(&ev) {
            assert!((si * si - li).abs() < 1e-7, "{si} vs sqrt({li})");
        }
    }
}
