//! Row-wise softmax — the `L(·)` operator of the paper.

use super::matrix::Matrix;

/// Numerically-stable row softmax, in place.
pub fn row_softmax_inplace(a: &mut Matrix) {
    let cols = a.cols();
    for i in 0..a.rows() {
        let row = a.row_mut(i);
        let m = row.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        let mut sum = 0.0;
        for x in row.iter_mut() {
            *x = (*x - m).exp();
            sum += *x;
        }
        debug_assert!(sum > 0.0);
        for x in row.iter_mut() {
            *x /= sum;
        }
    }
    let _ = cols;
}

/// Row softmax, returning a new matrix.
pub fn row_softmax(a: &Matrix) -> Matrix {
    let mut out = a.clone();
    row_softmax_inplace(&mut out);
    out
}

/// f32 row softmax over a flat row-major buffer (serving fast path).
pub fn row_softmax_f32(data: &mut [f32], rows: usize, cols: usize) {
    debug_assert_eq!(data.len(), rows * cols);
    for r in 0..rows {
        scaled_softmax_row(&mut data[r * cols..(r + 1) * cols], 1.0);
    }
}

/// Numerically-stable softmax of one row of pre-scale logits:
/// row ← softmax(scale · row). Single-row building block shared by
/// `row_softmax_f32` and the blocked `kernels::` fast path, which
/// applies it per row inside its logits scratch so the reduction order
/// is identical on the sequential and parallel paths.
#[inline]
pub fn scaled_softmax_row(row: &mut [f32], scale: f32) {
    let mut m = f32::NEG_INFINITY;
    for x in row.iter_mut() {
        *x *= scale;
        m = m.max(*x);
    }
    let mut sum = 0.0f32;
    for x in row.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    for x in row.iter_mut() {
        *x *= inv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_sum_to_one() {
        let mut rng = crate::rngx::Rng::new(1);
        let a = Matrix::from_fn(6, 9, |_, _| rng.normal() * 3.0);
        let s = row_softmax(&a);
        for i in 0..6 {
            let sum: f64 = s.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-12);
            assert!(s.row(i).iter().all(|&x| x > 0.0));
        }
    }

    #[test]
    fn stable_under_large_logits() {
        let a = Matrix::from_vec(1, 3, vec![1000.0, 1001.0, 999.0]);
        let s = row_softmax(&a);
        assert!(s.data().iter().all(|x| x.is_finite()));
        assert!(s[(0, 1)] > s[(0, 0)] && s[(0, 0)] > s[(0, 2)]);
    }

    #[test]
    fn shift_invariance() {
        let a = Matrix::from_vec(1, 4, vec![0.1, 0.2, 0.3, 0.4]);
        let b = a.map(|x| x + 100.0);
        assert!(row_softmax(&a).max_abs_diff(&row_softmax(&b)) < 1e-12);
    }

    #[test]
    fn f32_matches_f64() {
        let mut rng = crate::rngx::Rng::new(2);
        let a = Matrix::from_fn(4, 5, |_, _| rng.normal());
        let mut f = a.to_f32();
        row_softmax_f32(&mut f, 4, 5);
        let want = row_softmax(&a);
        for (x, y) in f.iter().zip(want.data()) {
            assert!((*x as f64 - y).abs() < 1e-6);
        }
    }
}
