//! Symmetric eigendecomposition via the cyclic Jacobi method.
//!
//! Robust, dependency-free, O(n³) per sweep with quadratic convergence —
//! exactly what the Figure-2 spectrum analysis (n ≤ a few thousand) and
//! the SPSD model zoo need. Input must be symmetric; callers holding a
//! nearly-symmetric matrix should `symmetrize()` first.

use super::matrix::Matrix;

/// Result of a symmetric eigendecomposition: `a == v · diag(values) · vᵀ`.
/// Eigenvalues are sorted in DESCENDING order; `vectors` columns match.
pub struct SymEigen {
    pub values: Vec<f64>,
    /// Column j is the eigenvector for values[j].
    pub vectors: Matrix,
}

/// Cyclic Jacobi eigendecomposition of a symmetric matrix.
///
/// `tol` bounds the off-diagonal Frobenius mass at convergence relative
/// to the matrix norm; 1e-12 is a good default. Panics on non-square
/// input; debug-asserts symmetry.
pub fn sym_eigen(a: &Matrix, tol: f64) -> SymEigen {
    assert!(a.is_square(), "sym_eigen needs a square matrix");
    let n = a.rows();
    debug_assert!(is_symmetric(a, 1e-9), "sym_eigen input must be symmetric");
    let mut m = a.clone();
    let mut v = Matrix::eye(n);

    let norm: f64 = m.data().iter().map(|x| x * x).sum::<f64>().sqrt();
    let stop = (tol * norm).max(f64::MIN_POSITIVE);

    for _sweep in 0..100 {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if (2.0 * off).sqrt() <= stop {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() <= stop / (n as f64 * n as f64) {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                // stable tangent of the rotation angle
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;

                // A <- Jᵀ A J applied to rows/cols p,q
                for k in 0..n {
                    let akp = m[(k, p)];
                    let akq = m[(k, q)];
                    m[(k, p)] = c * akp - s * akq;
                    m[(k, q)] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = m[(p, k)];
                    let aqk = m[(q, k)];
                    m[(p, k)] = c * apk - s * aqk;
                    m[(q, k)] = s * apk + c * aqk;
                }
                // accumulate eigenvectors
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    let mut values: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    // sort descending, permuting eigenvector columns alongside
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| values[j].partial_cmp(&values[i]).unwrap());
    let sorted_values: Vec<f64> = order.iter().map(|&i| values[i]).collect();
    let mut vectors = Matrix::zeros(n, n);
    for (new_j, &old_j) in order.iter().enumerate() {
        for i in 0..n {
            vectors[(i, new_j)] = v[(i, old_j)];
        }
    }
    values = sorted_values;
    SymEigen { values, vectors }
}

/// Eigenvalues only (descending), convenience wrapper.
pub fn sym_eigenvalues(a: &Matrix, tol: f64) -> Vec<f64> {
    sym_eigen(a, tol).values
}

/// Check |a_ij - a_ji| <= eps everywhere.
pub fn is_symmetric(a: &Matrix, eps: f64) -> bool {
    if !a.is_square() {
        return false;
    }
    for i in 0..a.rows() {
        for j in (i + 1)..a.cols() {
            if (a[(i, j)] - a[(j, i)]).abs() > eps {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::matmul;

    fn reconstruct(e: &SymEigen) -> Matrix {
        let _n = e.values.len();
        let d = Matrix::diag(&e.values);
        matmul(&matmul(&e.vectors, &d), &e.vectors.transpose())
            .map(|x| x)
            .symmetrize()
            .map(|x| x * 1.0)
            .clone()
    }

    #[test]
    fn diagonal_matrix_eigenvalues() {
        let a = Matrix::diag(&[3.0, 1.0, 2.0]);
        let e = sym_eigen(&a, 1e-12);
        assert!((e.values[0] - 3.0).abs() < 1e-12);
        assert!((e.values[1] - 2.0).abs() < 1e-12);
        assert!((e.values[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1
        let a = Matrix::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let e = sym_eigen(&a, 1e-14);
        assert!((e.values[0] - 3.0).abs() < 1e-10);
        assert!((e.values[1] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn reconstruction_random_symmetric() {
        let mut rng = crate::rngx::Rng::new(11);
        let n = 20;
        let raw = Matrix::from_fn(n, n, |_, _| rng.normal());
        let a = raw.symmetrize();
        let e = sym_eigen(&a, 1e-13);
        let back = reconstruct(&e);
        assert!(a.max_abs_diff(&back) < 1e-8, "{}", a.max_abs_diff(&back));
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let mut rng = crate::rngx::Rng::new(5);
        let n = 12;
        let a = Matrix::from_fn(n, n, |_, _| rng.normal()).symmetrize();
        let e = sym_eigen(&a, 1e-13);
        let vtv = matmul(&e.vectors.transpose(), &e.vectors);
        assert!(vtv.max_abs_diff(&Matrix::eye(n)) < 1e-8);
    }

    #[test]
    fn trace_equals_eigenvalue_sum() {
        let mut rng = crate::rngx::Rng::new(9);
        let a = Matrix::from_fn(15, 15, |_, _| rng.normal()).symmetrize();
        let vals = sym_eigenvalues(&a, 1e-12);
        let sum: f64 = vals.iter().sum();
        assert!((sum - a.trace()).abs() < 1e-8);
    }

    #[test]
    fn psd_gram_has_nonnegative_eigenvalues() {
        let mut rng = crate::rngx::Rng::new(2);
        let b = Matrix::from_fn(10, 6, |_, _| rng.normal());
        let g = crate::linalg::matmul::gram(&b); // 6x6 PSD
        let vals = sym_eigenvalues(&g, 1e-12);
        assert!(vals.iter().all(|&l| l > -1e-9), "{vals:?}");
    }

    #[test]
    fn is_symmetric_detects_asymmetry() {
        let mut a = Matrix::eye(3);
        assert!(is_symmetric(&a, 1e-12));
        a[(0, 1)] = 0.5;
        assert!(!is_symmetric(&a, 1e-12));
    }
}
