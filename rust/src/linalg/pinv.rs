//! Pseudoinverse: exact (SVD-based) and iterative (paper sec 7 eq 11).
//!
//! The exact path is the analysis ground truth (tolerance-rank
//! Moore-Penrose). The iterative path mirrors what the Pallas kernel and
//! the AOT artifacts run: the 7th-order Newton-Schulz iteration
//!
//!   Z_{j+1} = ¼ Z_j (13I − A Z_j (15I − A Z_j (7I − A Z_j)))
//!
//! with Z₀ = Aᵀ/(‖A‖₁‖A‖∞), plus the cubic order-3 baseline for the
//! E6 convergence bench.

use super::matmul::matmul;
use super::matrix::Matrix;
use super::svd::svd;

/// Moore-Penrose pseudoinverse with relative singular-value tolerance.
pub fn pinv(a: &Matrix, rtol: f64) -> Matrix {
    let d = svd(a);
    let smax = d.s.first().copied().unwrap_or(0.0);
    let tol = rtol * smax;
    // A⁺ = V Σ⁺ Uᵀ
    let k = d.s.len();
    let mut v_sinv = d.vt.transpose(); // n×k
    for j in 0..k {
        let inv = if d.s[j] > tol && d.s[j] > 0.0 { 1.0 / d.s[j] } else { 0.0 };
        for i in 0..v_sinv.rows() {
            v_sinv[(i, j)] *= inv;
        }
    }
    matmul(&v_sinv, &d.u.transpose())
}

/// ‖A‖₁ (max column abs sum).
fn norm1(a: &Matrix) -> f64 {
    let mut best: f64 = 0.0;
    for j in 0..a.cols() {
        let s: f64 = (0..a.rows()).map(|i| a[(i, j)].abs()).sum();
        best = best.max(s);
    }
    best
}

/// ‖A‖∞ (max row abs sum).
fn norm_inf(a: &Matrix) -> f64 {
    a.data()
        .chunks(a.cols())
        .map(|r| r.iter().map(|x| x.abs()).sum::<f64>())
        .fold(0.0, f64::max)
}

/// Z₀ = Aᵀ / (‖A‖₁‖A‖∞): satisfies the NS convergence precondition.
pub fn ns_init(a: &Matrix) -> Matrix {
    let denom = norm1(a) * norm_inf(a);
    a.transpose().scale(1.0 / denom.max(f64::MIN_POSITIVE))
}

/// The paper's order-7 iteration (eq 11), `iters` steps.
pub fn ns_pinv_ord7(a: &Matrix, iters: usize) -> Matrix {
    let n = a.rows();
    let eye = Matrix::eye(n);
    let mut z = ns_init(a);
    for _ in 0..iters {
        let az = matmul(a, &z);
        let inner1 = eye.scale(7.0).sub(&az);
        let inner2 = eye.scale(15.0).sub(&matmul(&az, &inner1));
        let inner3 = eye.scale(13.0).sub(&matmul(&az, &inner2));
        z = matmul(&z, &inner3).scale(0.25);
    }
    z
}

/// Cubic order-3 Newton-Schulz baseline: Z ← Z(3I − AZ(3I − AZ)).
pub fn ns_pinv_ord3(a: &Matrix, iters: usize) -> Matrix {
    let n = a.rows();
    let eye = Matrix::eye(n);
    let mut z = ns_init(a);
    for _ in 0..iters {
        let az = matmul(a, &z);
        let inner = eye.scale(3.0).sub(&az);
        let inner2 = eye.scale(3.0).sub(&matmul(&az, &inner));
        z = matmul(&z, &inner2);
    }
    z
}

/// Residual ‖AZ − I‖∞-max-entry — the convergence metric used by E6.
pub fn ns_residual(a: &Matrix, z: &Matrix) -> f64 {
    let az = matmul(a, z);
    az.max_abs_diff(&Matrix::eye(a.rows()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Rng;

    fn random_softmax_block(rng: &mut Rng, c: usize, d: usize) -> Matrix {
        let q = Matrix::from_fn(c, d, |_, _| rng.normal());
        let k = Matrix::from_fn(c, d, |_, _| rng.normal());
        let mut s = matmul(&q, &k.transpose()).scale(1.0 / (d as f64).sqrt());
        crate::linalg::softmax::row_softmax_inplace(&mut s);
        s
    }

    #[test]
    fn pinv_of_invertible_is_inverse() {
        let mut rng = Rng::new(1);
        let a = Matrix::from_fn(8, 8, |_, _| rng.normal())
            .add_scaled_identity(5.0);
        let p = pinv(&a, 1e-12);
        let ap = matmul(&a, &p);
        assert!(ap.max_abs_diff(&Matrix::eye(8)) < 1e-8);
    }

    #[test]
    fn pinv_penrose_conditions_rank_deficient() {
        let mut rng = Rng::new(2);
        let b = Matrix::from_fn(10, 3, |_, _| rng.normal());
        let a = matmul(&b, &b.transpose()); // rank 3, 10x10
        let p = pinv(&a, 1e-10);
        // A P A = A ; P A P = P ; (AP)ᵀ = AP ; (PA)ᵀ = PA
        let apa = matmul(&matmul(&a, &p), &a);
        assert!(apa.max_abs_diff(&a) < 1e-7);
        let pap = matmul(&matmul(&p, &a), &p);
        assert!(pap.max_abs_diff(&p) < 1e-7);
        let ap = matmul(&a, &p);
        assert!(ap.max_abs_diff(&ap.transpose()) < 1e-8);
    }

    #[test]
    fn ns_ord7_converges_to_inverse() {
        let mut rng = Rng::new(3);
        let a = random_softmax_block(&mut rng, 16, 32)
            .add_scaled_identity(0.5);
        let z = ns_pinv_ord7(&a, 8);
        assert!(ns_residual(&a, &z) < 1e-10, "{}", ns_residual(&a, &z));
    }

    #[test]
    fn ns_ord7_on_softmax_block() {
        let mut rng = Rng::new(4);
        let a = random_softmax_block(&mut rng, 24, 16);
        let z = ns_pinv_ord7(&a, 25);
        assert!(ns_residual(&a, &z) < 1e-6, "{}", ns_residual(&a, &z));
    }

    #[test]
    fn ord7_beats_ord3_at_equal_iters() {
        let mut rng = Rng::new(5);
        let a = random_softmax_block(&mut rng, 16, 16)
            .add_scaled_identity(0.2);
        let r7 = ns_residual(&a, &ns_pinv_ord7(&a, 5));
        let r3 = ns_residual(&a, &ns_pinv_ord3(&a, 5));
        assert!(r7 < r3, "r7={r7} r3={r3}");
    }

    #[test]
    fn ns_matches_exact_pinv_well_conditioned() {
        let mut rng = Rng::new(6);
        let a = random_softmax_block(&mut rng, 12, 8)
            .add_scaled_identity(1.0);
        let z = ns_pinv_ord7(&a, 10);
        let p = pinv(&a, 1e-13);
        assert!(z.max_abs_diff(&p) < 1e-9);
    }

    #[test]
    fn ns_init_precondition() {
        // spectral radius of (I - A Z0) must be < 1 for convergence;
        // check via ‖I − AZ₀‖₂ ≤ fro norm proxy on several random blocks
        let mut rng = Rng::new(7);
        for c in [4usize, 8, 20] {
            let a = random_softmax_block(&mut rng, c, 8);
            let z0 = ns_init(&a);
            let r = matmul(&a, &z0);
            let sing = crate::linalg::svd::singular_values(
                &Matrix::eye(c).sub(&r));
            assert!(sing[0] < 1.0 + 1e-12, "sigma_max={}", sing[0]);
        }
    }
}
