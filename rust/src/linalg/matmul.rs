//! Matrix multiplication kernels (f64 analysis path).
//!
//! `matmul` transposes the right operand once and walks both operands
//! row-major — the classic cache-friendly ikj/dot layout. Good enough
//! for the c×c / n×c analysis shapes in this crate; the f32 serving path
//! has its own micro-kernels in `attention::`.

use super::matrix::Matrix;

/// C = A · B.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch: {}x{} · {}x{}",
               a.rows(), a.cols(), b.rows(), b.cols());
    let bt = b.transpose();
    matmul_bt(a, &bt)
}

/// C = A · Bᵀ where `bt` is given already transposed (both row-major).
pub fn matmul_bt(a: &Matrix, bt: &Matrix) -> Matrix {
    assert_eq!(a.cols(), bt.cols());
    let (m, n) = (a.rows(), bt.rows());
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for j in 0..n {
            crow[j] = dot(arow, bt.row(j));
        }
    }
    c
}

/// y = A · x.
pub fn matvec(a: &Matrix, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols(), x.len());
    (0..a.rows()).map(|i| dot(a.row(i), x)).collect()
}

/// y = Aᵀ · x.
pub fn matvec_t(a: &Matrix, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows(), x.len());
    let mut y = vec![0.0; a.cols()];
    for i in 0..a.rows() {
        let xi = x[i];
        for (j, &aij) in a.row(i).iter().enumerate() {
            y[j] += aij * xi;
        }
    }
    y
}

/// Dot product with 4-way unrolled accumulation.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// Gram matrix AᵀA (symmetric; computes upper triangle once).
pub fn gram(a: &Matrix) -> Matrix {
    let n = a.cols();
    let at = a.transpose();
    let mut g = Matrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            let v = dot(at.row(i), at.row(j));
            g[(i, j)] = v;
            g[(j, i)] = v;
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_known_product() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn identity_is_neutral() {
        let a = Matrix::from_fn(5, 5, |i, j| ((i * 5 + j) as f64).sin());
        let c = matmul(&a, &Matrix::eye(5));
        assert!(a.max_abs_diff(&c) < 1e-12);
        let c2 = matmul(&Matrix::eye(5), &a);
        assert!(a.max_abs_diff(&c2) < 1e-12);
    }

    #[test]
    fn rectangular_shapes() {
        let a = Matrix::from_fn(3, 7, |i, j| (i + j) as f64);
        let b = Matrix::from_fn(7, 2, |i, j| (i as f64) - (j as f64));
        let c = matmul(&a, &b);
        assert_eq!((c.rows(), c.cols()), (3, 2));
        // check one entry by hand
        let want: f64 = (0..7).map(|k| (0 + k) as f64 * (k as f64 - 1.0)).sum();
        assert!((c[(0, 1)] - want).abs() < 1e-12);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = Matrix::from_fn(4, 3, |i, j| (i * 3 + j) as f64);
        let x = vec![1.0, -1.0, 2.0];
        let y = matvec(&a, &x);
        let xm = Matrix::from_vec(3, 1, x.clone());
        let ym = matmul(&a, &xm);
        for i in 0..4 {
            assert!((y[i] - ym[(i, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn matvec_t_matches_transpose() {
        let a = Matrix::from_fn(4, 3, |i, j| ((i * 3 + j) as f64).cos());
        let x = vec![0.5, -0.25, 1.5, 2.0];
        let y1 = matvec_t(&a, &x);
        let y2 = matvec(&a.transpose(), &x);
        for (u, v) in y1.iter().zip(&y2) {
            assert!((u - v).abs() < 1e-12);
        }
    }

    #[test]
    fn gram_is_symmetric_psd_diag() {
        let a = Matrix::from_fn(6, 4, |i, j| ((i + 2 * j) as f64).sin());
        let g = gram(&a);
        for i in 0..4 {
            assert!(g[(i, i)] >= 0.0);
            for j in 0..4 {
                assert!((g[(i, j)] - g[(j, i)]).abs() < 1e-12);
            }
        }
        let want = matmul(&a.transpose(), &a);
        assert!(g.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn dot_handles_remainders() {
        for n in [0, 1, 3, 4, 5, 7, 8, 9] {
            let a: Vec<f64> = (0..n).map(|i| i as f64).collect();
            let b: Vec<f64> = (0..n).map(|i| (i * 2) as f64).collect();
            let want: f64 = (0..n).map(|i| (i * i * 2) as f64).sum();
            assert_eq!(dot(&a, &b), want);
        }
    }
}
