//! Dense linear-algebra substrate (S7 in DESIGN.md).
//!
//! The crate cache ships no BLAS/LAPACK bindings, so everything the
//! paper's analysis needs is implemented here: matmul, Householder QR,
//! cyclic-Jacobi symmetric eigen, one-sided Jacobi SVD, tolerance-rank
//! Moore-Penrose pinv, the eq-11 Newton-Schulz iterations, matrix norms,
//! and the row-softmax operator `L(·)`.

pub mod eigen;
pub mod matmul;
pub mod matrix;
pub mod norms;
pub mod pinv;
pub mod qr;
pub mod softmax;
pub mod svd;

pub use eigen::{sym_eigen, sym_eigenvalues, SymEigen};
pub use matmul::{dot, gram, matmul, matmul_bt, matvec, matvec_t};
pub use matrix::Matrix;
pub use pinv::{ns_pinv_ord3, ns_pinv_ord7, ns_residual, pinv};
pub use qr::{qr, random_orthonormal, Qr};
pub use softmax::{row_softmax, row_softmax_f32, row_softmax_inplace, scaled_softmax_row};
pub use svd::{numerical_rank, singular_values, svd, Svd};
