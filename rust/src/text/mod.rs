//! Text substrate (S17): word-level tokenizer + synthetic corpus
//! generator used by the end-to-end training example (E10) and the
//! serving demo.
//!
//! The paper has no dataset; per the substitution rule we train on a
//! synthetic Markov-bigram corpus whose statistics a small MLM can
//! actually learn (so the loss curve is meaningful): a vocabulary of
//! word types with a sparse, skewed bigram transition table.

use crate::rngx::Rng;
use std::collections::HashMap;

/// Special token ids (match the L2 model's conventions).
pub const PAD: i32 = 0;
pub const UNK: i32 = 1;
pub const MASK: i32 = 2;
pub const FIRST_WORD_ID: i32 = 3;

/// Word-level vocabulary with frequency-ranked ids.
pub struct Tokenizer {
    word_to_id: HashMap<String, i32>,
    id_to_word: Vec<String>,
    vocab_cap: usize,
}

impl Tokenizer {
    /// Build from a corpus, keeping the `vocab_cap - 3` most frequent
    /// words (ids 0..3 are PAD/UNK/MASK).
    pub fn fit(corpus: &[String], vocab_cap: usize) -> Self {
        assert!(vocab_cap > 8);
        let mut freq: HashMap<&str, u64> = HashMap::new();
        for line in corpus {
            for w in line.split_whitespace() {
                *freq.entry(w).or_insert(0) += 1;
            }
        }
        let mut ranked: Vec<(&str, u64)> = freq.into_iter().collect();
        ranked.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(b.0)));
        ranked.truncate(vocab_cap - FIRST_WORD_ID as usize);

        let mut word_to_id = HashMap::new();
        let mut id_to_word = vec!["<pad>".to_string(), "<unk>".to_string(),
                                  "<mask>".to_string()];
        for (i, (w, _)) in ranked.iter().enumerate() {
            word_to_id.insert(w.to_string(), FIRST_WORD_ID + i as i32);
            id_to_word.push(w.to_string());
        }
        Tokenizer { word_to_id, id_to_word, vocab_cap }
    }

    pub fn vocab_size(&self) -> usize {
        self.id_to_word.len()
    }

    pub fn vocab_cap(&self) -> usize {
        self.vocab_cap
    }

    /// Encode to exactly `len` ids, truncating or right-padding with PAD.
    pub fn encode(&self, textline: &str, len: usize) -> Vec<i32> {
        let mut out: Vec<i32> = textline
            .split_whitespace()
            .take(len)
            .map(|w| *self.word_to_id.get(w).unwrap_or(&UNK))
            .collect();
        out.resize(len, PAD);
        out
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        ids.iter()
            .filter(|&&i| i != PAD)
            .map(|&i| {
                self.id_to_word
                    .get(i as usize)
                    .map(|s| s.as_str())
                    .unwrap_or("<unk>")
            })
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Synthetic Markov-bigram corpus generator.
///
/// `types` word types; each word has ~`branching` plausible successors
/// with Zipf-skewed choice, so bigram statistics are learnable.
pub struct CorpusGenerator {
    words: Vec<String>,
    successors: Vec<Vec<usize>>,
    rng: Rng,
}

impl CorpusGenerator {
    pub fn new(seed: u64, types: usize, branching: usize) -> Self {
        assert!(types >= 8 && branching >= 2);
        let mut rng = Rng::new(seed);
        let words: Vec<String> = (0..types).map(|i| format!("w{i:04}")).collect();
        let successors: Vec<Vec<usize>> = (0..types)
            .map(|_| {
                (0..branching)
                    .map(|_| rng.below(types as u64) as usize)
                    .collect()
            })
            .collect();
        CorpusGenerator { words, successors, rng }
    }

    /// Generate one sentence of `len` words following the bigram chain.
    pub fn sentence(&mut self, len: usize) -> String {
        let mut cur = self.rng.below(self.words.len() as u64) as usize;
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            out.push(self.words[cur].clone());
            let succ = &self.successors[cur];
            // Zipf-skewed successor choice
            let pick = (self.rng.zipf(succ.len() as u64, 1.3) - 1) as usize;
            cur = succ[pick];
        }
        out.join(" ")
    }

    /// Generate a corpus of `lines` sentences with lengths in
    /// [min_len, max_len].
    pub fn corpus(&mut self, lines: usize, min_len: usize, max_len: usize) -> Vec<String> {
        (0..lines)
            .map(|_| {
                let len = min_len
                    + self.rng.below((max_len - min_len + 1) as u64) as usize;
                self.sentence(len)
            })
            .collect()
    }
}

/// An MLM training batch: tokens with 15% positions replaced by MASK,
/// original ids as targets, and the loss mask marking masked positions.
pub struct MlmBatch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub loss_mask: Vec<f32>,
    pub batch: usize,
    pub seq: usize,
}

/// Build an MLM batch from encoded sequences (BERT-style 15% masking;
/// of the masked positions 80% become MASK, 10% random, 10% unchanged).
pub fn make_mlm_batch(rng: &mut Rng, encoded: &[Vec<i32>], vocab: usize) -> MlmBatch {
    let batch = encoded.len();
    let seq = encoded[0].len();
    let mut tokens = Vec::with_capacity(batch * seq);
    let mut targets = Vec::with_capacity(batch * seq);
    let mut loss_mask = Vec::with_capacity(batch * seq);
    for row in encoded {
        assert_eq!(row.len(), seq, "ragged batch");
        for &t in row {
            targets.push(t);
            if t != PAD && rng.uniform() < 0.15 {
                loss_mask.push(1.0);
                let r = rng.uniform();
                if r < 0.8 {
                    tokens.push(MASK);
                } else if r < 0.9 {
                    tokens.push(FIRST_WORD_ID
                        + rng.below((vocab as i64 - FIRST_WORD_ID as i64) as u64) as i32);
                } else {
                    tokens.push(t);
                }
            } else {
                loss_mask.push(0.0);
                tokens.push(t);
            }
        }
    }
    MlmBatch { tokens, targets, loss_mask, batch, seq }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_corpus() -> Vec<String> {
        let mut g = CorpusGenerator::new(7, 50, 4);
        g.corpus(200, 5, 30)
    }

    #[test]
    fn tokenizer_roundtrip_frequent_words() {
        let corpus = small_corpus();
        let tok = Tokenizer::fit(&corpus, 64);
        assert!(tok.vocab_size() <= 64);
        let line = &corpus[0];
        let ids = tok.encode(line, 32);
        assert_eq!(ids.len(), 32);
        let dec = tok.decode(&ids);
        // every decoded word must appear in the original line (or be unk)
        for w in dec.split_whitespace() {
            assert!(line.contains(w) || w == "<unk>");
        }
    }

    #[test]
    fn encode_pads_and_truncates() {
        let tok = Tokenizer::fit(&["a b c".to_string()], 16);
        let short = tok.encode("a b", 6);
        assert_eq!(&short[2..], &[PAD; 4]);
        let long = tok.encode("a b c a b c a b", 4);
        assert_eq!(long.len(), 4);
        assert!(long.iter().all(|&t| t != PAD));
    }

    #[test]
    fn unknown_words_map_to_unk() {
        let tok = Tokenizer::fit(&["hello world".to_string()], 16);
        let ids = tok.encode("hello mars", 2);
        assert_ne!(ids[0], UNK);
        assert_eq!(ids[1], UNK);
    }

    #[test]
    fn corpus_is_deterministic_per_seed() {
        let mut a = CorpusGenerator::new(1, 30, 3);
        let mut b = CorpusGenerator::new(1, 30, 3);
        assert_eq!(a.sentence(10), b.sentence(10));
    }

    #[test]
    fn bigram_structure_is_learnable() {
        // successor sets are small ⇒ conditional entropy of the bigram
        // distribution is far below log2(types)
        let mut g = CorpusGenerator::new(3, 100, 3);
        let text = g.corpus(300, 20, 20);
        let mut pair_counts: HashMap<(String, String), u64> = HashMap::new();
        let mut uni: HashMap<String, u64> = HashMap::new();
        for line in &text {
            let ws: Vec<&str> = line.split_whitespace().collect();
            for w in ws.windows(2) {
                *pair_counts.entry((w[0].into(), w[1].into())).or_insert(0) += 1;
                *uni.entry(w[0].into()).or_insert(0) += 1;
            }
        }
        // average successor fan-out per observed word ≤ branching
        let mut fanout: HashMap<&String, std::collections::HashSet<&String>> =
            HashMap::new();
        for (a, b) in pair_counts.keys() {
            fanout.entry(a).or_default().insert(b);
        }
        let avg: f64 = fanout.values().map(|s| s.len() as f64).sum::<f64>()
            / fanout.len() as f64;
        assert!(avg <= 3.01, "fanout {avg}");
    }

    #[test]
    fn mlm_batch_invariants() {
        let corpus = small_corpus();
        let tok = Tokenizer::fit(&corpus, 64);
        let encoded: Vec<Vec<i32>> =
            corpus[..8].iter().map(|l| tok.encode(l, 32)).collect();
        let mut rng = Rng::new(5);
        let b = make_mlm_batch(&mut rng, &encoded, tok.vocab_cap());
        assert_eq!(b.tokens.len(), 8 * 32);
        assert_eq!(b.batch, 8);
        assert_eq!(b.seq, 32);
        let masked: usize = b.loss_mask.iter().filter(|&&m| m == 1.0).count();
        assert!(masked > 0);
        for i in 0..b.tokens.len() {
            if b.loss_mask[i] == 0.0 {
                // unmasked positions keep their token
                assert_eq!(b.tokens[i], b.targets[i]);
            }
            // PAD positions never selected for loss
            if b.targets[i] == PAD {
                assert_eq!(b.loss_mask[i], 0.0);
            }
        }
    }
}
