//! The serving model layer: a multi-layer transformer encoder over a
//! pluggable attention operator.
//!
//! The paper's claim — and the claim of every O(n) baseline it is
//! compared against — is about an attention *operator* dropped into an
//! otherwise-fixed encoder (Linformer and Skyformer both evaluate this
//! way). This module is that encoder:
//!
//! * [`AttentionOp`] (`op`) — the one dispatch seam. Every variant in
//!   `attention/` implements it; so does the Copy-able serving config
//!   [`BatchedVariant`](crate::kernels::BatchedVariant).
//! * [`EncoderLayer`] (`layer`) — one pre-LN block: LN → MHA → residual
//!   → LN → FFN (fused bias+GELU between two blocked GEMMs) → residual.
//!   With projections on, the MHA is the projected form over per-head
//!   `W_Q`/`W_K`/`W_V` plus the concatenated output map `W_O`
//!   ([`Projections`]) — the `Q = XW_Q` formulation the paper defines
//!   its approximation over — still dispatched through the one
//!   [`AttentionOp`] seam.
//! * [`EncoderStack`] (`stack`) — `layers` blocks (each with its own
//!   operator — per-layer variant mixing) sharing one planned
//!   [`Workspace`](crate::kernels::Workspace); the first block is the
//!   weightless *seed block* (bare attention), so `layers = 1` is
//!   bitwise-identical to the pre-stack single-pass serving model.
//! * [`checkpoint`] — versioned little-endian weight files: `save` /
//!   `load` / fail-closed validation, so the stack serves externally
//!   trained weights (`init = load`) instead of only seeded draws.
//! * [`reference`] — the scalar multi-layer forward the kernel stack is
//!   parity-tested against (`tests/model_parity.rs`).
//! * [`quantized`] — load-time precision tiers: [`quantize_stack`]
//!   snaps a stack's GEMM weights onto a bf16/int8 lattice *once*, so
//!   the admission policy serves quantized tiers through the unchanged
//!   f32 forward (bitwise the per-product quantized kernel, paid at
//!   load instead of per request).
//!
//! `coordinator::cpu_engine` owns embedding and pooling and routes all
//! compute through [`EncoderStack::forward_batch`]; nothing in the
//! serving path matches on a variant enum anymore.
//!
//! # Invariants
//!
//! * **Pure served function** — a request's final activation depends
//!   only on `(model seed, shape, tokens)`: never on batch composition,
//!   worker assignment, or pool size (inherited from the kernel layer's
//!   fixed-block splits; pinned by `tests/model_parity.rs`).
//! * **Depth compatibility** — the depth-1 stack *is* the seed model,
//!   bitwise; deeper stacks prepend nothing and append full blocks, so
//!   caches and recorded traces remain valid exactly when `layers` (and
//!   the rest of the model config) is unchanged.
//! * **Workspace discipline** — `forward_batch` takes all LN/FFN
//!   scratch from the caller's arena and returns it before exiting;
//!   [`EncoderStack::plan_sizes`] names the peak working set so engines
//!   pre-plan it.

pub mod checkpoint;
pub mod layer;
pub mod op;
pub mod quantized;
pub mod reference;
pub mod stack;

pub use checkpoint::{Checkpoint, CheckpointError};
pub use layer::{EncoderLayer, Projections, LN_EPS};
pub use op::AttentionOp;
pub use quantized::quantize_stack;
pub use stack::{EncoderStack, WeightInit};
