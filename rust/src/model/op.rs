//! The `AttentionOp` seam — one dispatch point for every attention
//! variant.
//!
//! Before the encoder-stack refactor each serving call site matched on
//! the variant enum and called one of six per-variant entry points.
//! [`AttentionOp`] replaces those call sites with a single trait object
//! seam: anything that can attend one head — `(len × dh)` q/k/v in,
//! `(len × dh)` out — plugs into the batched executor, the encoder
//! stack, and therefore the whole serving path. This is the same
//! evaluation shape Linformer and Skyformer use: the encoder is fixed,
//! the attention operator is the swappable part.
//!
//! Implementations live next to their math in `attention/`:
//! [`FullOp`], [`NystromOp`], [`SpectralShiftOp`], [`LinformerOp`],
//! [`LshOp`], [`SparseOp`]. The serving configuration's Copy-able
//! [`BatchedVariant`](crate::kernels::BatchedVariant) also implements
//! the trait by constructing the matching op value on the stack and
//! delegating — so a config enum and a hand-built op are
//! interchangeable wherever `&dyn AttentionOp` is accepted.
//!
//! # Contract
//!
//! * **Purity** — `attend` must be a pure function of `(q, k, v)` and
//!   the op's own configuration: no interior mutability, no global
//!   state. This is what makes served embeddings independent of batch
//!   composition (the cache-coherence invariant). Memoizing a
//!   deterministic internal draw (e.g. [`LinformerOp`]'s seeded
//!   projection, cached per key count) is permitted: a hit is bitwise
//!   the regenerated value, so the function served is unchanged.
//! * **Thread-count determinism** — for any `ctx`, the result must be
//!   bitwise identical to the sequential result. Ops built on the
//!   `kernels::` primitives inherit this; scalar ops are trivially
//!   deterministic.
//! * **Workspace discipline** — the returned tensor's buffer comes from
//!   `ws` (callers recycle it with `ws.put`), and intermediates return
//!   to `ws` before `attend` exits. The scalar reference-grade ops
//!   [`LshOp`] / [`SparseOp`] allocate intermediates internally
//!   (documented baseline, not hot-path, operators) but still copy
//!   their output into `ws` scratch so arena take/put stays balanced.
//!
//! [`FullOp`]: crate::attention::full::FullOp
//! [`NystromOp`]: crate::attention::nystrom::NystromOp
//! [`SpectralShiftOp`]: crate::attention::spectral_shift::SpectralShiftOp
//! [`LinformerOp`]: crate::attention::linformer::LinformerOp
//! [`LshOp`]: crate::attention::lsh::LshOp
//! [`SparseOp`]: crate::attention::sparse::SparseOp

use crate::attention::Tensor2;
use crate::kernels::{KernelCtx, Workspace};

/// A pluggable self/cross-attention operator: one head at a time,
/// `(len × dh)` in, `(len × dh)` out. See the module docs for the
/// purity / determinism / workspace contract.
pub trait AttentionOp: Send + Sync {
    /// Stable identifier used in metrics, STATS and bench labels.
    fn name(&self) -> &'static str;

    /// `Some(c)` when execution lengths must be divisible by the
    /// landmark count (segment-means variants); `None` otherwise. The
    /// router/batcher align request lengths with
    /// [`aligned_len`](crate::coordinator::batcher::aligned_len) off
    /// this value.
    fn landmark_divisor(&self) -> Option<usize> {
        None
    }

    /// Compute attention for one head. `scale` is owned by the op
    /// (defaulting to 1/√d inside each implementation), so every caller
    /// — stack, batcher, test — sees the same served function.
    fn attend(&self, ctx: &KernelCtx, q: &Tensor2, k: &Tensor2, v: &Tensor2,
              ws: &mut Workspace) -> Tensor2;
}

pub use crate::attention::full::FullOp;
pub use crate::attention::linformer::LinformerOp;
pub use crate::attention::lsh::LshOp;
pub use crate::attention::nystrom::NystromOp;
pub use crate::attention::sparse::SparseOp;
pub use crate::attention::spectral_shift::SpectralShiftOp;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::qkv;
    use crate::attention::SpectralShiftConfig;
    use crate::kernels::BatchedVariant;

    /// Every op (and the enum-config impl) runs through the one seam.
    #[test]
    fn all_six_ops_attend_through_the_trait() {
        let (q, k, v) = qkv(1, 64, 16);
        let ops: Vec<Box<dyn AttentionOp>> = vec![
            Box::new(FullOp),
            Box::new(NystromOp { landmarks: 8, pinv_iters: 6 }),
            Box::new(SpectralShiftOp(SpectralShiftConfig::new(8))),
            Box::new(LinformerOp { kdim: 8, seed: 7 }),
            Box::new(LshOp { rounds: 2, bits: None, seed: 7 }),
            Box::new(SparseOp { window: None, stride: None }),
        ];
        let mut ws = Workspace::new();
        let ctx = KernelCtx::global();
        for op in &ops {
            let out = op.attend(&ctx, &q, &k, &v, &mut ws);
            assert_eq!((out.rows, out.cols), (64, 16), "{}", op.name());
            assert!(out.data.iter().all(|x| x.is_finite()), "{}", op.name());
            ws.put(out.data);
        }
        // names are distinct (they key metrics and bench rows)
        let mut names: Vec<&str> = ops.iter().map(|o| o.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 6);
    }

    #[test]
    fn enum_config_delegates_to_the_same_ops() {
        let (q, k, v) = qkv(2, 64, 16);
        let mut ws = Workspace::new();
        let ctx = KernelCtx::global();
        let via_enum = BatchedVariant::SpectralShift(SpectralShiftConfig::new(8))
            .attend(&ctx, &q, &k, &v, &mut ws);
        let via_op = SpectralShiftOp(SpectralShiftConfig::new(8))
            .attend(&ctx, &q, &k, &v, &mut ws);
        assert_eq!(via_enum.data, via_op.data, "enum and op must be one function");
    }

    #[test]
    fn landmark_divisors() {
        assert_eq!(FullOp.landmark_divisor(), None);
        assert_eq!(NystromOp { landmarks: 16, pinv_iters: 8 }.landmark_divisor(),
                   Some(16));
        assert_eq!(SpectralShiftOp(SpectralShiftConfig::new(32))
                       .landmark_divisor(),
                   Some(32));
        assert_eq!(LinformerOp { kdim: 16, seed: 0 }.landmark_divisor(), None);
        assert_eq!(LshOp { rounds: 1, bits: None, seed: 0 }.landmark_divisor(),
                   None);
        assert_eq!(SparseOp { window: None, stride: None }.landmark_divisor(),
                   None);
    }
}
