//! Scalar multi-layer reference forward — the ground truth the kernel
//! stack is parity-tested against.
//!
//! Mirrors [`EncoderStack::forward_batch`] block for block using only
//! reference-grade arithmetic: the seed scalar attention pipelines
//! preserved in [`spectral_shift::reference`], naive [`matmul_f32`],
//! and plain-loop LN/GELU below. Like the kernel `reference` modules,
//! this path is never "improved" for speed; `tests/model_parity.rs`
//! pins the fast stack against it at max rel err < 1e-4.
//!
//! [`spectral_shift::reference`]: crate::attention::spectral_shift::reference

use super::layer::{Projections, LN_EPS};
use super::stack::EncoderStack;
use crate::attention::spectral_shift::reference;
use crate::attention::{lsh_attention, matmul_f32, sparse_attention, Tensor2};
use crate::kernels::BatchedVariant;
use crate::rngx::Rng;

/// A scalar single-head attention function.
pub type AttnRef = Box<dyn Fn(&Tensor2, &Tensor2, &Tensor2) -> Tensor2>;

/// The reference attention for a serving variant: the preserved seed
/// scalar pipelines for full / nystrom / spectral-shift, a naive-matmul
/// rebuild of the seeded projection for linformer, and the (already
/// scalar) lsh / sparse entry points.
pub fn ref_attention(variant: BatchedVariant) -> AttnRef {
    match variant {
        BatchedVariant::Full => Box::new(naive_softmax_attention_ref),
        BatchedVariant::Nystrom { landmarks, pinv_iters } => {
            Box::new(move |q: &Tensor2, k: &Tensor2, v: &Tensor2| {
                reference::nystrom_attention_ref(q, k, v, landmarks, pinv_iters,
                                                 None)
            })
        }
        BatchedVariant::SpectralShift(cfg) => {
            Box::new(move |q: &Tensor2, k: &Tensor2, v: &Tensor2| {
                reference::spectral_shift_attention_ref(q, k, v, &cfg)
            })
        }
        BatchedVariant::Linformer { kdim, seed } => {
            // independent scalar pipeline: regenerate the same seeded
            // projection E the fast path draws, but project with the
            // naive matmul and attend with the naive softmax — a fast-
            // kernel bug cannot hide in a self-comparison
            Box::new(move |q: &Tensor2, k: &Tensor2, v: &Tensor2| {
                let m = k.rows;
                let mut rng = Rng::new(seed);
                let std = 1.0 / (kdim as f32).sqrt();
                let mut e = Tensor2::zeros(kdim, m);
                rng.fill_normal_f32(&mut e.data, 0.0, std);
                let kp = matmul_f32(&e, k);
                let vp = matmul_f32(&e, v);
                naive_softmax_attention_ref(q, &kp, &vp)
            })
        }
        BatchedVariant::Lsh { rounds, bits, seed } => {
            Box::new(move |q: &Tensor2, k: &Tensor2, v: &Tensor2| {
                lsh_attention(q, k, v, rounds, bits, seed, None)
            })
        }
        BatchedVariant::Sparse { window, stride } => {
            Box::new(move |q: &Tensor2, k: &Tensor2, v: &Tensor2| {
                sparse_attention(q, k, v, window, stride, None)
            })
        }
    }
}

/// Scalar forward through `stack` for one request's (plen × d)
/// embedding: seed bare-attention block, then each full pre-LN block
/// with naive matmuls and the scalar LN/GELU. Mirrors the kernel path
/// feature for feature: per-block attention operators (variant mixing)
/// and, when the block carries [`Projections`], the projected MHA via
/// [`projected_mha_ref`].
pub fn forward_ref(stack: &EncoderStack, x: &Tensor2) -> Tensor2 {
    let attn = ref_attention(stack.variants()[0]);
    let heads = stack.n_heads();
    let mut cur = mha_ref(x, heads, &attn);
    for (b, blk) in stack.blocks().iter().enumerate() {
        let attn = ref_attention(stack.variants()[b + 1]);
        // attention sublayer
        let ln = layernorm_ref(&cur, &blk.ln1_gain, &blk.ln1_bias);
        let att = match blk.projections() {
            Some(p) => projected_mha_ref(&ln, p, &attn),
            None => mha_ref(&ln, heads, &attn),
        };
        for (c, a) in cur.data.iter_mut().zip(&att.data) {
            *c += *a;
        }
        // FFN sublayer
        let ln2 = layernorm_ref(&cur, &blk.ln2_gain, &blk.ln2_bias);
        let w1 = Tensor2::from_vec(blk.d, blk.dff, blk.w1.clone());
        let mut f1 = matmul_f32(&ln2, &w1);
        for i in 0..f1.rows {
            for (v, &b) in f1.row_mut(i).iter_mut().zip(&blk.b1) {
                *v = gelu_ref(*v + b);
            }
        }
        let w2 = Tensor2::from_vec(blk.dff, blk.d, blk.w2.clone());
        let f2 = matmul_f32(&f1, &w2);
        for i in 0..cur.rows {
            let crow = cur.row_mut(i);
            let frow = f2.row(i);
            for j in 0..blk.d {
                crow[j] += frow[j] + blk.b2[j];
            }
        }
    }
    cur
}

/// Naive in-k-order matmul: `c[i][j] = Σ_k a[i][k]·b[k][j]`, adds
/// strictly in increasing k. This is the textbook triple loop — and
/// because the blocked GEMM also never splits or reorders k, the two
/// round identically, which matters below: discrete operators (LSH
/// bucketing) amplify any rounding difference on their *inputs* into
/// order-1 output changes, so the reference must project bitwise like
/// the kernel path does. (`matmul_f32`'s 4-way split accumulators
/// round differently, so it cannot be used here.)
fn matmul_k_order_ref(a: &Tensor2, b: &[f32], cols: usize) -> Tensor2 {
    assert_eq!(b.len(), a.cols * cols);
    let mut c = Tensor2::zeros(a.rows, cols);
    for i in 0..a.rows {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for (k, &av) in arow.iter().enumerate() {
            let brow = &b[k * cols..(k + 1) * cols];
            for j in 0..cols {
                crow[j] += av * brow[j];
            }
        }
    }
    c
}

/// Scalar projected multi-head attention: head `h` attends over
/// `q = x·W_Q^h`, `k = x·W_K^h`, `v = x·W_V^h` (naive in-order
/// matmuls), the head outputs are concatenated and pushed through
/// `W_O`. The mirror of [`Projections::mha_batch`] in reference-grade
/// arithmetic.
///
/// [`Projections::mha_batch`]: super::layer::Projections::mha_batch
pub fn projected_mha_ref(x: &Tensor2, proj: &Projections,
                         attn: &AttnRef) -> Tensor2 {
    let (h, dh) = (proj.n_heads(), proj.dh());
    let d = x.cols;
    assert_eq!(d, h * dh, "projection width mismatch");
    let mut merged = Tensor2::zeros(x.rows, d);
    for head in 0..h {
        let oh = attn(&matmul_k_order_ref(x, proj.wq(head), dh),
                      &matmul_k_order_ref(x, proj.wk(head), dh),
                      &matmul_k_order_ref(x, proj.wv(head), dh));
        assert_eq!((oh.rows, oh.cols), (x.rows, dh));
        for i in 0..x.rows {
            merged.row_mut(i)[head * dh..(head + 1) * dh]
                .copy_from_slice(oh.row(i));
        }
    }
    matmul_k_order_ref(&merged, proj.wo(), d)
}

/// Scalar multi-head wrapper: split columns into heads, attend each with
/// the scalar reference, stitch back.
pub fn mha_ref(x: &Tensor2, n_heads: usize, attn: &AttnRef) -> Tensor2 {
    assert!(n_heads > 0 && x.cols % n_heads == 0);
    let dh = x.cols / n_heads;
    let mut out = Tensor2::zeros(x.rows, x.cols);
    for h in 0..n_heads {
        let mut xs = Tensor2::zeros(x.rows, dh);
        for i in 0..x.rows {
            xs.row_mut(i)
                .copy_from_slice(&x.row(i)[h * dh..(h + 1) * dh]);
        }
        let oh = attn(&xs, &xs, &xs);
        assert_eq!((oh.rows, oh.cols), (x.rows, dh));
        for i in 0..x.rows {
            out.row_mut(i)[h * dh..(h + 1) * dh].copy_from_slice(oh.row(i));
        }
    }
    out
}

/// Plain-loop layer norm (same ε as the fused kernel).
pub fn layernorm_ref(x: &Tensor2, gain: &[f32], bias: &[f32]) -> Tensor2 {
    let (n, d) = (x.rows, x.cols);
    let mut out = Tensor2::zeros(n, d);
    for i in 0..n {
        let row = x.row(i);
        let mean: f32 = row.iter().sum::<f32>() / d as f32;
        let var: f32 =
            row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        for j in 0..d {
            out.data[i * d + j] = (row[j] - mean) * inv * gain[j] + bias[j];
        }
    }
    out
}

/// GELU, same tanh form as the fused kernel.
pub fn gelu_ref(z: f32) -> f32 {
    crate::kernels::gelu(z)
}

/// Naive scalar softmax attention (the full-variant reference; the fast
/// path streams keys through the flash kernel instead).
pub fn naive_softmax_attention_ref(q: &Tensor2, k: &Tensor2, v: &Tensor2) -> Tensor2 {
    let scale = crate::attention::default_scale(q.cols);
    let mut out = Tensor2::zeros(q.rows, v.cols);
    for i in 0..q.rows {
        let mut s: Vec<f32> = (0..k.rows)
            .map(|j| {
                q.row(i).iter().zip(k.row(j)).map(|(a, b)| a * b).sum::<f32>()
                    * scale
            })
            .collect();
        let m = s.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for x in s.iter_mut() {
            *x = (*x - m).exp();
            sum += *x;
        }
        for x in s.iter_mut() {
            *x /= sum;
        }
        for (j, &w) in s.iter().enumerate() {
            for (o, &vv) in out.row_mut(i).iter_mut().zip(v.row(j)) {
                *o += w * vv;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::{qkv, rel_err};
    use crate::attention::{softmax_attention, SpectralShiftConfig};
    use crate::kernels::{KernelCtx, Workspace};

    #[test]
    fn naive_full_matches_flash() {
        let (q, k, v) = qkv(1, 96, 8);
        let a = naive_softmax_attention_ref(&q, &k, &v);
        let b = softmax_attention(&q, &k, &v, None);
        assert!(a.max_abs_diff(&b) < 1e-4);
    }

    #[test]
    fn layernorm_ref_matches_kernel() {
        let mut rng = Rng::new(2);
        let x = Tensor2::randn(&mut rng, 33, 16, 2.0);
        let mut gain = vec![0.0f32; 16];
        let mut bias = vec![0.0f32; 16];
        rng.fill_normal_f32(&mut gain, 1.0, 0.1);
        rng.fill_normal_f32(&mut bias, 0.0, 0.1);
        let slow = layernorm_ref(&x, &gain, &bias);
        let fast = crate::kernels::layernorm(
            &KernelCtx::global(), &x, &gain, &bias, LN_EPS,
            &mut Workspace::new());
        assert!(slow.max_abs_diff(&fast) < 1e-5);
    }

    #[test]
    fn forward_ref_matches_kernel_stack() {
        // block-for-block mirror: depth 3, spectral shift
        let stack = EncoderStack::new(
            BatchedVariant::SpectralShift(SpectralShiftConfig::new(8)),
            3, 16, 2, 2, 9);
        let mut rng = Rng::new(10);
        let x = Tensor2::randn(&mut rng, 64, 16, 1.0);
        let want = forward_ref(&stack, &x);
        let mut exec = crate::kernels::BatchedAttention::new(KernelCtx::global());
        let mut ws = Workspace::new();
        let mut xs = vec![x];
        stack.forward_batch(&mut exec, &mut xs, &mut ws);
        let e = rel_err(&xs[0], &want);
        assert!(e < 1e-4, "stack vs scalar reference rel err {e}");
    }

    #[test]
    fn k_order_matmul_is_bitwise_the_blocked_gemm() {
        // the load-bearing assumption of the projected reference: the
        // textbook k-order loop and the blocked GEMM round identically
        // (neither splits or reorders the k reduction). Pinned to the
        // SCALAR arm since the ISA dispatch landed: the SIMD arms
        // contract mul+add into FMA, which rounds once where the
        // textbook loop rounds twice — they hold the 1e-4 envelope
        // (tests/kernel_parity.rs) but not bitwise identity with this
        // loop, per the PR-5 risk note on the projected-LSH path.
        let mut rng = Rng::new(3);
        let a = Tensor2::randn(&mut rng, 37, 24, 1.0);
        let mut b = vec![0.0f32; 24 * 12];
        rng.fill_normal_f32(&mut b, 0.0, 1.0);
        let slow = matmul_k_order_ref(&a, &b, 12);
        let mut fast = vec![0.0f32; 37 * 12];
        let ctx = KernelCtx::global().with_isa(crate::kernels::Isa::Scalar);
        crate::kernels::gemm_into(&ctx, &a.data, &b, &mut fast, 37, 24, 12);
        assert_eq!(slow.data, fast, "reference projection must round like \
                                     the kernel projection");
    }

    #[test]
    fn projected_forward_ref_matches_kernel_stack() {
        // same mirror with QKV/output projections live in every full
        // block — pins Projections::mha_batch against the naive path
        let stack = EncoderStack::new_mixed(
            vec![BatchedVariant::SpectralShift(SpectralShiftConfig::new(8)); 2],
            16, 2, 2, 9, true);
        let mut rng = Rng::new(12);
        let x = Tensor2::randn(&mut rng, 64, 16, 1.0);
        let want = forward_ref(&stack, &x);
        let mut exec = crate::kernels::BatchedAttention::new(KernelCtx::global());
        let mut ws = Workspace::new();
        let mut xs = vec![x];
        stack.forward_batch(&mut exec, &mut xs, &mut ws);
        let e = rel_err(&xs[0], &want);
        assert!(e < 1e-4, "projected stack vs scalar reference rel err {e}");
    }

    #[test]
    fn mixed_variant_forward_ref_matches_kernel_stack() {
        // per-block operators: spectral shift below, exact softmax on top
        let stack = EncoderStack::new_mixed(
            vec![BatchedVariant::SpectralShift(SpectralShiftConfig::new(8)),
                 BatchedVariant::SpectralShift(SpectralShiftConfig::new(8)),
                 BatchedVariant::Full],
            16, 2, 2, 9, false);
        let mut rng = Rng::new(13);
        let x = Tensor2::randn(&mut rng, 64, 16, 1.0);
        let want = forward_ref(&stack, &x);
        let mut exec = crate::kernels::BatchedAttention::new(KernelCtx::global());
        let mut ws = Workspace::new();
        let mut xs = vec![x];
        stack.forward_batch(&mut exec, &mut xs, &mut ws);
        let e = rel_err(&xs[0], &want);
        assert!(e < 1e-4, "mixed stack vs scalar reference rel err {e}");
    }
}
