//! One pre-LN transformer encoder block (weights + sublayer kernels).
//!
//! The block is the standard sandwich both Linformer and Skyformer hold
//! fixed while swapping the attention operator:
//!
//! ```text
//!   x ── LN₁ ──▶ MHA (any AttentionOp) ──▶ (+x) ── LN₂ ──▶ FFN ──▶ (+)
//! ```
//!
//! The attention sublayer itself is orchestrated at the stack level
//! (heads × requests fan out together over the pool); this module owns
//! the per-block weights and the LN/FFN compute, all running on the
//! shared kernel core: [`layernorm`] row-parallel, the two FFN GEMMs on
//! the blocked parallel [`gemm_into`], and the activation through the
//! fused [`bias_gelu`] pass — so the whole block inherits the kernels'
//! bitwise thread-count determinism and workspace discipline.
//!
//! Since the projection refactor a block may additionally carry
//! [`Projections`]: per-head `W_Q`/`W_K`/`W_V` maps plus an output
//! projection `W_O` over the concatenated heads. These wrap *around*
//! the unchanged [`AttentionOp`](super::op::AttentionOp) seam — the
//! operator still sees one `(len × dh)` head in, one out — which is
//! exactly the `Q = XW_Q, K = XW_K, V = XW_V` formulation the paper
//! (and Nyströmformer / Linformer) defines its approximation over.
//! Blocks without projections attend over the raw per-head slice of
//! the LN output, preserving the pre-projection served function
//! bitwise.

use super::op::AttentionOp;
use crate::attention::Tensor2;
use crate::kernels::{
    bias_gelu, gemm_into, layernorm, AttnTask, BatchedAttention, KernelCtx,
    Workspace,
};
use crate::rngx::Rng;

/// Layer-norm epsilon shared by the kernel and scalar-reference paths.
pub const LN_EPS: f32 = 1e-5;

/// Per-head attention projections of one encoder block: head `h`
/// attends over `q = x·W_Q^h`, `k = x·W_K^h`, `v = x·W_V^h` (each
/// `W^h` is `d_model × dh`), and the concatenated head outputs pass
/// through one `d_model × d_model` output projection `W_O`. Like every
/// other model weight the matrices are a seeded deterministic draw
/// unless loaded from a [`checkpoint`](super::checkpoint).
pub struct Projections {
    pub(crate) d: usize,
    pub(crate) n_heads: usize,
    pub(crate) dh: usize,
    /// `n_heads` head-major `(d × dh)` row-major matrices, concatenated.
    pub(crate) wq: Vec<f32>,
    pub(crate) wk: Vec<f32>,
    pub(crate) wv: Vec<f32>,
    /// `(d × d)` row-major output projection over concatenated heads.
    pub(crate) wo: Vec<f32>,
}

impl Projections {
    /// Draw one block's projection weights from `rng` (1/√fan_in
    /// scaling, fan_in = d_model for all four maps, so projected
    /// activations stay on the residual stream's scale).
    pub(crate) fn seeded(rng: &mut Rng, d: usize, n_heads: usize) -> Projections {
        assert!(n_heads >= 1 && d % n_heads == 0);
        let std = 1.0 / (d as f32).sqrt();
        let mut draw = |len: usize| -> Vec<f32> {
            let mut v = vec![0.0f32; len];
            rng.fill_normal_f32(&mut v, 0.0, std);
            v
        };
        let dh = d / n_heads;
        Projections {
            d,
            n_heads,
            dh,
            wq: draw(n_heads * d * dh),
            wk: draw(n_heads * d * dh),
            wv: draw(n_heads * d * dh),
            wo: draw(d * d),
        }
    }

    /// Assemble projections from already-materialized weights (the
    /// checkpoint load path). Shapes are the caller's contract.
    pub(crate) fn from_parts(d: usize, n_heads: usize, wq: Vec<f32>,
                             wk: Vec<f32>, wv: Vec<f32>, wo: Vec<f32>)
                             -> Projections {
        let dh = d / n_heads;
        assert_eq!(wq.len(), n_heads * d * dh);
        assert_eq!(wk.len(), n_heads * d * dh);
        assert_eq!(wv.len(), n_heads * d * dh);
        assert_eq!(wo.len(), d * d);
        Projections { d, n_heads, dh, wq, wk, wv, wo }
    }

    /// Heads per block.
    pub fn n_heads(&self) -> usize {
        self.n_heads
    }

    /// Per-head width `d_model / n_heads`.
    pub fn dh(&self) -> usize {
        self.dh
    }

    /// Head `h`'s `(d × dh)` query projection, row-major.
    pub fn wq(&self, h: usize) -> &[f32] {
        &self.wq[h * self.d * self.dh..(h + 1) * self.d * self.dh]
    }

    /// Head `h`'s `(d × dh)` key projection, row-major.
    pub fn wk(&self, h: usize) -> &[f32] {
        &self.wk[h * self.d * self.dh..(h + 1) * self.d * self.dh]
    }

    /// Head `h`'s `(d × dh)` value projection, row-major.
    pub fn wv(&self, h: usize) -> &[f32] {
        &self.wv[h * self.d * self.dh..(h + 1) * self.d * self.dh]
    }

    /// The `(d × d)` output projection, row-major.
    pub fn wo(&self) -> &[f32] {
        &self.wo
    }

    /// Projected multi-head attention for a batch of per-request
    /// activations: for every request and head, `q/k/v` are projected
    /// with the blocked parallel GEMM (staged from `ws`), all heads ×
    /// requests fan out over `exec`'s pool through the one
    /// [`AttentionOp`] seam, head outputs are stitched back and pushed
    /// through `W_O`. Returns one `(len × d)` tensor per request,
    /// backed by `exec.scratch()` — the caller recycles each with
    /// `exec.scratch().put(out.data)`, mirroring
    /// [`attention_batched_self_pooled`]'s contract, so warm serving
    /// stays allocation-free.
    ///
    /// [`attention_batched_self_pooled`]:
    ///     crate::kernels::attention_batched_self_pooled
    pub fn mha_batch(&self, exec: &mut BatchedAttention, xs: &[Tensor2],
                     op: &dyn AttentionOp, ws: &mut Workspace) -> Vec<Tensor2> {
        let (h, d, dh) = (self.n_heads, self.d, self.dh);
        if xs.is_empty() {
            return Vec::new();
        }
        let ctx = exec.ctx().clone();
        let mut tasks = Vec::with_capacity(xs.len() * h);
        for x in xs {
            assert_eq!(x.cols, d, "projection width mismatch");
            let n = x.rows;
            for head in 0..h {
                let mut project = |w: &[f32]| -> Tensor2 {
                    let mut t = Tensor2 { rows: n, cols: dh, data: ws.take(n * dh) };
                    gemm_into(&ctx, &x.data, w, &mut t.data, n, d, dh);
                    t
                };
                tasks.push(AttnTask {
                    q: project(self.wq(head)),
                    k: project(self.wk(head)),
                    v: project(self.wv(head)),
                });
            }
        }
        let heads = exec.run(&tasks, op);
        let mut outs = Vec::with_capacity(xs.len());
        let mut task_it = tasks.into_iter();
        let mut slot = 0;
        for x in xs {
            let n = x.rows;
            // stitch this request's heads into one (n × d) tensor ...
            let mut merged = Tensor2 { rows: n, cols: d, data: ws.take(n * d) };
            for head in 0..h {
                let ho = &heads[slot + head];
                assert_eq!((ho.rows, ho.cols), (n, dh));
                for i in 0..n {
                    merged.row_mut(i)[head * dh..(head + 1) * dh]
                        .copy_from_slice(ho.row(i));
                }
                let t = task_it.next().expect("one task per head");
                ws.put(t.q.data);
                ws.put(t.k.data);
                ws.put(t.v.data);
            }
            slot += h;
            // ... and push it through W_O into executor scratch
            let mut out = Tensor2 { rows: n, cols: d,
                                    data: exec.scratch().take(n * d) };
            gemm_into(&ctx, &merged.data, &self.wo, &mut out.data, n, d, d);
            ws.put(merged.data);
            outs.push(out);
        }
        // head outputs came from the per-task slot arenas — return them
        for (i, ho) in heads.into_iter().enumerate() {
            exec.put_slot(i, ho.data);
        }
        outs
    }
}

/// Weights of one encoder block. Like the serving model's embedding
/// table, they are a seeded deterministic draw: two stacks built from
/// the same `(seed, shape)` serve the same function, which is what lets
/// tests (and forked worker engines) rebuild and cross-check the model.
/// Checkpoint-loaded stacks replace the draw with externally trained
/// weights (see [`checkpoint`](super::checkpoint)).
pub struct EncoderLayer {
    pub(crate) d: usize,
    pub(crate) dff: usize,
    /// LN before attention: gain/bias over d_model.
    pub(crate) ln1_gain: Vec<f32>,
    pub(crate) ln1_bias: Vec<f32>,
    /// LN before the FFN.
    pub(crate) ln2_gain: Vec<f32>,
    pub(crate) ln2_bias: Vec<f32>,
    /// FFN expand: (d × dff) row-major, plus its bias.
    pub(crate) w1: Vec<f32>,
    pub(crate) b1: Vec<f32>,
    /// FFN contract: (dff × d) row-major, plus its bias.
    pub(crate) w2: Vec<f32>,
    pub(crate) b2: Vec<f32>,
    /// Attention projections (None = attend over the raw per-head
    /// slice — the pre-projection served function, kept bitwise).
    pub(crate) proj: Option<Projections>,
}

impl EncoderLayer {
    /// Draw one block's weights from `rng`. GEMM weights use 1/√fan_in
    /// scaling so the residual stream stays O(1) across depth; LN
    /// gains/biases get small seeded variation so they are load-bearing
    /// (a unit-gain LN would make the parameters dead weight). With
    /// `projections` the QKV/output maps are drawn *after* the
    /// LN/FFN weights, so the projection-free stream is identical to
    /// the pre-projection releases draw for draw.
    pub(crate) fn seeded(rng: &mut Rng, d: usize, dff: usize, n_heads: usize,
                         projections: bool) -> EncoderLayer {
        let mut draw = |len: usize, mean: f32, std: f32| -> Vec<f32> {
            let mut v = vec![0.0f32; len];
            rng.fill_normal_f32(&mut v, mean, std);
            v
        };
        let mut layer = EncoderLayer {
            d,
            dff,
            ln1_gain: draw(d, 1.0, 0.05),
            ln1_bias: draw(d, 0.0, 0.05),
            ln2_gain: draw(d, 1.0, 0.05),
            ln2_bias: draw(d, 0.0, 0.05),
            w1: draw(d * dff, 0.0, 1.0 / (d as f32).sqrt()),
            b1: draw(dff, 0.0, 0.02),
            w2: draw(dff * d, 0.0, 1.0 / (dff as f32).sqrt()),
            b2: draw(d, 0.0, 0.02),
            proj: None,
        };
        if projections {
            layer.proj = Some(Projections::seeded(rng, d, n_heads));
        }
        layer
    }

    /// This block's attention projections, when configured.
    pub fn projections(&self) -> Option<&Projections> {
        self.proj.as_ref()
    }

    /// LN₁(x): the tensor the attention sublayer attends over (q = k =
    /// v). Backed by `ws` scratch — return it with `ws.put` after the
    /// attention fan-out.
    pub fn attn_input(&self, ctx: &KernelCtx, x: &Tensor2,
                      ws: &mut Workspace) -> Tensor2 {
        layernorm(ctx, x, &self.ln1_gain, &self.ln1_bias, LN_EPS, ws)
    }

    /// The FFN sublayer in place: x += W₂·gelu(LN₂(x)·W₁ + b₁) + b₂.
    /// Both GEMMs run on the blocked parallel kernel, the activation on
    /// the fused bias+GELU pass; every intermediate comes from (and
    /// returns to) `ws`.
    pub fn ffn_sublayer(&self, ctx: &KernelCtx, x: &mut Tensor2,
                        ws: &mut Workspace) {
        let (n, d, dff) = (x.rows, self.d, self.dff);
        assert_eq!(x.cols, d, "activation width mismatch");
        let h = layernorm(ctx, x, &self.ln2_gain, &self.ln2_bias, LN_EPS, ws);
        let mut f1 = Tensor2 { rows: n, cols: dff, data: ws.take(n * dff) };
        gemm_into(ctx, &h.data, &self.w1, &mut f1.data, n, d, dff);
        bias_gelu(ctx, &mut f1, &self.b1);
        let mut f2 = ws.take(n * d);
        gemm_into(ctx, &f1.data, &self.w2, &mut f2, n, dff, d);
        for i in 0..n {
            let xrow = x.row_mut(i);
            let frow = &f2[i * d..(i + 1) * d];
            for j in 0..d {
                xrow[j] += frow[j] + self.b2[j];
            }
        }
        ws.put(h.data);
        ws.put(f1.data);
        ws.put(f2);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::BatchedVariant;

    fn layer(seed: u64, d: usize, dff: usize) -> EncoderLayer {
        EncoderLayer::seeded(&mut Rng::new(seed), d, dff, 2, false)
    }

    fn projected_layer(seed: u64, d: usize, dff: usize, h: usize) -> EncoderLayer {
        EncoderLayer::seeded(&mut Rng::new(seed), d, dff, h, true)
    }

    #[test]
    fn seeded_layers_are_reproducible() {
        let a = layer(7, 16, 32);
        let b = layer(7, 16, 32);
        assert_eq!(a.w1, b.w1);
        assert_eq!(a.ln1_gain, b.ln1_gain);
        let c = layer(8, 16, 32);
        assert_ne!(a.w1, c.w1);
    }

    #[test]
    fn projection_flag_does_not_perturb_the_base_draw() {
        // the LN/FFN stream must be identical with and without
        // projections (the off path is the PR-4 function, bitwise)
        let off = layer(7, 16, 32);
        let on = projected_layer(7, 16, 32, 2);
        assert_eq!(off.w1, on.w1);
        assert_eq!(off.b2, on.b2);
        assert!(off.proj.is_none());
        let p = on.projections().expect("projections drawn");
        assert_eq!(p.n_heads(), 2);
        assert_eq!(p.dh(), 8);
        assert_eq!(p.wq(0).len(), 16 * 8);
        assert_eq!(p.wo().len(), 16 * 16);
        // per-head slices are distinct draws
        assert_ne!(p.wq(0), p.wq(1));
    }

    #[test]
    fn projected_mha_is_thread_invariant_and_differs_from_bare() {
        let l = projected_layer(3, 16, 32, 2);
        let p = l.projections().unwrap();
        let mut rng = Rng::new(5);
        let xs = vec![
            Tensor2::randn(&mut rng, 48, 16, 1.0),
            Tensor2::randn(&mut rng, 32, 16, 1.0),
        ];
        let op = BatchedVariant::Full;
        let mut ws = Workspace::new();
        let mut seq_exec = BatchedAttention::new(KernelCtx::sequential());
        let a = p.mha_batch(&mut seq_exec, &xs, &op, &mut ws);
        let mut par_exec = BatchedAttention::new(KernelCtx::global());
        let b = p.mha_batch(&mut par_exec, &xs, &op, &mut ws);
        let bare = crate::kernels::attention_batched_self(
            &mut par_exec, &xs, 2, &op);
        for ((x, y), raw) in a.iter().zip(&b).zip(&bare) {
            assert_eq!(x.data, y.data, "projected MHA must be thread-invariant");
            assert_ne!(x.data, raw.data, "projections must be load-bearing");
            assert!(x.data.iter().all(|v| v.is_finite()));
        }
        for t in a {
            seq_exec.scratch().put(t.data);
        }
        for t in b {
            par_exec.scratch().put(t.data);
        }
    }

    #[test]
    fn projected_mha_keeps_the_arenas_flat() {
        let l = projected_layer(9, 16, 32, 4);
        let p = l.projections().unwrap();
        let mut rng = Rng::new(6);
        let xs = vec![Tensor2::randn(&mut rng, 64, 16, 1.0)];
        let op = BatchedVariant::Full;
        let mut ws = Workspace::new();
        let mut exec = BatchedAttention::new(KernelCtx::global());
        let outs = p.mha_batch(&mut exec, &xs, &op, &mut ws);
        for t in outs {
            exec.scratch().put(t.data);
        }
        let warm = ws.allocations();
        for _ in 0..3 {
            let outs = p.mha_batch(&mut exec, &xs, &op, &mut ws);
            for t in outs {
                exec.scratch().put(t.data);
            }
        }
        assert_eq!(ws.allocations(), warm,
                   "steady-state projected MHA must not grow the arena");
    }

    #[test]
    fn ffn_sublayer_is_thread_count_invariant_and_residual() {
        let l = layer(1, 16, 64);
        let mut rng = Rng::new(2);
        let base = Tensor2::randn(&mut rng, 50, 16, 1.0);
        let mut ws = Workspace::new();
        let mut seq = base.clone();
        l.ffn_sublayer(&KernelCtx::sequential(), &mut seq, &mut ws);
        let mut par = base.clone();
        l.ffn_sublayer(&KernelCtx::global(), &mut par, &mut ws);
        assert_eq!(seq.data, par.data, "FFN must be bitwise thread-invariant");
        // the sublayer is residual: output differs from input but stays
        // on its scale (1/√fan_in init keeps the update O(1))
        assert!(seq.data.iter().all(|v| v.is_finite()));
        assert_ne!(seq.data, base.data);
    }

    #[test]
    fn ffn_sublayer_steady_state_uses_the_arena() {
        let l = layer(3, 16, 32);
        let mut rng = Rng::new(4);
        let mut x = Tensor2::randn(&mut rng, 40, 16, 1.0);
        let mut ws = Workspace::new();
        l.ffn_sublayer(&KernelCtx::global(), &mut x, &mut ws);
        let warm = ws.allocations();
        for _ in 0..3 {
            l.ffn_sublayer(&KernelCtx::global(), &mut x, &mut ws);
        }
        assert_eq!(ws.allocations(), warm);
    }
}
