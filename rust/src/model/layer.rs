//! One pre-LN transformer encoder block (weights + sublayer kernels).
//!
//! The block is the standard sandwich both Linformer and Skyformer hold
//! fixed while swapping the attention operator:
//!
//! ```text
//!   x ── LN₁ ──▶ MHA (any AttentionOp) ──▶ (+x) ── LN₂ ──▶ FFN ──▶ (+)
//! ```
//!
//! The attention sublayer itself is orchestrated at the stack level
//! (heads × requests fan out together over the pool); this module owns
//! the per-block weights and the LN/FFN compute, all running on the
//! shared kernel core: [`layernorm`] row-parallel, the two FFN GEMMs on
//! the blocked parallel [`gemm_into`], and the activation through the
//! fused [`bias_gelu`] pass — so the whole block inherits the kernels'
//! bitwise thread-count determinism and workspace discipline.

use crate::attention::Tensor2;
use crate::kernels::{bias_gelu, gemm_into, layernorm, KernelCtx, Workspace};
use crate::rngx::Rng;

/// Layer-norm epsilon shared by the kernel and scalar-reference paths.
pub const LN_EPS: f32 = 1e-5;

/// Weights of one encoder block. Like the serving model's embedding
/// table, they are a seeded deterministic draw: two stacks built from
/// the same `(seed, shape)` serve the same function, which is what lets
/// tests (and forked worker engines) rebuild and cross-check the model.
pub struct EncoderLayer {
    pub(crate) d: usize,
    pub(crate) dff: usize,
    /// LN before attention: gain/bias over d_model.
    pub(crate) ln1_gain: Vec<f32>,
    pub(crate) ln1_bias: Vec<f32>,
    /// LN before the FFN.
    pub(crate) ln2_gain: Vec<f32>,
    pub(crate) ln2_bias: Vec<f32>,
    /// FFN expand: (d × dff) row-major, plus its bias.
    pub(crate) w1: Vec<f32>,
    pub(crate) b1: Vec<f32>,
    /// FFN contract: (dff × d) row-major, plus its bias.
    pub(crate) w2: Vec<f32>,
    pub(crate) b2: Vec<f32>,
}

impl EncoderLayer {
    /// Draw one block's weights from `rng`. GEMM weights use 1/√fan_in
    /// scaling so the residual stream stays O(1) across depth; LN
    /// gains/biases get small seeded variation so they are load-bearing
    /// (a unit-gain LN would make the parameters dead weight).
    pub(crate) fn seeded(rng: &mut Rng, d: usize, dff: usize) -> EncoderLayer {
        let mut draw = |len: usize, mean: f32, std: f32| -> Vec<f32> {
            let mut v = vec![0.0f32; len];
            rng.fill_normal_f32(&mut v, mean, std);
            v
        };
        EncoderLayer {
            d,
            dff,
            ln1_gain: draw(d, 1.0, 0.05),
            ln1_bias: draw(d, 0.0, 0.05),
            ln2_gain: draw(d, 1.0, 0.05),
            ln2_bias: draw(d, 0.0, 0.05),
            w1: draw(d * dff, 0.0, 1.0 / (d as f32).sqrt()),
            b1: draw(dff, 0.0, 0.02),
            w2: draw(dff * d, 0.0, 1.0 / (dff as f32).sqrt()),
            b2: draw(d, 0.0, 0.02),
        }
    }

    /// LN₁(x): the tensor the attention sublayer attends over (q = k =
    /// v). Backed by `ws` scratch — return it with `ws.put` after the
    /// attention fan-out.
    pub fn attn_input(&self, ctx: &KernelCtx, x: &Tensor2,
                      ws: &mut Workspace) -> Tensor2 {
        layernorm(ctx, x, &self.ln1_gain, &self.ln1_bias, LN_EPS, ws)
    }

    /// The FFN sublayer in place: x += W₂·gelu(LN₂(x)·W₁ + b₁) + b₂.
    /// Both GEMMs run on the blocked parallel kernel, the activation on
    /// the fused bias+GELU pass; every intermediate comes from (and
    /// returns to) `ws`.
    pub fn ffn_sublayer(&self, ctx: &KernelCtx, x: &mut Tensor2,
                        ws: &mut Workspace) {
        let (n, d, dff) = (x.rows, self.d, self.dff);
        assert_eq!(x.cols, d, "activation width mismatch");
        let h = layernorm(ctx, x, &self.ln2_gain, &self.ln2_bias, LN_EPS, ws);
        let mut f1 = Tensor2 { rows: n, cols: dff, data: ws.take(n * dff) };
        gemm_into(ctx, &h.data, &self.w1, &mut f1.data, n, d, dff);
        bias_gelu(ctx, &mut f1, &self.b1);
        let mut f2 = ws.take(n * d);
        gemm_into(ctx, &f1.data, &self.w2, &mut f2, n, dff, d);
        for i in 0..n {
            let xrow = x.row_mut(i);
            let frow = &f2[i * d..(i + 1) * d];
            for j in 0..d {
                xrow[j] += frow[j] + self.b2[j];
            }
        }
        ws.put(h.data);
        ws.put(f1.data);
        ws.put(f2);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer(seed: u64, d: usize, dff: usize) -> EncoderLayer {
        EncoderLayer::seeded(&mut Rng::new(seed), d, dff)
    }

    #[test]
    fn seeded_layers_are_reproducible() {
        let a = layer(7, 16, 32);
        let b = layer(7, 16, 32);
        assert_eq!(a.w1, b.w1);
        assert_eq!(a.ln1_gain, b.ln1_gain);
        let c = layer(8, 16, 32);
        assert_ne!(a.w1, c.w1);
    }

    #[test]
    fn ffn_sublayer_is_thread_count_invariant_and_residual() {
        let l = layer(1, 16, 64);
        let mut rng = Rng::new(2);
        let base = Tensor2::randn(&mut rng, 50, 16, 1.0);
        let mut ws = Workspace::new();
        let mut seq = base.clone();
        l.ffn_sublayer(&KernelCtx::sequential(), &mut seq, &mut ws);
        let mut par = base.clone();
        l.ffn_sublayer(&KernelCtx::global(), &mut par, &mut ws);
        assert_eq!(seq.data, par.data, "FFN must be bitwise thread-invariant");
        // the sublayer is residual: output differs from input but stays
        // on its scale (1/√fan_in init keeps the update O(1))
        assert!(seq.data.iter().all(|v| v.is_finite()));
        assert_ne!(seq.data, base.data);
    }

    #[test]
    fn ffn_sublayer_steady_state_uses_the_arena() {
        let l = layer(3, 16, 32);
        let mut rng = Rng::new(4);
        let mut x = Tensor2::randn(&mut rng, 40, 16, 1.0);
        let mut ws = Workspace::new();
        l.ffn_sublayer(&KernelCtx::global(), &mut x, &mut ws);
        let warm = ws.allocations();
        for _ in 0..3 {
            l.ffn_sublayer(&KernelCtx::global(), &mut x, &mut ws);
        }
        assert_eq!(ws.allocations(), warm);
    }
}
