//! Versioned binary checkpoints for the encoder stack — the bridge
//! from externally trained weights to the serving path.
//!
//! A checkpoint stores every full-block weight of an [`EncoderStack`]
//! (the seed block is weightless by construction, so depth-1 models
//! have an empty payload) in a little-endian, dependency-free format:
//!
//! ```text
//! offset  size  field
//!      0     8  magic  b"SSAFCKPT"
//!      8     4  version          u32 LE (currently 1)
//!     12     4  d_model          u32 LE
//!     16     4  n_heads          u32 LE
//!     20     4  ffn_mult         u32 LE
//!     24     4  layers           u32 LE (total depth incl. seed block)
//!     28     4  flags            u32 LE (bit 0: projections present)
//!     32     …  payload          f32 LE ×(layers−1) blocks, each:
//!                ln1_gain[d] ln1_bias[d] ln2_gain[d] ln2_bias[d]
//!                w1[d·dff] b1[dff] w2[dff·d] b2[d]
//!                then, if projections:
//!                wq[h·d·dh] wk[h·d·dh] wv[h·d·dh] wo[d·d]
//! ```
//!
//! The payload length is fully determined by the header, and both ends
//! are enforced: a short file fails with [`CheckpointError::Truncated`],
//! extra bytes with [`CheckpointError::TrailingBytes`] — malformed
//! checkpoints **fail closed**, they never serve. Loading is exact:
//! f32 bits round-trip untouched, so `save → load` reproduces the
//! stack bitwise (pinned in `tests/checkpoint.rs`).
//!
//! What a checkpoint deliberately does *not* store: the attention
//! operators (weightless, chosen by the serving config), the embedding
//! table and position signal (drawn from the model seed — the
//! checkpoint covers the encoder, matching the paper's "fixed encoder,
//! swappable operator" evaluation shape), and the model seed itself.

use super::layer::{EncoderLayer, Projections};
use super::stack::{EncoderStack, WeightInit};
use crate::kernels::BatchedVariant;
use std::fmt;
use std::path::Path;

/// Magic bytes leading every checkpoint file.
pub const MAGIC: &[u8; 8] = b"SSAFCKPT";
/// Format version written by [`save`] and accepted by [`load`].
pub const VERSION: u32 = 1;
/// Header bytes before the f32 payload.
const HEADER_LEN: usize = 32;
/// Dimension sanity bounds — a corrupt header must not drive a huge
/// allocation before the length check can catch it.
const MAX_DIM: usize = 1 << 20;

/// Why a checkpoint could not be written, read, or applied.
#[derive(Debug)]
pub enum CheckpointError {
    Io(std::io::Error),
    /// The file does not start with the checkpoint magic bytes.
    BadMagic,
    /// The file's format version is not [`VERSION`].
    UnsupportedVersion(u32),
    /// Header dimensions are zero, inconsistent, or absurd.
    BadDims(String),
    /// The file ends before the header-implied payload does.
    Truncated { need: usize, got: usize },
    /// The file continues past the header-implied payload.
    TrailingBytes(usize),
    /// The checkpoint's shape does not match the configured model.
    Mismatch { field: &'static str, want: usize, got: usize },
}

impl fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CheckpointError::Io(e) => write!(f, "io: {e}"),
            CheckpointError::BadMagic => write!(f, "not a checkpoint (bad magic)"),
            CheckpointError::UnsupportedVersion(v) => {
                write!(f, "unsupported checkpoint version {v} (expected {VERSION})")
            }
            CheckpointError::BadDims(why) => write!(f, "bad dimensions: {why}"),
            CheckpointError::Truncated { need, got } => {
                write!(f, "truncated: need {need} bytes, file has {got}")
            }
            CheckpointError::TrailingBytes(n) => {
                write!(f, "{n} trailing bytes after the payload")
            }
            CheckpointError::Mismatch { field, want, got } => {
                write!(f, "model/checkpoint mismatch on {field}: \
                           configured {want}, checkpoint has {got}")
            }
        }
    }
}

impl std::error::Error for CheckpointError {}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> Self {
        CheckpointError::Io(e)
    }
}

impl From<CheckpointError> for crate::runtime::RuntimeError {
    fn from(e: CheckpointError) -> Self {
        crate::runtime::RuntimeError::Checkpoint(e.to_string())
    }
}

/// A loaded checkpoint: validated header dimensions plus the full-block
/// weights, ready to become an [`EncoderStack`] via
/// [`Checkpoint::into_stack`].
pub struct Checkpoint {
    pub d_model: usize,
    pub n_heads: usize,
    pub ffn_mult: usize,
    /// Total depth, weightless seed block included.
    pub layers: usize,
    pub projections: bool,
    blocks: Vec<EncoderLayer>,
}

impl Checkpoint {
    /// Consume the checkpoint into a serving stack running `variants`
    /// (one operator per block; length must equal the checkpoint
    /// depth). The stack reports [`WeightInit::Loaded`].
    pub fn into_stack(self, variants: Vec<BatchedVariant>)
                      -> Result<EncoderStack, CheckpointError> {
        if variants.len() != self.layers {
            return Err(CheckpointError::Mismatch {
                field: "layers", want: variants.len(), got: self.layers,
            });
        }
        Ok(EncoderStack::from_blocks(variants, self.d_model, self.n_heads,
                                     self.d_model * self.ffn_mult, self.blocks,
                                     self.projections, WeightInit::Loaded))
    }

    /// Check the checkpoint against a configured model shape, naming
    /// the first field that disagrees.
    pub fn check_shape(&self, d_model: usize, n_heads: usize, ffn_mult: usize,
                       layers: usize, projections: bool)
                       -> Result<(), CheckpointError> {
        let fields = [
            ("d_model", d_model, self.d_model),
            ("n_heads", n_heads, self.n_heads),
            ("ffn_mult", ffn_mult, self.ffn_mult),
            ("layers", layers, self.layers),
            ("projections", projections as usize, self.projections as usize),
        ];
        for (field, want, got) in fields {
            if want != got {
                return Err(CheckpointError::Mismatch { field, want, got });
            }
        }
        Ok(())
    }
}

/// f32 elements of one full block's payload — the ONE payload-size
/// formula shared by `save` and `load`, so the writer and the
/// validator cannot drift. Computed in u128 because `load` must
/// evaluate crafted headers whose products overflow usize.
fn block_f32s(d: usize, ffn_mult: usize, projections: bool) -> u128 {
    let d = d as u128;
    let dff = d * ffn_mult as u128;
    // 4 LN vectors + w1 + b1 + w2 + b2
    let base = 4 * d + d * dff + dff + dff * d + d;
    // 3 per-head QKV maps (h · d · dh = d² each) + the d² output map
    if projections { base + 4 * d * d } else { base }
}

/// Serialize `stack` to `path` (see the module docs for the layout).
/// The write is atomic: bytes land in a `<path>.tmp` sibling first and
/// are renamed over the target, so a crash or full disk mid-save can
/// never truncate an existing good checkpoint out from under
/// fail-closed `init = load` restarts.
pub fn save(stack: &EncoderStack, path: impl AsRef<Path>)
            -> Result<(), CheckpointError> {
    let d = stack.d_model();
    let dff = stack.dff();
    let ffn_mult = dff / d;
    let projections = stack.projections();
    // the capacity hint comes from the shared formula; a real stack's
    // sizes always fit usize
    let mut out: Vec<u8> = Vec::with_capacity(
        HEADER_LEN
            + (4 * (stack.layers() as u128 - 1)
               * block_f32s(d, ffn_mult, projections)) as usize);
    out.extend_from_slice(MAGIC);
    for v in [VERSION, d as u32, stack.n_heads() as u32,
              ffn_mult as u32, stack.layers() as u32,
              projections as u32] {
        out.extend_from_slice(&v.to_le_bytes());
    }
    let mut put = |w: &[f32]| {
        for x in w {
            out.extend_from_slice(&x.to_le_bytes());
        }
    };
    for blk in stack.blocks() {
        put(&blk.ln1_gain);
        put(&blk.ln1_bias);
        put(&blk.ln2_gain);
        put(&blk.ln2_bias);
        put(&blk.w1);
        put(&blk.b1);
        put(&blk.w2);
        put(&blk.b2);
        if let Some(p) = blk.projections() {
            put(&p.wq);
            put(&p.wk);
            put(&p.wv);
            put(&p.wo);
        } else {
            assert!(!projections, "projection stack with a bare block");
        }
    }
    let path = path.as_ref();
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp);
    {
        use std::io::Write;
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&out)?;
        // flush to stable storage before the rename becomes visible —
        // without this a power loss after save() returns could leave a
        // zero-length file where the previous good checkpoint was
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Parse and validate a checkpoint file. Every failure mode is a typed
/// [`CheckpointError`]; no partially-loaded state escapes.
pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint, CheckpointError> {
    let bytes = std::fs::read(path)?;
    if bytes.len() < HEADER_LEN {
        // a file too short for the header can still fail BadMagic
        // first when even the magic is wrong — more precise than
        // "truncated" for garbage input
        if bytes.len() < 8 || &bytes[..8] != MAGIC {
            return Err(CheckpointError::BadMagic);
        }
        return Err(CheckpointError::Truncated {
            need: HEADER_LEN, got: bytes.len(),
        });
    }
    if &bytes[..8] != MAGIC {
        return Err(CheckpointError::BadMagic);
    }
    let u32_at = |off: usize| -> u32 {
        u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap())
    };
    let version = u32_at(8);
    if version != VERSION {
        return Err(CheckpointError::UnsupportedVersion(version));
    }
    let d = u32_at(12) as usize;
    let n_heads = u32_at(16) as usize;
    let ffn_mult = u32_at(20) as usize;
    let layers = u32_at(24) as usize;
    let flags = u32_at(28);
    let projections = flags & 1 != 0;
    if (flags & !1) != 0 {
        return Err(CheckpointError::BadDims(format!("unknown flags {flags:#x}")));
    }
    if d == 0 || n_heads == 0 || ffn_mult == 0 || layers == 0 {
        return Err(CheckpointError::BadDims("zero dimension".into()));
    }
    if d > MAX_DIM || layers > MAX_DIM || ffn_mult > MAX_DIM {
        return Err(CheckpointError::BadDims("dimension above sanity bound".into()));
    }
    if n_heads > d || d % n_heads != 0 {
        return Err(CheckpointError::BadDims(format!(
            "d_model {d} does not split into {n_heads} heads")));
    }
    // need is computed entirely in u128 (see block_f32s): with every
    // dimension individually under MAX_DIM the usize products can
    // still overflow (e.g. d = ffn_mult = 2^20, layers = 4), and an
    // overflow-wrapped `need` would let a crafted header through to
    // the payload loop's allocations. In widened arithmetic an absurd
    // header simply fails the length check — no real file can be 2^60
    // bytes.
    let per_block = block_f32s(d, ffn_mult, projections);
    let need = HEADER_LEN as u128 + 4 * (layers as u128 - 1) * per_block;
    let got = bytes.len() as u128;
    if got < need {
        return Err(CheckpointError::Truncated {
            need: need.min(usize::MAX as u128) as usize,
            got: bytes.len(),
        });
    }
    if got > need {
        return Err(CheckpointError::TrailingBytes((got - need) as usize));
    }
    // the length check passed, so every product below fits usize (the
    // file physically holds that many bytes)
    let dff = d * ffn_mult;
    let mut pos = HEADER_LEN;
    let mut take = |len: usize| -> Vec<f32> {
        let mut v = Vec::with_capacity(len);
        for _ in 0..len {
            v.push(f32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()));
            pos += 4;
        }
        v
    };
    let dh = d / n_heads;
    let blocks = (1..layers)
        .map(|_| {
            let mut blk = EncoderLayer {
                d,
                dff,
                ln1_gain: take(d),
                ln1_bias: take(d),
                ln2_gain: take(d),
                ln2_bias: take(d),
                w1: take(d * dff),
                b1: take(dff),
                w2: take(dff * d),
                b2: take(d),
                proj: None,
            };
            if projections {
                blk.proj = Some(Projections::from_parts(
                    d, n_heads,
                    take(n_heads * d * dh),
                    take(n_heads * d * dh),
                    take(n_heads * d * dh),
                    take(d * d)));
            }
            blk
        })
        .collect();
    Ok(Checkpoint { d_model: d, n_heads, ffn_mult, layers, projections, blocks })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::SpectralShiftConfig;

    fn stack(layers: usize, projections: bool) -> EncoderStack {
        EncoderStack::new_mixed(
            vec![BatchedVariant::SpectralShift(SpectralShiftConfig::new(8));
                 layers],
            16, 2, 2, 7, projections)
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir()
            .join(format!("ssaformer-ckpt-{}-{name}.bin", std::process::id()))
    }

    #[test]
    fn header_math_matches_the_format_spec() {
        // one block of d=16, ffn_mult=2 (dff=32):
        // 4·16 + 16·32 + 32 + 32·16 + 16
        assert_eq!(block_f32s(16, 2, false), 64 + 512 + 32 + 512 + 16);
        // projections add 4·d²
        assert_eq!(block_f32s(16, 2, true),
                   block_f32s(16, 2, false) + 4 * 256);
    }

    #[test]
    fn save_load_roundtrips_bitwise() {
        for projections in [false, true] {
            let s = stack(3, projections);
            let path = tmp(&format!("rt{projections}"));
            save(&s, &path).unwrap();
            let ck = load(&path).unwrap();
            assert_eq!((ck.d_model, ck.n_heads, ck.ffn_mult, ck.layers,
                        ck.projections),
                       (16, 2, 2, 3, projections));
            for (a, b) in s.blocks().iter().zip(&ck.blocks) {
                assert_eq!(a.w1, b.w1);
                assert_eq!(a.ln1_gain, b.ln1_gain);
                assert_eq!(a.b2, b.b2);
                match (a.projections(), b.projections()) {
                    (None, None) => assert!(!projections),
                    (Some(x), Some(y)) => {
                        assert_eq!(x.wq, y.wq);
                        assert_eq!(x.wo, y.wo);
                    }
                    _ => panic!("projection presence diverged"),
                }
            }
            std::fs::remove_file(&path).unwrap();
        }
    }

    #[test]
    fn depth1_checkpoints_are_header_only() {
        let s = stack(1, true);
        let path = tmp("d1");
        save(&s, &path).unwrap();
        assert_eq!(std::fs::metadata(&path).unwrap().len(), HEADER_LEN as u64);
        // the atomic-write staging file must have been renamed away
        let mut staged = path.as_os_str().to_owned();
        staged.push(".tmp");
        assert!(!std::path::Path::new(&staged).exists(),
                "save must rename its staging file over the target");
        let ck = load(&path).unwrap();
        assert_eq!(ck.layers, 1);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn malformed_files_fail_closed_with_typed_errors() {
        let s = stack(2, true);
        let path = tmp("bad");
        save(&s, &path).unwrap();
        let good = std::fs::read(&path).unwrap();

        // bad magic
        let mut b = good.clone();
        b[0] ^= 0xFF;
        std::fs::write(&path, &b).unwrap();
        assert!(matches!(load(&path), Err(CheckpointError::BadMagic)));

        // unsupported version
        let mut b = good.clone();
        b[8] = 99;
        std::fs::write(&path, &b).unwrap();
        assert!(matches!(load(&path),
                         Err(CheckpointError::UnsupportedVersion(99))));

        // truncation: drop the last byte; and a header-only torso
        std::fs::write(&path, &good[..good.len() - 1]).unwrap();
        assert!(matches!(load(&path), Err(CheckpointError::Truncated { .. })));
        std::fs::write(&path, &good[..HEADER_LEN + 3]).unwrap();
        assert!(matches!(load(&path), Err(CheckpointError::Truncated { .. })));

        // trailing garbage
        let mut b = good.clone();
        b.extend_from_slice(&[0, 1, 2]);
        std::fs::write(&path, &b).unwrap();
        assert!(matches!(load(&path), Err(CheckpointError::TrailingBytes(3))));

        // zero dimension
        let mut b = good.clone();
        b[12..16].copy_from_slice(&0u32.to_le_bytes());
        std::fs::write(&path, &b).unwrap();
        assert!(matches!(load(&path), Err(CheckpointError::BadDims(_))));

        // heads not dividing d_model
        let mut b = good;
        b[16..20].copy_from_slice(&3u32.to_le_bytes());
        std::fs::write(&path, &b).unwrap();
        assert!(matches!(load(&path), Err(CheckpointError::BadDims(_))));

        // missing file is an Io error
        std::fs::remove_file(&path).unwrap();
        assert!(matches!(load(&path), Err(CheckpointError::Io(_))));
    }

    #[test]
    fn absurd_header_products_fail_the_length_check_not_the_allocator() {
        // every dimension is individually under MAX_DIM but the payload
        // size overflows usize arithmetic — the u128 length check must
        // reject it as truncated, never panic or attempt the allocation
        let mut b = Vec::new();
        b.extend_from_slice(MAGIC);
        for v in [VERSION, 1u32 << 20, 1, 1 << 20, 4, 0] {
            b.extend_from_slice(&v.to_le_bytes());
        }
        let path = tmp("absurd");
        std::fs::write(&path, &b).unwrap();
        assert!(matches!(load(&path), Err(CheckpointError::Truncated { .. })));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn shape_checks_name_the_offending_field() {
        let s = stack(3, true);
        let path = tmp("shape");
        save(&s, &path).unwrap();
        let ck = load(&path).unwrap();
        assert!(ck.check_shape(16, 2, 2, 3, true).is_ok());
        match ck.check_shape(16, 2, 2, 4, true) {
            Err(CheckpointError::Mismatch { field: "layers", want: 4, got: 3 }) => {}
            other => panic!("{other:?}"),
        }
        match ck.check_shape(16, 2, 2, 3, false) {
            Err(CheckpointError::Mismatch { field: "projections", .. }) => {}
            other => panic!("{other:?}"),
        }
        // into_stack enforces the operator count
        let one_op = vec![BatchedVariant::Full];
        assert!(matches!(ck.into_stack(one_op),
                         Err(CheckpointError::Mismatch { .. })));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn loaded_stack_reports_its_init_and_serves_the_saved_function() {
        use crate::attention::Tensor2;
        use crate::kernels::{BatchedAttention, KernelCtx, Workspace};
        use crate::rngx::Rng;
        let s = stack(3, true);
        let path = tmp("serve");
        save(&s, &path).unwrap();
        let loaded = load(&path).unwrap()
            .into_stack(s.variants().to_vec()).unwrap();
        assert_eq!(loaded.init(), WeightInit::Loaded);
        assert_eq!(s.init(), WeightInit::Seeded);
        let mut exec = BatchedAttention::new(KernelCtx::global());
        let mut ws = Workspace::new();
        let mut rng = Rng::new(11);
        let x = Tensor2::randn(&mut rng, 64, 16, 1.0);
        let mut xa = vec![x.clone()];
        let mut xb = vec![x];
        s.forward_batch(&mut exec, &mut xa, &mut ws);
        loaded.forward_batch(&mut exec, &mut xb, &mut ws);
        assert_eq!(xa[0].data, xb[0].data,
                   "a reloaded checkpoint must serve bitwise the same function");
        std::fs::remove_file(&path).unwrap();
    }
}
