//! The multi-layer encoder stack — the serving model's compute graph.
//!
//! ```text
//!   x₀ = embed(tokens)                     (per request, plen × d)
//!   x₁ = MHA(x₀)                           seed block: bare attention
//!   for each deeper block b = 2..L:
//!     h  = x + MHA(LN₁(x))                 attention sublayer
//!     x  = h + FFN(LN₂(h))                 FFN sublayer (bias+GELU)
//!   out = mean-pool of the real rows of x_L
//! ```
//!
//! With projections on, each full block's MHA is the projected form
//! `W_O · concat_h(op(x·W_Q^h, x·W_K^h, x·W_V^h))` — the `Q = XW_Q`
//! formulation the paper defines spectral shifting over — while the
//! *seed block* always stays bare (it is weightless by construction).
//!
//! **Depth semantics / compatibility.** The stack's first block is the
//! *seed block*: the bare attention pass the pre-refactor single-pass
//! model served (no LN, no residual, no FFN, no projections). Deeper
//! blocks are full pre-LN sandwiches. `layers = 1` therefore
//! degenerates to exactly the old served function — bitwise, not just
//! numerically, whatever the projection flag says — so existing
//! embedding caches, parity tests and recorded traces stay valid, and
//! `layers = L+1` is always "the depth-L function plus one more
//! sandwich". `tests/model_parity.rs` pins both directions.
//!
//! **Per-layer operators.** Every block may run its own attention
//! variant ([`EncoderStack::new_mixed`]; config `variant = ss,ss,full`)
//! — e.g. cheap O(n) attention in the lower blocks and exact softmax in
//! the last, the hybrid the Linformer/Skyformer comparisons motivate.
//! Uniform stacks remain the common case and the default.
//!
//! **Weights.** Stack weights are either a seeded deterministic draw
//! (two stacks from one `(seed, shape)` serve one function) or loaded
//! from a [`checkpoint`](super::checkpoint) file; [`EncoderStack::init`]
//! reports which, and the STATS `model:` line surfaces it.
//!
//! **Execution.** Attention fans heads × requests over the pool through
//! the [`AttentionOp`] seam ([`attention_batched_self_pooled`], or the
//! projected fan-out in [`Projections::mha_batch`]); LN, the projection
//! GEMMs and the FFN GEMMs run row-blocked on the same pool. Every
//! kernel splits work by problem shape, never thread count, so a served
//! embedding is a pure function of `(weights, tokens)` — independent of
//! batch composition, worker assignment, and pool size.
//!
//! [`Projections::mha_batch`]: super::layer::Projections::mha_batch

use super::layer::EncoderLayer;
use super::op::AttentionOp;
use crate::attention::Tensor2;
use crate::kernels::{
    attention_batched_self_pooled, BatchedAttention, BatchedVariant, Workspace,
};
use crate::rngx::Rng;

/// Salt applied to the model seed before drawing stack weights, so the
/// embedding table (drawn from the unsalted seed) and the encoder
/// weights never share an RNG stream.
const STACK_SEED_SALT: u64 = 0xE6C0_DE5A;

/// Where a stack's weights came from — part of the served-model
/// identity reported on the STATS `model:` line.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightInit {
    /// Deterministic draw from the model seed.
    Seeded,
    /// Loaded from a checkpoint file (`init = load`).
    Loaded,
}

impl WeightInit {
    /// Stable token for STATS / logs.
    pub fn token(&self) -> &'static str {
        match self {
            WeightInit::Seeded => "seeded",
            WeightInit::Loaded => "loaded",
        }
    }
}

/// A depth-`layers` encoder over per-block pluggable attention
/// operators.
pub struct EncoderStack {
    d_model: usize,
    n_heads: usize,
    dff: usize,
    /// One operator per block (index 0 = the seed block): `layers`.
    variants: Vec<BatchedVariant>,
    /// Full pre-LN blocks (the seed block is weightless): `layers − 1`.
    blocks: Vec<EncoderLayer>,
    projections: bool,
    init: WeightInit,
}

impl EncoderStack {
    /// Build a stack of `layers` blocks (≥ 1) of width `d_model` with
    /// `ffn_mult`·d FFN expansion, weights drawn deterministically from
    /// `seed`. One attention operator shared by every block, no
    /// projections — the pre-projection constructor, kept so existing
    /// call sites (and their bitwise expectations) are untouched.
    pub fn new(variant: BatchedVariant, layers: usize, d_model: usize,
               n_heads: usize, ffn_mult: usize, seed: u64) -> EncoderStack {
        assert!(layers >= 1, "encoder stack needs at least one layer");
        EncoderStack::new_mixed(vec![variant; layers], d_model, n_heads,
                                ffn_mult, seed, false)
    }

    /// The general seeded constructor: one operator per block
    /// (`variants.len()` is the depth) and an optional projection
    /// sandwich around every full block's attention. With
    /// `projections = false` and uniform variants this is exactly
    /// [`EncoderStack::new`].
    pub fn new_mixed(variants: Vec<BatchedVariant>, d_model: usize,
                     n_heads: usize, ffn_mult: usize, seed: u64,
                     projections: bool) -> EncoderStack {
        let layers = variants.len();
        assert!(layers >= 1, "encoder stack needs at least one layer");
        assert!(ffn_mult >= 1, "ffn_mult must be >= 1");
        assert!(n_heads >= 1 && d_model % n_heads == 0,
                "d_model {d_model} must split into {n_heads} heads");
        let dff = d_model * ffn_mult;
        let mut rng = Rng::new(seed ^ STACK_SEED_SALT);
        let blocks = (1..layers)
            .map(|_| EncoderLayer::seeded(&mut rng, d_model, dff, n_heads,
                                          projections))
            .collect();
        EncoderStack::from_blocks(variants, d_model, n_heads, dff, blocks,
                                  projections, WeightInit::Seeded)
    }

    /// Assemble a stack around already-materialized block weights (the
    /// seeded constructors and the checkpoint load path both end here).
    pub(crate) fn from_blocks(variants: Vec<BatchedVariant>, d_model: usize,
                              n_heads: usize, dff: usize,
                              blocks: Vec<EncoderLayer>, projections: bool,
                              init: WeightInit) -> EncoderStack {
        assert_eq!(blocks.len() + 1, variants.len(),
                   "one operator per block, seed block included");
        // mixed stacks must agree on one landmark budget: alignment is
        // computed once per request, not per block
        let mut divisors = variants.iter().filter_map(|v| v.landmark_divisor());
        if let Some(first) = divisors.next() {
            assert!(divisors.all(|c| c == first),
                    "mixed landmark budgets are unsupported");
        }
        EncoderStack { d_model, n_heads, dff, variants, blocks, projections,
                       init }
    }

    pub fn layers(&self) -> usize {
        self.variants.len()
    }

    pub fn d_model(&self) -> usize {
        self.d_model
    }

    pub fn n_heads(&self) -> usize {
        self.n_heads
    }

    /// FFN inner width (d_model × ffn_mult).
    pub fn dff(&self) -> usize {
        self.dff
    }

    /// Whether full blocks project q/k/v and the merged head outputs.
    pub fn projections(&self) -> bool {
        self.projections
    }

    /// Where the stack's weights came from (seeded draw vs checkpoint).
    pub fn init(&self) -> WeightInit {
        self.init
    }

    /// The seed block's attention operator (uniform stacks: the only
    /// one). Also usable as `&dyn AttentionOp`.
    pub fn variant(&self) -> BatchedVariant {
        self.variants[0]
    }

    /// One operator per block, seed block first.
    pub fn variants(&self) -> &[BatchedVariant] {
        &self.variants
    }

    /// The full pre-LN blocks (empty at `layers = 1`); the scalar
    /// reference walks these to mirror the forward pass.
    pub fn blocks(&self) -> &[EncoderLayer] {
        &self.blocks
    }

    /// Mutable access to the full blocks — the in-repo trainer's weight
    /// update seam. Crate-internal: external callers go through the
    /// checkpoint path, which re-validates shapes on load.
    pub(crate) fn blocks_mut(&mut self) -> &mut [EncoderLayer] {
        &mut self.blocks
    }

    /// Divisibility constraint inherited from the attention operators
    /// (mixed stacks share one landmark budget, enforced at build).
    pub fn landmark_divisor(&self) -> Option<usize> {
        self.variants.iter().find_map(|v| v.landmark_divisor())
    }

    /// Forward a batch of per-request activations **in place**. Each
    /// `xs[r]` is that request's (plen × d_model) embedding on entry and
    /// its final-layer activation on exit (pooling is the caller's job —
    /// it needs the real-row count, which the stack deliberately does
    /// not know).
    ///
    /// Heads × requests fan out over `exec`'s pool each block; LN/FFN
    /// (and projection) scratch comes from `ws` (plan it with
    /// [`EncoderStack::plan_sizes`] to make even the first batch
    /// allocation-free).
    pub fn forward_batch(&self, exec: &mut BatchedAttention,
                         xs: &mut [Tensor2], ws: &mut Workspace) {
        if xs.is_empty() {
            return;
        }
        for x in xs.iter() {
            assert_eq!(x.cols, self.d_model, "activation width mismatch");
        }
        // seed block: bare attention, exactly the pre-refactor pass.
        // Copy (not swap) the merged output into x: x's buffer is the
        // caller's pre-planned max-bucket staging capacity, which a
        // swap would silently trade for an exact-size one, degrading
        // the plan under mixed bucket traffic. The merged buffers come
        // from (and return to) the executor's scratch arena, so the
        // whole pass is allocation-free once warm.
        let seed_op: &dyn AttentionOp = &self.variants[0];
        let outs = attention_batched_self_pooled(exec, xs, self.n_heads,
                                                 seed_op);
        for (x, o) in xs.iter_mut().zip(&outs) {
            x.data.copy_from_slice(&o.data);
        }
        for o in outs {
            exec.scratch().put(o.data);
        }
        let ctx = exec.ctx().clone();
        for (b, blk) in self.blocks.iter().enumerate() {
            let op: &dyn AttentionOp = &self.variants[b + 1];
            // attention sublayer: x += MHA(LN₁(x)) — projected when the
            // block carries QKV/output weights, bare otherwise
            let ln: Vec<Tensor2> =
                xs.iter().map(|x| blk.attn_input(&ctx, x, ws)).collect();
            let att = match blk.projections() {
                Some(p) => p.mha_batch(exec, &ln, op, ws),
                None => attention_batched_self_pooled(exec, &ln, self.n_heads,
                                                      op),
            };
            for t in ln {
                ws.put(t.data);
            }
            for (x, a) in xs.iter_mut().zip(&att) {
                for (xi, ai) in x.data.iter_mut().zip(&a.data) {
                    *xi += *ai;
                }
            }
            for a in att {
                exec.scratch().put(a.data);
            }
            // FFN sublayer: x += W₂·gelu(LN₂(x)·W₁ + b₁) + b₂
            for x in xs.iter_mut() {
                blk.ffn_sublayer(&ctx, x, ws);
            }
        }
    }

    /// The peak `ws` working set of [`EncoderStack::forward_batch`] plus
    /// the caller's staged activations, for a batch of `capacity`
    /// requests at sequence length `seq`. Feed to
    /// [`Workspace::plan`] at engine start so the first batch at the
    /// planned shape allocates nothing.
    pub fn plan_sizes(&self, capacity: usize, seq: usize) -> Vec<usize> {
        let d = self.d_model;
        // staged per-request activations (taken by the engine)
        let mut sizes = vec![seq * d; capacity];
        if !self.blocks.is_empty() {
            // LN₁ outputs coexist across the whole batch ...
            sizes.extend(std::iter::repeat_n(seq * d, capacity));
            // ... while FFN scratch is per-request, reused: LN₂ + inner
            // + output
            sizes.push(seq * d);
            sizes.push(seq * self.dff);
            sizes.push(seq * d);
            if self.projections {
                // q/k/v staging for every head of every request
                // coexists across the batch, plus one reused merge
                // buffer for the W_O input
                let dh = d / self.n_heads;
                sizes.extend(std::iter::repeat_n(
                    seq * dh, 3 * self.n_heads * capacity));
                sizes.push(seq * d);
            }
        }
        sizes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::SpectralShiftConfig;
    use crate::kernels::{attention_batched_self, KernelCtx};

    fn ss_stack(layers: usize) -> EncoderStack {
        EncoderStack::new(
            BatchedVariant::SpectralShift(SpectralShiftConfig::new(8)),
            layers, 16, 2, 2, 42)
    }

    fn projected_ss_stack(layers: usize) -> EncoderStack {
        EncoderStack::new_mixed(
            vec![BatchedVariant::SpectralShift(SpectralShiftConfig::new(8));
                 layers],
            16, 2, 2, 42, true)
    }

    fn batch(seed: u64, shapes: &[usize], d: usize) -> Vec<Tensor2> {
        let mut rng = Rng::new(seed);
        shapes.iter().map(|&n| Tensor2::randn(&mut rng, n, d, 1.0)).collect()
    }

    #[test]
    fn stack_shape_and_weight_count() {
        let s = ss_stack(4);
        assert_eq!(s.layers(), 4);
        assert_eq!(s.blocks().len(), 3, "seed block carries no weights");
        assert_eq!(s.dff(), 32);
        assert_eq!(s.landmark_divisor(), Some(8));
        assert!(!s.projections());
        assert_eq!(s.init(), WeightInit::Seeded);
        let s1 = ss_stack(1);
        assert!(s1.blocks().is_empty());
    }

    #[test]
    fn same_seed_same_function_different_seed_differs() {
        let a = ss_stack(3);
        let b = ss_stack(3);
        let mut exec = BatchedAttention::new(KernelCtx::global());
        let mut ws = Workspace::new();
        let mut xa = batch(1, &[64], 16);
        let mut xb = batch(1, &[64], 16);
        a.forward_batch(&mut exec, &mut xa, &mut ws);
        b.forward_batch(&mut exec, &mut xb, &mut ws);
        assert_eq!(xa[0].data, xb[0].data, "same seed must serve one function");
        let c = EncoderStack::new(
            BatchedVariant::SpectralShift(SpectralShiftConfig::new(8)),
            3, 16, 2, 2, 43);
        let mut xc = batch(1, &[64], 16);
        c.forward_batch(&mut exec, &mut xc, &mut ws);
        assert_ne!(xa[0].data, xc[0].data);
    }

    #[test]
    fn projections_change_the_function_but_not_the_off_path() {
        let off = ss_stack(3);
        let on = projected_ss_stack(3);
        assert!(on.projections());
        assert!(on.blocks().iter().all(|b| b.projections().is_some()));
        let mut exec = BatchedAttention::new(KernelCtx::global());
        let mut ws = Workspace::new();
        let mut xa = batch(1, &[64], 16);
        let mut xb = batch(1, &[64], 16);
        off.forward_batch(&mut exec, &mut xa, &mut ws);
        on.forward_batch(&mut exec, &mut xb, &mut ws);
        assert_ne!(xa[0].data, xb[0].data, "projections must be load-bearing");
        assert!(xb[0].data.iter().all(|v| v.is_finite()));
        // two projected stacks from one seed still serve one function
        let on2 = projected_ss_stack(3);
        let mut xc = batch(1, &[64], 16);
        on2.forward_batch(&mut exec, &mut xc, &mut ws);
        assert_eq!(xb[0].data, xc[0].data);
    }

    #[test]
    fn projected_depth1_is_bitwise_the_bare_seed_block() {
        // the seed block never projects, so the flag is inert at
        // layers = 1 — the PR-4 compatibility guarantee
        let off = ss_stack(1);
        let on = projected_ss_stack(1);
        let mut exec = BatchedAttention::new(KernelCtx::global());
        let mut ws = Workspace::new();
        let mut xa = batch(2, &[64], 16);
        let mut xb = batch(2, &[64], 16);
        off.forward_batch(&mut exec, &mut xa, &mut ws);
        on.forward_batch(&mut exec, &mut xb, &mut ws);
        assert_eq!(xa[0].data, xb[0].data);
    }

    #[test]
    fn mixed_variant_stacks_dispatch_per_block() {
        let ss = BatchedVariant::SpectralShift(SpectralShiftConfig::new(8));
        let mixed = EncoderStack::new_mixed(
            vec![ss, BatchedVariant::Full], 16, 2, 2, 42, false);
        assert_eq!(mixed.layers(), 2);
        assert_eq!(mixed.landmark_divisor(), Some(8));
        let uniform = EncoderStack::new(ss, 2, 16, 2, 2, 42);
        let mut exec = BatchedAttention::new(KernelCtx::global());
        let mut ws = Workspace::new();
        let mut xa = batch(1, &[64], 16);
        let mut xb = batch(1, &[64], 16);
        mixed.forward_batch(&mut exec, &mut xa, &mut ws);
        uniform.forward_batch(&mut exec, &mut xb, &mut ws);
        assert_ne!(xa[0].data, xb[0].data,
                   "block operator must be load-bearing");
        // same weights + same operators = same function
        let mixed2 = EncoderStack::new_mixed(
            vec![ss, BatchedVariant::Full], 16, 2, 2, 42, false);
        let mut xc = batch(1, &[64], 16);
        mixed2.forward_batch(&mut exec, &mut xc, &mut ws);
        assert_eq!(xa[0].data, xc[0].data);
    }

    #[test]
    fn forward_is_independent_of_batch_composition() {
        let s = ss_stack(3);
        let mut exec = BatchedAttention::new(KernelCtx::global());
        let mut ws = Workspace::new();
        let mut solo = batch(2, &[64], 16);
        s.forward_batch(&mut exec, &mut solo, &mut ws);
        let mut pair = batch(3, &[32], 16);
        pair.extend(batch(2, &[64], 16));
        s.forward_batch(&mut exec, &mut pair, &mut ws);
        assert_eq!(solo[0].data, pair[1].data,
                   "activations must not depend on batchmates");
    }

    #[test]
    fn forward_is_bitwise_thread_count_invariant() {
        for s in [ss_stack(4), projected_ss_stack(3)] {
            let mut ws = Workspace::new();
            let mut seq_exec = BatchedAttention::new(KernelCtx::sequential());
            let mut par_exec = BatchedAttention::new(KernelCtx::global());
            let mut xa = batch(4, &[64, 32], 16);
            let mut xb = batch(4, &[64, 32], 16);
            s.forward_batch(&mut seq_exec, &mut xa, &mut ws);
            s.forward_batch(&mut par_exec, &mut xb, &mut ws);
            for (a, b) in xa.iter().zip(&xb) {
                assert_eq!(a.data, b.data);
            }
        }
    }

    #[test]
    fn planned_workspace_makes_first_batch_allocation_free() {
        for s in [ss_stack(3), projected_ss_stack(3)] {
            let mut exec = BatchedAttention::new(KernelCtx::global());
            let mut ws = Workspace::new();
            // plan for capacity 2 at seq 64, then run exactly that shape
            // — the *first* forward must not grow the arena (staged
            // activations are taken by the caller in the engine; here we
            // mimic by pre-taking them from the same arena)
            ws.plan(&s.plan_sizes(2, 64));
            let planned = ws.allocations();
            let mut xs: Vec<Tensor2> = (0..2)
                .map(|i| {
                    let mut t =
                        Tensor2 { rows: 64, cols: 16, data: ws.take(64 * 16) };
                    let mut rng = Rng::new(i as u64);
                    rng.fill_normal_f32(&mut t.data, 0.0, 1.0);
                    t
                })
                .collect();
            s.forward_batch(&mut exec, &mut xs, &mut ws);
            assert_eq!(ws.allocations(), planned,
                       "planned stack must not allocate stage scratch");
            for t in xs {
                ws.put(t.data);
            }
        }
    }

    #[test]
    fn steady_state_forward_batches_keep_the_scratch_arena_flat() {
        for s in [ss_stack(3), projected_ss_stack(3)] {
            let mut exec = BatchedAttention::new(KernelCtx::global());
            let mut ws = Workspace::new();
            let mut xs = batch(7, &[64, 32], 16);
            s.forward_batch(&mut exec, &mut xs, &mut ws);
            let warm = (exec.scratch().allocations(), ws.allocations());
            for _ in 0..3 {
                s.forward_batch(&mut exec, &mut xs, &mut ws);
            }
            assert_eq!((exec.scratch().allocations(), ws.allocations()), warm,
                       "steady-state stack batches must not grow the arenas");
        }
    }

    #[test]
    fn one_layer_stack_is_bare_attention() {
        // the seed block alone must equal attention_batched_self run
        // directly — no LN, no residual, no FFN
        let s = ss_stack(1);
        let mut exec = BatchedAttention::new(KernelCtx::global());
        let mut ws = Workspace::new();
        let xs = batch(5, &[64], 16);
        let want = attention_batched_self(
            &mut exec, &xs, 2,
            &BatchedVariant::SpectralShift(SpectralShiftConfig::new(8)));
        let mut got = xs;
        s.forward_batch(&mut exec, &mut got, &mut ws);
        assert_eq!(got[0].data, want[0].data);
    }
}
