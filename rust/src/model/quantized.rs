//! Load-time quantized weight tiers for the admission policy.
//!
//! A precision tier is not a new serving path — it is the *same*
//! [`EncoderStack`] forward over weights snapped onto a tier's lattice.
//! [`quantize_stack`] rebuilds a stack whose GEMM weights (`w1`, `w2`
//! and, with projections, `wq`/`wk`/`wv`/`wo`) were round-tripped
//! through [`QuantMatrix`] **once** at engine load; biases and
//! layernorm parameters stay f32 (they are O(d) additions, not
//! products — quantizing them buys nothing and costs accuracy).
//!
//! Serving a quantized tier then runs the ordinary f32 kernels over
//! the expanded weights, which is *bitwise* the same arithmetic as
//! calling [`gemm_quant_into`](crate::kernels::gemm_quant_into) per
//! product (pinned by `quant_gemm_is_bitwise_the_f32_gemm_on_the_
//! expanded_weights` in `kernels::quant`) — but pays the expansion
//! cost once per load instead of once per request. Determinism is
//! inherited unchanged: a tier stack is a pure function of
//! (weights, precision), so hit ≡ recompute and thread-count
//! invariants hold within every tier.

use super::layer::{EncoderLayer, Projections};
use super::stack::EncoderStack;
use crate::kernels::{BatchedVariant, Precision, QuantMatrix};

/// Round-trip one GEMM weight through its tier lattice. `F32` is the
/// identity (bitwise copy) so a tier stack can always be built
/// uniformly.
fn requantize(w: &[f32], rows: usize, cols: usize, p: Precision) -> Vec<f32> {
    match p {
        Precision::F32 => w.to_vec(),
        _ => {
            let q = QuantMatrix::quantize(w, rows, cols, p);
            let mut out = vec![0.0f32; w.len()];
            q.dequantize_into(&mut out);
            out
        }
    }
}

/// Build the serving stack of one (variant list × precision) tier from
/// a source stack: same depth and shapes, `variants` swapped in (the
/// admission policy may route a tier to different operators), GEMM
/// weights snapped to `precision`. The seed block is weightless, so
/// only the `layers − 1` full blocks carry quantized payload.
///
/// Panics when `variants` does not match the stack depth — tier lists
/// are built by the engine from its own config, so a mismatch is a
/// construction bug, not an input error.
pub fn quantize_stack(stack: &EncoderStack, variants: Vec<BatchedVariant>,
                      precision: Precision) -> EncoderStack {
    assert_eq!(variants.len(), stack.layers(),
               "tier variant list must match the stack depth");
    let d = stack.d_model();
    let dff = stack.dff();
    let heads = stack.n_heads();
    let dh = d / heads;
    let blocks = stack
        .blocks()
        .iter()
        .map(|blk| EncoderLayer {
            d,
            dff,
            ln1_gain: blk.ln1_gain.clone(),
            ln1_bias: blk.ln1_bias.clone(),
            ln2_gain: blk.ln2_gain.clone(),
            ln2_bias: blk.ln2_bias.clone(),
            w1: requantize(&blk.w1, d, dff, precision),
            b1: blk.b1.clone(),
            w2: requantize(&blk.w2, dff, d, precision),
            b2: blk.b2.clone(),
            proj: blk.projections().map(|p| {
                // head-major concatenated QKV maps: head h owns rows
                // h·d..(h+1)·d, so per-row scales stay per-head-row
                Projections::from_parts(
                    d, heads,
                    requantize(&p.wq, heads * d, dh, precision),
                    requantize(&p.wk, heads * d, dh, precision),
                    requantize(&p.wv, heads * d, dh, precision),
                    requantize(&p.wo, d, d, precision))
            }),
        })
        .collect();
    EncoderStack::from_blocks(variants, d, heads, dff, blocks,
                              stack.projections(), stack.init())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::SpectralShiftConfig;
    use crate::attention::Tensor2;
    use crate::kernels::{BatchedAttention, KernelCtx, Workspace};
    use crate::model::WeightInit;
    use crate::rngx::Rng;

    fn source(layers: usize, projections: bool) -> EncoderStack {
        EncoderStack::new_mixed(vec![BatchedVariant::Full; layers],
                                16, 2, 2, 7, projections)
    }

    fn ss_variants(layers: usize) -> Vec<BatchedVariant> {
        vec![BatchedVariant::SpectralShift(SpectralShiftConfig::new(8));
             layers]
    }

    #[test]
    fn f32_tier_is_a_bitwise_copy_with_swapped_variants() {
        let s = source(3, true);
        let t = quantize_stack(&s, ss_variants(3), Precision::F32);
        assert_eq!(t.layers(), 3);
        assert_eq!(t.init(), WeightInit::Seeded);
        assert!(t.landmark_divisor().is_some(),
                "ss tier must carry the landmark divisor");
        for (a, b) in s.blocks().iter().zip(t.blocks()) {
            assert_eq!(a.w1, b.w1);
            assert_eq!(a.w2, b.w2);
            let (pa, pb) = (a.projections().unwrap(),
                            b.projections().unwrap());
            assert_eq!(pa.wq, pb.wq);
            assert_eq!(pa.wo, pb.wo);
        }
    }

    #[test]
    fn quantized_tiers_move_weights_onto_the_lattice_only() {
        let s = source(2, true);
        for p in [Precision::Bf16, Precision::Int8] {
            let t = quantize_stack(&s, ss_variants(2), p);
            let (a, b) = (&s.blocks()[0], &t.blocks()[0]);
            // weights change (Gaussian draws are off-lattice) …
            assert_ne!(a.w1, b.w1, "{p:?}");
            // … but stay close, and LN/bias vectors are untouched
            let err: f32 = a.w1.iter().zip(&b.w1)
                .map(|(x, y)| (x - y).abs()).fold(0.0, f32::max);
            assert!(err < 0.1, "{p:?}: max weight shift {err}");
            assert_eq!(a.ln1_gain, b.ln1_gain);
            assert_eq!(a.b1, b.b1);
            assert_eq!(a.b2, b.b2);
            // requantizing the tier is a fixed point: the lattice is
            // quantize-once stable
            let tt = quantize_stack(&t, ss_variants(2), p);
            assert_eq!(b.w1, tt.blocks()[0].w1, "{p:?}");
        }
    }

    #[test]
    fn tier_forward_diverges_boundedly_from_f32() {
        let s = source(3, true);
        let full = vec![BatchedVariant::Full; 3];
        let mut exec = BatchedAttention::new(KernelCtx::global());
        let mut ws = Workspace::new();
        let mut rng = Rng::new(3);
        let x = Tensor2::randn(&mut rng, 32, 16, 1.0);
        let mut x_ref = vec![x.clone()];
        s.forward_batch(&mut exec, &mut x_ref, &mut ws);
        for p in [Precision::Bf16, Precision::Int8] {
            let t = quantize_stack(&s, full.clone(), p);
            let mut x_q = vec![x.clone()];
            t.forward_batch(&mut exec, &mut x_q, &mut ws);
            let mut d2 = 0.0f64;
            let mut r2 = 0.0f64;
            for (a, b) in x_q[0].data.iter().zip(&x_ref[0].data) {
                d2 += ((a - b) as f64).powi(2);
                r2 += (*b as f64).powi(2);
            }
            let rel = (d2 / r2).sqrt();
            assert!(rel > 0.0 && rel < 0.2,
                    "{p:?}: end-to-end rel err {rel} out of range");
        }
    }

    #[test]
    fn tier_stacks_share_plan_sizes_with_the_source() {
        // workspace planning depends only on shapes, so tier stacks
        // never change the engine's memory plan
        let s = source(3, true);
        let t = quantize_stack(&s, ss_variants(3), Precision::Int8);
        assert_eq!(s.plan_sizes(4, 64), t.plan_sizes(4, 64));
    }

    #[test]
    #[should_panic(expected = "tier variant list")]
    fn depth_mismatch_is_a_construction_bug() {
        let s = source(2, false);
        quantize_stack(&s, ss_variants(3), Precision::Bf16);
    }
}
