//! Mini thread-pool runtime (S15) — the crate cache has no tokio, so
//! the coordinator's concurrency is built on std threads: a fixed-size
//! worker pool with a shared injector queue and graceful shutdown.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<std::collections::VecDeque<Job>>,
    available: Condvar,
    shutdown: AtomicBool,
}

/// Fixed-size thread pool. Dropping the pool joins all workers after
/// draining queued jobs.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn a pool with `size` workers (min 1).
    pub fn new(size: usize) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(std::collections::VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let workers = (0..size.max(1))
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("ssaformer-worker-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// Submit a job. Panics if the pool is shut down.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        assert!(!self.shared.shutdown.load(Ordering::Acquire),
                "execute on shut-down pool");
        self.shared.queue.lock().unwrap().push_back(Box::new(job));
        self.shared.available.notify_one();
    }

    /// Number of queued (not yet started) jobs.
    pub fn backlog(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    /// Signal shutdown and join workers, draining remaining jobs.
    pub fn shutdown(mut self) {
        self.begin_shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    fn begin_shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.available.notify_all();
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.begin_shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break Some(j);
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    break None;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        match job {
            Some(j) => j(),
            None => return,
        }
    }
}

/// One-shot cancellation token shared between coordinator components.
#[derive(Clone, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn cancel(&self) {
        self.0.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::time::Duration;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.shutdown();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn drop_joins_and_drains() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..20 {
                let c = counter.clone();
                pool.execute(move || {
                    std::thread::sleep(Duration::from_millis(1));
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
        } // drop
        assert_eq!(counter.load(Ordering::Relaxed), 20);
    }

    #[test]
    fn jobs_run_concurrently() {
        let pool = ThreadPool::new(4);
        let t0 = std::time::Instant::now();
        let done = Arc::new(AtomicUsize::new(0));
        for _ in 0..4 {
            let d = done.clone();
            pool.execute(move || {
                std::thread::sleep(Duration::from_millis(50));
                d.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.shutdown();
        let elapsed = t0.elapsed();
        assert_eq!(done.load(Ordering::Relaxed), 4);
        // 4 × 50ms jobs on 4 workers should take ≈50ms, not 200ms
        assert!(elapsed < Duration::from_millis(150), "{elapsed:?}");
    }

    #[test]
    fn cancel_token() {
        let t = CancelToken::new();
        let t2 = t.clone();
        assert!(!t.is_cancelled());
        t2.cancel();
        assert!(t.is_cancelled());
    }
}
