//! Mini thread-pool runtime (S15) — the crate cache has no tokio, so
//! the coordinator's concurrency is built on std threads: a fixed-size
//! worker pool with a shared injector queue and graceful shutdown.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<std::collections::VecDeque<Job>>,
    available: Condvar,
    shutdown: AtomicBool,
}

/// Fixed-size thread pool. Dropping the pool joins all workers after
/// draining queued jobs.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn a pool with `size` workers (min 1).
    pub fn new(size: usize) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(std::collections::VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let workers = (0..size.max(1))
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("ssaformer-worker-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// Submit a job. Panics if the pool is shut down.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        assert!(!self.shared.shutdown.load(Ordering::Acquire),
                "execute on shut-down pool");
        self.shared.queue.lock().unwrap().push_back(Box::new(job));
        self.shared.available.notify_one();
    }

    /// Number of queued (not yet started) jobs.
    pub fn backlog(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    /// Number of worker threads in the pool.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Fork-join parallel loop: runs `f(i)` for every `i in 0..tasks`
    /// across the pool and blocks until all of them complete. The caller
    /// executes one task inline, so a pool of W workers plus the caller
    /// gives W+1 lanes. Task results must be communicated through the
    /// closure's captures (e.g. disjoint `&mut` regions behind raw
    /// pointers); the borrow is safe because this function does not
    /// return until every task has finished, even when a task panics
    /// (the panic is re-raised on the caller after the join).
    ///
    /// Must not be called from inside a pool job of the same pool: the
    /// blocked caller would occupy a worker and can deadlock a saturated
    /// pool. The `kernels::` layer keeps nested work sequential for this
    /// reason.
    pub fn scope_for(&self, tasks: usize, f: impl Fn(usize) + Sync) {
        if tasks == 0 {
            return;
        }
        let f_ref: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: every job submitted below is awaited via the latch
        // before this frame returns, so the 'static lifetime is never
        // actually relied upon past the borrow of `f`.
        let f_static: &'static (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute(f_ref) };
        let pending = tasks - 1;
        let latch = Arc::new(Latch::new(pending));
        for i in 0..pending {
            let latch = latch.clone();
            self.execute(move || {
                let ok = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| f_static(i)))
                    .is_ok();
                latch.complete(ok);
            });
        }
        // the caller contributes the last task instead of idling
        let own = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| f_static(tasks - 1)));
        latch.wait();
        match own {
            Err(payload) => std::panic::resume_unwind(payload),
            Ok(()) if latch.poisoned() => panic!("scope_for: pooled task panicked"),
            Ok(()) => {}
        }
    }

    /// Signal shutdown and join workers, draining remaining jobs.
    pub fn shutdown(mut self) {
        self.begin_shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    fn begin_shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.available.notify_all();
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.begin_shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break Some(j);
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    break None;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        match job {
            Some(j) => j(),
            None => return,
        }
    }
}

/// Countdown latch for `scope_for`: tracks outstanding pooled tasks and
/// whether any of them panicked.
struct Latch {
    remaining: Mutex<usize>,
    all_done: Condvar,
    poisoned: AtomicBool,
}

impl Latch {
    fn new(count: usize) -> Self {
        Latch {
            remaining: Mutex::new(count),
            all_done: Condvar::new(),
            poisoned: AtomicBool::new(false),
        }
    }

    fn complete(&self, ok: bool) {
        if !ok {
            self.poisoned.store(true, Ordering::Release);
        }
        let mut rem = self.remaining.lock().unwrap();
        *rem -= 1;
        if *rem == 0 {
            self.all_done.notify_all();
        }
    }

    fn wait(&self) {
        let mut rem = self.remaining.lock().unwrap();
        while *rem > 0 {
            rem = self.all_done.wait(rem).unwrap();
        }
    }

    fn poisoned(&self) -> bool {
        self.poisoned.load(Ordering::Acquire)
    }
}

/// One-shot cancellation token shared between coordinator components.
#[derive(Clone, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn cancel(&self) {
        self.0.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::time::Duration;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.shutdown();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn drop_joins_and_drains() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..20 {
                let c = counter.clone();
                pool.execute(move || {
                    std::thread::sleep(Duration::from_millis(1));
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
        } // drop
        assert_eq!(counter.load(Ordering::Relaxed), 20);
    }

    #[test]
    fn jobs_run_concurrently() {
        let pool = ThreadPool::new(4);
        let t0 = std::time::Instant::now();
        let done = Arc::new(AtomicUsize::new(0));
        for _ in 0..4 {
            let d = done.clone();
            pool.execute(move || {
                std::thread::sleep(Duration::from_millis(50));
                d.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.shutdown();
        let elapsed = t0.elapsed();
        assert_eq!(done.load(Ordering::Relaxed), 4);
        // 4 × 50ms jobs on 4 workers should take ≈50ms, not 200ms
        assert!(elapsed < Duration::from_millis(150), "{elapsed:?}");
    }

    #[test]
    fn scope_for_covers_every_index_once() {
        let pool = ThreadPool::new(3);
        let hits: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
        pool.scope_for(hits.len(), |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn scope_for_writes_borrowed_output() {
        let pool = ThreadPool::new(4);
        let mut out = vec![0usize; 64];
        // disjoint &mut access through a raw pointer, as the kernels do
        struct Ptr(*mut usize);
        unsafe impl Send for Ptr {}
        unsafe impl Sync for Ptr {}
        let p = Ptr(out.as_mut_ptr());
        pool.scope_for(out.len(), |i| unsafe {
            *p.0.add(i) = i * i;
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn scope_for_zero_and_one_tasks() {
        let pool = ThreadPool::new(2);
        pool.scope_for(0, |_| panic!("must not run"));
        let ran = AtomicUsize::new(0);
        pool.scope_for(1, |i| {
            assert_eq!(i, 0);
            ran.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ran.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn scope_for_propagates_panics_after_join() {
        let pool = ThreadPool::new(2);
        let done = Arc::new(AtomicUsize::new(0));
        let d = done.clone();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.scope_for(8, |i| {
                if i == 3 {
                    panic!("task 3 exploded");
                }
                d.fetch_add(1, Ordering::Relaxed);
            });
        }));
        assert!(result.is_err());
        // all non-panicking tasks still completed before the join returned
        assert_eq!(done.load(Ordering::Relaxed), 7);
        // the pool is still usable afterwards
        pool.scope_for(4, |_| {
            done.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(done.load(Ordering::Relaxed), 11);
    }

    #[test]
    fn cancel_token() {
        let t = CancelToken::new();
        let t2 = t.clone();
        assert!(!t.is_cancelled());
        t2.cancel();
        assert!(t.is_cancelled());
    }
}
