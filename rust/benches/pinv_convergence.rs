//! E6 — sec 7 eq 11: convergence of the iterative pseudoinverse.
//!
//! Compares the paper's order-7 iteration against the cubic order-3
//! baseline and the exact SVD pinv: residual ‖AZ−I‖ per iteration,
//! iterations-to-tolerance, and wall-clock per target accuracy, on
//! landmark softmax blocks of varying conditioning.
//!
//! Run: cargo bench --bench pinv_convergence

use ssaformer::benchkit::{banner, bench, fmt_duration, Table};
use ssaformer::linalg::{self, Matrix};
use ssaformer::rngx::Rng;
use std::time::Duration;

fn softmax_block(rng: &mut Rng, c: usize, d: usize, ridge: f64) -> Matrix {
    let q = Matrix::from_fn(c, d, |_, _| rng.normal());
    let k = Matrix::from_fn(c, d, |_, _| rng.normal());
    let mut s = linalg::matmul(&q, &k.transpose()).scale(1.0 / (d as f64).sqrt());
    linalg::row_softmax_inplace(&mut s);
    s.add_scaled_identity(ridge)
}

fn cond(a: &Matrix) -> f64 {
    let s = linalg::singular_values(a);
    s[0] / s[s.len() - 1].max(1e-300)
}

fn main() {
    banner("E6a — residual ‖AZ−I‖max per iteration (c=32 softmax block)",
           "order-7 (paper eq 11) vs order-3 Newton-Schulz");
    let mut rng = Rng::new(1);
    let a = softmax_block(&mut rng, 32, 32, 0.0);
    println!("condition number: {:.1e}\n", cond(&a));
    let mut t = Table::new(&["iter", "ord-7 residual", "ord-3 residual"]);
    for iters in [1usize, 2, 4, 6, 8, 12, 16, 20, 24] {
        let r7 = linalg::ns_residual(&a, &linalg::ns_pinv_ord7(&a, iters));
        let r3 = linalg::ns_residual(&a, &linalg::ns_pinv_ord3(&a, iters));
        t.row(&[iters.to_string(), format!("{r7:.3e}"), format!("{r3:.3e}")]);
    }
    println!("{}", t.render());

    banner("E6b — iterations to reach 1e-6 residual vs conditioning",
           "ridge added to the softmax block controls cond(A)");
    let mut t = Table::new(&["cond(A)", "ord-7 iters", "ord-3 iters"]);
    for &ridge in &[1.0, 0.1, 0.01, 0.0] {
        let mut rng = Rng::new(2);
        let a = softmax_block(&mut rng, 32, 32, ridge);
        let to_tol = |ord7: bool| -> String {
            for it in 1..=80 {
                let z = if ord7 {
                    linalg::ns_pinv_ord7(&a, it)
                } else {
                    linalg::ns_pinv_ord3(&a, it)
                };
                if linalg::ns_residual(&a, &z) < 1e-6 {
                    return it.to_string();
                }
            }
            ">80".into()
        };
        t.row(&[format!("{:.1e}", cond(&a)), to_tol(true), to_tol(false)]);
    }
    println!("{}", t.render());

    banner("E6c — wall-clock to 1e-6 residual (c sweep)",
           "ord-7 with the iteration count from E6b vs exact SVD pinv");
    let mut t = Table::new(&["c", "ord-7 (8 iters)", "SVD pinv", "speedup"]);
    let budget = Duration::from_millis(300);
    for &c in &[16usize, 32, 64, 128] {
        let mut rng = Rng::new(3);
        let a = softmax_block(&mut rng, c, 32, 0.1);
        let s_ns = bench(|| { std::hint::black_box(
            linalg::ns_pinv_ord7(&a, 8)); }, budget, 20);
        let s_svd = bench(|| { std::hint::black_box(
            linalg::pinv(&a, 1e-12)); }, budget, 20);
        t.row(&[
            c.to_string(),
            fmt_duration(s_ns.median),
            fmt_duration(s_svd.median),
            format!("{:.1}x", s_svd.median.as_secs_f64()
                    / s_ns.median.as_secs_f64()),
        ]);
    }
    println!("{}", t.render());
    println!("reading: both iterations spend most steps escaping the \
              conservative Z₀ =\nAᵀ/(‖A‖₁‖A‖∞) init (residual ≈1), then \
              ord-7 collapses the residual in one\nor two steps where \
              ord-3 needs several. On this f64 CPU path the wall-clock\n\
              is roughly at parity with one-sided-Jacobi SVD (crossing \
              over at c≈64);\nthe iteration's real value is being \
              matmul-only — it lowers into the AOT\nartifact and maps to \
              the MXU, where an SVD cannot go (DESIGN.md §Hardware).\n");
}
