//! E9 — ablations over the design choices DESIGN.md calls out:
//!   (a) eq-8 vs eq-4 middle factor (the paper's internal inconsistency)
//!   (b) ±δIₙ add-back (the actual "spectral shift")
//!   (c) landmark count c sweep (accuracy/cost frontier)
//!   (d) segment-means vs random-row landmarks
//!   (e) rank_rtol sensitivity of the δ estimator
//!
//! Run: cargo bench --bench ablation_landmarks

use ssaformer::attention::full::{attention_matrix, softmax_attention};
use ssaformer::attention::landmarks::{random_landmarks, segment_means};
use ssaformer::attention::spectral_shift::{
    spectral_shift_attention, spectral_shift_matrix_exact, MiddleForm,
    SpectralShiftConfig,
};
use ssaformer::attention::Tensor2;
use ssaformer::benchkit::{banner, bench, fmt_duration, Table};
use ssaformer::linalg::norms;
use ssaformer::rngx::Rng;
use std::time::Duration;

/// q (and k) whose landmark block A_s is genuinely rank-deficient:
/// only `r` distinct segment patterns, so the c landmark rows take r
/// distinct values and rank(A_s) ≈ r < c — the regime where δ > 0 and
/// the spectral shift matters.
fn structured_qk(rng: &mut Rng, n: usize, d: usize, c: usize, r: usize)
                 -> (Tensor2, Tensor2) {
    let l = n / c;
    let patterns: Vec<Vec<f32>> = (0..r)
        .map(|_| (0..d).map(|_| 2.0 * rng.normal() as f32).collect())
        .collect();
    let mut q = Tensor2::zeros(n, d);
    let mut k = Tensor2::zeros(n, d);
    for seg in 0..c {
        let p = &patterns[seg % r];
        for i in seg * l..(seg + 1) * l {
            for j in 0..d {
                let noise = 0.05 * rng.normal() as f32;
                q.data[i * d + j] = p[j] + noise;
                k.data[i * d + j] = p[j] - noise;
            }
        }
    }
    (q, k)
}

fn rel_err(a: &Tensor2, b: &Tensor2) -> f32 {
    let num: f32 = a.data.iter().zip(&b.data).map(|(x, y)| (x - y).abs()).sum();
    let den: f32 = b.data.iter().map(|y| y.abs()).sum();
    num / den
}

fn main() {
    let (n, d) = (512, 64);
    let mut rng = Rng::new(0);
    let q = Tensor2::randn(&mut rng, n, d, 1.0);
    let k = Tensor2::randn(&mut rng, n, d, 1.0);
    let v = Tensor2::randn(&mut rng, n, d, 1.0);
    let exact = softmax_attention(&q, &k, &v, None);

    banner("E9a — eq-8 vs eq-4 middle factor + δIₙ add-back (n=512, c=32)",
           "output rel-err vs exact attention; matrix fro-err vs S");
    let s_true = attention_matrix(&q, &k, None);
    let mut t = Table::new(&["config", "out rel-err", "matrix fro-err", "δ"]);
    for (label, form, add_id) in [
        ("eq8 + δI (default)", MiddleForm::Eq8, true),
        ("eq8, no δI", MiddleForm::Eq8, false),
        ("eq4 + δI (as printed)", MiddleForm::Eq4, true),
        ("eq4, no δI", MiddleForm::Eq4, false),
    ] {
        let mut cfg = SpectralShiftConfig::new(32);
        cfg.middle_form = form;
        cfg.add_shift_identity = add_id;
        let out = spectral_shift_attention(&q, &k, &v, &cfg);
        let (s_apx, delta) = spectral_shift_matrix_exact(
            &q, &k, 32, 0.05, form, add_id, None);
        t.row(&[
            label.into(),
            format!("{:.4}", rel_err(&out, &exact)),
            format!("{:.4}", norms::fro(&s_true.sub(&s_apx))
                    / norms::fro(&s_true)),
            format!("{delta:.4}"),
        ]);
    }
    println!("{}", t.render());
    println!("note: on gaussian q,k the landmark block is numerically \
              full-rank, so\nδ̂≈0 and all four configs coincide — the \
              honest default-regime result.\nThe structured panel below \
              is where the spectral shift activates.\n");

    banner("E9a' — same ablation, rank-deficient A_s (8 patterns, c=32)",
           "only 8 distinct segment patterns ⇒ rank(A_s)≈8. FINDING: even \
            here δ≈0 —\nthe discarded singular values of a row-softmax \
            block are ≈0, not a flat\nθ>0 tail, so tr(A)−tr(A⁺A²)≈0. The \
            paper's spectral shift never activates\non actual attention \
            factors; it requires SPSD inputs with genuinely flat\npositive \
            tails (E4, where it does win). See DESIGN.md §Findings.");
    let (qs, ks) = structured_qk(&mut rng, n, d, 32, 8);
    let vs = Tensor2::randn(&mut rng, n, d, 1.0);
    let exact_s = softmax_attention(&qs, &ks, &vs, None);
    let s_true_s = attention_matrix(&qs, &ks, None);
    let mut t = Table::new(&["config", "out rel-err", "matrix fro-err", "δ"]);
    for (label, form, add_id) in [
        ("eq8 + δI (default)", MiddleForm::Eq8, true),
        ("eq8, no δI", MiddleForm::Eq8, false),
        ("eq4 + δI (as printed)", MiddleForm::Eq4, true),
        ("eq4, no δI", MiddleForm::Eq4, false),
    ] {
        let mut cfg = SpectralShiftConfig::new(32);
        cfg.middle_form = form;
        cfg.add_shift_identity = add_id;
        let out = spectral_shift_attention(&qs, &ks, &vs, &cfg);
        let (s_apx, delta) = spectral_shift_matrix_exact(
            &qs, &ks, 32, 0.05, form, add_id, None);
        t.row(&[
            label.into(),
            format!("{:.4}", rel_err(&out, &exact_s)),
            format!("{:.4}", norms::fro(&s_true_s.sub(&s_apx))
                    / norms::fro(&s_true_s)),
            format!("{delta:.4}"),
        ]);
    }
    println!("{}", t.render());

    banner("E9b — landmark count sweep (accuracy/latency frontier)", "");
    let mut t = Table::new(&["c", "rel-err vs exact", "median time"]);
    for &c in &[8usize, 16, 32, 64, 128, 256] {
        let cfg = SpectralShiftConfig::new(c);
        let out = spectral_shift_attention(&q, &k, &v, &cfg);
        let s = bench(|| { std::hint::black_box(
            spectral_shift_attention(&q, &k, &v, &cfg)); },
            Duration::from_millis(200), 15);
        t.row(&[c.to_string(), format!("{:.4}", rel_err(&out, &exact)),
                fmt_duration(s.median)]);
    }
    println!("{}", t.render());

    banner("E9c — segment-means vs random-row landmarks (c=32)",
           "error of the dense landmark factors (5 seeds for random)");
    let c = 32;
    let seg_q = segment_means(&q, c);
    let mut t = Table::new(&["landmark scheme", "out rel-err"]);
    // segment-means via the standard path
    let cfg = SpectralShiftConfig::new(c);
    let out_seg = spectral_shift_attention(&q, &k, &v, &cfg);
    t.row(&["segment-means".into(), format!("{:.4}", rel_err(&out_seg, &exact))]);
    let _ = seg_q;
    // random rows: emulate by permuting q,k rows then segment-means of
    // the permutation ≈ random sampling with replacement-free rows
    let mut errs = Vec::new();
    for seed in 0..5 {
        let mut r2 = Rng::new(100 + seed);
        let _ql = random_landmarks(&mut r2, &q, c);
        // full pipeline with random landmarks requires the factor path;
        // approximate by shuffling rows before segment-means:
        let mut idx: Vec<usize> = (0..n).collect();
        r2.shuffle(&mut idx);
        let gather = |x: &Tensor2| {
            let mut o = Tensor2::zeros(n, d);
            for (i, &j) in idx.iter().enumerate() {
                o.row_mut(i).copy_from_slice(x.row(j));
            }
            o
        };
        let (qs, ks, vs) = (gather(&q), gather(&k), gather(&v));
        let out = spectral_shift_attention(&qs, &ks, &vs, &cfg);
        // un-permute output rows for comparison
        let mut unperm = Tensor2::zeros(n, d);
        for (i, &j) in idx.iter().enumerate() {
            unperm.row_mut(j).copy_from_slice(out.row(i));
        }
        errs.push(rel_err(&unperm, &exact));
    }
    let mean_err: f32 = errs.iter().sum::<f32>() / errs.len() as f32;
    t.row(&["random rows (mean of 5)".into(), format!("{mean_err:.4}")]);
    println!("{}", t.render());
    println!("reading: on token-order-free gaussian inputs the two \
              schemes tie (as they\nmust — exchangeability); segment-means \
              wins on real sequences with local\ncorrelation, and is the \
              scheme both Nystromformer and this paper use.\n");

    banner("E9d — rank_rtol sensitivity of δ (structured q,k, n=256, c=32)",
           "δ=0 collapses SS to Nystrom; too-large rtol truncates real \
            spectrum.\nStructured inputs (rank(A_s)≈8) so the tolerance \
            has something to find.");
    let (q2, k2) = structured_qk(&mut rng, 256, d, 32, 8);
    let s2 = attention_matrix(&q2, &k2, None);
    let mut t = Table::new(&["rank_rtol", "δ", "matrix fro-err"]);
    for &rtol in &[1e-8, 1e-4, 1e-2, 0.05, 0.2, 0.5] {
        let (s_apx, delta) = spectral_shift_matrix_exact(
            &q2, &k2, 32, rtol, MiddleForm::Eq8, true, None);
        t.row(&[
            format!("{rtol:.0e}"),
            format!("{delta:.5}"),
            format!("{:.4}", norms::fro(&s2.sub(&s_apx)) / norms::fro(&s2)),
        ]);
    }
    println!("{}", t.render());
}
